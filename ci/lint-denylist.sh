#!/usr/bin/env bash
# Repo lint deny-list (blocking in CI, runnable locally from anywhere):
#
#   1. No `.lock()/.read()/.write()` followed by a raw `.unwrap()` in
#      the Rust tree — poisoned-lock recovery must use
#      `unwrap_or_else(|e| e.into_inner())` so one panicked worker
#      cannot cascade through the serving path.
#   2. No `unsafe` code outside `rust/src/exec/kernels.rs` — the raw
#      output-pointer GEMM fan-out is the single unsafe island, and its
#      disjointness justification is machine-checked by
#      `analysis::disjoint`. New unsafe goes there or not at all.
#   3. No `SystemTime` in `rust/src/obs` — all span/latency math must
#      be monotonic (`Instant`); wall-clock steps (NTP, suspend) would
#      corrupt recorded deltas.
set -euo pipefail
cd "$(dirname "$0")/.."

status=0

if matches=$(grep -RnE '\.(lock|read|write)\(\)[[:space:]]*\.unwrap\(\)' rust/src rust/tests); then
  echo "deny-list: raw .unwrap() on a lock guard — use unwrap_or_else(|e| e.into_inner()):"
  echo "$matches"
  status=1
fi

if matches=$(grep -RnE 'unsafe([[:space:]]+(impl|fn|trait)|[[:space:]]*\{)' \
    --include='*.rs' rust/src | grep -v '^rust/src/exec/kernels.rs:'); then
  echo "deny-list: unsafe outside rust/src/exec/kernels.rs:"
  echo "$matches"
  status=1
fi

# Comment lines are exempt: the module documents the ban itself.
if matches=$(grep -RnE 'SystemTime' rust/src/obs | grep -vE '^[^:]+:[0-9]+:[[:space:]]*//'); then
  echo "deny-list: SystemTime in rust/src/obs — span math must be monotonic (Instant):"
  echo "$matches"
  status=1
fi

if [ "$status" -eq 0 ]; then
  echo "deny-list: clean"
fi
exit "$status"
