"""L2: the paper's compute graphs as JAX functions over the L1 kernels.

Everything here exists at *build* time only: `aot.py` lowers these jitted
functions to HLO text once, and the rust coordinator executes the
artifacts on PJRT. The functions implement the GCONV-chain semantics
exactly as the rust compiler lowers them (Table 2 for batch
normalization, Fig. 6 for the MobileNet block), so the numerics of the
whole three-layer stack can be validated end to end.
"""

import jax.numpy as jnp

from .kernels.gconv_pallas import batch_reduce, gconv2d

EPS = 1e-5


def bn_fp_chain(x):
    """Batch normalization forward as the Table-2 GCONV chain FP1–FP4.

    x: [B, C, H, W]. Returns (o, t1, t2) so BP can reuse the
    intermediates, mirroring the chain's producer/consumer links.
    """
    b = x.shape[0]
    flat = x.reshape(b, -1)
    # FP1: μ = Σ_b I / Nbs — a B-dimension GCONV reduction.
    mu = batch_reduce(flat, reduce="add", scale=1.0 / b)
    # FP2: t1 = I − μ (element-wise GCONV, kernel = FP1 output).
    t1 = flat - mu[None]
    # FP3: t2 = 1/sqrt(Σ t1²/Nbs + ε) — square pre + add reduce + LUT.
    var = batch_reduce(t1, pre="square", reduce="add", scale=1.0 / b)
    t2 = 1.0 / jnp.sqrt(var + EPS)
    # FP4: O = t1 × t2.
    o = t1 * t2[None]
    return o.reshape(x.shape), t1, t2


def bn_bp_chain(g_out, o, t1, t2):
    """Batch normalization backward as Table-2 BP1–BP6.

    g_out: [B, C, H, W] upstream gradient; (o, t1, t2) from the FP chain.
    """
    b = g_out.shape[0]
    g = g_out.reshape(b, -1)
    o_flat = o.reshape(b, -1)
    # BP1: t3 = Σ_b O·gO / Nbs.
    t3 = batch_reduce(g * o_flat, reduce="add", scale=1.0 / b)
    # BP2: t4 = O × t3.
    t4 = o_flat * t3[None]
    # BP3: t5 = Σ_b gO / Nbs.
    t5 = batch_reduce(g, reduce="add", scale=1.0 / b)
    # BP4: t6 = gO − t5.
    t6 = g - t5[None]
    # BP5: t7 = t6 − t4.
    t7 = t6 - t4
    # BP6: gI = t7 × t2.
    gi = t7 * t2[None]
    return gi.reshape(g_out.shape)


def bn_train(x, g_out):
    """One BN training step through the GCONV chain: (O, gI)."""
    o, t1, t2 = bn_fp_chain(x)
    gi = bn_bp_chain(g_out, o, t1, t2)
    return o, gi


def mobilenet_block(x, dw_w, pw_w):
    """The Fig. 1(a) MobileNet block as its GCONV chain (Fig. 6).

    x: [B, C, H, W]; dw_w: [C, 1, 3, 3]; pw_w: [2C, C, 1, 1].
    depthwise conv → BN → ReLU → pointwise conv → BN → ReLU, with the
    convolutions running in the L1 Pallas GCONV kernel.
    """
    y = gconv2d(x, dw_w, stride=1, pad=1, groups=x.shape[1])
    y, _, _ = bn_fp_chain(y)
    y = jnp.maximum(y, 0.0)
    y = gconv2d(y, pw_w, stride=1, pad=0, groups=1)
    y, _, _ = bn_fp_chain(y)
    return (jnp.maximum(y, 0.0),)


def mobilenet_block_ref(x, dw_w, pw_w):
    """Pure-jnp reference of the same block (no Pallas, no chain),
    used by pytest to validate the chain numerics."""
    from .kernels.ref import batchnorm_ref, gconv2d_ref

    y = gconv2d_ref(x, dw_w, stride=1, pad=1, groups=x.shape[1])
    y = batchnorm_ref(y.reshape(y.shape[0], -1)).reshape(y.shape)
    y = jnp.maximum(y, 0.0)
    y = gconv2d_ref(y, pw_w, stride=1, pad=0, groups=1)
    y = batchnorm_ref(y.reshape(y.shape[0], -1)).reshape(y.shape)
    return jnp.maximum(y, 0.0)


def gconv_step(x, k):
    """A single general convolution for the generic artifact: the shape
    the quickstart example drives from rust."""
    return (gconv2d(x, k, stride=1, pad=1, groups=1),)


def bn_train_tuple(x, g_out):
    """Tuple-returning wrapper for AOT lowering."""
    o, gi = bn_train(x, g_out)
    return (o, gi)
