"""AOT compiler: lower the L2 graphs to HLO *text* artifacts.

HLO text — not ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 serializes HloModuleProto with 64-bit instruction ids
which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shapes the artifacts are specialized for (the rust coordinator reads
# these from the manifest).
BLOCK_SHAPE = dict(batch=8, channels=16, hw=14)
BN_SHAPE = dict(batch=8, channels=32, hw=8)
GCONV_SHAPE = dict(batch=4, in_ch=8, out_ch=16, hw=12, k=3)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def artifacts():
    """(name, jitted fn, example arg specs, metadata) for every artifact."""
    b, c, hw = BLOCK_SHAPE["batch"], BLOCK_SHAPE["channels"], BLOCK_SHAPE["hw"]
    bb, bc, bhw = BN_SHAPE["batch"], BN_SHAPE["channels"], BN_SHAPE["hw"]
    g = GCONV_SHAPE
    return [
        (
            "mobilenet_block",
            model.mobilenet_block,
            [spec(b, c, hw, hw), spec(c, 1, 3, 3), spec(2 * c, c, 1, 1)],
            {
                "inputs": [[b, c, hw, hw], [c, 1, 3, 3], [2 * c, c, 1, 1]],
                "outputs": [[b, 2 * c, hw, hw]],
                **BLOCK_SHAPE,
            },
        ),
        (
            "bn_train",
            model.bn_train_tuple,
            [spec(bb, bc, bhw, bhw), spec(bb, bc, bhw, bhw)],
            {
                "inputs": [[bb, bc, bhw, bhw]] * 2,
                "outputs": [[bb, bc, bhw, bhw]] * 2,
                **BN_SHAPE,
            },
        ),
        (
            "gconv_generic",
            model.gconv_step,
            [
                spec(g["batch"], g["in_ch"], g["hw"], g["hw"]),
                spec(g["out_ch"], g["in_ch"], g["k"], g["k"]),
            ],
            {
                "inputs": [
                    [g["batch"], g["in_ch"], g["hw"], g["hw"]],
                    [g["out_ch"], g["in_ch"], g["k"], g["k"]],
                ],
                "outputs": [[g["batch"], g["out_ch"], g["hw"], g["hw"]]],
                **g,
            },
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, specs, meta in artifacts():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
