"""Pure-jnp GCONV oracle.

The reference semantics of the L1 Pallas kernels, written with plain
jax.numpy broadcasting so it is obviously correct (if slow). Pytest +
hypothesis compare `kernels.gconv_pallas` against these functions across
shapes, strides, paddings, operators and dtypes.

The 2-D GCONV primitive covers the paper's Fig. 5 pattern:

    y[b, o, i, j] = reduce_{c, ky, kx}
        main(x[b, g(o)*Cg + c, i*s + ky, j*s + kx], k[o, c, ky, kx])

with pluggable `pre` (applied to x as loaded), `main`, `reduce` and
`post` operators (paper §3.1 "Representability"), plus `groups` for the
grouped/depthwise C-dimension (`Ng` in GCONV terms).
"""

import jax.numpy as jnp

PRE_OPS = {
    None: lambda x: x,
    "square": lambda x: x * x,
    "relu": lambda x: jnp.maximum(x, 0),
}

MAIN_OPS = {
    "mul": lambda x, k: x * k,
    "add": lambda x, k: x + k,
    "sub": lambda x, k: x - k,
    "pass": lambda x, k: x,
}

REDUCE_OPS = {
    "add": lambda t, axes: t.sum(axes),
    "max": lambda t, axes: t.max(axes),
}

POST_OPS = {
    None: lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0),
    "sigmoid": lambda y: 1.0 / (1.0 + jnp.exp(-y)),
}


def out_size(n, ks, stride, pad):
    """Convolution output extent along one axis."""
    return (n + 2 * pad - ks) // stride + 1


def gconv2d_ref(
    x,
    k,
    *,
    stride=1,
    pad=0,
    groups=1,
    pre=None,
    main="mul",
    reduce="add",
    post=None,
):
    """Reference 2-D GCONV.

    x: [B, C, H, W]; k: [O, C // groups, KH, KW] -> [B, O, OH, OW].
    """
    b, c, h, w = x.shape
    o, cg, kh, kw = k.shape
    assert c % groups == 0 and o % groups == 0
    assert cg == c // groups, f"kernel C {cg} != {c}//{groups}"
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    x = PRE_OPS[pre](x)
    oh = out_size(h, kh, stride, pad)
    ow = out_size(w, kw, stride, pad)
    og = o // groups

    outs = []
    for gi in range(groups):
        xg = x[:, gi * cg : (gi + 1) * cg]  # [B, Cg, H', W']
        kg = k[gi * og : (gi + 1) * og]  # [Og, Cg, KH, KW]
        # Gather all windows: [B, Cg, KH, KW, OH, OW]
        win = jnp.stack(
            [
                jnp.stack(
                    [
                        xg[
                            :,
                            :,
                            ky : ky + (oh - 1) * stride + 1 : stride,
                            kx : kx + (ow - 1) * stride + 1 : stride,
                        ]
                        for kx in range(kw)
                    ],
                    axis=2,
                )
                for ky in range(kh)
            ],
            axis=2,
        )
        # win: [B, Cg, KH, KW, OH, OW]; kg -> [1, Og, Cg, KH, KW, 1, 1]
        t = MAIN_OPS[main](
            win[:, None], kg[None, :, :, :, :, None, None]
        )  # [B, Og, Cg, KH, KW, OH, OW]
        # kernel-independent mains ("pass") don't broadcast over Og.
        t = jnp.broadcast_to(t, (t.shape[0], og) + t.shape[2:])
        y = REDUCE_OPS[reduce](t, (2, 3, 4))
        outs.append(y)
    y = jnp.concatenate(outs, axis=1)
    return POST_OPS[post](y)


def batch_reduce_ref(x, *, pre=None, reduce="add", scale=None):
    """Reference B-dimension GCONV reduction (BN FP1/FP3 pattern).

    x: [B, ...] -> [...] ; `scale` multiplies the result (e.g. 1/B).
    """
    t = PRE_OPS[pre](x)
    y = REDUCE_OPS[reduce](t, 0)
    if scale is not None:
        y = y * scale
    return y


def batchnorm_ref(x, eps=1e-5):
    """Reference batch normalization over the batch axis (Table 2 FP)."""
    mu = x.mean(axis=0, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=0, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)
