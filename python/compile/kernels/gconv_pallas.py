"""L1: the GCONV compute hot-spot as Pallas kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
spatial ASIC PE arrays, so the TPU mapping keeps its core insight —
schedule the HBM↔on-chip traffic so overlap-reuse is exploited — but
expresses it the TPU way: each grid step owns one `(batch, output-row)`
tile, the `BlockSpec` index map slides a `KH`-row input stripe into VMEM
(the scratchpad analogue of the paper's ILS, loading `stride` new rows
per step exactly like Fig. 8(a)'s primitive), and the channel reduction
feeds the MXU through a `dot_general` when `main/reduce` is the classic
multiply/accumulate.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels lower to plain HLO for both the pytest
oracle checks and the AOT artifacts; real-TPU efficiency is *estimated*
from the BlockSpec footprint in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Operator tables mirror kernels.ref; kept tiny and static so the kernel
# specializes at trace time (the paper's PEs select main/reduce by a
# decoded instruction field, Fig. 11(b)).
_PRE = {
    None: lambda x: x,
    "square": lambda x: x * x,
    "relu": lambda x: jnp.maximum(x, 0),
}
_MAIN = {
    "mul": lambda x, k: x * k,
    "add": lambda x, k: x + k,
    "sub": lambda x, k: x - k,
    "pass": lambda x, k: x,
}
_POST = {
    None: lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0),
    "sigmoid": lambda y: 1.0 / (1.0 + jnp.exp(-y)),
}


def _out_size(n, ks, stride, pad):
    return (n + 2 * pad - ks) // stride + 1


def _gconv2d_kernel(
    x_ref, k_ref, o_ref, *, stride, kh, kw, ow, groups, pre, main, reduce
):
    """One grid step: one batch sample × one output row.

    x_ref: [1, C, H_pad, W_pad] sample view; the kernel slices the
    KH-row stripe for its output row (`stride` new rows per step — the
    Fig. 8(a) overlap primitive); k_ref: [O, Cg, KH, KW]; o_ref: [1, O, 1, OW].
    """
    row = pl.program_id(1)
    xf = x_ref[0]  # [C, H_pad, W_pad]
    stripe = jax.lax.dynamic_slice(
        xf, (0, row * stride, 0), (xf.shape[0], kh, xf.shape[2])
    )
    x = _PRE[pre](stripe)  # [C, KH, W_pad]
    k = k_ref[...]  # [O, Cg, KH, KW]
    o, cg = k.shape[0], k.shape[1]
    og = o // groups

    fast_path = main == "mul" and reduce == "add"
    acc = None
    for kx in range(kw):
        # Strided W window for this kernel column: [C, KH, OW].
        xs = jax.lax.slice(
            x, (0, 0, kx), (x.shape[0], kh, kx + (ow - 1) * stride + 1), (1, 1, stride)
        )
        if groups == 1:
            if fast_path:
                # MXU path: contract (C, KH) — a [O, C*KH] x [C*KH, OW]
                # matmul per kernel column.
                term = jnp.einsum("ckw,ock->ow", xs, k[:, :, :, kx])
            else:
                t = _MAIN[main](xs[None, :, :, :], k[:, :, :, kx][:, :, :, None])
                t = jnp.broadcast_to(t, (o,) + t.shape[1:])
                term = t.sum((1, 2)) if reduce == "add" else t.max((1, 2))
        else:
            # Grouped path; the depthwise case (groups == C) reduces to a
            # per-channel multiply — the VPU path.
            xs_g = xs.reshape(groups, cg, kh, xs.shape[2])
            k_g = k[:, :, :, kx].reshape(groups, og, cg, kh)
            if fast_path:
                term = jnp.einsum("gckw,gock->gow", xs_g, k_g).reshape(o, -1)
            else:
                t = _MAIN[main](xs_g[:, None], k_g[..., None])
                t = jnp.broadcast_to(t, (groups, og) + t.shape[2:])
                red = t.sum((2, 3)) if reduce == "add" else t.max((2, 3))
                term = red.reshape(o, -1)
        if acc is None:
            acc = term
        elif reduce == "add":
            acc = acc + term
        else:
            acc = jnp.maximum(acc, term)
    o_ref[...] = acc[None, :, None, :]


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride",
        "pad",
        "groups",
        "pre",
        "main",
        "reduce",
        "post",
        "interpret",
    ),
)
def gconv2d(
    x,
    k,
    *,
    stride=1,
    pad=0,
    groups=1,
    pre=None,
    main="mul",
    reduce="add",
    post=None,
    interpret=True,
):
    """Pallas 2-D GCONV. Shapes as `kernels.ref.gconv2d_ref`."""
    b, c, h, w = x.shape
    o, cg, kh, kw = k.shape
    assert cg == c // groups
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = _out_size(h, kh, stride, pad)
    ow = _out_size(w, kw, stride, pad)
    w_pad = x.shape[3]

    kernel = functools.partial(
        _gconv2d_kernel,
        stride=stride,
        kh=kh,
        kw=kw,
        ow=ow,
        groups=groups,
        pre=pre,
        main=main,
        reduce=reduce,
    )
    y = pl.pallas_call(
        kernel,
        grid=(b, oh),
        in_specs=[
            # Each grid step sees one sample; the KH-row stripe (the
            # sliding ILS window) is sliced in-kernel since BlockSpec
            # index maps step in whole blocks, not `stride` rows.
            pl.BlockSpec((1, c, x.shape[2], w_pad), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((o, cg, kh, kw), lambda i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o, 1, ow), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o, oh, ow), x.dtype),
        interpret=interpret,
    )(x, k)
    return _POST[post](y)


def _batch_reduce_kernel(x_ref, o_ref, *, pre, reduce, scale):
    x = _PRE[pre](x_ref[...])
    y = x.sum(0) if reduce == "add" else x.max(0)
    if scale is not None:
        y = y * scale
    o_ref[...] = y[None]


@functools.partial(
    jax.jit, static_argnames=("pre", "reduce", "scale", "interpret")
)
def batch_reduce(x, *, pre=None, reduce="add", scale=None, interpret=True):
    """Pallas B-dimension GCONV reduction (BN FP1/FP3, Table 2).

    x: [B, N] -> [N]. The N axis is tiled across the grid so each VMEM
    block holds a [B, TN] slab (kernel-covers-input in B, per Fig. 5's
    `[Nks: Nbs]`).
    """
    b, n = x.shape
    tn = n if n <= 4096 else 4096
    while n % tn:
        tn -= 1
    kernel = functools.partial(_batch_reduce_kernel, pre=pre, reduce=reduce, scale=scale)
    y = pl.pallas_call(
        kernel,
        grid=(n // tn,),
        in_specs=[pl.BlockSpec((b, tn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
    )(x)
    return y[0]
