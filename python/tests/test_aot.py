"""AOT pipeline: the lowered HLO text must exist (after `make artifacts`),
parse as HLO, and the lowering itself must be reproducible in-process."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lowering_produces_hlo_text():
    lowered = jax.jit(model.gconv_step).lower(
        jax.ShapeDtypeStruct((2, 3, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((4, 3, 3, 3), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # return_tuple=True: the root computation returns a tuple.
    assert "ROOT" in text


def test_artifact_list_is_consistent():
    names = [a[0] for a in aot.artifacts()]
    assert names == ["mobilenet_block", "bn_train", "gconv_generic"]
    for _, fn, specs, meta in aot.artifacts():
        assert len(meta["inputs"]) == len(specs)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_artifacts_on_disk_match_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    for name, meta in manifest.items():
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(200)
        assert "HloModule" in head
        assert meta["inputs"], name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_lowered_block_numerics_match_eager():
    # The artifact function evaluated through jit equals the eager chain.
    rng = np.random.default_rng(0)
    b, c, hw = (aot.BLOCK_SHAPE[k] for k in ("batch", "channels", "hw"))
    x = jnp.asarray(rng.normal(size=(b, c, hw, hw)).astype(np.float32))
    dw = jnp.asarray(rng.normal(size=(c, 1, 3, 3)).astype(np.float32))
    pw = jnp.asarray(rng.normal(size=(2 * c, c, 1, 1)).astype(np.float32))
    (jitted,) = jax.jit(model.mobilenet_block)(x, dw, pw)
    (eager,) = model.mobilenet_block(x, dw, pw)
    np.testing.assert_allclose(jitted, eager, rtol=1e-5, atol=1e-5)
