"""L1 correctness: Pallas GCONV kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, strides, paddings, groups, operators and
dtypes — the core correctness signal for the kernel layer.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gconv_pallas as gp
from compile.kernels import ref

settings.register_profile("kernel", max_examples=40, deadline=None)
settings.load_profile("kernel")


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


@st.composite
def conv_case(draw):
    b = draw(st.integers(1, 3))
    c = draw(st.integers(1, 6))
    o = draw(st.integers(1, 6))
    k = draw(st.integers(1, 3))
    stride = draw(st.integers(1, 2))
    pad = draw(st.integers(0, 1))
    # input must cover the kernel
    hw = draw(st.integers(max(k, 3), 10))
    return b, c, o, k, stride, pad, hw


@given(conv_case(), st.integers(0, 2**31 - 1))
def test_gconv2d_matches_ref(case, seed):
    b, c, o, k, stride, pad, hw = case
    x = rand((b, c, hw, hw), np.float32, seed)
    w = rand((o, c, k, k), np.float32, seed + 1)
    got = gp.gconv2d(x, w, stride=stride, pad=pad)
    want = ref.gconv2d_ref(x, w, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 2), st.integers(0, 2**31 - 1))
def test_depthwise_matches_ref(b, c, stride, seed):
    hw = 8
    x = rand((b, c, hw, hw), np.float32, seed)
    w = rand((c, 1, 3, 3), np.float32, seed + 1)
    got = gp.gconv2d(x, w, stride=stride, pad=1, groups=c)
    want = ref.gconv2d_ref(x, w, stride=stride, pad=1, groups=c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_grouped_conv(groups):
    x = rand((2, 4, 8, 8), np.float32, 0)
    w = rand((8, 4 // groups, 3, 3), np.float32, 1)
    got = gp.gconv2d(x, w, pad=1, groups=groups)
    want = ref.gconv2d_ref(x, w, pad=1, groups=groups)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("main", ["mul", "add", "sub", "pass"])
@pytest.mark.parametrize("reduce", ["add", "max"])
def test_operator_generality(main, reduce):
    # §3.1 Representability: the same kernel runs non-multiply mains and
    # max reductions (pooling, difference patterns).
    x = rand((2, 3, 7, 7), np.float32, 2)
    w = rand((4, 3, 3, 3), np.float32, 3)
    got = gp.gconv2d(x, w, main=main, reduce=reduce)
    want = ref.gconv2d_ref(x, w, main=main, reduce=reduce)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pre", [None, "square", "relu"])
@pytest.mark.parametrize("post", [None, "relu", "sigmoid"])
def test_pre_post_operators(pre, post):
    x = rand((1, 2, 6, 6), np.float32, 4)
    w = rand((2, 2, 3, 3), np.float32, 5)
    got = gp.gconv2d(x, w, pre=pre, post=post)
    want = ref.gconv2d_ref(x, w, pre=pre, post=post)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_dtypes(dtype):
    x = rand((2, 3, 6, 6), dtype, 6)
    w = rand((4, 3, 3, 3), dtype, 7)
    got = gp.gconv2d(x, w, pad=1)
    want = ref.gconv2d_ref(x, w, pad=1)
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@given(
    st.integers(1, 8),
    st.integers(1, 300),
    st.sampled_from([None, "square"]),
    st.sampled_from(["add", "max"]),
    st.integers(0, 2**31 - 1),
)
def test_batch_reduce_matches_ref(b, n, pre, reduce, seed):
    x = rand((b, n), np.float32, seed)
    scale = 1.0 / b if reduce == "add" else None
    got = gp.batch_reduce(x, pre=pre, reduce=reduce, scale=scale)
    want = ref.batch_reduce_ref(x, pre=pre, reduce=reduce, scale=scale)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_1x1_conv_is_channel_mix():
    # Pointwise conv (MobileNet): GCONV with no sliding dims.
    x = rand((2, 8, 5, 5), np.float32, 8)
    w = rand((16, 8, 1, 1), np.float32, 9)
    got = gp.gconv2d(x, w)
    want = jnp.einsum("bchw,oc->bohw", x, w[:, :, 0, 0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kernel_covering_input_is_fc():
    # §3.1: kernel size = input size models a tensor (FC) operation.
    x = rand((2, 4, 6, 6), np.float32, 10)
    w = rand((10, 4, 6, 6), np.float32, 11)
    got = gp.gconv2d(x, w)
    assert got.shape == (2, 10, 1, 1)
    want = jnp.einsum("bchw,ochw->bo", x, w)[:, :, None, None]
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
