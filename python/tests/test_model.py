"""L2 correctness: the GCONV-chain graphs vs reference implementations.

The key claims: the Table-2 batch-normalization chain computes exactly
batch normalization (forward AND backward — BP validated against
jax.grad of the reference), and the Fig. 6 MobileNet-block chain matches
a plain jnp implementation of the Fig. 1(a) block.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import batchnorm_ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("shape", [(4, 3, 5, 5), (8, 16, 4, 4), (2, 1, 7, 3)])
def test_bn_fp_chain_matches_reference(shape):
    x = rand(shape, 0)
    o, _, _ = model.bn_fp_chain(x)
    want = batchnorm_ref(x.reshape(shape[0], -1)).reshape(shape)
    np.testing.assert_allclose(o, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 3, 5, 5), (8, 8, 3, 3)])
def test_bn_bp_chain_matches_jax_grad(shape):
    # Table 2 BP1-BP6 must equal autodiff through the reference BN.
    x = rand(shape, 1)
    g_out = rand(shape, 2)

    def ref_fn(x):
        return batchnorm_ref(x.reshape(shape[0], -1)).reshape(shape)

    _, vjp = jax.vjp(ref_fn, x)
    want = vjp(g_out)[0]
    _, gi = model.bn_train(x, g_out)
    np.testing.assert_allclose(gi, want, rtol=1e-3, atol=1e-3)


def test_bn_output_statistics():
    # Normalized output: zero mean, unit variance over the batch.
    x = rand((32, 8, 4, 4), 3) * 3.0 + 1.5
    o, _, _ = model.bn_fp_chain(x)
    flat = np.asarray(o).reshape(32, -1)
    np.testing.assert_allclose(flat.mean(0), 0.0, atol=1e-4)
    np.testing.assert_allclose(flat.var(0), 1.0, atol=1e-2)


def test_mobilenet_block_matches_reference():
    b, c, hw = 4, 8, 10
    x = rand((b, c, hw, hw), 4)
    dw = rand((c, 1, 3, 3), 5)
    pw = rand((2 * c, c, 1, 1), 6)
    (got,) = model.mobilenet_block(x, dw, pw)
    want = model.mobilenet_block_ref(x, dw, pw)
    assert got.shape == (b, 2 * c, hw, hw)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_block_output_is_nonnegative():
    # Final ReLU.
    x = rand((2, 4, 6, 6), 7)
    dw = rand((4, 1, 3, 3), 8)
    pw = rand((8, 4, 1, 1), 9)
    (y,) = model.mobilenet_block(x, dw, pw)
    assert float(jnp.min(y)) >= 0.0


def test_gconv_step_shapes():
    x = rand((4, 8, 12, 12), 10)
    k = rand((16, 8, 3, 3), 11)
    (y,) = model.gconv_step(x, k)
    assert y.shape == (4, 16, 12, 12)
