//! Design-space exploration: Algorithm 1 generalizes to *any*
//! accelerator structure (paper §4.4), so sweep PE-array shapes and
//! scratchpad sizes around the Eyeriss point and report how GCONV-chain
//! performance and data movement respond.
//!
//! Run: `cargo run --release --example accelerator_explorer`

use gconv_chain::accel::configs::eyeriss;
use gconv_chain::networks::mobilenet_block;
use gconv_chain::report::{print_table, r2};
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

fn main() {
    let net = mobilenet_block(8, 32, 28);
    let base = eyeriss();

    // --- Sweep 1: array aspect ratio at constant 168 PEs. ---
    let mut rows = Vec::new();
    for (py, px) in [(4, 42), (6, 28), (12, 14), (14, 12), (28, 6), (42, 4)] {
        let mut a = base.clone();
        a.spatial[0].size = py;
        a.spatial[1].size = px;
        let r = simulate(&net, &a, SimOptions { mode: ExecMode::GconvChain, training: true });
        rows.push(vec![
            format!("{py}x{px}"),
            format!("{:.3}", r.seconds * 1e3),
            format!("{:.2e}", r.movement.gb_total()),
            r2(r.utilization),
        ]);
    }
    print_table(
        "PE-array aspect ratio (168 PEs, MobileNet block)",
        &["py x px", "ms/step", "GB words", "util"],
        &rows,
    );

    // --- Sweep 2: KLS capacity (kernel reuse depth). ---
    let mut rows = Vec::new();
    for kls in [1usize, 16, 64, 224, 512, 1024] {
        let mut a = base.clone();
        a.ls.kls = kls;
        let r = simulate(&net, &a, SimOptions { mode: ExecMode::GconvChain, training: true });
        rows.push(vec![
            kls.to_string(),
            format!("{:.3}", r.seconds * 1e3),
            format!("{:.2e}", r.movement.kernel),
            format!("{:.2e}", r.movement.gb_total()),
        ]);
    }
    print_table(
        "KLS capacity sweep (kernel words/PE)",
        &["KLS", "ms/step", "kernel words", "GB words"],
        &rows,
    );

    // --- Sweep 3: input bus width (loading bound). ---
    let mut rows = Vec::new();
    for bw in [2usize, 4, 8, 16, 32] {
        let mut a = base.clone();
        a.bw.i = bw;
        a.bw.o = bw;
        a.bw.k = bw;
        let r = simulate(&net, &a, SimOptions { mode: ExecMode::GconvChain, training: true });
        rows.push(vec![bw.to_string(), format!("{:.3}", r.seconds * 1e3), r2(r.utilization)]);
    }
    print_table("GB bus width sweep (words/cycle)", &["bw", "ms/step", "util"], &rows);
}
