//! Quickstart: compile a MobileNet block (Fig. 1(a)) into a GCONV chain,
//! map it onto Eyeriss with Algorithm 1, and compare the baseline
//! execution model against GCONV Chain.
//!
//! Run: `cargo run --release --example quickstart`

use gconv_chain::accel::configs::eyeriss;
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::mapping::{fuse_chain, map_gconv, MapMode};
use gconv_chain::networks::mobilenet_block;
use gconv_chain::report::{print_table, r2};
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

fn main() {
    // 1. A network in the layer IR (depthwise → BN → ReLU → pointwise →
    //    BN → ReLU — the Fig. 1(a) block).
    let net = mobilenet_block(8, 32, 28);
    println!("network: {} ({} layers)", net.name, net.len());

    // 2. Lower to the GCONV chain (training = FP + BP + WG) and fuse.
    let mut chain = lower_network(&net, Mode::Training);
    println!("\nGCONV chain before fusion: {} ops", chain.len());
    let stats = fuse_chain(&mut chain);
    println!(
        "after operation fusion:    {} ops (-{:.0}%)",
        chain.len(),
        100.0 * stats.length_reduction()
    );
    for e in chain.entries().iter().take(8) {
        println!("  [{}] {}", e.phase, e.op);
    }
    println!("  ...");

    // 3. Map one GCONV with Algorithm 1 and show the unrolling lists
    //    (the Fig. 9 view).
    let accel = eyeriss();
    let conv = &chain.entries().iter().find(|e| e.op.name.contains("conv_pw")).unwrap().op;
    let m = map_gconv(conv, &accel, MapMode::Gconv);
    println!("\nAlgorithm-1 mapping of `{}` on {}:", conv.name, accel.full_name);
    for (axis, entries) in m.spatial.iter().enumerate() {
        let list: Vec<String> =
            entries.iter().map(|e| format!("[{},{},{}]", e.param, e.dim, e.factor)).collect();
        println!("  spatial {}: {}", accel.spatial[axis].name, list.join(" "));
    }
    let list: Vec<String> =
        m.temporal.iter().map(|e| format!("[{},{},{}]", e.param, e.dim, e.factor)).collect();
    println!("  temporal:   {}", list.join(" "));
    println!("  PEs occupied: {}/{}", m.occupied_pes(), accel.pes());

    // 4. Simulate baseline vs GCONV Chain.
    let rows: Vec<Vec<String>> = [ExecMode::Baseline, ExecMode::GconvChain]
        .into_iter()
        .map(|mode| {
            let r = simulate(&net, &accel, SimOptions { mode, training: true });
            vec![
                format!("{mode:?}"),
                format!("{:.3}", r.seconds * 1e3),
                format!("{:.2e}", r.movement.gb_total()),
                format!("{:.2e}", r.movement.offload),
                r2(r.utilization),
            ]
        })
        .collect();
    print_table(
        "MobileNet block on Eyeriss",
        &["mode", "ms/step", "GB words", "offload words", "util"],
        &rows,
    );
}
