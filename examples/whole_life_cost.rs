//! Whole-life cost walk-through (paper §6.6): development cost versus
//! update count (Fig. 20) and total cost of ownership versus years of
//! deployment (Fig. 21), with the energy-efficiency inputs measured by
//! the simulator rather than assumed.
//!
//! Run: `cargo run --release --example whole_life_cost`

use gconv_chain::accel::configs::by_code;
use gconv_chain::accel::gpu::GpuModel;
use gconv_chain::cost::dev::{dev_cost, DevCostParams, Platform};
use gconv_chain::cost::tco::{fig21_platforms, tco};
use gconv_chain::networks::benchmark;
use gconv_chain::report::print_table;
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

/// MAC/J of a simulated platform, in GPU-relative units (GPU = 1).
fn efficiency(net_code: &str, accel_code: &str, mode: ExecMode) -> f64 {
    let net = benchmark(net_code);
    let accel = by_code(accel_code);
    let r = simulate(&net, &accel, SimOptions { mode, training: true });
    // Energy model unit ≈ 1 pJ per 16-bit MAC; total work / total energy
    // gives MAC/unit. The GPU model gives MAC/J; align units via the
    // same 1 pJ scale.
    let work: f64 = r.energy.compute; // = MACs × 1 unit
    let macs_per_unit = work / r.energy.total();
    let gpu = GpuModel::v100();
    let gpu_macs_per_unit = gpu.macs_per_joule() * 1e-12; // 1 unit = 1 pJ
    macs_per_unit / gpu_macs_per_unit
}

fn main() {
    // --- Fig. 20: development cost. ---
    let p = DevCostParams::default();
    let mut rows = Vec::new();
    for updates in [0usize, 2, 4, 6, 8, 10] {
        let mut row = vec![updates.to_string()];
        for pl in [Platform::Tip, Platform::GcCip, Platform::Lip] {
            let (hw, sw) = dev_cost(&p, pl, updates);
            row.push(format!("{:.0}k", (hw + sw) / 1e3));
        }
        rows.push(row);
    }
    print_table(
        "Development cost vs updates (Fig. 20)",
        &["updates", "TIP", "GC-CIP", "LIP"],
        &rows,
    );

    // --- Fig. 21: TCO with simulator-measured efficiencies. ---
    let gc = efficiency("MN", "ER", ExecMode::GconvChain);
    let tip = efficiency("MN", "TPU", ExecMode::Baseline);
    let lip = efficiency("MN", "DNNW", ExecMode::Baseline);
    println!(
        "\nmeasured energy efficiency vs GPU: GC-CIP {gc:.2}x, TIP {tip:.2}x, LIP {lip:.2}x"
    );
    let platforms = fig21_platforms(gc, tip, lip);
    let mut rows = Vec::new();
    for years in [1.0f64, 3.0, 5.0, 10.0] {
        let mut row = vec![format!("{years:.0}y")];
        for pf in &platforms {
            row.push(format!("{:.1}k", tco(pf, years) / 1e3));
        }
        rows.push(row);
    }
    let headers: Vec<String> = std::iter::once("horizon".to_string())
        .chain(platforms.iter().map(|p| p.name.to_string()))
        .collect();
    print_table("Total cost of ownership (Fig. 21)", &headers, &rows);

    let find = |n: &str| platforms.iter().find(|p| p.name == n).unwrap();
    for years in [3.0, 10.0] {
        let saving = 1.0 - tco(find("GC-CIP"), years) / tco(find("TIP"), years);
        println!(
            "GC-CIP vs TIP saving after {years:.0} years: {:.0}% (paper: {}%)",
            100.0 * saving,
            if years < 5.0 { 45 } else { 65 }
        );
    }
}
