//! End-to-end MobileNet v1 inference on the native GCONV execution
//! engine: lower the network to its FP GCONV chain, interpret the whole
//! chain in pure Rust (no Python, no XLA, no artifacts), and report
//! per-layer and total throughput.
//!
//! Run: `cargo run --release --example native_inference [BATCH]
//! [--threads N] [--fuse] [--model SPEC.json] [--bench-json]
//! [--serve-json]`
//!
//! * default: inference demo (batch 2, synthesized weights); with
//!   `--model PATH` the demo runs a spec-imported network instead of
//!   MobileNet;
//! * `--threads N`: run on a scoped rayon pool of N workers;
//! * `--fuse`: rewrite the chain with executable operation fusion
//!   (§4.3) before running — fewer entries, bit-identical outputs;
//! * `--bench-json`: measure the MobileNet and AlexNet FP chains on the
//!   naive oracle vs the fast execution tiers vs the fused chain
//!   (batch defaults to 1) and write `BENCH_native_exec.json` — the
//!   repo's perf trajectory artifact, also produced by
//!   `cargo bench --bench native_exec`;
//! * `--serve-json`: measure steady-state MobileNet serving (fresh
//!   executor per request vs one reused session vs the engine) and
//!   write `BENCH_serve.json` (requests/sec, p50/p99 latency,
//!   bind-amortization ratio).

use gconv_chain::args::{take_flag, take_required_string, take_usize};
use gconv_chain::exec::bench::{
    bench_network, bench_serve, input_spec, write_json, write_serve_json, NetBench,
};
use gconv_chain::exec::{with_threads, ChainExec, Tensor};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::ir::Network;
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::{alexnet, mobilenet};
use gconv_chain::report::{print_table, si};

const JSON_PATH: &str = "BENCH_native_exec.json";
const SERVE_JSON_PATH: &str = "BENCH_serve.json";

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let threads = take_usize(&mut args, "--threads");
    let bench_mode = take_flag(&mut args, "--bench-json");
    let serve_mode = take_flag(&mut args, "--serve-json");
    let fuse = take_flag(&mut args, "--fuse");
    let model = take_required_string(&mut args, "--model").unwrap_or_else(|e| {
        eprintln!("{e} (a spec-file path)");
        std::process::exit(2);
    });
    let batch_arg: Option<usize> = args.first().and_then(|a| a.parse().ok());
    let body = move || {
        if serve_mode || bench_mode {
            if model.is_some() {
                eprintln!("--model is only supported for the inference demo");
                std::process::exit(2);
            }
            if serve_mode {
                run_serve_json(threads);
            } else {
                run_bench_json(batch_arg.unwrap_or(1), threads);
            }
        } else {
            run_inference(batch_arg.unwrap_or(2), fuse, model.as_deref());
        }
    };
    with_threads(threads, body).expect("building the rayon pool failed");
}

/// Steady-state serving bench over the MobileNet FP chain at batch 1,
/// emitted as `BENCH_serve.json`.
fn run_serve_json(requested_threads: usize) {
    let threads = match requested_threads {
        0 => rayon::current_num_threads(),
        n => n,
    };
    println!("serve bench: MN, 8 requests — per-request vs session vs engine…");
    let b = bench_serve("MN", 8, 4).expect("serve bench failed");
    println!(
        "  {}: per-request {:.2} req/s | session {:.2} req/s (p50 {:.2} ms, p99 {:.2} ms) | \
         engine {:.2} req/s | speedup {} | bind amortization {} | bit-identical: {}",
        b.net,
        b.per_request_rps(),
        b.session_rps(),
        b.p50_s * 1e3,
        b.p99_s * 1e3,
        b.engine_rps(),
        match b.speedup() {
            Some(x) => format!("{x:.2}x"),
            None => "n/a".to_string(),
        },
        match b.bind_amortization() {
            Some(x) => format!("{x:.0}x"),
            None => "n/a".to_string(),
        },
        b.bit_identical
    );
    let ok = b.bit_identical;
    write_serve_json(SERVE_JSON_PATH, &[b], threads).expect("writing serve JSON failed");
    println!("wrote {SERVE_JSON_PATH}");
    if !ok {
        eprintln!("FAIL: a serving path diverged from the per-request outputs");
        std::process::exit(1);
    }
}

/// Naive-vs-fast bench over the MobileNet and AlexNet FP chains,
/// emitted as `BENCH_native_exec.json`.
fn run_bench_json(batch: usize, requested_threads: usize) {
    let threads = match requested_threads {
        0 => rayon::current_num_threads(),
        n => n,
    };
    let nets = [mobilenet(batch), alexnet(batch)];
    let mut results: Vec<NetBench> = Vec::new();
    for net in &nets {
        println!(
            "benchmarking {} (batch {batch}) — naive oracle vs fast tiers vs fused…",
            net.name
        );
        let b = bench_network(net, 2).expect("bench run failed");
        print_net_summary(&b);
        results.push(b);
    }
    write_json(JSON_PATH, &results, threads).expect("writing bench JSON failed");
    println!("wrote {JSON_PATH} ({} networks, {threads} threads)", results.len());
    if results.iter().any(|b| !b.bit_identical || !b.fused_bit_identical) {
        eprintln!("FAIL: a fast or fused path diverged from the naive oracle");
        std::process::exit(1);
    }
}

fn print_net_summary(b: &NetBench) {
    let speedup = match b.speedup() {
        Some(x) => format!("{x:.1}x"),
        None => "n/a".to_string(),
    };
    let fuse = match b.fusion_speedup() {
        Some(x) => format!("{x:.2}x"),
        None => "n/a".to_string(),
    };
    println!(
        "  {}: naive {:.2}s | fast {:.2}s ({:.2} Gops/s) | fused {:.2}s | {} | fuse {} \
         (chain -{:.0}%) | bit-identical: {}",
        b.net,
        b.naive_s,
        b.fast_s,
        b.fast_gops(),
        b.fused_s,
        speedup,
        fuse,
        b.chain_reduction() * 100.0,
        b.bit_identical && b.fused_bit_identical
    );
}

/// The original demo: one FP chain on the fast tiers, with a per-layer
/// throughput table. Default network: MobileNet; `--model PATH` runs a
/// spec-imported network instead (batch overridden to the CLI batch).
/// With `fuse`, the chain is rewritten by executable operation fusion
/// first.
fn run_inference(batch: usize, fuse: bool, model: Option<&str>) {
    let net: Network = match model {
        Some(path) => {
            let spec = gconv_chain::frontend::load_spec(std::path::Path::new(path))
                .expect("loading the model spec failed");
            gconv_chain::frontend::build_with_batch(&spec, Some(batch))
                .expect("building the model spec failed")
        }
        None => mobilenet(batch),
    };
    let mut chain = lower_network(&net, Mode::Inference);
    if fuse {
        let stats = fuse_executable(&mut chain);
        println!(
            "operation fusion: {} → {} entries (-{:.0}%)",
            stats.before,
            stats.after,
            stats.length_reduction() * 100.0
        );
    }
    println!(
        "{}: {} GCONV entries, {} main ops per batch of {batch}",
        net.name,
        chain.len(),
        si(chain.total_work() as f64)
    );

    let mut exec = ChainExec::new(chain);
    let (input_name, dims) = input_spec(&net).expect("network has no input layer");
    exec.set_input(&input_name, Tensor::rand(&dims, 42, 1.0));
    let report = exec.run_last().expect("native execution failed");

    // Per-layer table: one row per IR layer (chain entries grouped by
    // the op-name prefix before the phase suffix, e.g. "bn3.FP2" → bn3).
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cur: Option<(String, f64, usize, usize)> = None;
    for e in &report.entries {
        let layer = e.name.split('.').next().unwrap_or(&e.name).to_string();
        match &mut cur {
            Some((name, secs, work, n)) if *name == layer => {
                *secs += e.seconds;
                *work += e.work;
                *n += 1;
            }
            _ => {
                if let Some((name, secs, work, n)) = cur.take() {
                    rows.push(layer_row(name, secs, work, n));
                }
                cur = Some((layer, e.seconds, e.work, 1));
            }
        }
    }
    if let Some((name, secs, work, n)) = cur.take() {
        rows.push(layer_row(name, secs, work, n));
    }
    print_table(
        &format!("{} FP chain on the native backend (batch {batch})", net.name),
        &["layer", "gconvs", "main ops", "ms", "Gops/s"],
        &rows,
    );

    let out = &report.outputs[0];
    let probs = out.data();
    let top = probs
        .iter()
        .take(1000)
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, p)| (i, *p))
        .unwrap_or((0, 0.0));
    println!("sample 0: argmax class {} (p = {:.4}), output volume {}", top.0, top.1, out);

    let throughput = batch as f64 / report.total_s;
    println!(
        "total: {:.2} s wall, {} main ops, {} ops/s, {:.3} samples/s",
        report.total_s,
        si(report.total_work() as f64),
        si(report.work_rate()),
        throughput
    );
    assert!(
        throughput.is_finite() && throughput > 0.0,
        "throughput must be finite and non-zero"
    );
}

fn layer_row(name: String, secs: f64, work: usize, n: usize) -> Vec<String> {
    let gops = if secs > 0.0 { work as f64 / secs / 1e9 } else { 0.0 };
    vec![
        name,
        n.to_string(),
        si(work as f64),
        format!("{:.2}", secs * 1e3),
        format!("{gops:.2}"),
    ]
}
