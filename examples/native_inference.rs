//! End-to-end MobileNet v1 inference on the native GCONV execution
//! engine: lower the network to its FP GCONV chain, interpret the whole
//! chain in pure Rust (no Python, no XLA, no artifacts), and report
//! per-layer and total throughput.
//!
//! Run: `cargo run --release --example native_inference [BATCH]`
//! (default batch 2; weights are synthesized deterministically).

use gconv_chain::exec::{ChainExec, Tensor};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::networks::mobilenet;
use gconv_chain::report::{print_table, si};

fn main() {
    let batch: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(2);
    let net = mobilenet(batch);
    let chain = lower_network(&net, Mode::Inference);
    println!(
        "{}: {} GCONV entries, {} main ops per batch of {batch}",
        net.name,
        chain.len(),
        si(chain.total_work() as f64)
    );

    let mut exec = ChainExec::new(chain);
    exec.set_input("data.data", Tensor::rand(&[batch, 3, 224, 224], 42, 1.0));
    let report = exec.run_last().expect("native execution failed");

    // Per-layer table: one row per IR layer (chain entries grouped by
    // the op-name prefix before the phase suffix, e.g. "bn3.FP2" → bn3).
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut cur: Option<(String, f64, usize, usize)> = None;
    for e in &report.entries {
        let layer = e.name.split('.').next().unwrap_or(&e.name).to_string();
        match &mut cur {
            Some((name, secs, work, n)) if *name == layer => {
                *secs += e.seconds;
                *work += e.work;
                *n += 1;
            }
            _ => {
                if let Some((name, secs, work, n)) = cur.take() {
                    rows.push(layer_row(name, secs, work, n));
                }
                cur = Some((layer, e.seconds, e.work, 1));
            }
        }
    }
    if let Some((name, secs, work, n)) = cur.take() {
        rows.push(layer_row(name, secs, work, n));
    }
    print_table(
        &format!("MobileNet FP chain on the native backend (batch {batch})"),
        &["layer", "gconvs", "main ops", "ms", "Gops/s"],
        &rows,
    );

    let out = &report.outputs[0];
    let probs = out.data();
    let top = probs
        .iter()
        .take(1000)
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, p)| (i, *p))
        .unwrap_or((0, 0.0));
    println!("sample 0: argmax class {} (p = {:.4}), output volume {}", top.0, top.1, out);

    let throughput = batch as f64 / report.total_s;
    println!(
        "total: {:.2} s wall, {} main ops, {} ops/s, {:.3} samples/s",
        report.total_s,
        si(report.total_work() as f64),
        si(report.work_rate()),
        throughput
    );
    assert!(
        throughput.is_finite() && throughput > 0.0,
        "throughput must be finite and non-zero"
    );
}

fn layer_row(name: String, secs: f64, work: usize, n: usize) -> Vec<String> {
    let gops = if secs > 0.0 { work as f64 / secs / 1e9 } else { 0.0 };
    vec![
        name,
        n.to_string(),
        si(work as f64),
        format!("{:.2}", secs * 1e3),
        format!("{gops:.2}"),
    ]
}
