//! Training through the GCONV chain: drive the Table-2 batch-norm
//! FP+BP artifact over a stream of synthetic mini-batches and verify the
//! analytic gradient invariants hold at every step — the chain's
//! backward pass is real autodiff-grade math, not a simulator estimate.
//!
//! Run: `make artifacts && cargo run --release --example train_bn_gconv`

use gconv_chain::prop::Rng;
use gconv_chain::runtime::{literal_f32, to_vec_f32, Runtime};

fn main() {
    let Ok(mut rt) = Runtime::cpu("artifacts") else {
        eprintln!("PJRT unavailable");
        return;
    };
    if !rt.available("bn_train") {
        eprintln!("artifacts missing — run `make artifacts` first");
        return;
    }

    let (b, c, hw) = (8usize, 32usize, 8usize);
    let n = b * c * hw * hw;
    let feat = c * hw * hw;
    let dims = [b as i64, c as i64, hw as i64, hw as i64];
    let mut rng = Rng::new(123);

    println!("step | ||x||      ||gI||     max|mean|  max|var-1|  sum(gI)   <gI,O>");
    for step in 0..10 {
        // Synthetic data drifts over steps (scale grows) — BN must keep
        // normalizing regardless.
        let scale = 1.0 + step as f32 * 0.5;
        let x: Vec<f32> = (0..n).map(|_| (rng.f64() as f32 - 0.5) * scale).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.f64() as f32 - 0.5).collect();
        let out = rt
            .execute("bn_train", &[literal_f32(&x, &dims).unwrap(), literal_f32(&g, &dims).unwrap()])
            .unwrap();
        let o = to_vec_f32(&out[0]).unwrap();
        let gi = to_vec_f32(&out[1]).unwrap();

        // Per-feature invariants (spot-checked on a stride of features).
        let mut max_mean = 0f64;
        let mut max_var = 0f64;
        let mut max_sum = 0f64;
        let mut max_dot = 0f64;
        for f in (0..feat).step_by(61) {
            let (mut m, mut v, mut s, mut d) = (0f64, 0f64, 0f64, 0f64);
            for bi in 0..b {
                m += o[bi * feat + f] as f64;
                s += gi[bi * feat + f] as f64;
                d += (gi[bi * feat + f] * o[bi * feat + f]) as f64;
            }
            m /= b as f64;
            for bi in 0..b {
                v += (o[bi * feat + f] as f64 - m).powi(2);
            }
            v /= b as f64;
            max_mean = max_mean.max(m.abs());
            max_var = max_var.max((v - 1.0).abs());
            max_sum = max_sum.max(s.abs());
            max_dot = max_dot.max(d.abs());
        }
        let norm = |v: &[f32]| (v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt();
        println!(
            "{step:>4} | {:>9.3} {:>9.3}  {:>9.2e} {:>9.2e} {:>9.2e} {:>9.2e}",
            norm(&x),
            norm(&gi),
            max_mean,
            max_var,
            max_sum,
            max_dot
        );
        assert!(max_mean < 1e-3 && max_var < 5e-2, "BN forward broke at step {step}");
        assert!(max_sum < 1e-2 && max_dot < 1e-2, "BN backward broke at step {step}");
    }
    println!("\nall gradient invariants held across 10 training steps ✓");
}
