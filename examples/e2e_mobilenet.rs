//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. **Numerics** — serve 256 batched requests through the compiled
//!    MobileNet-block GCONV chain (L1 Pallas kernel → L2 JAX graph →
//!    HLO-text artifact → rust PJRT), reporting latency + throughput.
//! 2. **Simulation** — run the full MobileNet training workload through
//!    the accelerator model on all five Table-4 accelerators and report
//!    the paper's headline metric (end-to-end speedup, Fig. 14).
//!
//! Run: `make artifacts && cargo run --release --example e2e_mobilenet`

use gconv_chain::accel::configs::{by_code, ACCEL_CODES};
use gconv_chain::coordinator::{ChainExecutor, Request};
use gconv_chain::networks::benchmark;
use gconv_chain::prop::Rng;
use gconv_chain::report::{geomean, print_table, r2};
use gconv_chain::runtime::literal_f32;
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

fn main() {
    numerics();
    simulation();
}

/// Part 1: real numerics through the PJRT runtime.
fn numerics() {
    let (b, c, hw) = (8usize, 16usize, 14usize);
    let mut rng = Rng::new(7);
    let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f64() as f32 - 0.5).collect() };
    let dw = literal_f32(&rand(c * 9), &[c as i64, 1, 3, 3]).unwrap();
    let pw = literal_f32(&rand(2 * c * c), &[2 * c as i64, c as i64, 1, 1]).unwrap();

    let Ok(mut exec) = ChainExecutor::new(
        "artifacts",
        "mobilenet_block",
        &[b as i64, c as i64, hw as i64, hw as i64],
        2 * c * hw * hw,
        vec![dw, pw],
    ) else {
        eprintln!("artifacts missing — run `make artifacts` first");
        return;
    };

    let total = 256u64;
    let mut responses = Vec::new();
    for id in 0..total {
        exec.submit(Request { id, data: rand(c * hw * hw) }).unwrap();
        // Dynamic batching: execute whenever a full batch is ready.
        responses.extend(exec.step(false).unwrap());
    }
    responses.extend(exec.drain().unwrap());
    assert_eq!(responses.len(), total as usize);
    // Sanity: outputs are post-ReLU.
    assert!(responses.iter().all(|r| r.data.iter().all(|&v| v >= 0.0)));

    let s = exec.stats();
    let mut lat: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    let p99 = lat[lat.len() * 99 / 100];
    println!("=== E2E numerics: MobileNet-block chain on PJRT (CPU) ===");
    println!(
        "served {} samples in {} batches of {b}: {:.1} samples/s",
        s.samples,
        s.batches,
        s.throughput()
    );
    println!("latency p50 {:.3} ms, p99 {:.3} ms", p50 * 1e3, p99 * 1e3);
}

/// Part 2: the paper's headline metric on the full MobileNet.
fn simulation() {
    let net = benchmark("MN");
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for acode in ACCEL_CODES {
        let accel = by_code(acode);
        let base = simulate(&net, &accel, SimOptions { mode: ExecMode::Baseline, training: true });
        let gc = simulate(&net, &accel, SimOptions { mode: ExecMode::GconvChain, training: true });
        let speedup = base.seconds / gc.seconds;
        speedups.push(speedup);
        rows.push(vec![
            acode.to_string(),
            format!("{:.1}", base.seconds * 1e3),
            format!("{:.1}", gc.seconds * 1e3),
            r2(speedup),
            r2(base.energy.total() / gc.energy.total()),
        ]);
    }
    print_table(
        "MobileNet training step: baseline vs GCONV Chain (headline, Fig. 14)",
        &["accel", "base ms", "GCONV ms", "speedup", "energy gain"],
        &rows,
    );
    println!("geomean speedup: {:.2}x (paper reports 3.4x avg across all nets)", geomean(&speedups));
}
