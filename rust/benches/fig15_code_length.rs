//! Fig. 15: code length (instruction count) of GC-CIP vs LIP vs TIP.
#[path = "util.rs"]
mod util;
use gconv_chain::accel::baseline::tip_instruction_count;
use gconv_chain::accel::configs::{eyeriss, tpu};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::isa::chain_code_length;
use gconv_chain::mapping::{fuse_chain, map_gconv, MapMode};
use gconv_chain::report::{print_table, r2, si};
use util::*;

fn main() {
    timed("fig15", || {
        let er = eyeriss();
        let tp = tpu();
        // One coarse TIP matrix instruction (+ its loads/store) covers a
        // GB-resident tile of ~1e8 MACs.
        let tile = 100_000_000;
        let mut rows = Vec::new();
        let mut rl = Vec::new();
        let mut rt = Vec::new();
        for ncode in NETS {
            let n = net(ncode);
            let mut chain = lower_network(&n, Mode::Training);
            fuse_chain(&mut chain);
            let mappings: Vec<_> =
                chain.entries().iter().map(|e| map_gconv(&e.op, &er, MapMode::Gconv)).collect();
            let gc = chain_code_length(&chain, &mappings);
            // One layer-instruction per layer, occupying ~5 words at our
            // word granularity (opcode + shape configuration fields).
            let lip = n.len() * 5;
            let tip: usize =
                chain.entries().iter().map(|e| tip_instruction_count(&e.op, tile)).sum();
            rl.push(gc as f64 / lip as f64);
            rt.push(tip as f64 / gc as f64);
            rows.push(vec![
                ncode.to_string(),
                si(gc as f64),
                si(lip as f64),
                si(tip as f64),
                r2(gc as f64 / lip as f64),
                r2(tip as f64 / gc as f64),
            ]);
        }
        let _ = tp;
        print_table(
            "Code length (Fig. 15)",
            &["net", "GC-CIP", "LIP", "TIP", "GC/LIP", "TIP/GC"],
            &rows,
        );
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "GC-CIP/LIP avg {:.1}x (paper 5.8x); TIP/GC-CIP avg {:.1}x (paper 2.6x)",
            avg(&rl),
            avg(&rt)
        );
    });
}
