//! Shared bench-harness helpers (compiled into each bench via `#[path]`).
#![allow(dead_code)]

use gconv_chain::accel::configs::by_code;
use gconv_chain::ir::Network;
use gconv_chain::networks::benchmark;
use gconv_chain::sim::{simulate, ExecMode, SimOptions, SimResult};
use std::time::Instant;

pub const NETS: [&str; 7] = ["AN", "GLN", "DN", "MN", "ZFFR", "C3D", "CapNN"];
pub const ACCELS: [&str; 5] = ["TPU", "DNNW", "ER", "EP", "NLR"];

/// Paper §6.1 exclusions: ZFFR/C3D/CapNN are not evaluated on DNNW and
/// C3D not on the CIP baselines.
pub fn evaluated(net: &str, accel: &str) -> bool {
    if accel == "DNNW" && matches!(net, "ZFFR" | "C3D" | "CapNN") {
        return false;
    }
    if net == "C3D" && matches!(accel, "ER" | "EP" | "NLR") {
        return false;
    }
    true
}

pub fn run(net: &Network, accel: &str, mode: ExecMode) -> SimResult {
    simulate(net, &by_code(accel), SimOptions { mode, training: true })
}

pub fn net(code: &str) -> Network {
    benchmark(code)
}

/// Time a closure, printing the wall-clock the harness itself took.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("\n[bench harness: {label} regenerated in {:.2?}]", t0.elapsed());
    out
}
