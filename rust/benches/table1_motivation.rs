//! Table 1: impact of non-traditional layers on modern CNN acceleration.
#[path = "util.rs"]
mod util;
use gconv_chain::accel::baseline::replication_factor;
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::report::{pct, print_table, r2};
use gconv_chain::sim::ExecMode;
use util::*;

fn main() {
    timed("table1", || {
        let mut rows = Vec::new();
        for ncode in NETS {
            let n = net(ncode);
            let chain = lower_network(&n, Mode::Training);
            // (a) non-traditional shares.
            let layer_ratio = n.nodes().iter().filter(|x| !x.layer.is_traditional()).count() as f64
                / n.len() as f64;
            let (t, nt) = chain.work_split();
            let comp_ratio = nt as f64 / (t + nt) as f64;
            let foot: f64 = chain
                .entries()
                .iter()
                .filter(|e| !e.traditional)
                .map(|e| e.op.output_elements() as f64)
                .sum::<f64>()
                / chain.entries().iter().map(|e| e.op.output_elements() as f64).sum::<f64>();
            // (b) inefficiencies.
            let repl: f64 = {
                let num: f64 = chain.entries().iter().map(|e| replication_factor(&e.op) * e.op.input_elements() as f64).sum();
                let den: f64 = chain.entries().iter().map(|e| e.op.input_elements() as f64).sum();
                num / den
            };
            let offload: f64 = chain
                .entries()
                .iter()
                .filter(|e| !e.traditional)
                .map(|e| e.op.output_elements() as f64)
                .sum::<f64>()
                / chain.entries().iter().map(|e| e.op.output_elements() as f64).sum::<f64>();
            let util = run(&n, "DNNW", ExecMode::Baseline).utilization;
            rows.push(vec![
                ncode.to_string(),
                pct(layer_ratio),
                pct(comp_ratio),
                pct(foot),
                format!("{}x", r2(repl)),
                pct(offload),
                pct(util),
            ]);
        }
        print_table(
            "Non-traditional layer impact (Table 1)",
            &["net", "layers", "comp", "data", "TIP repl", "CIP offload", "LIP util"],
            &rows,
        );
        println!("paper layers: AN 24% GLN 13% DN 66% MN 62% ZFFR 29% C3D 52% CapNN 18%");
        println!("paper repl: AN 35x GLN 6x DN 2x MN 2x ZFFR 4x C3D 6x CapNN 3x");
    });
}
