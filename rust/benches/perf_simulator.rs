//! §Perf: wall-clock of the simulator itself — the full 7-net x 5-accel
//! x 2-mode sweep is the repository's hot path (every figure regenerates
//! from it). Tracked before/after in EXPERIMENTS.md §Perf.
#[path = "util.rs"]
mod util;
use gconv_chain::sim::ExecMode;
use std::time::Instant;
use util::*;

fn main() {
    // Warm-up (page in networks etc).
    let _ = run(&net("AN"), "ER", ExecMode::GconvChain);
    let t0 = Instant::now();
    let mut cells = 0;
    for ncode in NETS {
        let n = net(ncode);
        for acode in ACCELS {
            for mode in [ExecMode::Baseline, ExecMode::GconvChain] {
                let r = run(&n, acode, mode);
                assert!(r.seconds > 0.0);
                cells += 1;
            }
        }
    }
    let dt = t0.elapsed();
    println!(
        "full sweep: {cells} simulations in {:.3?} ({:.1} ms/sim)",
        dt,
        dt.as_secs_f64() * 1e3 / cells as f64
    );
}
