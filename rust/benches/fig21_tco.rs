//! Fig. 21: total cost of ownership over ten years, with the energy
//! efficiencies measured by the simulator.
#[path = "util.rs"]
mod util;
use gconv_chain::accel::gpu::GpuModel;
use gconv_chain::cost::tco::{fig21_platforms, tco};
use gconv_chain::report::print_table;
use gconv_chain::sim::ExecMode;
use util::*;

fn eff_vs_gpu(ncode: &str, acode: &str, mode: ExecMode) -> f64 {
    let r = run(&net(ncode), acode, mode);
    let per_unit = r.energy.compute / r.energy.total();
    per_unit / (GpuModel::v100().macs_per_joule() * 1e-12)
}

fn main() {
    timed("fig21", || {
        let gc = eff_vs_gpu("MN", "ER", ExecMode::GconvChain);
        let tip = eff_vs_gpu("MN", "TPU", ExecMode::Baseline);
        let lip = eff_vs_gpu("MN", "DNNW", ExecMode::Baseline);
        let platforms = fig21_platforms(gc, tip, lip);
        let mut rows = Vec::new();
        for y in 0..=10usize {
            let mut row = vec![format!("{y}")];
            for pf in &platforms {
                row.push(format!("{:.1}k", tco(pf, y as f64) / 1e3));
            }
            rows.push(row);
        }
        let headers: Vec<String> = std::iter::once("year".to_string())
            .chain(platforms.iter().map(|p| p.name.to_string()))
            .collect();
        print_table("Total cost of ownership (Fig. 21)", &headers, &rows);
        let find = |n: &str| platforms.iter().find(|p| p.name == n).unwrap();
        for y in [3.0, 10.0] {
            println!(
                "GC-CIP saving vs TIP at {y:.0}y: {:.0}% (paper: {}%)",
                100.0 * (1.0 - tco(find("GC-CIP"), y) / tco(find("TIP"), y)),
                if y < 5.0 { 45 } else { 65 }
            );
        }
    });
}
