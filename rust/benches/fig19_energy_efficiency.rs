//! Fig. 19: energy efficiency (iso-power performance) of GC-CIPs vs
//! TIP, LIP and a V100 GPU.
#[path = "util.rs"]
mod util;
use gconv_chain::accel::gpu::GpuModel;
use gconv_chain::report::{geomean, print_table, r2};
use gconv_chain::sim::ExecMode;
use util::*;

/// MACs per energy unit (unit ≈ 1 pJ), i.e. iso-power performance.
fn eff(r: &gconv_chain::sim::SimResult) -> f64 {
    r.energy.compute / r.energy.total()
}

fn main() {
    timed("fig19", || {
        let gpu = GpuModel::v100();
        let gpu_eff = gpu.macs_per_joule() * 1e-12; // 1 energy unit = 1 pJ
        let mut rows = Vec::new();
        let (mut vs_tip, mut vs_lip, mut vs_gpu) = (vec![], vec![], vec![]);
        for ncode in NETS {
            let n = net(ncode);
            let tip = eff(&run(&n, "TPU", ExecMode::Baseline));
            let lip = if evaluated(ncode, "DNNW") {
                eff(&run(&n, "DNNW", ExecMode::Baseline))
            } else {
                f64::NAN
            };
            let gc_er = eff(&run(&n, "ER", ExecMode::GconvChain));
            let gc_ep = eff(&run(&n, "EP", ExecMode::GconvChain));
            let best = gc_er.max(gc_ep);
            vs_tip.push(best / tip);
            if lip.is_finite() {
                vs_lip.push(best / lip);
            }
            vs_gpu.push(best / gpu_eff);
            rows.push(vec![
                ncode.to_string(),
                r2(gc_er / gpu_eff),
                r2(gc_ep / gpu_eff),
                r2(tip / gpu_eff),
                if lip.is_finite() { r2(lip / gpu_eff) } else { "-".into() },
                "1.00".to_string(),
            ]);
        }
        print_table(
            "Energy efficiency normalized to V100 (Fig. 19)",
            &["net", "GC-ER", "GC-EP", "TIP", "LIP", "GPU"],
            &rows,
        );
        println!(
            "GC-CIP vs TIP avg {:.1}x (paper 2.1x), vs LIP avg {:.1}x (paper 3.0x), vs GPU avg {:.1}x (paper 4.5x)",
            geomean(&vs_tip),
            geomean(&vs_lip),
            geomean(&vs_gpu)
        );
    });
}
