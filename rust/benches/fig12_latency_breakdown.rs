//! Fig. 12: baseline latency breakdown — where each baseline loses time
//! (traditional-only / non-traditional-only / all-busy / offload).
#[path = "util.rs"]
mod util;
use gconv_chain::report::{pct, print_table};
use gconv_chain::sim::ExecMode;
use util::*;

fn main() {
    timed("fig12", || {
        let mut rows = Vec::new();
        for acode in ACCELS {
            for ncode in NETS {
                if !evaluated(ncode, acode) {
                    continue;
                }
                let n = net(ncode);
                let r = run(&n, acode, ExecMode::Baseline);
                let t = r.seconds.max(f64::EPSILON);
                rows.push(vec![
                    format!("{acode}/{ncode}"),
                    pct(r.breakdown.all_busy / t),
                    pct(r.breakdown.trad_only / t),
                    pct(r.breakdown.nontrad_only / t),
                    pct(r.breakdown.offload / t),
                    format!("{:.1}", r.seconds * 1e3),
                ]);
            }
        }
        print_table(
            "Baseline latency breakdown (Fig. 12)",
            &["accel/net", "all-busy", "trad-only", "non-trad", "offload", "total ms"],
            &rows,
        );
        println!("paper: TPU all-busy ~31%, DNNW ~2%; EP offload ~43% of runtime");
    });
}
