//! Ablation of the two chain optimizations (§4.3): operation fusion and
//! consistent mapping.
#[path = "util.rs"]
mod util;
use gconv_chain::report::{print_table, r2};
use gconv_chain::sim::ExecMode;
use util::*;

fn main() {
    timed("ablation", || {
        let mut rows = Vec::new();
        for ncode in ["AN", "DN", "MN"] {
            let n = net(ncode);
            let full = run(&n, "ER", ExecMode::GconvChain);
            let nofuse = run(&n, "ER", ExecMode::GconvNoFusion);
            let nocons = run(&n, "ER", ExecMode::GconvNoConsistent);
            rows.push(vec![
                ncode.to_string(),
                r2(nofuse.seconds / full.seconds),
                r2(nofuse.energy.movement() / full.energy.movement()),
                format!("{} -> {}", nofuse.chain_len, full.chain_len),
                r2(nocons.seconds / full.seconds),
            ]);
        }
        print_table(
            "Chain-optimization ablation on Eyeriss (§4.3)",
            &["net", "fusion speedup", "fusion movement", "chain len", "consistent speedup"],
            &rows,
        );
        println!("paper: fusion 1.1x perf / 1.3x movement energy, -30% chain; exchange up to 3.9x loading");
    });
}
