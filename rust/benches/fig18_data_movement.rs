//! Fig. 18: data-movement energy (on-chip GB movement + offload/reload),
//! normalized to the TPU baseline.
#[path = "util.rs"]
mod util;
use gconv_chain::report::{print_table, r2};
use gconv_chain::sim::ExecMode;
use util::*;

fn main() {
    timed("fig18", || {
        let mut rows = Vec::new();
        for ncode in ["AN", "GLN", "DN", "MN"] {
            let n = net(ncode);
            let norm = run(&n, "TPU", ExecMode::Baseline).energy.movement();
            let mut row = vec![ncode.to_string()];
            for acode in ACCELS {
                let b = run(&n, acode, ExecMode::Baseline);
                let g = run(&n, acode, ExecMode::GconvChain);
                row.push(format!("{}/{}", r2(b.energy.movement() / norm), r2(g.energy.movement() / norm)));
            }
            rows.push(row);
        }
        let mut headers = vec!["net (base/GC)".to_string()];
        headers.extend(ACCELS.iter().map(|s| s.to_string()));
        print_table("Movement energy normalized to TPU baseline (Fig. 18)", &headers, &rows);
        println!("paper: GC-ER 16%, GC-EP 22% of TPU; CIP baselines dominated by offload energy");
    });
}
