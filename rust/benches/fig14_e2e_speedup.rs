//! Fig. 14: end-to-end speedup of GCONV Chain over every baseline.
#[path = "util.rs"]
mod util;
use gconv_chain::report::{geomean, print_table, r2};
use gconv_chain::sim::ExecMode;
use util::*;

fn main() {
    timed("fig14", || {
        let mut rows = Vec::new();
        let mut all = Vec::new();
        for ncode in NETS {
            let n = net(ncode);
            let mut row = vec![ncode.to_string()];
            for acode in ACCELS {
                if !evaluated(ncode, acode) {
                    row.push("-".into());
                    continue;
                }
                let b = run(&n, acode, ExecMode::Baseline);
                let g = run(&n, acode, ExecMode::GconvChain);
                let s = b.seconds / g.seconds;
                all.push(s);
                row.push(r2(s));
            }
            rows.push(row);
        }
        let mut headers = vec!["net".to_string()];
        headers.extend(ACCELS.iter().map(|s| s.to_string()));
        print_table("End-to-end speedup over baseline (Fig. 14)", &headers, &rows);
        println!(
            "average {:.2}x, max {:.2}x   (paper: avg 3.4x, max 8.2x)",
            geomean(&all),
            all.iter().cloned().fold(0.0f64, f64::max)
        );
    });
}
