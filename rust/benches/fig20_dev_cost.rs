//! Fig. 20: development cost (HW + SW NRE and updates).
#[path = "util.rs"]
mod util;
use gconv_chain::cost::dev::{dev_cost, DevCostParams, Platform};
use gconv_chain::report::print_table;
use util::timed;

fn main() {
    timed("fig20", || {
        let p = DevCostParams::default();
        let mut rows = Vec::new();
        for updates in 0..=10usize {
            let mut row = vec![updates.to_string()];
            for pl in [Platform::Tip, Platform::GcCip, Platform::Lip] {
                let (hw, sw) = dev_cost(&p, pl, updates);
                row.push(format!("{:.0}k (hw {:.0}k + sw {:.0}k)", (hw + sw) / 1e3, hw / 1e3, sw / 1e3));
            }
            rows.push(row);
        }
        print_table("Development cost vs updates (Fig. 20)", &["updates", "TIP", "GC-CIP", "LIP"], &rows);
        let total = |pl, u| {
            let (h, s) = dev_cost(&p, pl, u);
            h + s
        };
        println!(
            "TIP - GC-CIP gap after 10 updates: {:.0}k$ (paper: ~60k$)",
            (total(Platform::Tip, 10) - total(Platform::GcCip, 10)) / 1e3
        );
    });
}
