//! Naive-oracle vs fast-tier benchmark for the native GCONV execution
//! engine, with a machine-readable artifact.
//!
//! Measures the MobileNet and AlexNet inference chains end-to-end on
//! the naive per-element oracle and on the tiered fast paths (blocked
//! dot/GEMM + odometer indexing + buffer pooling), checks the outputs
//! stay bit-identical, prints per-net and per-layer tables, and writes
//! `BENCH_native_exec.json` (CI uploads it as the repo's performance
//! trajectory).
//!
//! Run:
//!   cargo bench --bench native_exec
//!   cargo bench --bench native_exec -- MN --threads 2 --runs 1
//!
//! Flags: net codes (`MN`, `AN`; default both), `--batch N` (default 1),
//! `--runs R` fast-path repetitions keeping the best (default 2),
//! `--threads N` scoped rayon pool, `--json PATH` output path.

use gconv_chain::args::{take_string, take_usize};
use gconv_chain::exec::bench::{bench_network, write_json, NetBench};
use gconv_chain::exec::with_threads;
use gconv_chain::networks::{alexnet, mobilenet};
use gconv_chain::report::print_table;

const DEFAULT_JSON: &str = "BENCH_native_exec.json";

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench` can forward a `--bench` flag; it is not ours.
    args.retain(|a| a != "--bench");
    let threads = take_usize(&mut args, "--threads");
    let runs = match take_usize(&mut args, "--runs") {
        0 => 2,
        n => n,
    };
    let batch = match take_usize(&mut args, "--batch") {
        0 => 1,
        n => n,
    };
    let json_path = take_string(&mut args, "--json").unwrap_or_else(|| DEFAULT_JSON.to_string());
    let body = move || run(&args, batch, runs, threads, &json_path);
    if let Err(e) = with_threads(threads, body) {
        eprintln!("bench failed: {e:#}");
        std::process::exit(1);
    }
}

fn run(codes: &[String], batch: usize, runs: usize, requested: usize, json_path: &str) {
    let threads = match requested {
        0 => rayon::current_num_threads(),
        n => n,
    };
    let mut nets = Vec::new();
    if codes.is_empty() || codes.iter().any(|c| c == "MN") {
        nets.push(mobilenet(batch));
    }
    if codes.is_empty() || codes.iter().any(|c| c == "AN") {
        nets.push(alexnet(batch));
    }
    if nets.is_empty() {
        eprintln!("no known net codes in {codes:?} (known: MN, AN)");
        std::process::exit(2);
    }

    let mut results: Vec<NetBench> = Vec::new();
    for net in &nets {
        eprintln!(
            "benchmarking {} (batch {batch}, {runs} fast run(s), {threads} threads)…",
            net.name
        );
        results.push(bench_network(net, runs).expect("bench run failed"));
    }

    let rows: Vec<Vec<String>> = results.iter().map(net_row).collect();
    let headers = [
        "net", "entries", "Mops", "naive s", "fast s", "naive Gops/s", "fast Gops/s", "speedup",
        "bit-id",
    ];
    print_table(
        "Native exec: naive oracle vs fast tiers (end-to-end FP chain)",
        &headers,
        &rows,
    );
    for b in &results {
        let lrows: Vec<Vec<String>> = b.layers.iter().map(layer_row).collect();
        print_table(
            &format!("{} per-layer (batch {})", b.net, b.batch),
            &["layer", "gconvs", "Mops", "naive ms", "fast ms", "speedup"],
            &lrows,
        );
    }

    write_json(json_path, &results, threads).expect("writing bench JSON failed");
    println!("wrote {json_path}");

    if results.iter().any(|b| !b.bit_identical) {
        eprintln!("FAIL: a fast path diverged from the naive oracle");
        std::process::exit(1);
    }
}

fn net_row(b: &NetBench) -> Vec<String> {
    vec![
        b.net.clone(),
        b.entries.to_string(),
        format!("{:.1}", b.work as f64 / 1e6),
        format!("{:.3}", b.naive_s),
        format!("{:.3}", b.fast_s),
        format!("{:.3}", b.naive_gops()),
        format!("{:.3}", b.fast_gops()),
        format!("{:.2}x", b.speedup()),
        b.bit_identical.to_string(),
    ]
}

fn layer_row(l: &gconv_chain::exec::bench::LayerBench) -> Vec<String> {
    vec![
        l.layer.clone(),
        l.gconvs.to_string(),
        format!("{:.1}", l.work as f64 / 1e6),
        format!("{:.2}", l.naive_s * 1e3),
        format!("{:.2}", l.fast_s * 1e3),
        format!("{:.2}x", l.speedup()),
    ]
}
