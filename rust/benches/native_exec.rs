//! Naive-oracle vs fast-tier vs fused-chain benchmark for the native
//! GCONV execution engine, with a machine-readable artifact.
//!
//! Measures benchmark inference chains end-to-end on the naive
//! per-element oracle, on the tiered fast paths (blocked dot/GEMM +
//! odometer indexing + buffer pooling), on the executable-fused
//! chain (§4.3), and on the `Precision::Fast` SIMD GEMM microkernel;
//! checks the outputs stay bit-identical on every bit-exact path and
//! within the relative-error tolerance on the Fast leg, prints per-net
//! and per-layer tables, and writes `BENCH_native_exec.json` (CI
//! uploads it as the repo's performance trajectory).
//!
//! Run:
//!   cargo bench --bench native_exec
//!   cargo bench --bench native_exec -- MN AN --threads 2 --runs 1
//!   cargo bench --bench native_exec -- MN --serve --requests 16
//!
//! Flags: net codes (any of AN GLN DN MN ZFFR C3D CapNN; default
//! MN + AN), `--model PATH` to bench an imported spec-file network
//! instead, `--batch N` (default 1), `--runs R` fast-path repetitions
//! keeping the best (default 2), `--threads N` scoped rayon pool,
//! `--json PATH` output path. Note: the naive oracle side makes the
//! heavy nets (DN, GLN, C3D, ZFFR) take minutes — CI sticks to MN + AN.
//!
//! `--serve` switches to the serving benchmark instead: each selected
//! network's batch-1 FP chain is driven request-by-request through a
//! fresh `ChainExec` (the one-shot calling convention), one reused
//! `Session`, and the coalescing `Engine`; the report
//! (`BENCH_serve.json`) carries requests/sec, p50/p99 latency and the
//! bind-amortization ratio, gated on bit-identical outputs.
//! `--requests N` (default 16) and `--max-batch N` (default 4) size
//! the request stream; `--clients C` (default 2, `0` to skip) adds a
//! concurrent-load leg driving the same stream over loopback TCP
//! through `gconv_chain::server`, reporting wire rps, p50/p99 latency,
//! the coalescing rate, and `BUSY` backpressure rejections.
//! `--degraded` adds one more TCP leg with the fault-injection
//! registry armed at a 1% wave-failure rate, reporting how much
//! rps/p99 the self-healing path costs versus the clean load leg.

use gconv_chain::args::{take_flag, take_required_string, take_string, take_usize};
use gconv_chain::exec::bench::{
    bench_network, bench_serve, write_json, write_serve_json, NetBench, ServeBench,
};
use gconv_chain::exec::with_threads;
use gconv_chain::networks::{benchmark_with_batch, BENCHMARK_CODES};
use gconv_chain::report::print_table;

const DEFAULT_JSON: &str = "BENCH_native_exec.json";
const DEFAULT_SERVE_JSON: &str = "BENCH_serve.json";

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench` can forward a `--bench` flag; it is not ours.
    args.retain(|a| a != "--bench");
    let threads = take_usize(&mut args, "--threads");
    let runs = match take_usize(&mut args, "--runs") {
        0 => 2,
        n => n,
    };
    let batch = match take_usize(&mut args, "--batch") {
        0 => 1,
        n => n,
    };
    let serve = take_flag(&mut args, "--serve");
    let requests = match take_usize(&mut args, "--requests") {
        0 => 16,
        n => n,
    };
    let max_batch = match take_usize(&mut args, "--max-batch") {
        0 => 4,
        n => n,
    };
    let clients = match take_string(&mut args, "--clients") {
        None => 2,
        Some(v) => v.parse::<usize>().unwrap_or_else(|_| {
            eprintln!("--clients expects a number, got {v:?}");
            std::process::exit(2);
        }),
    };
    let degraded = take_flag(&mut args, "--degraded");
    let model = take_required_string(&mut args, "--model").unwrap_or_else(|e| {
        eprintln!("{e} (a spec-file path)");
        std::process::exit(2);
    });
    let default_json = if serve { DEFAULT_SERVE_JSON } else { DEFAULT_JSON };
    let json_path = take_string(&mut args, "--json").unwrap_or_else(|| default_json.to_string());
    let body = move || {
        if serve {
            if model.is_some() {
                eprintln!("--model is only supported for the naive-vs-fast bench (not --serve)");
                std::process::exit(2);
            }
            run_serve(&args, requests, max_batch, clients, degraded, threads, &json_path);
        } else {
            run(&args, batch, runs, threads, &json_path, model.as_deref());
        }
    };
    if let Err(e) = with_threads(threads, body) {
        eprintln!("bench failed: {e:#}");
        std::process::exit(1);
    }
}

/// Net codes from the CLI arguments (default MN + AN).
fn select_codes(codes: &[String]) -> Vec<&'static str> {
    if codes.is_empty() {
        return vec!["MN", "AN"];
    }
    let known: Vec<&str> = BENCHMARK_CODES
        .iter()
        .copied()
        .filter(|c| codes.iter().any(|a| a == c))
        .collect();
    if known.is_empty() {
        eprintln!("no known net codes in {codes:?} (known: {BENCHMARK_CODES:?})");
        std::process::exit(2);
    }
    known
}

fn run_serve(
    codes: &[String],
    requests: usize,
    max_batch: usize,
    clients: usize,
    degraded: bool,
    requested: usize,
    json: &str,
) {
    let threads = match requested {
        0 => rayon::current_num_threads(),
        n => n,
    };
    let mut results: Vec<ServeBench> = Vec::new();
    for code in select_codes(codes) {
        eprintln!(
            "serve-benchmarking {code} (batch 1, {requests} requests, micro-batch ≤ \
             {max_batch}, {clients} load client(s), degraded={degraded}, {threads} threads)…"
        );
        results.push(
            bench_serve(code, requests, max_batch, clients, degraded)
                .expect("serve bench failed"),
        );
    }
    let rows: Vec<Vec<String>> = results.iter().map(serve_row).collect();
    print_table(
        "Serve: fresh executor per request vs bind-once session vs engine (batch 1)",
        &[
            "net",
            "reqs",
            "per-req r/s",
            "session r/s",
            "engine r/s",
            "p50 ms",
            "p99 ms",
            "speedup",
            "bind amort",
            "load r/s",
            "load p99",
            "busy",
            "deg r/s",
            "bit-id",
        ],
        &rows,
    );
    write_serve_json(json, &results, threads).expect("writing serve JSON failed");
    println!("wrote {json}");
    let wire_diverged = results.iter().any(|b| {
        !b.bit_identical
            || !b.load.as_ref().is_none_or(|l| l.bit_identical)
            || !b.degraded.as_ref().is_none_or(|d| d.bit_identical)
    });
    if wire_diverged {
        eprintln!("FAIL: a serving path diverged from the per-request outputs");
        std::process::exit(1);
    }
}

fn serve_row(b: &ServeBench) -> Vec<String> {
    vec![
        b.net.clone(),
        b.requests.to_string(),
        format!("{:.2}", b.per_request_rps()),
        format!("{:.2}", b.session_rps()),
        format!("{:.2}", b.engine_rps()),
        format!("{:.2}", b.p50_s * 1e3),
        format!("{:.2}", b.p99_s * 1e3),
        ratio(b.speedup()),
        ratio(b.bind_amortization()),
        match &b.load {
            Some(l) => format!("{:.2}", l.rps()),
            None => "n/a".to_string(),
        },
        match &b.load {
            Some(l) => format!("{:.2}", l.p99_s * 1e3),
            None => "n/a".to_string(),
        },
        match &b.load {
            Some(l) => l.busy_rejections.to_string(),
            None => "n/a".to_string(),
        },
        match &b.degraded {
            Some(d) => format!("{:.2}", d.rps()),
            None => "n/a".to_string(),
        },
        (b.bit_identical
            && b.load.as_ref().is_none_or(|l| l.bit_identical)
            && b.degraded.as_ref().is_none_or(|d| d.bit_identical))
        .to_string(),
    ]
}

fn run(
    codes: &[String],
    batch: usize,
    runs: usize,
    requested: usize,
    json_path: &str,
    model: Option<&str>,
) {
    let threads = match requested {
        0 => rayon::current_num_threads(),
        n => n,
    };
    // `--model PATH` benchmarks the imported spec *instead of* the
    // default code set (explicit codes still add their builders).
    let mut nets: Vec<gconv_chain::ir::Network> = Vec::new();
    if let Some(path) = model {
        let spec = gconv_chain::frontend::load_spec(std::path::Path::new(path))
            .expect("loading the model spec failed");
        let net = gconv_chain::frontend::build_with_batch(&spec, Some(batch))
            .expect("building the model spec failed");
        nets.push(net);
    }
    if model.is_none() || !codes.is_empty() {
        for code in select_codes(codes) {
            nets.push(benchmark_with_batch(code, batch));
        }
    }

    let mut results: Vec<NetBench> = Vec::new();
    for net in &nets {
        eprintln!(
            "benchmarking {} (batch {batch}, {runs} fast run(s), {threads} threads)…",
            net.name
        );
        results.push(bench_network(net, runs).expect("bench run failed"));
    }

    let rows: Vec<Vec<String>> = results.iter().map(net_row).collect();
    let headers = [
        "net",
        "entries",
        "Mops",
        "naive s",
        "fast s",
        "fused s",
        "simd s",
        "fast Gops/s",
        "speedup",
        "fuse x",
        "simd x",
        "Δchain",
        "bit-id",
    ];
    print_table(
        "Native exec: naive oracle vs fast tiers vs fused chain (end-to-end FP)",
        &headers,
        &rows,
    );
    for b in &results {
        let lrows: Vec<Vec<String>> = b.layers.iter().map(layer_row).collect();
        print_table(
            &format!("{} per-layer (batch {})", b.net, b.batch),
            &["layer", "gconvs", "Mops", "naive ms", "fast ms", "speedup"],
            &lrows,
        );
    }

    write_json(json_path, &results, threads).expect("writing bench JSON failed");
    println!("wrote {json_path}");

    if results
        .iter()
        .any(|b| !b.bit_identical || !b.fused_bit_identical || !b.fastp_within_tol)
    {
        eprintln!(
            "FAIL: a fast or fused path diverged from the naive oracle, or the \
             Precision::Fast leg drifted past tolerance"
        );
        std::process::exit(1);
    }
}

fn ratio(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.2}x"),
        None => "n/a".to_string(),
    }
}

fn net_row(b: &NetBench) -> Vec<String> {
    vec![
        b.net.clone(),
        format!("{}→{}", b.entries, b.fused_entries),
        format!("{:.1}", b.work as f64 / 1e6),
        format!("{:.3}", b.naive_s),
        format!("{:.3}", b.fast_s),
        format!("{:.3}", b.fused_s),
        format!("{:.3}", b.fastp_s),
        format!("{:.3}", b.fast_gops()),
        ratio(b.speedup()),
        ratio(b.fusion_speedup()),
        ratio(b.fastp_speedup()),
        format!("-{:.0}%", b.chain_reduction() * 100.0),
        (b.bit_identical && b.fused_bit_identical && b.fastp_within_tol).to_string(),
    ]
}

fn layer_row(l: &gconv_chain::exec::bench::LayerBench) -> Vec<String> {
    vec![
        l.layer.clone(),
        l.gconvs.to_string(),
        format!("{:.1}", l.work as f64 / 1e6),
        format!("{:.2}", l.naive_s * 1e3),
        format!("{:.2}", l.fast_s * 1e3),
        ratio(l.speedup()),
    ]
}
