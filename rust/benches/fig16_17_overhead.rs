//! Figs. 16/17: area and power overhead of GCONV support on Eyeriss.
#[path = "util.rs"]
mod util;
use gconv_chain::energy::overhead::{area_overhead, power_overhead, ChipBudget};
use gconv_chain::report::{pct, print_table};
use util::timed;

fn main() {
    timed("fig16_17", || {
        let b = ChipBudget::eyeriss();
        let a = area_overhead(&b);
        let p = power_overhead(&b);
        print_table(
            "GCONV-support overhead on Eyeriss (Figs. 16/17)",
            &["component", "area", "power"],
            &[
                vec!["storage (instr. buffers)".to_string(), pct(a.storage), pct(p.storage)],
                vec!["compute (main/reduce PEs)".to_string(), pct(a.compute), pct(p.compute)],
                vec!["control (decoder + FSM)".to_string(), pct(a.control), pct(p.control)],
                vec!["TOTAL".to_string(), pct(a.total()), pct(p.total())],
            ],
        );
        println!("paper: 20% area, 19% power");
    });
}
