//! Fig. 13: speedup on the convolution layers only — the GCONV mapping
//! must be no worse than each accelerator's native dataflow.
#[path = "util.rs"]
mod util;
use gconv_chain::report::{geomean, print_table, r2};
use gconv_chain::sim::ExecMode;
use util::*;

fn main() {
    timed("fig13", || {
        let mut rows = Vec::new();
        let mut all = Vec::new();
        for ncode in NETS {
            let n = net(ncode);
            let mut row = vec![ncode.to_string()];
            for acode in ACCELS {
                if !evaluated(ncode, acode) {
                    row.push("-".into());
                    continue;
                }
                let b = run(&n, acode, ExecMode::Baseline);
                let g = run(&n, acode, ExecMode::GconvChain);
                let s = b.conv_seconds / g.conv_seconds;
                all.push(s);
                row.push(r2(s));
            }
            rows.push(row);
        }
        let mut headers = vec!["net".to_string()];
        headers.extend(ACCELS.iter().map(|s| s.to_string()));
        print_table("Convolution-layer speedup (Fig. 13)", &headers, &rows);
        println!("average {:.2}x (paper: >= 1x everywhere; salient on MN & NLR)", geomean(&all));
    });
}
