//! `gconv-chain` CLI — compile networks to GCONV chains, simulate them
//! on the Table-4 accelerators, and run real chain numerics on the
//! native execution engine.

use gconv_chain::accel::configs::{by_code, ACCEL_CODES};
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::{benchmark, BENCHMARK_CODES};
use gconv_chain::report::{print_table, r2};
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

const USAGE: &str = "\
gconv-chain — GCONV Chain compiler + simulator (paper reproduction)

USAGE:
    gconv-chain chain <NET> [--inference] [--fuse]   print the GCONV chain
    gconv-chain simulate <NET> <ACCEL>       baseline vs GCONV on one pair
    gconv-chain matrix                       Fig. 14 speedup matrix
    gconv-chain run [NET] [SAMPLES] [--fuse] execute chain numerics (native)
    gconv-chain serve [NET] [REQUESTS] [--fuse] [--max-batch N]
                                             bind-once/run-many serving demo

OPTIONS:
    --threads N    run on a scoped rayon pool of N workers (default:
                   one per core) — pin for reproducible bench numbers
    --fuse         rewrite the chain with executable operation fusion
                   (§4.3) first: fewer entries, bit-identical outputs
    --max-batch N  serve: coalesce up to N single-sample requests into
                   one micro-batch session run (default 8)

    NET   = AN GLN DN MN ZFFR C3D CapNN
    ACCEL = TPU DNNW ER EP NLR";

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let threads = gconv_chain::args::take_usize(&mut args, "--threads");
    let dispatch = move || match args.first().map(String::as_str) {
        Some("chain") => cmd_chain(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("matrix") => cmd_matrix(),
        Some("run") => cmd_run(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        _ => println!("{USAGE}"),
    };
    if let Err(e) = gconv_chain::exec::with_threads(threads, dispatch) {
        eprintln!("failed to build the thread pool: {e:#}");
        std::process::exit(2);
    }
}

fn cmd_chain(args: &[String]) {
    let Some(net_code) = args.first() else {
        println!("{USAGE}");
        return;
    };
    let mode =
        if args.iter().any(|a| a == "--inference") { Mode::Inference } else { Mode::Training };
    let net = benchmark(net_code);
    let mut chain = lower_network(&net, mode);
    if args.iter().any(|a| a == "--fuse") {
        let stats = fuse_executable(&mut chain);
        println!(
            "executable operation fusion: {} → {} entries (-{:.0}%)",
            stats.before,
            stats.after,
            stats.length_reduction() * 100.0
        );
    }
    print!("{chain}");
    let (t, n) = chain.work_split();
    println!(
        "total work: {:.3e} MACs ({:.1}% non-traditional)",
        chain.total_work() as f64,
        100.0 * n as f64 / (t + n) as f64
    );
}

fn cmd_simulate(args: &[String]) {
    let (Some(net_code), Some(accel_code)) = (args.first(), args.get(1)) else {
        println!("{USAGE}");
        return;
    };
    let net = benchmark(net_code);
    let accel = by_code(accel_code);
    let rows: Vec<Vec<String>> = [ExecMode::Baseline, ExecMode::GconvChain]
        .into_iter()
        .map(|mode| {
            let r = simulate(&net, &accel, SimOptions { mode, training: true });
            vec![
                format!("{mode:?}"),
                format!("{:.3}", r.seconds * 1e3),
                format!("{:.3e}", r.movement.gb_total()),
                format!("{:.3e}", r.movement.offload),
                format!("{:.3e}", r.energy.total()),
                r2(r.utilization),
            ]
        })
        .collect();
    print_table(
        &format!("{net_code} on {accel_code} (training step)"),
        &["mode", "ms", "GB words", "offload words", "energy", "util"],
        &rows,
    );
}

fn cmd_matrix() {
    let mut rows = Vec::new();
    for code in BENCHMARK_CODES {
        let net = benchmark(code);
        let mut row = vec![code.to_string()];
        for acode in ACCEL_CODES {
            let accel = by_code(acode);
            let b = simulate(&net, &accel, SimOptions { mode: ExecMode::Baseline, training: true });
            let g =
                simulate(&net, &accel, SimOptions { mode: ExecMode::GconvChain, training: true });
            row.push(r2(b.seconds / g.seconds));
        }
        rows.push(row);
    }
    print_table(
        "End-to-end speedup of GCONV Chain over baselines (Fig. 14)",
        &["net", "TPU", "DNNW", "ER", "EP", "NLR"],
        &rows,
    );
}

fn cmd_run(args: &[String]) {
    use gconv_chain::coordinator::{ChainExecutor, Request};
    use gconv_chain::exec::bench::input_spec;
    use gconv_chain::networks::mobilenet_block;

    let mut args = args.to_vec();
    let fuse = gconv_chain::args::take_flag(&mut args, "--fuse");
    // Default workload: one MobileNet block (Fig. 1(a)); any benchmark
    // code (AN, MN, …) runs its full inference chain instead.
    let net = match args.first().map(String::as_str) {
        None => mobilenet_block(8, 16, 14),
        Some(code) => benchmark(code),
    };
    let total: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let mut chain = lower_network(&net, Mode::Inference);
    if fuse {
        let stats = fuse_executable(&mut chain);
        println!(
            "executable operation fusion: {} → {} entries (-{:.0}%)",
            stats.before,
            stats.after,
            stats.length_reduction() * 100.0
        );
    }
    let (input_name, dims) = input_spec(&net).expect("network has no input layer");
    let mut exec = ChainExecutor::native(chain, &input_name, &dims).expect("lowering failed");
    let sample_len = exec.sample_len();
    println!("executing {} on the {} backend…", net.name, exec.backend_name());

    let mut rng = gconv_chain::prop::Rng::new(42);
    let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f64() as f32 - 0.5).collect() };
    for id in 0..total {
        exec.submit(Request { id, data: rand(sample_len) }).unwrap();
    }
    let mut served = 0;
    while served < total as usize {
        let out = exec.step(true).unwrap();
        served += out.len();
    }
    let s = exec.stats();
    println!(
        "served {} samples in {} batches: {:.2} samples/s, mean latency {:.3} ms",
        s.samples,
        s.batches,
        s.throughput(),
        s.mean_latency_s * 1e3
    );
}

fn cmd_serve(args: &[String]) {
    use gconv_chain::exec::serve::Engine;
    use gconv_chain::exec::Tensor;

    let mut args = args.to_vec();
    let fuse = gconv_chain::args::take_flag(&mut args, "--fuse");
    let max_batch = match gconv_chain::args::take_usize(&mut args, "--max-batch") {
        0 => 8,
        n => n,
    };
    let code = args.first().map(String::as_str).unwrap_or("MN").to_string();
    let total: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32).max(1);

    let net = benchmark(&code);
    let (input_name, dims) = gconv_chain::exec::bench::input_spec(&net)
        .expect("network has no input layer");
    let sample_len: usize = dims[1..].iter().product();
    println!(
        "serving {code} ({input_name}, {sample_len} values/sample): {total} requests, \
         micro-batches of up to {max_batch}, fuse={fuse}…"
    );

    let mut engine = Engine::new(max_batch).with_fuse(fuse);
    let mut sample_dims = dims.clone();
    sample_dims[0] = 1;
    for id in 0..total {
        let x = Tensor::rand(&sample_dims, 0xD15_C0 ^ id, 1.0);
        engine.submit(&code, id, x.into_data()).expect("submit failed");
    }
    let responses = engine.drain().expect("serving failed");
    let s = engine.stats();
    let mut latencies: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
    latencies.sort_by(f64::total_cmp);
    let pct = |p: usize| latencies[(latencies.len() * p / 100).min(latencies.len() - 1)];
    println!(
        "served {} requests in {} micro-batches ({} coalesced, {} sessions built, \
         {} cache hits): {:.2} req/s, p50 {:.2} ms, p99 {:.2} ms",
        s.requests,
        s.batches,
        s.coalesced,
        s.sessions_built,
        s.cache_hits,
        s.throughput(),
        pct(50) * 1e3,
        pct(99) * 1e3
    );
}
