//! `gconv-chain` CLI — compile networks to GCONV chains, simulate them
//! on the Table-4 accelerators, and run real chain numerics on the
//! native execution engine. Networks come from the seven benchmark
//! builders *or* from model spec files (`--model path/to/spec.json`,
//! or any bundled spec name under `rust/specs/`).

use anyhow::{Context, Result};
use gconv_chain::accel::configs::{by_code, ACCEL_CODES};
use gconv_chain::frontend;
use gconv_chain::gconv::lower::{lower_network, Mode};
use gconv_chain::ir::Network;
use gconv_chain::mapping::fuse_executable;
use gconv_chain::networks::{resolve, resolve_with_batch, BENCHMARK_CODES};
use gconv_chain::report::{print_table, r2};
use gconv_chain::sim::{simulate, ExecMode, SimOptions};

const USAGE: &str = "\
gconv-chain — GCONV Chain compiler + simulator (paper reproduction)

USAGE:
    gconv-chain chain <NET> [--inference] [--fuse]   print the GCONV chain
    gconv-chain simulate <NET> <ACCEL>       baseline vs GCONV on one pair
    gconv-chain matrix                       Fig. 14 speedup matrix
    gconv-chain run [NET] [SAMPLES] [--fuse] execute chain numerics (native)
    gconv-chain serve [NET] [REQUESTS] [--fuse] [--max-batch N]
                                             bind-once/run-many serving demo
    gconv-chain serve NET --listen ADDR [--max-requests N]
                                             TCP serving front over the engine
    gconv-chain client ADDR [NET] [REQUESTS] drive a TCP serving front; verify
                                             responses bit-identical to a local
                                             in-process engine
    gconv-chain stats ADDR [--metrics]       fetch a serving front's live health
                                             snapshot (counters + quarantine);
                                             --metrics prints the raw Prometheus
                                             exposition (wire kind 6/7) instead
    gconv-chain profile [NET] [--fuse] [--trace-out PATH]
                                             time one request through a bound
                                             session and print the per-layer
                                             breakdown (time, share, tier, GOP/s)
    gconv-chain specs                        list + validate bundled model specs
    gconv-chain audit [NET] [--fuse] [--budget BYTES]
                                             statically audit lowered chains:
                                             prove the rule set or exit non-zero
                                             with named diagnostics (default:
                                             all seven benchmarks + tinycnn)

OPTIONS:
    --model PATH   import the network from a model spec file instead of
                   a benchmark code (works for chain/simulate/run/serve)
    --threads N    run on a scoped rayon pool of N workers (default:
                   one per core) — pin for reproducible bench numbers
    --fuse         rewrite the chain with executable operation fusion
                   (§4.3) first: fewer entries, bit-identical outputs
    --max-batch N  serve: coalesce up to N single-sample requests into
                   one micro-batch session run (default 8)
    --precision P  serve: GEMM-tier numerics, P = bitexact (default) or
                   fast (SIMD lane microkernel; outputs land within the
                   documented relative tolerance instead of bit-exact —
                   the `client` bit-identity check assumes bitexact)
    --listen ADDR  serve: bind a TCP serving front (e.g. 127.0.0.1:4461)
                   instead of running the in-process demo stream
    --max-requests N
                   with --listen: serve N requests, then shut down
                   gracefully (smoke-test mode; default: run until killed)
    --faults SPEC  with --listen: arm the seeded fault-injection registry
                   for the server's lifetime, e.g.
                   \"seed=7,serve.step[MN]=panic@nth:3,conn.read=delay:5@p:0.1\"
                   (sites: pool.alloc kernels.eval serve.step
                   scheduler.wave conn.read; chaos/soak testing only)
    --trace-out PATH
                   profile: also write the per-layer timeline as
                   chrome://tracing JSON (openable in chrome://tracing
                   or Perfetto)

    NET   = AN GLN DN MN ZFFR C3D CapNN, a bundled spec name, or (with
            --model) a spec file path
    ACCEL = TPU DNNW ER EP NLR";

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let threads = gconv_chain::args::take_usize(&mut args, "--threads");
    let dispatch = move || -> Result<()> {
        match args.first().map(String::as_str) {
            Some("chain") => cmd_chain(&args[1..]),
            Some("simulate") => cmd_simulate(&args[1..]),
            Some("matrix") => cmd_matrix(),
            Some("run") => cmd_run(&args[1..]),
            Some("serve") => cmd_serve(&args[1..]),
            Some("client") => cmd_client(&args[1..]),
            Some("stats") => cmd_stats(&args[1..]),
            Some("profile") => cmd_profile(&args[1..]),
            Some("specs") => cmd_specs(),
            Some("audit") => cmd_audit(&args[1..]),
            _ => {
                println!("{USAGE}");
                Ok(())
            }
        }
    };
    match gconv_chain::exec::with_threads(threads, dispatch) {
        Err(e) => {
            eprintln!("failed to build the thread pool: {e:#}");
            std::process::exit(2);
        }
        Ok(Err(e)) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
        Ok(Ok(())) => {}
    }
}

/// The numeric positional left after NET/`--model` consumption
/// (SAMPLES / REQUESTS). A leftover non-numeric argument is an error
/// rather than a silently-applied default.
fn count_arg(args: &[String], default: u64, what: &str) -> Result<u64> {
    match args.first() {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("unexpected argument {s:?} (expected a {what} count)")),
    }
}

/// The spec a `--model PATH` flag names, loaded (but not built).
/// `--model` with a missing value is an error, not a silent fallback
/// to the default network.
fn take_spec(args: &mut Vec<String>) -> Result<Option<frontend::ModelSpec>> {
    let taken = gconv_chain::args::take_required_string(args, "--model")
        .map_err(|e| anyhow::anyhow!("{e} (a spec-file path)"))?;
    match taken {
        Some(path) => Ok(Some(frontend::load_spec(std::path::Path::new(&path))?)),
        None => Ok(None),
    }
}

/// The network a `--model PATH` flag names, built at the spec's baked
/// batch size. `None` when the flag is absent.
fn take_model(args: &mut Vec<String>) -> Result<Option<Network>> {
    match take_spec(args)? {
        Some(spec) => {
            let name = spec.name.clone();
            let net = frontend::build_network(&spec)
                .with_context(|| format!("building network {name:?} from --model spec"))?;
            Ok(Some(net))
        }
        None => Ok(None),
    }
}

fn cmd_chain(args: &[String]) -> Result<()> {
    let mut args = args.to_vec();
    let model = take_model(&mut args)?;
    let net = match (model, args.first()) {
        (Some(net), _) => net,
        (None, Some(code)) => resolve(code)?,
        (None, None) => {
            println!("{USAGE}");
            return Ok(());
        }
    };
    let mode =
        if args.iter().any(|a| a == "--inference") { Mode::Inference } else { Mode::Training };
    let mut chain = lower_network(&net, mode);
    if args.iter().any(|a| a == "--fuse") {
        let stats = fuse_executable(&mut chain);
        println!(
            "executable operation fusion: {} → {} entries (-{:.0}%)",
            stats.before,
            stats.after,
            stats.length_reduction() * 100.0
        );
    }
    print!("{chain}");
    let (t, n) = chain.work_split();
    println!(
        "total work: {:.3e} MACs ({:.1}% non-traditional)",
        chain.total_work() as f64,
        100.0 * n as f64 / (t + n) as f64
    );
    Ok(())
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let mut args = args.to_vec();
    let model = take_model(&mut args)?;
    // With --model the accelerator is the only positional argument;
    // otherwise the layout is `simulate <NET> <ACCEL>`.
    let (net, label, accel_arg) = match (model, args.first()) {
        (Some(net), accel) => {
            let label = net.name.clone();
            (net, label, accel.cloned())
        }
        (None, Some(code)) => (resolve(code)?, code.clone(), args.get(1).cloned()),
        (None, None) => {
            println!("{USAGE}");
            return Ok(());
        }
    };
    let Some(accel_code) = accel_arg else {
        println!("{USAGE}");
        return Ok(());
    };
    let accel = by_code(&accel_code);
    let rows: Vec<Vec<String>> = [ExecMode::Baseline, ExecMode::GconvChain]
        .into_iter()
        .map(|mode| {
            let r = simulate(&net, &accel, SimOptions { mode, training: true });
            vec![
                format!("{mode:?}"),
                format!("{:.3}", r.seconds * 1e3),
                format!("{:.3e}", r.movement.gb_total()),
                format!("{:.3e}", r.movement.offload),
                format!("{:.3e}", r.energy.total()),
                r2(r.utilization),
            ]
        })
        .collect();
    print_table(
        &format!("{label} on {accel_code} (training step)"),
        &["mode", "ms", "GB words", "offload words", "energy", "util"],
        &rows,
    );
    Ok(())
}

fn cmd_matrix() -> Result<()> {
    let mut rows = Vec::new();
    for code in BENCHMARK_CODES {
        let net = resolve(code)?;
        let mut row = vec![code.to_string()];
        for acode in ACCEL_CODES {
            let accel = by_code(acode);
            let b = simulate(&net, &accel, SimOptions { mode: ExecMode::Baseline, training: true });
            let g =
                simulate(&net, &accel, SimOptions { mode: ExecMode::GconvChain, training: true });
            row.push(r2(b.seconds / g.seconds));
        }
        rows.push(row);
    }
    print_table(
        "End-to-end speedup of GCONV Chain over baselines (Fig. 14)",
        &["net", "TPU", "DNNW", "ER", "EP", "NLR"],
        &rows,
    );
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    use gconv_chain::coordinator::{ChainExecutor, Request};
    use gconv_chain::exec::bench::input_spec;
    use gconv_chain::networks::mobilenet_block;

    let mut args = args.to_vec();
    let fuse = gconv_chain::args::take_flag(&mut args, "--fuse");
    let model = take_model(&mut args)?;
    // Default workload: one MobileNet block (Fig. 1(a)); any benchmark
    // code, bundled spec name or `--model` spec file runs its full
    // inference chain instead. The NET positional is consumed so
    // SAMPLES is always the next argument.
    let code = args.first().cloned();
    let net = match (model, code) {
        (Some(net), _) => net,
        (None, None) => mobilenet_block(8, 16, 14),
        (None, Some(code)) => {
            args.remove(0);
            resolve(&code)?
        }
    };
    let total = count_arg(&args, 64, "SAMPLES")?;
    let mut chain = lower_network(&net, Mode::Inference);
    if fuse {
        let stats = fuse_executable(&mut chain);
        println!(
            "executable operation fusion: {} → {} entries (-{:.0}%)",
            stats.before,
            stats.after,
            stats.length_reduction() * 100.0
        );
    }
    let (input_name, dims) = input_spec(&net)?;
    let mut exec = ChainExecutor::native(chain, &input_name, &dims).context("lowering failed")?;
    let sample_len = exec.sample_len();
    println!("executing {} on the {} backend…", net.name, exec.backend_name());

    let mut rng = gconv_chain::prop::Rng::new(42);
    let mut rand = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f64() as f32 - 0.5).collect() };
    for id in 0..total {
        exec.submit(Request { id, data: rand(sample_len) })?;
    }
    let mut served = 0;
    while served < total as usize {
        let out = exec.step(true)?;
        served += out.len();
    }
    let s = exec.stats();
    println!(
        "served {} samples in {} batches: {:.2} samples/s, mean latency {:.3} ms",
        s.samples,
        s.batches,
        s.throughput(),
        s.mean_latency_s * 1e3
    );
    Ok(())
}

/// How `serve` should run: the in-process demo stream, or a TCP
/// serving front bound to `listen`.
struct ServeOpts {
    max_batch: usize,
    fuse: bool,
    listen: Option<String>,
    max_requests: Option<u64>,
    faults: Option<gconv_chain::exec::FaultPlan>,
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use gconv_chain::exec::serve::Engine;
    use gconv_chain::exec::Precision;

    let mut args = args.to_vec();
    let fuse = gconv_chain::args::take_flag(&mut args, "--fuse");
    let precision = match gconv_chain::args::take_required_string(&mut args, "--precision")
        .map_err(|e| anyhow::anyhow!("{e} (bitexact or fast)"))?
        .as_deref()
    {
        None | Some("bitexact") => Precision::BitExact,
        Some("fast") => Precision::Fast,
        Some(other) => anyhow::bail!("--precision expects bitexact or fast, got {other:?}"),
    };
    let listen = gconv_chain::args::take_required_string(&mut args, "--listen")
        .map_err(|e| anyhow::anyhow!("{e} (an ADDR:PORT to bind)"))?;
    let max_requests = match gconv_chain::args::take_usize(&mut args, "--max-requests") {
        0 => None,
        n => Some(n as u64),
    };
    let max_batch = match gconv_chain::args::take_usize(&mut args, "--max-batch") {
        0 => 8,
        n => n,
    };
    let faults = gconv_chain::args::take_required_string(&mut args, "--faults")
        .map_err(|e| anyhow::anyhow!("{e} (a fault spec, e.g. conn.read=delay:5@p:0.1)"))?
        .map(|spec| {
            gconv_chain::exec::FaultPlan::parse(&spec)
                .map_err(|e| anyhow::anyhow!("--faults {spec:?}: {e}"))
        })
        .transpose()?;
    anyhow::ensure!(
        faults.is_none() || listen.is_some(),
        "--faults requires --listen (it arms the serving front's injection sites)"
    );
    let opts = ServeOpts { max_batch, fuse, listen, max_requests, faults };
    let mut engine = Engine::new(max_batch).with_fuse(fuse).with_precision(precision);
    // The served network: a `--model` spec, a benchmark code, a spec
    // file path, or a bundled spec stem (default MN). Specs register
    // with the engine so it can relower at every micro-batch size;
    // requests go to the spec's model name.
    let spec = match take_spec(&mut args)? {
        Some(spec) => spec,
        None => {
            let code = match args.first().cloned() {
                Some(c) => {
                    args.remove(0);
                    c
                }
                None => "MN".to_string(),
            };
            if BENCHMARK_CODES.contains(&code.as_str()) {
                let net1 = resolve_with_batch(&code, Some(1))?;
                return serve_dispatch(engine, args, code, net1, opts);
            }
            let Some(path) = frontend::find_spec(&code) else {
                return Err(gconv_chain::networks::unknown_network(&code));
            };
            frontend::load_spec(&path)?
        }
    };
    let net1 = frontend::build_with_batch(&spec, Some(1))
        .with_context(|| format!("building network {:?}", spec.name))?;
    let code = engine.register_spec(spec)?;
    serve_dispatch(engine, args, code, net1, opts)
}

/// Route `serve` to the in-process demo stream or, with `--listen`,
/// the TCP serving front.
fn serve_dispatch(
    mut engine: gconv_chain::exec::serve::Engine,
    args: Vec<String>,
    code: String,
    net1: Network,
    opts: ServeOpts,
) -> Result<()> {
    match opts.listen.clone() {
        Some(addr) => serve_network(engine, args, code, addr, opts),
        None => serve_requests(&mut engine, args, code, net1, opts.max_batch, opts.fuse),
    }
}

/// Bind the TCP serving front on `addr` and run until shutdown
/// (`--max-requests` or an external kill), then print the report.
fn serve_network(
    engine: gconv_chain::exec::serve::Engine,
    args: Vec<String>,
    code: String,
    addr: String,
    opts: ServeOpts,
) -> Result<()> {
    use gconv_chain::server::{serve, ServerConfig};

    if let Some(extra) = args.first() {
        anyhow::bail!("unexpected argument {extra:?} with --listen (requests come over TCP)");
    }
    let max_requests = opts.max_requests;
    // Armed for the whole server lifetime; the guard disarms on exit.
    // Injected panics are expected (and caught by the supervisor), so
    // suppress their backtrace noise.
    let _fault_guard = opts.faults.map(|plan| {
        gconv_chain::exec::faults::silence_injected_panics();
        println!("fault injection armed: {} rule(s), seed {}", plan.rules.len(), plan.seed);
        plan.arm()
    });
    let config = ServerConfig { max_requests, ..ServerConfig::default() };
    let handle = serve(&addr, engine, config)?;
    match max_requests {
        Some(n) => println!("serving {code} on {} for {n} request(s)…", handle.addr()),
        None => println!("serving {code} on {} (kill the process to stop)…", handle.addr()),
    }
    let report = handle.wait()?;
    println!(
        "served {} request(s) ({} busy-rejected, {} error(s), {} timeout(s), {} expired, \
         {} malformed, {} slow client(s)); {} connection(s) accepted ({} refused), \
         peak queue depth {}",
        report.served,
        report.rejected_busy,
        report.errored,
        report.timeouts,
        report.expired,
        report.malformed,
        report.slow_clients,
        report.conns_accepted,
        report.conns_rejected,
        report.max_queue_depth
    );
    if report.panics > 0 || !report.quarantined.is_empty() {
        let names: Vec<String> = report
            .quarantined
            .iter()
            .map(|q| format!("{} ({} strike(s))", q.model, q.strikes))
            .collect();
        println!(
            "supervisor: {} panic(s) caught, {} submit(s) refused while quarantined, \
             quarantined: [{}]",
            report.panics,
            report.quarantine_rejected,
            names.join(", ")
        );
    }
    let e = report.engine;
    println!(
        "engine: {} micro-batch(es), {} coalesced, {} session(s) built, {} cache hit(s), \
         {:.2} req/s steady-state",
        e.batches,
        e.coalesced,
        e.sessions_built,
        e.cache_hits,
        e.throughput()
    );
    Ok(())
}

/// `client ADDR [NET] [REQUESTS]`: send deterministic single-sample
/// requests to a serving front and pin every response bit-identical to
/// a local in-process engine over the same synthesized weights.
fn cmd_client(args: &[String]) -> Result<()> {
    use gconv_chain::exec::serve::Engine;
    use gconv_chain::exec::Tensor;
    use gconv_chain::server::Client;
    use std::time::{Duration, Instant};

    let mut args = args.to_vec();
    let Some(addr) = args.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    args.remove(0);
    // The NET positional (default MN); a bare number is REQUESTS.
    let code = match args.first() {
        Some(c) if c.parse::<u64>().is_err() => {
            let c = c.clone();
            args.remove(0);
            c
        }
        _ => "MN".to_string(),
    };
    let total = count_arg(&args, 8, "REQUESTS")?.max(1);

    // Local reference: the same engine the server runs, over the same
    // deterministically synthesized weights.
    let mut engine = Engine::new(1);
    let net1 = if BENCHMARK_CODES.contains(&code.as_str()) {
        resolve_with_batch(&code, Some(1))?
    } else {
        let Some(path) = frontend::find_spec(&code) else {
            return Err(gconv_chain::networks::unknown_network(&code));
        };
        let spec = frontend::load_spec(&path)?;
        let net1 = frontend::build_with_batch(&spec, Some(1))
            .with_context(|| format!("building network {:?}", spec.name))?;
        engine.register_spec(spec)?;
        net1
    };
    let (input_name, dims) = gconv_chain::exec::bench::input_spec(&net1)?;
    let mut sample_dims = dims.clone();
    sample_dims[0] = 1;
    let inputs: Vec<Vec<f32>> = (0..total)
        .map(|id| Tensor::rand(&sample_dims, 0xC11E_47 ^ id, 1.0).into_data())
        .collect();
    for (id, x) in inputs.iter().enumerate() {
        engine.submit(&code, id as u64, x.clone())?;
    }
    let mut reference = engine.drain()?;
    reference.sort_by_key(|r| r.id);
    anyhow::ensure!(reference.len() == inputs.len(), "reference engine dropped requests");

    println!(
        "sending {total} request(s) for {code} ({input_name}, {} values/sample) to {addr}…",
        sample_dims[1..].iter().product::<usize>()
    );
    let mut client = Client::connect_retry(&addr, Duration::from_secs(10))?;
    client.set_timeouts(Duration::from_secs(60), Duration::from_secs(10))?;
    let mut latencies: Vec<f64> = Vec::with_capacity(inputs.len());
    let mut busy_total: u64 = 0;
    let t0 = Instant::now();
    for (i, x) in inputs.iter().enumerate() {
        let t = Instant::now();
        let (out, busy) = client.infer_retry_busy(
            &code,
            &sample_dims[1..],
            x,
            1000,
            Duration::from_millis(2),
        )?;
        latencies.push(t.elapsed().as_secs_f64());
        busy_total += u64::from(busy);
        let want = reference[i].data.as_slice();
        let identical = out.len() == want.len()
            && out.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
        anyhow::ensure!(identical, "response {i} diverged from the in-process engine");
    }
    let seconds = t0.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    let pct = |p: usize| {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[(latencies.len() * p / 100).min(latencies.len() - 1)]
        }
    };
    let rps = if seconds > 0.0 { inputs.len() as f64 / seconds } else { 0.0 };
    println!(
        "{} response(s) bit-identical to the in-process engine: {rps:.2} req/s, \
         p50 {:.2} ms, p99 {:.2} ms, {busy_total} BUSY retry(ies)",
        inputs.len(),
        pct(50) * 1e3,
        pct(99) * 1e3
    );
    Ok(())
}

/// `stats ADDR [--metrics]`: fetch and print a serving front's health
/// snapshot, or (with `--metrics`) its raw Prometheus exposition.
fn cmd_stats(args: &[String]) -> Result<()> {
    use gconv_chain::server::{Client, HEALTH_FIELDS};
    use std::time::Duration;

    let mut args = args.to_vec();
    let metrics = gconv_chain::args::take_flag(&mut args, "--metrics");
    let Some(addr) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    if let Some(extra) = args.get(1) {
        anyhow::bail!("unexpected argument {extra:?} (stats takes only ADDR and --metrics)");
    }
    let mut client = Client::connect_retry(addr, Duration::from_secs(10))?;
    client.set_timeouts(Duration::from_secs(10), Duration::from_secs(10))?;
    if metrics {
        print!("{}", client.metrics()?);
        return Ok(());
    }
    let h = client.health()?;
    println!("health of {addr}:");
    for field in HEALTH_FIELDS {
        println!("  {:<20} {}", field.name, (field.get)(&h));
    }
    if h.quarantined.is_empty() {
        println!("  quarantined          (none)");
    } else {
        for q in &h.quarantined {
            println!("  quarantined          {} ({} strike(s))", q.model, q.strikes);
        }
    }
    Ok(())
}

/// `profile [NET]`: bind one serving session, run it once to warm the
/// buffer pool, then run one profiled request (kernel histograms armed)
/// on a single worker and print the per-layer breakdown — wall time,
/// share of end-to-end latency, kernel tier, effective GOP/s.
/// `--trace-out PATH` additionally writes the timeline as
/// chrome://tracing JSON.
fn cmd_profile(args: &[String]) -> Result<()> {
    use gconv_chain::exec::bench::input_spec;
    use gconv_chain::exec::serve::Session;
    use gconv_chain::exec::{KernelTier, Tensor};
    use gconv_chain::networks::mobilenet_block;
    use gconv_chain::obs::TraceEvent;

    let mut args = args.to_vec();
    let fuse = gconv_chain::args::take_flag(&mut args, "--fuse");
    let trace_out = gconv_chain::args::take_required_string(&mut args, "--trace-out")
        .map_err(|e| anyhow::anyhow!("{e} (a path for the chrome://tracing JSON)"))?;
    let model = take_model(&mut args)?;
    let net = match (model, args.first().cloned()) {
        (Some(net), _) => net,
        (None, None) => mobilenet_block(8, 16, 14),
        (None, Some(code)) => {
            args.remove(0);
            resolve(&code)?
        }
    };
    if let Some(extra) = args.first() {
        anyhow::bail!("unexpected argument {extra:?} (profile takes NET and flags only)");
    }
    let mut chain = lower_network(&net, Mode::Inference);
    if fuse {
        let stats = fuse_executable(&mut chain);
        println!(
            "executable operation fusion: {} → {} entries (-{:.0}%)",
            stats.before,
            stats.after,
            stats.length_reduction() * 100.0
        );
    }
    let (input_name, dims) = input_spec(&net)?;
    let x = Tensor::rand(&dims, 0x9_0F11E, 1.0);
    // One worker, so per-entry wall times add up to the end-to-end
    // latency instead of overlapping across rayon workers; the timed
    // run profiles a warmed session (pool filled, weights prepacked).
    let (report, tiers) = gconv_chain::exec::with_threads(1, || -> Result<_> {
        let mut session = Session::builder(chain).input(&input_name, x).build()?;
        let warm = session.run()?;
        session.recycle(warm);
        let _guard = gconv_chain::obs::profile();
        Ok((session.run()?, session.tiers()))
    })??;

    let tier_name = |t: Option<KernelTier>| match t {
        Some(KernelTier::Gemm) => "gemm",
        Some(KernelTier::Odometer) => "odometer",
        Some(KernelTier::Naive) => "naive",
        None => "special",
    };
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut events: Vec<TraceEvent> = Vec::new();
    let mut ts_us = 0.0;
    let mut covered = 0.0;
    for e in &report.entries {
        let tier = tier_name(tiers.get(e.index).copied().flatten());
        let share = if report.total_s > 0.0 { 100.0 * e.seconds / report.total_s } else { 0.0 };
        let gops = if e.seconds > 0.0 { e.work as f64 / e.seconds / 1e9 } else { 0.0 };
        covered += e.seconds;
        rows.push(vec![
            e.index.to_string(),
            e.name.clone(),
            tier.to_string(),
            format!("{:.3}", e.seconds * 1e3),
            format!("{share:.1}"),
            format!("{gops:.2}"),
        ]);
        events.push(TraceEvent {
            name: e.name.clone(),
            cat: tier.to_string(),
            ts_us,
            dur_us: e.seconds * 1e6,
            tid: 0,
            args: vec![
                ("work".to_string(), e.work.to_string()),
                ("gops".to_string(), format!("{gops:.2}")),
            ],
        });
        ts_us += e.seconds * 1e6;
    }
    print_table(
        &format!("{} per-layer profile (1 thread, warmed session)", net.name),
        &["#", "entry", "tier", "ms", "%", "GOP/s"],
        &rows,
    );
    let coverage = if report.total_s > 0.0 { 100.0 * covered / report.total_s } else { 0.0 };
    println!(
        "total {:.3} ms end-to-end; per-entry sum {:.3} ms ({coverage:.1}% coverage)",
        report.total_s * 1e3,
        covered * 1e3
    );
    if let Some(path) = trace_out {
        let json = gconv_chain::obs::export::trace_json(&events);
        std::fs::write(&path, json).with_context(|| format!("writing trace to {path}"))?;
        println!("wrote chrome://tracing JSON ({} event(s)) to {path}", events.len());
    }
    Ok(())
}

/// Submit and drain `REQUESTS` single-sample requests for `code`
/// through the engine, then print the latency/throughput summary.
fn serve_requests(
    engine: &mut gconv_chain::exec::serve::Engine,
    args: Vec<String>,
    code: String,
    net1: Network,
    max_batch: usize,
    fuse: bool,
) -> Result<()> {
    use gconv_chain::exec::Tensor;
    let total = count_arg(&args, 32, "REQUESTS")?.max(1);

    let (input_name, dims) = gconv_chain::exec::bench::input_spec(&net1)?;
    let sample_len: usize = dims[1..].iter().product();
    println!(
        "serving {code} ({input_name}, {sample_len} values/sample): {total} requests, \
         micro-batches of up to {max_batch}, fuse={fuse}…"
    );

    let mut sample_dims = dims.clone();
    sample_dims[0] = 1;
    for id in 0..total {
        let x = Tensor::rand(&sample_dims, 0xD15_C0 ^ id, 1.0);
        engine.submit(&code, id, x.into_data())?;
    }
    let responses = engine.drain()?;
    let s = engine.stats();
    let mut latencies: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
    latencies.sort_by(f64::total_cmp);
    // Guard the percentile/throughput math: an empty response set (or
    // zero-duration clock) must print zeros, not panic or divide.
    let pct = |p: usize| {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[(latencies.len() * p / 100).min(latencies.len() - 1)]
        }
    };
    println!(
        "served {} requests in {} micro-batches ({} coalesced, {} sessions built, \
         {} cache hits): {:.2} req/s, p50 {:.2} ms, p99 {:.2} ms",
        s.requests,
        s.batches,
        s.coalesced,
        s.sessions_built,
        s.cache_hits,
        s.throughput(),
        pct(50) * 1e3,
        pct(99) * 1e3
    );
    Ok(())
}

/// List every bundled spec file, import + lower each one, and run the
/// static chain audit over the lowered chain; fail (non-zero exit) if
/// any is invalid — the CI spec-validation gate. The audit honours
/// `GCONV_AUDIT_BUDGET` (bytes), the lever the frontend tests pull.
fn cmd_specs() -> Result<()> {
    use gconv_chain::analysis::{audit_chain_with, AuditConfig};

    let dir = frontend::spec_dir();
    let files = frontend::discover_specs();
    if files.is_empty() {
        println!("no .json spec files found under {}", dir.display());
        return Ok(());
    }
    let cfg = AuditConfig::from_env();
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut failures = 0usize;
    for path in &files {
        let stem = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        match frontend::load_spec(path).and_then(|s| frontend::build_network(&s)) {
            Ok(net) => {
                let chain = lower_network(&net, Mode::Inference);
                let rep = audit_chain_with(&chain, &cfg);
                let audit = if rep.is_clean() {
                    format!("clean ({} obligations)", rep.total_checked())
                } else {
                    failures += 1;
                    eprint!("{}: static chain audit failed:\n{rep}", path.display());
                    format!("{} DIAGNOSTIC(S)", rep.diagnostics().len())
                };
                rows.push(vec![
                    stem,
                    net.name.clone(),
                    net.len().to_string(),
                    chain.len().to_string(),
                    format!("{:.3e}", chain.total_work() as f64),
                    audit,
                ]);
            }
            Err(e) => {
                failures += 1;
                eprintln!("{}: {e:#}", path.display());
                rows.push(vec![
                    stem,
                    "IMPORT FAILED".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
    print_table(
        &format!("Bundled model specs ({})", dir.display()),
        &["spec", "network", "layers", "chain ops", "FP work", "audit"],
        &rows,
    );
    anyhow::ensure!(failures == 0, "{failures} spec file(s) failed import or audit");
    Ok(())
}

/// Statically audit lowered chains against the full rule set and print
/// a per-rule obligation report — the CLI face of
/// `analysis::audit_chain`. With no NET, audits all seven benchmark
/// networks plus the bundled `tinycnn` spec, each in both inference
/// and training lowering. Exits non-zero on any diagnostic.
fn cmd_audit(args: &[String]) -> Result<()> {
    use gconv_chain::analysis::{audit_chain_with, AuditConfig, Rule};

    let mut args = args.to_vec();
    let fuse = gconv_chain::args::take_flag(&mut args, "--fuse");
    let budget = gconv_chain::args::take_usize(&mut args, "--budget");
    let model = take_model(&mut args)?;

    let mut cfg = AuditConfig::from_env();
    if budget > 0 {
        cfg.budget_bytes = budget;
    }

    let mut nets: Vec<Network> = Vec::new();
    match (model, args.first()) {
        (Some(net), _) => nets.push(net),
        (None, Some(code)) => nets.push(resolve(code)?),
        (None, None) => {
            for code in BENCHMARK_CODES {
                nets.push(resolve(code)?);
            }
            nets.push(resolve("tinycnn").context("resolving the bundled tinycnn spec")?);
        }
    }

    let mut checked = vec![0usize; Rule::ALL.len()];
    let mut flagged = vec![0usize; Rule::ALL.len()];
    let mut diagnostics = 0usize;
    for net in &nets {
        for mode in [Mode::Inference, Mode::Training] {
            let mut chain = lower_network(net, mode);
            if fuse {
                fuse_executable(&mut chain);
            }
            let rep = audit_chain_with(&chain, &cfg);
            let tag = if fuse { "fused" } else { "unfused" };
            print!("[{mode:?}/{tag}] {rep}");
            diagnostics += rep.diagnostics().len();
            for (k, r) in Rule::ALL.iter().enumerate() {
                checked[k] += rep.checked(*r);
                flagged[k] += rep.flagged(*r);
            }
        }
    }

    let rows: Vec<Vec<String>> = Rule::ALL
        .iter()
        .zip(checked.iter().zip(&flagged))
        .map(|(r, (&c, &f))| {
            vec![r.id().to_string(), r.describes().to_string(), c.to_string(), f.to_string()]
        })
        .collect();
    print_table(
        "Static chain audit (per rule)",
        &["rule", "invariant", "obligations", "diagnostics"],
        &rows,
    );
    anyhow::ensure!(diagnostics == 0, "{diagnostics} audit diagnostic(s) — see the report above");
    println!("every chain audited clean");
    Ok(())
}
