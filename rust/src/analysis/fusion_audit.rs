//! Fusion-legality re-audit: re-derive the refusal rules of
//! `mapping::fusion` on the (possibly fused) chain and check its
//! decisions, instead of trusting them.
//!
//! Executable fusion only rewrites scalar pipelines, so the statically
//! checkable post-conditions are: special entries never absorbed
//! anything ([`Rule::FusionSpecial`]), every provenance record names a
//! known operator slot ([`Rule::FusionSlot`]), and — the one rule
//! whose violation silently corrupts numerics — a padded host that
//! absorbed a producer into `pre` still maps the padding value +0.0
//! to +0.0 bit-exactly ([`Rule::FusionPadding`]). The padding rule is
//! exact for chains this repo lowers: every padded op the lowering
//! emits with its own `pre` uses a zero-preserving stage that never
//! maps a non-zero to zero, so evaluating the *composed* pipeline at
//! +0.0 accepts exactly the fusions `executable_pre` accepts.

use super::{AuditReport, Rule};
use crate::exec::lut_apply;
use crate::gconv::chain::GconvChain;
use crate::gconv::op::{ScalarStage, StageStack};

pub(crate) fn run(chain: &GconvChain, rep: &mut AuditReport) {
    for (i, e) in chain.entries().iter().enumerate() {
        rep.check(Rule::FusionSpecial);
        if e.special.is_some() && !e.fused.is_empty() {
            rep.flag(
                Rule::FusionSpecial,
                i,
                &e.op.name,
                "fusion records",
                "none (special entries never fuse)",
                format!("{} absorbed op(s)", e.fused.len()),
            );
        }

        for f in &e.fused {
            rep.check(Rule::FusionSlot);
            if !matches!(f.slot, "pre" | "post" | "main" | "elided") {
                rep.flag(
                    Rule::FusionSlot,
                    i,
                    &e.op.name,
                    format!("fused op {:?} slot", f.name),
                    "one of pre/post/main/elided",
                    format!("{:?}", f.slot),
                );
            }
        }

        let padded = e.op.dims.iter().any(|&(_, p)| p.ps > 0 || p.pe > 0);
        let fused_pre = e.fused.iter().any(|f| f.slot == "pre");
        if padded && fused_pre {
            rep.check(Rule::FusionPadding);
            match stack_at_zero(&e.op.pre.stages()) {
                Some(v) if v.to_bits() == 0.0f32.to_bits() => {}
                Some(v) => rep.flag(
                    Rule::FusionPadding,
                    i,
                    &e.op.name,
                    "composed pre pipeline at +0.0",
                    "+0.0 bit-exactly",
                    format!("{v:e}"),
                ),
                None => rep.flag(
                    Rule::FusionPadding,
                    i,
                    &e.op.name,
                    "composed pre pipeline at +0.0",
                    "a resolvable pipeline",
                    "an unresolvable LUT stage",
                ),
            }
        }
    }
}

/// The composed pipeline evaluated at +0.0 (`None` when a LUT stage
/// does not resolve — separately flagged by [`Rule::DataflowLut`]).
fn stack_at_zero(stack: &StageStack) -> Option<f32> {
    let mut x = 0.0f32;
    for s in stack.as_slice() {
        x = match *s {
            ScalarStage::Square => x * x,
            ScalarStage::Mul(c) => c * x,
            ScalarStage::Lut(n) => lut_apply(n, x).ok()?,
        };
    }
    Some(x)
}
