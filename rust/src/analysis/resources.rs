//! Resource-bounds pass: replay the level schedule with the
//! executor's allocate-then-release protocol and derive the peak live
//! bytes; flag it against the configured budget and keep every size
//! computation in checked arithmetic.
//!
//! "Live" means exactly what `exec::chain_exec` keeps: every buffer
//! produced in a level is allocated before any operand of that level
//! is released, wanted outputs are held to the end, and a buffer is
//! released when its last scheduled consumer has run. The derived
//! peak is the high-water mark a `BufferPool` sized to the chain must
//! absorb.

use super::{backward_deps, schedule, AuditConfig, AuditReport, Rule, Schedule};
use crate::gconv::chain::GconvChain;

pub(crate) fn run(chain: &GconvChain, cfg: &AuditConfig, rep: &mut AuditReport) {
    let entries = chain.entries();
    let Schedule { needed, levels, mut uses, wanted: _ } = schedule(chain, cfg);

    // Output-buffer size of every scheduled entry, in f32 bytes.
    let mut bytes = vec![0usize; chain.len()];
    for (i, e) in entries.iter().enumerate() {
        if !needed[i] {
            continue;
        }
        rep.check(Rule::ResourceOverflow);
        let elems = e.op.output_extents().into_iter().try_fold(1usize, |a, x| a.checked_mul(x));
        match elems.and_then(|n| n.checked_mul(4)) {
            Some(b) => bytes[i] = b,
            None => {
                rep.flag(
                    Rule::ResourceOverflow,
                    i,
                    &e.op.name,
                    "output buffer bytes",
                    "within usize",
                    "overflow",
                );
                return;
            }
        }
    }

    let mut live = 0usize;
    let mut peak = 0usize;
    let mut over: Option<usize> = None; // first allocation past the budget
    for lv in &levels {
        for &i in lv {
            live = match live.checked_add(bytes[i]) {
                Some(l) => l,
                None => {
                    rep.flag(
                        Rule::ResourceOverflow,
                        i,
                        &entries[i].op.name,
                        "live byte total",
                        "within usize",
                        "overflow",
                    );
                    return;
                }
            };
            if live > cfg.budget_bytes && over.is_none() {
                over = Some(i);
            }
        }
        peak = peak.max(live);
        for &i in lv {
            for p in backward_deps(&entries[i].op, i) {
                uses[p] = uses[p].saturating_sub(1);
                if uses[p] == 0 {
                    live = live.saturating_sub(bytes[p]);
                }
            }
        }
    }

    rep.peak_live_bytes = peak;
    rep.check(Rule::ResourcePeak);
    if let Some(i) = over {
        rep.flag(
            Rule::ResourcePeak,
            i,
            &entries[i].op.name,
            "peak live bytes",
            format!("<= {} (the configured budget)", cfg.budget_bytes),
            format!("{peak}, first exceeded at this entry's allocation"),
        );
    }
}
