//! Dataflow-soundness pass: the chain is a DAG, the re-derived level
//! schedule is monotone, replaying the executor's refcounted free
//! protocol never reads a released buffer, and every scalar-pipeline
//! LUT name resolves.
//!
//! The schedule and use counts are recomputed here (see the shared
//! helpers in the parent module) rather than taken from
//! `exec::chain_exec` — the replay below is an independent derivation
//! the executor's scheduler is checked against.

use super::{producer_deps, schedule, AuditConfig, AuditReport, Rule, Schedule};
use crate::exec::lut_known;
use crate::gconv::chain::GconvChain;
use crate::gconv::op::{DataRef, ScalarStage};

pub(crate) fn run(chain: &GconvChain, cfg: &AuditConfig, rep: &mut AuditReport) {
    let n = chain.len();
    let entries = chain.entries();

    // --- Acyclicity: operand references point strictly backwards. ---
    let mut acyclic = true;
    for (i, e) in entries.iter().enumerate() {
        rep.check(Rule::DataflowAcyclic);
        let refs = [("input", Some(&e.op.input)), ("kernel", e.op.kernel.as_ref())];
        for (what, r) in refs {
            if let Some(DataRef::Gconv(p)) = r {
                if *p >= i {
                    acyclic = false;
                    rep.flag(
                        Rule::DataflowAcyclic,
                        i,
                        &e.op.name,
                        format!("{what} operand"),
                        format!("a producer index < {i}"),
                        format!("#{p}"),
                    );
                }
            }
        }
    }

    // --- LUT resolvability over every pre/post pipeline stage. ---
    for (i, e) in entries.iter().enumerate() {
        for (slot, stack) in [("pre", e.op.pre.stages()), ("post", e.op.post.stages())] {
            for s in stack.as_slice() {
                if let ScalarStage::Lut(name) = s {
                    rep.check(Rule::DataflowLut);
                    if !lut_known(name) {
                        rep.flag(
                            Rule::DataflowLut,
                            i,
                            &e.op.name,
                            format!("{slot} LUT {name:?}"),
                            "a name the interpreter resolves",
                            "unknown",
                        );
                    }
                }
            }
        }
    }

    // --- Wanted outputs must exist. ---
    rep.check(Rule::DataflowSchedule);
    if let Some(w) = &cfg.wanted {
        for &x in w {
            if x >= n {
                rep.flag_chain(
                    Rule::DataflowSchedule,
                    format!("wanted output #{x}"),
                    format!("an entry index < {n}"),
                    x.to_string(),
                );
            }
        }
    }
    if !acyclic {
        return; // the schedule replay is undefined on cyclic chains
    }

    let Schedule { needed, levels, mut uses, wanted } = schedule(chain, cfg);

    // --- Schedule monotonicity: every dep of a scheduled entry is
    // itself scheduled, at a strictly earlier level. ---
    let mut level_of = vec![usize::MAX; n];
    for (l, lv) in levels.iter().enumerate() {
        for &i in lv {
            level_of[i] = l;
        }
    }
    for (l, lv) in levels.iter().enumerate() {
        for &i in lv {
            rep.check(Rule::DataflowSchedule);
            for p in producer_deps(&entries[i].op) {
                if !needed[p] || level_of[p] >= l {
                    rep.flag(
                        Rule::DataflowSchedule,
                        i,
                        &entries[i].op.name,
                        format!("operand #{p} level"),
                        format!("scheduled before level {l}"),
                        if needed[p] {
                            format!("level {}", level_of[p])
                        } else {
                            "not scheduled".to_string()
                        },
                    );
                }
            }
        }
    }

    // --- Refcount replay: decrement per reference after each level,
    // as the executor does; a read of an exhausted operand is a
    // read-after-free. ---
    for lv in &levels {
        for &i in lv {
            rep.check(Rule::DataflowRefcount);
            for p in producer_deps(&entries[i].op) {
                if uses[p] == 0 {
                    rep.flag(
                        Rule::DataflowRefcount,
                        i,
                        &entries[i].op.name,
                        format!("operand #{p}"),
                        "a live buffer",
                        "freed before this read",
                    );
                }
            }
        }
        for &i in lv {
            for p in producer_deps(&entries[i].op) {
                uses[p] = uses[p].saturating_sub(1);
            }
        }
    }
    // The extra wanted use must survive the whole replay — that is
    // what hands the output buffers to the caller.
    for &w in &wanted {
        rep.check(Rule::DataflowRefcount);
        if uses[w] == 0 {
            rep.flag(
                Rule::DataflowRefcount,
                w,
                &entries[w].op.name,
                "wanted output buffer",
                "held through the run",
                "released by a consumer",
            );
        }
    }
}
