//! Operand-coverage pass: every loop-nest read of a chain-internal
//! operand falls inside the extents its producer emits, under the
//! same reshape / rank-aligned / squeezed-broadcast rules the
//! interpreter's binder applies — re-derived here so the audit proves
//! the bind will succeed instead of asking it.

use super::{operand_extents, params_ok, AuditReport, Rule};
use crate::exec::interp::MAX_DIMS;
use crate::gconv::chain::GconvChain;
use crate::gconv::op::{DataRef, GconvOp, MainOp, ReduceOp};

pub(crate) fn run(chain: &GconvChain, rep: &mut AuditReport) {
    let entries = chain.entries();
    for (i, e) in entries.iter().enumerate() {
        let op = &e.op;

        // --- Parameter sanity (everything below divides by Ng). ---
        rep.check(Rule::CoverageParams);
        let mut ok = params_ok(op);
        if !ok {
            for &(d, p) in &op.dims {
                for (what, v) in
                    [("Ng", p.ng), ("Nop", p.nop), ("Nopc", p.nopc), ("Nks", p.nks), ("s", p.s)]
                {
                    if v == 0 {
                        rep.flag(
                            Rule::CoverageParams,
                            i,
                            &op.name,
                            format!("dimension {d} {what}"),
                            ">= 1",
                            "0",
                        );
                    }
                }
            }
        }
        if op.dims.len() > MAX_DIMS {
            rep.flag(
                Rule::CoverageParams,
                i,
                &op.name,
                "dimension count",
                format!("<= {MAX_DIMS}"),
                op.dims.len().to_string(),
            );
            ok = false;
        }
        if ok && op.reduce == ReduceOp::None {
            let red_total = op.dims.iter().map(|&(_, p)| p.nks).product::<usize>().max(1);
            if red_total > 1 {
                rep.flag(
                    Rule::CoverageParams,
                    i,
                    &op.name,
                    "reduce operator",
                    "a reduction (Nks loops present)",
                    format!("None with {red_total} reduction steps"),
                );
            }
        }
        if !ok || e.special.is_some() {
            // Special-op operand sizing is proven by the disjointness
            // pass alongside its partition facts.
            continue;
        }

        // --- Input operand coverage (chain-internal producers only:
        // external/weight operands are materialized to fit). ---
        if let DataRef::Gconv(p) = op.input {
            if p < i && params_ok(&entries[p].op) {
                rep.check(Rule::CoverageInput);
                let dims = operand_extents(&entries[p].op);
                if let Err((subject, expected, found)) = input_covers(op, &dims) {
                    rep.flag(Rule::CoverageInput, i, &op.name, subject, expected, found);
                }
            }
            // Forward references are the acyclicity pass's finding.
        }

        // --- Kernel operand: exact element count. ---
        if !matches!(op.main, MainOp::Pass) {
            rep.check(Rule::CoverageKernel);
            match &op.kernel {
                None => rep.flag(
                    Rule::CoverageKernel,
                    i,
                    &op.name,
                    "kernel operand",
                    format!("an operand ({:?} consumes parameters)", op.main),
                    "none",
                ),
                Some(DataRef::Gconv(p)) if *p < i => {
                    let have: usize = operand_extents(&entries[*p].op).iter().product();
                    let want = op.kernel_elements();
                    if have != want {
                        rep.flag(
                            Rule::CoverageKernel,
                            i,
                            &op.name,
                            format!("kernel operand #{p} elements"),
                            want.to_string(),
                            have.to_string(),
                        );
                    }
                }
                Some(_) => {}
            }
        }
    }
}

/// Would the binder accept `in_dims` as `op`'s input? Mirrors the
/// three acceptance modes of `exec::interp`'s `bind_input` (exact
/// element count, rank-aligned with broadcasts, squeezed positional)
/// plus its final layout-product check, returning the first failing
/// `(subject, expected, found)` instead of an executor error.
fn input_covers(op: &GconvOp, in_dims: &[usize]) -> Result<(), (String, String, String)> {
    let nd = op.dims.len();
    let mut ngs = Vec::with_capacity(nd);
    let mut group_in = Vec::with_capacity(nd);
    let mut exp_in = Vec::with_capacity(nd);
    for &(_, p) in &op.dims {
        let covered = p.input_extent() / p.ng;
        ngs.push(p.ng);
        group_in.push(covered);
        exp_in.push(p.ng * covered);
    }

    let Some(elements) = checked_product(in_dims) else {
        return Err(overflow("input extent product", in_dims));
    };
    let Some(expected) = checked_product(&exp_in) else {
        return Err(overflow("expected extent product", &exp_in));
    };

    // Mode 1: exact element count — reshape semantics.
    if elements == expected {
        return Ok(());
    }

    // Mode 2: rank-aligned — larger extents (stride-discarded tails)
    // and extent-1 broadcasts accepted per dimension.
    if in_dims.len() == nd {
        let aligned = in_dims
            .iter()
            .zip(ngs.iter().zip(&group_in))
            .all(|(&a, (&ng, &gi))| (a % ng == 0 && a / ng >= gi) || a == 1);
        if aligned {
            return Ok(());
        }
    }

    // Mode 3: squeezed — non-unit extents matched positionally against
    // the dimensions that expect more than one element.
    let kept: Vec<usize> = (0..nd).filter(|&i| exp_in[i] > 1).collect();
    let sq: Vec<usize> = in_dims.iter().copied().filter(|&d| d > 1).collect();
    if sq.len() != kept.len() {
        return Err((
            "input shape".to_string(),
            format!("extents covering {exp_in:?}"),
            format!("{in_dims:?}"),
        ));
    }
    let mut bound = 1usize;
    for (&k, &a) in kept.iter().zip(&sq) {
        if a % ngs[k] != 0 || a / ngs[k] < group_in[k] {
            return Err((
                format!("input dimension {}", op.dims[k].0),
                format!(">= {} (Ng {} x per-group {})", exp_in[k], ngs[k], group_in[k]),
                a.to_string(),
            ));
        }
        bound = match bound.checked_mul(a) {
            Some(b) => b,
            None => return Err(overflow("bound extent product", &sq)),
        };
    }
    // Final layout check: the bound extents must account for every
    // element (zero-extent inputs land here).
    if bound != elements {
        return Err(("bound input elements".to_string(), bound.to_string(), elements.to_string()));
    }
    Ok(())
}

fn checked_product(dims: &[usize]) -> Option<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}

fn overflow(what: &str, dims: &[usize]) -> (String, String, String) {
    (what.to_string(), "within usize".to_string(), format!("overflow over {dims:?}"))
}
