//! Write-disjointness pass: prove that every parallel write site in
//! the native executor partitions its output exactly.
//!
//! The packed-GEMM path (`exec::kernels`) fans one entry out into
//! (group, column-block) jobs that write through a shared raw output
//! pointer; each job owns the rows of its group slice and the columns
//! of its block. That is sound iff the mixed-radix output index
//! `sum_d ((g_d * Nop_d + op_d) * Nopc_d + opc_d) * stride_d` is a
//! bijection from (group, row, column) digits onto `[0, out_total)` —
//! which holds exactly when every per-dimension extent is positive and
//! `prod(Ng) * prod(Nop) * prod(Nopc) = prod(Ng*Nop*Nopc)` without
//! overflow, with fixed-width column blocks tiling `[0, n_cols)`.
//! This pass discharges those obligations per entry, plus the
//! equivalent partition facts for the special-op routines
//! (`exec::special`): max-pool BP scatter stays inside one window set
//! and concat block copies tile the output. GEMM-tier entries carry
//! one more obligation: the bind-time prepacked weight slab is
//! `groups × rows × k_total` elements, and that size arithmetic must
//! stay within `usize` or the pack loop's row offsets would wrap.

use super::{operand_extents, params_ok, static_tier, AuditReport, Rule};
use crate::exec::interp::MAX_DIMS;
use crate::exec::{KernelTier, GEMM_COL_BLOCK};
use crate::gconv::chain::{GconvChain, SpecialOp};
use crate::gconv::op::{DataRef, DimParams, GconvOp};
use crate::ir::Dim;

pub(crate) fn run(chain: &GconvChain, rep: &mut AuditReport) {
    // The kernel's column-block width is a compile-time constant; the
    // tiling argument below needs it positive. Proven once per audit.
    rep.check(Rule::DisjointGemm);
    if GEMM_COL_BLOCK == 0 {
        rep.flag_chain(Rule::DisjointGemm, "GEMM column block width", ">= 1", "0");
    }

    for (i, e) in chain.entries().iter().enumerate() {
        if !params_ok(&e.op) {
            continue; // flagged by the coverage pass
        }
        match &e.special {
            None => check_gemm_partition(i, &e.op, rep),
            Some(SpecialOp::MaxPoolBp { fwd, in_extents }) => {
                rep.scatter_sites += 1;
                check_scatter(chain, i, fwd, in_extents, rep);
            }
            Some(SpecialOp::Concat { axis, pre_extent, branch_extent }) => {
                rep.scatter_sites += 1;
                check_concat(chain, i, *axis, *pre_extent, *branch_extent, rep);
            }
        }
    }
}

/// The (group, row, column) job partition of one loop-nest entry is a
/// bijection onto its output — the disjointness proof for the raw
/// output pointer the GEMM tier shares across jobs. The same identity
/// underwrites the safe tiers (their chunked writes partition the
/// same index space), so it is discharged for every entry; entries
/// the static tier model places on the GEMM path are counted as
/// proven parallel write sites.
fn check_gemm_partition(i: usize, op: &GconvOp, rep: &mut AuditReport) {
    rep.check(Rule::DisjointGemm);
    let mut n_groups = 1usize;
    let mut n_rows = 1usize;
    let mut n_cols = 1usize;
    let mut out_total = 1usize;
    for &(d, p) in &op.dims {
        let ext = p.ng.checked_mul(p.nop).and_then(|x| x.checked_mul(p.nopc));
        let acc = ext.and_then(|ext| {
            n_groups = n_groups.checked_mul(p.ng)?;
            n_rows = n_rows.checked_mul(p.nop)?;
            n_cols = n_cols.checked_mul(p.nopc)?;
            out_total = out_total.checked_mul(ext)?;
            Some(())
        });
        if acc.is_none() {
            rep.flag(
                Rule::DisjointGemm,
                i,
                &op.name,
                format!("dimension {d} job index arithmetic"),
                "products within usize",
                "overflow",
            );
            return;
        }
    }
    // With every factor positive (params_ok) the mixed-radix digit map
    // is onto iff the factored job count equals the output count.
    let jobs = n_groups.checked_mul(n_rows).and_then(|x| x.checked_mul(n_cols));
    if jobs != Some(out_total) {
        rep.flag(
            Rule::DisjointGemm,
            i,
            &op.name,
            "job partition (groups x rows x cols)",
            format!("{out_total} outputs"),
            format!("{jobs:?} jobs"),
        );
        return;
    }
    if static_tier(op) == KernelTier::Gemm {
        // Bind-time prepack: the plan-owned weight slab holds
        // `groups × rows × k_total` packed elements, and `fill_wpack`
        // offsets rows by `(g·rows + op)·k_total` — sound only when
        // that product does not wrap.
        let slab = checked_product(op.dims.iter().map(|&(_, p)| p.nks))
            .and_then(|k| n_groups.checked_mul(n_rows)?.checked_mul(k));
        if slab.is_none() {
            rep.flag(
                Rule::DisjointGemm,
                i,
                &op.name,
                "prepacked weight slab (groups x rows x k_total)",
                "within usize",
                "overflow",
            );
            return;
        }
        rep.gemm_sites += 1;
    }
}

/// Max-pool BP scatter: the routine walks forward windows and
/// accumulates each window's gradient onto the argmax position inside
/// that window. Window positions are derived per forward dimension,
/// so routing stays inside one window set only when no forward
/// dimension multiplexes groups or parallel kernels.
fn check_scatter(
    chain: &GconvChain,
    i: usize,
    fwd: &[(Dim, DimParams)],
    in_extents: &[usize],
    rep: &mut AuditReport,
) {
    let e = &chain.entries()[i];
    let name = &e.op.name;
    rep.check(Rule::CoverageSpecial);
    if fwd.len() != in_extents.len() || fwd.len() > MAX_DIMS {
        rep.flag(
            Rule::CoverageSpecial,
            i,
            name,
            "forward geometry",
            format!("matching dims within {MAX_DIMS}"),
            format!("{} fwd dims, {} input extents", fwd.len(), in_extents.len()),
        );
        return;
    }
    if fwd.iter().any(|&(_, p)| p.nopc == 0 || p.nks == 0 || p.s == 0) {
        rep.flag(
            Rule::CoverageSpecial,
            i,
            name,
            "forward loop parameters",
            ">= 1",
            "a zero window parameter",
        );
        return;
    }

    rep.check(Rule::DisjointScatter);
    for &(d, p) in fwd {
        if p.ng != 1 || p.nop != 1 {
            rep.flag(
                Rule::DisjointScatter,
                i,
                name,
                format!("forward dimension {d}"),
                "Ng = 1 and Nop = 1 (scatter routes within one window set)",
                format!("Ng = {}, Nop = {}", p.ng, p.nop),
            );
        }
    }

    // Operand sizing: the gradient operand carries one value per
    // forward window; the saved-input operand (and the output) carry
    // the forward input.
    let windows = checked_product(fwd.iter().map(|&(_, p)| p.output_extent()));
    let fwd_in = checked_product(in_extents.iter().copied());
    let out = checked_product(e.op.output_extents().into_iter());
    let (Some(windows), Some(fwd_in), Some(out)) = (windows, fwd_in, out) else {
        rep.flag(Rule::CoverageSpecial, i, name, "extent products", "within usize", "overflow");
        return;
    };
    if out != fwd_in {
        rep.flag(
            Rule::CoverageSpecial,
            i,
            name,
            "output elements",
            format!("{fwd_in} (the forward input)"),
            out.to_string(),
        );
    }
    check_operand_elements(chain, i, "input (gradient)", &e.op.input, windows, rep);
    if let Some(k) = &e.op.kernel {
        check_operand_elements(chain, i, "kernel (saved input)", k, fwd_in, rep);
    } else {
        rep.flag(Rule::CoverageSpecial, i, name, "kernel operand", "the saved input", "none");
    }
}

/// Concat step: the routine copies the `input` block then the `kernel`
/// block side by side along the axis — an exact partition of the
/// output iff `pre + branch` tiles the axis extent and both operands
/// carry exactly their block's elements.
fn check_concat(
    chain: &GconvChain,
    i: usize,
    axis: usize,
    pre: usize,
    branch: usize,
    rep: &mut AuditReport,
) {
    let e = &chain.entries()[i];
    let name = &e.op.name;
    let dims = operand_extents(&e.op);
    rep.check(Rule::DisjointConcat);
    if axis >= dims.len() {
        rep.flag(
            Rule::DisjointConcat,
            i,
            name,
            "concat axis",
            format!("< {} (output rank)", dims.len()),
            axis.to_string(),
        );
        return;
    }
    if pre.checked_add(branch) != Some(dims[axis]) || pre == 0 || branch == 0 {
        rep.flag(
            Rule::DisjointConcat,
            i,
            name,
            "axis partition (pre + branch)",
            format!("{} with both blocks non-empty", dims[axis]),
            format!("{pre} + {branch}"),
        );
        return;
    }
    let mut rest = dims;
    rest.remove(axis);
    let Some(outer_inner) = checked_product(rest.into_iter()) else {
        rep.flag(Rule::DisjointConcat, i, name, "extent products", "within usize", "overflow");
        return;
    };
    rep.check(Rule::CoverageSpecial);
    let want_in = outer_inner.checked_mul(pre);
    let want_ker = outer_inner.checked_mul(branch);
    let (Some(want_in), Some(want_ker)) = (want_in, want_ker) else {
        rep.flag(Rule::CoverageSpecial, i, name, "block products", "within usize", "overflow");
        return;
    };
    check_operand_elements(chain, i, "input (pre block)", &e.op.input, want_in, rep);
    if let Some(k) = &e.op.kernel {
        check_operand_elements(chain, i, "kernel (branch)", k, want_ker, rep);
    } else {
        rep.flag(Rule::CoverageSpecial, i, name, "kernel operand", "a branch block", "none");
    }
}

/// Element-count obligation for a special-op operand: provable only
/// for well-formed chain-internal producers (externals are
/// materialized to fit; forward references are the acyclicity pass's
/// finding).
fn check_operand_elements(
    chain: &GconvChain,
    i: usize,
    what: &str,
    operand: &DataRef,
    want: usize,
    rep: &mut AuditReport,
) {
    let DataRef::Gconv(p) = operand else {
        return;
    };
    if *p >= i || !params_ok(&chain.entries()[*p].op) {
        return;
    }
    let have: usize = operand_extents(&chain.entries()[*p].op).iter().product();
    if have != want {
        rep.flag(
            Rule::CoverageSpecial,
            i,
            &chain.entries()[i].op.name,
            format!("{what} operand #{p} elements"),
            want.to_string(),
            have.to_string(),
        );
    }
}

fn checked_product(vals: impl Iterator<Item = usize>) -> Option<usize> {
    let mut acc = 1usize;
    for v in vals {
        acc = acc.checked_mul(v)?;
    }
    Some(acc)
}
