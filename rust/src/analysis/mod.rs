//! Static chain auditor: prove a lowered (optionally fused) GCONV
//! chain safe to execute — without executing it.
//!
//! The paper's whole-life-cost argument (§2, §6) rests on the GCONV
//! chain being a *uniform, analyzable* representation. This module
//! turns that claim into checked invariants: [`audit_chain`] walks a
//! chain and either proves a set of named rules or emits structured
//! [`Diagnostic`]s (rule id, chain entry, operand/dimension,
//! expected-vs-found). Nothing here evaluates numerics; every pass is
//! pure shape/graph arithmetic re-derived independently from the
//! executor, so the audit cross-checks `exec` rather than quoting it.
//!
//! Passes (one submodule each):
//! - [`coverage`] — operand coverage: every loop-nest read of an
//!   input/kernel operand falls inside the producer's bound extents
//!   under the stride/padding/broadcast rules of `exec::interp`'s
//!   binder (re-derived here, not called).
//! - [`disjoint`] — write disjointness: the (group, column-block)
//!   parallel GEMM jobs of `exec::kernels` write non-overlapping
//!   output ranges (the machine-checked justification for the raw
//!   output-pointer jobs there), and special-op scatter/concat steps
//!   partition their outputs exactly.
//! - [`fusion_audit`] — fusion legality re-audit: re-derives the
//!   refusal rules of `mapping::fusion` on the fused chain (padding
//!   zero-preservation, specials never fuse, slot provenance).
//! - [`dataflow`] — dataflow soundness: acyclicity, level-schedule
//!   monotonicity and use-count/refcount consistency with
//!   `exec::chain_exec`'s scheduler (no read-after-free under buffer
//!   recycling), LUT names resolvable.
//! - [`resources`] — resource bounds: peak live bytes under the level
//!   schedule vs a configurable budget (`BufferPool` capacity scale).
//!
//! Wired in three layers: a debug-mode assertion in
//! `exec::serve::SessionBuilder::build`, import rejection in
//! `Engine::register_spec` + the `specs` subcommand, and the
//! `gconv-chain audit` CLI (per-rule report over the benchmark
//! networks and bundled specs).

pub mod coverage;
pub mod dataflow;
pub mod disjoint;
pub mod fusion_audit;
pub mod resources;

use crate::exec::{KernelTier, GEMM_MIN_REDUCTION};
use crate::gconv::chain::GconvChain;
use crate::gconv::op::{DataRef, GconvOp, MainOp, ReduceOp};
use std::fmt;

/// A named invariant the auditor proves (or flags). Rule ids are
/// stable strings (`pass.check`) — tests and CI match on them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Every loop parameter (`Ng`/`Nop`/`Nopc`/`Nks`/stride) is >= 1,
    /// the dimension count fits the interpreter, and `reduce: None`
    /// has no reduction loops.
    CoverageParams,
    /// A chain-internal input operand covers the consumer's expected
    /// extents under the binder's reshape/rank-aligned/squeezed rules.
    CoverageInput,
    /// A main operator that consumes parameters has a kernel operand
    /// of exactly the expected element count.
    CoverageKernel,
    /// Special entries (max-pool BP, concat) have both operands with
    /// the element counts their dedicated routines require.
    CoverageSpecial,
    /// The (group, row, column) GEMM job partition is a bijection onto
    /// the output: extent products match and index arithmetic cannot
    /// overflow, so parallel jobs write disjoint ranges.
    DisjointGemm,
    /// Max-pool BP scatter routes each window's gradient inside a
    /// single window set (`Ng = Nop = 1` per forward dimension).
    DisjointScatter,
    /// Concat block copies partition the output exactly
    /// (`pre + branch` extents tile the concatenation axis).
    DisjointConcat,
    /// A padded entry that absorbed a producer into `pre` keeps the
    /// padding value: the composed pipeline maps +0.0 to +0.0
    /// bit-exactly (the `mapping::fusion` refusal rule, re-derived).
    FusionPadding,
    /// Special entries never participate in operation fusion.
    FusionSpecial,
    /// Fusion provenance records name a known operator slot.
    FusionSlot,
    /// Operand references point strictly backwards (the chain is a
    /// DAG in execution order).
    DataflowAcyclic,
    /// The level schedule is monotone: every producer's level precedes
    /// its consumers', and wanted outputs are in range.
    DataflowSchedule,
    /// Replaying the executor's refcounted free protocol never reads a
    /// buffer after its last consumer released it.
    DataflowRefcount,
    /// Every LUT name in a pre/post pipeline resolves.
    DataflowLut,
    /// Peak live bytes under the level schedule stay within the
    /// configured budget.
    ResourcePeak,
    /// Element/byte size arithmetic stays within `usize`.
    ResourceOverflow,
}

impl Rule {
    /// All rules, in declaration order (the per-rule report order).
    pub const ALL: [Rule; 16] = [
        Rule::CoverageParams,
        Rule::CoverageInput,
        Rule::CoverageKernel,
        Rule::CoverageSpecial,
        Rule::DisjointGemm,
        Rule::DisjointScatter,
        Rule::DisjointConcat,
        Rule::FusionPadding,
        Rule::FusionSpecial,
        Rule::FusionSlot,
        Rule::DataflowAcyclic,
        Rule::DataflowSchedule,
        Rule::DataflowRefcount,
        Rule::DataflowLut,
        Rule::ResourcePeak,
        Rule::ResourceOverflow,
    ];

    /// Stable rule id (`pass.check`).
    pub fn id(self) -> &'static str {
        match self {
            Rule::CoverageParams => "coverage.params",
            Rule::CoverageInput => "coverage.input",
            Rule::CoverageKernel => "coverage.kernel",
            Rule::CoverageSpecial => "coverage.special",
            Rule::DisjointGemm => "disjoint.gemm",
            Rule::DisjointScatter => "disjoint.scatter",
            Rule::DisjointConcat => "disjoint.concat",
            Rule::FusionPadding => "fusion.padding",
            Rule::FusionSpecial => "fusion.special",
            Rule::FusionSlot => "fusion.slot",
            Rule::DataflowAcyclic => "dataflow.acyclic",
            Rule::DataflowSchedule => "dataflow.schedule",
            Rule::DataflowRefcount => "dataflow.refcount",
            Rule::DataflowLut => "dataflow.lut",
            Rule::ResourcePeak => "resource.peak",
            Rule::ResourceOverflow => "resource.overflow",
        }
    }

    /// One-line description for the per-rule report table.
    pub fn describes(self) -> &'static str {
        match self {
            Rule::CoverageParams => "loop parameters >= 1, dims bounded, reduce consistent",
            Rule::CoverageInput => "input operand covers expected extents (bind rules)",
            Rule::CoverageKernel => "kernel operand present with exact element count",
            Rule::CoverageSpecial => "special-op operands sized for their native routines",
            Rule::DisjointGemm => "parallel GEMM jobs partition the output (bijection)",
            Rule::DisjointScatter => "max-pool BP scatter stays inside its window set",
            Rule::DisjointConcat => "concat block copies tile the output exactly",
            Rule::FusionPadding => "fused pre pipeline preserves padding zeros bit-exactly",
            Rule::FusionSpecial => "special entries never absorb fused ops",
            Rule::FusionSlot => "fusion records name a known operator slot",
            Rule::DataflowAcyclic => "operand references point strictly backwards",
            Rule::DataflowSchedule => "level schedule monotone, wanted outputs in range",
            Rule::DataflowRefcount => "no read-after-free under refcounted recycling",
            Rule::DataflowLut => "every pre/post LUT name resolves",
            Rule::ResourcePeak => "peak live bytes within the configured budget",
            Rule::ResourceOverflow => "size arithmetic within usize",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One failed proof obligation: which rule, where, and the
/// expected-vs-found mismatch.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// The violated rule.
    pub rule: Rule,
    /// Chain entry index (`None` for whole-chain findings).
    pub entry: Option<usize>,
    /// Op name of the entry (empty for whole-chain findings).
    pub name: String,
    /// The dimension/operand/quantity the rule inspected.
    pub subject: String,
    /// What the rule requires.
    pub expected: String,
    /// What the chain carries.
    pub found: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.entry {
            Some(i) => write!(
                f,
                "{}: entry #{i} ({}) {}: expected {}, found {}",
                self.rule, self.name, self.subject, self.expected, self.found
            ),
            None => write!(
                f,
                "{}: {}: expected {}, found {}",
                self.rule, self.subject, self.expected, self.found
            ),
        }
    }
}

/// Auditor configuration.
#[derive(Clone, Debug)]
pub struct AuditConfig {
    /// Peak-live-bytes budget for [`Rule::ResourcePeak`] (default:
    /// unlimited — the peak is still computed and reported).
    pub budget_bytes: usize,
    /// Output entries the schedule must retain (default: the last
    /// entry, matching `ChainExec::run_last` and session defaults).
    pub wanted: Option<Vec<usize>>,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig { budget_bytes: usize::MAX, wanted: None }
    }
}

impl AuditConfig {
    /// Default config with `GCONV_AUDIT_BUDGET` (bytes) applied when
    /// set and parseable — the test lever the `specs` gate uses.
    pub fn from_env() -> Self {
        let mut cfg = AuditConfig::default();
        if let Ok(v) = std::env::var("GCONV_AUDIT_BUDGET") {
            if let Ok(bytes) = v.trim().parse::<usize>() {
                cfg.budget_bytes = bytes;
            }
        }
        cfg
    }
}

/// The result of auditing one chain: per-rule obligation counts, the
/// diagnostics, and the derived resource peak.
#[derive(Clone, Debug)]
pub struct AuditReport {
    /// Network the chain was lowered from.
    pub network: String,
    /// Chain length.
    pub entries: usize,
    /// Peak live bytes under the level schedule (computed by the
    /// resource pass even when no budget is set).
    pub peak_live_bytes: usize,
    /// Entries the static tier model places on the packed-GEMM path —
    /// the parallel write sites the disjointness proof covers.
    pub gemm_sites: usize,
    /// Special entries (scatter/concat) covered by the disjointness
    /// proof.
    pub scatter_sites: usize,
    checked: [usize; Rule::ALL.len()],
    diagnostics: Vec<Diagnostic>,
}

impl AuditReport {
    fn new(network: &str, entries: usize) -> Self {
        AuditReport {
            network: network.to_string(),
            entries,
            peak_live_bytes: 0,
            gemm_sites: 0,
            scatter_sites: 0,
            checked: [0; Rule::ALL.len()],
            diagnostics: Vec::new(),
        }
    }

    /// True when every obligation was proven.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// All diagnostics, in pass order.
    pub fn diagnostics(&self) -> &[Diagnostic] {
        &self.diagnostics
    }

    /// Obligations discharged under `rule`.
    pub fn checked(&self, rule: Rule) -> usize {
        self.checked[rule.index()]
    }

    /// Total obligations discharged.
    pub fn total_checked(&self) -> usize {
        self.checked.iter().sum()
    }

    /// Diagnostics emitted under `rule`.
    pub fn flagged(&self, rule: Rule) -> usize {
        self.diagnostics.iter().filter(|d| d.rule == rule).count()
    }

    /// True when `rule` emitted at least one diagnostic.
    pub fn has(&self, rule: Rule) -> bool {
        self.diagnostics.iter().any(|d| d.rule == rule)
    }

    pub(crate) fn check(&mut self, rule: Rule) {
        self.checked[rule.index()] += 1;
    }

    pub(crate) fn flag(
        &mut self,
        rule: Rule,
        entry: usize,
        name: &str,
        subject: impl Into<String>,
        expected: impl Into<String>,
        found: impl Into<String>,
    ) {
        self.diagnostics.push(Diagnostic {
            rule,
            entry: Some(entry),
            name: name.to_string(),
            subject: subject.into(),
            expected: expected.into(),
            found: found.into(),
        });
    }

    pub(crate) fn flag_chain(
        &mut self,
        rule: Rule,
        subject: impl Into<String>,
        expected: impl Into<String>,
        found: impl Into<String>,
    ) {
        self.diagnostics.push(Diagnostic {
            rule,
            entry: None,
            name: String::new(),
            subject: subject.into(),
            expected: expected.into(),
            found: found.into(),
        });
    }
}

impl fmt::Display for AuditReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "audit {}: {} entries, {} obligations, {} diagnostic(s), peak live {} bytes \
             ({} GEMM + {} scatter parallel write sites proven disjoint)",
            self.network,
            self.entries,
            self.total_checked(),
            self.diagnostics.len(),
            self.peak_live_bytes,
            self.gemm_sites,
            self.scatter_sites
        )?;
        for d in &self.diagnostics {
            writeln!(f, "  {d}")?;
        }
        Ok(())
    }
}

/// Audit `chain` with default configuration (no resource budget, the
/// last entry as the wanted output).
pub fn audit_chain(chain: &GconvChain) -> AuditReport {
    audit_chain_with(chain, &AuditConfig::default())
}

/// Audit `chain` under `cfg`, running every pass regardless of earlier
/// findings (one report names every violated rule, not just the first).
pub fn audit_chain_with(chain: &GconvChain, cfg: &AuditConfig) -> AuditReport {
    let mut rep = AuditReport::new(&chain.network, chain.len());
    coverage::run(chain, &mut rep);
    disjoint::run(chain, &mut rep);
    fusion_audit::run(chain, &mut rep);
    dataflow::run(chain, cfg, &mut rep);
    resources::run(chain, cfg, &mut rep);
    rep
}

// ------------------------------------------------------------------
// Shared graph/shape derivations. These deliberately re-derive what
// `exec::chain_exec` computes (levels, reachability, use counts)
// rather than calling it: the audit is an independent implementation
// the executor is checked against. Unlike the executor, every helper
// here guards against corrupted chains (forward/out-of-range operand
// references) instead of assuming `GconvChain::push` validated them —
// mutation tests feed exactly such chains.
// ------------------------------------------------------------------

/// Producer indices `op` reads (duplicates kept: an entry using the
/// same producer as input and kernel holds two uses, matching the
/// executor's per-reference accounting).
pub(crate) fn producer_deps(op: &GconvOp) -> Vec<usize> {
    let mut out = Vec::with_capacity(2);
    if let DataRef::Gconv(p) = op.input {
        out.push(p);
    }
    if let Some(DataRef::Gconv(p)) = op.kernel {
        out.push(p);
    }
    out
}

/// `producer_deps` restricted to well-formed backward references
/// (`p < i`) — the safe subset every pass except the acyclicity check
/// (which reports the rest) operates on.
pub(crate) fn backward_deps(op: &GconvOp, i: usize) -> Vec<usize> {
    let mut out = producer_deps(op);
    out.retain(|&p| p < i);
    out
}

/// Are all loop parameters of `op` positive? Derivations below divide
/// by `Ng` and multiply extents, so passes skip entries that fail this
/// (the coverage pass flags them).
pub(crate) fn params_ok(op: &GconvOp) -> bool {
    op.dims
        .iter()
        .all(|&(_, p)| p.ng >= 1 && p.nop >= 1 && p.nopc >= 1 && p.nks >= 1 && p.s >= 1)
}

/// The extents a chain-internal operand presents to its consumer —
/// the producer's output extents, `[1]` for zero-dimension producers
/// (mirrors the executor's operand shaping).
pub(crate) fn operand_extents(op: &GconvOp) -> Vec<usize> {
    let d = op.output_extents();
    if d.is_empty() {
        vec![1]
    } else {
        d
    }
}

/// The execution tier the planner selects for `op`, re-derived from
/// shape/operator properties alone (the planner needs bound tensors;
/// the audit must not).
pub(crate) fn static_tier(op: &GconvOp) -> KernelTier {
    if op.dims.is_empty() {
        return KernelTier::Naive;
    }
    let need_kernel = !matches!(op.main, MainOp::Pass);
    let ker_elements: usize =
        if need_kernel { op.dims.iter().map(|&(_, p)| p.kernel_extent()).product() } else { 0 };
    let red_total = op.dims.iter().map(|&(_, p)| p.nks).product::<usize>().max(1);
    if op.main == MainOp::Mul
        && op.reduce == ReduceOp::Add
        && ker_elements > 0
        && red_total >= GEMM_MIN_REDUCTION
    {
        KernelTier::Gemm
    } else {
        KernelTier::Odometer
    }
}

/// The level schedule the dataflow and resource passes replay:
/// reachability from `wanted`, per-entry levels, and per-entry use
/// counts — all over guarded backward deps only.
pub(crate) struct Schedule {
    /// Entries reachable from `wanted`.
    pub(crate) needed: Vec<bool>,
    /// Needed entries grouped by level, ascending.
    pub(crate) levels: Vec<Vec<usize>>,
    /// Consumer counts within the needed subgraph, plus one per
    /// `wanted` occurrence.
    pub(crate) uses: Vec<usize>,
    /// The wanted set actually used (in-range entries only).
    pub(crate) wanted: Vec<usize>,
}

pub(crate) fn schedule(chain: &GconvChain, cfg: &AuditConfig) -> Schedule {
    let n = chain.len();
    let mut wanted = cfg
        .wanted
        .clone()
        .unwrap_or_else(|| if n > 0 { vec![n - 1] } else { Vec::new() });
    wanted.retain(|&w| w < n);

    let mut needed = vec![false; n];
    for &w in &wanted {
        needed[w] = true;
    }
    for i in (0..n).rev() {
        if needed[i] {
            for p in backward_deps(&chain.entries()[i].op, i) {
                needed[p] = true;
            }
        }
    }

    let mut level = vec![0usize; n];
    for i in 0..n {
        for p in backward_deps(&chain.entries()[i].op, i) {
            level[i] = level[i].max(level[p] + 1);
        }
    }
    let depth = level.iter().copied().max().map_or(0, |m| m + 1);
    let mut levels = vec![Vec::new(); depth];
    for (i, &l) in level.iter().enumerate() {
        if needed[i] {
            levels[l].push(i);
        }
    }
    levels.retain(|l| !l.is_empty());

    let mut uses = vec![0usize; n];
    for i in 0..n {
        if needed[i] {
            for p in backward_deps(&chain.entries()[i].op, i) {
                uses[p] += 1;
            }
        }
    }
    for &w in &wanted {
        uses[w] += 1;
    }

    Schedule { needed, levels, uses, wanted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::lower::{lower_network, Mode};
    use crate::mapping::fuse_executable;
    use crate::networks::mobilenet_block;

    #[test]
    fn rule_ids_are_unique_and_indexed() {
        for (i, r) in Rule::ALL.iter().enumerate() {
            assert_eq!(r.index(), i);
        }
        let mut ids: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), Rule::ALL.len());
    }

    #[test]
    fn mobilenet_block_audits_clean_all_modes() {
        let net = mobilenet_block(2, 8, 16);
        for mode in [Mode::Inference, Mode::Training] {
            for fuse in [false, true] {
                let mut chain = lower_network(&net, mode);
                if fuse {
                    fuse_executable(&mut chain);
                }
                let rep = audit_chain(&chain);
                assert!(rep.is_clean(), "mode {mode:?} fuse {fuse}:\n{rep}");
                assert!(rep.total_checked() > 0);
                assert!(rep.peak_live_bytes > 0);
            }
        }
    }

    #[test]
    fn diagnostics_render_rule_entry_and_mismatch() {
        let mut rep = AuditReport::new("t", 3);
        rep.flag(Rule::CoverageInput, 2, "conv1.fp", "input dimension H", ">= 10", "8");
        rep.flag_chain(Rule::DataflowSchedule, "wanted output #9", "< 3", "9");
        let text = format!("{rep}");
        assert!(text.contains("coverage.input: entry #2 (conv1.fp) input dimension H"));
        assert!(text.contains("expected >= 10, found 8"));
        assert!(text.contains("dataflow.schedule: wanted output #9"));
        assert!(!rep.is_clean());
        assert_eq!(rep.flagged(Rule::CoverageInput), 1);
    }
}
