//! Faster R-CNN with ZFNet backbone (Ren et al. / Zeiler & Fergus) —
//! paper code **ZFFR**.
//!
//! New layer types per Table 1(a): RoI pooling and proposal. Detection
//! networks train with batch 1 (one image, many RoIs).

use crate::ir::{Layer, Network, PoolKind, Shape};

/// Build ZF + Faster R-CNN for `batch` 3×600×600 images (short-side-600
/// protocol, square for simplicity), 300 proposals.
pub fn zf_faster_rcnn(batch: usize) -> Network {
    let mut n = Network::new("ZF-FasterRCNN");
    let data = n.add("data", Layer::Input { shape: Shape::bchw(batch, 3, 600, 600) }, &[]);

    // ZFNet backbone (conv1..conv5).
    let c1 = n.add(
        "conv1",
        Layer::Conv { out_channels: 96, kernel: (7, 7), stride: 2, pad: 3, groups: 1 },
        &[data],
    );
    let r1 = n.add("relu1", Layer::Relu, &[c1]);
    let l1 = n.add("norm1", Layer::Lrn { local_size: 3 }, &[r1]);
    let p1 = n.add(
        "pool1",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 1 },
        &[l1],
    );

    let c2 = n.add(
        "conv2",
        Layer::Conv { out_channels: 256, kernel: (5, 5), stride: 2, pad: 2, groups: 1 },
        &[p1],
    );
    let r2 = n.add("relu2", Layer::Relu, &[c2]);
    let l2 = n.add("norm2", Layer::Lrn { local_size: 3 }, &[r2]);
    let p2 = n.add(
        "pool2",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 1 },
        &[l2],
    );

    let c3 = n.add(
        "conv3",
        Layer::Conv { out_channels: 384, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[p2],
    );
    let r3 = n.add("relu3", Layer::Relu, &[c3]);
    let c4 = n.add(
        "conv4",
        Layer::Conv { out_channels: 384, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[r3],
    );
    let r4 = n.add("relu4", Layer::Relu, &[c4]);
    let c5 = n.add(
        "conv5",
        Layer::Conv { out_channels: 256, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[r4],
    );
    let r5 = n.add("relu5", Layer::Relu, &[c5]);

    // Region proposal network.
    let rpn = n.add(
        "rpn_conv/3x3",
        Layer::Conv { out_channels: 256, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[r5],
    );
    let rpn_r = n.add("rpn_relu", Layer::Relu, &[rpn]);
    let rpn_cls = n.add(
        "rpn_cls_score",
        Layer::Conv { out_channels: 18, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[rpn_r],
    );
    let _rpn_bbox = n.add(
        "rpn_bbox_pred",
        Layer::Conv { out_channels: 36, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[rpn_r],
    );
    let proposal = n.add("proposal", Layer::Proposal { anchors: 9 }, &[rpn_cls]);
    let _ = proposal;

    // RoI pooling on conv5 features + detection head.
    let roi = n.add("roi_pool5", Layer::RoiPool { num_rois: 300, output: (6, 6) }, &[r5]);
    let f6 = n.add("fc6", Layer::FullyConnected { out_features: 4096 }, &[roi]);
    let r6 = n.add("relu6", Layer::Relu, &[f6]);
    let d6 = n.add("drop6", Layer::Dropout, &[r6]);
    let f7 = n.add("fc7", Layer::FullyConnected { out_features: 4096 }, &[d6]);
    let r7 = n.add("relu7", Layer::Relu, &[f7]);
    let d7 = n.add("drop7", Layer::Dropout, &[r7]);
    let cls = n.add("cls_score", Layer::FullyConnected { out_features: 21 }, &[d7]);
    let _bbox = n.add("bbox_pred", Layer::FullyConnected { out_features: 84 }, &[d7]);
    n.add("cls_prob", Layer::Softmax, &[cls]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn roi_pool_produces_300_rois() {
        let net = zf_faster_rcnn(1);
        let roi = net.nodes().iter().find(|n| n.name == "roi_pool5").unwrap();
        assert_eq!(roi.output.extent(Dim::B), 300);
        assert_eq!(roi.output.extent(Dim::H), 6);
    }

    #[test]
    fn has_proposal_and_roi_layers() {
        let net = zf_faster_rcnn(1);
        assert!(net.nodes().iter().any(|n| matches!(n.layer, Layer::Proposal { .. })));
        assert!(net.nodes().iter().any(|n| matches!(n.layer, Layer::RoiPool { .. })));
    }
}
