//! The seven benchmark CNNs of the paper (Table 1(a)).
//!
//! Four classification networks — AlexNet (AN), GoogLeNet (GLN),
//! DenseNet-121 (DN), MobileNet v1 (MN) — plus Faster R-CNN with a ZFNet
//! backbone (ZFFR), the C3D video network and CapsNet (CapNN). Layer
//! hyper-parameters follow the original publications / Caffe prototxts.
//!
//! All builders take the mini-batch size; the paper trains with
//! mini-batch 32 for the 2-D CNNs (Fig. 9 note) and smaller batches for
//! the memory-heavy C3D/CapsNet.

mod alexnet;
mod c3d;
mod capsnet;
mod densenet;
mod googlenet;
mod mobilenet;
mod zffr;

pub use alexnet::alexnet;
pub use c3d::c3d;
pub use capsnet::capsnet;
pub use densenet::densenet121;
pub use googlenet::googlenet;
pub use mobilenet::mobilenet;
pub use zffr::zf_faster_rcnn;

use anyhow::{anyhow, Context, Result};

use crate::frontend;
use crate::ir::Network;

/// Short paper codes for the benchmarks, in Table 1(a) order.
pub const BENCHMARK_CODES: [&str; 7] = ["AN", "GLN", "DN", "MN", "ZFFR", "C3D", "CapNN"];

/// The paper's mini-batch size for a benchmark code (Fig. 9 note: 32
/// for the 2-D classification CNNs, smaller for the memory-heavy ones).
pub fn paper_batch(code: &str) -> usize {
    match code {
        "ZFFR" => 1,
        "C3D" => 8,
        "CapNN" => 16,
        _ => 32,
    }
}

/// Build a benchmark by its paper code with the paper's batch sizes.
pub fn benchmark(code: &str) -> Network {
    try_benchmark(code).unwrap_or_else(|e| panic!("{e}"))
}

/// [`benchmark`], returning a named error for unknown codes.
pub fn try_benchmark(code: &str) -> Result<Network> {
    try_benchmark_with_batch(code, paper_batch(code))
}

/// Build a benchmark by its paper code at an explicit mini-batch size
/// (native-execution smokes and benches run the full topologies at
/// batch 1 to keep wall-clock sane).
pub fn benchmark_with_batch(code: &str, batch: usize) -> Network {
    try_benchmark_with_batch(code, batch).unwrap_or_else(|e| panic!("{e}"))
}

/// [`benchmark_with_batch`], returning a named error for unknown
/// codes: the error lists the benchmark codes and the discovered
/// bundled spec files instead of panicking on a typo.
pub fn try_benchmark_with_batch(code: &str, batch: usize) -> Result<Network> {
    Ok(match code {
        "AN" => alexnet(batch),
        "GLN" => googlenet(batch),
        "DN" => densenet121(batch),
        "MN" => mobilenet(batch),
        "ZFFR" => zf_faster_rcnn(batch),
        "C3D" => c3d(batch),
        "CapNN" => capsnet(batch),
        other => return Err(unknown_network(other)),
    })
}

/// The `unknown network` error: names the typo'd code and lists what
/// *would* resolve — benchmark codes plus every bundled spec file.
/// Public so other entry points (CLI serve) can fail the same way.
pub fn unknown_network(name: &str) -> anyhow::Error {
    let stems: Vec<String> = frontend::discover_specs()
        .iter()
        .filter_map(|p| p.file_stem().map(|s| s.to_string_lossy().into_owned()))
        .collect();
    let specs = if stems.is_empty() {
        String::new()
    } else {
        format!("; spec files in {}: {}", frontend::spec_dir().display(), stems.join(", "))
    };
    anyhow!(
        "unknown network {name:?}: benchmark codes are {}{specs}; \
         a path to a .json model spec also works",
        BENCHMARK_CODES.join(", ")
    )
}

/// Resolve a network by benchmark code, spec-file path, or bundled
/// spec name (a file stem under the spec directory), at the paper /
/// spec-default batch size.
pub fn resolve(name: &str) -> Result<Network> {
    resolve_with_batch(name, None)
}

/// [`resolve`] with an optional batch override (benchmark builders are
/// invoked at that batch; spec inputs get their `B` extent rewritten).
pub fn resolve_with_batch(name: &str, batch: Option<usize>) -> Result<Network> {
    if BENCHMARK_CODES.contains(&name) {
        return try_benchmark_with_batch(name, batch.unwrap_or_else(|| paper_batch(name)));
    }
    let Some(path) = frontend::find_spec(name) else {
        return Err(unknown_network(name));
    };
    let spec = frontend::load_spec(&path)?;
    frontend::build_with_batch(&spec, batch)
        .with_context(|| format!("building network from {}", path.display()))
}

/// All seven benchmarks.
pub fn all_benchmarks() -> Vec<Network> {
    BENCHMARK_CODES.iter().map(|c| benchmark(c)).collect()
}

/// A small synthetic network used by tests and the quickstart example:
/// depthwise/BN/ReLU/pointwise — one MobileNet block (Fig. 1(a)).
pub fn mobilenet_block(batch: usize, channels: usize, hw: usize) -> Network {
    use crate::ir::{Layer, Shape};
    let mut net = Network::new("MobileNetBlock");
    let input =
        net.add("data", Layer::Input { shape: Shape::bchw(batch, channels, hw, hw) }, &[]);
    let dw = net.add(
        "conv_dw",
        Layer::Conv {
            out_channels: channels,
            kernel: (3, 3),
            stride: 1,
            pad: 1,
            groups: channels,
        },
        &[input],
    );
    let bn1 = net.add("bn_dw", Layer::BatchNorm, &[dw]);
    let r1 = net.add("relu_dw", Layer::Relu, &[bn1]);
    let pw = net.add(
        "conv_pw",
        Layer::Conv { out_channels: channels * 2, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[r1],
    );
    let bn2 = net.add("bn_pw", Layer::BatchNorm, &[pw]);
    net.add("relu_pw", Layer::Relu, &[bn2]);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::lower::{lower_network, Mode};

    #[test]
    fn all_benchmarks_build() {
        for net in all_benchmarks() {
            assert!(!net.is_empty(), "{} is empty", net.name);
        }
    }

    #[test]
    fn all_benchmarks_lower_to_chains() {
        for net in all_benchmarks() {
            let chain = lower_network(&net, Mode::Training);
            assert!(chain.len() >= net.len(), "{} chain too short", net.name);
            assert!(chain.total_work() > 0);
        }
    }

    #[test]
    fn nontraditional_ratio_matches_table1_ordering() {
        // Table 1(a): DN and MN have the highest non-traditional layer
        // ratios among the classification CNNs; C3D is dominated by 3-D
        // (non-traditional) computation.
        let ratio = |code: &str| {
            let chain = lower_network(&benchmark(code), Mode::Training);
            let (t, n) = chain.work_split();
            n as f64 / (t + n) as f64
        };
        let an = ratio("AN");
        let mn = ratio("MN");
        let c3d = ratio("C3D");
        assert!(
            mn > an,
            "MobileNet ({mn:.3}) should be more non-traditional than AlexNet ({an:.3})"
        );
        assert!(c3d > 0.5, "C3D is dominated by 3-D (non-traditional) compute, got {c3d:.3}");
    }

    #[test]
    fn alexnet_parameter_count_is_plausible() {
        // ~61M parameters in the original AlexNet.
        let n = alexnet(32).param_count();
        assert!((55_000_000..70_000_000).contains(&n), "AlexNet params {n}");
    }

    #[test]
    fn mobilenet_parameter_count_is_plausible() {
        // ~4.2M parameters in MobileNet v1.
        let n = mobilenet(32).param_count();
        assert!((3_000_000..6_000_000).contains(&n), "MobileNet params {n}");
    }

    #[test]
    fn unknown_codes_yield_named_errors_listing_alternatives() {
        let err = try_benchmark("MOBILENET").unwrap_err().to_string();
        assert!(err.contains("unknown network \"MOBILENET\""), "{err}");
        assert!(err.contains("AN, GLN, DN, MN, ZFFR, C3D, CapNN"), "{err}");
    }

    #[test]
    fn resolve_handles_codes_and_rejects_typos() {
        assert_eq!(resolve_with_batch("MN", Some(1)).unwrap().name, "MobileNet");
        assert!(resolve("MNN").is_err());
    }

    #[test]
    fn block_helper_matches_figure_1a() {
        let net = mobilenet_block(4, 16, 8);
        let kinds: Vec<&str> = net.nodes().iter().map(|n| n.layer.kind()).collect();
        assert_eq!(
            kinds,
            vec!["input", "conv(grouped)", "batch_norm", "relu", "conv", "batch_norm", "relu"]
        );
    }
}
