//! DenseNet-121 (Huang et al., 2017) — paper code **DN**.
//!
//! New layer types per Table 1(a): batch normalization and scale (the
//! Caffe deployment splits BN into `BatchNorm` + `Scale`, which we model
//! the same way). Every dense layer is BN→Scale→ReLU→1×1 conv →
//! BN→Scale→ReLU→3×3 conv with growth rate 32, concatenated.

use crate::ir::{Layer, Network, NodeId, PoolKind, Shape};

const GROWTH: usize = 32;

/// BN → Scale → ReLU → conv composite.
fn bsrc(
    n: &mut Network,
    name: &str,
    input: NodeId,
    out_ch: usize,
    kernel: usize,
    pad: usize,
) -> NodeId {
    let bn = n.add(&format!("{name}/bn"), Layer::BatchNorm, &[input]);
    let sc = n.add(&format!("{name}/scale"), Layer::Scale, &[bn]);
    let re = n.add(&format!("{name}/relu"), Layer::Relu, &[sc]);
    n.add(
        &format!("{name}/conv"),
        Layer::Conv { out_channels: out_ch, kernel: (kernel, kernel), stride: 1, pad, groups: 1 },
        &[re],
    )
}

/// One dense layer: bottleneck 1×1 (4·growth) then 3×3 (growth), concat.
fn dense_layer(n: &mut Network, name: &str, input: NodeId) -> NodeId {
    let b = bsrc(n, &format!("{name}/x1"), input, 4 * GROWTH, 1, 0);
    let c = bsrc(n, &format!("{name}/x2"), b, GROWTH, 3, 1);
    n.add(&format!("{name}/concat"), Layer::Concat, &[input, c])
}

/// Transition: BN→Scale→ReLU→1×1 conv (halve channels) → 2×2 avg pool.
fn transition(n: &mut Network, name: &str, input: NodeId, out_ch: usize) -> NodeId {
    let c = bsrc(n, name, input, out_ch, 1, 0);
    n.add(
        &format!("{name}/pool"),
        Layer::Pool { kind: PoolKind::Avg, kernel: 2, stride: 2, pad: 0 },
        &[c],
    )
}

/// Build DenseNet-121 for `batch` 3×224×224 images.
pub fn densenet121(batch: usize) -> Network {
    let mut n = Network::new("DenseNet121");
    let data = n.add("data", Layer::Input { shape: Shape::bchw(batch, 3, 224, 224) }, &[]);
    let c1 = n.add(
        "conv1",
        Layer::Conv { out_channels: 64, kernel: (7, 7), stride: 2, pad: 3, groups: 1 },
        &[data],
    );
    let bn1 = n.add("conv1/bn", Layer::BatchNorm, &[c1]);
    let sc1 = n.add("conv1/scale", Layer::Scale, &[bn1]);
    let r1 = n.add("conv1/relu", Layer::Relu, &[sc1]);
    let mut x = n.add(
        "pool1",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[r1],
    );

    let mut channels = 64;
    for (bi, layers) in [6usize, 12, 24, 16].iter().enumerate() {
        for li in 0..*layers {
            x = dense_layer(&mut n, &format!("block{}/layer{}", bi + 1, li + 1), x);
            channels += GROWTH;
        }
        if bi < 3 {
            channels /= 2;
            x = transition(&mut n, &format!("transition{}", bi + 1), x, channels);
        }
    }
    let bn = n.add("final/bn", Layer::BatchNorm, &[x]);
    let sc = n.add("final/scale", Layer::Scale, &[bn]);
    let re = n.add("final/relu", Layer::Relu, &[sc]);
    let gap = n.add("pool_final", Layer::GlobalAvgPool, &[re]);
    let fc = n.add("fc6", Layer::FullyConnected { out_features: 1000 }, &[gap]);
    n.add("prob", Layer::Softmax, &[fc]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn channel_growth_follows_densenet121() {
        let net = densenet121(32);
        let out = |name: &str| net.nodes().iter().find(|n| n.name == name).unwrap().output.clone();
        // After block1 (6 layers): 64 + 6*32 = 256; transition halves.
        assert_eq!(out("block1/layer6/concat").extent(Dim::C), 256);
        assert_eq!(out("transition1/pool").extent(Dim::C), 128);
        // Final: 512 + 16*32 = 1024 channels at 7x7.
        assert_eq!(out("block4/layer16/concat").extent(Dim::C), 1024);
        assert_eq!(out("block4/layer16/concat").extent(Dim::H), 7);
    }

    #[test]
    fn bn_scale_pairs_dominate_layer_count() {
        // Table 1(a): 66% of DenseNet layers are non-traditional.
        let net = densenet121(32);
        let non_trad = net.nodes().iter().filter(|n| !n.layer.is_traditional()).count();
        let ratio = non_trad as f64 / net.len() as f64;
        assert!(ratio > 0.5, "non-traditional layer ratio {ratio:.2}");
    }
}
