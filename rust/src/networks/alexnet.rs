//! AlexNet (Krizhevsky et al., 2012) — paper code **AN**.
//!
//! New layer types per Table 1(a): LRN and dropout. Uses the original
//! two-tower grouping on conv2/4/5 (groups = 2).

use crate::ir::{Layer, Network, PoolKind, Shape};

/// Build AlexNet for `batch` samples of 3×227×227.
pub fn alexnet(batch: usize) -> Network {
    let mut n = Network::new("AlexNet");
    let data = n.add("data", Layer::Input { shape: Shape::bchw(batch, 3, 227, 227) }, &[]);

    let c1 = n.add(
        "conv1",
        Layer::Conv { out_channels: 96, kernel: (11, 11), stride: 4, pad: 0, groups: 1 },
        &[data],
    );
    let r1 = n.add("relu1", Layer::Relu, &[c1]);
    let l1 = n.add("norm1", Layer::Lrn { local_size: 5 }, &[r1]);
    let p1 = n.add(
        "pool1",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[l1],
    );

    let c2 = n.add(
        "conv2",
        Layer::Conv { out_channels: 256, kernel: (5, 5), stride: 1, pad: 2, groups: 2 },
        &[p1],
    );
    let r2 = n.add("relu2", Layer::Relu, &[c2]);
    let l2 = n.add("norm2", Layer::Lrn { local_size: 5 }, &[r2]);
    let p2 = n.add(
        "pool2",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[l2],
    );

    let c3 = n.add(
        "conv3",
        Layer::Conv { out_channels: 384, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[p2],
    );
    let r3 = n.add("relu3", Layer::Relu, &[c3]);
    let c4 = n.add(
        "conv4",
        Layer::Conv { out_channels: 384, kernel: (3, 3), stride: 1, pad: 1, groups: 2 },
        &[r3],
    );
    let r4 = n.add("relu4", Layer::Relu, &[c4]);
    let c5 = n.add(
        "conv5",
        Layer::Conv { out_channels: 256, kernel: (3, 3), stride: 1, pad: 1, groups: 2 },
        &[r4],
    );
    let r5 = n.add("relu5", Layer::Relu, &[c5]);
    let p5 = n.add(
        "pool5",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[r5],
    );

    let f6 = n.add("fc6", Layer::FullyConnected { out_features: 4096 }, &[p5]);
    let r6 = n.add("relu6", Layer::Relu, &[f6]);
    let d6 = n.add("drop6", Layer::Dropout, &[r6]);
    let f7 = n.add("fc7", Layer::FullyConnected { out_features: 4096 }, &[d6]);
    let r7 = n.add("relu7", Layer::Relu, &[f7]);
    let d7 = n.add("drop7", Layer::Dropout, &[r7]);
    let f8 = n.add("fc8", Layer::FullyConnected { out_features: 1000 }, &[d7]);
    n.add("prob", Layer::Softmax, &[f8]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn feature_map_sizes_match_original() {
        let net = alexnet(32);
        let by_name = |name: &str| {
            net.nodes().iter().find(|n| n.name == name).unwrap().output.clone()
        };
        assert_eq!(by_name("conv1").extent(Dim::H), 55);
        assert_eq!(by_name("pool1").extent(Dim::H), 27);
        assert_eq!(by_name("conv2").extent(Dim::H), 27);
        assert_eq!(by_name("pool2").extent(Dim::H), 13);
        assert_eq!(by_name("pool5").extent(Dim::H), 6);
        assert_eq!(by_name("pool5").extent(Dim::C), 256);
        assert_eq!(by_name("fc8").extent(Dim::C), 1000);
    }

    #[test]
    fn has_lrn_and_dropout() {
        let net = alexnet(32);
        assert!(net.nodes().iter().any(|n| matches!(n.layer, Layer::Lrn { .. })));
        assert!(net.nodes().iter().any(|n| matches!(n.layer, Layer::Dropout)));
    }
}
