//! MobileNet v1 (Howard et al., 2017) — paper code **MN**.
//!
//! New layer type per Table 1(a): depthwise convolution. Every block is
//! the Fig. 1(a) pattern: depthwise 3×3 → BN → ReLU → pointwise 1×1 →
//! BN → ReLU.

use crate::ir::{Layer, Network, NodeId, Shape};

/// Append one depthwise-separable block.
fn block(n: &mut Network, idx: usize, input: NodeId, in_ch: usize, out_ch: usize, stride: usize) -> NodeId {
    let dw = n.add(
        &format!("conv{idx}_dw"),
        Layer::Conv { out_channels: in_ch, kernel: (3, 3), stride, pad: 1, groups: in_ch },
        &[input],
    );
    let bn1 = n.add(&format!("bn{idx}_dw"), Layer::BatchNorm, &[dw]);
    let r1 = n.add(&format!("relu{idx}_dw"), Layer::Relu, &[bn1]);
    let pw = n.add(
        &format!("conv{idx}_pw"),
        Layer::Conv { out_channels: out_ch, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[r1],
    );
    let bn2 = n.add(&format!("bn{idx}_pw"), Layer::BatchNorm, &[pw]);
    n.add(&format!("relu{idx}_pw"), Layer::Relu, &[bn2])
}

/// Build MobileNet v1 (width multiplier 1.0) for `batch` 3×224×224 images.
pub fn mobilenet(batch: usize) -> Network {
    let mut n = Network::new("MobileNet");
    let data = n.add("data", Layer::Input { shape: Shape::bchw(batch, 3, 224, 224) }, &[]);
    let c1 = n.add(
        "conv1",
        Layer::Conv { out_channels: 32, kernel: (3, 3), stride: 2, pad: 1, groups: 1 },
        &[data],
    );
    let bn1 = n.add("bn1", Layer::BatchNorm, &[c1]);
    let mut x = n.add("relu1", Layer::Relu, &[bn1]);

    // (in_ch, out_ch, stride) for the 13 separable blocks.
    let cfg: [(usize, usize, usize); 13] = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    for (i, &(ic, oc, s)) in cfg.iter().enumerate() {
        x = block(&mut n, i + 2, x, ic, oc, s);
    }
    let gap = n.add("avg_pool", Layer::GlobalAvgPool, &[x]);
    let fc = n.add("fc", Layer::FullyConnected { out_features: 1000 }, &[gap]);
    n.add("prob", Layer::Softmax, &[fc]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn final_feature_map_is_7x7x1024() {
        let net = mobilenet(32);
        let last_relu = net.nodes().iter().rev().find(|n| n.name.starts_with("relu14")).unwrap();
        assert_eq!(last_relu.output.extent(Dim::H), 7);
        assert_eq!(last_relu.output.extent(Dim::C), 1024);
    }

    #[test]
    fn depthwise_layers_are_nontraditional() {
        let net = mobilenet(32);
        let dw = net
            .nodes()
            .iter()
            .filter(|n| n.name.ends_with("_dw") && n.name.starts_with("conv"));
        for node in dw {
            assert!(!node.layer.is_traditional(), "{} should be non-traditional", node.name);
        }
    }
}
