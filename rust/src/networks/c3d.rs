//! C3D (Tran et al., 2015) — paper code **C3D**.
//!
//! New layer types per Table 1(a): 3-D convolution and 3-D pooling. Input
//! is a 16-frame 112×112 clip; Table 1(a) reports 99% of its data
//! footprint in non-traditional (3-D) layers.

use crate::ir::{Layer, Network, PoolKind, Shape};

/// Build C3D for `batch` clips of 3×16×112×112.
pub fn c3d(batch: usize) -> Network {
    let mut n = Network::new("C3D");
    let data = n.add("data", Layer::Input { shape: Shape::bcthw(batch, 3, 16, 112, 112) }, &[]);

    let conv3 = |out| Layer::Conv3d { out_channels: out, kernel: (3, 3, 3), stride: 1, pad: 1 };

    let c1 = n.add("conv1a", conv3(64), &[data]);
    let r1 = n.add("relu1a", Layer::Relu, &[c1]);
    let p1 = n.add(
        "pool1",
        Layer::Pool3d { kind: PoolKind::Max, kernel: (1, 2, 2), stride: (1, 2, 2) },
        &[r1],
    );

    let c2 = n.add("conv2a", conv3(128), &[p1]);
    let r2 = n.add("relu2a", Layer::Relu, &[c2]);
    let p2 = n.add(
        "pool2",
        Layer::Pool3d { kind: PoolKind::Max, kernel: (2, 2, 2), stride: (2, 2, 2) },
        &[r2],
    );

    let c3a = n.add("conv3a", conv3(256), &[p2]);
    let r3a = n.add("relu3a", Layer::Relu, &[c3a]);
    let c3b = n.add("conv3b", conv3(256), &[r3a]);
    let r3b = n.add("relu3b", Layer::Relu, &[c3b]);
    let p3 = n.add(
        "pool3",
        Layer::Pool3d { kind: PoolKind::Max, kernel: (2, 2, 2), stride: (2, 2, 2) },
        &[r3b],
    );

    let c4a = n.add("conv4a", conv3(512), &[p3]);
    let r4a = n.add("relu4a", Layer::Relu, &[c4a]);
    let c4b = n.add("conv4b", conv3(512), &[r4a]);
    let r4b = n.add("relu4b", Layer::Relu, &[c4b]);
    let p4 = n.add(
        "pool4",
        Layer::Pool3d { kind: PoolKind::Max, kernel: (2, 2, 2), stride: (2, 2, 2) },
        &[r4b],
    );

    let c5a = n.add("conv5a", conv3(512), &[p4]);
    let r5a = n.add("relu5a", Layer::Relu, &[c5a]);
    let c5b = n.add("conv5b", conv3(512), &[r5a]);
    let r5b = n.add("relu5b", Layer::Relu, &[c5b]);
    let p5 = n.add(
        "pool5",
        Layer::Pool3d { kind: PoolKind::Max, kernel: (2, 2, 2), stride: (2, 2, 2) },
        &[r5b],
    );

    let f6 = n.add("fc6", Layer::FullyConnected { out_features: 4096 }, &[p5]);
    let r6 = n.add("relu6", Layer::Relu, &[f6]);
    let d6 = n.add("drop6", Layer::Dropout, &[r6]);
    let f7 = n.add("fc7", Layer::FullyConnected { out_features: 4096 }, &[d6]);
    let r7 = n.add("relu7", Layer::Relu, &[f7]);
    let d7 = n.add("drop7", Layer::Dropout, &[r7]);
    let f8 = n.add("fc8", Layer::FullyConnected { out_features: 487 }, &[d7]);
    n.add("prob", Layer::Softmax, &[f8]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn temporal_downsampling() {
        let net = c3d(8);
        let out = |name: &str| net.nodes().iter().find(|n| n.name == name).unwrap().output.clone();
        assert_eq!(out("pool1").extent(Dim::T), 16); // (1,2,2) keeps T
        assert_eq!(out("pool2").extent(Dim::T), 8);
        assert_eq!(out("pool5").extent(Dim::T), 1);
        assert_eq!(out("pool5").extent(Dim::H), 4);
    }

    #[test]
    fn three_d_layers_are_nontraditional() {
        let net = c3d(8);
        for node in net.nodes() {
            if matches!(node.layer, Layer::Conv3d { .. } | Layer::Pool3d { .. }) {
                assert!(!node.layer.is_traditional());
            }
        }
    }
}
