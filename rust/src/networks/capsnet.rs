//! CapsNet (Sabour et al., 2017) — paper code **CapNN**.
//!
//! New layer types per Table 1(a): primary and digit capsules. MNIST
//! configuration: conv 9×9×256 → primary caps (32×8D, 9×9 s2) → digit
//! caps (10×16D, 3 routing iterations).

use crate::ir::{Layer, Network, Shape};

/// Build CapsNet for `batch` 1×28×28 images.
pub fn capsnet(batch: usize) -> Network {
    let mut n = Network::new("CapsNet");
    let data = n.add("data", Layer::Input { shape: Shape::bchw(batch, 1, 28, 28) }, &[]);
    let c1 = n.add(
        "conv1",
        Layer::Conv { out_channels: 256, kernel: (9, 9), stride: 1, pad: 0, groups: 1 },
        &[data],
    );
    let r1 = n.add("relu1", Layer::Relu, &[c1]);
    let prim = n.add(
        "primarycaps",
        Layer::PrimaryCaps { caps_channels: 32, vec: 8, kernel: 9, stride: 2 },
        &[r1],
    );
    n.add("digitcaps", Layer::DigitCaps { out_caps: 10, out_vec: 16, routing: 3 }, &[prim]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn capsule_shapes_match_paper() {
        let net = capsnet(16);
        let out = |name: &str| net.nodes().iter().find(|n| n.name == name).unwrap().output.clone();
        // Primary caps: 32 channels of 6x6 8-D capsules.
        let p = out("primarycaps");
        assert_eq!(p.extent(Dim::C), 32);
        assert_eq!(p.extent(Dim::H), 6);
        assert_eq!(p.extent(Dim::V), 8);
        // Digit caps: 10 16-D capsules.
        let d = out("digitcaps");
        assert_eq!(d.extent(Dim::C), 10);
        assert_eq!(d.extent(Dim::V), 16);
    }

    #[test]
    fn digitcaps_transform_dominates_params() {
        // 1152 x 8 x 10 x 16 ≈ 1.47M transform parameters.
        let net = capsnet(16);
        let dc = net.nodes().iter().find(|n| n.name == "digitcaps").unwrap();
        let params = dc.layer.param_count(&net.input_shapes(dc.id));
        assert_eq!(params, 1152 * 8 * 10 * 16);
    }
}
