//! GoogLeNet / Inception v1 (Szegedy et al., 2015) — paper code **GLN**.
//!
//! New layer types per Table 1(a): average pooling and concat. Auxiliary
//! classifier heads are omitted (they are disabled at inference and the
//! paper's training evaluation keeps the main path).

use crate::ir::{Layer, Network, NodeId, PoolKind, Shape};

/// Inception module: four parallel branches concatenated over channels.
#[allow(clippy::too_many_arguments)]
fn inception(
    n: &mut Network,
    name: &str,
    input: NodeId,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    cp: usize,
) -> NodeId {
    // 1x1 branch.
    let b1 = n.add(
        &format!("{name}/1x1"),
        Layer::Conv { out_channels: c1, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[input],
    );
    let b1 = n.add(&format!("{name}/relu_1x1"), Layer::Relu, &[b1]);
    // 3x3 branch.
    let b3r = n.add(
        &format!("{name}/3x3_reduce"),
        Layer::Conv { out_channels: c3r, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[input],
    );
    let b3r = n.add(&format!("{name}/relu_3x3_reduce"), Layer::Relu, &[b3r]);
    let b3 = n.add(
        &format!("{name}/3x3"),
        Layer::Conv { out_channels: c3, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[b3r],
    );
    let b3 = n.add(&format!("{name}/relu_3x3"), Layer::Relu, &[b3]);
    // 5x5 branch.
    let b5r = n.add(
        &format!("{name}/5x5_reduce"),
        Layer::Conv { out_channels: c5r, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[input],
    );
    let b5r = n.add(&format!("{name}/relu_5x5_reduce"), Layer::Relu, &[b5r]);
    let b5 = n.add(
        &format!("{name}/5x5"),
        Layer::Conv { out_channels: c5, kernel: (5, 5), stride: 1, pad: 2, groups: 1 },
        &[b5r],
    );
    let b5 = n.add(&format!("{name}/relu_5x5"), Layer::Relu, &[b5]);
    // Pool branch.
    let bp = n.add(
        &format!("{name}/pool"),
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 1, pad: 1 },
        &[input],
    );
    let bpp = n.add(
        &format!("{name}/pool_proj"),
        Layer::Conv { out_channels: cp, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[bp],
    );
    let bpp = n.add(&format!("{name}/relu_pool_proj"), Layer::Relu, &[bpp]);
    n.add(&format!("{name}/output"), Layer::Concat, &[b1, b3, b5, bpp])
}

/// Build GoogLeNet for `batch` 3×224×224 images.
pub fn googlenet(batch: usize) -> Network {
    let mut n = Network::new("GoogLeNet");
    let data = n.add("data", Layer::Input { shape: Shape::bchw(batch, 3, 224, 224) }, &[]);
    let c1 = n.add(
        "conv1/7x7_s2",
        Layer::Conv { out_channels: 64, kernel: (7, 7), stride: 2, pad: 3, groups: 1 },
        &[data],
    );
    let r1 = n.add("conv1/relu", Layer::Relu, &[c1]);
    let p1 = n.add(
        "pool1/3x3_s2",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[r1],
    );
    let l1 = n.add("pool1/norm1", Layer::Lrn { local_size: 5 }, &[p1]);
    let c2r = n.add(
        "conv2/3x3_reduce",
        Layer::Conv { out_channels: 64, kernel: (1, 1), stride: 1, pad: 0, groups: 1 },
        &[l1],
    );
    let c2r = n.add("conv2/relu_reduce", Layer::Relu, &[c2r]);
    let c2 = n.add(
        "conv2/3x3",
        Layer::Conv { out_channels: 192, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
        &[c2r],
    );
    let c2 = n.add("conv2/relu", Layer::Relu, &[c2]);
    let l2 = n.add("conv2/norm2", Layer::Lrn { local_size: 5 }, &[c2]);
    let p2 = n.add(
        "pool2/3x3_s2",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[l2],
    );

    let i3a = inception(&mut n, "inception_3a", p2, 64, 96, 128, 16, 32, 32);
    let i3b = inception(&mut n, "inception_3b", i3a, 128, 128, 192, 32, 96, 64);
    let p3 = n.add(
        "pool3/3x3_s2",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[i3b],
    );
    let i4a = inception(&mut n, "inception_4a", p3, 192, 96, 208, 16, 48, 64);
    let i4b = inception(&mut n, "inception_4b", i4a, 160, 112, 224, 24, 64, 64);
    let i4c = inception(&mut n, "inception_4c", i4b, 128, 128, 256, 24, 64, 64);
    let i4d = inception(&mut n, "inception_4d", i4c, 112, 144, 288, 32, 64, 64);
    let i4e = inception(&mut n, "inception_4e", i4d, 256, 160, 320, 32, 128, 128);
    let p4 = n.add(
        "pool4/3x3_s2",
        Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 },
        &[i4e],
    );
    let i5a = inception(&mut n, "inception_5a", p4, 256, 160, 320, 32, 128, 128);
    let i5b = inception(&mut n, "inception_5b", i5a, 384, 192, 384, 48, 128, 128);

    let gap = n.add("pool5/avg", Layer::GlobalAvgPool, &[i5b]);
    let drop = n.add("pool5/drop", Layer::Dropout, &[gap]);
    let fc = n.add("loss3/classifier", Layer::FullyConnected { out_features: 1000 }, &[drop]);
    n.add("prob", Layer::Softmax, &[fc]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Dim;

    #[test]
    fn inception_output_channels() {
        let net = googlenet(32);
        let out = |name: &str| net.nodes().iter().find(|n| n.name == name).unwrap().output.clone();
        assert_eq!(out("inception_3a/output").extent(Dim::C), 256);
        assert_eq!(out("inception_4a/output").extent(Dim::C), 512);
        assert_eq!(out("inception_5b/output").extent(Dim::C), 1024);
        assert_eq!(out("inception_5b/output").extent(Dim::H), 7);
    }

    #[test]
    fn has_avg_pool_and_concat() {
        let net = googlenet(32);
        assert!(net.nodes().iter().any(|n| matches!(n.layer, Layer::GlobalAvgPool)));
        assert!(net.nodes().iter().any(|n| matches!(n.layer, Layer::Concat)));
    }
}
