//! Tiny CLI flag helpers shared by the `gconv-chain` binary, the
//! examples and the benches (space-separated `--flag value` style; no
//! external argument-parsing crates are available offline).

/// Remove `flag` from `args`, returning whether it was present.
pub fn take_flag(args: &mut Vec<String>, flag: &str) -> bool {
    if let Some(i) = args.iter().position(|a| a == flag) {
        args.remove(i);
        return true;
    }
    false
}

/// Remove `flag N` from `args`, returning N (0 when the flag is absent
/// or its value is missing/malformed).
pub fn take_usize(args: &mut Vec<String>, flag: &str) -> usize {
    match take_string(args, flag) {
        Some(v) => v.parse().unwrap_or(0),
        None => 0,
    }
}

/// [`take_string`], but a present flag with a missing value is an
/// error instead of a silent `None` (for flags like `--model PATH`
/// where falling back to a default would mislead).
pub fn take_required_string(
    args: &mut Vec<String>,
    flag: &str,
) -> Result<Option<String>, String> {
    let had_flag = args.iter().any(|a| a == flag);
    match take_string(args, flag) {
        Some(v) => Ok(Some(v)),
        None if had_flag => Err(format!("{flag} needs a value")),
        None => Ok(None),
    }
}

/// Remove `flag VALUE` from `args`, returning VALUE if both were
/// present. A trailing flag with no value is removed and yields None;
/// a following token that is itself a flag (leading `--`) is *not*
/// consumed as the value.
pub fn take_string(args: &mut Vec<String>, flag: &str) -> Option<String> {
    let i = args.iter().position(|a| a == flag)?;
    if i + 1 < args.len() && !args[i + 1].starts_with("--") {
        let v = args[i + 1].clone();
        args.drain(i..=i + 1);
        return Some(v);
    }
    args.remove(i);
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn take_flag_removes_only_the_flag() {
        let mut args = argv(&["a", "--fast", "b"]);
        assert!(take_flag(&mut args, "--fast"));
        assert_eq!(args, argv(&["a", "b"]));
        assert!(!take_flag(&mut args, "--fast"));
    }

    #[test]
    fn take_usize_removes_flag_and_value() {
        let mut args = argv(&["x", "--threads", "4", "y"]);
        assert_eq!(take_usize(&mut args, "--threads"), 4);
        assert_eq!(args, argv(&["x", "y"]));
        assert_eq!(take_usize(&mut args, "--threads"), 0);
    }

    #[test]
    fn malformed_or_missing_values_yield_zero() {
        let mut args = argv(&["--threads", "two"]);
        assert_eq!(take_usize(&mut args, "--threads"), 0);
        assert!(args.is_empty());
        let mut tail = argv(&["--threads"]);
        assert_eq!(take_usize(&mut tail, "--threads"), 0);
        assert!(tail.is_empty());
    }

    #[test]
    fn flag_like_values_are_not_consumed() {
        let mut args = argv(&["--threads", "--bench-json"]);
        assert_eq!(take_usize(&mut args, "--threads"), 0);
        assert_eq!(args, argv(&["--bench-json"]));
    }

    #[test]
    fn take_required_string_errors_on_missing_values() {
        let mut args = argv(&["--model", "a.json"]);
        assert_eq!(take_required_string(&mut args, "--model"), Ok(Some("a.json".into())));
        assert_eq!(take_required_string(&mut args, "--model"), Ok(None));
        let mut args = argv(&["--model"]);
        assert!(take_required_string(&mut args, "--model").is_err());
        let mut args = argv(&["--model", "--fuse"]);
        assert!(take_required_string(&mut args, "--model").is_err());
        assert_eq!(args, argv(&["--fuse"]));
    }

    #[test]
    fn take_string_returns_the_value() {
        let mut args = argv(&["--json", "out.json", "MN"]);
        assert_eq!(take_string(&mut args, "--json"), Some("out.json".into()));
        assert_eq!(args, argv(&["MN"]));
        assert_eq!(take_string(&mut args, "--json"), None);
    }
}
