//! The analytical performance model of paper §4.2: computation cycles
//! (Eq. 6) and per-level data movement (Table 3, Eq. 7–10).

pub mod cycles;
pub mod movement;

pub use cycles::{compute_cycles, gconv_cycles, CycleBreakdown};
pub use movement::{gconv_movement, Movement};
