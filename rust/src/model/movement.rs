//! Data-movement model (paper Table 3 + Eq. (7)–(10)).
//!
//! For each data type X ∈ {input, kernel, output} the traffic between the
//! global buffer and the PE array is
//!
//! ```text
//! movement_X = #M_X × SP_X × TP_X            (Eq. 10)
//! #M_X  = Π loops outside the X pointer       (Eq. 8)
//! SP_X  = spatial tile per cycle               (Eq. 9 / Table 3)
//! TP_X  = temporal tile inside the X pointer   (Eq. 7 / Table 3)
//! ```
//!
//! Table 3 encodes the parallel-reuses: inputs are independent of `Nop`,
//! kernels of `Nopc`, outputs of `Nks`; the input expression
//! `Pg·(Pks + Ps·(Popc−1))` additionally discounts overlap-reuse.

use super::super::mapping::unroll::{Mapping, UnrollEntry};
use crate::accel::structure::AccelStructure;
use crate::gconv::op::{GconvOp, Param};
use crate::ir::Dim;
use std::collections::BTreeMap;

/// Traffic (in words) of one mapped GCONV.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Movement {
    /// Global-buffer → array input words.
    pub input: f64,
    /// Global-buffer → array kernel-parameter words.
    pub kernel: f64,
    /// Array → global-buffer output words (plus partial-sum spills).
    pub output: f64,
    /// Local-scratchpad accesses (reads at the PEs), all types.
    pub ls_accesses: f64,
}

impl Movement {
    /// Total GB↔array words.
    pub fn gb_total(&self) -> f64 {
        self.input + self.kernel + self.output
    }
}

/// Per-dimension factor of Table 3 for store `x` given unroll products.
fn tile_factor(x: char, g: usize, op: usize, opc: usize, ks: usize, s: usize) -> f64 {
    (match x {
        'i' => g * (ks + s * (opc - 1)),
        'k' => g * op * ks,
        'o' => g * op * opc,
        _ => unreachable!(),
    }) as f64
}

/// Accumulate per-(dim,param) products for a set of entries.
fn products(entries: &[&UnrollEntry]) -> BTreeMap<(Dim, Param), usize> {
    let mut m = BTreeMap::new();
    for e in entries {
        *m.entry((e.dim, e.param)).or_insert(1) *= e.factor;
    }
    m
}

/// Table-3 tile size over `dims` for store `x` from unroll products.
fn tile_size(
    x: char,
    dims: &[(Dim, usize)],
    prod: &BTreeMap<(Dim, Param), usize>,
) -> f64 {
    let mut total = 1.0;
    for &(d, s) in dims {
        let g = prod.get(&(d, Param::G)).copied().unwrap_or(1);
        let op = prod.get(&(d, Param::Op)).copied().unwrap_or(1);
        let opc = prod.get(&(d, Param::Opc)).copied().unwrap_or(1);
        let ks = prod.get(&(d, Param::Ks)).copied().unwrap_or(1);
        total *= tile_factor(x, g, op, opc, ks, s);
    }
    total
}

/// Compute the GB↔array movement of a mapped GCONV (Eq. 7–10) and the
/// per-PE local-scratchpad access count.
pub fn gconv_movement(op: &GconvOp, accel: &AccelStructure, m: &Mapping) -> Movement {
    let dims: Vec<(Dim, usize)> = op.dims.iter().map(|&(d, p)| (d, p.s)).collect();

    // Spatial tiles (Eq. 9): product over every spatial axis entry.
    let spatial_entries: Vec<&UnrollEntry> = m.spatial.iter().flatten().collect();
    let sp = products(&spatial_entries);

    // Reuse pointers over the temporal list.
    let ptrs = crate::mapping::unroll::TileTracker::pointers(op, accel, &m.temporal);

    let mut out = Movement::default();
    for (slot, x) in ['i', 'o', 'k'].into_iter().enumerate() {
        let sp_tile = tile_size(x, &dims, &sp);
        // TP tile inside the pointer (Eq. 7).
        let inside: Vec<&UnrollEntry> = match ptrs[slot] {
            Some(p) => m.temporal.iter().take(p + 1).collect(),
            None => Vec::new(),
        };
        let tp_tile = tile_size(x, &dims, &products(&inside));
        // #M: iterations of every loop outside the pointer (Eq. 8).
        let outside_iters: f64 = match ptrs[slot] {
            Some(p) => m.temporal.iter().skip(p + 1).map(|e| e.factor as f64).product(),
            None => m.temporal.iter().map(|e| e.factor as f64).product(),
        };
        let traffic = outside_iters * sp_tile * tp_tile;
        match x {
            'i' => out.input = traffic,
            'o' => out.output = traffic,
            'k' => {
                out.kernel = if op.kernel.is_some() { traffic } else { 0.0 };
            }
            _ => unreachable!(),
        }
    }
    // Kernel-less reductions (pooling, BN statistics) still stream inputs
    // and outputs; the `kernel` lane is zeroed above.

    // Local-scratchpad accesses: each main op reads input + kernel from
    // LS and updates the output register — 3 accesses per MAC, the
    // canonical CIP energy model. TIP-style structures with 1-word LS
    // pay these at the array-bus level instead, which the GB numbers
    // above already capture; we still count the register reads.
    out.ls_accesses = 3.0 * op.work() as f64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::{eyeriss, tpu};
    use crate::gconv::op::{DataRef, DimParams};
    use crate::mapping::unroll::{map_gconv, MapMode};

    fn conv_op() -> GconvOp {
        GconvOp::conv(
            "conv",
            vec![
                (Dim::B, DimParams::opc(16)),
                (Dim::C, DimParams { nop: 32, nks: 16, ..Default::default() }),
                (Dim::H, DimParams::window(28, 3, 1, 1)),
                (Dim::W, DimParams::window(28, 3, 1, 1)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        )
    }

    #[test]
    fn movement_at_least_unique_data() {
        // GB traffic can never undercut the unique tensor sizes.
        let op = conv_op();
        let accel = eyeriss();
        let m = map_gconv(&op, &accel, MapMode::Gconv);
        let mv = gconv_movement(&op, &accel, &m);
        assert!(mv.input >= op.input_elements() as f64 * 0.99, "{} < {}", mv.input, op.input_elements());
        assert!(mv.kernel >= op.kernel_elements() as f64 * 0.99);
        assert!(mv.output >= op.output_elements() as f64 * 0.99);
    }

    #[test]
    fn movement_at_most_no_reuse_bound() {
        // With zero reuse every MAC would load input+kernel and store the
        // output: 3 × work words is a hard upper bound at the GB.
        let op = conv_op();
        for accel in [eyeriss(), tpu()] {
            let m = map_gconv(&op, &accel, MapMode::Gconv);
            let mv = gconv_movement(&op, &accel, &m);
            assert!(
                mv.gb_total() <= 3.0 * op.work() as f64,
                "{}: {} > {}",
                accel.name,
                mv.gb_total(),
                3.0 * op.work() as f64
            );
        }
    }

    #[test]
    fn eyeriss_moves_less_than_tpu_on_conv() {
        // The CIP exploits overlap + scratchpad reuse the systolic TIP
        // cannot (the core claim behind Fig. 18).
        let op = conv_op();
        let er = eyeriss();
        let tp = tpu();
        let m_er = gconv_movement(&op, &er, &map_gconv(&op, &er, MapMode::Gconv));
        let m_tp = gconv_movement(&op, &tp, &map_gconv(&op, &tp, MapMode::Gconv));
        assert!(
            m_er.gb_total() < m_tp.gb_total(),
            "ER {} should move less than TPU {}",
            m_er.gb_total(),
            m_tp.gb_total()
        );
    }

    #[test]
    fn kernel_less_op_has_zero_kernel_traffic() {
        let pool = GconvOp {
            name: "pool".into(),
            dims: vec![
                (Dim::B, DimParams::opc(16)),
                (Dim::C, DimParams::opc(32)),
                (Dim::H, DimParams::window(14, 2, 2, 0)),
                (Dim::W, DimParams::window(14, 2, 2, 0)),
            ],
            pre: crate::gconv::op::PreOp::None,
            main: crate::gconv::op::MainOp::Pass,
            reduce: crate::gconv::op::ReduceOp::Max,
            post: crate::gconv::op::PostOp::None,
            input: DataRef::External("x".into()),
            kernel: None,
        };
        let accel = eyeriss();
        let m = map_gconv(&pool, &accel, MapMode::Gconv);
        let mv = gconv_movement(&pool, &accel, &m);
        assert_eq!(mv.kernel, 0.0);
        assert!(mv.input > 0.0);
    }
}
