//! Computation-cycle model (paper Eq. (6)) plus the data-loading bound
//! that motivates consistent mapping and operation fusion (§4.3).

use super::movement::{gconv_movement, Movement};
use crate::accel::structure::AccelStructure;
use crate::gconv::op::{GconvOp, Param};
use crate::mapping::unroll::Mapping;

/// Cycle count of one mapped GCONV, split by bound.
#[derive(Clone, Copy, Debug, Default)]
pub struct CycleBreakdown {
    /// Eq. (6) computation cycles.
    pub compute: f64,
    /// Input-loading cycles at the GB bus (after loading parallelism).
    pub load_input: f64,
    /// Kernel-loading cycles.
    pub load_kernel: f64,
    /// Output write-back cycles.
    pub store_output: f64,
    /// The governing total (compute and transfers are double-buffered;
    /// the slowest lane wins).
    pub total: f64,
}

/// Eq. (6): `Cyc = Π_d Π_p ceil(Np_d / SP_Pp_d)`.
pub fn compute_cycles(op: &GconvOp, m: &Mapping) -> f64 {
    let mut cyc = 1.0;
    for &(d, dp) in &op.dims {
        for p in Param::ALL {
            let n = dp.get(p);
            let sp = m.spatial_factor(d, p);
            cyc *= (n as f64 / sp as f64).ceil();
        }
    }
    cyc
}

/// Full cycle model for one mapped GCONV.
///
/// `load_parallelism` is the number of input words the consumer can pull
/// per bus cycle given the producer's storage format — `bw.i` when the
/// mapping is consistent (§4.3), degraded toward 1 when it is not.
pub fn gconv_cycles(
    op: &GconvOp,
    accel: &AccelStructure,
    m: &Mapping,
    load_parallelism: f64,
) -> (CycleBreakdown, Movement) {
    let mv = gconv_movement(op, accel, m);
    let compute = compute_cycles(op, m);
    let load_input = mv.input / (accel.bw.i as f64).min(load_parallelism).max(1.0);
    let load_kernel = mv.kernel / accel.bw.k as f64;
    let store_output = mv.output / accel.bw.o as f64;
    let total = compute.max(load_input).max(load_kernel).max(store_output);
    (CycleBreakdown { compute, load_input, load_kernel, store_output, total }, mv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::{all_accelerators, eyeriss};
    use crate::gconv::op::{DataRef, DimParams};
    use crate::ir::Dim;
    use crate::mapping::unroll::{map_gconv, MapMode};

    fn conv_op() -> GconvOp {
        GconvOp::conv(
            "conv",
            vec![
                (Dim::B, DimParams::opc(16)),
                (Dim::C, DimParams { nop: 32, nks: 16, ..Default::default() }),
                (Dim::H, DimParams::window(28, 3, 1, 1)),
                (Dim::W, DimParams::window(28, 3, 1, 1)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        )
    }

    #[test]
    fn cycles_bounded_by_work_over_pes() {
        // Perfect utilization would finish in work/PEs cycles; Eq. (6)
        // can only be ≥ that (ceil losses), and ≤ the full loop count.
        let op = conv_op();
        for accel in all_accelerators() {
            let m = map_gconv(&op, &accel, MapMode::Gconv);
            let c = compute_cycles(&op, &m);
            let lower = op.work() as f64 / accel.pes() as f64;
            assert!(c >= lower * 0.99, "{}: {c} < {lower}", accel.name);
            assert!(c <= op.work() as f64, "{}: {c} > work", accel.name);
        }
    }

    #[test]
    fn total_is_max_of_lanes() {
        let op = conv_op();
        let accel = eyeriss();
        let m = map_gconv(&op, &accel, MapMode::Gconv);
        let (cb, _) = gconv_cycles(&op, &accel, &m, accel.bw.i as f64);
        assert!(cb.total >= cb.compute && cb.total >= cb.load_input);
        assert_eq!(
            cb.total,
            cb.compute.max(cb.load_input).max(cb.load_kernel).max(cb.store_output)
        );
    }

    #[test]
    fn inconsistent_loading_slows_data_bound_ops() {
        // An element-wise op is load-bound: parallelism 1 vs full bus
        // width changes its total cycles.
        let ew = GconvOp {
            name: "relu".into(),
            dims: vec![(Dim::B, DimParams::opc(32)), (Dim::C, DimParams::opc(4096))],
            pre: crate::gconv::op::PreOp::None,
            main: crate::gconv::op::MainOp::Pass,
            reduce: crate::gconv::op::ReduceOp::None,
            post: crate::gconv::op::PostOp::Lut("relu"),
            input: DataRef::External("x".into()),
            kernel: None,
        };
        let accel = eyeriss();
        let m = map_gconv(&ew, &accel, MapMode::Gconv);
        let (fast, _) = gconv_cycles(&ew, &accel, &m, accel.bw.i as f64);
        let (slow, _) = gconv_cycles(&ew, &accel, &m, 1.0);
        assert!(slow.total > fast.total, "slow {} vs fast {}", slow.total, fast.total);
    }
}
