//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (which call the L1
//! Pallas kernels) to **HLO text** — the interchange format that
//! round-trips through the `xla` crate's text parser (serialized protos
//! from jax ≥ 0.5 carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects). This module compiles those artifacts once on the PJRT CPU
//! client and caches the executables; Python never runs at request time.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact cache on one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Directory artifacts are loaded from.
    pub artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU-backed runtime rooted at `artifact_dir`.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            exes: HashMap::new(),
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
        })
    }

    /// Backend platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) the artifact `name` — a `<name>.hlo.txt` file in
    /// the artifact directory.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// True if the artifact file exists on disk.
    pub fn available(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Execute artifact `name` with the given inputs; returns the output
    /// tuple elements (aot.py lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let exe = self.exes.get(name).expect("just loaded");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0].to_literal_sync().context("fetching result")?;
        Ok(lit.to_tuple().context("unpacking result tuple")?)
    }

    /// Number of cached executables.
    pub fn cached(&self) -> usize {
        self.exes.len()
    }
}

/// Build a rank-N f32 literal from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {:?} != len {}", dims, data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Flatten a literal back to f32s.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests exercise the PJRT client directly (no artifacts
    /// needed); the artifact round-trip is covered by the integration
    /// test `rust/tests/aot_roundtrip.rs` once `make artifacts` has run.
    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu("artifacts").expect("PJRT CPU client");
        assert!(["cpu", "host"].contains(&rt.platform().to_lowercase().as_str()));
        assert_eq!(rt.cached(), 0);
    }

    #[test]
    fn literal_round_trip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3, 3]).is_err());
    }

    #[test]
    fn missing_artifact_reported() {
        let mut rt = Runtime::cpu("artifacts").unwrap();
        assert!(!rt.available("no_such_artifact"));
        assert!(rt.load("no_such_artifact").is_err());
    }
}
