//! Owned dense `f32` tensors for the native GCONV interpreter.
//!
//! The interpreter works on flat row-major buffers whose extents follow
//! the per-dimension extents of the [`crate::gconv::op::GconvOp`] being
//! evaluated (input/kernel/output extents of Table 3), so the tensor type
//! stays deliberately small: a shape vector plus a `Vec<f32>`. Dimension
//! *names* live on the op, not on the tensor — the binding logic in
//! [`super::interp`] reconciles the two.

use std::fmt;

use anyhow::{ensure, Result};

use crate::prop::Rng;

/// A dense row-major `f32` tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build a tensor from extents and a flat row-major buffer.
    pub fn new(dims: &[usize], data: Vec<f32>) -> Result<Self> {
        ensure!(dims.iter().all(|&d| d > 0), "zero extent in shape {dims:?}");
        let n: usize = dims.iter().product();
        ensure!(
            n == data.len(),
            "shape {dims:?} holds {n} elements, buffer has {}",
            data.len()
        );
        Ok(Tensor {
            dims: dims.to_vec(),
            data,
        })
    }

    /// All-zero tensor.
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        Tensor {
            dims: dims.to_vec(),
            data: vec![0.0; n],
        }
    }

    /// Constant-filled tensor.
    pub fn filled(dims: &[usize], v: f32) -> Self {
        let n: usize = dims.iter().product();
        Tensor {
            dims: dims.to_vec(),
            data: vec![v; n],
        }
    }

    /// Tensor whose element at flat index `i` is `f(i)`.
    pub fn from_fn(dims: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n: usize = dims.iter().product();
        Tensor {
            dims: dims.to_vec(),
            data: (0..n).map(&mut f).collect(),
        }
    }

    /// Deterministic pseudo-random tensor, uniform in `[-scale, scale]`.
    /// Same `(dims, seed, scale)` always produces the same data (the
    /// generator is the in-repo splitmix64, [`crate::prop::Rng`]).
    pub fn rand(dims: &[usize], seed: u64, scale: f32) -> Self {
        let mut rng = Rng::new(seed);
        Tensor::from_fn(dims, |_| (rng.f64() as f32 * 2.0 - 1.0) * scale)
    }

    /// Extents, outermost first.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.data.len()
    }

    /// Flat row-major buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Row-major strides (in elements) matching [`Tensor::dims`].
    pub fn strides(&self) -> Vec<usize> {
        row_major_strides(&self.dims)
    }

    /// Element at a full multi-index (checked).
    pub fn at(&self, idx: &[usize]) -> f32 {
        assert_eq!(idx.len(), self.dims.len(), "index rank mismatch");
        let mut flat = 0;
        for ((&i, &d), s) in idx.iter().zip(&self.dims).zip(self.strides()) {
            assert!(i < d, "index {i} out of bounds for extent {d}");
            flat += i * s;
        }
        self.data[flat]
    }

    /// Same data under new extents (element count must match).
    pub fn reshape(self, dims: &[usize]) -> Result<Self> {
        Tensor::new(dims, self.data)
    }

    /// Extents with size-1 dimensions dropped.
    pub fn squeezed_dims(&self) -> Vec<usize> {
        self.dims.iter().copied().filter(|&d| d > 1).collect()
    }

    /// Exact bit-level equality: same extents and every element has the
    /// same `f32` bit pattern (`-0.0 != 0.0`, equal NaN payloads match).
    /// The differential tests use this to pin the fast execution paths
    /// to the naive oracle.
    pub fn bit_eq(&self, other: &Tensor) -> bool {
        if self.dims != other.dims {
            return false;
        }
        let mut same = true;
        for (a, b) in self.data.iter().zip(&other.data) {
            same &= a.to_bits() == b.to_bits();
        }
        same
    }

    /// Largest absolute element-wise difference against `other`
    /// (tensors must have equal element counts; shapes may differ).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.elements(), other.elements(), "element count mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Row-major strides for a list of extents.
pub fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut strides = vec![1; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * dims[i + 1];
    }
    strides
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?} ({} elements)", self.dims, self.elements())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::new(&[2, 0], vec![]).is_err());
    }

    #[test]
    fn strides_are_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(row_major_strides(&[]), Vec::<usize>::new());
        assert_eq!(row_major_strides(&[5]), vec![1]);
    }

    #[test]
    fn at_indexes_row_major() {
        let t = Tensor::from_fn(&[2, 3], |i| i as f32);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn rand_is_deterministic_and_bounded() {
        let a = Tensor::rand(&[4, 4], 7, 0.5);
        let b = Tensor::rand(&[4, 4], 7, 0.5);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|v| v.abs() <= 0.5));
        let c = Tensor::rand(&[4, 4], 8, 0.5);
        assert_ne!(a, c);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_fn(&[2, 3], |i| i as f32);
        let t = t.reshape(&[3, 2]).unwrap();
        assert_eq!(t.at(&[2, 1]), 5.0);
        assert!(Tensor::zeros(&[2, 3]).reshape(&[4]).is_err());
    }

    #[test]
    fn bit_eq_distinguishes_signed_zero_and_shape() {
        let a = Tensor::new(&[2], vec![0.0, 1.0]).unwrap();
        let b = Tensor::new(&[2], vec![-0.0, 1.0]).unwrap();
        assert!(a.bit_eq(&a.clone()));
        assert!(!a.bit_eq(&b), "-0.0 must not bit-match 0.0");
        let c = Tensor::new(&[1, 2], vec![0.0, 1.0]).unwrap();
        assert!(!a.bit_eq(&c), "shape participates in bit equality");
        let n = Tensor::filled(&[2], f32::NAN);
        assert!(n.bit_eq(&n.clone()), "equal NaN payloads match");
    }

    #[test]
    fn max_abs_diff_finds_worst_element() {
        let a = Tensor::new(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(&[3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
