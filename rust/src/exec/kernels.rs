//! Fast-path execution kernels for the native GCONV interpreter.
//!
//! `BoundPlan::bind` (in `super::interp`) validates shapes and resolves
//! the scalar operators once; this module decides *how* a bound plan is
//! evaluated. Three tiers implement the same loop nest:
//!
//! * [`KernelTier::Gemm`] — `Mul`+`Add` GCONVs with a non-trivial
//!   reduction (conv, FC, WG: the chain's FLOP-dominant ops) lower to an
//!   im2col-style packed panel and a cache-blocked dot microkernel over
//!   contiguous `&[f32]` slices. Per group `g`, the op is the GEMM
//!   `out[op][opc] = Σ_k wpack[g·op][k] · panel[k][opc]`: packing pays
//!   the per-element index arithmetic once per *column* and amortizes it
//!   over every kernel row, and the per-`k` row walk is stride-1 across
//!   columns so the autovectorizer can chew on it.
//! * [`KernelTier::Odometer`] — every other nest replaces the oracle's
//!   per-element div/mod coordinate decomposition and per-step stride
//!   recomputation with odometer-carry iteration over output
//!   coordinates plus a precomputed reduction-step table.
//! * [`KernelTier::Naive`] — the reference oracle (`Plan::eval_one`),
//!   kept for differential testing and degenerate 0-dimension plans.
//!
//! Every tier reproduces the oracle **bit-for-bit**: the same `f32`
//! operator applications, the same sequential `f64` accumulation, the
//! same reduction order. The property tests in
//! `rust/tests/native_exec.rs` pin this across randomized shapes.

use rayon::prelude::*;

use crate::gconv::op::ReduceOp;

use super::interp::{main_apply, BoundPlan, Plan, MAX_DIMS};

/// Reduction length below which GEMM panel packing cannot amortize its
/// per-column index arithmetic and the odometer path wins.
pub const GEMM_MIN_REDUCTION: usize = 8;

/// Output elements per parallel work item on the element-wise tiers.
const PAR_CHUNK: usize = 2048;

/// Columns per packed GEMM panel block. The panel is `red_total × NC`
/// `f32`s — small enough to stay cache-resident while every kernel row
/// streams over it; the `f64` accumulator tile is `NC` wide.
pub const NC: usize = 64;

/// How a bound plan is evaluated (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Packed-panel blocked dot/GEMM fast path.
    Gemm,
    /// Incremental odometer iteration (generic fast path).
    Odometer,
    /// Per-element reference oracle.
    Naive,
}

/// One step of the flattened reduction: per-dimension `ks` digits plus
/// the input/kernel offsets they contribute. `x_off` is relative to an
/// output element's window base (which may start in the padding, so the
/// base is signed; the sum is only used when all dims are in bounds).
struct RedStep {
    x_off: i64,
    w_off: usize,
    ks: [u32; MAX_DIMS],
}

/// The reduction-step table shared by both fast paths: one entry per
/// flattened `Nks` step, in the oracle's row-major reduction order.
fn red_steps(plan: &BoundPlan) -> Vec<RedStep> {
    let mut steps = Vec::with_capacity(plan.red_total);
    for r in 0..plan.red_total {
        let mut st = RedStep {
            x_off: 0,
            w_off: 0,
            ks: [0; MAX_DIMS],
        };
        for (i, d) in plan.dims.iter().enumerate() {
            let k = (r / d.red_stride) % d.nks;
            st.ks[i] = k as u32;
            st.x_off += (k * d.in_stride) as i64;
            st.w_off += k * d.ker_stride;
        }
        steps.push(st);
    }
    steps
}

/// True when no window position of the plan can fall outside the bound
/// input (no padding, input covers every window): the per-step bounds
/// check can be skipped entirely.
fn never_oob(plan: &BoundPlan) -> bool {
    for d in &plan.dims {
        if d.ps != 0 || (d.nopc - 1) * d.s + d.nks > d.in_actual {
            return false;
        }
    }
    true
}

/// Per-dimension output odometer: the decomposed `(g, op, opc)` output
/// coordinate plus the flattened window bases derived from it, advanced
/// one output element at a time with carry — no div/mod per element,
/// and the bases are maintained incrementally so an element-wise entry
/// costs O(1) index work instead of a per-dimension loop.
struct OutState {
    g: [usize; MAX_DIMS],
    kop: [usize; MAX_DIMS],
    opc: [usize; MAX_DIMS],
    in_base: [usize; MAX_DIMS],
    pos0: [i64; MAX_DIMS],
    ker_base: [usize; MAX_DIMS],
    /// `Σ_i (in_base[i] + pos0[i]) · in_stride[i]` — may be negative
    /// while the window starts in padding.
    x_base: i64,
    /// `Σ_i ker_base[i] · ker_stride[i]`.
    w_base: usize,
}

impl OutState {
    /// Decompose flat output index `o` — the oracle's div/mod split,
    /// done once per parallel chunk instead of once per element.
    fn seed(plan: &BoundPlan, o: usize) -> OutState {
        let mut st = OutState {
            g: [0; MAX_DIMS],
            kop: [0; MAX_DIMS],
            opc: [0; MAX_DIMS],
            in_base: [0; MAX_DIMS],
            pos0: [0; MAX_DIMS],
            ker_base: [0; MAX_DIMS],
            x_base: 0,
            w_base: 0,
        };
        for (i, d) in plan.dims.iter().enumerate() {
            let oc = (o / d.out_stride) % d.out_ext;
            let g = oc / d.npc;
            let r = oc % d.npc;
            let kop = r / d.nopc;
            let opc = r % d.nopc;
            st.g[i] = g;
            st.kop[i] = kop;
            st.opc[i] = opc;
            st.in_base[i] = g * d.in_actual;
            st.pos0[i] = (opc * d.s) as i64 - d.ps as i64;
            st.ker_base[i] = (g * d.nop + kop) * d.nks;
            st.x_base += (st.in_base[i] as i64 + st.pos0[i]) * d.in_stride as i64;
            st.w_base += st.ker_base[i] * d.ker_stride;
        }
        st
    }

    /// Advance to the next output element in row-major order, updating
    /// only the dimensions whose digits change (odometer carry) and
    /// adjusting the flattened bases by the matching deltas.
    fn advance(&mut self, plan: &BoundPlan) {
        let mut i = plan.dims.len();
        while i > 0 {
            i -= 1;
            let d = &plan.dims[i];
            self.opc[i] += 1;
            if self.opc[i] < d.nopc {
                self.pos0[i] += d.s as i64;
                self.x_base += (d.s * d.in_stride) as i64;
                return;
            }
            self.opc[i] = 0;
            self.pos0[i] = -(d.ps as i64);
            self.x_base -= ((d.nopc - 1) * d.s * d.in_stride) as i64;
            self.kop[i] += 1;
            if self.kop[i] < d.nop {
                self.ker_base[i] += d.nks;
                self.w_base += d.nks * d.ker_stride;
                return;
            }
            self.kop[i] = 0;
            self.g[i] += 1;
            if self.g[i] < d.ng {
                self.in_base[i] += d.in_actual;
                self.x_base += (d.in_actual * d.in_stride) as i64;
                // ker_base goes from (g·nop + nop−1)·nks to
                // (g+1)·nop·nks: the combined kop-reset + group-step
                // delta is exactly +nks.
                self.ker_base[i] = self.g[i] * d.nop * d.nks;
                self.w_base += d.nks * d.ker_stride;
                return;
            }
            self.g[i] = 0;
            self.x_base -= ((d.ng - 1) * d.in_actual * d.in_stride) as i64;
            self.in_base[i] = 0;
            // ker_base was (ng·nop − 1)·nks (last kernel of the last
            // group) and resets to 0.
            self.w_base -= (d.ng * d.nop - 1) * d.nks * d.ker_stride;
            self.ker_base[i] = 0;
            // carry into dimension i − 1
        }
    }

    /// Flattened window base offsets of the current output element.
    fn bases(&self) -> (i64, usize) {
        (self.x_base, self.w_base)
    }
}

/// Evaluate one output element from its odometer state: the oracle's
/// reduction loop with table-resolved offsets (bit-identical results,
/// no div/mod).
fn eval_steps(plan: &Plan, st: &OutState, steps: &[RedStep], safe: bool) -> f32 {
    let (x_base, w_base) = st.bases();
    let reduce = plan.bound.reduce;
    let main = plan.bound.main;
    let mut acc: f64 = match reduce {
        ReduceOp::Max => f64::NEG_INFINITY,
        _ => 0.0,
    };
    let mut any = false;
    for step in steps {
        let mut oob = false;
        if !safe {
            for (i, d) in plan.bound.dims.iter().enumerate() {
                let pos = st.pos0[i] + i64::from(step.ks[i]);
                if pos < 0 || pos >= d.in_actual as i64 {
                    oob = true;
                    break;
                }
            }
        }
        if oob && reduce == ReduceOp::Max {
            continue; // max pooling ignores padding
        }
        let mut x = 0.0;
        if !oob {
            x = plan.xs[(x_base + step.x_off) as usize];
        }
        let a = plan.bound.pre.apply(x);
        let m = match plan.ws {
            Some(ws) => main_apply(main, a, ws[w_base + step.w_off]),
            None => main_apply(main, a, 0.0),
        };
        match reduce {
            ReduceOp::Add => acc += f64::from(m),
            ReduceOp::Max => acc = acc.max(f64::from(m)),
            ReduceOp::None => acc = f64::from(m),
        }
        any = true;
    }
    if !any {
        acc = 0.0; // fully padded window (degenerate BP edge)
    }
    plan.bound.post.apply(acc as f32)
}

/// Generic fast path: odometer-carry iteration over output coordinates
/// plus the precomputed reduction-step table — no per-element div/mod,
/// no per-step stride recomputation, no string matching.
pub(super) fn eval_odometer(plan: &Plan, out: &mut [f32]) {
    let steps = red_steps(plan.bound);
    let safe = never_oob(plan.bound);
    let chunks = out.par_chunks_mut(PAR_CHUNK).enumerate();
    chunks.for_each(|(ci, chunk)| {
        let mut st = OutState::seed(plan.bound, ci * PAR_CHUNK);
        for slot in chunk.iter_mut() {
            *slot = eval_steps(plan, &st, &steps, safe);
            st.advance(plan.bound);
        }
    });
}

/// Reference oracle tier: per-element `Plan::eval_one` (div/mod
/// coordinate decomposition per output, per-step stride recomputation).
pub(super) fn eval_naive(plan: &Plan, out: &mut [f32]) {
    let chunks = out.par_chunks_mut(PAR_CHUNK).enumerate();
    chunks.for_each(|(ci, chunk)| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = plan.eval_one(ci * PAR_CHUNK + j);
        }
    });
}

/// Raw output pointer shared across GEMM jobs. Each job writes a
/// disjoint set of output indices (see the SAFETY note at the write
/// site), so unsynchronized parallel writes are sound.
struct OutPtr(*mut f32);

unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Dense dot/GEMM fast path for `Mul`+`Add` plans with a kernel operand.
///
/// Kernel rows are packed once into contiguous length-`K` slices
/// (`K = red_total`). Column blocks of at most [`NC`] outputs pack their
/// input windows — `pre` applied, padding resolved to `pre(0)` exactly
/// as the oracle does — into a `K × nc` panel stored `k`-major, so the
/// inner loop `acc[c] += panel[k][c] · w[k]` is a stride-1 rank-1 update
/// the autovectorizer handles well. Accumulation stays sequential `f64`
/// in reduction order: results are bit-identical to the oracle while
/// per-element index arithmetic is amortized over all kernel rows.
pub(super) fn eval_gemm(plan: &Plan, out: &mut [f32]) {
    let steps = red_steps(plan.bound);
    let safe = never_oob(plan.bound);
    let k_total = plan.bound.red_total;

    // Flattened group / kernel-row / column spaces and their strides.
    let dims = &plan.bound.dims;
    let ngs: Vec<usize> = dims.iter().map(|d| d.ng).collect();
    let nops: Vec<usize> = dims.iter().map(|d| d.nop).collect();
    let nopcs: Vec<usize> = dims.iter().map(|d| d.nopc).collect();
    let g_stride = super::tensor::row_major_strides(&ngs);
    let r_stride = super::tensor::row_major_strides(&nops);
    let c_stride = super::tensor::row_major_strides(&nopcs);
    let n_groups: usize = ngs.iter().product();
    let n_rows: usize = nops.iter().product();
    let n_cols: usize = nopcs.iter().product();

    // Pack every kernel row once: wpack[(g·n_rows + op)·K + k]. Row
    // packing is cheap next to the GEMM itself and makes each row a
    // contiguous slice regardless of the op's kernel layout.
    let ws = plan.ws.expect("gemm tier requires a kernel operand");
    let mut wpack = vec![0.0f32; n_groups * n_rows * k_total];
    for g in 0..n_groups {
        for op in 0..n_rows {
            let mut w_base = 0usize;
            for (i, d) in dims.iter().enumerate() {
                let gi = (g / g_stride[i]) % d.ng;
                let oi = (op / r_stride[i]) % d.nop;
                w_base += (gi * d.nop + oi) * d.nks * d.ker_stride;
            }
            let row = &mut wpack[(g * n_rows + op) * k_total..][..k_total];
            for (k, step) in steps.iter().enumerate() {
                row[k] = ws[w_base + step.w_off];
            }
        }
    }

    // One job per (group, column block); jobs write disjoint outputs.
    let mut jobs = Vec::new();
    for g in 0..n_groups {
        let mut c0 = 0;
        while c0 < n_cols {
            jobs.push((g, c0));
            c0 += NC;
        }
    }

    let out_ptr = OutPtr(out.as_mut_ptr());
    let par_jobs = jobs.par_iter();
    par_jobs.for_each(|&(g, c0)| {
        let nc = NC.min(n_cols - c0);

        // Output offsets, window bases and per-dim window starts of the
        // block's columns (the per-column index arithmetic paid once and
        // amortized over every kernel row below).
        let mut col_off = [0usize; NC];
        let mut x_bases = [0i64; NC];
        let mut pos0 = [[0i64; MAX_DIMS]; NC];
        for c in 0..nc {
            let col = c0 + c;
            let mut off = 0usize;
            let mut xb = 0i64;
            for (i, d) in dims.iter().enumerate() {
                let gi = (g / g_stride[i]) % d.ng;
                let oi = (col / c_stride[i]) % d.nopc;
                let p0 = (oi * d.s) as i64 - d.ps as i64;
                off += oi * d.out_stride;
                xb += ((gi * d.in_actual) as i64 + p0) * d.in_stride as i64;
                pos0[c][i] = p0;
            }
            col_off[c] = off;
            x_bases[c] = xb;
        }

        // Pack the panel k-major: panel[k·nc + c] = pre(x or 0).
        let mut panel = vec![0.0f32; k_total * nc];
        for c in 0..nc {
            for (k, step) in steps.iter().enumerate() {
                let mut oob = false;
                if !safe {
                    for (i, d) in dims.iter().enumerate() {
                        let pos = pos0[c][i] + i64::from(step.ks[i]);
                        if pos < 0 || pos >= d.in_actual as i64 {
                            oob = true;
                            break;
                        }
                    }
                }
                let mut x = 0.0;
                if !oob {
                    x = plan.xs[(x_bases[c] + step.x_off) as usize];
                }
                panel[k * nc + c] = plan.bound.pre.apply(x);
            }
        }

        // Every kernel row of this group streams over the panel. The
        // row loop is itself parallel so few-column plans (FC at small
        // batch: one group, one column) still use every core; rayon's
        // work stealing only splits when outer jobs leave cores idle.
        let rows = (0..n_rows).into_par_iter().with_min_len(8);
        rows.for_each(|op| {
            let mut row_base = 0usize;
            for (i, d) in dims.iter().enumerate() {
                let gi = (g / g_stride[i]) % d.ng;
                let oi = (op / r_stride[i]) % d.nop;
                row_base += (gi * d.nop + oi) * d.nopc * d.out_stride;
            }
            let wrow = &wpack[(g * n_rows + op) * k_total..][..k_total];
            let mut acc = [0.0f64; NC];
            for (k, &w) in wrow.iter().enumerate() {
                let prow = &panel[k * nc..k * nc + nc];
                for (a, &p) in acc[..nc].iter_mut().zip(prow) {
                    *a += f64::from(p * w);
                }
            }
            for c in 0..nc {
                let v = plan.bound.post.apply(acc[c] as f32);
                // SAFETY: output index = Σ_i ((g_i·nop_i + op_i)·nopc_i
                // + opc_i)·out_stride_i is the row-major mixed-radix
                // flattening of (g, op, opc) — a bijection onto
                // 0..out_total; jobs partition the (group, column)
                // space disjointly and row tasks within a job partition
                // the row space, so every output index is written by
                // exactly one task exactly once, within bounds.
                unsafe {
                    *out_ptr.0.add(row_base + col_off[c]) = v;
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::exec::interp::{eval_gconv, eval_gconv_naive, plan_tier};
    use crate::exec::tensor::Tensor;
    use crate::gconv::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp};
    use crate::ir::Dim;

    /// A conv-shaped op whose reduction (3×3 = 9 steps) takes the GEMM
    /// tier: C[Nop:2, Nks:3] × W[window 4, ks 3, s 1, ps 1].
    fn conv_case() -> (GconvOp, Tensor, Tensor) {
        let dims = vec![
            (Dim::C, DimParams::op_ks(2, 3)),
            (Dim::W, DimParams::window(4, 3, 1, 1)),
        ];
        let x = DataRef::External("x".into());
        let w = DataRef::Weights("w".into());
        let op = GconvOp::conv("k", dims, x, w);
        let xs = Tensor::rand(&[3, 4], 7, 1.0);
        let ws = Tensor::rand(&[6, 3], 8, 1.0);
        (op, xs, ws)
    }

    #[test]
    fn conv_plan_takes_the_gemm_tier() {
        let (op, xs, ws) = conv_case();
        let tier = plan_tier(&op, &xs, Some(&ws)).unwrap();
        assert_eq!(tier, KernelTier::Gemm);
    }

    #[test]
    fn short_reductions_take_the_odometer_tier() {
        let (mut op, xs, _ws) = conv_case();
        op.dims[0].1 = DimParams::op_ks(2, 1); // 1×3 = 3 steps < minimum
        let xs2 = Tensor::rand(&[1, xs.dims()[1]], 9, 1.0);
        let ws2 = Tensor::rand(&[2, 3], 10, 1.0);
        let tier = plan_tier(&op, &xs2, Some(&ws2)).unwrap();
        assert_eq!(tier, KernelTier::Odometer);
    }

    #[test]
    fn kernel_less_ops_take_the_odometer_tier() {
        let op = GconvOp {
            name: "pool".into(),
            dims: vec![(Dim::W, DimParams::window(2, 2, 2, 0))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::Max,
            post: PostOp::None,
            input: DataRef::External("x".into()),
            kernel: None,
        };
        let xs = Tensor::rand(&[4], 11, 1.0);
        let tier = plan_tier(&op, &xs, None).unwrap();
        assert_eq!(tier, KernelTier::Odometer);
    }

    #[test]
    fn gemm_path_matches_oracle_bitwise() {
        let (op, xs, ws) = conv_case();
        let fast = eval_gconv(&op, &xs, Some(&ws)).unwrap();
        let naive = eval_gconv_naive(&op, &xs, Some(&ws)).unwrap();
        assert!(fast.bit_eq(&naive));
    }

    /// Bind a plan to the input's layout (the tests never need data to
    /// inspect the bound geometry).
    fn bind(op: &GconvOp, xs: &Tensor) -> BoundPlan {
        BoundPlan::bind(op, xs.dims(), xs.elements(), None).unwrap()
    }

    #[test]
    fn red_steps_follow_the_oracle_order() {
        let (op, xs, _ws) = conv_case();
        let plan = bind(&op, &xs);
        let steps = red_steps(&plan);
        assert_eq!(steps.len(), 9);
        assert_eq!(steps[0].ks[..2], [0, 0]);
        assert_eq!(steps[1].ks[..2], [0, 1]);
        assert_eq!(steps[3].ks[..2], [1, 0]);
        assert_eq!(steps[8].ks[..2], [2, 2]);
    }

    fn assert_advance_matches_reseeding(plan: &BoundPlan) {
        let mut st = OutState::seed(plan, 0);
        for o in 0..plan.out_total {
            // `fresh` recomputes digits and bases from scratch; `st`
            // reached the same element by incremental carries.
            let fresh = OutState::seed(plan, o);
            assert_eq!(st.pos0, fresh.pos0, "pos0 at output {o}");
            assert_eq!(st.in_base, fresh.in_base, "in_base at output {o}");
            assert_eq!(st.ker_base, fresh.ker_base, "ker_base at output {o}");
            assert_eq!(st.bases(), fresh.bases(), "bases at output {o}");
            st.advance(plan);
        }
    }

    #[test]
    fn odometer_advance_matches_reseeding() {
        let (op, xs, _ws) = conv_case();
        assert_advance_matches_reseeding(&bind(&op, &xs));
    }

    #[test]
    fn odometer_advance_carries_through_groups() {
        // Ng > 1 on both dims exercises the group-carry branch.
        let cdim = DimParams {
            ng: 2,
            nop: 2,
            nopc: 1,
            nks: 2,
            s: 1,
            ps: 0,
            pe: 0,
        };
        let wdim = DimParams {
            ng: 3,
            nop: 1,
            nopc: 2,
            nks: 2,
            s: 2,
            ps: 1,
            pe: 0,
        };
        let dims = vec![(Dim::C, cdim), (Dim::W, wdim)];
        let x = DataRef::External("x".into());
        let w = DataRef::Weights("w".into());
        let op = GconvOp::conv("grp", dims, x, w);
        let xs = Tensor::rand(&op.input_extents(), 21, 1.0);
        let ws = Tensor::rand(&op.kernel_extents(), 22, 1.0);
        assert_advance_matches_reseeding(&bind(&op, &xs));
        let fast = eval_gconv(&op, &xs, Some(&ws)).unwrap();
        let naive = eval_gconv_naive(&op, &xs, Some(&ws)).unwrap();
        assert!(fast.bit_eq(&naive));
    }

    #[test]
    fn never_oob_detects_padding() {
        let (op, xs, _ws) = conv_case();
        assert!(!never_oob(&bind(&op, &xs)), "ps=1 window can leave the input");
        let dims = vec![(Dim::W, DimParams::window(3, 2, 1, 0))];
        let x = DataRef::External("x".into());
        let w = DataRef::Weights("w".into());
        let op2 = GconvOp::conv("nopad", dims, x, w);
        let xs2 = Tensor::rand(&[4], 12, 1.0);
        assert!(never_oob(&bind(&op2, &xs2)));
    }
}
