//! Fast-path execution kernels for the native GCONV interpreter.
//!
//! `BoundPlan::bind` (in `super::interp`) validates shapes and resolves
//! the scalar operators once; this module decides *how* a bound plan is
//! evaluated. Three tiers implement the same loop nest:
//!
//! * [`KernelTier::Gemm`] — `Mul`+`Add` GCONVs with a non-trivial
//!   reduction (conv, FC, WG: the chain's FLOP-dominant ops) lower to an
//!   im2col-style packed panel and a cache-blocked dot microkernel over
//!   contiguous `&[f32]` slices. Per group `g`, the op is the GEMM
//!   `out[op][opc] = Σ_k wpack[g·op][k] · panel[k][opc]`: packing pays
//!   the per-element index arithmetic once per *column* and amortizes it
//!   over every kernel row, and the per-`k` row walk is stride-1 across
//!   columns so the autovectorizer can chew on it.
//! * [`KernelTier::Odometer`] — every other nest replaces the oracle's
//!   per-element div/mod coordinate decomposition and per-step stride
//!   recomputation with odometer-carry iteration over output
//!   coordinates plus a precomputed reduction-step table.
//! * [`KernelTier::Naive`] — the reference oracle (`Plan::eval_one`),
//!   kept for differential testing and degenerate 0-dimension plans.
//!
//! Under the default [`Precision::BitExact`] every tier reproduces the
//! oracle **bit-for-bit**: the same `f32` operator applications, the
//! same sequential `f64` accumulation, the same reduction order. The
//! property tests in `rust/tests/native_exec.rs` pin this across
//! randomized shapes. [`Precision::Fast`] swaps the GEMM tier's
//! accumulator for hand-unrolled per-lane `f32` accumulation — a
//! different summation order, gated by a tolerance differential
//! ([`FAST_REL_TOL`]) instead of bit equality.
//!
//! Kernel-row packing is hoisted out of the eval path: a
//! [`PrepackedWeights`] slab built once per bind (`BoundPlan::prepack`)
//! is reused by every subsequent eval, so a steady-state
//! `Session::run` touches only the input panel. Plans without a slab
//! (the one-shot `ChainExec` path, chain-produced kernels) pack on the
//! fly through the buffer pool.

use rayon::prelude::*;

use crate::gconv::op::ReduceOp;

use super::interp::{main_apply, BoundPlan, Plan, MAX_DIMS};
use super::pool::BufferPool;

/// Reduction length below which GEMM panel packing cannot amortize its
/// per-column index arithmetic and the odometer path wins.
pub const GEMM_MIN_REDUCTION: usize = 8;

/// Output elements per parallel work item on the element-wise tiers.
const PAR_CHUNK: usize = 2048;

/// Columns per packed GEMM panel block. The panel is `red_total × NC`
/// `f32`s — small enough to stay cache-resident while every kernel row
/// streams over it; the `f64` accumulator tile is `NC` wide.
pub const NC: usize = 64;

/// How a bound plan is evaluated (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Packed-panel blocked dot/GEMM fast path.
    Gemm,
    /// Incremental odometer iteration (generic fast path).
    Odometer,
    /// Per-element reference oracle.
    Naive,
}

/// Numeric contract of the GEMM microkernel. Only the GEMM tier is
/// affected: the odometer and naive tiers are always bit-exact.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Sequential `f64` accumulation in the oracle's reduction order —
    /// bit-identical to the naive reference. The default, and the only
    /// mode the conformance matrix and golden digests accept.
    #[default]
    BitExact,
    /// Hand-unrolled `f32` lanes with four independent accumulators per
    /// column, combined pairwise at the end. Changes summation order,
    /// so results may differ from the oracle in the low mantissa bits;
    /// the differential gates bound the drift by [`FAST_REL_TOL`].
    Fast,
}

/// Relative-error bound the `Precision::Fast` differential gates
/// enforce against the bit-exact oracle: `|fast − exact| /
/// max(|exact|, 1)` per element. Conservative for the reduction
/// lengths the chains reach (f32 accumulation error grows ~`√K·ε`).
pub const FAST_REL_TOL: f32 = 1e-3;

/// Kernel rows packed once at bind time into the GEMM layout
/// `data[(g·n_rows + op)·k_total + k]` — identical to the slab
/// `eval_gemm` would otherwise rebuild per eval. Owned by the
/// `BoundPlan`, so the weights are frozen into the plan and the eval
/// path never touches the raw kernel tensor again.
pub(super) struct PrepackedWeights {
    data: Vec<f32>,
}

/// One step of the flattened reduction: per-dimension `ks` digits plus
/// the input/kernel offsets they contribute. `x_off` is relative to an
/// output element's window base (which may start in the padding, so the
/// base is signed; the sum is only used when all dims are in bounds).
struct RedStep {
    x_off: i64,
    w_off: usize,
    ks: [u32; MAX_DIMS],
}

/// The reduction-step table shared by both fast paths: one entry per
/// flattened `Nks` step, in the oracle's row-major reduction order.
fn red_steps(plan: &BoundPlan) -> Vec<RedStep> {
    let mut steps = Vec::with_capacity(plan.red_total);
    for r in 0..plan.red_total {
        let mut st = RedStep {
            x_off: 0,
            w_off: 0,
            ks: [0; MAX_DIMS],
        };
        for (i, d) in plan.dims.iter().enumerate() {
            let k = (r / d.red_stride) % d.nks;
            st.ks[i] = k as u32;
            st.x_off += (k * d.in_stride) as i64;
            st.w_off += k * d.ker_stride;
        }
        steps.push(st);
    }
    steps
}

/// True when no window position of the plan can fall outside the bound
/// input (no padding, input covers every window): the per-step bounds
/// check can be skipped entirely.
fn never_oob(plan: &BoundPlan) -> bool {
    for d in &plan.dims {
        if d.ps != 0 || (d.nopc - 1) * d.s + d.nks > d.in_actual {
            return false;
        }
    }
    true
}

/// Flattened group / kernel-row / column spaces of a GEMM-tier plan and
/// their row-major strides — shared by bind-time weight prepacking and
/// the eval-time panel/row loops so both agree on the slab layout.
struct GemmGeom {
    g_stride: Vec<usize>,
    r_stride: Vec<usize>,
    c_stride: Vec<usize>,
    n_groups: usize,
    n_rows: usize,
    n_cols: usize,
}

impl GemmGeom {
    fn of(plan: &BoundPlan) -> GemmGeom {
        let ngs: Vec<usize> = plan.dims.iter().map(|d| d.ng).collect();
        let nops: Vec<usize> = plan.dims.iter().map(|d| d.nop).collect();
        let nopcs: Vec<usize> = plan.dims.iter().map(|d| d.nopc).collect();
        GemmGeom {
            g_stride: super::tensor::row_major_strides(&ngs),
            r_stride: super::tensor::row_major_strides(&nops),
            c_stride: super::tensor::row_major_strides(&nopcs),
            n_groups: ngs.iter().product(),
            n_rows: nops.iter().product(),
            n_cols: nopcs.iter().product(),
        }
    }
}

/// Pack every kernel row into `wpack[(g·n_rows + op)·K + k]`: each row
/// becomes a contiguous length-`K` slice regardless of the op's kernel
/// layout. The single packing routine behind both the bind-time slab
/// and the per-eval fallback, so the two are identical by construction.
fn fill_wpack(wpack: &mut [f32], plan: &BoundPlan, geom: &GemmGeom, steps: &[RedStep], ws: &[f32]) {
    let k_total = plan.red_total;
    for g in 0..geom.n_groups {
        for op in 0..geom.n_rows {
            let mut w_base = 0usize;
            for (i, d) in plan.dims.iter().enumerate() {
                let gi = (g / geom.g_stride[i]) % d.ng;
                let oi = (op / geom.r_stride[i]) % d.nop;
                w_base += (gi * d.nop + oi) * d.nks * d.ker_stride;
            }
            let row = &mut wpack[(g * geom.n_rows + op) * k_total..][..k_total];
            for (k, step) in steps.iter().enumerate() {
                row[k] = ws[w_base + step.w_off];
            }
        }
    }
}

/// Build the bind-time slab from the kernel operand (GEMM-tier plans
/// only; `BoundPlan::prepack` guards the tier and operand length).
pub(super) fn pack_weights(plan: &BoundPlan, ws: &[f32]) -> PrepackedWeights {
    let steps = red_steps(plan);
    let geom = GemmGeom::of(plan);
    let mut data = vec![0.0f32; geom.n_groups * geom.n_rows * plan.red_total];
    fill_wpack(&mut data, plan, &geom, &steps, ws);
    PrepackedWeights { data }
}

/// Scratch shared through the buffer pool when one is wired up. Pool
/// hits return stale contents, so every caller fully overwrites the
/// prefix it reads back.
fn take_scratch(pool: Option<&BufferPool>, n: usize) -> Vec<f32> {
    match pool {
        Some(p) => p.take(n),
        None => vec![0.0; n],
    }
}

/// Per-dimension output odometer: the decomposed `(g, op, opc)` output
/// coordinate plus the flattened window bases derived from it, advanced
/// one output element at a time with carry — no div/mod per element,
/// and the bases are maintained incrementally so an element-wise entry
/// costs O(1) index work instead of a per-dimension loop.
struct OutState {
    g: [usize; MAX_DIMS],
    kop: [usize; MAX_DIMS],
    opc: [usize; MAX_DIMS],
    in_base: [usize; MAX_DIMS],
    pos0: [i64; MAX_DIMS],
    ker_base: [usize; MAX_DIMS],
    /// `Σ_i (in_base[i] + pos0[i]) · in_stride[i]` — may be negative
    /// while the window starts in padding.
    x_base: i64,
    /// `Σ_i ker_base[i] · ker_stride[i]`.
    w_base: usize,
}

impl OutState {
    /// Decompose flat output index `o` — the oracle's div/mod split,
    /// done once per parallel chunk instead of once per element.
    fn seed(plan: &BoundPlan, o: usize) -> OutState {
        let mut st = OutState {
            g: [0; MAX_DIMS],
            kop: [0; MAX_DIMS],
            opc: [0; MAX_DIMS],
            in_base: [0; MAX_DIMS],
            pos0: [0; MAX_DIMS],
            ker_base: [0; MAX_DIMS],
            x_base: 0,
            w_base: 0,
        };
        for (i, d) in plan.dims.iter().enumerate() {
            let oc = (o / d.out_stride) % d.out_ext;
            let g = oc / d.npc;
            let r = oc % d.npc;
            let kop = r / d.nopc;
            let opc = r % d.nopc;
            st.g[i] = g;
            st.kop[i] = kop;
            st.opc[i] = opc;
            st.in_base[i] = g * d.in_actual;
            st.pos0[i] = (opc * d.s) as i64 - d.ps as i64;
            st.ker_base[i] = (g * d.nop + kop) * d.nks;
            st.x_base += (st.in_base[i] as i64 + st.pos0[i]) * d.in_stride as i64;
            st.w_base += st.ker_base[i] * d.ker_stride;
        }
        st
    }

    /// Advance to the next output element in row-major order, updating
    /// only the dimensions whose digits change (odometer carry) and
    /// adjusting the flattened bases by the matching deltas.
    fn advance(&mut self, plan: &BoundPlan) {
        let mut i = plan.dims.len();
        while i > 0 {
            i -= 1;
            let d = &plan.dims[i];
            self.opc[i] += 1;
            if self.opc[i] < d.nopc {
                self.pos0[i] += d.s as i64;
                self.x_base += (d.s * d.in_stride) as i64;
                return;
            }
            self.opc[i] = 0;
            self.pos0[i] = -(d.ps as i64);
            self.x_base -= ((d.nopc - 1) * d.s * d.in_stride) as i64;
            self.kop[i] += 1;
            if self.kop[i] < d.nop {
                self.ker_base[i] += d.nks;
                self.w_base += d.nks * d.ker_stride;
                return;
            }
            self.kop[i] = 0;
            self.g[i] += 1;
            if self.g[i] < d.ng {
                self.in_base[i] += d.in_actual;
                self.x_base += (d.in_actual * d.in_stride) as i64;
                // ker_base goes from (g·nop + nop−1)·nks to
                // (g+1)·nop·nks: the combined kop-reset + group-step
                // delta is exactly +nks.
                self.ker_base[i] = self.g[i] * d.nop * d.nks;
                self.w_base += d.nks * d.ker_stride;
                return;
            }
            self.g[i] = 0;
            self.x_base -= ((d.ng - 1) * d.in_actual * d.in_stride) as i64;
            self.in_base[i] = 0;
            // ker_base was (ng·nop − 1)·nks (last kernel of the last
            // group) and resets to 0.
            self.w_base -= (d.ng * d.nop - 1) * d.nks * d.ker_stride;
            self.ker_base[i] = 0;
            // carry into dimension i − 1
        }
    }

    /// Flattened window base offsets of the current output element.
    fn bases(&self) -> (i64, usize) {
        (self.x_base, self.w_base)
    }
}

/// Evaluate one output element from its odometer state: the oracle's
/// reduction loop with table-resolved offsets (bit-identical results,
/// no div/mod).
fn eval_steps(plan: &Plan, st: &OutState, steps: &[RedStep], safe: bool) -> f32 {
    let (x_base, w_base) = st.bases();
    let reduce = plan.bound.reduce;
    let main = plan.bound.main;
    let mut acc: f64 = match reduce {
        ReduceOp::Max => f64::NEG_INFINITY,
        _ => 0.0,
    };
    let mut any = false;
    for step in steps {
        let mut oob = false;
        if !safe {
            for (i, d) in plan.bound.dims.iter().enumerate() {
                let pos = st.pos0[i] + i64::from(step.ks[i]);
                if pos < 0 || pos >= d.in_actual as i64 {
                    oob = true;
                    break;
                }
            }
        }
        if oob && reduce == ReduceOp::Max {
            continue; // max pooling ignores padding
        }
        let mut x = 0.0;
        if !oob {
            x = plan.xs[(x_base + step.x_off) as usize];
        }
        let a = plan.bound.pre.apply(x);
        let m = match plan.ws {
            Some(ws) => main_apply(main, a, ws[w_base + step.w_off]),
            None => main_apply(main, a, 0.0),
        };
        match reduce {
            ReduceOp::Add => acc += f64::from(m),
            ReduceOp::Max => acc = acc.max(f64::from(m)),
            ReduceOp::None => acc = f64::from(m),
        }
        any = true;
    }
    if !any {
        acc = 0.0; // fully padded window (degenerate BP edge)
    }
    plan.bound.post.apply(acc as f32)
}

/// Generic fast path: odometer-carry iteration over output coordinates
/// plus the precomputed reduction-step table — no per-element div/mod,
/// no per-step stride recomputation, no string matching.
pub(super) fn eval_odometer(plan: &Plan, out: &mut [f32]) {
    let steps = red_steps(plan.bound);
    let safe = never_oob(plan.bound);
    let chunks = out.par_chunks_mut(PAR_CHUNK).enumerate();
    chunks.for_each(|(ci, chunk)| {
        let mut st = OutState::seed(plan.bound, ci * PAR_CHUNK);
        for slot in chunk.iter_mut() {
            *slot = eval_steps(plan, &st, &steps, safe);
            st.advance(plan.bound);
        }
    });
}

/// Reference oracle tier: per-element `Plan::eval_one` (div/mod
/// coordinate decomposition per output, per-step stride recomputation).
pub(super) fn eval_naive(plan: &Plan, out: &mut [f32]) {
    let chunks = out.par_chunks_mut(PAR_CHUNK).enumerate();
    chunks.for_each(|(ci, chunk)| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = plan.eval_one(ci * PAR_CHUNK + j);
        }
    });
}

/// Raw output pointer shared across GEMM jobs. Each job writes a
/// disjoint set of output indices (see the SAFETY note at the write
/// site), so unsynchronized parallel writes are sound.
struct OutPtr(*mut f32);

unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Dense dot/GEMM fast path for `Mul`+`Add` plans with a kernel operand.
///
/// Kernel rows come from the plan-owned [`PrepackedWeights`] slab when
/// the bind provided one, and are otherwise packed on the fly into
/// pooled scratch (`K = red_total` per row). Column blocks of at most
/// [`NC`] outputs pack their input windows — `pre` applied, padding
/// resolved to `pre(0)` exactly as the oracle does — into a `K × nc`
/// panel stored `k`-major, so the inner loop
/// `acc[c] += panel[k][c] · w[k]` is a stride-1 rank-1 update the
/// autovectorizer handles well. Under [`Precision::BitExact`]
/// accumulation stays sequential `f64` in reduction order (bit-identical
/// to the oracle); [`Precision::Fast`] unrolls the reduction over four
/// independent `f32` accumulator lanes per column instead.
pub(super) fn eval_gemm(
    plan: &Plan,
    pool: Option<&BufferPool>,
    precision: Precision,
    out: &mut [f32],
) {
    let steps = red_steps(plan.bound);
    let safe = never_oob(plan.bound);
    let k_total = plan.bound.red_total;
    let dims = &plan.bound.dims;
    let geom = GemmGeom::of(plan.bound);
    let (n_groups, n_rows, n_cols) = (geom.n_groups, geom.n_rows, geom.n_cols);

    // Kernel rows: the bind-time slab when present, else pack now into
    // pooled scratch (fully overwritten by `fill_wpack`, so stale pool
    // contents are never read).
    let wpack_scratch: Option<Vec<f32>>;
    let wpack: &[f32] = match &plan.bound.prepacked {
        Some(packed) => {
            wpack_scratch = None;
            &packed.data
        }
        None => {
            let ws = plan.ws.expect("gemm tier requires a kernel operand");
            let mut buf = take_scratch(pool, n_groups * n_rows * k_total);
            fill_wpack(&mut buf, plan.bound, &geom, &steps, ws);
            wpack_scratch = Some(buf);
            wpack_scratch.as_deref().unwrap()
        }
    };

    // One job per (group, column block); jobs write disjoint outputs.
    let mut jobs = Vec::new();
    for g in 0..n_groups {
        let mut c0 = 0;
        while c0 < n_cols {
            jobs.push((g, c0));
            c0 += NC;
        }
    }

    // Panel scratch also rides the pool: one fixed-width `K × NC`
    // buffer per worker shard, taken per eval and shelved again. The
    // shard count is deterministic for a fixed thread pool, so a
    // warmed steady-state run allocates nothing (pool misses stay flat
    // from run 2 on). Each job overwrites the `K × nc` prefix it reads.
    let workers = jobs.len().min(rayon::current_num_threads()).max(1);
    let shard_len = jobs.len().div_ceil(workers);
    let mut panels: Vec<Vec<f32>> = (0..workers)
        .map(|_| take_scratch(pool, k_total * NC))
        .collect();

    let out_ptr = OutPtr(out.as_mut_ptr());
    panels.par_iter_mut().enumerate().for_each(|(wi, panel)| {
        let shard = &jobs[(wi * shard_len).min(jobs.len())..((wi + 1) * shard_len).min(jobs.len())];
        for &(g, c0) in shard {
            let nc = NC.min(n_cols - c0);

            // Output offsets, window bases and per-dim window starts of
            // the block's columns (the per-column index arithmetic paid
            // once and amortized over every kernel row below).
            let mut col_off = [0usize; NC];
            let mut x_bases = [0i64; NC];
            let mut pos0 = [[0i64; MAX_DIMS]; NC];
            for c in 0..nc {
                let col = c0 + c;
                let mut off = 0usize;
                let mut xb = 0i64;
                for (i, d) in dims.iter().enumerate() {
                    let gi = (g / geom.g_stride[i]) % d.ng;
                    let oi = (col / geom.c_stride[i]) % d.nopc;
                    let p0 = (oi * d.s) as i64 - d.ps as i64;
                    off += oi * d.out_stride;
                    xb += ((gi * d.in_actual) as i64 + p0) * d.in_stride as i64;
                    pos0[c][i] = p0;
                }
                col_off[c] = off;
                x_bases[c] = xb;
            }

            // Pack the panel k-major: panel[k·nc + c] = pre(x or 0).
            for c in 0..nc {
                for (k, step) in steps.iter().enumerate() {
                    let mut oob = false;
                    if !safe {
                        for (i, d) in dims.iter().enumerate() {
                            let pos = pos0[c][i] + i64::from(step.ks[i]);
                            if pos < 0 || pos >= d.in_actual as i64 {
                                oob = true;
                                break;
                            }
                        }
                    }
                    let mut x = 0.0;
                    if !oob {
                        x = plan.xs[(x_bases[c] + step.x_off) as usize];
                    }
                    panel[k * nc + c] = plan.bound.pre.apply(x);
                }
            }

            // Every kernel row of this group streams over the panel.
            // The row loop is itself parallel so few-column plans (FC
            // at small batch: one group, one column) still use every
            // core; rayon's work stealing only splits when outer jobs
            // leave cores idle.
            let panel_ro: &[f32] = panel;
            let rows = (0..n_rows).into_par_iter().with_min_len(8);
            rows.for_each(|op| {
                let mut row_base = 0usize;
                for (i, d) in dims.iter().enumerate() {
                    let gi = (g / geom.g_stride[i]) % d.ng;
                    let oi = (op / geom.r_stride[i]) % d.nop;
                    row_base += (gi * d.nop + oi) * d.nopc * d.out_stride;
                }
                let wrow = &wpack[(g * n_rows + op) * k_total..][..k_total];
                match precision {
                    Precision::BitExact => {
                        let mut acc = [0.0f64; NC];
                        for (k, &w) in wrow.iter().enumerate() {
                            let prow = &panel_ro[k * nc..k * nc + nc];
                            for (a, &p) in acc[..nc].iter_mut().zip(prow) {
                                *a += f64::from(p * w);
                            }
                        }
                        for c in 0..nc {
                            let v = plan.bound.post.apply(acc[c] as f32);
                            // SAFETY: output index = Σ_i ((g_i·nop_i +
                            // op_i)·nopc_i + opc_i)·out_stride_i is the
                            // row-major mixed-radix flattening of
                            // (g, op, opc) — a bijection onto
                            // 0..out_total; jobs partition the (group,
                            // column) space disjointly (shards partition
                            // the jobs) and row tasks within a job
                            // partition the row space, so every output
                            // index is written by exactly one task
                            // exactly once, within bounds.
                            unsafe {
                                *out_ptr.0.add(row_base + col_off[c]) = v;
                            }
                        }
                    }
                    Precision::Fast => {
                        // Four independent accumulator lanes over the
                        // unrolled k loop: the lanes and the stride-1 c
                        // loop give the autovectorizer f32x8-shaped
                        // work with no loop-carried dependence.
                        let mut acc0 = [0.0f32; NC];
                        let mut acc1 = [0.0f32; NC];
                        let mut acc2 = [0.0f32; NC];
                        let mut acc3 = [0.0f32; NC];
                        let mut k = 0usize;
                        while k + 4 <= k_total {
                            let (w0, w1) = (wrow[k], wrow[k + 1]);
                            let (w2, w3) = (wrow[k + 2], wrow[k + 3]);
                            let p0 = &panel_ro[k * nc..k * nc + nc];
                            let p1 = &panel_ro[(k + 1) * nc..(k + 1) * nc + nc];
                            let p2 = &panel_ro[(k + 2) * nc..(k + 2) * nc + nc];
                            let p3 = &panel_ro[(k + 3) * nc..(k + 3) * nc + nc];
                            for c in 0..nc {
                                acc0[c] += p0[c] * w0;
                                acc1[c] += p1[c] * w1;
                                acc2[c] += p2[c] * w2;
                                acc3[c] += p3[c] * w3;
                            }
                            k += 4;
                        }
                        while k < k_total {
                            let w = wrow[k];
                            let prow = &panel_ro[k * nc..k * nc + nc];
                            for c in 0..nc {
                                acc0[c] += prow[c] * w;
                            }
                            k += 1;
                        }
                        for c in 0..nc {
                            let sum = (acc0[c] + acc1[c]) + (acc2[c] + acc3[c]);
                            let v = plan.bound.post.apply(sum);
                            // SAFETY: same disjoint (group, column)
                            // job × row-task partition as the bit-exact
                            // arm above — precision only changes the
                            // summation order, never the write set.
                            unsafe {
                                *out_ptr.0.add(row_base + col_off[c]) = v;
                            }
                        }
                    }
                }
            });
        }
    });

    // Shelve the scratch for the next eval (session steady state).
    if let Some(p) = pool {
        for panel in panels {
            p.put(panel);
        }
        if let Some(buf) = wpack_scratch {
            p.put(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::sync::atomic::{AtomicUsize, Ordering};

    use crate::exec::interp::{eval_bound, eval_gconv, eval_gconv_naive, plan_tier};
    use crate::exec::tensor::Tensor;
    use crate::gconv::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp};
    use crate::ir::Dim;

    /// A conv-shaped op whose reduction (3×3 = 9 steps) takes the GEMM
    /// tier: C[Nop:2, Nks:3] × W[window 4, ks 3, s 1, ps 1].
    fn conv_case() -> (GconvOp, Tensor, Tensor) {
        let dims = vec![
            (Dim::C, DimParams::op_ks(2, 3)),
            (Dim::W, DimParams::window(4, 3, 1, 1)),
        ];
        let x = DataRef::External("x".into());
        let w = DataRef::Weights("w".into());
        let op = GconvOp::conv("k", dims, x, w);
        let xs = Tensor::rand(&[3, 4], 7, 1.0);
        let ws = Tensor::rand(&[6, 3], 8, 1.0);
        (op, xs, ws)
    }

    #[test]
    fn conv_plan_takes_the_gemm_tier() {
        let (op, xs, ws) = conv_case();
        let tier = plan_tier(&op, &xs, Some(&ws)).unwrap();
        assert_eq!(tier, KernelTier::Gemm);
    }

    #[test]
    fn short_reductions_take_the_odometer_tier() {
        let (mut op, xs, _ws) = conv_case();
        op.dims[0].1 = DimParams::op_ks(2, 1); // 1×3 = 3 steps < minimum
        let xs2 = Tensor::rand(&[1, xs.dims()[1]], 9, 1.0);
        let ws2 = Tensor::rand(&[2, 3], 10, 1.0);
        let tier = plan_tier(&op, &xs2, Some(&ws2)).unwrap();
        assert_eq!(tier, KernelTier::Odometer);
    }

    #[test]
    fn kernel_less_ops_take_the_odometer_tier() {
        let op = GconvOp {
            name: "pool".into(),
            dims: vec![(Dim::W, DimParams::window(2, 2, 2, 0))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::Max,
            post: PostOp::None,
            input: DataRef::External("x".into()),
            kernel: None,
        };
        let xs = Tensor::rand(&[4], 11, 1.0);
        let tier = plan_tier(&op, &xs, None).unwrap();
        assert_eq!(tier, KernelTier::Odometer);
    }

    #[test]
    fn gemm_path_matches_oracle_bitwise() {
        let (op, xs, ws) = conv_case();
        let fast = eval_gconv(&op, &xs, Some(&ws)).unwrap();
        let naive = eval_gconv_naive(&op, &xs, Some(&ws)).unwrap();
        assert!(fast.bit_eq(&naive));
    }

    /// Bind a plan to the input's layout (the tests never need data to
    /// inspect the bound geometry).
    fn bind(op: &GconvOp, xs: &Tensor) -> BoundPlan {
        BoundPlan::bind(op, xs.dims(), xs.elements(), None).unwrap()
    }

    #[test]
    fn red_steps_follow_the_oracle_order() {
        let (op, xs, _ws) = conv_case();
        let plan = bind(&op, &xs);
        let steps = red_steps(&plan);
        assert_eq!(steps.len(), 9);
        assert_eq!(steps[0].ks[..2], [0, 0]);
        assert_eq!(steps[1].ks[..2], [0, 1]);
        assert_eq!(steps[3].ks[..2], [1, 0]);
        assert_eq!(steps[8].ks[..2], [2, 2]);
    }

    fn assert_advance_matches_reseeding(plan: &BoundPlan) {
        let mut st = OutState::seed(plan, 0);
        for o in 0..plan.out_total {
            // `fresh` recomputes digits and bases from scratch; `st`
            // reached the same element by incremental carries.
            let fresh = OutState::seed(plan, o);
            assert_eq!(st.pos0, fresh.pos0, "pos0 at output {o}");
            assert_eq!(st.in_base, fresh.in_base, "in_base at output {o}");
            assert_eq!(st.ker_base, fresh.ker_base, "ker_base at output {o}");
            assert_eq!(st.bases(), fresh.bases(), "bases at output {o}");
            st.advance(plan);
        }
    }

    #[test]
    fn odometer_advance_matches_reseeding() {
        let (op, xs, _ws) = conv_case();
        assert_advance_matches_reseeding(&bind(&op, &xs));
    }

    #[test]
    fn odometer_advance_carries_through_groups() {
        // Ng > 1 on both dims exercises the group-carry branch.
        let cdim = DimParams {
            ng: 2,
            nop: 2,
            nopc: 1,
            nks: 2,
            s: 1,
            ps: 0,
            pe: 0,
        };
        let wdim = DimParams {
            ng: 3,
            nop: 1,
            nopc: 2,
            nks: 2,
            s: 2,
            ps: 1,
            pe: 0,
        };
        let dims = vec![(Dim::C, cdim), (Dim::W, wdim)];
        let x = DataRef::External("x".into());
        let w = DataRef::Weights("w".into());
        let op = GconvOp::conv("grp", dims, x, w);
        let xs = Tensor::rand(&op.input_extents(), 21, 1.0);
        let ws = Tensor::rand(&op.kernel_extents(), 22, 1.0);
        assert_advance_matches_reseeding(&bind(&op, &xs));
        let fast = eval_gconv(&op, &xs, Some(&ws)).unwrap();
        let naive = eval_gconv_naive(&op, &xs, Some(&ws)).unwrap();
        assert!(fast.bit_eq(&naive));
    }

    #[test]
    fn never_oob_detects_padding() {
        let (op, xs, _ws) = conv_case();
        assert!(!never_oob(&bind(&op, &xs)), "ps=1 window can leave the input");
        let dims = vec![(Dim::W, DimParams::window(3, 2, 1, 0))];
        let x = DataRef::External("x".into());
        let w = DataRef::Weights("w".into());
        let op2 = GconvOp::conv("nopad", dims, x, w);
        let xs2 = Tensor::rand(&[4], 12, 1.0);
        assert!(never_oob(&bind(&op2, &xs2)));
    }

    #[test]
    fn prepacked_plan_matches_per_eval_packing_bitwise() {
        let (op, xs, ws) = conv_case();
        let mut bound = bind(&op, &xs);
        let fresh = eval_bound(&bound, &xs, Some(&ws), None, false, Precision::BitExact).unwrap();
        let packs = AtomicUsize::new(0);
        bound.prepack(&ws, Some(&packs)).unwrap();
        assert_eq!(packs.load(Ordering::Relaxed), 1);
        assert!(bound.prepacked.is_some());
        let packed = eval_bound(&bound, &xs, Some(&ws), None, false, Precision::BitExact).unwrap();
        assert!(packed.bit_eq(&fresh), "the slab must reproduce per-eval packing");
    }

    #[test]
    fn prepack_skips_non_gemm_tiers() {
        let (mut op, _xs, _ws) = conv_case();
        op.dims[0].1 = DimParams::op_ks(2, 1); // 1×3 = 3 steps: odometer
        let xs2 = Tensor::rand(&[1, 4], 9, 1.0);
        let ws2 = Tensor::rand(&[2, 3], 10, 1.0);
        let mut bound = bind(&op, &xs2);
        let packs = AtomicUsize::new(0);
        bound.prepack(&ws2, Some(&packs)).unwrap();
        assert_eq!(packs.load(Ordering::Relaxed), 0, "no slab off the GEMM tier");
        assert!(bound.prepacked.is_none());
    }

    #[test]
    fn prepack_rejects_a_mis_sized_kernel() {
        let (op, xs, _ws) = conv_case();
        let mut bound = bind(&op, &xs);
        let short = Tensor::rand(&[3, 3], 13, 1.0);
        assert!(bound.prepack(&short, None).is_err());
    }

    #[test]
    fn fast_precision_stays_within_tolerance() {
        let (op, xs, ws) = conv_case();
        let exact = eval_gconv(&op, &xs, Some(&ws)).unwrap();
        let bound = bind(&op, &xs);
        // k_total = 9 exercises both the unrolled quad loop and the
        // remainder loop of the fast microkernel.
        let fast = eval_bound(&bound, &xs, Some(&ws), None, false, Precision::Fast).unwrap();
        assert_eq!(fast.dims(), exact.dims());
        for (f, e) in fast.data().iter().zip(exact.data()) {
            let rel = (f - e).abs() / e.abs().max(1.0);
            assert!(rel <= FAST_REL_TOL, "fast {f} vs exact {e}: rel err {rel}");
        }
    }

    #[test]
    fn gemm_eval_scratch_rides_the_buffer_pool() {
        let (op, xs, ws) = conv_case();
        let bound = bind(&op, &xs);
        let pool = BufferPool::new();
        let first = eval_bound(
            &bound,
            &xs,
            Some(&ws),
            Some(&pool),
            false,
            Precision::BitExact,
        )
        .unwrap();
        let misses_first = pool.stats().misses;
        assert!(misses_first >= 3, "output + wpack + panel all allocate cold");
        pool.put(first.into_data());
        let second = eval_bound(
            &bound,
            &xs,
            Some(&ws),
            Some(&pool),
            false,
            Precision::BitExact,
        )
        .unwrap();
        assert_eq!(
            pool.stats().misses,
            misses_first,
            "a warmed eval allocates no fresh scratch"
        );
        drop(second);
    }
}
