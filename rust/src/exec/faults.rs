//! Deterministic fault injection for the serving stack.
//!
//! The whole-life-cost argument (§6) counts availability: a serving
//! front is only as cheap as its worst failure mode. This module makes
//! failure modes *testable* the same way the conformance suite made
//! numerics testable — named injection sites threaded through the hot
//! path, armed with a seeded [`FaultPlan`], deterministic under a fixed
//! seed and call order.
//!
//! Sites ([`SITES`]):
//!
//! | site | where it fires |
//! | --- | --- |
//! | `pool.alloc` | [`super::pool::BufferPool::take`], before the shelf lock |
//! | `kernels.eval` | `interp::eval_bound`, before tier dispatch |
//! | `serve.step` | [`super::serve::Engine::step`], scoped by model code |
//! | `scheduler.wave` | the server driver, once per per-model wave group |
//! | `conn.read` | the connection thread, after each complete frame |
//!
//! Each [`FaultRule`] injects a panic, an `Err`, or an artificial
//! delay, triggered probabilistically (seeded) or on the n-th matching
//! call, optionally filtered to one *scope* (the model code, at sites
//! that have one). **Disarmed, every site is a single relaxed atomic
//! load** — the registry cannot perturb numbers or timing when off.
//!
//! Arming is process-global and exclusive: [`FaultPlan::arm`] returns
//! a [`FaultGuard`] that holds a static lock (concurrent arming tests
//! serialize) and disarms on drop, so a panicking test cannot leak an
//! armed registry into its neighbors.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use crate::prop::Rng;

/// Buffer-pool allocation ([`super::pool::BufferPool::take`]). `Err`
/// injections at this site escalate to panics — allocation has no error
/// channel.
pub const SITE_POOL_ALLOC: &str = "pool.alloc";
/// Kernel evaluation of one bound plan (`interp::eval_bound`).
pub const SITE_KERNELS_EVAL: &str = "kernels.eval";
/// One engine micro-batch step ([`super::serve::Engine::step`]); the
/// scope is the model code being served.
pub const SITE_SERVE_STEP: &str = "serve.step";
/// One per-model wave group in the server driver; the scope is the
/// model code.
pub const SITE_SCHEDULER_WAVE: &str = "scheduler.wave";
/// One parsed frame on a connection thread.
pub const SITE_CONN_READ: &str = "conn.read";

/// Every named injection site.
pub const SITES: [&str; 5] = [
    SITE_POOL_ALLOC,
    SITE_KERNELS_EVAL,
    SITE_SERVE_STEP,
    SITE_SCHEDULER_WAVE,
    SITE_CONN_READ,
];

/// What a firing rule does to the call it intercepts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the site (sites under `catch_unwind` convert this to
    /// structured `INTERNAL` replies; others kill their thread).
    Panic,
    /// Return a [`FaultError`] through the site's `Result` channel.
    Err,
    /// Sleep this long, then proceed normally (numerics unchanged).
    Delay(Duration),
}

/// When a rule fires, evaluated per *matching* call (site + scope).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Trigger {
    /// Fire each matching call with this probability (seeded RNG).
    Prob(f64),
    /// Fire exactly once, on the n-th matching call (1-based).
    Nth(u64),
    /// Fire on every n-th matching call (n, 2n, 3n, …).
    EveryNth(u64),
}

/// One injection rule of a [`FaultPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRule {
    /// Which site the rule intercepts (one of [`SITES`]).
    pub site: String,
    /// Optional scope filter — at `serve.step`/`scheduler.wave` the
    /// model code; `None` matches every call at the site.
    pub scope: Option<String>,
    /// What to inject.
    pub kind: FaultKind,
    /// When to inject it.
    pub trigger: Trigger,
}

/// The error an `Err`-kind rule returns through a site's `Result`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultError {
    /// The site that injected the failure.
    pub site: &'static str,
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected fault: err at {}", self.site)
    }
}

impl std::error::Error for FaultError {}

/// Per-site call/injection counters of the armed registry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SiteStats {
    /// Calls that reached the armed slow path at this site.
    pub calls: u64,
    /// Calls a rule fired on.
    pub injected: u64,
}

/// A seeded set of [`FaultRule`]s, armed globally via
/// [`FaultPlan::arm`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the probabilistic triggers.
    pub seed: u64,
    /// Rules, checked in order; the first firing rule wins.
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// An empty plan with the given trigger seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: Vec::new() }
    }

    /// Append one rule (builder style).
    pub fn with(mut self, rule: FaultRule) -> FaultPlan {
        self.rules.push(rule);
        self
    }

    /// Parse a CLI/test spec. Grammar (clauses comma-separated):
    ///
    /// ```text
    /// spec    := clause ("," clause)*
    /// clause  := "seed=" u64
    ///          | site ("[" scope "]")? "=" kind "@" trigger
    /// kind    := "panic" | "err" | "delay:" millis
    /// trigger := "p:" float | "nth:" n | "every:" n
    /// ```
    ///
    /// Example: `seed=42,serve.step[bad]=panic@nth:1,conn.read=delay:20@p:0.1`
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(0);
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some(seed) = clause.strip_prefix("seed=") {
                plan.seed =
                    seed.parse().map_err(|_| format!("{clause:?}: seed is not a u64"))?;
                continue;
            }
            let (target, action) = clause
                .split_once('=')
                .ok_or_else(|| format!("{clause:?}: expected site=kind@trigger"))?;
            let (site, scope) = match target.split_once('[') {
                Some((site, rest)) => {
                    let scope = rest
                        .strip_suffix(']')
                        .ok_or_else(|| format!("{clause:?}: unterminated scope"))?;
                    (site, Some(scope.to_string()))
                }
                None => (target, None),
            };
            let site = SITES
                .iter()
                .find(|&&s| s == site)
                .ok_or_else(|| format!("{clause:?}: unknown site {site:?} (sites: {SITES:?})"))?;
            let (kind, trigger) = action
                .split_once('@')
                .ok_or_else(|| format!("{clause:?}: expected kind@trigger"))?;
            let kind = if kind == "panic" {
                FaultKind::Panic
            } else if kind == "err" {
                FaultKind::Err
            } else if let Some(ms) = kind.strip_prefix("delay:") {
                let ms: u64 =
                    ms.parse().map_err(|_| format!("{clause:?}: delay millis not a u64"))?;
                FaultKind::Delay(Duration::from_millis(ms))
            } else {
                return Err(format!("{clause:?}: unknown kind {kind:?}"));
            };
            let trigger = if let Some(p) = trigger.strip_prefix("p:") {
                let p: f64 =
                    p.parse().map_err(|_| format!("{clause:?}: probability not an f64"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("{clause:?}: probability {p} outside 0..=1"));
                }
                Trigger::Prob(p)
            } else if let Some(n) = trigger.strip_prefix("nth:") {
                let n: u64 = n.parse().map_err(|_| format!("{clause:?}: nth not a u64"))?;
                if n == 0 {
                    return Err(format!("{clause:?}: nth is 1-based"));
                }
                Trigger::Nth(n)
            } else if let Some(n) = trigger.strip_prefix("every:") {
                let n: u64 = n.parse().map_err(|_| format!("{clause:?}: every not a u64"))?;
                if n == 0 {
                    return Err(format!("{clause:?}: every must be ≥ 1"));
                }
                Trigger::EveryNth(n)
            } else {
                return Err(format!("{clause:?}: unknown trigger {trigger:?}"));
            };
            plan.rules.push(FaultRule {
                site: site.to_string(),
                scope,
                kind,
                trigger,
            });
        }
        if plan.rules.is_empty() {
            return Err("fault spec names no rules".into());
        }
        Ok(plan)
    }

    /// Arm the global registry with this plan. Exclusive: a second
    /// `arm` blocks until the previous [`FaultGuard`] drops.
    pub fn arm(self) -> FaultGuard {
        let lock = arm_lock().lock().unwrap_or_else(|e| e.into_inner());
        {
            let mut state = registry().lock().unwrap_or_else(|e| e.into_inner());
            *state = Some(Armed {
                rules: self.rules.into_iter().map(|r| (r, 0)).collect(),
                rng: Rng::new(self.seed),
                stats: HashMap::new(),
            });
        }
        ARMED.store(true, Ordering::SeqCst);
        FaultGuard { _lock: lock }
    }
}

/// Keeps the registry armed; disarms (and clears all rules) on drop.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        let mut state = registry().lock().unwrap_or_else(|e| e.into_inner());
        *state = None;
    }
}

/// Armed state: rules with per-rule match counters, the trigger RNG,
/// and per-site stats.
struct Armed {
    rules: Vec<(FaultRule, u64)>,
    rng: Rng,
    stats: HashMap<String, SiteStats>,
}

static ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<Option<Armed>> {
    static REGISTRY: OnceLock<Mutex<Option<Armed>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(None))
}

fn arm_lock() -> &'static Mutex<()> {
    static ARM_LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    ARM_LOCK.get_or_init(|| Mutex::new(()))
}

/// Whether a [`FaultPlan`] is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Per-site counters of the armed plan (`None` when disarmed).
pub fn stats() -> Option<HashMap<String, SiteStats>> {
    let state = registry().lock().unwrap_or_else(|e| e.into_inner());
    state.as_ref().map(|a| a.stats.clone())
}

/// The unscoped injection hook. Disarmed this is one relaxed atomic
/// load; armed it evaluates the plan's rules for `site`.
#[inline]
pub fn trip(site: &'static str) -> Result<(), FaultError> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    trip_slow(site, None)
}

/// The scoped injection hook (`scope` is the model code at the serving
/// sites).
#[inline]
pub fn trip_scoped(site: &'static str, scope: &str) -> Result<(), FaultError> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    trip_slow(site, Some(scope))
}

/// Injection hook for sites with no error channel ([`SITE_POOL_ALLOC`]):
/// an injected `Err` escalates to a panic.
#[inline]
pub fn trip_panic(site: &'static str) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    if let Err(e) = trip_slow(site, None) {
        panic!("{e}");
    }
}

#[cold]
fn trip_slow(site: &'static str, scope: Option<&str>) -> Result<(), FaultError> {
    let mut fire: Option<FaultKind> = None;
    {
        let mut guard = registry().lock().unwrap_or_else(|e| e.into_inner());
        let Some(armed) = guard.as_mut() else {
            return Ok(());
        };
        let Armed { rules, rng, stats } = armed;
        let entry = stats.entry(site.to_string()).or_default();
        entry.calls += 1;
        for (rule, seen) in rules.iter_mut() {
            if rule.site != site {
                continue;
            }
            if let Some(want) = &rule.scope {
                if scope != Some(want.as_str()) {
                    continue;
                }
            }
            *seen += 1;
            let hit = match rule.trigger {
                Trigger::Prob(p) => rng.f64() < p,
                Trigger::Nth(n) => *seen == n,
                Trigger::EveryNth(n) => *seen % n == 0,
            };
            if hit {
                fire = Some(rule.kind);
                break;
            }
        }
        if fire.is_some() {
            stats.entry(site.to_string()).or_default().injected += 1;
        }
    }
    // The registry lock is released before acting: a panic here cannot
    // poison it, and a delay never serializes unrelated sites.
    match fire {
        None => Ok(()),
        Some(FaultKind::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
        Some(FaultKind::Err) => Err(FaultError { site }),
        Some(FaultKind::Panic) => panic!("injected fault: panic at {site}"),
    }
}

/// Install a process-wide panic hook that suppresses the backtrace
/// noise of *injected* panics (they are expected and caught) while
/// forwarding every real panic to the previous hook. Idempotent; used
/// by the chaos tests and the `--faults` CLI path.
pub fn silence_injected_panics() {
    static INSTALLED: OnceLock<()> = OnceLock::new();
    INSTALLED.get_or_init(|| {
        let previous = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("injected fault") {
                previous(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // The arming tests use *synthetic* site names no production code
    // trips: the lib test binary runs multi-threaded, and an armed rule
    // on a real site would fire inside whatever unrelated engine test
    // happens to run concurrently. (Registry matching is string-keyed,
    // so synthetic sites exercise the same paths.)

    fn rule(site: &str, kind: FaultKind, trigger: Trigger) -> FaultRule {
        FaultRule { site: site.to_string(), scope: None, kind, trigger }
    }

    #[test]
    fn disarmed_sites_are_transparent() {
        // No rules ever target these real sites in this binary, so the
        // hooks must pass through whether or not a concurrent test has
        // the registry armed for its own synthetic sites.
        assert!(trip(SITE_KERNELS_EVAL).is_ok());
        assert!(trip_scoped(SITE_SCHEDULER_WAVE, "m").is_ok());
        trip_panic(SITE_POOL_ALLOC);
    }

    #[test]
    fn nth_trigger_fires_exactly_once() {
        const SITE: &str = "test.nth";
        let plan = FaultPlan::new(1).with(rule(SITE, FaultKind::Err, Trigger::Nth(3)));
        let guard = plan.arm();
        assert!(armed());
        assert!(trip(SITE).is_ok());
        assert!(trip(SITE).is_ok());
        assert_eq!(trip(SITE), Err(FaultError { site: SITE }));
        assert!(trip(SITE).is_ok(), "nth is one-shot");
        let s = stats().unwrap();
        assert_eq!(s[SITE], SiteStats { calls: 4, injected: 1 });
        drop(guard);
        assert!(trip(SITE).is_ok());
    }

    #[test]
    fn every_nth_trigger_fires_on_multiples() {
        const SITE: &str = "test.every";
        let plan = FaultPlan::new(1).with(rule(SITE, FaultKind::Err, Trigger::EveryNth(2)));
        let _guard = plan.arm();
        let fired: Vec<bool> = (0..6).map(|_| trip(SITE).is_err()).collect();
        assert_eq!(fired, [false, true, false, true, false, true]);
    }

    #[test]
    fn probability_extremes_are_deterministic() {
        let plan = FaultPlan::new(7)
            .with(rule("test.p1", FaultKind::Err, Trigger::Prob(1.0)))
            .with(rule("test.p0", FaultKind::Err, Trigger::Prob(0.0)));
        let _guard = plan.arm();
        for _ in 0..16 {
            assert!(trip("test.p1").is_err());
            assert!(trip("test.p0").is_ok());
        }
    }

    #[test]
    fn scope_filters_to_the_named_model() {
        const SITE: &str = "test.scoped";
        let plan = FaultPlan::new(1).with(FaultRule {
            site: SITE.to_string(),
            scope: Some("bad".to_string()),
            kind: FaultKind::Err,
            trigger: Trigger::Nth(1),
        });
        let _guard = plan.arm();
        assert!(trip_scoped(SITE, "good").is_ok());
        assert!(trip(SITE).is_ok(), "unscoped call never matches a scoped rule");
        assert!(trip_scoped(SITE, "bad").is_err(), "the scoped call is the 1st match");
    }

    #[test]
    fn delay_rules_return_ok() {
        const SITE: &str = "test.delay";
        let plan = FaultPlan::new(1).with(rule(
            SITE,
            FaultKind::Delay(Duration::from_millis(1)),
            Trigger::EveryNth(1),
        ));
        let _guard = plan.arm();
        let t0 = std::time::Instant::now();
        assert!(trip(SITE).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(1));
    }

    #[test]
    fn panic_rules_panic_with_the_site_name() {
        const SITE: &str = "test.panic";
        silence_injected_panics();
        let plan = FaultPlan::new(1).with(rule(SITE, FaultKind::Panic, Trigger::Nth(1)));
        let _guard = plan.arm();
        let err = std::panic::catch_unwind(|| trip_panic(SITE)).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(SITE), "{msg}");
        // The registry lock was released before panicking: the site
        // still serves calls.
        assert_eq!(stats().unwrap()[SITE].injected, 1);
    }

    #[test]
    fn specs_parse_to_rules() {
        let plan =
            FaultPlan::parse("seed=42,serve.step[bad]=panic@nth:1,conn.read=delay:20@p:0.25")
                .unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.rules.len(), 2);
        assert_eq!(plan.rules[0], FaultRule {
            site: SITE_SERVE_STEP.to_string(),
            scope: Some("bad".to_string()),
            kind: FaultKind::Panic,
            trigger: Trigger::Nth(1),
        });
        assert_eq!(plan.rules[1], FaultRule {
            site: SITE_CONN_READ.to_string(),
            scope: None,
            kind: FaultKind::Delay(Duration::from_millis(20)),
            trigger: Trigger::Prob(0.25),
        });
    }

    #[test]
    fn malformed_specs_are_rejected_with_context() {
        for bad in [
            "",
            "seed=42",
            "nope.site=err@p:0.5",
            "conn.read=explode@p:0.5",
            "conn.read=err@p:1.5",
            "conn.read=err@nth:0",
            "conn.read=err",
            "serve.step[bad=err@p:0.5",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} must be rejected");
        }
    }
}
