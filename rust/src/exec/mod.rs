//! Native GCONV execution engine: a pure-Rust, parallel interpreter for
//! GCONV chains.
//!
//! The paper's thesis (§3) is that *every* CNN layer — forward and
//! backward — reduces to a chain of general convolutions. This module is
//! the executable ground truth for that claim inside the Rust crate
//! itself: no Python, no XLA, no AOT artifacts.
//!
//! * [`tensor`] — a small owned row-major `f32` tensor.
//! * [`interp`] — evaluates one [`crate::gconv::op::GconvOp`] by walking
//!   its multi-dimensional `Ng`/`Nop`/`Nopc`/`Nks` loop nest (Eq. 1,
//!   Fig. 4) and applying the four pluggable operators
//!   `pre`/`main`/`reduce`/`post` of §3.1 — enough to cover conv, FC,
//!   pooling, BN, LRN, softmax and their BP/WG forms produced by
//!   [`crate::gconv::lower::lower_network`].
//! * [`chain_exec`] — schedules a whole [`crate::gconv::GconvChain`]:
//!   level-order over the producer/consumer DAG, independent entries and
//!   output/batch slices in parallel via rayon, intermediate buffers
//!   reference-counted and freed at last use.
//!
//! The [`crate::coordinator`] exposes this engine as the default
//! [`crate::coordinator::Backend`] behind its batching request API; the
//! optional PJRT/XLA path (cargo feature `pjrt`) plugs into the same
//! trait.
//!
//! ```
//! use gconv_chain::exec::{ChainExec, Tensor};
//! use gconv_chain::gconv::lower::{lower_network, Mode};
//! use gconv_chain::networks::mobilenet_block;
//!
//! let chain = lower_network(&mobilenet_block(2, 4, 6), Mode::Inference);
//! let mut exec = ChainExec::new(chain); // weights auto-synthesized
//! exec.set_input("data.data", Tensor::rand(&[2, 4, 6, 6], 1, 1.0));
//! let report = exec.run_last().unwrap();
//! assert_eq!(report.outputs[0].elements(), 2 * 8 * 6 * 6);
//! ```

pub mod chain_exec;
pub mod interp;
pub mod tensor;

pub use chain_exec::{ChainExec, EntryRun, RunReport};
pub use interp::{eval_gconv, lut_apply, lut_known};
pub use tensor::Tensor;
