//! Native GCONV execution engine: a pure-Rust, parallel interpreter for
//! GCONV chains.
//!
//! The paper's thesis (§3) is that *every* CNN layer — forward and
//! backward — reduces to a chain of general convolutions, and (§5) that
//! this one form can be processed *efficiently* end-to-end. This module
//! is the executable ground truth for both claims inside the Rust crate
//! itself: no Python, no XLA, no AOT artifacts.
//!
//! * [`tensor`] — a small owned row-major `f32` tensor.
//! * [`interp`] — binds one [`crate::gconv::op::GconvOp`] to tensors
//!   (shape validation, stride precomputation, LUT-name resolution —
//!   including the composed [`crate::gconv::op::StageStack`] pipelines
//!   written by executable operation fusion, §4.3) and evaluates its
//!   multi-dimensional `Ng`/`Nop`/`Nopc`/`Nks` loop nest (Eq. 1,
//!   Fig. 4) with the four pluggable operators `pre`/`main`/`reduce`/
//!   `post` of §3.1 — enough to cover conv, FC, pooling, BN, LRN,
//!   softmax and their BP/WG forms produced by
//!   [`crate::gconv::lower::lower_network`].
//! * `kernels` (internal) — the tiered executors behind [`eval_gconv`]:
//!   a packed-panel dot/GEMM fast path for `Mul`+`Add` reductions
//!   ([`KernelTier::Gemm`]), an odometer-indexed generic fast path
//!   ([`KernelTier::Odometer`]), and the naive per-element oracle
//!   ([`KernelTier::Naive`], reachable via [`eval_gconv_naive`]) kept
//!   for differential testing. All tiers are bit-identical under the
//!   default [`Precision::BitExact`]; [`Precision::Fast`] swaps the
//!   GEMM microkernel for hand-unrolled per-lane `f32` accumulation
//!   bounded by a tolerance differential ([`FAST_REL_TOL`]). GEMM
//!   kernel rows are packed once per bind into a plan-owned slab
//!   (`BoundPlan::prepack`), so steady-state serving never repacks
//!   frozen weights.
//! * `special` (internal) — dedicated routines for chain entries the
//!   loop nest cannot express ([`crate::gconv::chain::SpecialOp`]):
//!   max-pool BP argmax routing (recomputed from the saved forward
//!   input) and channel concatenation.
//! * `pool` (internal impl, public [`BufferPool`]) — size-bucketed
//!   recycling of intermediate buffers across chain levels and runs,
//!   with run-epoch trimming behind [`TrimPolicy`].
//! * [`serve`] — bind-once/run-many serving: [`Session`] freezes a
//!   chain at fixed operand shapes (operand validation, reachability,
//!   level schedule and every entry's plan bound once at construction,
//!   zero rebinds per request) and [`Engine`] adds a chain cache keyed
//!   by (network, batch, fuse) with `Arc`-shared weights plus a queue
//!   that coalesces compatible single-sample requests into micro-batch
//!   runs — bit-identical to per-sample execution, gated on a
//!   cross-sample-coupling probe.
//! * [`chain_exec`] — schedules a whole [`crate::gconv::GconvChain`]:
//!   level-order over the producer/consumer DAG, independent entries and
//!   output/batch slices in parallel via rayon, intermediates
//!   `Arc`-shared, reference-counted and recycled at last use; every
//!   chain-internal operand is shape-checked up front, so a chain that
//!   cannot execute fails at bind time, not mid-run. Chains rewritten
//!   by [`crate::mapping::fuse_executable`] run here directly and stay
//!   bit-identical to their unfused forms.
//! * [`bench`] — the naive-vs-fast and fused-vs-unfused measurement
//!   harness behind `cargo bench --bench native_exec` and
//!   `BENCH_native_exec.json`.
//! * [`faults`] — deterministic fault injection: named sites threaded
//!   through the hot path (`pool.alloc`, `kernels.eval`, `serve.step`,
//!   `scheduler.wave`, `conn.read`), armed by a seeded [`FaultPlan`]
//!   from tests or `serve --faults`; a single relaxed atomic check when
//!   disarmed. The chaos suite (`rust/tests/chaos.rs`) drives the
//!   server's panic isolation, quarantine, and deadline paths with it.
//!
//! Observability ([`crate::obs`]): the engine mirrors its counters into
//! the process-global registry — `gconv_kernel_*_ns` per-tier kernel
//! histograms (armed by `obs::profile()`, one relaxed load when
//! disarmed), `gconv_engine_*` request/batch/coalescing counters and
//! queue-wait histogram, `gconv_session_*` bind/prepack/run counters,
//! and `gconv_pool_*` allocation counters. The per-struct stats
//! ([`EngineStats`], [`SessionStats`], [`PoolStats`]) remain the
//! authoritative per-instance counters.
//!
//! The [`crate::coordinator`] exposes this engine as the default
//! [`crate::coordinator::Backend`] behind its batching request API; the
//! optional PJRT/XLA path (cargo feature `pjrt`) plugs into the same
//! trait.
//!
//! ```
//! use gconv_chain::exec::{ChainExec, Tensor};
//! use gconv_chain::gconv::lower::{lower_network, Mode};
//! use gconv_chain::networks::mobilenet_block;
//!
//! let chain = lower_network(&mobilenet_block(2, 4, 6), Mode::Inference);
//! let mut exec = ChainExec::new(chain); // weights auto-synthesized
//! exec.set_input("data.data", Tensor::rand(&[2, 4, 6, 6], 1, 1.0));
//! let report = exec.run_last().unwrap();
//! assert_eq!(report.outputs[0].elements(), 2 * 8 * 6 * 6);
//! ```

use anyhow::Result;

pub mod bench;
pub mod chain_exec;
pub mod faults;
pub mod interp;
mod kernels;
mod pool;
pub mod serve;
mod special;
pub mod tensor;

pub use chain_exec::{ChainExec, EntryRun, RunReport, TrimPolicy};
pub use faults::{FaultGuard, FaultKind, FaultPlan, FaultRule, Trigger};
pub use interp::{
    eval_gconv, eval_gconv_naive, eval_gconv_with_precision, lut_apply, lut_known, plan_tier,
    LutFn,
};
pub use kernels::{KernelTier, Precision, FAST_REL_TOL, GEMM_MIN_REDUCTION, NC as GEMM_COL_BLOCK};
pub use pool::{BufferPool, PoolStats};
pub use serve::{
    ChainKey, Engine, EngineResponse, EngineStats, Session, SessionBuilder, SessionStats,
    SubmitError,
};
pub use tensor::Tensor;

/// Run `f` on a scoped rayon thread pool of `threads` workers
/// (`threads == 0` keeps the process-global default pool). The CLI's and
/// examples' `--threads` flag routes through this so bench numbers are
/// reproducible on machines with different core counts.
pub fn with_threads<R: Send>(threads: usize, f: impl FnOnce() -> R + Send) -> Result<R> {
    if threads == 0 {
        return Ok(f());
    }
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()?;
    Ok(pool.install(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_threads_scopes_the_pool_size() {
        let seen = with_threads(2, rayon::current_num_threads).unwrap();
        assert_eq!(seen, 2);
    }

    #[test]
    fn with_threads_zero_uses_the_default_pool() {
        let outside = rayon::current_num_threads();
        let seen = with_threads(0, rayon::current_num_threads).unwrap();
        assert_eq!(seen, outside);
    }
}
