//! Naive-vs-fast and fused-vs-unfused measurement harness for the
//! native execution engine.
//!
//! Runs a network's inference chain three ways — the naive per-element
//! oracle, the tiered fast paths, and the fast paths on the chain
//! rewritten by *executable operation fusion* (§4.3,
//! [`crate::mapping::fuse_executable`]) — and aggregates per-layer and
//! end-to-end timings plus bit-identity gates: the unfused fast tiers
//! must match the oracle on every entry, and the fused chain must match
//! the unfused final output bit-for-bit.
//! `rust/benches/native_exec.rs` and the `--bench-json` mode of
//! `examples/native_inference.rs` both drive this module and emit the
//! result as `BENCH_native_exec.json`, the repo's performance-trajectory
//! artifact (CI uploads it on every run). Every numeric JSON field is
//! emitted through a finite-guard: zero-duration timings on tiny layers
//! yield `null`, never `inf`/`NaN`.

use std::collections::HashMap;
use std::fs;

use anyhow::{Context, Result};

use crate::gconv::lower::{lower_network, Mode};
use crate::ir::{Layer, Network};
use crate::mapping::fuse_executable;

use super::chain_exec::{ChainExec, RunReport};
use super::tensor::Tensor;

/// `num / den` when both sides are positive and the ratio is finite;
/// `None` otherwise (sub-resolution timings on tiny layers can measure
/// exactly zero).
fn finite_ratio(num: f64, den: f64) -> Option<f64> {
    if num > 0.0 && den > 0.0 {
        let r = num / den;
        r.is_finite().then_some(r)
    } else {
        None
    }
}

/// Per-layer aggregation of one naive-vs-fast comparison (chain entries
/// grouped by the op-name prefix before the phase suffix, so
/// `"bn3.FP2"` rolls up into layer `"bn3"`).
#[derive(Clone, Debug)]
pub struct LayerBench {
    /// Layer name.
    pub layer: String,
    /// GCONV entries in the layer.
    pub gconvs: usize,
    /// `main` operations per chain run.
    pub work: usize,
    /// Seconds in the layer, naive oracle.
    pub naive_s: f64,
    /// Seconds in the layer, fast tiers.
    pub fast_s: f64,
}

impl LayerBench {
    /// Naive-to-fast speedup for this layer; `None` when either timing
    /// is zero or the ratio is non-finite.
    pub fn speedup(&self) -> Option<f64> {
        finite_ratio(self.naive_s, self.fast_s)
    }
}

/// One network's end-to-end naive-vs-fast-vs-fused measurement.
#[derive(Clone, Debug)]
pub struct NetBench {
    /// Network name (e.g. `"MobileNet"`).
    pub net: String,
    /// Mini-batch size of the lowered chain.
    pub batch: usize,
    /// GCONV entries executed (unfused chain).
    pub entries: usize,
    /// Total `main` operations per unfused chain run.
    pub work: usize,
    /// End-to-end seconds, naive oracle.
    pub naive_s: f64,
    /// End-to-end seconds, fast tiers (best measured run).
    pub fast_s: f64,
    /// GCONV entries executed on the fused chain.
    pub fused_entries: usize,
    /// End-to-end seconds, fused chain on the fast tiers (best run).
    pub fused_s: f64,
    /// Whether the unfused fast path matched the oracle bit-for-bit on
    /// every chain entry.
    pub bit_identical: bool,
    /// Whether the fused chain's final output matched the unfused one
    /// bit-for-bit.
    pub fused_bit_identical: bool,
    /// Per-layer breakdown (unfused chain).
    pub layers: Vec<LayerBench>,
}

impl NetBench {
    /// End-to-end naive-to-fast speedup (`None` on zero timings).
    pub fn speedup(&self) -> Option<f64> {
        finite_ratio(self.naive_s, self.fast_s)
    }

    /// End-to-end fusion speedup: unfused-fast over fused-fast.
    pub fn fusion_speedup(&self) -> Option<f64> {
        finite_ratio(self.fast_s, self.fused_s)
    }

    /// Fractional chain-length reduction from executable fusion.
    pub fn chain_reduction(&self) -> f64 {
        1.0 - self.fused_entries as f64 / self.entries.max(1) as f64
    }

    /// Giga `main`-operations per second on the naive oracle.
    pub fn naive_gops(&self) -> f64 {
        gops(self.work, self.naive_s)
    }

    /// Giga `main`-operations per second on the fast tiers.
    pub fn fast_gops(&self) -> f64 {
        gops(self.work, self.fast_s)
    }

    /// Effective giga-ops per second of the fused chain, counted in
    /// *unfused* work (the workload semantics are identical, fusion just
    /// executes it in fewer ops).
    pub fn fused_gops(&self) -> f64 {
        gops(self.work, self.fused_s)
    }
}

fn gops(work: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        work as f64 / seconds / 1e9
    } else {
        0.0
    }
}

/// Input operand name and batched shape of a network's `Input` layer
/// (the operand the lowering emits as `"<name>.data"`).
pub fn input_spec(net: &Network) -> Result<(String, Vec<usize>)> {
    let input = net
        .nodes()
        .iter()
        .find(|n| matches!(n.layer, Layer::Input { .. }))
        .context("network has no Input layer")?;
    let dims: Vec<usize> = input.output.iter().map(|(_, n)| n).collect();
    Ok((format!("{}.data", input.name), dims))
}

/// Lower `net` for inference and measure its FP chain end-to-end: the
/// naive oracle once (it is the slow side), then the fast tiers
/// `fast_runs` times on the unfused chain and again on the
/// executable-fused chain (the first run warms each buffer pool; the
/// best run is kept). Gates: the unfused fast path must match the
/// oracle on *every* retained entry, and the fused final output must
/// match the unfused one — both bit-for-bit. Weights are synthesized
/// deterministically; the input is a fixed pseudo-random tensor,
/// identical on all paths.
pub fn bench_network(net: &Network, fast_runs: usize) -> Result<NetBench> {
    let (input_name, dims) = input_spec(net)?;
    let x = Tensor::rand(&dims, 0xBE7C_4A11, 1.0);

    let naive_chain = lower_network(net, Mode::Inference);
    let all: Vec<usize> = (0..naive_chain.len()).collect();
    let mut naive = ChainExec::new(naive_chain).with_naive_oracle();
    naive.set_input(&input_name, x.clone());
    let naive_report = naive.run_last()?;

    let fast_chain = lower_network(net, Mode::Inference);
    let mut fast = ChainExec::new(fast_chain);
    fast.set_input(&input_name, x.clone());
    let mut fast_report = fast.run_last()?;
    for _ in 1..fast_runs.max(1) {
        let r = fast.run_last()?;
        if r.total_s < fast_report.total_s {
            fast_report = r;
        }
    }

    // Executable fusion: shorter chain, same synthesized operands, same
    // final numbers (the rewrite is semantics-preserving by legality).
    let mut fused_chain = lower_network(net, Mode::Inference);
    fuse_executable(&mut fused_chain);
    let mut fused = ChainExec::new(fused_chain);
    fused.set_input(&input_name, x);
    let mut fused_report = fused.run_last()?;
    for _ in 1..fast_runs.max(1) {
        let r = fused.run_last()?;
        if r.total_s < fused_report.total_s {
            fused_report = r;
        }
    }
    let fused_bit_identical = fused_report.outputs[0].bit_eq(&fast_report.outputs[0]);

    // Untimed differential gate: *every* chain entry must match the
    // oracle bit-for-bit, not just the final network output.
    let dn = naive.run(&all)?;
    let df = fast.run(&all)?;
    let mut bit_identical = df.outputs.len() == dn.outputs.len();
    for (a, b) in df.outputs.iter().zip(&dn.outputs) {
        bit_identical &= a.bit_eq(b);
    }
    Ok(NetBench {
        net: net.name.clone(),
        batch: dims[0],
        entries: fast_report.entries.len(),
        work: fast_report.total_work(),
        naive_s: naive_report.total_s,
        fast_s: fast_report.total_s,
        fused_entries: fused_report.entries.len(),
        fused_s: fused_report.total_s,
        bit_identical,
        fused_bit_identical,
        layers: layer_rows(&naive_report, &fast_report),
    })
}

/// Merge two reports of the same chain into per-layer rows (paired by
/// chain-entry index, so differing retention sets cannot misalign).
fn layer_rows(naive: &RunReport, fast: &RunReport) -> Vec<LayerBench> {
    let mut naive_secs = HashMap::new();
    for ne in &naive.entries {
        naive_secs.insert(ne.index, ne.seconds);
    }
    let mut rows: Vec<LayerBench> = Vec::new();
    for fe in &fast.entries {
        let layer = layer_of(&fe.name);
        let ns = naive_secs.get(&fe.index).copied().unwrap_or(0.0);
        match rows.last_mut() {
            Some(row) if row.layer == layer => {
                row.gconvs += 1;
                row.work += fe.work;
                row.naive_s += ns;
                row.fast_s += fe.seconds;
            }
            _ => rows.push(LayerBench {
                layer,
                gconvs: 1,
                work: fe.work,
                naive_s: ns,
                fast_s: fe.seconds,
            }),
        }
    }
    rows
}

/// Layer name of a chain-entry name (`"bn3.FP2"` → `"bn3"`).
fn layer_of(name: &str) -> String {
    name.split('.').next().unwrap_or(name).to_string()
}

/// A float as a JSON number with `prec` decimals, or `null` when it is
/// not finite — the emitter-level gate against `inf`/`NaN` in the
/// artifact.
fn jnum(v: f64, prec: usize) -> String {
    if v.is_finite() {
        format!("{v:.prec$}")
    } else {
        "null".to_string()
    }
}

/// An optional ratio as a JSON number or `null`.
fn jopt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => jnum(x, prec),
        None => "null".to_string(),
    }
}

/// Render measurements as the `BENCH_native_exec.json` document.
pub fn to_json(benches: &[NetBench], threads: usize) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"native_exec\",\n");
    s.push_str(&format!("  \"threads\": {threads},\n"));
    s.push_str("  \"networks\": [\n");
    for (bi, b) in benches.iter().enumerate() {
        s.push_str("    {\n");
        s.push_str(&format!("      \"net\": \"{}\",\n", esc(&b.net)));
        s.push_str(&format!("      \"batch\": {},\n", b.batch));
        s.push_str(&format!("      \"entries\": {},\n", b.entries));
        s.push_str(&format!("      \"work\": {},\n", b.work));
        s.push_str(&format!(
            "      \"naive\": {{\"seconds\": {}, \"gops\": {}}},\n",
            jnum(b.naive_s, 6),
            jnum(b.naive_gops(), 3)
        ));
        s.push_str(&format!(
            "      \"fast\": {{\"seconds\": {}, \"gops\": {}}},\n",
            jnum(b.fast_s, 6),
            jnum(b.fast_gops(), 3)
        ));
        s.push_str(&format!(
            "      \"fused\": {{\"seconds\": {}, \"gops\": {}, \"entries\": {}, \
             \"speedup_vs_fast\": {}, \"bit_identical\": {}}},\n",
            jnum(b.fused_s, 6),
            jnum(b.fused_gops(), 3),
            b.fused_entries,
            jopt(b.fusion_speedup(), 3),
            b.fused_bit_identical
        ));
        s.push_str(&format!(
            "      \"chain_reduction\": {},\n",
            jnum(b.chain_reduction(), 3)
        ));
        s.push_str(&format!("      \"speedup\": {},\n", jopt(b.speedup(), 3)));
        let bits = b.bit_identical;
        s.push_str(&format!("      \"bit_identical\": {bits},\n"));
        s.push_str("      \"layers\": [\n");
        for (li, l) in b.layers.iter().enumerate() {
            let sep = if li + 1 < b.layers.len() { "," } else { "" };
            s.push_str(&format!(
                "        {{\"layer\": \"{}\", \"gconvs\": {}, \"work\": {}, \
                 \"naive_s\": {}, \"fast_s\": {}, \"speedup\": {}}}{}\n",
                esc(&l.layer),
                l.gconvs,
                l.work,
                jnum(l.naive_s, 6),
                jnum(l.fast_s, 6),
                jopt(l.speedup(), 3),
                sep
            ));
        }
        s.push_str("      ]\n");
        let sep = if bi + 1 < benches.len() { "," } else { "" };
        s.push_str(&format!("    }}{sep}\n"));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

/// Write the JSON document to `path`.
pub fn write_json(path: &str, benches: &[NetBench], threads: usize) -> Result<()> {
    let json = to_json(benches, threads);
    fs::write(path, json).with_context(|| format!("writing {path}"))?;
    Ok(())
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::networks::mobilenet_block;

    #[test]
    fn block_bench_is_bit_identical_and_json_renders() {
        let net = mobilenet_block(2, 4, 6);
        let b = bench_network(&net, 2).unwrap();
        assert!(b.bit_identical, "fast paths must match the oracle");
        assert!(b.fused_bit_identical, "fusion must preserve the final output");
        assert!(b.fused_entries < b.entries, "the block's ReLUs must fuse away");
        assert!(b.chain_reduction() > 0.0);
        assert_eq!(b.batch, 2);
        assert!(b.entries > 0 && b.work > 0);
        assert!(!b.layers.is_empty());
        let gconvs: usize = b.layers.iter().map(|l| l.gconvs).sum();
        assert_eq!(gconvs, b.entries);
        let json = to_json(&[b], 2);
        assert!(json.contains("\"bench\": \"native_exec\""));
        assert!(json.contains("\"net\": \"MobileNetBlock\""));
        assert!(json.contains("\"bit_identical\": true"));
        assert!(json.contains("\"fused\""));
        assert!(json.contains("\"chain_reduction\""));
        assert!(!json.contains("inf") && !json.to_lowercase().contains("nan"));
        assert!(json.trim_end().ends_with('}'));
    }

    #[test]
    fn zero_timings_emit_null_not_inf() {
        let b = NetBench {
            net: "tiny".into(),
            batch: 1,
            entries: 1,
            work: 10,
            naive_s: 0.0,
            fast_s: 0.0,
            fused_entries: 1,
            fused_s: 0.0,
            bit_identical: true,
            fused_bit_identical: true,
            layers: vec![LayerBench {
                layer: "l".into(),
                gconvs: 1,
                work: 10,
                naive_s: 1.0,
                fast_s: 0.0,
            }],
        };
        assert_eq!(b.speedup(), None);
        assert_eq!(b.fusion_speedup(), None);
        assert_eq!(b.layers[0].speedup(), None);
        let json = to_json(&[b], 1);
        assert!(json.contains("\"speedup\": null"));
        assert!(!json.contains("inf") && !json.to_lowercase().contains("nan"));
    }

    #[test]
    fn esc_escapes_quotes_and_backslashes() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
    }
}
