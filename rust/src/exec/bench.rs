//! Naive-vs-fast and fused-vs-unfused measurement harness for the
//! native execution engine.
//!
//! Runs a network's inference chain four ways — the naive per-element
//! oracle, the tiered fast paths, the fast paths on the chain
//! rewritten by *executable operation fusion* (§4.3,
//! [`crate::mapping::fuse_executable`]), and the fast paths again
//! under [`Precision::Fast`] (the unrolled SIMD GEMM microkernel) —
//! and aggregates per-layer and end-to-end timings plus the gates: the
//! unfused fast tiers must match the oracle on every entry, the fused
//! chain must match the unfused final output bit-for-bit, and the
//! `Precision::Fast` output must stay within the [`FAST_REL_TOL`]
//! relative-error differential of the bit-exact output.
//! `rust/benches/native_exec.rs` and the `--bench-json` mode of
//! `examples/native_inference.rs` both drive this module and emit the
//! result as `BENCH_native_exec.json`, the repo's performance-trajectory
//! artifact (CI uploads it on every run). Every numeric JSON field is
//! emitted through a finite-guard: zero-duration timings on tiny layers
//! yield `null`, never `inf`/`NaN`.

use std::collections::HashMap;
use std::fs;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::gconv::lower::{lower_network, Mode};
use crate::ir::{Layer, Network};
use crate::mapping::fuse_executable;
use crate::networks::benchmark_with_batch;
use crate::server::{self, Backoff, Client, ErrorCode, Response, ServerConfig};

use super::chain_exec::{ChainExec, RunReport};
use super::faults::{self, FaultKind, FaultPlan, FaultRule, Trigger};
use super::kernels::{Precision, FAST_REL_TOL};
use super::serve::{Engine, Session};
use super::tensor::Tensor;

/// `num / den` when both sides are positive and the ratio is finite;
/// `None` otherwise (sub-resolution timings on tiny layers can measure
/// exactly zero).
fn finite_ratio(num: f64, den: f64) -> Option<f64> {
    if num > 0.0 && den > 0.0 {
        let r = num / den;
        r.is_finite().then_some(r)
    } else {
        None
    }
}

/// Per-layer aggregation of one naive-vs-fast comparison (chain entries
/// grouped by the op-name prefix before the phase suffix, so
/// `"bn3.FP2"` rolls up into layer `"bn3"`).
#[derive(Clone, Debug)]
pub struct LayerBench {
    /// Layer name.
    pub layer: String,
    /// GCONV entries in the layer.
    pub gconvs: usize,
    /// `main` operations per chain run.
    pub work: usize,
    /// Seconds in the layer, naive oracle.
    pub naive_s: f64,
    /// Seconds in the layer, fast tiers.
    pub fast_s: f64,
}

impl LayerBench {
    /// Naive-to-fast speedup for this layer; `None` when either timing
    /// is zero or the ratio is non-finite.
    pub fn speedup(&self) -> Option<f64> {
        finite_ratio(self.naive_s, self.fast_s)
    }
}

/// One network's end-to-end naive-vs-fast-vs-fused measurement.
#[derive(Clone, Debug)]
pub struct NetBench {
    /// Network name (e.g. `"MobileNet"`).
    pub net: String,
    /// Mini-batch size of the lowered chain.
    pub batch: usize,
    /// GCONV entries executed (unfused chain).
    pub entries: usize,
    /// Total `main` operations per unfused chain run.
    pub work: usize,
    /// End-to-end seconds, naive oracle.
    pub naive_s: f64,
    /// End-to-end seconds, fast tiers (best measured run).
    pub fast_s: f64,
    /// GCONV entries executed on the fused chain.
    pub fused_entries: usize,
    /// End-to-end seconds, fused chain on the fast tiers (best run).
    pub fused_s: f64,
    /// Whether the unfused fast path matched the oracle bit-for-bit on
    /// every chain entry.
    pub bit_identical: bool,
    /// Whether the fused chain's final output matched the unfused one
    /// bit-for-bit.
    pub fused_bit_identical: bool,
    /// End-to-end seconds, unfused chain under [`Precision::Fast`]
    /// (best measured run).
    pub fastp_s: f64,
    /// Max per-element relative error of the `Precision::Fast` output
    /// against the bit-exact fast output (guarded by
    /// `max(|exact|, 1)`).
    pub fastp_max_rel_err: f64,
    /// Whether `fastp_max_rel_err` stayed within [`FAST_REL_TOL`].
    pub fastp_within_tol: bool,
    /// Per-layer breakdown (unfused chain).
    pub layers: Vec<LayerBench>,
}

impl NetBench {
    /// End-to-end naive-to-fast speedup (`None` on zero timings).
    pub fn speedup(&self) -> Option<f64> {
        finite_ratio(self.naive_s, self.fast_s)
    }

    /// End-to-end fusion speedup: unfused-fast over fused-fast.
    pub fn fusion_speedup(&self) -> Option<f64> {
        finite_ratio(self.fast_s, self.fused_s)
    }

    /// Fractional chain-length reduction from executable fusion.
    pub fn chain_reduction(&self) -> f64 {
        1.0 - self.fused_entries as f64 / self.entries.max(1) as f64
    }

    /// Giga `main`-operations per second on the naive oracle.
    pub fn naive_gops(&self) -> f64 {
        gops(self.work, self.naive_s)
    }

    /// Giga `main`-operations per second on the fast tiers.
    pub fn fast_gops(&self) -> f64 {
        gops(self.work, self.fast_s)
    }

    /// Effective giga-ops per second of the fused chain, counted in
    /// *unfused* work (the workload semantics are identical, fusion just
    /// executes it in fewer ops).
    pub fn fused_gops(&self) -> f64 {
        gops(self.work, self.fused_s)
    }

    /// Giga `main`-operations per second under [`Precision::Fast`].
    pub fn fastp_gops(&self) -> f64 {
        gops(self.work, self.fastp_s)
    }

    /// Speedup of the `Precision::Fast` microkernel over the bit-exact
    /// fast tiers on the same unfused chain.
    pub fn fastp_speedup(&self) -> Option<f64> {
        finite_ratio(self.fast_s, self.fastp_s)
    }
}

fn gops(work: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        work as f64 / seconds / 1e9
    } else {
        0.0
    }
}

/// Input operand name and batched shape of a network's `Input` layer
/// (the operand the lowering emits as `"<name>.data"`).
pub fn input_spec(net: &Network) -> Result<(String, Vec<usize>)> {
    let input = net
        .nodes()
        .iter()
        .find(|n| matches!(n.layer, Layer::Input { .. }))
        .context("network has no Input layer")?;
    let dims: Vec<usize> = input.output.iter().map(|(_, n)| n).collect();
    Ok((format!("{}.data", input.name), dims))
}

/// Lower `net` for inference and measure its FP chain end-to-end: the
/// naive oracle once (it is the slow side), then the fast tiers
/// `fast_runs` times on the unfused chain and again on the
/// executable-fused chain (the first run warms each buffer pool; the
/// best run is kept). Gates: the unfused fast path must match the
/// oracle on *every* retained entry, and the fused final output must
/// match the unfused one — both bit-for-bit. Weights are synthesized
/// deterministically; the input is a fixed pseudo-random tensor,
/// identical on all paths.
pub fn bench_network(net: &Network, fast_runs: usize) -> Result<NetBench> {
    let (input_name, dims) = input_spec(net)?;
    let x = Tensor::rand(&dims, 0xBE7C_4A11, 1.0);

    let naive_chain = lower_network(net, Mode::Inference);
    let all: Vec<usize> = (0..naive_chain.len()).collect();
    let mut naive = ChainExec::new(naive_chain).with_naive_oracle();
    naive.set_input(&input_name, x.clone());
    let naive_report = naive.run_last()?;

    let fast_chain = lower_network(net, Mode::Inference);
    let mut fast = ChainExec::new(fast_chain);
    fast.set_input(&input_name, x.clone());
    let mut fast_report = fast.run_last()?;
    for _ in 1..fast_runs.max(1) {
        let r = fast.run_last()?;
        if r.total_s < fast_report.total_s {
            fast_report = r;
        }
    }

    // Executable fusion: shorter chain, same synthesized operands, same
    // final numbers (the rewrite is semantics-preserving by legality).
    let mut fused_chain = lower_network(net, Mode::Inference);
    fuse_executable(&mut fused_chain);
    let mut fused = ChainExec::new(fused_chain);
    fused.set_input(&input_name, x.clone());
    let mut fused_report = fused.run_last()?;
    for _ in 1..fast_runs.max(1) {
        let r = fused.run_last()?;
        if r.total_s < fused_report.total_s {
            fused_report = r;
        }
    }
    let fused_bit_identical = fused_report.outputs[0].bit_eq(&fast_report.outputs[0]);

    // Precision::Fast: the unfused chain once more on the unrolled SIMD
    // GEMM microkernel. Timed like the fast leg; gated by the
    // relative-error differential against the bit-exact output instead
    // of bit identity (the lane split changes summation order).
    let mut fastp = ChainExec::new(lower_network(net, Mode::Inference))
        .with_precision(Precision::Fast);
    fastp.set_input(&input_name, x);
    let mut fastp_report = fastp.run_last()?;
    for _ in 1..fast_runs.max(1) {
        let r = fastp.run_last()?;
        if r.total_s < fastp_report.total_s {
            fastp_report = r;
        }
    }
    let mut fastp_max_rel_err = 0.0f64;
    for (a, b) in fastp_report.outputs[0].data().iter().zip(fast_report.outputs[0].data()) {
        let rel = f64::from((a - b).abs()) / f64::from(b.abs()).max(1.0);
        fastp_max_rel_err = fastp_max_rel_err.max(rel);
    }
    let fastp_within_tol = fastp_max_rel_err <= f64::from(FAST_REL_TOL);

    // Untimed differential gate: *every* chain entry must match the
    // oracle bit-for-bit, not just the final network output.
    let dn = naive.run(&all)?;
    let df = fast.run(&all)?;
    let mut bit_identical = df.outputs.len() == dn.outputs.len();
    for (a, b) in df.outputs.iter().zip(&dn.outputs) {
        bit_identical &= a.bit_eq(b);
    }
    Ok(NetBench {
        net: net.name.clone(),
        batch: dims[0],
        entries: fast_report.entries.len(),
        work: fast_report.total_work(),
        naive_s: naive_report.total_s,
        fast_s: fast_report.total_s,
        fused_entries: fused_report.entries.len(),
        fused_s: fused_report.total_s,
        bit_identical,
        fused_bit_identical,
        fastp_s: fastp_report.total_s,
        fastp_max_rel_err,
        fastp_within_tol,
        layers: layer_rows(&naive_report, &fast_report),
    })
}

/// Merge two reports of the same chain into per-layer rows (paired by
/// chain-entry index, so differing retention sets cannot misalign).
fn layer_rows(naive: &RunReport, fast: &RunReport) -> Vec<LayerBench> {
    let mut naive_secs = HashMap::new();
    for ne in &naive.entries {
        naive_secs.insert(ne.index, ne.seconds);
    }
    let mut rows: Vec<LayerBench> = Vec::new();
    for fe in &fast.entries {
        let layer = layer_of(&fe.name);
        let ns = naive_secs.get(&fe.index).copied().unwrap_or(0.0);
        match rows.last_mut() {
            Some(row) if row.layer == layer => {
                row.gconvs += 1;
                row.work += fe.work;
                row.naive_s += ns;
                row.fast_s += fe.seconds;
            }
            _ => rows.push(LayerBench {
                layer,
                gconvs: 1,
                work: fe.work,
                naive_s: ns,
                fast_s: fe.seconds,
            }),
        }
    }
    rows
}

/// Layer name of a chain-entry name (`"bn3.FP2"` → `"bn3"`).
fn layer_of(name: &str) -> String {
    name.split('.').next().unwrap_or(name).to_string()
}


/// One network's serve-mode measurement: the same request stream
/// through (a) a fresh [`ChainExec`] per request — the one-shot calling
/// convention a deployment without sessions pays, re-synthesizing,
/// re-validating and re-binding everything per request — (b) one
/// reused [`Session`], and (c) the [`Engine`] with its chain cache and
/// coalescing queue.
#[derive(Clone, Debug)]
pub struct ServeBench {
    /// Network code.
    pub net: String,
    /// Requests served on each path.
    pub requests: usize,
    /// Total seconds, fresh `ChainExec` per request.
    pub per_request_s: f64,
    /// `Plan` binds performed by the per-request path.
    pub per_request_binds: usize,
    /// Total seconds, one warmed session.
    pub session_s: f64,
    /// `Plan` binds performed by the session (all at construction).
    pub session_binds: usize,
    /// Median per-request session latency (seconds).
    pub p50_s: f64,
    /// 99th-percentile per-request session latency (seconds).
    pub p99_s: f64,
    /// Total seconds through the engine (queue + cache + coalescing).
    pub engine_s: f64,
    /// Micro-batches the engine executed.
    pub engine_batches: usize,
    /// Whether session and engine outputs matched the per-request
    /// outputs bit-for-bit on every request.
    pub bit_identical: bool,
    /// The network-serving leg: the same request stream again, but
    /// over loopback TCP from concurrent clients (`None` when the
    /// load leg was skipped with `clients == 0`).
    pub load: Option<LoadBench>,
    /// The degraded-mode leg: the load stream once more with the
    /// fault-injection registry armed at [`DEGRADED_FAULT_RATE`]
    /// (`None` unless requested).
    pub degraded: Option<DegradedBench>,
}

/// Injected-failure probability of the degraded serving leg: each
/// per-model wave group fails (gracefully, `INTERNAL`) with this
/// probability.
pub const DEGRADED_FAULT_RATE: f64 = 0.01;

/// Throughput/latency of the serving front *while faults are being
/// injected* — the self-healing overhead measured against the clean
/// [`LoadBench`]: how much rps/p99 degrade when
/// [`DEGRADED_FAULT_RATE`] of wave groups fail and the supervisor
/// purges/rebuilds engine state.
#[derive(Clone, Debug)]
pub struct DegradedBench {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests attempted across all clients.
    pub requests: usize,
    /// Requests answered with an output frame.
    pub completed: usize,
    /// Requests absorbed as injected `INTERNAL` failures.
    pub injected_errors: u64,
    /// `BUSY` rejections absorbed (and retried) by the clients.
    pub busy_rejections: u64,
    /// Wall seconds from first connect to last response.
    pub seconds: f64,
    /// Median end-to-end latency of *successful* requests (seconds).
    pub p50_s: f64,
    /// 99th-percentile end-to-end latency of successful requests.
    pub p99_s: f64,
    /// Whether every successful response matched the per-request path
    /// bit-for-bit (injection must never corrupt numerics, only fail
    /// requests).
    pub bit_identical: bool,
}

impl DegradedBench {
    /// Successful requests per second across all clients.
    pub fn rps(&self) -> f64 {
        rps(self.completed, self.seconds)
    }
}

/// Nearest-rank p50/p99 of one per-request serving stage, in
/// nanoseconds (bucket upper bounds of the server's log-scale stage
/// histograms).
#[derive(Clone, Copy, Debug)]
pub struct StageQuantiles {
    /// Median stage latency (bucket-quantized nanoseconds).
    pub p50_ns: u64,
    /// 99th-percentile stage latency (bucket-quantized nanoseconds).
    pub p99_ns: u64,
}

/// Where a request's time went during the load leg, stage by stage:
/// socket read (first byte to full frame), scheduler queue wait,
/// engine evaluation, reply write. Read from the server's live
/// registry before shutdown.
#[derive(Clone, Copy, Debug)]
pub struct StageProfile {
    /// Frame read stage (`gconv_read_ns`).
    pub read: StageQuantiles,
    /// Queue wait stage (`gconv_queue_wait_ns`).
    pub queue: StageQuantiles,
    /// Engine evaluation stage (`gconv_eval_ns`).
    pub eval: StageQuantiles,
    /// Reply write stage (`gconv_write_ns`).
    pub write: StageQuantiles,
}

/// Concurrent-load measurement over the TCP serving front
/// ([`crate::server::serve`]): `clients` connections on loopback send
/// the bench request stream through the bounded scheduler queue and
/// the engine driver, retrying `BUSY` rejections.
#[derive(Clone, Debug)]
pub struct LoadBench {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests completed across all clients.
    pub requests: usize,
    /// `BUSY` rejections absorbed (and retried) by the clients.
    pub busy_rejections: u64,
    /// Wall seconds from first connect to last response.
    pub seconds: f64,
    /// Median end-to-end request latency (seconds, over the wire).
    pub p50_s: f64,
    /// 99th-percentile end-to-end request latency (seconds).
    pub p99_s: f64,
    /// Requests that rode a coalesced micro-batch (size > 1).
    pub coalesced: usize,
    /// Micro-batches the server's engine executed.
    pub batches: usize,
    /// High-water mark of the bounded submission queue.
    pub max_queue_depth: usize,
    /// Whether every wire response matched the per-request path
    /// bit-for-bit.
    pub bit_identical: bool,
    /// Per-stage latency quantiles of the leg (read / queue wait /
    /// eval / write), from the server's stage histograms.
    pub profile: StageProfile,
}

impl LoadBench {
    /// Requests per second across all clients.
    pub fn rps(&self) -> f64 {
        rps(self.requests, self.seconds)
    }

    /// Fraction of requests that rode a coalesced micro-batch.
    pub fn coalescing_rate(&self) -> Option<f64> {
        finite_ratio(self.coalesced as f64, self.requests as f64)
    }
}

impl ServeBench {
    /// Requests per second, per-request path.
    pub fn per_request_rps(&self) -> f64 {
        rps(self.requests, self.per_request_s)
    }

    /// Requests per second, session path.
    pub fn session_rps(&self) -> f64 {
        rps(self.requests, self.session_s)
    }

    /// Requests per second, engine path.
    pub fn engine_rps(&self) -> f64 {
        rps(self.requests, self.engine_s)
    }

    /// Steady-state throughput of session reuse over the per-request
    /// calling convention.
    pub fn speedup(&self) -> Option<f64> {
        finite_ratio(self.per_request_s, self.session_s)
    }

    /// How many binds the one-shot path paid per bind the session
    /// paid: `requests × entries` versus one construction's worth.
    pub fn bind_amortization(&self) -> Option<f64> {
        finite_ratio(self.per_request_binds as f64, self.session_binds as f64)
    }
}

fn rps(requests: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        requests as f64 / seconds
    } else {
        0.0
    }
}

/// Nearest-rank percentile of an ascending-sorted latency slice:
/// `sorted[len * p / 100]`, clamped to the last element; `0.0` on an
/// empty slice. Every serving leg (session, load, degraded) reports
/// through this one convention.
fn percentile(sorted: &[f64], p: usize) -> f64 {
    if sorted.is_empty() {
        0.0
    } else {
        sorted[(sorted.len() * p / 100).min(sorted.len() - 1)]
    }
}

/// Measure steady-state serving of `code`'s FP chain at batch 1 (see
/// [`ServeBench`]). All paths see the same deterministic request
/// stream and synthesized weights; outputs are gated bit-identical.
/// With `clients > 0` a fourth leg drives the stream over loopback TCP
/// from that many concurrent connections (see [`LoadBench`]); with
/// `degraded` also set, a fifth leg repeats it with the fault registry
/// armed at [`DEGRADED_FAULT_RATE`] (see [`DegradedBench`]).
pub fn bench_serve(
    code: &str,
    requests: usize,
    max_batch: usize,
    clients: usize,
    degraded: bool,
) -> Result<ServeBench> {
    ensure!(requests > 0, "serve bench needs at least one request");
    let net = benchmark_with_batch(code, 1);
    let (input_name, dims) = input_spec(&net)?;
    let chain = lower_network(&net, Mode::Inference);
    let inputs: Vec<Tensor> = (0..requests)
        .map(|i| Tensor::rand(&dims, 0x5E21_BEEF ^ i as u64, 1.0))
        .collect();

    // (a) per-request: construct, synthesize, validate, bind, run —
    // every request.
    let mut per_outputs: Vec<Tensor> = Vec::with_capacity(requests);
    let mut per_request_binds = 0usize;
    let t0 = Instant::now();
    for x in &inputs {
        let mut exec = ChainExec::new(chain.clone());
        exec.set_input(&input_name, x.clone());
        let mut report = exec.run_last()?;
        per_request_binds += exec.bind_calls();
        let out = report.outputs.remove(0);
        per_outputs.push((*out).clone());
    }
    let per_request_s = t0.elapsed().as_secs_f64();

    // (b) session: bind once, run many. One warm-up run fills the
    // buffer pool; the timed loop is the steady state.
    let mut session = Session::builder(chain)
        .input(&input_name, Tensor::zeros(&dims))
        .build()?;
    session.set_input(&input_name, inputs[0].clone())?;
    let warm = session.run()?;
    session.recycle(warm);
    let mut bit_identical = true;
    let mut latencies = Vec::with_capacity(requests);
    let t1 = Instant::now();
    for (i, x) in inputs.iter().enumerate() {
        let t = Instant::now();
        session.set_input(&input_name, x.clone())?;
        let mut report = session.run()?;
        latencies.push(t.elapsed().as_secs_f64());
        let out = report.outputs.remove(0);
        bit_identical &= out.bit_eq(&per_outputs[i]);
        session.recycle_outputs(vec![out]);
    }
    let session_s = t1.elapsed().as_secs_f64();
    let session_binds = session.stats().plan_binds;
    latencies.sort_by(f64::total_cmp);
    let p50_s = percentile(&latencies, 50);
    let p99_s = percentile(&latencies, 99);

    // (c) engine: same stream through the queue/cache front end. The
    // one-time costs (network resolution, the batch-2 coalescing
    // probe, lazy session construction) are warmed up outside the
    // timed window, symmetric with the session leg above.
    let mut engine = Engine::new(max_batch);
    engine.submit(code, u64::MAX, inputs[0].data().to_vec())?;
    ensure!(engine.drain()?.len() == 1, "engine warm-up dropped its request");
    let warm_batches = engine.stats().batches;
    let t2 = Instant::now();
    for (i, x) in inputs.iter().enumerate() {
        engine.submit(code, i as u64, x.data().to_vec())?;
    }
    let mut responses = engine.drain()?;
    let engine_s = t2.elapsed().as_secs_f64();
    responses.sort_by_key(|r| r.id);
    ensure!(responses.len() == requests, "engine dropped requests");
    for (i, r) in responses.iter().enumerate() {
        let want = per_outputs[i].data();
        bit_identical &= r.data.len() == want.len()
            && r.data.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
    }

    // (d) network serving: the same stream once more, over loopback
    // TCP from concurrent client connections.
    let load = if clients > 0 {
        Some(bench_load(code, clients, &inputs, &dims, &per_outputs, max_batch)?)
    } else {
        None
    };

    // (e) degraded serving: the load stream again with the fault
    // registry armed — measures what self-healing costs under load.
    let deg = if degraded && clients > 0 {
        Some(bench_degraded(code, clients, &inputs, &dims, &per_outputs, max_batch)?)
    } else {
        None
    };

    Ok(ServeBench {
        net: net.name.clone(),
        requests,
        per_request_s,
        per_request_binds,
        session_s,
        session_binds,
        p50_s,
        p99_s,
        engine_s,
        engine_batches: engine.stats().batches - warm_batches,
        bit_identical,
        load,
        degraded: deg,
    })
}

/// The multi-client load leg of [`bench_serve`]: serve a fresh engine
/// on an ephemeral loopback port, fan the request stream across
/// `clients` concurrent connections (`BUSY` rejections are retried),
/// and pin every wire response bit-identical to the per-request path.
fn bench_load(
    code: &str,
    clients: usize,
    inputs: &[Tensor],
    dims: &[usize],
    reference: &[Tensor],
    max_batch: usize,
) -> Result<LoadBench> {
    let requests = inputs.len();
    let mut engine = Engine::new(max_batch);
    // Warm the chain cache so the timed window measures serving, not
    // one-time lowering — symmetric with the session and engine legs.
    engine.submit(code, u64::MAX, inputs[0].data().to_vec())?;
    ensure!(engine.drain()?.len() == 1, "load warm-up dropped its request");
    let warm = engine.stats();
    let config = ServerConfig {
        queue_depth: max_batch.max(clients),
        ..ServerConfig::default()
    };
    let handle = server::serve("127.0.0.1:0", engine, config)?;
    let addr = handle.addr().to_string();
    let sample_dims = &dims[1..];
    let t0 = Instant::now();
    let joined = std::thread::scope(|scope| {
        let mut workers = Vec::with_capacity(clients);
        for c in 0..clients {
            let addr = addr.clone();
            workers.push(scope.spawn(move || -> Result<(Vec<(usize, Vec<f32>, f64)>, u32)> {
                let mut client = Client::connect_retry(&addr, Duration::from_secs(10))?;
                let mut done = Vec::new();
                let mut busy_total = 0u32;
                for i in (c..requests).step_by(clients) {
                    let t = Instant::now();
                    let (out, busy) = client.infer_retry_busy(
                        code,
                        sample_dims,
                        inputs[i].data(),
                        10_000,
                        Duration::from_millis(1),
                    )?;
                    done.push((i, out, t.elapsed().as_secs_f64()));
                    busy_total += busy;
                }
                Ok((done, busy_total))
            }));
        }
        workers
            .into_iter()
            .map(|w| w.join().map_err(|_| anyhow!("load client thread panicked"))?)
            .collect::<Result<Vec<_>>>()
    })?;
    let seconds = t0.elapsed().as_secs_f64();
    let profile = stage_profile(handle.counters());
    let report = handle.shutdown()?;

    let mut bit_identical = true;
    let mut latencies = Vec::with_capacity(requests);
    let mut served = 0usize;
    let mut busy_rejections = 0u64;
    for (done, busy) in joined {
        busy_rejections += u64::from(busy);
        for (i, out, lat) in done {
            served += 1;
            latencies.push(lat);
            let want = reference[i].data();
            bit_identical &= out.len() == want.len()
                && out.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
        }
    }
    ensure!(served == requests, "load leg completed {served} of {requests} requests");
    latencies.sort_by(f64::total_cmp);
    Ok(LoadBench {
        clients,
        requests,
        busy_rejections,
        seconds,
        p50_s: percentile(&latencies, 50),
        p99_s: percentile(&latencies, 99),
        coalesced: report.engine.coalesced.saturating_sub(warm.coalesced),
        batches: report.engine.batches.saturating_sub(warm.batches),
        max_queue_depth: report.max_queue_depth,
        bit_identical,
        profile,
    })
}

/// Snapshot the four stage histograms of a live server into a
/// [`StageProfile`].
fn stage_profile(c: &crate::server::Counters) -> StageProfile {
    let q = |h: &crate::obs::Hist| StageQuantiles {
        p50_ns: h.percentile(50),
        p99_ns: h.percentile(99),
    };
    StageProfile {
        read: q(&c.read_ns),
        queue: q(&c.queue_wait_ns),
        eval: q(&c.eval_ns),
        write: q(&c.write_ns),
    }
}

/// The degraded-mode leg of [`bench_serve`]: the same loopback load
/// pattern as [`bench_load`], but with the fault registry armed so
/// [`DEGRADED_FAULT_RATE`] of per-model wave groups fail gracefully.
/// Clients absorb injected `INTERNAL` failures (counted, not retried)
/// and retry `BUSY` with jittered backoff; successful responses must
/// still be bit-identical — injection degrades availability, never
/// numerics.
fn bench_degraded(
    code: &str,
    clients: usize,
    inputs: &[Tensor],
    dims: &[usize],
    reference: &[Tensor],
    max_batch: usize,
) -> Result<DegradedBench> {
    let requests = inputs.len();
    let mut engine = Engine::new(max_batch);
    engine.submit(code, u64::MAX, inputs[0].data().to_vec())?;
    ensure!(engine.drain()?.len() == 1, "degraded warm-up dropped its request");
    faults::silence_injected_panics();
    let _faults = FaultPlan::new(0xDE6_AD)
        .with(FaultRule {
            site: faults::SITE_SCHEDULER_WAVE.to_string(),
            scope: None,
            kind: FaultKind::Err,
            trigger: Trigger::Prob(DEGRADED_FAULT_RATE),
        })
        .arm();
    let config = ServerConfig {
        queue_depth: max_batch.max(clients),
        ..ServerConfig::default()
    };
    let handle = server::serve("127.0.0.1:0", engine, config)?;
    let addr = handle.addr().to_string();
    let sample_dims = &dims[1..];
    let t0 = Instant::now();
    let joined = std::thread::scope(|scope| {
        let mut workers = Vec::with_capacity(clients);
        for c in 0..clients {
            let addr = addr.clone();
            workers.push(scope.spawn(
                move || -> Result<(Vec<(usize, Vec<f32>, f64)>, u64, u64)> {
                    let mut client = Client::connect_retry(&addr, Duration::from_secs(10))?;
                    let mut done = Vec::new();
                    let mut busy = 0u64;
                    let mut injected = 0u64;
                    for i in (c..requests).step_by(clients) {
                        let mut backoff = Backoff::new(
                            c as u64,
                            Duration::from_millis(1),
                            Duration::from_millis(16),
                        );
                        let t = Instant::now();
                        loop {
                            match client.request(code, sample_dims, inputs[i].data())? {
                                Response::Output { data, .. } => {
                                    done.push((i, data, t.elapsed().as_secs_f64()));
                                    break;
                                }
                                Response::Error { code: ErrorCode::Busy, .. } => {
                                    busy += 1;
                                    backoff.sleep();
                                }
                                // An injected failure: absorbed, not
                                // retried — the leg measures the front
                                // staying up, not retry loops.
                                Response::Error { .. } => {
                                    injected += 1;
                                    break;
                                }
                                Response::Health(_) | Response::Metrics(_) => {
                                    anyhow::bail!("unexpected status frame in the degraded leg")
                                }
                            }
                        }
                    }
                    Ok((done, busy, injected))
                },
            ));
        }
        workers
            .into_iter()
            .map(|w| w.join().map_err(|_| anyhow!("degraded client thread panicked"))?)
            .collect::<Result<Vec<_>>>()
    })?;
    let seconds = t0.elapsed().as_secs_f64();
    let _report = handle.shutdown()?;

    let mut bit_identical = true;
    let mut latencies = Vec::with_capacity(requests);
    let mut completed = 0usize;
    let mut busy_rejections = 0u64;
    let mut injected_errors = 0u64;
    for (done, busy, injected) in joined {
        busy_rejections += busy;
        injected_errors += injected;
        for (i, out, lat) in done {
            completed += 1;
            latencies.push(lat);
            let want = reference[i].data();
            bit_identical &= out.len() == want.len()
                && out.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
        }
    }
    ensure!(
        completed as u64 + injected_errors == requests as u64,
        "degraded leg lost requests: {completed} completed + {injected_errors} failed != {requests}"
    );
    latencies.sort_by(f64::total_cmp);
    Ok(DegradedBench {
        clients,
        requests,
        completed,
        injected_errors,
        busy_rejections,
        seconds,
        p50_s: percentile(&latencies, 50),
        p99_s: percentile(&latencies, 99),
        bit_identical,
    })
}

/// Render serve measurements as the `BENCH_serve.json` document.
pub fn serve_to_json(benches: &[ServeBench], threads: usize) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve\",\n");
    s.push_str(&format!("  \"threads\": {threads},\n"));
    s.push_str("  \"networks\": [\n");
    for (bi, b) in benches.iter().enumerate() {
        s.push_str("    {\n");
        s.push_str(&format!("      \"net\": \"{}\",\n", esc(&b.net)));
        s.push_str(&format!("      \"requests\": {},\n", b.requests));
        s.push_str(&format!(
            "      \"per_request\": {{\"seconds\": {}, \"rps\": {}, \"binds\": {}}},\n",
            jnum(b.per_request_s, 6),
            jnum(b.per_request_rps(), 3),
            b.per_request_binds
        ));
        s.push_str(&format!(
            "      \"session\": {{\"seconds\": {}, \"rps\": {}, \"binds\": {}, \
             \"p50_ms\": {}, \"p99_ms\": {}}},\n",
            jnum(b.session_s, 6),
            jnum(b.session_rps(), 3),
            b.session_binds,
            jnum(b.p50_s * 1e3, 4),
            jnum(b.p99_s * 1e3, 4)
        ));
        s.push_str(&format!(
            "      \"engine\": {{\"seconds\": {}, \"rps\": {}, \"batches\": {}}},\n",
            jnum(b.engine_s, 6),
            jnum(b.engine_rps(), 3),
            b.engine_batches
        ));
        match &b.load {
            None => s.push_str("      \"load\": null,\n"),
            Some(l) => {
                s.push_str(&format!(
                    "      \"load\": {{\"clients\": {}, \"requests\": {}, \"seconds\": {}, \
                     \"rps\": {}, \"p50_ms\": {}, \"p99_ms\": {}, \"batches\": {}, \
                     \"coalesced\": {}, \"coalescing_rate\": {}, \"busy_rejected\": {}, \
                     \"max_queue_depth\": {}, \"bit_identical\": {}}},\n",
                    l.clients,
                    l.requests,
                    jnum(l.seconds, 6),
                    jnum(l.rps(), 3),
                    jnum(l.p50_s * 1e3, 4),
                    jnum(l.p99_s * 1e3, 4),
                    l.batches,
                    l.coalesced,
                    jopt(l.coalescing_rate(), 4),
                    l.busy_rejections,
                    l.max_queue_depth,
                    l.bit_identical
                ));
            }
        }
        match b.load.as_ref().map(|l| &l.profile) {
            None => s.push_str("      \"profile\": null,\n"),
            Some(p) => {
                let stage = |q: &StageQuantiles| {
                    format!("{{\"p50_ns\": {}, \"p99_ns\": {}}}", q.p50_ns, q.p99_ns)
                };
                s.push_str(&format!(
                    "      \"profile\": {{\"read\": {}, \"queue\": {}, \"eval\": {}, \
                     \"write\": {}}},\n",
                    stage(&p.read),
                    stage(&p.queue),
                    stage(&p.eval),
                    stage(&p.write)
                ));
            }
        }
        match &b.degraded {
            None => s.push_str("      \"degraded\": null,\n"),
            Some(d) => {
                s.push_str(&format!(
                    "      \"degraded\": {{\"fault_rate\": {}, \"clients\": {}, \
                     \"requests\": {}, \"completed\": {}, \"injected_errors\": {}, \
                     \"busy_rejected\": {}, \"seconds\": {}, \"rps\": {}, \"p50_ms\": {}, \
                     \"p99_ms\": {}, \"bit_identical\": {}}},\n",
                    jnum(DEGRADED_FAULT_RATE, 4),
                    d.clients,
                    d.requests,
                    d.completed,
                    d.injected_errors,
                    d.busy_rejections,
                    jnum(d.seconds, 6),
                    jnum(d.rps(), 3),
                    jnum(d.p50_s * 1e3, 4),
                    jnum(d.p99_s * 1e3, 4),
                    d.bit_identical
                ));
            }
        }
        s.push_str(&format!("      \"speedup\": {},\n", jopt(b.speedup(), 3)));
        s.push_str(&format!(
            "      \"bind_amortization\": {},\n",
            jopt(b.bind_amortization(), 3)
        ));
        s.push_str(&format!("      \"bit_identical\": {}\n", b.bit_identical));
        let sep = if bi + 1 < benches.len() { "," } else { "" };
        s.push_str(&format!("    }}{sep}\n"));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

/// Write the serve JSON document to `path`.
pub fn write_serve_json(path: &str, benches: &[ServeBench], threads: usize) -> Result<()> {
    fs::write(path, serve_to_json(benches, threads)).with_context(|| format!("writing {path}"))
}

/// A float as a JSON number with `prec` decimals, or `null` when it is
/// not finite — the emitter-level gate against `inf`/`NaN` in the
/// artifact.
fn jnum(v: f64, prec: usize) -> String {
    if v.is_finite() {
        format!("{v:.prec$}")
    } else {
        "null".to_string()
    }
}

/// An optional ratio as a JSON number or `null`.
fn jopt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => jnum(x, prec),
        None => "null".to_string(),
    }
}

/// Render measurements as the `BENCH_native_exec.json` document.
pub fn to_json(benches: &[NetBench], threads: usize) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"native_exec\",\n");
    s.push_str(&format!("  \"threads\": {threads},\n"));
    s.push_str("  \"networks\": [\n");
    for (bi, b) in benches.iter().enumerate() {
        s.push_str("    {\n");
        s.push_str(&format!("      \"net\": \"{}\",\n", esc(&b.net)));
        s.push_str(&format!("      \"batch\": {},\n", b.batch));
        s.push_str(&format!("      \"entries\": {},\n", b.entries));
        s.push_str(&format!("      \"work\": {},\n", b.work));
        s.push_str(&format!(
            "      \"naive\": {{\"seconds\": {}, \"gops\": {}}},\n",
            jnum(b.naive_s, 6),
            jnum(b.naive_gops(), 3)
        ));
        s.push_str(&format!(
            "      \"fast\": {{\"seconds\": {}, \"gops\": {}}},\n",
            jnum(b.fast_s, 6),
            jnum(b.fast_gops(), 3)
        ));
        s.push_str(&format!(
            "      \"fused\": {{\"seconds\": {}, \"gops\": {}, \"entries\": {}, \
             \"speedup_vs_fast\": {}, \"bit_identical\": {}}},\n",
            jnum(b.fused_s, 6),
            jnum(b.fused_gops(), 3),
            b.fused_entries,
            jopt(b.fusion_speedup(), 3),
            b.fused_bit_identical
        ));
        s.push_str(&format!(
            "      \"precision_fast\": {{\"seconds\": {}, \"gops\": {}, \
             \"speedup_vs_fast\": {}, \"max_rel_err\": {}, \"within_tol\": {}}},\n",
            jnum(b.fastp_s, 6),
            jnum(b.fastp_gops(), 3),
            jopt(b.fastp_speedup(), 3),
            jnum(b.fastp_max_rel_err, 9),
            b.fastp_within_tol
        ));
        s.push_str(&format!(
            "      \"chain_reduction\": {},\n",
            jnum(b.chain_reduction(), 3)
        ));
        s.push_str(&format!("      \"speedup\": {},\n", jopt(b.speedup(), 3)));
        let bits = b.bit_identical;
        s.push_str(&format!("      \"bit_identical\": {bits},\n"));
        s.push_str("      \"layers\": [\n");
        for (li, l) in b.layers.iter().enumerate() {
            let sep = if li + 1 < b.layers.len() { "," } else { "" };
            s.push_str(&format!(
                "        {{\"layer\": \"{}\", \"gconvs\": {}, \"work\": {}, \
                 \"naive_s\": {}, \"fast_s\": {}, \"speedup\": {}}}{}\n",
                esc(&l.layer),
                l.gconvs,
                l.work,
                jnum(l.naive_s, 6),
                jnum(l.fast_s, 6),
                jopt(l.speedup(), 3),
                sep
            ));
        }
        s.push_str("      ]\n");
        let sep = if bi + 1 < benches.len() { "," } else { "" };
        s.push_str(&format!("    }}{sep}\n"));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

/// Write the JSON document to `path`.
pub fn write_json(path: &str, benches: &[NetBench], threads: usize) -> Result<()> {
    let json = to_json(benches, threads);
    fs::write(path, json).with_context(|| format!("writing {path}"))?;
    Ok(())
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::networks::mobilenet_block;

    #[test]
    fn block_bench_is_bit_identical_and_json_renders() {
        let net = mobilenet_block(2, 4, 6);
        let b = bench_network(&net, 2).unwrap();
        assert!(b.bit_identical, "fast paths must match the oracle");
        assert!(b.fused_bit_identical, "fusion must preserve the final output");
        assert!(
            b.fastp_within_tol,
            "Precision::Fast drifted past tolerance: {}",
            b.fastp_max_rel_err
        );
        assert!(b.fused_entries < b.entries, "the block's ReLUs must fuse away");
        assert!(b.chain_reduction() > 0.0);
        assert_eq!(b.batch, 2);
        assert!(b.entries > 0 && b.work > 0);
        assert!(!b.layers.is_empty());
        let gconvs: usize = b.layers.iter().map(|l| l.gconvs).sum();
        assert_eq!(gconvs, b.entries);
        let json = to_json(&[b], 2);
        assert!(json.contains("\"bench\": \"native_exec\""));
        assert!(json.contains("\"net\": \"MobileNetBlock\""));
        assert!(json.contains("\"bit_identical\": true"));
        assert!(json.contains("\"fused\""));
        assert!(json.contains("\"precision_fast\""));
        assert!(json.contains("\"within_tol\": true"));
        assert!(json.contains("\"chain_reduction\""));
        assert!(!json.contains("inf") && !json.to_lowercase().contains("nan"));
        assert!(json.trim_end().ends_with('}'));
    }

    #[test]
    fn zero_timings_emit_null_not_inf() {
        let b = NetBench {
            net: "tiny".into(),
            batch: 1,
            entries: 1,
            work: 10,
            naive_s: 0.0,
            fast_s: 0.0,
            fused_entries: 1,
            fused_s: 0.0,
            bit_identical: true,
            fused_bit_identical: true,
            fastp_s: 0.0,
            fastp_max_rel_err: 0.0,
            fastp_within_tol: true,
            layers: vec![LayerBench {
                layer: "l".into(),
                gconvs: 1,
                work: 10,
                naive_s: 1.0,
                fast_s: 0.0,
            }],
        };
        assert_eq!(b.speedup(), None);
        assert_eq!(b.fusion_speedup(), None);
        assert_eq!(b.fastp_speedup(), None);
        assert_eq!(b.layers[0].speedup(), None);
        let json = to_json(&[b], 1);
        assert!(json.contains("\"speedup\": null"));
        assert!(!json.contains("inf") && !json.to_lowercase().contains("nan"));
    }

    #[test]
    fn esc_escapes_quotes_and_backslashes() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
    }

    #[test]
    fn percentile_is_nearest_rank_and_zero_guarded() {
        assert_eq!(percentile(&[], 50), 0.0);
        assert_eq!(percentile(&[], 99), 0.0);
        let one = [7.0];
        assert_eq!(percentile(&one, 0), 7.0);
        assert_eq!(percentile(&one, 50), 7.0);
        assert_eq!(percentile(&one, 99), 7.0);
        let ten: Vec<f64> = (1..=10).map(f64::from).collect();
        assert_eq!(percentile(&ten, 0), 1.0);
        // Nearest-rank over 10 samples: index 10·50/100 = 5.
        assert_eq!(percentile(&ten, 50), 6.0);
        assert_eq!(percentile(&ten, 99), 10.0);
        // p == 100 would index one past the end: clamped.
        assert_eq!(percentile(&ten, 100), 10.0);
        let hundred: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(percentile(&hundred, 50), 51.0);
        assert_eq!(percentile(&hundred, 99), 100.0);
    }

    #[test]
    fn serve_json_renders_synthetic_rows() {
        let b = ServeBench {
            net: "tiny".into(),
            requests: 4,
            per_request_s: 2.0,
            per_request_binds: 40,
            session_s: 1.0,
            session_binds: 10,
            p50_s: 0.25,
            p99_s: 0.5,
            engine_s: 1.5,
            engine_batches: 4,
            bit_identical: true,
            load: None,
            degraded: None,
        };
        assert_eq!(b.speedup(), Some(2.0));
        assert_eq!(b.bind_amortization(), Some(4.0));
        assert_eq!(b.session_rps(), 4.0);
        let json = serve_to_json(&[b.clone()], 2);
        assert!(json.contains("\"bench\": \"serve\""));
        assert!(json.contains("\"bind_amortization\": 4.000"));
        assert!(json.contains("\"p50_ms\": 250.0000"));
        assert!(json.contains("\"bit_identical\": true"));
        assert!(json.contains("\"load\": null"));
        assert!(json.contains("\"profile\": null"));
        assert!(json.contains("\"degraded\": null"));
        assert!(!json.contains("inf") && !json.to_lowercase().contains("nan"));

        let mut b = b;
        b.load = Some(LoadBench {
            clients: 3,
            requests: 4,
            busy_rejections: 2,
            seconds: 2.0,
            p50_s: 0.25,
            p99_s: 0.5,
            coalesced: 2,
            batches: 3,
            max_queue_depth: 3,
            bit_identical: true,
            profile: StageProfile {
                read: StageQuantiles { p50_ns: 1023, p99_ns: 4095 },
                queue: StageQuantiles { p50_ns: 2047, p99_ns: 8191 },
                eval: StageQuantiles { p50_ns: 65535, p99_ns: 131071 },
                write: StageQuantiles { p50_ns: 511, p99_ns: 2047 },
            },
        });
        b.degraded = Some(DegradedBench {
            clients: 3,
            requests: 4,
            completed: 3,
            injected_errors: 1,
            busy_rejections: 0,
            seconds: 2.0,
            p50_s: 0.25,
            p99_s: 0.5,
            bit_identical: true,
        });
        assert_eq!(b.degraded.as_ref().unwrap().rps(), 1.5);
        let json = serve_to_json(&[b], 2);
        assert!(json.contains("\"load\": {\"clients\": 3"));
        assert!(json.contains("\"profile\": {\"read\": {\"p50_ns\": 1023, \"p99_ns\": 4095}"));
        assert!(json.contains("\"eval\": {\"p50_ns\": 65535, \"p99_ns\": 131071}"));
        assert!(json.contains("\"coalescing_rate\": 0.5000"));
        assert!(json.contains("\"busy_rejected\": 2"));
        assert!(json.contains("\"max_queue_depth\": 3"));
        assert!(json.contains("\"degraded\": {\"fault_rate\": 0.0100"));
        assert!(json.contains("\"injected_errors\": 1"));
        assert!(!json.contains("inf") && !json.to_lowercase().contains("nan"));
    }

    #[test]
    #[ignore = "full MobileNet serve loop; CI runs it in release via `-- --ignored`"]
    fn serve_bench_mobilenet_is_bit_identical_and_amortizes_binds() {
        // Degraded leg off: the armed fault registry is process-global
        // and other `--ignored` lib tests may run concurrently.
        let b = bench_serve("MN", 4, 4, 2, false).unwrap();
        assert!(b.bit_identical, "session/engine outputs must match per-request");
        assert!(b.session_binds > 0);
        assert_eq!(b.per_request_binds, b.requests * b.session_binds);
        assert_eq!(b.bind_amortization(), Some(b.requests as f64));
        let load = b.load.as_ref().expect("load leg requested");
        assert!(load.bit_identical, "wire outputs must match per-request");
        assert_eq!(load.requests, b.requests);
        let json = serve_to_json(&[b], 0);
        assert!(json.contains("\"bench\": \"serve\""));
        assert!(json.contains("\"load\": {\"clients\": 2"));
    }
}
