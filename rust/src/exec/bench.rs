//! Naive-vs-fast measurement harness for the native execution engine.
//!
//! Runs a network's inference chain twice — once forced through the
//! naive per-element oracle, once on the tiered fast paths — and
//! aggregates per-layer and end-to-end timings plus a bit-identity
//! check. `rust/benches/native_exec.rs` and the `--bench-json` mode of
//! `examples/native_inference.rs` both drive this module and emit the
//! result as `BENCH_native_exec.json`, the repo's performance-trajectory
//! artifact (CI uploads it on every run).

use std::collections::HashMap;
use std::fs;

use anyhow::{Context, Result};

use crate::gconv::lower::{lower_network, Mode};
use crate::ir::{Layer, Network};

use super::chain_exec::{ChainExec, RunReport};
use super::tensor::Tensor;

/// Per-layer aggregation of one naive-vs-fast comparison (chain entries
/// grouped by the op-name prefix before the phase suffix, so
/// `"bn3.FP2"` rolls up into layer `"bn3"`).
#[derive(Clone, Debug)]
pub struct LayerBench {
    /// Layer name.
    pub layer: String,
    /// GCONV entries in the layer.
    pub gconvs: usize,
    /// `main` operations per chain run.
    pub work: usize,
    /// Seconds in the layer, naive oracle.
    pub naive_s: f64,
    /// Seconds in the layer, fast tiers.
    pub fast_s: f64,
}

impl LayerBench {
    /// Naive-to-fast speedup for this layer.
    pub fn speedup(&self) -> f64 {
        if self.fast_s > 0.0 {
            self.naive_s / self.fast_s
        } else {
            0.0
        }
    }
}

/// One network's end-to-end naive-vs-fast measurement.
#[derive(Clone, Debug)]
pub struct NetBench {
    /// Network name (e.g. `"MobileNet"`).
    pub net: String,
    /// Mini-batch size of the lowered chain.
    pub batch: usize,
    /// GCONV entries executed.
    pub entries: usize,
    /// Total `main` operations per chain run.
    pub work: usize,
    /// End-to-end seconds, naive oracle.
    pub naive_s: f64,
    /// End-to-end seconds, fast tiers (best measured run).
    pub fast_s: f64,
    /// Whether the two paths produced bit-identical final outputs.
    pub bit_identical: bool,
    /// Per-layer breakdown.
    pub layers: Vec<LayerBench>,
}

impl NetBench {
    /// End-to-end naive-to-fast speedup.
    pub fn speedup(&self) -> f64 {
        if self.fast_s > 0.0 {
            self.naive_s / self.fast_s
        } else {
            0.0
        }
    }

    /// Giga `main`-operations per second on the naive oracle.
    pub fn naive_gops(&self) -> f64 {
        gops(self.work, self.naive_s)
    }

    /// Giga `main`-operations per second on the fast tiers.
    pub fn fast_gops(&self) -> f64 {
        gops(self.work, self.fast_s)
    }
}

fn gops(work: usize, seconds: f64) -> f64 {
    if seconds > 0.0 {
        work as f64 / seconds / 1e9
    } else {
        0.0
    }
}

/// Input operand name and batched shape of a network's `Input` layer
/// (the operand the lowering emits as `"<name>.data"`).
fn input_spec(net: &Network) -> Result<(String, Vec<usize>)> {
    let input = net
        .nodes()
        .iter()
        .find(|n| matches!(n.layer, Layer::Input { .. }))
        .context("network has no Input layer")?;
    let dims: Vec<usize> = input.output.iter().map(|(_, n)| n).collect();
    Ok((format!("{}.data", input.name), dims))
}

/// Lower `net` for inference and measure its FP chain end-to-end: the
/// naive oracle once (it is the slow side), the fast tiers `fast_runs`
/// times (the first run warms the buffer pool; the best run is kept).
/// Both timed sides execute the *same* pruned workload (ancestors of
/// the final entry) with buffer recycling engaged; a separate untimed
/// pass retains every entry on both paths and feeds the all-entry
/// differential gate. Weights are synthesized deterministically; the
/// input is a fixed pseudo-random tensor, identical on both paths.
pub fn bench_network(net: &Network, fast_runs: usize) -> Result<NetBench> {
    let (input_name, dims) = input_spec(net)?;
    let x = Tensor::rand(&dims, 0xBE7C_4A11, 1.0);

    let naive_chain = lower_network(net, Mode::Inference);
    let all: Vec<usize> = (0..naive_chain.len()).collect();
    let mut naive = ChainExec::new(naive_chain).with_naive_oracle();
    naive.set_input(&input_name, x.clone());
    let naive_report = naive.run_last()?;

    let fast_chain = lower_network(net, Mode::Inference);
    let mut fast = ChainExec::new(fast_chain);
    fast.set_input(&input_name, x);
    let mut fast_report = fast.run_last()?;
    for _ in 1..fast_runs.max(1) {
        let r = fast.run_last()?;
        if r.total_s < fast_report.total_s {
            fast_report = r;
        }
    }

    // Untimed differential gate: *every* chain entry must match the
    // oracle bit-for-bit, not just the final network output.
    let dn = naive.run(&all)?;
    let df = fast.run(&all)?;
    let mut bit_identical = df.outputs.len() == dn.outputs.len();
    for (a, b) in df.outputs.iter().zip(&dn.outputs) {
        bit_identical &= a.bit_eq(b);
    }
    Ok(NetBench {
        net: net.name.clone(),
        batch: dims[0],
        entries: fast_report.entries.len(),
        work: fast_report.total_work(),
        naive_s: naive_report.total_s,
        fast_s: fast_report.total_s,
        bit_identical,
        layers: layer_rows(&naive_report, &fast_report),
    })
}

/// Merge two reports of the same chain into per-layer rows (paired by
/// chain-entry index, so differing retention sets cannot misalign).
fn layer_rows(naive: &RunReport, fast: &RunReport) -> Vec<LayerBench> {
    let mut naive_secs = HashMap::new();
    for ne in &naive.entries {
        naive_secs.insert(ne.index, ne.seconds);
    }
    let mut rows: Vec<LayerBench> = Vec::new();
    for fe in &fast.entries {
        let layer = layer_of(&fe.name);
        let ns = naive_secs.get(&fe.index).copied().unwrap_or(0.0);
        match rows.last_mut() {
            Some(row) if row.layer == layer => {
                row.gconvs += 1;
                row.work += fe.work;
                row.naive_s += ns;
                row.fast_s += fe.seconds;
            }
            _ => rows.push(LayerBench {
                layer,
                gconvs: 1,
                work: fe.work,
                naive_s: ns,
                fast_s: fe.seconds,
            }),
        }
    }
    rows
}

/// Layer name of a chain-entry name (`"bn3.FP2"` → `"bn3"`).
fn layer_of(name: &str) -> String {
    name.split('.').next().unwrap_or(name).to_string()
}

/// Render measurements as the `BENCH_native_exec.json` document.
pub fn to_json(benches: &[NetBench], threads: usize) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"native_exec\",\n");
    s.push_str(&format!("  \"threads\": {threads},\n"));
    s.push_str("  \"networks\": [\n");
    for (bi, b) in benches.iter().enumerate() {
        s.push_str("    {\n");
        s.push_str(&format!("      \"net\": \"{}\",\n", esc(&b.net)));
        s.push_str(&format!("      \"batch\": {},\n", b.batch));
        s.push_str(&format!("      \"entries\": {},\n", b.entries));
        s.push_str(&format!("      \"work\": {},\n", b.work));
        s.push_str(&format!(
            "      \"naive\": {{\"seconds\": {:.6}, \"gops\": {:.3}}},\n",
            b.naive_s,
            b.naive_gops()
        ));
        s.push_str(&format!(
            "      \"fast\": {{\"seconds\": {:.6}, \"gops\": {:.3}}},\n",
            b.fast_s,
            b.fast_gops()
        ));
        s.push_str(&format!("      \"speedup\": {:.3},\n", b.speedup()));
        let bits = b.bit_identical;
        s.push_str(&format!("      \"bit_identical\": {bits},\n"));
        s.push_str("      \"layers\": [\n");
        for (li, l) in b.layers.iter().enumerate() {
            let sep = if li + 1 < b.layers.len() { "," } else { "" };
            s.push_str(&format!(
                "        {{\"layer\": \"{}\", \"gconvs\": {}, \"work\": {}, \
                 \"naive_s\": {:.6}, \"fast_s\": {:.6}, \"speedup\": {:.3}}}{}\n",
                esc(&l.layer),
                l.gconvs,
                l.work,
                l.naive_s,
                l.fast_s,
                l.speedup(),
                sep
            ));
        }
        s.push_str("      ]\n");
        let sep = if bi + 1 < benches.len() { "," } else { "" };
        s.push_str(&format!("    }}{sep}\n"));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

/// Write the JSON document to `path`.
pub fn write_json(path: &str, benches: &[NetBench], threads: usize) -> Result<()> {
    let json = to_json(benches, threads);
    fs::write(path, json).with_context(|| format!("writing {path}"))?;
    Ok(())
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::networks::mobilenet_block;

    #[test]
    fn block_bench_is_bit_identical_and_json_renders() {
        let net = mobilenet_block(2, 4, 6);
        let b = bench_network(&net, 2).unwrap();
        assert!(b.bit_identical, "fast paths must match the oracle");
        assert_eq!(b.batch, 2);
        assert!(b.entries > 0 && b.work > 0);
        assert!(!b.layers.is_empty());
        let gconvs: usize = b.layers.iter().map(|l| l.gconvs).sum();
        assert_eq!(gconvs, b.entries);
        let json = to_json(&[b], 2);
        assert!(json.contains("\"bench\": \"native_exec\""));
        assert!(json.contains("\"net\": \"MobileNetBlock\""));
        assert!(json.contains("\"bit_identical\": true"));
        assert!(json.trim_end().ends_with('}'));
    }

    #[test]
    fn esc_escapes_quotes_and_backslashes() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
    }
}
