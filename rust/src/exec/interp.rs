//! Native GCONV interpreter: evaluate one [`GconvOp`] by walking its
//! multi-dimensional loop nest.
//!
//! Paper §3.1 defines a 1-D GCONV by four loop parameters per dimension
//! (`Ng` groups, `Nop` parallel kernels, `Nopc` outputs per kernel,
//! `Nks` kernel size, plus stride `s` and padding `ps`) and replaces the
//! fixed multiply-accumulate of traditional convolution with four
//! pluggable operators:
//!
//! ```text
//! out[g, op, opc] = post( reduce_{ks} main( pre(in[g, opc·s + ks − ps]),
//!                                           ker[g, op, ks] ) )
//! ```
//!
//! A multi-dimension GCONV runs this nest in every data dimension at
//! once (Fig. 4): an output coordinate decomposes per dimension into
//! `(g, op, opc)`, the reduction ranges over the cartesian product of the
//! per-dimension `Nks` loops, and input/kernel coordinates follow Eq. (1).
//! This module is the executable ground truth for the lowering in
//! [`crate::gconv::lower`]: conv, FC, pooling, BN, LRN, softmax and their
//! BP/WG forms all reduce to this one evaluator.
//!
//! Binding an op to an input layout produces an owned `BoundPlan`
//! (validated shapes, precomputed strides, LUT names resolved, execution
//! tier chosen); evaluation pairs a bound plan with concrete operand
//! slices and dispatches to a tier (see `super::kernels`): a
//! packed-panel dot/GEMM fast path for `Mul`+`Add` reductions, an
//! odometer-indexed generic fast path for everything else, and the naive
//! per-element oracle (`Plan::eval_one`, reachable via
//! [`eval_gconv_naive`]) kept for differential testing. All tiers are
//! bit-identical under the default [`Precision::BitExact`]; the GEMM
//! tier additionally offers [`Precision::Fast`], a SIMD-friendly
//! reordered accumulation gated by a tolerance differential instead
//! (see `super::kernels`). A `BoundPlan` owns no operand tensors — at
//! most a prepacked copy of its frozen kernel rows
//! ([`BoundPlan::prepack`]) — so the serving layer ([`super::serve`])
//! binds each chain entry once, packs its weights once, and re-runs the
//! stored plans against fresh buffers on every request.
//!
//! ## Index semantics
//!
//! Along one dimension with parameters `(ng, nop, nopc, nks, s, ps)`:
//!
//! * output extent `ng·nop·nopc`, kernel extent `ng·nop·nks`,
//!   covered input extent `ng·max((nopc−1)·s + nks − 2·ps, 1)`
//!   (Table 3 / [`DimParams::input_extent`]);
//! * for output coordinate `(g, op, opc)` and reduction step `ks`, the
//!   input position is `g·Nin + opc·s + ks − ps` (where `Nin` is the
//!   per-group input extent) and the kernel position `(g·nop + op)·nks +
//!   ks`;
//! * positions falling outside the input are *padding*: they contribute
//!   a zero input value under `Add`/`None` reduction and are skipped
//!   entirely under `Max` reduction (max pooling ignores its padding).
//!
//! Input tensors may carry a larger extent than the covered extent along
//! sliding-window dimensions — strided convolutions legitimately discard
//! a tail row/column (e.g. a stride-2 3×3 conv over 224 covers only 223
//! rows) — so binding accepts any actual extent ≥ the covered extent.
//! Conversely, a rank-aligned input with extent **1** along a dimension
//! whose covered extent is larger binds as a *broadcast* (stride 0):
//! backward ops like GlobalAvgPool's BP spread one gradient value over
//! the whole spatial extent this way. Chain idioms whose operands
//! genuinely under-cover the nest (max-pool BP's argmax routing,
//! concatenation) are not loop nests at all: the lowering marks them as
//! [`crate::gconv::chain::SpecialOp`] entries and `super::special`
//! executes them with dedicated routines; any *other* under-covering
//! operand stays a bind-time error, which the chain executor now raises
//! up front before running anything (see [`bind_input`]).
//!
//! [`DimParams::input_extent`]: crate::gconv::op::DimParams::input_extent

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, ensure, Context, Result};

use crate::gconv::op::{
    GconvOp, MainOp, PostOp, PreOp, ReduceOp, ScalarStage, StageStack, MAX_FUSED_STAGES,
};

use super::faults;
use super::kernels::{self, KernelTier, Precision, PrepackedWeights, GEMM_MIN_REDUCTION};
use super::pool::BufferPool;
use super::tensor::{row_major_strides, Tensor};

/// Epsilon used by the `"rsqrt_eps"` LUT (BN FP3 variance stabilizer).
pub const BN_EPS: f32 = 1e-5;
/// LRN coefficients used by the `"lrn_scale"` LUT (Krizhevsky et al.
/// defaults): `scale = (1 + ALPHA·x)^(−BETA)` for `x = Σ window x²`.
pub const LRN_ALPHA: f32 = 1e-4;
/// See [`LRN_ALPHA`].
pub const LRN_BETA: f32 = 0.75;

/// Most loop-nest dimensions a plan can carry (the execution tiers use
/// fixed-size index state of this width).
pub const MAX_DIMS: usize = 8;

/// A look-up-table function resolved from its lowering name. In the
/// paper's accelerator these are literal lookup tables (§3.1
/// "Representability") and may fold per-layer constants — here each gets
/// one fixed analytic definition:
///
/// * [`LutFn::RsqrtEps`] (`"rsqrt_eps"`): `1/√(x + ε)` with ε =
///   [`BN_EPS`]. (Table 2 FP3 folds the `1/Nbs` variance scaling into
///   the hardware LUT; the native definition keeps the plain form, so BN
///   normalizes by the batch *sum* of squares — the chain's golden tests
///   pin this semantics.)
/// * [`LutFn::LrnScale`] (`"lrn_scale"`): `(1 + α·x)^(−β)` with the
///   AlexNet α/β defaults.
/// * [`LutFn::SquashScale`] (`"squash_scale"`): for `x = ‖s‖²`, the
///   capsule squash scale `x/((1+x)·√(x+ε))`.
/// * [`LutFn::Fused`] (`"fused"`): identity — the placeholder slot the
///   *analytical* fusion policy writes (§4.3). The executable policy
///   ([`crate::mapping::fuse_executable`]) composes real
///   [`StageStack`] pipelines instead, resolved to [`StackEval`] here
///   at bind.
///
/// Names resolve **once at bind time** ([`LutFn::resolve`]); the hot
/// loops only ever see the enum, so an unknown LUT name is a bind error
/// and can never panic mid-evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LutFn {
    /// `max(x, 0)`.
    Relu,
    /// `1/(1 + e^{−x})`.
    Sigmoid,
    /// `e^x`.
    Exp,
    /// `1/x`.
    Recip,
    /// `1/√(x + ε)`.
    RsqrtEps,
    /// `(1 + α·x)^{−β}`.
    LrnScale,
    /// `x/((1+x)·√(x+ε))`.
    SquashScale,
    /// Identity (operation-fusion placeholder).
    Fused,
}

impl LutFn {
    /// Every LUT the interpreter implements.
    pub const ALL: [LutFn; 8] = [
        LutFn::Relu,
        LutFn::Sigmoid,
        LutFn::Exp,
        LutFn::Recip,
        LutFn::RsqrtEps,
        LutFn::LrnScale,
        LutFn::SquashScale,
        LutFn::Fused,
    ];

    /// Resolve a lowering name (as carried by [`PreOp::Lut`] /
    /// [`PostOp::Lut`]) to its implementation, or `None` if unknown.
    pub fn resolve(name: &str) -> Option<LutFn> {
        match name {
            "relu" => Some(LutFn::Relu),
            "sigmoid" => Some(LutFn::Sigmoid),
            "exp" => Some(LutFn::Exp),
            "recip" => Some(LutFn::Recip),
            "rsqrt_eps" => Some(LutFn::RsqrtEps),
            "lrn_scale" => Some(LutFn::LrnScale),
            "squash_scale" => Some(LutFn::SquashScale),
            "fused" => Some(LutFn::Fused),
            _ => None,
        }
    }

    /// The lowering name this LUT resolves from.
    pub fn name(self) -> &'static str {
        match self {
            LutFn::Relu => "relu",
            LutFn::Sigmoid => "sigmoid",
            LutFn::Exp => "exp",
            LutFn::Recip => "recip",
            LutFn::RsqrtEps => "rsqrt_eps",
            LutFn::LrnScale => "lrn_scale",
            LutFn::SquashScale => "squash_scale",
            LutFn::Fused => "fused",
        }
    }

    /// Evaluate the LUT at `x`.
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            LutFn::Relu => x.max(0.0),
            LutFn::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            LutFn::Exp => x.exp(),
            LutFn::Recip => x.recip(),
            LutFn::RsqrtEps => 1.0 / (x + BN_EPS).sqrt(),
            LutFn::LrnScale => (1.0 + LRN_ALPHA * x).powf(-LRN_BETA),
            LutFn::SquashScale => x / ((1.0 + x) * (x + BN_EPS).sqrt()),
            LutFn::Fused => x,
        }
    }
}

/// True when `name` is a LUT the interpreter implements (kept in sync
/// with [`LutFn::resolve`] by construction — and by a unit test).
pub fn lut_known(name: &str) -> bool {
    LutFn::resolve(name).is_some()
}

/// Evaluate LUT `name` at `x`, erroring on unknown names (the
/// interpreter itself resolves names once at bind time and never hits
/// the error path mid-evaluation).
pub fn lut_apply(name: &str, x: f32) -> Result<f32> {
    match LutFn::resolve(name) {
        Some(f) => Ok(f.apply(x)),
        None => bail!("unknown LUT {name:?}"),
    }
}

/// One scalar stage of a composed pipeline with its LUT resolved.
#[derive(Clone, Copy, Debug)]
pub(super) enum StageEval {
    Square,
    Mul(f32),
    Lut(LutFn),
}

impl StageEval {
    #[inline]
    fn apply(self, x: f32) -> f32 {
        match self {
            StageEval::Square => x * x,
            StageEval::Mul(c) => x * c,
            StageEval::Lut(f) => f.apply(x),
        }
    }
}

/// A [`StageStack`] (composed by executable operation fusion, §4.3) with
/// every LUT name resolved at bind time. Each stage applies in order as
/// a plain `f32 → f32` map, so a fused chain reproduces the unfused
/// chain bit-for-bit (the intermediate each erased op would have written
/// is exactly the value flowing between stages).
#[derive(Clone, Copy, Debug)]
pub(super) struct StackEval {
    len: u8,
    stages: [StageEval; MAX_FUSED_STAGES],
}

impl StackEval {
    fn resolve(op_name: &str, slot: &str, stack: &StageStack) -> Result<StackEval> {
        let mut ev = StackEval { len: 0, stages: [StageEval::Square; MAX_FUSED_STAGES] };
        for &s in stack.as_slice() {
            ev.stages[ev.len as usize] = match s {
                ScalarStage::Square => StageEval::Square,
                ScalarStage::Mul(c) => StageEval::Mul(c),
                ScalarStage::Lut(name) => match LutFn::resolve(name) {
                    Some(f) => StageEval::Lut(f),
                    None => bail!("{op_name}: unknown {slot} LUT {name:?} in composed pipeline"),
                },
            };
            ev.len += 1;
        }
        Ok(ev)
    }

    #[inline]
    fn apply(&self, mut x: f32) -> f32 {
        for s in &self.stages[..self.len as usize] {
            x = s.apply(x);
        }
        x
    }
}

/// A [`PreOp`] with its LUT name resolved at bind time.
#[derive(Clone, Copy, Debug)]
pub(super) enum PreEval {
    None,
    Square,
    Mul(f32),
    Lut(LutFn),
    Stack(StackEval),
}

impl PreEval {
    #[inline]
    pub(super) fn apply(self, x: f32) -> f32 {
        match self {
            PreEval::None => x,
            PreEval::Square => x * x,
            PreEval::Mul(c) => x * c,
            PreEval::Lut(f) => f.apply(x),
            PreEval::Stack(s) => s.apply(x),
        }
    }
}

/// A [`PostOp`] with its LUT name resolved at bind time.
#[derive(Clone, Copy, Debug)]
pub(super) enum PostEval {
    None,
    Mul(f32),
    Lut(LutFn),
    Stack(StackEval),
}

impl PostEval {
    #[inline]
    pub(super) fn apply(self, x: f32) -> f32 {
        match self {
            PostEval::None => x,
            PostEval::Mul(c) => x * c,
            PostEval::Lut(f) => f.apply(x),
            PostEval::Stack(s) => s.apply(x),
        }
    }
}

#[inline]
pub(super) fn main_apply(main: MainOp, a: f32, w: f32) -> f32 {
    match main {
        MainOp::Mul => a * w,
        MainOp::Add => a + w,
        MainOp::Sub => a - w,
        MainOp::SquareDiff => (a - w) * (a - w),
        MainOp::And => {
            if a != 0.0 && w != 0.0 {
                1.0
            } else {
                0.0
            }
        }
        MainOp::Pass => a,
        MainOp::Max => a.max(w),
    }
}

/// One dimension of the bound loop nest.
#[derive(Clone, Copy, Debug)]
pub(super) struct LoopDim {
    pub(super) ng: usize,
    pub(super) nop: usize,
    pub(super) nopc: usize,
    pub(super) nks: usize,
    pub(super) s: usize,
    pub(super) ps: usize,
    /// `nop · nopc` (outputs per group).
    pub(super) npc: usize,
    /// Output extent `ng·nop·nopc` along this dimension.
    pub(super) out_ext: usize,
    /// Row-major output stride.
    pub(super) out_stride: usize,
    /// Per-group extent of the *bound* input tensor (≥ the covered
    /// extent; sliding windows may discard a tail).
    pub(super) in_actual: usize,
    /// Row-major input stride (over extents `ng·in_actual`).
    pub(super) in_stride: usize,
    /// Row-major kernel stride (over extents `ng·nop·nks`).
    pub(super) ker_stride: usize,
    /// Stride of this dimension's `ks` loop in the flattened reduction
    /// space.
    pub(super) red_stride: usize,
}

/// A [`GconvOp`] bound to a concrete *input layout*: validated shapes,
/// precomputed strides, scalar operators resolved, execution tier
/// chosen. A `BoundPlan` owns no operand tensors — at most a prepacked
/// copy of its frozen kernel rows ([`BoundPlan::prepack`]) — so it
/// outlives the call that created it: the serving layer
/// ([`super::serve::Session`]) binds every chain entry once at
/// construction and re-runs the stored plans against fresh buffers on
/// every request, paying the shape validation, LUT resolution, stride
/// precomputation *and weight packing* exactly once. [`Plan`] is the
/// per-call view pairing a bound plan with the operand slices of one
/// evaluation.
pub(super) struct BoundPlan {
    /// Op name, kept for error messages.
    pub(super) name: String,
    pub(super) main: MainOp,
    pub(super) reduce: ReduceOp,
    pub(super) pre: PreEval,
    pub(super) post: PostEval,
    pub(super) dims: Vec<LoopDim>,
    pub(super) out_dims: Vec<usize>,
    pub(super) out_total: usize,
    pub(super) red_total: usize,
    /// Element count the bound input layout requires.
    pub(super) in_elements: usize,
    /// Required kernel element count (0 when `main` is `Pass` — the
    /// kernel operand, if any, is ignored then).
    pub(super) ker_elements: usize,
    /// Execution tier, fixed at bind time (a pure shape/operator
    /// property).
    tier: KernelTier,
    /// Bind-time packed kernel rows (GEMM tier only, populated by
    /// [`BoundPlan::prepack`]): when present, `eval_bound` never packs
    /// or even reads the raw kernel tensor again.
    pub(super) prepacked: Option<PrepackedWeights>,
}

/// Per-call view of a bound plan plus the operand slices of this
/// evaluation — what the execution tiers in `super::kernels` consume.
pub(super) struct Plan<'t> {
    pub(super) bound: &'t BoundPlan,
    pub(super) xs: &'t [f32],
    pub(super) ws: Option<&'t [f32]>,
}

/// Shape-only input binding: how a tensor with extents `in_dims` (and
/// `elements` total) binds to `op`'s input slot — exact element count
/// (reshape semantics), rank-aligned slack/broadcast, or squeezed
/// alignment (see the module docs). Shared by [`BoundPlan::bind`] and the
/// chain executor's up-front operand validation, so an under-covering
/// chain-internal operand is a bind-time error in both places, never a
/// mid-chain evaluation failure.
pub(super) struct InputLayout {
    /// Actual per-group input extent per dimension.
    pub(super) in_actual: Vec<usize>,
    /// Dimensions bound as stride-0 broadcasts.
    pub(super) broadcast: Vec<bool>,
    /// Layout extents of the bound tensor (broadcast dims occupy one
    /// slot).
    pub(super) in_full: Vec<usize>,
}

pub(super) fn bind_input(op: &GconvOp, in_dims: &[usize], elements: usize) -> Result<InputLayout> {
    let nd = op.dims.len();
    let mut ngs = Vec::with_capacity(nd);
    let mut group_in = Vec::with_capacity(nd); // covered per-group input
    let mut exp_in = Vec::with_capacity(nd); // ng · group_in
    for &(d, p) in &op.dims {
        ensure!(
            p.ng >= 1 && p.nop >= 1 && p.nopc >= 1 && p.nks >= 1 && p.s >= 1,
            "{}: dimension {d} has a zero loop parameter or stride",
            op.name
        );
        // Per-group covered extent — Table 3's formula, shared with
        // `DimParams::input_extent` (which multiplies by `ng`).
        let covered = p.input_extent() / p.ng;
        ngs.push(p.ng);
        group_in.push(covered);
        exp_in.push(p.ng * covered);
    }

    // Determine the actual per-group extent of every dimension, plus
    // which dimensions broadcast (stride 0).
    let expected: usize = exp_in.iter().product();
    let mut broadcast = vec![false; nd];
    let in_actual: Vec<usize> = if elements == expected {
        // Exact element count: reshape semantics, covered extents.
        group_in.clone()
    } else if in_dims.len() == nd
        && in_dims
            .iter()
            .zip(ngs.iter().zip(&group_in))
            .all(|(&a, (&ng, &gi))| (a % ng == 0 && a / ng >= gi) || a == 1)
    {
        // Rank-aligned: accept larger extents (stride-discarded
        // tails) and extent-1 broadcasts.
        (0..nd)
            .map(|i| {
                let a = in_dims[i];
                if a == 1 && exp_in[i] > 1 {
                    broadcast[i] = true;
                    group_in[i]
                } else {
                    a / ngs[i]
                }
            })
            .collect()
    } else {
        // Squeezed alignment: match non-unit dimensions positionally.
        let kept: Vec<usize> = (0..nd).filter(|&i| exp_in[i] > 1).collect();
        let sq: Vec<usize> = in_dims.iter().copied().filter(|&d| d > 1).collect();
        ensure!(
            sq.len() == kept.len(),
            "{}: input tensor {:?} does not fit expected extents {:?}",
            op.name,
            in_dims,
            exp_in
        );
        let mut actual = group_in.clone();
        for (&i, &a) in kept.iter().zip(&sq) {
            ensure!(
                a % ngs[i] == 0 && a / ngs[i] >= group_in[i],
                "{}: input extent {} under-covers dimension {} (need ≥ {})",
                op.name,
                a,
                op.dims[i].0,
                exp_in[i]
            );
            actual[i] = a / ngs[i];
        }
        actual
    };
    // Layout extents of the bound tensor (broadcast dims occupy one
    // slot); strides over these, zeroed where broadcasting.
    let in_full: Vec<usize> = (0..nd)
        .map(|i| if broadcast[i] { 1 } else { ngs[i] * in_actual[i] })
        .collect();
    ensure!(
        in_full.iter().product::<usize>() == elements,
        "{}: input has {} elements, bound extents {:?} need {}",
        op.name,
        elements,
        in_full,
        in_full.iter().product::<usize>()
    );
    Ok(InputLayout { in_actual, broadcast, in_full })
}

impl BoundPlan {
    /// Bind `op` to an input of extents `in_dims` (`in_elements`
    /// total). Every call is counted into `binds` when one is given —
    /// the per-executor bind counters behind the serve bench's
    /// bind-amortization ratio and the "a session never rebinds after
    /// construction" test both hang off this.
    pub(super) fn bind(
        op: &GconvOp,
        in_dims: &[usize],
        in_elements: usize,
        binds: Option<&AtomicUsize>,
    ) -> Result<Self> {
        if let Some(c) = binds {
            c.fetch_add(1, Ordering::Relaxed);
        }
        ensure!(
            op.dims.len() <= MAX_DIMS,
            "{}: more than {MAX_DIMS} dimensions",
            op.name
        );
        let nd = op.dims.len();

        // Expected kernel/output extents (Table 3).
        let mut ker_ext = Vec::with_capacity(nd);
        let mut out_ext = Vec::with_capacity(nd);
        for &(_, p) in &op.dims {
            ker_ext.push(p.ng * p.nop * p.nks);
            out_ext.push(p.ng * p.nop * p.nopc);
        }

        // Bind the input layout (shape-only logic shared with the chain
        // executor's validation).
        let layout = bind_input(op, in_dims, in_elements)?;
        let InputLayout { in_actual, broadcast, in_full } = layout;
        debug_assert_eq!(in_full.iter().product::<usize>(), in_elements);

        // Kernel requirement (exact element count, checked against the
        // concrete tensor per call by [`BoundPlan::check_operands`]).
        let need_kernel = !matches!(op.main, MainOp::Pass);
        let ker_elements: usize = if need_kernel { ker_ext.iter().product() } else { 0 };

        // Resolve the scalar operators up front so the hot loops are
        // infallible and never string-match (unknown LUT names are bind
        // errors, not evaluation panics).
        let pre = match op.pre {
            PreOp::None => PreEval::None,
            PreOp::Square => PreEval::Square,
            PreOp::Mul(c) => PreEval::Mul(c),
            PreOp::Lut(name) => match LutFn::resolve(name) {
                Some(f) => PreEval::Lut(f),
                None => bail!("{}: unknown pre LUT {name:?}", op.name),
            },
            PreOp::Stack(s) => PreEval::Stack(StackEval::resolve(&op.name, "pre", &s)?),
        };
        let post = match op.post {
            PostOp::None => PostEval::None,
            PostOp::Mul(c) => PostEval::Mul(c),
            PostOp::Lut(name) => match LutFn::resolve(name) {
                Some(f) => PostEval::Lut(f),
                None => bail!("{}: unknown post LUT {name:?}", op.name),
            },
            PostOp::Stack(s) => PostEval::Stack(StackEval::resolve(&op.name, "post", &s)?),
        };

        let nks: Vec<usize> = op.dims.iter().map(|&(_, p)| p.nks).collect();
        let red_total = nks.iter().product::<usize>().max(1);
        ensure!(
            op.reduce != ReduceOp::None || red_total == 1,
            "{}: reduce None with a non-trivial Nks loop ({red_total} steps)",
            op.name
        );

        let out_strides = row_major_strides(&out_ext);
        let in_strides = row_major_strides(&in_full);
        let ker_strides = row_major_strides(&ker_ext);
        let red_strides = row_major_strides(&nks);

        let dims: Vec<LoopDim> = (0..nd)
            .map(|i| {
                let p = op.dims[i].1;
                LoopDim {
                    ng: p.ng,
                    nop: p.nop,
                    nopc: p.nopc,
                    nks: p.nks,
                    s: p.s,
                    ps: p.ps,
                    npc: p.nop * p.nopc,
                    out_ext: out_ext[i],
                    out_stride: out_strides[i],
                    in_actual: in_actual[i],
                    in_stride: if broadcast[i] { 0 } else { in_strides[i] },
                    ker_stride: ker_strides[i],
                    red_stride: red_strides[i],
                }
            })
            .collect();

        let out_total: usize = out_ext.iter().product();
        let out_dims = if nd == 0 { vec![1] } else { out_ext };
        // Tier selection is a pure shape/operator property: the dense
        // dot/GEMM path for `Mul`+`Add` reductions long enough to
        // amortize panel packing, the odometer path for every other
        // nest, the naive oracle for degenerate 0-dimension plans.
        let tier = if nd == 0 {
            KernelTier::Naive
        } else if op.main == MainOp::Mul
            && op.reduce == ReduceOp::Add
            && ker_elements > 0
            && red_total >= GEMM_MIN_REDUCTION
        {
            KernelTier::Gemm
        } else {
            KernelTier::Odometer
        };
        Ok(BoundPlan {
            name: op.name.clone(),
            main: op.main,
            reduce: op.reduce,
            pre,
            post,
            dims,
            out_dims,
            out_total,
            red_total,
            in_elements,
            ker_elements,
            tier,
            prepacked: None,
        })
    }

    /// Which execution tier evaluation picks for this plan.
    pub(super) fn tier(&self, force_naive: bool) -> KernelTier {
        if force_naive {
            KernelTier::Naive
        } else {
            self.tier
        }
    }

    /// Pack the (frozen) kernel operand into a plan-owned slab so no
    /// subsequent eval repacks it. A no-op off the GEMM tier — only
    /// that tier consumes packed rows. Re-invoking replaces the slab
    /// (how `Session::set_weights` keeps a plan in sync when weights
    /// are swapped). Every pack is counted into `prepacks` when a
    /// counter is given; the "steady-state runs never repack" test
    /// hangs off that counter staying flat across `Session::run`s.
    pub(super) fn prepack(
        &mut self,
        kernel: &Tensor,
        prepacks: Option<&AtomicUsize>,
    ) -> Result<()> {
        if self.tier != KernelTier::Gemm {
            return Ok(());
        }
        ensure!(
            kernel.elements() == self.ker_elements,
            "{}: kernel has {} elements, the bound layout needs {}",
            self.name,
            kernel.elements(),
            self.ker_elements
        );
        if let Some(c) = prepacks {
            c.fetch_add(1, Ordering::Relaxed);
        }
        let packed = kernels::pack_weights(self, kernel.data());
        self.prepacked = Some(packed);
        Ok(())
    }

    /// Check concrete operand tensors against the bound layout. Only
    /// element counts are compared — the expensive shape work happened
    /// once at bind time, which is what makes a stored plan cheap to
    /// re-run against fresh buffers.
    pub(super) fn check_operands(&self, input: &Tensor, kernel: Option<&Tensor>) -> Result<()> {
        ensure!(
            input.elements() == self.in_elements,
            "{}: input has {} elements, the bound layout needs {}",
            self.name,
            input.elements(),
            self.in_elements
        );
        if self.ker_elements > 0 {
            let k = kernel.with_context(|| {
                format!("{}: main {:?} needs a kernel operand", self.name, self.main)
            })?;
            ensure!(
                k.elements() == self.ker_elements,
                "{}: kernel has {} elements, expected {}",
                self.name,
                k.elements(),
                self.ker_elements
            );
        }
        Ok(())
    }
}

impl Plan<'_> {
    /// Evaluate output element `o` (flat row-major index) — the naive
    /// reference oracle: per-element div/mod coordinate decomposition
    /// and per-step stride recomputation. The fast tiers in
    /// `super::kernels` must match it bit-for-bit.
    #[inline]
    pub(super) fn eval_one(&self, o: usize) -> f32 {
        let bound = self.bound;
        // Decompose the output coordinate per dimension.
        debug_assert!(bound.dims.len() <= MAX_DIMS);
        let mut in_base = [0usize; MAX_DIMS]; // group offset (elements)
        let mut pos0 = [0i64; MAX_DIMS]; // window start within the group
        let mut ker_base = [0usize; MAX_DIMS];
        for (i, d) in bound.dims.iter().enumerate() {
            let oc = (o / d.out_stride) % d.out_ext;
            let g = oc / d.npc;
            let r = oc % d.npc;
            let kop = r / d.nopc;
            let opc = r % d.nopc;
            in_base[i] = g * d.in_actual;
            pos0[i] = (opc * d.s) as i64 - d.ps as i64;
            ker_base[i] = (g * d.nop + kop) * d.nks;
        }

        let reduce = bound.reduce;
        let mut acc: f64 = match reduce {
            ReduceOp::Max => f64::NEG_INFINITY,
            _ => 0.0,
        };
        let mut any = false;
        for r in 0..bound.red_total {
            let mut x_idx = 0usize;
            let mut w_idx = 0usize;
            let mut oob = false;
            for (i, d) in bound.dims.iter().enumerate() {
                let ks = (r / d.red_stride) % d.nks;
                let pos = pos0[i] + ks as i64;
                if pos < 0 || pos >= d.in_actual as i64 {
                    oob = true;
                } else {
                    x_idx += (in_base[i] + pos as usize) * d.in_stride;
                }
                w_idx += (ker_base[i] + ks) * d.ker_stride;
            }
            if oob && reduce == ReduceOp::Max {
                continue; // max pooling ignores padding
            }
            let mut x = 0.0;
            if !oob {
                x = self.xs[x_idx];
            }
            let a = bound.pre.apply(x);
            let m = match self.ws {
                Some(ws) => main_apply(bound.main, a, ws[w_idx]),
                None => main_apply(bound.main, a, 0.0),
            };
            match reduce {
                ReduceOp::Add => acc += m as f64,
                ReduceOp::Max => acc = acc.max(m as f64),
                ReduceOp::None => acc = m as f64,
            }
            any = true;
        }
        if !any {
            acc = 0.0; // fully padded window (degenerate BP edge)
        }
        bound.post.apply(acc as f32)
    }
}

/// Evaluate one GCONV over concrete tensors, dispatching to the fastest
/// applicable execution tier (see `super::kernels`).
///
/// `input` must cover the op's expected input extents (Table 3); larger
/// extents along sliding-window dimensions are accepted (see the module
/// docs). `kernel` is required exactly when the `main` operator consumes
/// a kernel operand (i.e. it is not [`MainOp::Pass`]).
///
/// Every tier accumulates in `f64` in the same reduction order, so long
/// `Add` chains (e.g. FC layers reducing over thousands of inputs) keep
/// well below the 1e-4 tolerance the golden tests pin — and all tiers
/// produce bit-identical results.
///
/// Output extents are `Ng·Nop·Nopc` per dimension, in the op's dimension
/// order. Independent output elements are computed in parallel with
/// rayon.
pub fn eval_gconv(op: &GconvOp, input: &Tensor, kernel: Option<&Tensor>) -> Result<Tensor> {
    eval_in(op, input, kernel, None, false, Precision::BitExact)
}

/// Evaluate one GCONV with the naive per-element oracle, bypassing the
/// fast tiers. Retained for differential testing: the property tests
/// assert the fast paths match this bit-for-bit.
pub fn eval_gconv_naive(op: &GconvOp, input: &Tensor, kernel: Option<&Tensor>) -> Result<Tensor> {
    eval_in(op, input, kernel, None, true, Precision::BitExact)
}

/// [`eval_gconv`] under an explicit [`Precision`]. Only the GEMM tier
/// reacts to the knob; every other tier is bit-exact regardless. The
/// Fast-vs-BitExact differential property test drives this entry point.
pub fn eval_gconv_with_precision(
    op: &GconvOp,
    input: &Tensor,
    kernel: Option<&Tensor>,
    precision: Precision,
) -> Result<Tensor> {
    eval_in(op, input, kernel, None, false, precision)
}

/// Which execution tier [`eval_gconv`] would pick for this op/tensor
/// binding (exposed for tests, benches and instrumentation).
pub fn plan_tier(op: &GconvOp, input: &Tensor, kernel: Option<&Tensor>) -> Result<KernelTier> {
    let bound = BoundPlan::bind(op, input.dims(), input.elements(), None)?;
    bound.check_operands(input, kernel)?;
    Ok(bound.tier(false))
}

/// Full-control evaluation entry point: optional buffer pool for the
/// output allocation and GEMM scratch, optional forcing of the naive
/// oracle tier, explicit GEMM precision.
pub(super) fn eval_in(
    op: &GconvOp,
    input: &Tensor,
    kernel: Option<&Tensor>,
    pool: Option<&BufferPool>,
    force_naive: bool,
    precision: Precision,
) -> Result<Tensor> {
    eval_counted(op, input, kernel, pool, force_naive, precision, None)
}

/// [`eval_in`] with an attributed bind counter: the one-shot path binds
/// a fresh plan on every call, and the chain executor counts those
/// binds so the serve bench can report how much of that work a
/// [`super::serve::Session`] amortizes away.
pub(super) fn eval_counted(
    op: &GconvOp,
    input: &Tensor,
    kernel: Option<&Tensor>,
    pool: Option<&BufferPool>,
    force_naive: bool,
    precision: Precision,
    binds: Option<&AtomicUsize>,
) -> Result<Tensor> {
    let bound = BoundPlan::bind(op, input.dims(), input.elements(), binds)?;
    eval_bound(&bound, input, kernel, pool, force_naive, precision)
}

/// Evaluate a *pre-bound* plan against concrete operand tensors: the
/// bind-once/run-many half of the calling convention. No shape
/// analysis, no LUT resolution, no stride computation, no weight
/// packing when the plan carries a prepacked slab — only an
/// element-count check, an output buffer (pooled when available) and
/// the tier dispatch.
pub(super) fn eval_bound(
    bound: &BoundPlan,
    input: &Tensor,
    kernel: Option<&Tensor>,
    pool: Option<&BufferPool>,
    force_naive: bool,
    precision: Precision,
) -> Result<Tensor> {
    faults::trip(faults::SITE_KERNELS_EVAL)?;
    bound.check_operands(input, kernel)?;
    if bound.out_total == 0 {
        bail!("{}: empty output", bound.name);
    }
    let mut data = match pool {
        Some(p) => p.take(bound.out_total),
        None => vec![0.0; bound.out_total],
    };
    debug_assert_eq!(data.len(), bound.out_total);
    let ws = if bound.ker_elements > 0 {
        kernel.map(|k| k.data())
    } else {
        None
    };
    let plan = Plan { bound, xs: input.data(), ws };
    let tier = bound.tier(force_naive);
    // Disarmed (the default), this profiling hook costs exactly one
    // relaxed load; armed, it times the kernel dispatch and feeds the
    // per-tier histogram in the global registry. Either way the kernel
    // sees identical operands and buffers — output bits cannot change.
    let span = crate::obs::profiling().then(crate::obs::Span::start);
    match tier {
        KernelTier::Gemm => kernels::eval_gemm(&plan, pool, precision, &mut data),
        KernelTier::Odometer => kernels::eval_odometer(&plan, &mut data),
        KernelTier::Naive => kernels::eval_naive(&plan, &mut data),
    }
    if let Some(span) = span {
        kernel_hist(tier).record(span.elapsed_ns());
    }
    Tensor::new(&bound.out_dims, data)
}

/// Cached global-registry handles for the per-tier kernel histograms,
/// so the armed profiling path never re-locks the registry.
fn kernel_hist(tier: KernelTier) -> &'static crate::obs::Hist {
    use std::sync::OnceLock;
    static GEMM: OnceLock<std::sync::Arc<crate::obs::Hist>> = OnceLock::new();
    static ODOMETER: OnceLock<std::sync::Arc<crate::obs::Hist>> = OnceLock::new();
    static NAIVE: OnceLock<std::sync::Arc<crate::obs::Hist>> = OnceLock::new();
    match tier {
        KernelTier::Gemm => GEMM.get_or_init(|| crate::obs::hist("gconv_kernel_gemm_ns")),
        KernelTier::Odometer => {
            ODOMETER.get_or_init(|| crate::obs::hist("gconv_kernel_odometer_ns"))
        }
        KernelTier::Naive => NAIVE.get_or_init(|| crate::obs::hist("gconv_kernel_naive_ns")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::gconv::op::{DataRef, DimParams};
    use crate::ir::Dim;

    fn xref() -> DataRef {
        DataRef::External("x".into())
    }

    fn wref() -> DataRef {
        DataRef::Weights("w".into())
    }

    #[test]
    fn identity_pass_copies_input() {
        let op = GconvOp {
            name: "copy".into(),
            dims: vec![(Dim::C, DimParams::opc(4))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        let x = Tensor::new(&[4], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        let y = eval_gconv(&op, &x, None).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn relu_post_clamps_negatives() {
        let op = GconvOp {
            name: "relu".into(),
            dims: vec![(Dim::C, DimParams::opc(4))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::Lut("relu"),
            input: xref(),
            kernel: None,
        };
        let x = Tensor::new(&[4], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        let y = eval_gconv(&op, &x, None).unwrap();
        assert_eq!(y.data(), &[1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn one_d_sliding_window_convolves() {
        // Nopc=3, Nks=2, s=1: y[i] = x[i]·w[0] + x[i+1]·w[1].
        let dims = vec![(Dim::W, DimParams::window(3, 2, 1, 0))];
        let op = GconvOp::conv("conv1d", dims, xref(), wref());
        let x = Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::new(&[2], vec![10.0, 1.0]).unwrap();
        let y = eval_gconv(&op, &x, Some(&w)).unwrap();
        assert_eq!(y.data(), &[12.0, 23.0, 34.0]);
    }

    #[test]
    fn zero_padding_contributes_zero_under_add() {
        // Nopc=3, Nks=3, s=1, ps=1 over 3 inputs, all-ones kernel:
        // y = [x0+x1, x0+x1+x2, x1+x2].
        let dims = vec![(Dim::W, DimParams::window(3, 3, 1, 1))];
        let op = GconvOp::conv("pad", dims, xref(), wref());
        let x = Tensor::new(&[3], vec![1.0, 2.0, 4.0]).unwrap();
        let w = Tensor::filled(&[3], 1.0);
        let y = eval_gconv(&op, &x, Some(&w)).unwrap();
        assert_eq!(y.data(), &[3.0, 7.0, 6.0]);
    }

    #[test]
    fn max_reduce_skips_padding() {
        // All-negative inputs with a padded window: padding must NOT
        // contribute a zero under Max reduction.
        let op = GconvOp {
            name: "maxpad".into(),
            dims: vec![(Dim::W, DimParams::window(2, 3, 2, 1))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::Max,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        let x = Tensor::new(&[3], vec![-5.0, -2.0, -7.0]).unwrap();
        let y = eval_gconv(&op, &x, None).unwrap();
        assert_eq!(y.data(), &[-2.0, -2.0]);
    }

    #[test]
    fn groups_isolate_kernels_and_inputs() {
        // Ng=2 over 4 inputs, Nks=2 kernel covering each group:
        // y[g] = x[2g]·w[2g] + x[2g+1]·w[2g+1].
        let dims = vec![(Dim::C, DimParams::g_ks(2, 2))];
        let op = GconvOp::conv("grouped", dims, xref(), wref());
        let x = Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let wdata = vec![1.0, 10.0, 100.0, 1000.0];
        let w = Tensor::new(&[4], wdata).unwrap();
        let y = eval_gconv(&op, &x, Some(&w)).unwrap();
        assert_eq!(y.data(), &[21.0, 4300.0]);
    }

    #[test]
    fn nop_applies_parallel_kernels_to_shared_input() {
        // Nop=2, Nks=3: two dot products over the same input.
        let dims = vec![(Dim::C, DimParams::op_ks(2, 3))];
        let op = GconvOp::conv("fc", dims, xref(), wref());
        let x = Tensor::new(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let wdata = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let w = Tensor::new(&[2, 3], wdata).unwrap();
        let y = eval_gconv(&op, &x, Some(&w)).unwrap();
        assert_eq!(y.data(), &[1.0, 6.0]);
    }

    #[test]
    fn oversized_input_discards_tail_rows() {
        // Stride-2 window covering 3 of 4 inputs: the 4th is never read.
        let op = GconvOp {
            name: "tail".into(),
            dims: vec![(Dim::W, DimParams::window(2, 1, 2, 0))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        // Covered extent = (2-1)·2 + 1 = 3; give 4.
        let x = Tensor::new(&[4], vec![9.0, 8.0, 7.0, 6.0]).unwrap();
        let y = eval_gconv(&op, &x, None).unwrap();
        assert_eq!(y.data(), &[9.0, 7.0]);
    }

    #[test]
    fn rank_aligned_unit_extent_broadcasts() {
        // GlobalAvgPool-BP idiom: spread one gradient value (extent 1)
        // over the full output extent with a pre-scale.
        let op = GconvOp {
            name: "gapbp".into(),
            dims: vec![(Dim::C, DimParams::opc(2)), (Dim::W, DimParams::opc(3))],
            pre: PreOp::Mul(0.5),
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        let x = Tensor::new(&[2, 1], vec![2.0, 4.0]).unwrap();
        let y = eval_gconv(&op, &x, None).unwrap();
        assert_eq!(y.dims(), &[2, 3]);
        assert_eq!(y.data(), &[1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn missing_kernel_is_rejected() {
        let dims = vec![(Dim::C, DimParams::ks(2))];
        let op = GconvOp::conv("needsw", dims, xref(), wref());
        let x = Tensor::zeros(&[2]);
        assert!(eval_gconv(&op, &x, None).is_err());
    }

    #[test]
    fn wrong_kernel_size_is_rejected() {
        let dims = vec![(Dim::C, DimParams::ks(2))];
        let op = GconvOp::conv("badw", dims, xref(), wref());
        let x = Tensor::zeros(&[2]);
        let w = Tensor::zeros(&[3]);
        assert!(eval_gconv(&op, &x, Some(&w)).is_err());
    }

    #[test]
    fn under_covering_input_is_rejected() {
        let op = GconvOp {
            name: "short".into(),
            dims: vec![(Dim::W, DimParams::window(4, 2, 1, 0))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        let x = Tensor::zeros(&[3]); // needs 5
        assert!(eval_gconv(&op, &x, None).is_err());
    }

    #[test]
    fn squared_diff_and_scalar_ops_apply() {
        let op = GconvOp {
            name: "sqdiff".into(),
            dims: vec![(Dim::C, DimParams::g(3))],
            pre: PreOp::Mul(2.0),
            main: MainOp::SquareDiff,
            reduce: ReduceOp::None,
            post: PostOp::Mul(0.5),
            input: xref(),
            kernel: Some(wref()),
        };
        let x = Tensor::new(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let w = Tensor::new(&[3], vec![0.0, 4.0, 6.0]).unwrap();
        // 0.5·(2x − w)²
        let y = eval_gconv(&op, &x, Some(&w)).unwrap();
        assert_eq!(y.data(), &[2.0, 0.0, 0.0]);
    }

    #[test]
    fn lut_definitions_are_sane() {
        assert_eq!(lut_apply("relu", -3.0).unwrap(), 0.0);
        assert!((lut_apply("sigmoid", 0.0).unwrap() - 0.5).abs() < 1e-7);
        assert!((lut_apply("recip", 4.0).unwrap() - 0.25).abs() < 1e-7);
        let rsqrt = lut_apply("rsqrt_eps", 1.0).unwrap();
        assert!((rsqrt - 1.0 / (1.0f32 + BN_EPS).sqrt()).abs() < 1e-7);
        assert_eq!(lut_apply("fused", 1.25).unwrap(), 1.25);
        assert!(lut_known("exp") && !lut_known("nope"));
    }

    #[test]
    fn lut_known_stays_in_sync_with_resolution() {
        for f in LutFn::ALL {
            assert_eq!(LutFn::resolve(f.name()), Some(f));
            assert!(lut_known(f.name()), "{} must be known", f.name());
            assert!(lut_apply(f.name(), 0.5).is_ok());
        }
        assert!(!lut_known("warp_drive"));
        assert!(lut_apply("warp_drive", 0.5).is_err());
    }

    #[test]
    fn unknown_lut_rejected_at_bind() {
        let op = GconvOp {
            name: "bad".into(),
            dims: vec![(Dim::C, DimParams::opc(2))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::Lut("warp_drive"),
            input: xref(),
            kernel: None,
        };
        assert!(eval_gconv(&op, &Tensor::zeros(&[2]), None).is_err());
    }

    #[test]
    fn composed_stacks_apply_in_order() {
        use crate::gconv::op::{ScalarStage, StageStack};
        // post = relu ∘ (×−1): out = relu(−x·x... ) — pre Square then
        // post stack [Mul(−1), Lut(relu)] gives relu(−x²) = 0 for all x,
        // and [Lut(relu), Mul(−1)] gives −relu(x²) = −x².
        let mut neg_then_relu = StageStack::empty();
        neg_then_relu.push(ScalarStage::Mul(-1.0));
        neg_then_relu.push(ScalarStage::Lut("relu"));
        let mut relu_then_neg = StageStack::empty();
        relu_then_neg.push(ScalarStage::Lut("relu"));
        relu_then_neg.push(ScalarStage::Mul(-1.0));
        let op = |stack| GconvOp {
            name: "stacked".into(),
            dims: vec![(Dim::C, DimParams::opc(3))],
            pre: PreOp::Square,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::Stack(stack),
            input: xref(),
            kernel: None,
        };
        let x = Tensor::new(&[3], vec![1.0, -2.0, 3.0]).unwrap();
        let a = eval_gconv(&op(neg_then_relu), &x, None).unwrap();
        assert_eq!(a.data(), &[0.0, 0.0, 0.0]);
        let b = eval_gconv(&op(relu_then_neg), &x, None).unwrap();
        assert_eq!(b.data(), &[-1.0, -4.0, -9.0]);
    }

    #[test]
    fn unknown_stack_lut_rejected_at_bind() {
        use crate::gconv::op::{ScalarStage, StageStack};
        let mut stack = StageStack::empty();
        stack.push(ScalarStage::Lut("warp_drive"));
        let op = GconvOp {
            name: "bad".into(),
            dims: vec![(Dim::C, DimParams::opc(2))],
            pre: PreOp::Stack(stack),
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        assert!(eval_gconv(&op, &Tensor::zeros(&[2]), None).is_err());
    }

    #[test]
    fn unknown_pre_lut_rejected_at_bind() {
        let op = GconvOp {
            name: "bad".into(),
            dims: vec![(Dim::C, DimParams::opc(2))],
            pre: PreOp::Lut("tachyon"),
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: xref(),
            kernel: None,
        };
        assert!(eval_gconv(&op, &Tensor::zeros(&[2]), None).is_err());
    }

    #[test]
    fn multi_dim_conv_matches_hand_computation() {
        // 2 output channels, 1 input channel, 2×2 kernels over 3×3.
        let dims = vec![
            (Dim::C, DimParams::op_ks(2, 1)),
            (Dim::H, DimParams::window(2, 2, 1, 0)),
            (Dim::W, DimParams::window(2, 2, 1, 0)),
        ];
        let op = GconvOp::conv("conv2d", dims, xref(), wref());
        let x = Tensor::from_fn(&[1, 3, 3], |i| (i + 1) as f32);
        // w0 = identity-diagonal, w1 = all ones.
        let wdata = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let w = Tensor::new(&[2, 2, 2], wdata).unwrap();
        let y = eval_gconv(&op, &x, Some(&w)).unwrap();
        assert_eq!(y.dims(), &[2, 2, 2]);
        assert_eq!(y.data(), &[6.0, 8.0, 12.0, 14.0, 12.0, 16.0, 24.0, 28.0]);
    }
}
