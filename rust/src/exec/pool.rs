//! Size-bucketed recycling pool for intermediate `f32` buffers.
//!
//! A GCONV chain allocates one output buffer per entry per run;
//! steady-state serving (the coordinator re-runs the same chain on every
//! batch) would otherwise allocate and free the identical set of buffers
//! each step. The pool shelves freed buffers by exact element count and
//! hands them back on the next request, so a warmed-up chain run
//! allocates no fresh intermediate buffers. The GEMM tier's eval
//! scratch (on-the-fly weight packs and the per-shard input panels)
//! rides the same shelf; its bind-time weight slabs do not — they are
//! owned by the plan and live for the plan's whole life.
//!
//! Recycled buffers come back with **stale contents**: every execution
//! tier writes all of its output elements exactly once, which is why
//! [`BufferPool::take`] does not zero what it recycles (the
//! re-execution tests in `chain_exec` pin that reuse stays
//! bit-identical).
//!
//! Shelved buffers carry the *run epoch* they were last recycled in
//! ([`BufferPool::begin_run`]); [`BufferPool::trim_stale`] drops
//! everything older than the current epoch, which is how the executor's
//! high-water trim policy keeps the shelf from growing monotonically
//! when one pool serves differently-shaped workloads over its lifetime
//! (see `chain_exec::TrimPolicy`).
//!
//! The pool is `Sync` and every method takes `&self`, so one pool can
//! back many executors: the serving layer (`super::serve`) shares one
//! `Arc<BufferPool>` across all of an engine's sessions — sessions of
//! different batch sizes recycle each other's buffers, and a
//! high-water-trimming session releases a larger, no-longer-served
//! session's shelf instead of holding it forever.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::faults;

/// Global-registry mirrors of the pool counters (`gconv_pool_*`),
/// summed across every pool in the process. [`PoolStats`] stays the
/// per-pool truth the conformance tests assert on; the mirrors feed
/// the metrics frame. Handles are cached so the hot path stays one
/// relaxed `fetch_add` per event.
struct PoolMetrics {
    hits: Arc<crate::obs::Counter>,
    misses: Arc<crate::obs::Counter>,
    recycled: Arc<crate::obs::Counter>,
    dropped: Arc<crate::obs::Counter>,
    trimmed: Arc<crate::obs::Counter>,
}

fn pool_metrics() -> &'static PoolMetrics {
    static M: OnceLock<PoolMetrics> = OnceLock::new();
    M.get_or_init(|| PoolMetrics {
        hits: crate::obs::counter("gconv_pool_hits"),
        misses: crate::obs::counter("gconv_pool_misses"),
        recycled: crate::obs::counter("gconv_pool_recycled"),
        dropped: crate::obs::counter("gconv_pool_dropped"),
        trimmed: crate::obs::counter("gconv_pool_trimmed"),
    })
}

/// Bytes the default pool will shelve before dropping returned buffers.
const DEFAULT_CAPACITY_BYTES: usize = 256 << 20;

/// Cumulative allocation counters (see [`BufferPool::stats`]). The
/// `misses` counter is the pool's allocation count: a run that adds no
/// misses performed no fresh intermediate allocations.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served from the shelf (no allocation).
    pub hits: usize,
    /// `take` calls that had to allocate fresh.
    pub misses: usize,
    /// Buffers accepted back by `put`.
    pub recycled: usize,
    /// Buffers rejected by `put` because the pool was at capacity.
    pub dropped: usize,
    /// Buffers released by `trim_stale`/`trim_all` (the executor's
    /// high-water / clear trim policies).
    pub trimmed: usize,
}

struct PoolShelf {
    /// element count → shelved buffers tagged with their last-use epoch.
    buckets: HashMap<usize, Vec<(u64, Vec<f32>)>>,
    held_bytes: usize,
    epoch: u64,
    stats: PoolStats,
}

/// A thread-safe, size-bucketed `Vec<f32>` recycler.
pub struct BufferPool {
    capacity_bytes: usize,
    shelf: Mutex<PoolShelf>,
}

impl BufferPool {
    /// Pool with the default capacity (256 MiB of shelved buffers).
    pub fn new() -> Self {
        BufferPool::with_capacity(DEFAULT_CAPACITY_BYTES)
    }

    /// Pool shelving at most `capacity_bytes` of returned buffers.
    pub fn with_capacity(capacity_bytes: usize) -> Self {
        let shelf = PoolShelf {
            buckets: HashMap::new(),
            held_bytes: 0,
            epoch: 0,
            stats: PoolStats::default(),
        };
        BufferPool {
            capacity_bytes,
            shelf: Mutex::new(shelf),
        }
    }

    /// A buffer of exactly `n` elements: recycled if one is shelved
    /// (contents stale — the caller overwrites every element), freshly
    /// zero-initialized otherwise.
    pub fn take(&self, n: usize) -> Vec<f32> {
        // Before the lock: an injected panic can never poison the shelf
        // mid-update (and the recovery below keeps even a poisoned
        // guard usable — every critical section leaves the shelf
        // consistent).
        faults::trip_panic(faults::SITE_POOL_ALLOC);
        let mut guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        let shelf = &mut *guard;
        if let Some(bucket) = shelf.buckets.get_mut(&n) {
            if let Some((_, buf)) = bucket.pop() {
                shelf.held_bytes -= n * 4;
                shelf.stats.hits += 1;
                drop(guard);
                pool_metrics().hits.inc();
                return buf;
            }
        }
        shelf.stats.misses += 1;
        drop(guard);
        pool_metrics().misses.inc();
        vec![0.0; n]
    }

    /// Return a buffer for reuse (stamped with the current run epoch).
    /// Empty buffers and returns that would push the pool past capacity
    /// are dropped.
    pub fn put(&self, buf: Vec<f32>) {
        let n = buf.len();
        if n == 0 {
            return;
        }
        let mut guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        let shelf = &mut *guard;
        if shelf.held_bytes + n * 4 > self.capacity_bytes {
            shelf.stats.dropped += 1;
            drop(guard);
            pool_metrics().dropped.inc();
            return;
        }
        shelf.held_bytes += n * 4;
        shelf.stats.recycled += 1;
        let epoch = shelf.epoch;
        shelf.buckets.entry(n).or_default().push((epoch, buf));
        drop(guard);
        pool_metrics().recycled.inc();
    }

    /// Open a new run epoch: buffers recycled from now on are considered
    /// part of the current working set by [`BufferPool::trim_stale`].
    pub fn begin_run(&self) {
        let mut guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        guard.epoch += 1;
    }

    /// Drop every shelved buffer that was *not* recycled in the current
    /// epoch — the high-water trim: whatever the last run actually
    /// cycled through stays, leftovers from earlier, differently-shaped
    /// workloads are released.
    pub fn trim_stale(&self) {
        let mut guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        let shelf = &mut *guard;
        let cur = shelf.epoch;
        let mut freed = 0usize;
        let mut count = 0usize;
        for (&n, bucket) in shelf.buckets.iter_mut() {
            let before = bucket.len();
            bucket.retain(|&(e, _)| e == cur);
            let dropped = before - bucket.len();
            freed += dropped * n * 4;
            count += dropped;
        }
        shelf.buckets.retain(|_, b| !b.is_empty());
        shelf.held_bytes -= freed;
        shelf.stats.trimmed += count;
        drop(guard);
        pool_metrics().trimmed.add(count as u64);
    }

    /// Drop every shelved buffer (counted as trimmed).
    pub fn trim_all(&self) {
        let mut guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        let shelf = &mut *guard;
        let count: usize = shelf.buckets.values().map(Vec::len).sum();
        shelf.buckets.clear();
        shelf.held_bytes = 0;
        shelf.stats.trimmed += count;
        drop(guard);
        pool_metrics().trimmed.add(count as u64);
    }

    /// Cumulative allocation counters.
    pub fn stats(&self) -> PoolStats {
        let guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        guard.stats
    }

    /// Bytes currently shelved.
    pub fn held_bytes(&self) -> usize {
        let guard = self.shelf.lock().unwrap_or_else(|e| e.into_inner());
        guard.held_bytes
    }

    /// Drop every shelved buffer (alias of [`BufferPool::trim_all`];
    /// cumulative counters are kept).
    pub fn clear(&self) {
        self.trim_all();
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycles_exact_sizes() {
        let pool = BufferPool::new();
        let a = pool.take(8);
        assert_eq!(a.len(), 8);
        pool.put(a);
        let b = pool.take(8);
        assert_eq!(b.len(), 8);
        let s = pool.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.recycled, 1);
    }

    #[test]
    fn sizes_do_not_cross_buckets() {
        let pool = BufferPool::new();
        pool.put(vec![1.0; 4]);
        let b = pool.take(5);
        assert_eq!(b.len(), 5);
        assert_eq!(pool.stats().misses, 1);
    }

    #[test]
    fn capacity_bounds_shelved_bytes() {
        let pool = BufferPool::with_capacity(16);
        pool.put(vec![0.0; 4]); // 16 bytes: fits exactly
        pool.put(vec![0.0; 4]); // would exceed capacity: dropped
        assert_eq!(pool.held_bytes(), 16);
        let s = pool.stats();
        assert_eq!(s.recycled, 1);
        assert_eq!(s.dropped, 1);
    }

    #[test]
    fn empty_buffers_are_ignored() {
        let pool = BufferPool::new();
        pool.put(Vec::new());
        assert_eq!(pool.stats().recycled, 0);
        assert_eq!(pool.held_bytes(), 0);
    }

    #[test]
    fn clear_empties_the_shelf() {
        let pool = BufferPool::new();
        pool.put(vec![0.0; 8]);
        pool.clear();
        assert_eq!(pool.held_bytes(), 0);
        assert_eq!(pool.stats().trimmed, 1);
        assert_eq!(pool.take(8).len(), 8);
        assert_eq!(pool.stats().hits, 0);
    }

    #[test]
    fn stale_epochs_are_trimmed_and_current_ones_kept() {
        let pool = BufferPool::new();
        pool.begin_run();
        pool.put(vec![0.0; 4]); // epoch 1
        pool.begin_run();
        pool.put(vec![0.0; 8]); // epoch 2 (current)
        pool.trim_stale();
        let s = pool.stats();
        assert_eq!(s.trimmed, 1, "{s:?}");
        assert_eq!(pool.held_bytes(), 32, "the current-epoch buffer stays");
        // The kept buffer still serves a hit.
        assert_eq!(pool.take(8).len(), 8);
        assert_eq!(pool.stats().hits, 1);
        // A re-taken-and-re-put buffer is re-stamped to the new epoch.
        pool.begin_run();
        pool.put(pool.take(16)); // miss, then put at epoch 3
        pool.trim_stale();
        assert_eq!(pool.held_bytes(), 64);
    }
}
