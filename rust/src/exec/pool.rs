//! Size-bucketed recycling pool for intermediate `f32` buffers.
//!
//! A GCONV chain allocates one output buffer per entry per run;
//! steady-state serving (the coordinator re-runs the same chain on every
//! batch) would otherwise allocate and free the identical set of buffers
//! each step. The pool shelves freed buffers by exact element count and
//! hands them back on the next request, so a warmed-up chain run
//! allocates no fresh intermediate *output* buffers. (The GEMM tier's
//! per-job packing scratch is separate and short-lived.)
//!
//! Recycled buffers come back with **stale contents**: every execution
//! tier writes all of its output elements exactly once, which is why
//! [`BufferPool::take`] does not zero what it recycles (the
//! re-execution tests in `chain_exec` pin that reuse stays
//! bit-identical).

use std::collections::HashMap;
use std::sync::Mutex;

/// Bytes the default pool will shelve before dropping returned buffers.
const DEFAULT_CAPACITY_BYTES: usize = 256 << 20;

/// Cumulative allocation counters (see [`BufferPool::stats`]). The
/// `misses` counter is the pool's allocation count: a run that adds no
/// misses performed no fresh intermediate allocations.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served from the shelf (no allocation).
    pub hits: usize,
    /// `take` calls that had to allocate fresh.
    pub misses: usize,
    /// Buffers accepted back by `put`.
    pub recycled: usize,
    /// Buffers rejected by `put` because the pool was at capacity.
    pub dropped: usize,
}

struct PoolShelf {
    buckets: HashMap<usize, Vec<Vec<f32>>>,
    held_bytes: usize,
    stats: PoolStats,
}

/// A thread-safe, size-bucketed `Vec<f32>` recycler.
pub struct BufferPool {
    capacity_bytes: usize,
    shelf: Mutex<PoolShelf>,
}

impl BufferPool {
    /// Pool with the default capacity (256 MiB of shelved buffers).
    pub fn new() -> Self {
        BufferPool::with_capacity(DEFAULT_CAPACITY_BYTES)
    }

    /// Pool shelving at most `capacity_bytes` of returned buffers.
    pub fn with_capacity(capacity_bytes: usize) -> Self {
        let shelf = PoolShelf {
            buckets: HashMap::new(),
            held_bytes: 0,
            stats: PoolStats::default(),
        };
        BufferPool {
            capacity_bytes,
            shelf: Mutex::new(shelf),
        }
    }

    /// A buffer of exactly `n` elements: recycled if one is shelved
    /// (contents stale — the caller overwrites every element), freshly
    /// zero-initialized otherwise.
    pub fn take(&self, n: usize) -> Vec<f32> {
        let mut guard = self.shelf.lock().expect("buffer pool poisoned");
        let shelf = &mut *guard;
        if let Some(bucket) = shelf.buckets.get_mut(&n) {
            if let Some(buf) = bucket.pop() {
                shelf.held_bytes -= n * 4;
                shelf.stats.hits += 1;
                return buf;
            }
        }
        shelf.stats.misses += 1;
        drop(guard);
        vec![0.0; n]
    }

    /// Return a buffer for reuse. Empty buffers and returns that would
    /// push the pool past capacity are dropped.
    pub fn put(&self, buf: Vec<f32>) {
        let n = buf.len();
        if n == 0 {
            return;
        }
        let mut guard = self.shelf.lock().expect("buffer pool poisoned");
        let shelf = &mut *guard;
        if shelf.held_bytes + n * 4 > self.capacity_bytes {
            shelf.stats.dropped += 1;
            return;
        }
        shelf.held_bytes += n * 4;
        shelf.stats.recycled += 1;
        shelf.buckets.entry(n).or_default().push(buf);
    }

    /// Cumulative allocation counters.
    pub fn stats(&self) -> PoolStats {
        let guard = self.shelf.lock().expect("buffer pool poisoned");
        guard.stats
    }

    /// Bytes currently shelved.
    pub fn held_bytes(&self) -> usize {
        let guard = self.shelf.lock().expect("buffer pool poisoned");
        guard.held_bytes
    }

    /// Drop every shelved buffer (counters are kept).
    pub fn clear(&self) {
        let mut guard = self.shelf.lock().expect("buffer pool poisoned");
        guard.buckets.clear();
        guard.held_bytes = 0;
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        BufferPool::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycles_exact_sizes() {
        let pool = BufferPool::new();
        let a = pool.take(8);
        assert_eq!(a.len(), 8);
        pool.put(a);
        let b = pool.take(8);
        assert_eq!(b.len(), 8);
        let s = pool.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.recycled, 1);
    }

    #[test]
    fn sizes_do_not_cross_buckets() {
        let pool = BufferPool::new();
        pool.put(vec![1.0; 4]);
        let b = pool.take(5);
        assert_eq!(b.len(), 5);
        assert_eq!(pool.stats().misses, 1);
    }

    #[test]
    fn capacity_bounds_shelved_bytes() {
        let pool = BufferPool::with_capacity(16);
        pool.put(vec![0.0; 4]); // 16 bytes: fits exactly
        pool.put(vec![0.0; 4]); // would exceed capacity: dropped
        assert_eq!(pool.held_bytes(), 16);
        let s = pool.stats();
        assert_eq!(s.recycled, 1);
        assert_eq!(s.dropped, 1);
    }

    #[test]
    fn empty_buffers_are_ignored() {
        let pool = BufferPool::new();
        pool.put(Vec::new());
        assert_eq!(pool.stats().recycled, 0);
        assert_eq!(pool.held_bytes(), 0);
    }

    #[test]
    fn clear_empties_the_shelf() {
        let pool = BufferPool::new();
        pool.put(vec![0.0; 8]);
        pool.clear();
        assert_eq!(pool.held_bytes(), 0);
        assert_eq!(pool.take(8).len(), 8);
        assert_eq!(pool.stats().hits, 0);
    }
}
