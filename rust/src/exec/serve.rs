//! Bind-once/run-many serving for GCONV chains.
//!
//! The paper's whole-life-cost argument (§5–§6) is that one GCONV
//! engine amortizes across every workload a user ever runs. For a
//! deployment serving sustained traffic that means the per-request cost
//! must shrink to the numerics themselves: the one-shot
//! [`ChainExec::run`] re-validates operands, re-computes reachability
//! and re-binds every entry's `Plan` on each call, which is pure
//! overhead once the chain and its operand shapes are fixed. This
//! module hoists all of that to construction time:
//!
//! * [`Session`] — a lowered (optionally fused) chain frozen at fixed
//!   operand shapes. Construction computes the needed set, the level
//!   schedule and the use counts for its `wanted` entries, validates
//!   every chain-internal operand, materializes (or synthesizes)
//!   externals, and **pre-binds an owned plan for every entry** (shape
//!   validation, LUT resolution, stride precomputation, tier choice —
//!   see `super::interp::BoundPlan`). GEMM-tier entries with frozen
//!   kernel operands also **prepack their weight panels at build**
//!   (`BoundPlan::prepack`), so [`Session::run`] never repacks
//!   weights — only [`Session::set_weights`] does, once per
//!   replacement. [`Session::run`] then executes
//!   the stored plans against fresh buffers: zero `Plan` binds after
//!   construction, pinned by the bind and prepack counters in
//!   [`SessionStats`].
//!   Special entries (argmax routing, concat) are validated up front
//!   the same way and dispatch straight to their dedicated routines.
//!   Sessions can share one [`BufferPool`] (and, via `Arc`, their
//!   weight tensors), and [`Session::recycle`] returns delivered
//!   output buffers, so steady-state serving allocates nothing.
//! * [`Engine`] — a serving frontend holding a chain cache keyed by
//!   [`ChainKey`] (network code, batch size, fuse flag). Sessions are
//!   lowered/fused/bound lazily on first use and share weight tensors
//!   across batch sizes via `Arc`. A request queue coalesces compatible
//!   single-sample requests into micro-batch runs and splits the
//!   responses back out — bit-identical to per-sample runs, which is
//!   only claimed (and tested) for chains with no cross-sample
//!   coupling; chains with batch statistics (BatchNorm) or
//!   batch-shaped externals are detected and served per-sample.
//!
//! [`ChainExec::run`]: super::chain_exec::ChainExec::run

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};
use rayon::prelude::*;

use crate::frontend::{build_with_batch, ModelSpec};
use crate::gconv::chain::{GconvChain, SpecialOp};
use crate::gconv::lower::{lower_network, Mode};
use crate::gconv::op::DataRef;
use crate::ir::{Dim, Network};
use crate::mapping::fuse_executable;
use crate::networks::{benchmark_with_batch, BENCHMARK_CODES};

use super::bench::input_spec;
use super::faults;
use super::chain_exec::{
    build_levels, collect_outputs, deps, external_specs, materialize_externals, reachable,
    use_counts, validate_chain, EntryRun, RunReport, TrimPolicy, SYNTH_SCALE, SYNTH_SEED,
};
use super::interp::{eval_bound, BoundPlan};
use super::kernels::{KernelTier, Precision};
use super::pool::{BufferPool, PoolStats};
use super::special;
use super::tensor::Tensor;

/// Global-registry mirrors of the session counters, summed across
/// every session in the process (`gconv_session_*`). The per-session
/// [`SessionStats`] stay authoritative for conformance assertions;
/// these feed the metrics frame and the `profile` CLI.
struct SessionMetrics {
    binds: Arc<crate::obs::Counter>,
    prepacks: Arc<crate::obs::Counter>,
    runs: Arc<crate::obs::Counter>,
}

fn session_metrics() -> &'static SessionMetrics {
    static M: std::sync::OnceLock<SessionMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| SessionMetrics {
        binds: crate::obs::counter("gconv_session_binds"),
        prepacks: crate::obs::counter("gconv_session_prepacks"),
        runs: crate::obs::counter("gconv_session_runs"),
    })
}

/// Global-registry mirrors of [`EngineStats`] plus the queue-wait
/// histogram (`gconv_engine_*`), summed across every engine in the
/// process.
struct EngineMetrics {
    requests: Arc<crate::obs::Counter>,
    batches: Arc<crate::obs::Counter>,
    coalesced: Arc<crate::obs::Counter>,
    sessions_built: Arc<crate::obs::Counter>,
    cache_hits: Arc<crate::obs::Counter>,
    /// Nanoseconds a request sat queued before its wave formed.
    queue_ns: Arc<crate::obs::Hist>,
}

fn engine_metrics() -> &'static EngineMetrics {
    static M: std::sync::OnceLock<EngineMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| EngineMetrics {
        requests: crate::obs::counter("gconv_engine_requests"),
        batches: crate::obs::counter("gconv_engine_batches"),
        coalesced: crate::obs::counter("gconv_engine_coalesced"),
        sessions_built: crate::obs::counter("gconv_engine_sessions_built"),
        cache_hits: crate::obs::counter("gconv_engine_cache_hits"),
        queue_ns: crate::obs::hist("gconv_engine_queue_ns"),
    })
}

/// Counters of one [`Session`]. `plan_binds` is incremented by every
/// `Plan` bind performed on the session's behalf — all of them happen
/// during construction, and the conformance tests assert the counter
/// stays flat across [`Session::run`] calls.
#[derive(Clone, Copy, Debug)]
pub struct SessionStats {
    /// Entries the session schedules per run (the needed set).
    pub entries: usize,
    /// `Plan::bind` calls performed for this session. Fixed at
    /// construction; [`Session::run`] never adds to it.
    pub plan_binds: usize,
    /// Weight-panel prepacks performed on the session's behalf: one per
    /// GEMM-tier entry with a frozen kernel operand at construction,
    /// plus one per touched plan on [`Session::set_weights`].
    /// [`Session::run`] never adds to it — the repack-free invariant
    /// the conformance tests pin.
    pub weight_prepacks: usize,
    /// Completed [`Session::run`] calls.
    pub runs: usize,
    /// Allocation counters of the session's buffer pool (shared
    /// counters when the pool is shared between sessions).
    pub pool: PoolStats,
}

/// Configures and builds a [`Session`]. Shapes freeze at
/// [`SessionBuilder::build`]: every external operand either comes from
/// the builder or is synthesized deterministically, and the plans bind
/// against those extents.
pub struct SessionBuilder {
    chain: GconvChain,
    wanted: Option<Vec<usize>>,
    externals: HashMap<DataRef, Arc<Tensor>>,
    synthesize: bool,
    synth_seed: u64,
    synth_scale: f32,
    force_naive: bool,
    trim: TrimPolicy,
    pool: Option<Arc<BufferPool>>,
    precision: Precision,
}

impl SessionBuilder {
    fn new(chain: GconvChain) -> Self {
        SessionBuilder {
            chain,
            wanted: None,
            externals: HashMap::new(),
            synthesize: true,
            synth_seed: SYNTH_SEED,
            synth_scale: SYNTH_SCALE,
            force_naive: false,
            trim: TrimPolicy::Keep,
            pool: None,
            precision: Precision::BitExact,
        }
    }

    /// Entries whose outputs every run returns (default: the last
    /// chain entry). Order and duplicates are preserved, exactly like
    /// the `wanted` argument of `ChainExec::run`.
    pub fn wanted(mut self, wanted: &[usize]) -> Self {
        self.wanted = Some(wanted.to_vec());
        self
    }

    /// Provide the network input tensor the session binds its input
    /// shape against (replaceable per run via [`Session::set_input`]
    /// with the same extents).
    pub fn input(mut self, name: &str, t: Tensor) -> Self {
        self.externals.insert(DataRef::External(name.to_string()), Arc::new(t));
        self
    }

    /// Provide a layer's trained parameters.
    pub fn weights(mut self, name: &str, t: Tensor) -> Self {
        self.externals.insert(DataRef::Weights(name.to_string()), Arc::new(t));
        self
    }

    /// Share an operand tensor with other sessions (no copy — this is
    /// how the [`Engine`] hands one weight set to every batch size).
    pub fn shared(mut self, r: DataRef, t: Arc<Tensor>) -> Self {
        self.externals.insert(r, t);
        self
    }

    /// Error on missing externals instead of synthesizing them.
    pub fn strict(mut self) -> Self {
        self.synthesize = false;
        self
    }

    /// Override the seed/scale used to synthesize missing externals.
    pub fn synthesis(mut self, seed: u64, scale: f32) -> Self {
        self.synthesize = true;
        self.synth_seed = seed;
        self.synth_scale = scale;
        self
    }

    /// Force every entry through the naive per-element oracle (the
    /// conformance suite's session-reuse-vs-oracle leg).
    pub fn naive_oracle(mut self) -> Self {
        self.force_naive = true;
        self
    }

    /// Shelf-retention policy applied after each run.
    pub fn trim(mut self, policy: TrimPolicy) -> Self {
        self.trim = policy;
        self
    }

    /// Use a shared buffer pool instead of a private one — sessions of
    /// different shapes can then recycle each other's buffers, and the
    /// `HighWater` trim keeps the shelf at the live working set.
    pub fn pool(mut self, pool: Arc<BufferPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Numeric mode of the GEMM microkernel (default
    /// [`Precision::BitExact`]). [`Precision::Fast`] trades the
    /// bit-exactness guarantee for unrolled multi-lane accumulation,
    /// bounded by the [`super::kernels::FAST_REL_TOL`] differential.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Validate, materialize and pre-bind: everything `ChainExec::run`
    /// redoes per call happens exactly once, here.
    pub fn build(self) -> Result<Session> {
        let chain = self.chain;
        ensure!(!chain.is_empty(), "cannot build a session over an empty chain");
        let n = chain.len();
        let wanted = self.wanted.unwrap_or_else(|| vec![n - 1]);
        ensure!(!wanted.is_empty(), "session needs at least one wanted entry");
        for &w in &wanted {
            ensure!(w < n, "wanted entry #{w} out of range (chain has {n})");
        }
        let needed = reachable(&chain, &wanted);
        validate_chain(&chain, &needed)?;
        // Debug builds also discharge the full static audit up front —
        // a chain the auditor cannot prove safe never reaches bind.
        #[cfg(debug_assertions)]
        {
            let cfg = crate::analysis::AuditConfig {
                wanted: Some(wanted.clone()),
                ..Default::default()
            };
            let report = crate::analysis::audit_chain_with(&chain, &cfg);
            ensure!(report.is_clean(), "static chain audit failed:\n{report}");
        }
        let mut externals = self.externals;
        materialize_externals(
            &chain,
            &needed,
            &mut externals,
            self.synthesize,
            self.synth_seed,
            self.synth_scale,
        )?;

        // Level schedule restricted to the needed set, and the per-run
        // use counts both computed once.
        let levels: Vec<Vec<usize>> = build_levels(&chain)
            .into_iter()
            .map(|l| l.into_iter().filter(|&i| needed[i]).collect::<Vec<_>>())
            .filter(|l: &Vec<usize>| !l.is_empty())
            .collect();
        let base_uses = use_counts(&chain, &needed, &wanted);

        // Pre-bind every needed loop-nest entry against its operand
        // extents; every bind is counted. GEMM-tier entries with frozen
        // (non-chain-produced) kernel operands also prepack their
        // weight panels here — the eval path then never repacks.
        // Special entries were validated by `validate_chain` and need
        // no plan.
        let binds = AtomicUsize::new(0);
        let prepacks = AtomicUsize::new(0);
        let operand_shape = |r: &DataRef| -> Result<(Vec<usize>, usize)> {
            match r {
                DataRef::Gconv(p) => {
                    let mut d = chain.entries()[*p].op.output_extents();
                    if d.is_empty() {
                        d.push(1);
                    }
                    let elems = d.iter().product();
                    Ok((d, elems))
                }
                other => {
                    let t = externals
                        .get(other)
                        .ok_or_else(|| anyhow!("external operand {other} not provided"))?;
                    Ok((t.dims().to_vec(), t.elements()))
                }
            }
        };
        let mut plans: Vec<Option<BoundPlan>> = Vec::with_capacity(n);
        let mut input_like: Vec<DataRef> = Vec::new();
        for (i, e) in chain.entries().iter().enumerate() {
            if !needed[i] || e.special.is_some() {
                plans.push(None);
                continue;
            }
            let (in_dims, in_elems) = operand_shape(&e.op.input)
                .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
            let mut bp = BoundPlan::bind(&e.op, &in_dims, in_elems, Some(&binds))
                .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
            if bp.ker_elements > 0 {
                let k = e.op.kernel.as_ref().with_context(|| {
                    format!("chain entry #{i} ({}) needs a kernel operand", e.op.name)
                })?;
                let (_, got) = operand_shape(k)
                    .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
                ensure!(
                    got == bp.ker_elements,
                    "chain entry #{i} ({}): kernel operand has {got} elements, expected {}",
                    e.op.name,
                    bp.ker_elements
                );
                // Chain-produced kernels change every run and cannot be
                // prepacked; the naive oracle never reads the packed
                // slab at all.
                if !self.force_naive && !matches!(k, DataRef::Gconv(_)) {
                    let t = externals.get(k).expect("checked by operand_shape above");
                    bp.prepack(t, Some(&prepacks))
                        .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
                }
            }
            if !matches!(e.op.input, DataRef::Gconv(_)) {
                input_like.push(e.op.input.clone());
            }
            plans.push(Some(bp));
        }

        let entries = needed.iter().filter(|&&x| x).count();
        let metrics = session_metrics();
        metrics.binds.add(binds.load(Ordering::Relaxed) as u64);
        metrics.prepacks.add(prepacks.load(Ordering::Relaxed) as u64);
        Ok(Session {
            chain,
            externals,
            wanted,
            levels,
            base_uses,
            plans,
            input_like,
            pool: self.pool.unwrap_or_else(|| Arc::new(BufferPool::new())),
            trim: self.trim,
            force_naive: self.force_naive,
            precision: self.precision,
            binds,
            prepacks,
            runs: 0,
            entries,
        })
    }
}

/// A chain frozen for serving: operand shapes fixed, schedule and use
/// counts precomputed, every entry's `Plan` pre-bound. `run` executes
/// the stored plans against fresh buffers — see the module docs.
pub struct Session {
    chain: GconvChain,
    externals: HashMap<DataRef, Arc<Tensor>>,
    wanted: Vec<usize>,
    levels: Vec<Vec<usize>>,
    base_uses: Vec<usize>,
    plans: Vec<Option<BoundPlan>>,
    /// External refs bound as loop-nest *inputs*: their extents shape
    /// the bound plans, so replacements must match dims exactly (kernel
    /// operands bind by element count only).
    input_like: Vec<DataRef>,
    pool: Arc<BufferPool>,
    trim: TrimPolicy,
    force_naive: bool,
    precision: Precision,
    binds: AtomicUsize,
    prepacks: AtomicUsize,
    runs: usize,
    entries: usize,
}

impl Session {
    /// Start configuring a session over `chain`.
    pub fn builder(chain: GconvChain) -> SessionBuilder {
        SessionBuilder::new(chain)
    }

    /// Session over `chain` with defaults: last entry wanted, missing
    /// externals synthesized deterministically, private buffer pool.
    pub fn new(chain: GconvChain) -> Result<Session> {
        SessionBuilder::new(chain).build()
    }

    /// The chain being served.
    pub fn chain(&self) -> &GconvChain {
        &self.chain
    }

    /// Replace the network input for subsequent runs. The extents must
    /// match the tensor the session was built with — plans are bound to
    /// those shapes; build a new session to serve a different shape.
    pub fn set_input(&mut self, name: &str, t: Tensor) -> Result<()> {
        self.set_external(DataRef::External(name.to_string()), Arc::new(t))
    }

    /// Replace a layer's parameters (element count must match the
    /// bound layout).
    pub fn set_weights(&mut self, name: &str, t: Tensor) -> Result<()> {
        self.set_external(DataRef::Weights(name.to_string()), Arc::new(t))
    }

    fn set_external(&mut self, r: DataRef, t: Arc<Tensor>) -> Result<()> {
        let old = self
            .externals
            .get(&r)
            .ok_or_else(|| anyhow!("session does not read operand {r}"))?;
        ensure!(
            old.elements() == t.elements(),
            "operand {r} was bound with {} elements, replacement has {}",
            old.elements(),
            t.elements()
        );
        if self.input_like.contains(&r) {
            ensure!(
                old.dims() == t.dims(),
                "input operand {r} was bound with extents {:?}, replacement has {:?} — \
                 build a new session to serve a different shape",
                old.dims(),
                t.dims()
            );
        }
        self.externals.insert(r.clone(), t.clone());
        // Plans whose kernel operand was just replaced hold a packed
        // copy of the old weights — repack them now (a per-replacement
        // cost, never a per-run one). `prepack` is a no-op off the
        // GEMM tier.
        if !self.force_naive {
            let before = self.prepacks.load(Ordering::Relaxed);
            for (i, e) in self.chain.entries().iter().enumerate() {
                if e.op.kernel.as_ref() != Some(&r) {
                    continue;
                }
                if let Some(plan) = self.plans[i].as_mut() {
                    plan.prepack(&t, Some(&self.prepacks))
                        .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
                }
            }
            let repacked = self.prepacks.load(Ordering::Relaxed) - before;
            session_metrics().prepacks.add(repacked as u64);
        }
        Ok(())
    }

    /// Execute one request over the pre-bound chain. Performs **zero**
    /// `Plan` binds, no operand re-validation and no reachability work;
    /// with a warmed pool (and outputs returned via
    /// [`Session::recycle`]) it allocates nothing either.
    pub fn run(&mut self) -> Result<RunReport> {
        self.pool.begin_run();
        let n = self.chain.len();
        let mut uses = self.base_uses.clone();
        let mut buffers: Vec<Option<Arc<Tensor>>> = (0..n).map(|_| None).collect();
        let mut records: Vec<EntryRun> = Vec::with_capacity(self.entries);
        let t_total = Instant::now();
        for level in &self.levels {
            let results: Result<Vec<(usize, Tensor, f64)>> = level
                .par_iter()
                .map(|&i| {
                    let e = &self.chain.entries()[i];
                    let input = self.operand(&e.op.input, &buffers)?;
                    let kernel = match &e.op.kernel {
                        Some(r) => Some(self.operand(r, &buffers)?),
                        None => None,
                    };
                    let t0 = Instant::now();
                    let pool = Some(self.pool.as_ref());
                    let out = match &e.special {
                        Some(sp) => special::eval_special(&e.op, sp, input, kernel, pool),
                        None => {
                            let bp = self.plans[i].as_ref().expect("needed entries pre-bind");
                            eval_bound(bp, input, kernel, pool, self.force_naive, self.precision)
                        }
                    }
                    .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
                    Ok((i, out, t0.elapsed().as_secs_f64()))
                })
                .collect();
            for (i, out, seconds) in results? {
                let e = &self.chain.entries()[i];
                records.push(EntryRun {
                    index: i,
                    name: e.op.name.clone(),
                    phase: e.phase,
                    seconds,
                    out_elements: out.elements(),
                    work: e.op.work(),
                });
                debug_assert!(uses[i] > 0, "executed entries are consumed or wanted");
                buffers[i] = Some(Arc::new(out));
            }
            for &i in level {
                for d in deps(&self.chain.entries()[i].op) {
                    uses[d] -= 1;
                    if uses[d] == 0 {
                        if let Some(t) = buffers[d].take() {
                            if let Ok(t) = Arc::try_unwrap(t) {
                                self.pool.put(t.into_data());
                            }
                        }
                    }
                }
            }
        }
        records.sort_by_key(|r| r.index);
        let outputs = collect_outputs(&self.wanted, &mut uses, &mut buffers)?;
        match self.trim {
            TrimPolicy::Keep => {}
            TrimPolicy::HighWater => self.pool.trim_stale(),
            TrimPolicy::Clear => self.pool.trim_all(),
        }
        self.runs += 1;
        session_metrics().runs.inc();
        Ok(RunReport {
            outputs,
            entries: records,
            total_s: t_total.elapsed().as_secs_f64(),
        })
    }

    /// The kernel tier each chain entry dispatches to: `None` for
    /// special entries and entries outside the needed set. Indexed by
    /// chain position, like [`EntryRun::index`] — the `profile` CLI
    /// joins the two to tag its per-layer table.
    pub fn tiers(&self) -> Vec<Option<KernelTier>> {
        self.plans.iter().map(|p| p.as_ref().map(|bp| bp.tier(self.force_naive))).collect()
    }

    /// Rebuild this session around a different `wanted` set, keeping
    /// its chain, operand tensors (including weights provided after
    /// the original build), pool and configuration. The schedule and
    /// plans are specific to the wanted set, so this is a fresh
    /// construction (it re-binds) — not a per-run cost.
    pub fn with_wanted(self, wanted: &[usize]) -> Result<Session> {
        let mut builder = SessionBuilder::new(self.chain)
            .wanted(wanted)
            .trim(self.trim)
            .pool(self.pool)
            .precision(self.precision);
        if self.force_naive {
            builder = builder.naive_oracle();
        }
        builder.externals = self.externals;
        builder.build()
    }

    /// Return a delivered report's output buffers to the pool (only
    /// uniquely-owned ones — buffers the caller still shares stay
    /// alive). With this, a steady-state serve loop performs no
    /// allocations at all from run 2 on.
    pub fn recycle(&mut self, report: RunReport) {
        self.recycle_outputs(report.outputs);
    }

    /// [`Session::recycle`] for bare output tensors.
    pub fn recycle_outputs(&mut self, outputs: Vec<Arc<Tensor>>) {
        for t in outputs {
            if let Ok(t) = Arc::try_unwrap(t) {
                self.pool.put(t.into_data());
            }
        }
    }

    /// Session counters (see [`SessionStats`]).
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            entries: self.entries,
            plan_binds: self.binds.load(Ordering::Relaxed),
            weight_prepacks: self.prepacks.load(Ordering::Relaxed),
            runs: self.runs,
            pool: self.pool.stats(),
        }
    }

    /// Look up an operand tensor for evaluation.
    fn operand<'a>(
        &'a self,
        r: &DataRef,
        buffers: &'a [Option<Arc<Tensor>>],
    ) -> Result<&'a Tensor> {
        match r {
            DataRef::Gconv(i) => buffers[*i]
                .as_deref()
                .ok_or_else(|| anyhow!("producer #{i} buffer already freed or never run")),
            other => self
                .externals
                .get(other)
                .map(|t| &**t)
                .ok_or_else(|| anyhow!("external operand {other} not provided")),
        }
    }
}

/// Chain-cache key: one [`Session`] exists per (network code, batch
/// size, fuse flag) triple, built lazily on first use.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ChainKey {
    /// Network code (a benchmark code or a registered builder name).
    pub net: String,
    /// Micro-batch size the chain was lowered for.
    pub batch: usize,
    /// Whether executable operation fusion rewrote the chain.
    pub fused: bool,
}

/// Engine counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Requests served.
    pub requests: usize,
    /// Micro-batch runs executed.
    pub batches: usize,
    /// Requests that rode in a coalesced batch (size > 1).
    pub coalesced: usize,
    /// Sessions lowered/fused/bound into the cache.
    pub sessions_built: usize,
    /// Requests served by an already-cached session.
    pub cache_hits: usize,
    /// Seconds spent executing micro-batches.
    pub exec_s: f64,
}

impl EngineStats {
    /// Requests per second over the executed batches.
    pub fn throughput(&self) -> f64 {
        if self.exec_s > 0.0 {
            self.requests as f64 / self.exec_s
        } else {
            0.0
        }
    }
}

/// One queued single-sample request.
struct Pending {
    id: u64,
    net: String,
    data: Vec<f32>,
    t0: Instant,
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct EngineResponse {
    /// Caller-assigned id.
    pub id: u64,
    /// Flattened per-sample output.
    pub data: Vec<f32>,
    /// Seconds from submit to response.
    pub latency_s: f64,
    /// Size of the micro-batch that served this request.
    pub batch: usize,
}

/// Per-network serving metadata, resolved once per code.
#[derive(Clone)]
struct NetEntry {
    input_name: String,
    sample_dims: Vec<usize>,
    sample_len: usize,
    out_len: usize,
    /// Whether micro-batching N samples is bit-identical to N separate
    /// batch-1 runs (no cross-sample coupling, batch-independent
    /// externals, batch-major output) — the coalescing gate.
    per_sample: bool,
    /// Weight tensors shared across every session of this network
    /// (batch-independent by the `per_sample` probe, or only ever used
    /// at batch 1 otherwise).
    weights: HashMap<DataRef, Arc<Tensor>>,
}

type NetBuilder = Box<dyn Fn(usize) -> Network + Send>;

/// Named request-rejection errors of [`Engine::submit`], surfaced at
/// submit time — not deferred to bind inside [`Engine::step`] — so
/// callers (the serving front's scheduler in particular) can map them
/// to structured wire errors by downcasting the returned
/// `anyhow::Error`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// No builder, spec, or benchmark code matches the request.
    UnknownModel {
        /// The code the request asked for.
        code: String,
        /// Registered codes at rejection time (sorted).
        registered: Vec<String>,
    },
    /// The flat sample payload does not match the model's input shape.
    ShapeMismatch {
        /// The code the request asked for.
        code: String,
        /// Elements the request carried.
        got: usize,
        /// Elements the registered input shape requires.
        want: usize,
    },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownModel { code, registered } => write!(
                f,
                "unknown network {code:?}: registered codes are [{}], benchmark codes \
                 are {} — use Engine::register or Engine::register_spec for custom \
                 models",
                registered.join(", "),
                BENCHMARK_CODES.join(", ")
            ),
            SubmitError::ShapeMismatch { code, got, want } => {
                write!(f, "sample for {code} has {got} values, expected {want}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Serving frontend over the session layer: a lazily-filled chain
/// cache (see [`ChainKey`]), `Arc`-shared weights, and a queue that
/// coalesces compatible single-sample requests into micro-batch
/// [`Session`] runs — see the module docs.
pub struct Engine {
    max_batch: usize,
    fuse: bool,
    trim: TrimPolicy,
    precision: Precision,
    builders: HashMap<String, NetBuilder>,
    nets: HashMap<String, NetEntry>,
    sessions: HashMap<ChainKey, Session>,
    pool: Arc<BufferPool>,
    queue: VecDeque<Pending>,
    stats: EngineStats,
}

impl Engine {
    /// Engine coalescing at most `max_batch` requests per run. The
    /// seven benchmark codes resolve automatically; other networks need
    /// [`Engine::register`].
    pub fn new(max_batch: usize) -> Engine {
        Engine {
            max_batch: max_batch.max(1),
            fuse: false,
            trim: TrimPolicy::Keep,
            precision: Precision::BitExact,
            builders: HashMap::new(),
            nets: HashMap::new(),
            sessions: HashMap::new(),
            pool: Arc::new(BufferPool::new()),
            queue: VecDeque::new(),
            stats: EngineStats::default(),
        }
    }

    /// Rewrite every lowered chain with executable operation fusion.
    pub fn with_fuse(mut self, fuse: bool) -> Self {
        self.fuse = fuse;
        self
    }

    /// Shelf-retention policy of the shared buffer pool.
    pub fn with_trim(mut self, trim: TrimPolicy) -> Self {
        self.trim = trim;
        self
    }

    /// Numeric mode every session of this engine serves with (default
    /// [`Precision::BitExact`]; see [`SessionBuilder::precision`]).
    /// Coalescing stays sample-stable under either mode — the
    /// microkernel's accumulation order per output element does not
    /// depend on the batch size.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Register a network builder under `code`. `build(batch)` must
    /// return the network lowered-to-be at that mini-batch size.
    pub fn register<F>(&mut self, code: &str, build: F)
    where
        F: Fn(usize) -> Network + Send + 'static,
    {
        self.builders.insert(code.to_string(), Box::new(build));
    }

    /// Register an imported model spec: requests for `spec.name` are
    /// served by relowering the spec at each micro-batch size (the
    /// input's `B` extent is rewritten; everything else re-infers). The
    /// spec is validated at batches 1 and 2 up front — the sizes the
    /// per-sample probe needs — so a malformed spec fails here, with
    /// context, instead of inside the serving loop. Returns the code.
    pub fn register_spec(&mut self, spec: ModelSpec) -> Result<String> {
        let code = spec.name.clone();
        for b in [1usize, 2] {
            let net = build_with_batch(&spec, Some(b))
                .with_context(|| format!("validating model spec {code:?} at batch {b}"))?;
            // The spec must also survive the static chain audit on the
            // exact chain the engine will execute (fusion included) —
            // shape inference proves the layers compose; the audit
            // proves the lowered loop nests are safe to run.
            let mut chain = lower_network(&net, Mode::Inference);
            if self.fuse {
                fuse_executable(&mut chain);
            }
            let report = crate::analysis::audit_chain(&chain);
            ensure!(
                report.is_clean(),
                "model spec {code:?} failed the static chain audit at batch {b}:\n{report}"
            );
        }
        self.register(&code, move |b| {
            build_with_batch(&spec, Some(b)).expect("spec validated at registration")
        });
        Ok(code)
    }

    /// Enqueue one single-sample request for network `code`.
    pub fn submit(&mut self, code: &str, id: u64, data: Vec<f32>) -> Result<()> {
        self.resolve_net(code)?;
        let info = &self.nets[code];
        if data.len() != info.sample_len {
            return Err(SubmitError::ShapeMismatch {
                code: code.to_string(),
                got: data.len(),
                want: info.sample_len,
            }
            .into());
        }
        self.queue.push_back(Pending {
            id,
            net: code.to_string(),
            data,
            t0: Instant::now(),
        });
        Ok(())
    }

    /// Pending queue depth.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve one micro-batch: the front request plus up to
    /// `max_batch − 1` queued requests for the same network (queue
    /// order preserved). Without `flush`, waits until a full batch of
    /// compatible requests is queued. Networks the coalescing gate
    /// rejects are served one sample at a time.
    pub fn step(&mut self, flush: bool) -> Result<Vec<EngineResponse>> {
        let Some(front) = self.queue.front() else {
            return Ok(Vec::new());
        };
        let code = front.net.clone();
        faults::trip_scoped(faults::SITE_SERVE_STEP, &code)?;
        let cap = if self.nets[&code].per_sample { self.max_batch } else { 1 };
        let picked: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, p)| p.net == code)
            .map(|(i, _)| i)
            .take(cap)
            .collect();
        if !flush && picked.len() < cap {
            return Ok(Vec::new());
        }
        let mut group: Vec<Pending> = Vec::with_capacity(picked.len());
        for &i in picked.iter().rev() {
            group.push(self.queue.remove(i).expect("picked index in range"));
        }
        group.reverse();
        self.run_group(&code, group)
    }

    /// Serve until the queue is empty.
    pub fn drain(&mut self) -> Result<Vec<EngineResponse>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.step(true)?);
        }
        Ok(all)
    }

    /// Engine counters.
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Drop every trace of `code` except its registered builder: queued
    /// requests (returning how many were discarded — the caller answers
    /// them), cached sessions, and the resolved [`NetEntry`]. This is
    /// the server supervisor's recovery hook: after a panic inside a
    /// wave the model's engine state may be mid-update, so it is
    /// rebuilt from the builder on the next request — other models'
    /// sessions are untouched and keep serving bit-identically.
    pub fn purge(&mut self, code: &str) -> usize {
        let before = self.queue.len();
        self.queue.retain(|p| p.net != code);
        let dropped = before - self.queue.len();
        self.sessions.retain(|k, _| k.net != code);
        self.nets.remove(code);
        dropped
    }

    /// Allocation counters of the shared buffer pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Resolve serving metadata for `code` (benchmark codes register
    /// themselves): input spec and per-sample output length from the
    /// batch-1 lowering, the coalescing gate from a batch-2 probe, and
    /// the shared weight set materialized once.
    fn resolve_net(&mut self, code: &str) -> Result<()> {
        if self.nets.contains_key(code) {
            return Ok(());
        }
        if !self.builders.contains_key(code) {
            if !BENCHMARK_CODES.contains(&code) {
                let mut known: Vec<String> = self.builders.keys().cloned().collect();
                known.sort_unstable();
                return Err(SubmitError::UnknownModel {
                    code: code.to_string(),
                    registered: known,
                }
                .into());
            }
            let owned = code.to_string();
            self.builders
                .insert(owned.clone(), Box::new(move |b| benchmark_with_batch(&owned, b)));
        }
        let build = &self.builders[code];
        let net1 = build(1);
        let (input_name, dims) = input_spec(&net1)?;
        ensure!(
            dims.first() == Some(&1),
            "{code}: builder ignored the batch argument (input shape {dims:?})"
        );
        let lower = |net: &Network, fuse: bool| {
            let mut chain = lower_network(net, Mode::Inference);
            if fuse {
                fuse_executable(&mut chain);
            }
            chain
        };
        let chain1 = lower(&net1, self.fuse);
        ensure!(!chain1.is_empty(), "{code}: empty inference chain");
        let out_len = chain1.entries()[chain1.len() - 1].op.output_elements();

        // Coalescing gate, probed on a batch-2 lowering: every entry
        // must carry the batch as a plain `g`/`opc` dimension (no
        // cross-sample reduction or kernel replication), the output
        // must be batch-major, and every external operand must be
        // batch-independent (a dropout mask or batch-shaped table would
        // otherwise change per-sample numerics with the batch size).
        let chain2 = lower(&build(2), self.fuse);
        let input_ref = DataRef::External(input_name.clone());
        let specs1 = external_extent_map(&chain1);
        let specs2 = external_extent_map(&chain2);
        let externals_batch_free = specs1.len() == specs2.len()
            && specs1
                .iter()
                .all(|(r, n)| *r == input_ref || specs2.get(r) == Some(n));
        let per_sample = externals_batch_free && chain_is_per_sample(&chain2, 2);

        let mut ext1 = seeded_externals(&chain1, &input_name, &dims)?;
        ext1.remove(&input_ref);
        let weights: HashMap<DataRef, Arc<Tensor>> = ext1
            .into_iter()
            .filter(|(r, _)| matches!(r, DataRef::Weights(_)))
            .collect();
        self.nets.insert(
            code.to_string(),
            NetEntry {
                input_name,
                sample_dims: dims[1..].to_vec(),
                sample_len: dims[1..].iter().product(),
                out_len,
                per_sample,
                weights,
            },
        );
        Ok(())
    }

    /// Get or lazily build the session for `key`.
    fn ensure_session(&mut self, key: &ChainKey, info: &NetEntry) -> Result<()> {
        if self.sessions.contains_key(key) {
            return Ok(());
        }
        let build = &self.builders[&key.net];
        let net = build(key.batch);
        let mut chain = lower_network(&net, Mode::Inference);
        if key.fused {
            fuse_executable(&mut chain);
        }
        let mut dims = vec![key.batch];
        dims.extend_from_slice(&info.sample_dims);
        let mut builder = Session::builder(chain)
            .input(&info.input_name, Tensor::zeros(&dims))
            .trim(self.trim)
            .pool(self.pool.clone())
            .precision(self.precision);
        for (r, t) in &info.weights {
            builder = builder.shared(r.clone(), t.clone());
        }
        let session = builder
            .build()
            .with_context(|| format!("building session for {key:?}"))?;
        self.sessions.insert(key.clone(), session);
        self.stats.sessions_built += 1;
        engine_metrics().sessions_built.inc();
        Ok(())
    }

    /// Run one coalesced group through its session and split the
    /// responses back out (order preserved).
    fn run_group(&mut self, code: &str, group: Vec<Pending>) -> Result<Vec<EngineResponse>> {
        let batch = group.len();
        // The wave has formed: each rider's queue wait ends here.
        let metrics = engine_metrics();
        let formed = Instant::now();
        for p in &group {
            let waited = formed.saturating_duration_since(p.t0);
            metrics.queue_ns.record(u64::try_from(waited.as_nanos()).unwrap_or(u64::MAX));
        }
        let info = self.nets[code].clone();
        let key = ChainKey { net: code.to_string(), batch, fused: self.fuse };
        let cached = self.sessions.contains_key(&key);
        self.ensure_session(&key, &info)?;
        if cached {
            self.stats.cache_hits += batch;
            metrics.cache_hits.add(batch as u64);
        }

        let mut data = Vec::with_capacity(batch * info.sample_len);
        for p in &group {
            data.extend_from_slice(&p.data);
        }
        let mut dims = vec![batch];
        dims.extend_from_slice(&info.sample_dims);
        let input = Tensor::new(&dims, data)?;

        let t_exec = Instant::now();
        let session = self.sessions.get_mut(&key).expect("just ensured");
        session.set_input(&info.input_name, input)?;
        let report = session.run()?;
        let exec_s = t_exec.elapsed().as_secs_f64();

        let out = &report.outputs[0];
        ensure!(
            out.elements() == batch * info.out_len,
            "{code}: batch {batch} produced {} values, expected {}",
            out.elements(),
            batch * info.out_len
        );
        let mut responses = Vec::with_capacity(batch);
        for (i, p) in group.into_iter().enumerate() {
            let start = i * info.out_len;
            responses.push(EngineResponse {
                id: p.id,
                data: out.data()[start..start + info.out_len].to_vec(),
                latency_s: p.t0.elapsed().as_secs_f64(),
                batch,
            });
        }
        session.recycle(report);
        self.stats.requests += batch;
        self.stats.batches += 1;
        metrics.requests.add(batch as u64);
        metrics.batches.inc();
        if batch > 1 {
            self.stats.coalesced += batch;
            metrics.coalesced.add(batch as u64);
        }
        self.stats.exec_s += exec_s;
        Ok(responses)
    }
}

/// Deterministically synthesized externals of a chain (the input
/// provided explicitly so its shape is the real batched shape, not the
/// covered extents).
fn seeded_externals(
    chain: &GconvChain,
    input_name: &str,
    input_dims: &[usize],
) -> Result<HashMap<DataRef, Arc<Tensor>>> {
    let wanted = [chain.len() - 1];
    let needed = reachable(chain, &wanted);
    let mut ext: HashMap<DataRef, Arc<Tensor>> = HashMap::new();
    ext.insert(
        DataRef::External(input_name.to_string()),
        Arc::new(Tensor::zeros(input_dims)),
    );
    materialize_externals(chain, &needed, &mut ext, true, SYNTH_SEED, SYNTH_SCALE)?;
    Ok(ext)
}

/// First-seen element count of every external operand a chain would
/// synthesize — the shapes of [`seeded_externals`] without generating
/// any data (the batch-independence probe only compares counts).
fn external_extent_map(chain: &GconvChain) -> HashMap<DataRef, usize> {
    let wanted = [chain.len() - 1];
    let needed = reachable(chain, &wanted);
    let mut map = HashMap::new();
    for (_, r, dims) in external_specs(chain, &needed) {
        map.entry(r).or_insert_with(|| dims.iter().product::<usize>());
    }
    map
}

/// True when a chain lowered at `batch` has no cross-sample coupling:
/// every entry carries `Dim::B` as a plain `g`/`opc` loop of extent
/// `batch` (no batch reduction, no kernel replication over the batch),
/// the final output is batch-major, and no entry routes through a
/// max-pool-BP special (whose windows could span samples). Under these
/// conditions every output element of sample `i` depends only on
/// sample `i`'s input and the shared weights, with identical reduction
/// order — so micro-batching is bit-identical to per-sample runs.
fn chain_is_per_sample(chain: &GconvChain, batch: usize) -> bool {
    let batch_major = match chain.entries().last() {
        Some(e) => matches!(e.op.dims.first(), Some(&(Dim::B, _))),
        None => false,
    };
    batch_major
        && chain.entries().iter().all(|e| {
            if matches!(e.special, Some(SpecialOp::MaxPoolBp { .. })) {
                return false;
            }
            e.op.dims.iter().any(|&(d, p)| {
                d == Dim::B && p.nks == 1 && p.nop == 1 && p.ng * p.nopc == batch
            })
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::analysis::static_tier;
    use crate::exec::{ChainExec, KernelTier, FAST_REL_TOL};
    use crate::ir::{Layer, Shape};
    use crate::networks::mobilenet_block;

    fn block_chain() -> GconvChain {
        lower_network(&mobilenet_block(2, 4, 6), Mode::Inference)
    }

    fn block_input() -> Tensor {
        Tensor::rand(&[2, 4, 6, 6], 31, 1.0)
    }

    /// A small per-sample network (conv → ReLU → FC: no batch
    /// statistics) the engine is allowed to coalesce.
    fn per_sample_net(batch: usize) -> Network {
        let mut net = Network::new("psnet");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, 2, 4, 4) }, &[]);
        let c = net.add(
            "conv",
            Layer::Conv { out_channels: 3, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
            &[i],
        );
        let r = net.add("relu", Layer::Relu, &[c]);
        net.add("fc", Layer::FullyConnected { out_features: 5 }, &[r]);
        net
    }

    #[test]
    fn session_matches_chain_exec_bitwise() {
        let mut exec = ChainExec::new(block_chain());
        exec.set_input("data.data", block_input());
        let want = exec.run_last().unwrap();

        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let got = session.run().unwrap();
        assert!(want.outputs[0].bit_eq(&got.outputs[0]));
        // Reuse stays bit-identical (stale pooled buffers, same plans).
        let again = session.run().unwrap();
        assert!(want.outputs[0].bit_eq(&again.outputs[0]));
    }

    #[test]
    fn session_never_rebinds_after_construction() {
        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let built = session.stats();
        assert!(built.plan_binds > 0, "construction pre-binds every entry");
        assert_eq!(built.plan_binds, built.entries, "one bind per needed entry");
        for _ in 0..3 {
            let report = session.run().unwrap();
            session.recycle(report);
        }
        let after = session.stats();
        assert_eq!(after.plan_binds, built.plan_binds, "run() must never bind");
        assert_eq!(after.runs, 3);

        // The one-shot executor, by contrast, rebinds every run.
        let mut exec = ChainExec::new(block_chain());
        exec.set_input("data.data", block_input());
        exec.run_last().unwrap();
        let one = exec.bind_calls();
        exec.run_last().unwrap();
        assert_eq!(exec.bind_calls(), 2 * one, "one-shot path rebinds per run");
    }

    #[test]
    fn session_prepacks_weights_once_at_build_and_never_on_run() {
        let chain = block_chain();
        let needed = reachable(&chain, &[chain.len() - 1]);
        let expected = chain
            .entries()
            .iter()
            .enumerate()
            .filter(|(i, e)| {
                needed[*i]
                    && e.special.is_none()
                    && e.op.kernel.as_ref().is_some_and(|k| !matches!(k, DataRef::Gconv(_)))
                    && static_tier(&e.op) == KernelTier::Gemm
            })
            .count();
        assert!(expected > 0, "block chain must bind GEMM entries with frozen weights");

        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let built = session.stats();
        assert_eq!(built.weight_prepacks, expected, "one prepack per bound GEMM entry");
        for _ in 0..3 {
            let report = session.run().unwrap();
            session.recycle(report);
        }
        assert_eq!(
            session.stats().weight_prepacks,
            built.weight_prepacks,
            "run() must never repack frozen weights"
        );

        // The naive oracle never reads the packed layout at all.
        let naive = Session::builder(block_chain())
            .input("data.data", block_input())
            .naive_oracle()
            .build()
            .unwrap();
        assert_eq!(naive.stats().weight_prepacks, 0);
    }

    #[test]
    fn replacing_weights_repacks_touched_plans_and_serves_the_new_weights() {
        let chain = block_chain();
        let needed = reachable(&chain, &[chain.len() - 1]);
        let mut found: Option<(String, usize)> = None;
        let mut touched = 0usize;
        for (i, e) in chain.entries().iter().enumerate() {
            let Some(DataRef::Weights(n)) = &e.op.kernel else { continue };
            if !needed[i] || static_tier(&e.op) != KernelTier::Gemm {
                continue;
            }
            if found.is_none() {
                found = Some((n.clone(), e.op.kernel_elements()));
            }
            if found.as_ref().is_some_and(|(f, _)| f == n) {
                touched += 1;
            }
        }
        let (name, elems) = found.expect("block chain has a GEMM entry with frozen weights");
        // Kernel operands bind by element count, so a flat replacement
        // of the right size is accepted.
        let replacement = Tensor::rand(&[elems], 99, 1.0);

        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let base = session.stats().weight_prepacks;
        session.set_weights(&name, replacement.clone()).unwrap();
        assert_eq!(
            session.stats().weight_prepacks,
            base + touched,
            "set_weights repacks exactly the plans reading the replaced operand"
        );
        let got = session.run().unwrap();

        // The repacked slab must actually serve the new weights: a
        // session built with the replacement from scratch (identical
        // synthesized externals otherwise) matches bit-for-bit.
        let mut fresh = Session::builder(block_chain())
            .input("data.data", block_input())
            .weights(&name, replacement)
            .build()
            .unwrap();
        let want = fresh.run().unwrap();
        assert!(got.outputs[0].bit_eq(&want.outputs[0]));
    }

    #[test]
    fn fast_precision_session_stays_within_tolerance() {
        let mut exact = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let want = exact.run().unwrap();
        let mut fast = Session::builder(block_chain())
            .input("data.data", block_input())
            .precision(Precision::Fast)
            .build()
            .unwrap();
        let got = fast.run().unwrap();
        let tol = f64::from(FAST_REL_TOL);
        for (a, b) in got.outputs[0].data().iter().zip(want.outputs[0].data()) {
            let rel = f64::from((a - b).abs()) / f64::from(b.abs()).max(1.0);
            assert!(rel <= tol, "fast={a} exact={b} rel={rel}");
        }
    }

    #[test]
    fn engine_precision_fast_stays_close_to_bitexact() {
        let sample = Tensor::rand(&[2 * 4 * 4], 77, 1.0).into_data();
        let run = |precision: Precision| {
            let mut engine = Engine::new(1).with_precision(precision);
            engine.register("ps", per_sample_net);
            engine.submit("ps", 0, sample.clone()).unwrap();
            let mut responses = engine.drain().unwrap();
            responses.remove(0).data
        };
        let exact = run(Precision::BitExact);
        let fast = run(Precision::Fast);
        let tol = f64::from(FAST_REL_TOL);
        for (a, b) in fast.iter().zip(&exact) {
            let rel = f64::from((a - b).abs()) / f64::from(b.abs()).max(1.0);
            assert!(rel <= tol, "fast={a} exact={b} rel={rel}");
        }
    }

    #[test]
    fn profiling_arm_is_output_invariant_and_allocation_free() {
        // Arming the per-entry kernel timing hooks must change nothing
        // observable about serving: outputs stay bit-identical and the
        // warmed pool still serves every buffer (no fresh allocations).
        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let disarmed = session.run().unwrap();
        let want = disarmed.outputs[0].clone();
        session.recycle(disarmed);
        let after_warmup = session.stats().pool;

        let guard = crate::obs::profile();
        let armed = session.run().unwrap();
        assert!(want.bit_eq(&armed.outputs[0]), "armed profiling changed the output bits");
        session.recycle(armed);
        let s = session.stats().pool;
        assert_eq!(s.misses, after_warmup.misses, "armed run allocated fresh buffers: {s:?}");
        // The armed run fed the kernel histograms.
        let hist_count = |name: &str| -> u64 {
            crate::obs::global()
                .snapshot()
                .into_iter()
                .find_map(|m| match m {
                    crate::obs::MetricSnapshot::Hist { name: n, count, .. } if n == name => {
                        Some(count)
                    }
                    _ => None,
                })
                .unwrap_or(0)
        };
        let timed = hist_count("gconv_kernel_gemm_ns")
            + hist_count("gconv_kernel_odometer_ns")
            + hist_count("gconv_kernel_naive_ns");
        assert!(timed > 0, "armed run recorded no kernel samples");
        drop(guard);

        // Disarmed again: still bit-identical, still allocation-free.
        let again = session.run().unwrap();
        assert!(want.bit_eq(&again.outputs[0]));
        session.recycle(again);
        assert_eq!(session.stats().pool.misses, after_warmup.misses);
    }

    #[test]
    fn session_rerun_allocates_nothing() {
        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        let r1 = session.run().unwrap();
        session.recycle(r1);
        let after_warmup = session.stats().pool;
        for k in 2..=4 {
            let r = session.run().unwrap();
            session.recycle(r);
            let s = session.stats().pool;
            assert_eq!(
                s.misses, after_warmup.misses,
                "run {k} allocated fresh buffers: {s:?}"
            );
        }
    }

    #[test]
    fn high_water_trim_releases_a_larger_sessions_buffers() {
        let pool = Arc::new(BufferPool::new());
        let big_chain = lower_network(&mobilenet_block(4, 8, 12), Mode::Inference);
        let mut big = Session::builder(big_chain)
            .input("data.data", Tensor::rand(&[4, 8, 12, 12], 5, 1.0))
            .pool(pool.clone())
            .build()
            .unwrap();
        let r = big.run().unwrap();
        big.recycle(r);
        drop(big);
        let shelved_after_big = pool.held_bytes();
        assert!(shelved_after_big > 0, "big session must shelve buffers");

        let mut small = Session::builder(block_chain())
            .input("data.data", block_input())
            .pool(pool.clone())
            .trim(TrimPolicy::HighWater)
            .build()
            .unwrap();
        let r = small.run().unwrap();
        small.recycle(r);
        let s = pool.stats();
        assert!(s.trimmed > 0, "high-water trim must drop the stale big shelf: {s:?}");
        assert!(pool.held_bytes() < shelved_after_big);
        // The small session's own working set survives and serves hits.
        let before = pool.stats().hits;
        let r = small.run().unwrap();
        small.recycle(r);
        assert!(pool.stats().hits > before);
    }

    #[test]
    fn set_input_rejects_shape_changes_and_unknown_operands() {
        let mut session = Session::builder(block_chain())
            .input("data.data", block_input())
            .build()
            .unwrap();
        // Same extents: fine.
        session.set_input("data.data", Tensor::rand(&[2, 4, 6, 6], 9, 1.0)).unwrap();
        // Different extents with the same element count: rejected for
        // a loop-nest input.
        let err = session
            .set_input("data.data", Tensor::rand(&[4, 2, 6, 6], 9, 1.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("extents"), "unexpected error: {err}");
        // Different element count: rejected.
        assert!(session.set_input("data.data", Tensor::zeros(&[2, 4, 6, 5])).is_err());
        // Operand the session never read: rejected.
        assert!(session.set_input("nope", Tensor::zeros(&[1])).is_err());
    }

    #[test]
    fn session_strict_mode_requires_externals() {
        let err = Session::builder(block_chain()).strict().build();
        assert!(err.is_err(), "strict session with no tensors must fail to build");
    }

    #[test]
    fn session_wanted_set_matches_chain_exec() {
        let chain = block_chain();
        let wanted: Vec<usize> = (0..chain.len()).collect();
        let mut exec = ChainExec::new(block_chain());
        exec.set_input("data.data", block_input());
        let want = exec.run(&wanted).unwrap();

        let mut session = Session::builder(chain)
            .wanted(&wanted)
            .input("data.data", block_input())
            .build()
            .unwrap();
        let got = session.run().unwrap();
        assert_eq!(got.outputs.len(), want.outputs.len());
        for (a, b) in got.outputs.iter().zip(&want.outputs) {
            assert!(a.bit_eq(b));
        }
    }

    #[test]
    fn engine_coalesces_per_sample_requests_bit_identically() {
        let mut engine = Engine::new(4);
        engine.register("ps", per_sample_net);
        let samples: Vec<Vec<f32>> = (0..4)
            .map(|i| Tensor::rand(&[2 * 4 * 4], 100 + i, 1.0).into_data())
            .collect();
        for (i, s) in samples.iter().enumerate() {
            engine.submit("ps", i as u64, s.clone()).unwrap();
        }
        let mut responses = engine.drain().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 4);
        assert_eq!(engine.stats().batches, 1, "per-sample net must coalesce");
        assert!(responses.iter().all(|r| r.batch == 4));

        // Reference: each sample through its own batch-1 session.
        for (i, s) in samples.iter().enumerate() {
            let mut session = Session::builder(lower_network(&per_sample_net(1), Mode::Inference))
                .input("data.data", Tensor::new(&[1, 2, 4, 4], s.clone()).unwrap())
                .build()
                .unwrap();
            let want = session.run().unwrap();
            let got = &responses[i].data;
            assert_eq!(got.len(), want.outputs[0].elements());
            let same = got
                .iter()
                .zip(want.outputs[0].data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "coalesced sample {i} diverged from its batch-1 run");
        }
    }

    #[test]
    fn engine_refuses_to_coalesce_batch_statistics() {
        // mobilenet_block carries BatchNorm: batch statistics couple
        // samples, so the engine must serve it one sample at a time.
        let mut engine = Engine::new(4);
        engine.register("bn", |b| mobilenet_block(b, 4, 6));
        for i in 0..3 {
            let x = Tensor::rand(&[4 * 6 * 6], 7 + i, 1.0).into_data();
            engine.submit("bn", i, x).unwrap();
        }
        let responses = engine.drain().unwrap();
        assert_eq!(responses.len(), 3);
        assert!(responses.iter().all(|r| r.batch == 1));
        assert_eq!(engine.stats().batches, 3);
        assert_eq!(engine.stats().coalesced, 0);
        // All three rode the same cached batch-1 session.
        assert_eq!(engine.stats().sessions_built, 1);
        assert_eq!(engine.stats().cache_hits, 2);
    }

    #[test]
    fn engine_waits_for_a_full_batch_unless_flushed() {
        let mut engine = Engine::new(3);
        engine.register("ps", per_sample_net);
        engine.submit("ps", 0, vec![0.5; 32]).unwrap();
        assert!(engine.step(false).unwrap().is_empty());
        assert_eq!(engine.pending(), 1);
        let out = engine.step(true).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(engine.pending(), 0);
    }

    #[test]
    fn engine_rejects_bad_sample_lengths_and_unknown_codes() {
        let mut engine = Engine::new(2);
        engine.register("ps", per_sample_net);
        assert!(engine.submit("ps", 0, vec![0.0; 3]).is_err());
        let err = engine.submit("no-such-net", 0, vec![0.0; 3]).unwrap_err().to_string();
        assert!(err.contains("register_spec") && err.contains("[ps]"), "{err}");
        assert_eq!(engine.pending(), 0);
    }

    #[test]
    fn engine_submit_errors_are_named_and_downcastable() {
        // The serving front maps rejections to wire error codes by
        // downcasting, so the error type — not just its text — is API.
        let mut engine = Engine::new(2);
        engine.register("ps", per_sample_net);
        let err = engine.submit("ps", 0, vec![0.0; 3]).unwrap_err();
        assert_eq!(
            err.downcast_ref::<SubmitError>(),
            Some(&SubmitError::ShapeMismatch { code: "ps".into(), got: 3, want: 32 })
        );
        assert!(err.to_string().contains("has 3 values, expected 32"), "{err}");
        let err = engine.submit("no-such-net", 0, vec![0.0; 32]).unwrap_err();
        match err.downcast_ref::<SubmitError>() {
            Some(SubmitError::UnknownModel { code, registered }) => {
                assert_eq!(code, "no-such-net");
                assert_eq!(registered, &["ps".to_string()]);
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        // Rejected submissions never reach the queue.
        assert_eq!(engine.pending(), 0);
        // A well-formed submit still works after the rejections.
        engine.submit("ps", 1, vec![0.5; 32]).unwrap();
        assert_eq!(engine.pending(), 1);
    }

    #[test]
    fn engine_throughput_guards_zero_duration_and_zero_requests() {
        let stats = EngineStats::default();
        assert_eq!(stats.throughput(), 0.0);
        let stats = EngineStats { requests: 5, exec_s: 0.0, ..EngineStats::default() };
        assert_eq!(stats.throughput(), 0.0);
        let stats = EngineStats { requests: 10, exec_s: 2.0, ..EngineStats::default() };
        assert_eq!(stats.throughput(), 5.0);
    }

    #[test]
    fn engine_serves_registered_specs_bit_identically_to_sessions() {
        // The spec describes the same conv → ReLU → FC classifier as
        // `per_sample_net`, so the engine must coalesce it and match a
        // direct Session run bit-for-bit.
        let spec = crate::frontend::export_network(&per_sample_net(1));
        let mut engine = Engine::new(2);
        let code = engine.register_spec(spec).unwrap();
        assert_eq!(code, "psnet");
        let samples: Vec<Vec<f32>> = (0..2)
            .map(|i| Tensor::rand(&[2 * 4 * 4], 40 + i, 1.0).into_data())
            .collect();
        for (i, s) in samples.iter().enumerate() {
            engine.submit(&code, i as u64, s.clone()).unwrap();
        }
        let mut responses = engine.drain().unwrap();
        responses.sort_by_key(|r| r.id);
        assert!(responses.iter().all(|r| r.batch == 2), "spec net must coalesce");
        for (i, s) in samples.iter().enumerate() {
            let mut session = Session::builder(lower_network(&per_sample_net(1), Mode::Inference))
                .input("data.data", Tensor::new(&[1, 2, 4, 4], s.clone()).unwrap())
                .build()
                .unwrap();
            let want = session.run().unwrap();
            let same = responses[i]
                .data
                .iter()
                .zip(want.outputs[0].data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "spec-served sample {i} diverged from its session run");
        }
    }

    #[test]
    fn per_sample_probe_accepts_conv_and_rejects_bn() {
        let ps = lower_network(&per_sample_net(2), Mode::Inference);
        assert!(chain_is_per_sample(&ps, 2));
        let bn = lower_network(&mobilenet_block(2, 4, 6), Mode::Inference);
        assert!(!chain_is_per_sample(&bn, 2));
    }
}
