//! Parallel GCONV-chain scheduler: execute a whole [`GconvChain`] on the
//! native interpreter.
//!
//! The chain (paper §3.2) links GCONVs by producer/consumer relations
//! ([`DataRef::Gconv`] references point backwards by construction), so
//! scheduling is a level-order walk of the dependency DAG:
//!
//! 1. every entry's *level* is `1 + max(level(deps))` — entries in the
//!    same level have no mutual data dependencies;
//! 2. a level's entries evaluate concurrently (rayon), and each entry's
//!    own output elements evaluate in parallel too (nested parallelism —
//!    rayon's work stealing balances wide levels against wide ops, which
//!    is how independent batch slices end up on separate cores);
//! 3. intermediate buffers are `Arc`-shared (multi-consumer operands and
//!    duplicated `wanted` outputs never deep-copy), reference-counted,
//!    and recycled through a size-bucketed [`BufferPool`] as soon as
//!    their last consumer has run — a warmed-up chain run allocates no
//!    fresh intermediate output buffers.
//!
//! External operands ([`DataRef::External`] / [`DataRef::Weights`]) come
//! from a tensor store filled by the caller. Anything missing is — by
//! default — synthesized deterministically from the operand name (the
//! in-repo splitmix64 generator), which makes whole-network smoke runs
//! possible without trained checkpoints; [`ChainExec::strict`] turns
//! that off for callers that want hard errors instead.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};
use rayon::prelude::*;

use crate::gconv::chain::{GconvChain, Phase, SpecialOp};
use crate::gconv::op::{DataRef, GconvOp, MainOp};

use super::interp::{bind_input, eval_counted};
use super::kernels::Precision;
use super::pool::{BufferPool, PoolStats};
use super::special;
use super::tensor::Tensor;

/// Default seed for deterministic synthesis of missing externals —
/// shared with the serving layer so a [`super::serve::Session`] and a
/// [`ChainExec`] over the same chain see identical synthesized weights
/// (the cross-engine conformance suite depends on this).
pub(super) const SYNTH_SEED: u64 = 0x6C0_17BD_600D_CAFE;
/// Default scale for synthesized externals.
pub(super) const SYNTH_SCALE: f32 = 0.1;

/// What [`ChainExec::run`] does with the buffer-pool shelf after each
/// run. A long-lived executor that served a large workload and then
/// settles into smaller ones would otherwise hold the large working set
/// forever (the shelf only grows until its byte capacity).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TrimPolicy {
    /// Keep every shelved buffer (capacity-bounded) — the default.
    #[default]
    Keep,
    /// After each run, drop the shelved buffers the run did not recycle
    /// (high-water trim: the shelf never outgrows the working set of
    /// the workload currently being served).
    HighWater,
    /// Drop every shelved buffer after each run.
    Clear,
}

/// Timing/size record of one executed chain entry.
#[derive(Clone, Debug)]
pub struct EntryRun {
    /// Chain index.
    pub index: usize,
    /// Op name (e.g. `"conv1.fp"`, `"bn3.FP2"`).
    pub name: String,
    /// FP / BP / WG.
    pub phase: Phase,
    /// Wall-clock seconds spent evaluating this entry.
    pub seconds: f64,
    /// Output elements produced.
    pub out_elements: usize,
    /// `main`-operator applications (the op's loop-nest work).
    pub work: usize,
}

/// Result of one [`ChainExec::run`]: requested output tensors plus
/// per-entry timing.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Requested outputs, parallel to the `wanted` argument of `run`.
    /// Outputs are `Arc`-shared with the executor's buffer management:
    /// listing the same entry twice yields two pointers to one buffer.
    pub outputs: Vec<Arc<Tensor>>,
    /// Per-entry records, sorted by chain index.
    pub entries: Vec<EntryRun>,
    /// End-to-end wall-clock seconds for the whole chain.
    pub total_s: f64,
}

impl RunReport {
    /// Total `main`-operator work across all executed entries.
    pub fn total_work(&self) -> usize {
        self.entries.iter().map(|e| e.work).sum()
    }

    /// `main` operations per second over the whole run.
    pub fn work_rate(&self) -> f64 {
        if self.total_s > 0.0 {
            self.total_work() as f64 / self.total_s
        } else {
            0.0
        }
    }
}

/// Native chain executor: owns the chain, its external-tensor store, the
/// precomputed level schedule, and the intermediate-buffer pool.
pub struct ChainExec {
    chain: GconvChain,
    externals: HashMap<DataRef, Arc<Tensor>>,
    synthesize: bool,
    synth_seed: u64,
    synth_scale: f32,
    levels: Vec<Vec<usize>>,
    pool: BufferPool,
    force_naive: bool,
    trim: TrimPolicy,
    precision: Precision,
    /// `BoundPlan::bind` calls attributed to this executor — the
    /// one-shot calling convention binds every entry's plan on every
    /// run; the serve bench reads this to report how much of that work
    /// session reuse amortizes away.
    bind_calls: AtomicUsize,
}

impl ChainExec {
    /// Build an executor for `chain`. Missing externals are synthesized
    /// deterministically by default (see the module docs).
    pub fn new(chain: GconvChain) -> Self {
        let levels = build_levels(&chain);
        ChainExec {
            chain,
            externals: HashMap::new(),
            synthesize: true,
            synth_seed: SYNTH_SEED,
            synth_scale: SYNTH_SCALE,
            levels,
            pool: BufferPool::new(),
            force_naive: false,
            trim: TrimPolicy::Keep,
            precision: Precision::BitExact,
            bind_calls: AtomicUsize::new(0),
        }
    }

    /// Set the shelf-retention policy applied after each run (see
    /// [`TrimPolicy`]; the default keeps everything, capacity-bounded).
    pub fn with_trim(mut self, policy: TrimPolicy) -> Self {
        self.trim = policy;
        self
    }

    /// Override the seed/scale used to synthesize missing externals.
    pub fn with_synthesis(mut self, seed: u64, scale: f32) -> Self {
        self.synthesize = true;
        self.synth_seed = seed;
        self.synth_scale = scale;
        self
    }

    /// Error on missing externals instead of synthesizing them.
    pub fn strict(mut self) -> Self {
        self.synthesize = false;
        self
    }

    /// Force every entry through the naive per-element oracle instead of
    /// the fast execution tiers. Differential testing and the
    /// `native_exec` bench baseline use this; results are bit-identical
    /// either way.
    pub fn with_naive_oracle(mut self) -> Self {
        self.force_naive = true;
        self
    }

    /// Numeric mode of the GEMM microkernel (default
    /// [`Precision::BitExact`]). [`Precision::Fast`] trades the
    /// bit-exactness guarantee for unrolled multi-lane accumulation,
    /// bounded by the [`super::kernels::FAST_REL_TOL`] differential.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Provide a network input / stored activation tensor (matches
    /// [`DataRef::External`] operands by name, e.g. `"data.data"`).
    pub fn set_input(&mut self, name: &str, t: Tensor) {
        self.externals.insert(DataRef::External(name.to_string()), Arc::new(t));
    }

    /// Provide a layer's trained parameters (matches
    /// [`DataRef::Weights`] operands by name, e.g. `"conv1"`).
    pub fn set_weights(&mut self, name: &str, t: Tensor) {
        self.externals.insert(DataRef::Weights(name.to_string()), Arc::new(t));
    }

    /// The chain being executed.
    pub fn chain(&self) -> &GconvChain {
        &self.chain
    }

    /// The level schedule (entries per dependency level) — exposed for
    /// tests and instrumentation.
    pub fn levels(&self) -> &[Vec<usize>] {
        &self.levels
    }

    /// Allocation counters of the intermediate-buffer pool. The
    /// `misses` counter is the executor's intermediate allocation count:
    /// a re-run that adds no misses allocated nothing new.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Cumulative `Plan` binds this executor has performed. The one-shot
    /// calling convention re-binds every needed entry on every run —
    /// compare with [`super::serve::SessionStats::plan_binds`], which
    /// stays flat after construction.
    pub fn bind_calls(&self) -> usize {
        self.bind_calls.load(Ordering::Relaxed)
    }

    /// Execute the chain, returning the outputs of the `wanted` entries
    /// plus per-entry timing. Only entries the `wanted` set transitively
    /// depends on are evaluated; buffers of entries whose last consumer
    /// has run (and that are not in `wanted`) are recycled eagerly.
    pub fn run(&mut self, wanted: &[usize]) -> Result<RunReport> {
        let n = self.chain.len();
        ensure!(n > 0, "cannot run an empty chain");
        for &w in wanted {
            ensure!(w < n, "wanted entry #{w} out of range (chain has {n})");
        }

        // Reverse reachability from `wanted` (deps point backwards, so
        // one descending sweep closes the set).
        let needed = reachable(&self.chain, wanted);
        // Shape-check every chain-internal operand up front: an
        // under-covering operand is a bind-time error raised before any
        // entry executes, not a failure in the middle of the chain.
        validate_chain(&self.chain, &needed)?;
        materialize_externals(
            &self.chain,
            &needed,
            &mut self.externals,
            self.synthesize,
            self.synth_seed,
            self.synth_scale,
        )?;
        self.pool.begin_run();

        // Consumer counts restricted to the needed subgraph, plus one
        // use per `wanted` occurrence.
        let mut uses = use_counts(&self.chain, &needed, wanted);
        let mut buffers: Vec<Option<Arc<Tensor>>> = (0..n).map(|_| None).collect();
        let mut records: Vec<EntryRun> = Vec::with_capacity(n);
        let t_total = Instant::now();
        for full_level in &self.levels {
            let mut level = Vec::new();
            for &i in full_level {
                if needed[i] {
                    level.push(i);
                }
            }
            let results: Result<Vec<(usize, Tensor, f64)>> = level
                .par_iter()
                .map(|&i| {
                    let e = &self.chain.entries()[i];
                    let input = self.operand(&e.op.input, &buffers)?;
                    let kernel = match &e.op.kernel {
                        Some(r) => Some(self.operand(r, &buffers)?),
                        None => None,
                    };
                    let t0 = Instant::now();
                    let pool = Some(&self.pool);
                    let out = match &e.special {
                        Some(sp) => special::eval_special(&e.op, sp, input, kernel, pool),
                        None => eval_counted(
                            &e.op,
                            input,
                            kernel,
                            pool,
                            self.force_naive,
                            self.precision,
                            Some(&self.bind_calls),
                        ),
                    }
                    .with_context(|| format!("chain entry #{i} ({})", e.op.name))?;
                    Ok((i, out, t0.elapsed().as_secs_f64()))
                })
                .collect();
            for (i, out, seconds) in results? {
                let e = &self.chain.entries()[i];
                records.push(EntryRun {
                    index: i,
                    name: e.op.name.clone(),
                    phase: e.phase,
                    seconds,
                    out_elements: out.elements(),
                    work: e.op.work(),
                });
                // Every scheduled entry is wanted or has a needed
                // consumer, so its buffer is always retained here.
                debug_assert!(uses[i] > 0, "executed entries are consumed or wanted");
                buffers[i] = Some(Arc::new(out));
            }
            // Free buffers whose last consumer has now run; uniquely
            // owned ones go straight back to the pool.
            for &i in &level {
                for d in deps(&self.chain.entries()[i].op) {
                    uses[d] -= 1;
                    if uses[d] == 0 {
                        if let Some(t) = buffers[d].take() {
                            if let Ok(t) = Arc::try_unwrap(t) {
                                self.pool.put(t.into_data());
                            }
                        }
                    }
                }
            }
        }
        records.sort_by_key(|r| r.index);
        let outputs = collect_outputs(wanted, &mut uses, &mut buffers)?;
        match self.trim {
            TrimPolicy::Keep => {}
            TrimPolicy::HighWater => self.pool.trim_stale(),
            TrimPolicy::Clear => self.pool.trim_all(),
        }
        Ok(RunReport {
            outputs,
            entries: records,
            total_s: t_total.elapsed().as_secs_f64(),
        })
    }

    /// Execute the chain and return the final entry's output (the
    /// network result of an inference-mode chain).
    pub fn run_last(&mut self) -> Result<RunReport> {
        ensure!(!self.chain.is_empty(), "cannot run an empty chain");
        self.run(&[self.chain.len() - 1])
    }

    /// Look up an operand tensor for evaluation.
    fn operand<'a>(
        &'a self,
        r: &DataRef,
        buffers: &'a [Option<Arc<Tensor>>],
    ) -> Result<&'a Tensor> {
        match r {
            DataRef::Gconv(i) => buffers[*i]
                .as_deref()
                .ok_or_else(|| anyhow!("producer #{i} buffer already freed or never run")),
            other => self
                .externals
                .get(other)
                .map(Arc::as_ref)
                .ok_or_else(|| anyhow!("external operand {other} not provided")),
        }
    }
}

/// Shape-check the chain-internal operands of every `needed` entry
/// against their producers' output extents, using the same binding
/// rules [`super::eval_gconv`] applies — so a chain that cannot
/// execute fails here, up front, with the entry named, instead of
/// failing mid-run after earlier levels already executed. Shared by
/// [`ChainExec::run`] (per call) and the serving layer (once at
/// session construction).
pub(super) fn validate_chain(chain: &GconvChain, needed: &[bool]) -> Result<()> {
    let out_dims = |p: usize| -> Vec<usize> {
        let d = chain.entries()[p].op.output_extents();
        if d.is_empty() {
            vec![1]
        } else {
            d
        }
    };
    for i in 0..chain.len() {
        if !needed[i] {
            continue;
        }
        let e = &chain.entries()[i];
        let ctx = |what: &str, p: usize| {
            format!("chain entry #{i} ({}): {what} operand from #{p}", e.op.name)
        };
        if let Some(sp) = &e.special {
            // Specials bind by element count only.
            let want_in = match sp {
                SpecialOp::MaxPoolBp { fwd, .. } => special::maxpool_bp_windows(fwd),
                SpecialOp::Concat { axis, branch_extent, .. } => {
                    let dims = out_dims(i);
                    ensure!(*axis < dims.len(), "{}", ctx("concat axis", i));
                    let total: usize = dims.iter().product();
                    total / dims[*axis] * (dims[*axis] - branch_extent)
                }
            };
            if let DataRef::Gconv(p) = &e.op.input {
                let got: usize = out_dims(*p).iter().product();
                ensure!(
                    got == want_in,
                    "{}: has {got} elements, expected {want_in}",
                    ctx("input", *p)
                );
            }
            ensure!(
                e.op.kernel.is_some(),
                "chain entry #{i} ({}): special needs two operands",
                e.op.name
            );
            let want_ker = match sp {
                SpecialOp::MaxPoolBp { in_extents, .. } => in_extents.iter().product(),
                SpecialOp::Concat { axis, branch_extent, .. } => {
                    let dims = out_dims(i);
                    let total: usize = dims.iter().product();
                    total / dims[*axis] * branch_extent
                }
            };
            if let Some(DataRef::Gconv(p)) = &e.op.kernel {
                let got: usize = out_dims(*p).iter().product();
                ensure!(
                    got == want_ker,
                    "{}: has {got} elements, expected {want_ker}",
                    ctx("kernel", *p)
                );
            }
            continue;
        }
        if let DataRef::Gconv(p) = &e.op.input {
            let dims = out_dims(*p);
            let elements = dims.iter().product();
            bind_input(&e.op, &dims, elements).with_context(|| ctx("input", *p))?;
        }
        if !matches!(e.op.main, MainOp::Pass) {
            if let Some(DataRef::Gconv(p)) = &e.op.kernel {
                let got: usize = out_dims(*p).iter().product();
                let want = e.op.kernel_elements();
                ensure!(
                    got == want,
                    "{}: has {got} elements, expected {want}",
                    ctx("kernel", *p)
                );
            }
        }
    }
    Ok(())
}

/// Every external operand of the `needed` entries with the extents a
/// synthesized stand-in would take: `(entry index, operand ref,
/// extents)`, in chain order, duplicates included (the first
/// occurrence of a ref defines its synthesized shape). Shared by
/// [`materialize_externals`] and the serving layer's batch-independence
/// probe, which needs the shapes without generating any data.
pub(super) fn external_specs(
    chain: &GconvChain,
    needed: &[bool],
) -> Vec<(usize, DataRef, Vec<usize>)> {
    let mut specs = Vec::new();
    for i in 0..chain.len() {
        if !needed[i] {
            continue;
        }
        let e = &chain.entries()[i];
        // Per-operand extents; special entries bind their operands by
        // their own geometry, not the op's Table-3 extents.
        let (in_ext, ker_ext) = match &e.special {
            Some(SpecialOp::MaxPoolBp { fwd, in_extents }) => {
                let windows = fwd.iter().map(|&(_, p)| p.output_extent()).collect();
                (windows, in_extents.clone())
            }
            Some(SpecialOp::Concat { axis, pre_extent, branch_extent }) => {
                let mut dims = e.op.output_extents();
                if dims.is_empty() {
                    dims.push(1);
                }
                let mut pre_dims = dims.clone();
                pre_dims[*axis] = *pre_extent;
                let mut branch_dims = dims;
                branch_dims[*axis] = *branch_extent;
                (pre_dims, branch_dims)
            }
            None => (e.op.input_extents(), e.op.kernel_extents()),
        };
        if !matches!(e.op.input, DataRef::Gconv(_)) {
            specs.push((i, e.op.input.clone(), in_ext));
        }
        if let Some(k) = &e.op.kernel {
            if !matches!(k, DataRef::Gconv(_)) {
                specs.push((i, k.clone(), ker_ext));
            }
        }
    }
    specs
}

/// Ensure every external operand of the `needed` entries has a tensor,
/// synthesizing missing ones (deterministically, keyed by operand name)
/// when allowed. Pruned entries are skipped: their externals are
/// neither required (strict mode) nor synthesized. Tensors are
/// `Arc`-shared so the serving layer can hand the same weight buffers
/// to many sessions without copying.
pub(super) fn materialize_externals(
    chain: &GconvChain,
    needed: &[bool],
    externals: &mut HashMap<DataRef, Arc<Tensor>>,
    synthesize: bool,
    synth_seed: u64,
    synth_scale: f32,
) -> Result<()> {
    for (i, r, mut dims) in external_specs(chain, needed) {
        if externals.contains_key(&r) {
            continue;
        }
        ensure!(
            synthesize,
            "chain entry #{i} ({}) needs external operand {r}, and synthesis is off",
            chain.entries()[i].op.name
        );
        if dims.is_empty() {
            dims.push(1);
        }
        let seed = synth_seed ^ fnv1a(r.to_string().as_bytes());
        let t = Tensor::rand(&dims, seed, synth_scale);
        externals.insert(r, Arc::new(t));
    }
    Ok(())
}

/// Reverse reachability of the `wanted` entries: deps point backwards,
/// so one descending sweep closes the set.
pub(super) fn reachable(chain: &GconvChain, wanted: &[usize]) -> Vec<bool> {
    let n = chain.len();
    let mut needed = vec![false; n];
    for &w in wanted {
        needed[w] = true;
    }
    for i in (0..n).rev() {
        if needed[i] {
            for d in deps(&chain.entries()[i].op) {
                needed[d] = true;
            }
        }
    }
    needed
}

/// Level schedule of a chain: every entry's level is `1 + max(level of
/// its deps)`; entries in one level have no mutual data dependencies
/// and evaluate concurrently.
pub(super) fn build_levels(chain: &GconvChain) -> Vec<Vec<usize>> {
    let n = chain.len();
    let mut level = vec![0usize; n];
    for i in 0..n {
        for d in deps(&chain.entries()[i].op) {
            level[i] = level[i].max(level[d] + 1);
        }
    }
    let depth = level.iter().copied().max().map_or(0, |m| m + 1);
    let mut levels = vec![Vec::new(); depth];
    for (i, &l) in level.iter().enumerate() {
        levels[l].push(i);
    }
    levels
}

/// Consumer counts restricted to the needed subgraph, plus one use per
/// `wanted` occurrence (which keeps requested buffers alive for the
/// hand-off to the caller).
pub(super) fn use_counts(chain: &GconvChain, needed: &[bool], wanted: &[usize]) -> Vec<usize> {
    let n = chain.len();
    let mut uses = vec![0usize; n];
    for i in 0..n {
        if needed[i] {
            for d in deps(&chain.entries()[i].op) {
                uses[d] += 1;
            }
        }
    }
    for &w in wanted {
        uses[w] += 1;
    }
    uses
}

/// Move the requested output buffers out of the executor's buffer
/// table: the extra `wanted` use kept each alive; the Arc moves out on
/// its last occurrence and is shared (pointer-equal, never a deep copy)
/// when `wanted` lists the same entry again.
pub(super) fn collect_outputs(
    wanted: &[usize],
    uses: &mut [usize],
    buffers: &mut [Option<Arc<Tensor>>],
) -> Result<Vec<Arc<Tensor>>> {
    wanted
        .iter()
        .map(|&w| {
            uses[w] -= 1;
            let t = match uses[w] {
                0 => buffers[w].take(),
                _ => buffers[w].clone(),
            };
            t.ok_or_else(|| anyhow!("output of entry #{w} was not retained"))
        })
        .collect()
}

/// Chain-internal dependencies of an op (producer indices).
pub(super) fn deps(op: &GconvOp) -> Vec<usize> {
    let mut out = Vec::with_capacity(2);
    if let DataRef::Gconv(i) = op.input {
        out.push(i);
    }
    if let Some(DataRef::Gconv(i)) = op.kernel {
        out.push(i);
    }
    out
}

/// FNV-1a hash of a byte string (seeds external-tensor synthesis).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::gconv::chain::ChainEntry;
    use crate::gconv::op::{DimParams, MainOp, PostOp, PreOp, ReduceOp};
    use crate::ir::Dim;

    fn ew(name: &str, main: MainOp, input: DataRef, kernel: Option<DataRef>) -> GconvOp {
        GconvOp {
            name: name.into(),
            dims: vec![(Dim::C, DimParams::opc(4))],
            pre: PreOp::None,
            main,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input,
            kernel,
        }
    }

    fn push(c: &mut GconvChain, op: GconvOp) -> usize {
        c.push(ChainEntry::new(op, 0, true, Phase::Fp))
    }

    fn diamond() -> GconvChain {
        // x → a, x → b (independent), then c = a + b.
        let mut c = GconvChain::new("diamond");
        let x = DataRef::External("x".into());
        let a = push(&mut c, ew("a", MainOp::Pass, x.clone(), None));
        let b = push(&mut c, ew("b", MainOp::Pass, x, None));
        let (ra, rb) = (DataRef::Gconv(a), DataRef::Gconv(b));
        push(&mut c, ew("c", MainOp::Add, ra, Some(rb)));
        c
    }

    fn x1234() -> Tensor {
        Tensor::new(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap()
    }

    #[test]
    fn levels_group_independent_entries() {
        let exec = ChainExec::new(diamond());
        assert_eq!(exec.levels(), &[vec![0, 1], vec![2]]);
    }

    #[test]
    fn diamond_sums_both_branches() {
        let mut exec = ChainExec::new(diamond());
        exec.set_input("x", x1234());
        let report = exec.run_last().unwrap();
        assert_eq!(report.outputs[0].data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(report.entries.len(), 3);
        assert!(report.total_s >= 0.0);
        assert_eq!(report.total_work(), 12);
    }

    #[test]
    fn strict_mode_rejects_missing_externals() {
        let mut exec = ChainExec::new(diamond()).strict();
        let err = exec.run_last().unwrap_err().to_string();
        assert!(err.contains('x'), "unexpected error: {err}");
    }

    #[test]
    fn synthesis_is_deterministic_across_runs_and_instances() {
        let mut e1 = ChainExec::new(diamond());
        let mut e2 = ChainExec::new(diamond());
        let a = e1.run_last().unwrap().outputs.remove(0);
        let b = e1.run_last().unwrap().outputs.remove(0);
        let c = e2.run_last().unwrap().outputs.remove(0);
        assert_eq!(a, b);
        assert_eq!(a, c);
        // Different seed ⇒ different data.
        let mut e3 = ChainExec::new(diamond()).with_synthesis(99, 0.1);
        let d = e3.run_last().unwrap().outputs.remove(0);
        assert_ne!(a, d);
    }

    #[test]
    fn wanted_outputs_are_retained_even_mid_chain() {
        let mut exec = ChainExec::new(diamond());
        exec.set_input("x", x1234());
        let report = exec.run(&[0, 2]).unwrap();
        assert_eq!(report.outputs.len(), 2);
        assert_eq!(report.outputs[0].data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(report.outputs[1].data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn unneeded_entries_are_pruned() {
        // Asking only for entry 0 must not evaluate 1 or 2.
        let mut exec = ChainExec::new(diamond());
        exec.set_input("x", x1234());
        let report = exec.run(&[0]).unwrap();
        assert_eq!(report.entries.len(), 1);
        assert_eq!(report.entries[0].index, 0);
        assert_eq!(report.outputs[0].data(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn out_of_range_wanted_is_rejected() {
        let mut exec = ChainExec::new(diamond());
        assert!(exec.run(&[7]).is_err());
    }

    #[test]
    fn shared_weights_are_synthesized_once() {
        // Two entries reading the same Weights ref must see identical data.
        let mut c = GconvChain::new("w");
        let w = DataRef::Weights("shared".into());
        let x = DataRef::External("x".into());
        let y = DataRef::External("y".into());
        push(&mut c, ew("a", MainOp::Mul, x, Some(w.clone())));
        push(&mut c, ew("b", MainOp::Mul, y, Some(w)));
        let mut exec = ChainExec::new(c);
        let ones = Tensor::filled(&[4], 1.0);
        exec.set_input("x", ones.clone());
        exec.set_input("y", ones);
        let report = exec.run(&[0, 1]).unwrap();
        assert_eq!(report.outputs[0], report.outputs[1]);
    }

    #[test]
    fn duplicated_wanted_outputs_share_one_buffer() {
        // A diamond-shaped chain with the sink requested twice: both
        // outputs must be the *same* allocation — pointer identity, not
        // a deep copy.
        let mut exec = ChainExec::new(diamond());
        exec.set_input("x", x1234());
        let report = exec.run(&[2, 2]).unwrap();
        let a = &report.outputs[0];
        let b = &report.outputs[1];
        assert!(Arc::ptr_eq(a, b), "duplicated outputs must share");
        assert_eq!(a.data().as_ptr(), b.data().as_ptr());
        assert_eq!(a.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn rerun_recycles_buffers_instead_of_allocating() {
        // Allocation counter: the second run of the same chain must be
        // served from the pool (its only fresh allocation is the final
        // output, whose first-run buffer the caller still holds).
        let mut exec = ChainExec::new(diamond());
        exec.set_input("x", x1234());
        let first = exec.run_last().unwrap();
        let misses_first = exec.pool_stats().misses;
        assert!(misses_first >= 3, "first run allocates per entry");
        let second = exec.run_last().unwrap();
        let stats = exec.pool_stats();
        assert_eq!(stats.misses, misses_first + 1, "{stats:?}");
        assert!(stats.hits >= 2, "{stats:?}");
        // Recycled (stale-content) buffers must not change results.
        assert!(first.outputs[0].bit_eq(&second.outputs[0]));
    }

    #[test]
    fn under_covering_operand_fails_before_anything_executes() {
        // Producer emits 2 elements, consumer expects 4: the up-front
        // validation must name the broken entry and nothing may run.
        let mut c = GconvChain::new("bad");
        let x = DataRef::External("x".into());
        let mut small = ew("small", MainOp::Pass, x, None);
        small.dims = vec![(Dim::C, DimParams::opc(2))];
        push(&mut c, small);
        push(&mut c, ew("big", MainOp::Pass, DataRef::Gconv(0), None));
        let mut exec = ChainExec::new(c);
        let err = exec.run_last().unwrap_err().to_string();
        assert!(err.contains("big"), "unexpected error: {err}");
        assert_eq!(exec.pool_stats().misses, 0, "validation must precede execution");
    }

    #[test]
    fn clear_trim_policy_empties_the_shelf_every_run() {
        let mut exec = ChainExec::new(diamond()).with_trim(TrimPolicy::Clear);
        exec.set_input("x", x1234());
        exec.run_last().unwrap();
        let s1 = exec.pool_stats();
        assert!(s1.trimmed > 0, "{s1:?}");
        exec.run_last().unwrap();
        let s2 = exec.pool_stats();
        assert_eq!(s2.hits, s1.hits, "cleared shelf cannot serve hits: {s2:?}");
        assert!(s2.misses > s1.misses);
    }

    #[test]
    fn high_water_trim_keeps_the_live_working_set() {
        let mut exec = ChainExec::new(diamond()).with_trim(TrimPolicy::HighWater);
        exec.set_input("x", x1234());
        exec.run_last().unwrap();
        exec.run_last().unwrap();
        let s = exec.pool_stats();
        assert!(s.hits >= 2, "recycled-this-run buffers must survive the trim: {s:?}");
    }

    #[test]
    fn naive_oracle_toggle_is_bit_identical() {
        let mut fast = ChainExec::new(diamond());
        let mut slow = ChainExec::new(diamond()).with_naive_oracle();
        fast.set_input("x", x1234());
        slow.set_input("x", x1234());
        let a = fast.run_last().unwrap();
        let b = slow.run_last().unwrap();
        assert!(a.outputs[0].bit_eq(&b.outputs[0]));
    }
}
