//! Native execution of [`SpecialOp`] chain entries — ops whose numerics
//! the GCONV loop-nest interpreter cannot express because an operand
//! genuinely under-covers the nest:
//!
//! * **Max-pool BP** (argmax routing): the entry's `input` operand is
//!   the pooled-output gradient, its `kernel` operand the saved forward
//!   input. The routine recomputes the argmax mask from the forward
//!   input (first maximum in reduction order, padding skipped exactly
//!   like the forward `Max` reduction) and *scatters* each window's
//!   gradient onto the winning input element; overlapping windows
//!   accumulate, fully-padded windows route nothing. The scatter runs
//!   sequentially — max-pool BP is a vanishing fraction of a training
//!   chain's work next to the conv BP/WG GEMMs.
//! * **Concat**: copy the `input` operand then the `kernel` operand
//!   side by side along the concatenation axis (row-major block copies).
//!
//! Both routines validate operand element counts and produce tensors
//! shaped by the entry's [`GconvOp`] output extents, so consumers bind
//! them exactly like interpreter-produced buffers.

use anyhow::{ensure, Context, Result};

use crate::gconv::chain::SpecialOp;
use crate::gconv::op::{DimParams, GconvOp};
use crate::ir::Dim;

use super::interp::MAX_DIMS;
use super::pool::BufferPool;
use super::tensor::{row_major_strides, Tensor};

/// Number of pooled-output gradient elements a `MaxPoolBp` special
/// expects: the product of the forward pooling geometry's output
/// extents.
pub(super) fn maxpool_bp_windows(fwd: &[(Dim, DimParams)]) -> usize {
    fwd.iter().map(|&(_, p)| p.output_extent()).product()
}

/// Evaluate one special entry over concrete operand tensors.
pub(super) fn eval_special(
    op: &GconvOp,
    sp: &SpecialOp,
    input: &Tensor,
    kernel: Option<&Tensor>,
    pool: Option<&BufferPool>,
) -> Result<Tensor> {
    match sp {
        SpecialOp::MaxPoolBp { fwd, in_extents } => {
            let x = kernel
                .with_context(|| format!("{}: max-pool BP needs the forward input", op.name))?;
            eval_maxpool_bp(op, fwd, in_extents, input, x, pool)
        }
        SpecialOp::Concat { axis, pre_extent, branch_extent } => {
            let b = kernel
                .with_context(|| format!("{}: concat needs its branch operand", op.name))?;
            eval_concat(op, *axis, *pre_extent, *branch_extent, input, b, pool)
        }
    }
}

/// Output extents of the entry's op (consumers bind against these).
fn out_dims(op: &GconvOp) -> Vec<usize> {
    let d = op.output_extents();
    if d.is_empty() {
        vec![1]
    } else {
        d
    }
}

fn take_buffer(pool: Option<&BufferPool>, n: usize) -> Vec<f32> {
    match pool {
        Some(p) => p.take(n),
        None => vec![0.0; n],
    }
}

/// Max-pool backward: recompute the argmax per forward window from the
/// saved forward input `x` and scatter the gradient `g` accordingly.
fn eval_maxpool_bp(
    op: &GconvOp,
    fwd: &[(Dim, DimParams)],
    in_extents: &[usize],
    g: &Tensor,
    x: &Tensor,
    pool: Option<&BufferPool>,
) -> Result<Tensor> {
    let nd = fwd.len();
    ensure!(nd == in_extents.len() && nd <= MAX_DIMS, "{}: bad routing geometry", op.name);
    for &(d, p) in fwd {
        ensure!(
            p.ng == 1 && p.nop == 1,
            "{}: routing dimension {d} must be a plain window",
            op.name
        );
    }
    let out_total: usize = in_extents.iter().product();
    ensure!(
        x.elements() == out_total,
        "{}: forward input has {} elements, routing expects {}",
        op.name,
        x.elements(),
        out_total
    );
    ensure!(
        op.output_elements() == out_total,
        "{}: op output ({}) disagrees with routing extents ({})",
        op.name,
        op.output_elements(),
        out_total
    );
    let windows = maxpool_bp_windows(fwd);
    ensure!(
        g.elements() == windows,
        "{}: gradient has {} elements, forward pooling produced {}",
        op.name,
        g.elements(),
        windows
    );

    let win_ext: Vec<usize> = fwd.iter().map(|&(_, p)| p.output_extent()).collect();
    let nks: Vec<usize> = fwd.iter().map(|&(_, p)| p.nks).collect();
    let red: usize = nks.iter().product::<usize>().max(1);
    let x_strides = row_major_strides(in_extents);
    let w_strides = row_major_strides(&win_ext);
    let red_strides = row_major_strides(&nks);

    let mut data = take_buffer(pool, out_total);
    data.fill(0.0); // recycled buffers come back stale; the scatter accumulates
    let xs = x.data();
    let gs = g.data();
    for w in 0..windows {
        let mut pos0 = [0i64; MAX_DIMS];
        for i in 0..nd {
            let p = fwd[i].1;
            let oc = (w / w_strides[i]) % win_ext[i];
            pos0[i] = (oc * p.s) as i64 - p.ps as i64;
        }
        // First in-bounds maximum in reduction order — ties route to the
        // earliest element, deterministically.
        let mut best: Option<(usize, f32)> = None;
        for r in 0..red {
            let mut idx = 0usize;
            let mut oob = false;
            for i in 0..nd {
                let ks = (r / red_strides[i]) % nks[i];
                let pos = pos0[i] + ks as i64;
                if pos < 0 || pos >= in_extents[i] as i64 {
                    oob = true;
                    break;
                }
                idx += pos as usize * x_strides[i];
            }
            if oob {
                continue;
            }
            let v = xs[idx];
            let better = match best {
                None => true,
                Some((_, bv)) => v > bv,
            };
            if better {
                best = Some((idx, v));
            }
        }
        if let Some((idx, _)) = best {
            data[idx] += gs[w];
        }
    }
    Tensor::new(&out_dims(op), data)
}

/// Pairwise concatenation: `a` then `b` along the axis at `axis` of the
/// op's dims (row-major block copies; every output element written
/// exactly once, so recycled buffers need no zeroing).
fn eval_concat(
    op: &GconvOp,
    axis: usize,
    pre: usize,
    branch: usize,
    a: &Tensor,
    b: &Tensor,
    pool: Option<&BufferPool>,
) -> Result<Tensor> {
    let dims = out_dims(op);
    ensure!(axis < dims.len(), "{}: concat axis {} out of range", op.name, axis);
    ensure!(
        dims[axis] == pre + branch,
        "{}: axis extent {} != {} + {}",
        op.name,
        dims[axis],
        pre,
        branch
    );
    let outer: usize = dims[..axis].iter().product();
    let inner: usize = dims[axis + 1..].iter().product();
    ensure!(
        a.elements() == outer * pre * inner,
        "{}: prefix operand has {} elements, expected {}",
        op.name,
        a.elements(),
        outer * pre * inner
    );
    ensure!(
        b.elements() == outer * branch * inner,
        "{}: branch operand has {} elements, expected {}",
        op.name,
        b.elements(),
        outer * branch * inner
    );
    let total = outer * (pre + branch) * inner;
    let mut data = take_buffer(pool, total);
    debug_assert_eq!(data.len(), total);
    let pa = a.data();
    let pb = b.data();
    let (pn, bn) = (pre * inner, branch * inner);
    for o in 0..outer {
        let dst = o * (pn + bn);
        data[dst..dst + pn].copy_from_slice(&pa[o * pn..(o + 1) * pn]);
        data[dst + pn..dst + pn + bn].copy_from_slice(&pb[o * bn..(o + 1) * bn]);
    }
    Tensor::new(&dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::op::{DataRef, MainOp, PostOp, PreOp, ReduceOp};

    fn movement_op(name: &str, dims: Vec<(Dim, DimParams)>, kernel: Option<DataRef>) -> GconvOp {
        GconvOp {
            name: name.into(),
            dims,
            pre: PreOp::None,
            main: MainOp::Mul,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: DataRef::External("g".into()),
            kernel,
        }
    }

    #[test]
    fn maxpool_bp_routes_to_window_winners() {
        // 1-D pool, k2 s2 over [1, 3, 2, 4]: winners at 1 and 3.
        let op = movement_op(
            "bp",
            vec![(Dim::W, DimParams::g(4))],
            Some(DataRef::External("x".into())),
        );
        let fwd = vec![(Dim::W, DimParams::window(2, 2, 2, 0))];
        let sp = SpecialOp::MaxPoolBp { fwd, in_extents: vec![4] };
        let g = Tensor::new(&[2], vec![10.0, 20.0]).unwrap();
        let x = Tensor::new(&[4], vec![1.0, 3.0, 2.0, 4.0]).unwrap();
        let out = eval_special(&op, &sp, &g, Some(&x), None).unwrap();
        assert_eq!(out.data(), &[0.0, 10.0, 0.0, 20.0]);
    }

    #[test]
    fn maxpool_bp_overlapping_windows_accumulate_and_ties_go_first() {
        // k2 s1 over [5, 5, 1]: window 0 ties → first element; window 1
        // picks index 1; gradients accumulate on shared winners.
        let op = movement_op(
            "bp",
            vec![(Dim::W, DimParams::g(3))],
            Some(DataRef::External("x".into())),
        );
        let fwd = vec![(Dim::W, DimParams::window(2, 2, 1, 0))];
        let sp = SpecialOp::MaxPoolBp { fwd, in_extents: vec![3] };
        let g = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let x = Tensor::new(&[3], vec![5.0, 5.0, 1.0]).unwrap();
        let out = eval_special(&op, &sp, &g, Some(&x), None).unwrap();
        assert_eq!(out.data(), &[1.0, 2.0, 0.0]);
    }

    #[test]
    fn maxpool_bp_skips_fully_padded_and_clipped_windows() {
        // Ceil-mode: 3 windows of k2 s2 over 5 inputs; the last window
        // covers only index 4 (overhang = end padding).
        let op = movement_op(
            "bp",
            vec![(Dim::W, DimParams::g(5))],
            Some(DataRef::External("x".into())),
        );
        let fwd = vec![(Dim::W, DimParams::window_ceil(3, 2, 2, 0, 5))];
        let sp = SpecialOp::MaxPoolBp { fwd, in_extents: vec![5] };
        let g = Tensor::new(&[3], vec![1.0, 2.0, 4.0]).unwrap();
        let x = Tensor::new(&[5], vec![0.0, 9.0, 8.0, 0.0, 7.0]).unwrap();
        let out = eval_special(&op, &sp, &g, Some(&x), None).unwrap();
        assert_eq!(out.data(), &[0.0, 1.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn concat_copies_blocks_along_the_axis() {
        // outer 2 (B), axis C with 2 + 1, inner 2 (W).
        let dims = vec![
            (Dim::B, DimParams::opc(2)),
            (Dim::C, DimParams::opc(3)),
            (Dim::W, DimParams::opc(2)),
        ];
        let op = movement_op("cat", dims, Some(DataRef::External("b".into())));
        let sp = SpecialOp::Concat { axis: 1, pre_extent: 2, branch_extent: 1 };
        let a = Tensor::from_fn(&[2, 2, 2], |i| i as f32);
        let b = Tensor::new(&[2, 1, 2], vec![100.0, 101.0, 110.0, 111.0]).unwrap();
        let out = eval_special(&op, &sp, &a, Some(&b), None).unwrap();
        assert_eq!(out.dims(), &[2, 3, 2]);
        #[rustfmt::skip]
        let want = vec![
            0.0, 1.0, 2.0, 3.0, 100.0, 101.0,
            4.0, 5.0, 6.0, 7.0, 110.0, 111.0,
        ];
        assert_eq!(out.data(), &want);
    }

    #[test]
    fn operand_count_mismatches_are_errors() {
        let op = movement_op(
            "bp",
            vec![(Dim::W, DimParams::g(4))],
            Some(DataRef::External("x".into())),
        );
        let fwd = vec![(Dim::W, DimParams::window(2, 2, 2, 0))];
        let sp = SpecialOp::MaxPoolBp { fwd, in_extents: vec![4] };
        let g = Tensor::zeros(&[3]); // forward produced 2 windows
        let x = Tensor::zeros(&[4]);
        assert!(eval_special(&op, &sp, &g, Some(&x), None).is_err());
        assert!(eval_special(&op, &sp, &Tensor::zeros(&[2]), None, None).is_err());
    }
}
