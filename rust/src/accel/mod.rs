//! Accelerator models: the abstract structure every mapper/model consumes
//! (§4.4), the five Table-4 configurations, and the baseline-mode models
//! (TIP im2col, CIP offloading, LIP pipelining) plus the host and GPU
//! comparators.

pub mod baseline;
pub mod configs;
pub mod gpu;
pub mod offload;
pub mod pipeline;
pub mod structure;

pub use configs::{all_accelerators, by_code, dnnweaver, eager_pruning, eyeriss, nlr, tpu};
pub use structure::{AccelStructure, Category, SpatialDim};
