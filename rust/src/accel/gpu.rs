//! GPU comparator (NVIDIA Tesla V100) for Fig. 19 / Fig. 21.
//!
//! Only used as an efficiency/TCO yardstick — the paper compares
//! iso-power performance of GC-CIPs against a V100 (up to 7.6×, 4.5×
//! average advantage).

/// A simple roofline GPU model.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Marketing name.
    pub name: &'static str,
    /// Peak throughput in MAC/s (fp16 tensor-core peak counted as MACs).
    pub peak_macs_per_s: f64,
    /// Achieved fraction of peak on CNN training (measured utilizations
    /// for mixed conv + element-wise workloads).
    pub utilization: f64,
    /// Board power in watts.
    pub tdp_w: f64,
    /// Street price in USD (Fig. 21 CAPEX).
    pub price_usd: f64,
}

impl GpuModel {
    /// Tesla V100 (SXM2 32 GB).
    pub fn v100() -> Self {
        GpuModel {
            name: "V100",
            // 125 TFLOPS tensor peak → 62.5 T MAC/s.
            peak_macs_per_s: 62.5e12,
            // End-to-end CNN training sustains ~20% of tensor peak
            // (element-wise layers, BN barriers, launch overheads).
            utilization: 0.20,
            tdp_w: 300.0,
            price_usd: 9_000.0,
        }
    }

    /// Seconds to execute `work` MACs.
    pub fn seconds(&self, work: f64) -> f64 {
        work / (self.peak_macs_per_s * self.utilization)
    }

    /// Energy in joules for `work` MACs (busy at TDP).
    pub fn energy_j(&self, work: f64) -> f64 {
        self.seconds(work) * self.tdp_w
    }

    /// MACs per joule — the Fig. 19 iso-power performance metric.
    pub fn macs_per_joule(&self) -> f64 {
        self.peak_macs_per_s * self.utilization / self.tdp_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_sustains_tens_of_gmacs_per_joule() {
        let g = GpuModel::v100();
        let mpj = g.macs_per_joule();
        assert!((1e9..1e12).contains(&mpj), "{mpj:e}");
    }

    #[test]
    fn seconds_scale_linearly() {
        let g = GpuModel::v100();
        assert!((g.seconds(2e12) / g.seconds(1e12) - 2.0).abs() < 1e-12);
    }
}
