//! The five evaluated accelerators (Table 4).
//!
//! Capacities are in 16-bit words (2 bytes/word). Where Table 4 leaves a
//! field blank we adopt the original work's published configuration
//! (noted inline). §4.4 catalogues the spatial-dimension capabilities:
//! Tetris/Simba-style ([17][26]) have one input-parallel axis and one
//! reduce axis without overlap; DNNWeaver ([25]) has one axis with
//! overlap; EagerPruning's ([6]) subsystem axis exploits reduce and
//! overlap at the same time; the TPU is a systolic array (reduce along
//! columns) with no overlap-reuse.

use super::structure::{AccelStructure, Bandwidth, Category, GlobalBuffer, LocalStores, SpatialDim};
use crate::gconv::op::Param;
use crate::ir::Dim;

const KB: usize = 1024 / 2; // words per kB at 16-bit

/// Google TPU (scaled 4×4 down from the datacenter design, §6.1):
/// 64×64 systolic array, I&O GB 1.5 MB, K GB 0.25 MB, bandwidths
/// I/O/K = 64/64/11 words per cycle.
pub fn tpu() -> AccelStructure {
    AccelStructure {
        name: "TPU",
        full_name: "TPU (scaled)",
        category: Category::Tip,
        spatial: vec![
            // Rows: weight-stationary systolic reduction (partials flow).
            SpatialDim { name: "row", size: 64, reduce: true, overlap: false },
            // Columns: input broadcast across parallel kernels.
            SpatialDim { name: "col", size: 64, reduce: false, overlap: false },
        ],
        ls: LocalStores { ils: 1, ols: 1, kls: 1 },
        gb: GlobalBuffer { i: 1536 * KB / 2, o: 1536 * KB / 2, k: 256 * KB },
        bw: Bandwidth { i: 64, o: 64, k: 11 },
        freq_ghz: 0.7,
        spatial_priority: vec![
            [Param::Ks, Param::Opc, Param::Op, Param::G],
            [Param::Op, Param::Opc, Param::Ks, Param::G],
        ],
        temporal_priority: [Param::Op, Param::Ks, Param::Opc, Param::G],
        // The baseline TPU maps matmul (im2col): rows take the reduction
        // (C-dim ks), columns the output channels.
        baseline_dims: vec![Some(vec![Dim::C]), Some(vec![Dim::C])],
        offload_overlap: 0.0, // TIP runs everything on-chip
    }
}

/// DNNWeaver on Altera Stratix V SGSD5 (AlexNet configuration, §6.1):
/// 14 PUs × 74 PEs; KLS 1 word per PE; 8.5 kB GB slice per PU.
pub fn dnnweaver() -> AccelStructure {
    AccelStructure {
        name: "DNNW",
        full_name: "DNNWeaver",
        category: Category::Lip,
        spatial: vec![
            // PUs: independent output-channel slices.
            SpatialDim { name: "pu", size: 14, reduce: false, overlap: false },
            // PEs inside a PU: adder-chain reduction + line-buffer overlap.
            SpatialDim { name: "pe", size: 74, reduce: true, overlap: true },
        ],
        ls: LocalStores { ils: 1, ols: 1, kls: 1 },
        gb: GlobalBuffer { i: 14 * 4 * KB, o: 14 * 4 * KB, k: 14 * 17 * KB / 2 },
        bw: Bandwidth { i: 14, o: 14, k: 14 },
        freq_ghz: 0.7,
        spatial_priority: vec![
            [Param::Op, Param::Opc, Param::Ks, Param::G],
            [Param::Ks, Param::Opc, Param::Op, Param::G],
        ],
        temporal_priority: [Param::Op, Param::Ks, Param::Opc, Param::G],
        // Baseline dataflow: PUs over output channels (C), PEs walk the
        // width dimension.
        baseline_dims: vec![Some(vec![Dim::C]), Some(vec![Dim::W])],
        offload_overlap: 0.0, // LIP runs everything on-chip
    }
}

/// Eyeriss (Table 4 / [5]): 12×14 array; ILS 12 / OLS 24 / KLS 224 words
/// per PE; 108 kB global buffer (original work), read bandwidth split
/// across data types as in the original implementation.
pub fn eyeriss() -> AccelStructure {
    AccelStructure {
        name: "ER",
        full_name: "Eyeriss",
        category: Category::Cip,
        spatial: vec![
            // py: inter-row psum forwarding (reduce) + diagonal input
            // sharing with px (row-stationary overlap primitive).
            SpatialDim { name: "py", size: 12, reduce: true, overlap: true },
            SpatialDim { name: "px", size: 14, reduce: false, overlap: false },
        ],
        ls: LocalStores { ils: 12, ols: 24, kls: 224 },
        gb: GlobalBuffer { i: 50 * KB, o: 50 * KB, k: 8 * KB },
        bw: Bandwidth { i: 8, o: 8, k: 8 },
        freq_ghz: 0.7,
        // Algorithm 1: ks first in py (reduce), opc/op first in px
        // (output bandwidth).
        spatial_priority: vec![
            [Param::Ks, Param::Opc, Param::Op, Param::G],
            [Param::Opc, Param::Op, Param::Ks, Param::G],
        ],
        // Line 20: op first (reuses inputs in place).
        temporal_priority: [Param::Op, Param::Ks, Param::Opc, Param::G],
        // Baseline row-stationary is dedicated to H (py) and W (temporal):
        // spatial axes serve H/C only.
        baseline_dims: vec![Some(vec![Dim::H, Dim::C]), Some(vec![Dim::H, Dim::W, Dim::C])],
        offload_overlap: 0.6,
    }
}

/// EagerPruning (Table 4 / [6]): 4 subsystems × 512 PEs; input pool of
/// 64 words per subsystem; 1.5 MB per data type; 32 words/cycle per
/// subsystem. Dense computation (§6.1).
pub fn eager_pruning() -> AccelStructure {
    AccelStructure {
        name: "EP",
        full_name: "EagerPruning",
        category: Category::Cip,
        spatial: vec![
            SpatialDim { name: "sub", size: 4, reduce: false, overlap: false },
            // §4.4: the subsystem's PE dimension exploits reduce and
            // overlap at the same time.
            SpatialDim { name: "pe", size: 512, reduce: true, overlap: true },
        ],
        ls: LocalStores { ils: 64, ols: 1, kls: 1 },
        gb: GlobalBuffer { i: 768 * KB, o: 768 * KB, k: 768 * KB },
        bw: Bandwidth { i: 4 * 32, o: 4 * 32, k: 4 * 32 },
        freq_ghz: 0.7,
        spatial_priority: vec![
            [Param::Op, Param::Opc, Param::Ks, Param::G],
            [Param::Ks, Param::Opc, Param::Op, Param::G],
        ],
        temporal_priority: [Param::Op, Param::Ks, Param::Opc, Param::G],
        // Baseline: subsystems slice output channels; the wide PE axis
        // walks the spatial dims of traditional convolution.
        baseline_dims: vec![Some(vec![Dim::C]), Some(vec![Dim::W, Dim::H])],
        offload_overlap: 0.15,
    }
}

/// NLR ([7], the FPGA loop-tiled design): Tm = 64 output channels × Tn =
/// 7 input channels; I&K GB 1.5 MB, O GB 0.75 MB; bandwidths I&K 7, O 64.
pub fn nlr() -> AccelStructure {
    AccelStructure {
        name: "NLR",
        full_name: "NLR (FPGA loop tiling)",
        category: Category::Cip,
        spatial: vec![
            // Tn: parallel input channels reduced by an adder tree.
            SpatialDim { name: "tn", size: 7, reduce: true, overlap: false },
            // Tm: parallel output channels.
            SpatialDim { name: "tm", size: 64, reduce: false, overlap: false },
        ],
        ls: LocalStores { ils: 1, ols: 1, kls: 1 },
        gb: GlobalBuffer { i: 768 * KB, o: 384 * KB, k: 768 * KB },
        bw: Bandwidth { i: 7, o: 64, k: 7 },
        freq_ghz: 0.7,
        spatial_priority: vec![
            [Param::Ks, Param::Opc, Param::Op, Param::G],
            [Param::Op, Param::Opc, Param::Ks, Param::G],
        ],
        temporal_priority: [Param::Op, Param::Ks, Param::Opc, Param::G],
        // Baseline "only unrolls the input and output feature maps"
        // (Fig. 13 discussion): both axes pinned to C.
        baseline_dims: vec![Some(vec![Dim::C]), Some(vec![Dim::C])],
        offload_overlap: 0.6,
    }
}

/// Simba ([26] in §4.4: "two spatial dimensions, one with input
/// parallel-reuse and the other with *reduce* but no overlap-reuse") —
/// not part of Table 4's evaluation set, included to demonstrate that
/// Algorithm 1 generalizes to further structures unchanged: a 16-chiplet
/// MCM with 16 PEs each, 8-wide dot-product units per PE modelled as the
/// reduce axis, small distributed weight buffers.
pub fn simba() -> AccelStructure {
    AccelStructure {
        name: "SIMBA",
        full_name: "Simba (MCM)",
        category: Category::Cip,
        spatial: vec![
            // Chiplet/PE axis: input multicast, no reduction across it.
            SpatialDim { name: "pe", size: 16 * 16, reduce: false, overlap: false },
            // Vector MAC lane: adder-tree reduction.
            SpatialDim { name: "lane", size: 8, reduce: true, overlap: false },
        ],
        ls: LocalStores { ils: 8, ols: 24, kls: 64 },
        gb: GlobalBuffer { i: 32 * KB, o: 32 * KB, k: 256 * KB },
        bw: Bandwidth { i: 16, o: 16, k: 16 },
        freq_ghz: 0.7,
        spatial_priority: vec![
            [Param::Op, Param::Opc, Param::Ks, Param::G],
            [Param::Ks, Param::Opc, Param::Op, Param::G],
        ],
        temporal_priority: [Param::Op, Param::Ks, Param::Opc, Param::G],
        baseline_dims: vec![Some(vec![Dim::C, Dim::H, Dim::W]), Some(vec![Dim::C])],
        offload_overlap: 0.5,
    }
}

/// All five accelerators in Table-4 order.
pub fn all_accelerators() -> Vec<AccelStructure> {
    vec![tpu(), dnnweaver(), eyeriss(), eager_pruning(), nlr()]
}

/// Accelerator codes in Table-4 order.
pub const ACCEL_CODES: [&str; 5] = ["TPU", "DNNW", "ER", "EP", "NLR"];

/// Look up an accelerator by its paper code.
pub fn by_code(code: &str) -> AccelStructure {
    match code {
        "TPU" => tpu(),
        "DNNW" => dnnweaver(),
        "ER" => eyeriss(),
        "EP" => eager_pruning(),
        "NLR" => nlr(),
        other => panic!("unknown accelerator {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_code_round_trips() {
        for code in ACCEL_CODES {
            assert_eq!(by_code(code).name, code);
        }
    }

    #[test]
    fn peak_rates_scale_with_pes() {
        // TPU (4096 PEs) has ~24x the peak rate of Eyeriss (168 PEs).
        let ratio = tpu().peak_macs_per_s() / eyeriss().peak_macs_per_s();
        assert!((ratio - 4096.0 / 168.0).abs() < 1e-9);
    }

    #[test]
    fn eyeriss_ls_matches_table4() {
        let er = eyeriss();
        assert_eq!((er.ls.ils, er.ls.ols, er.ls.kls), (12, 24, 224));
    }
}
