//! Host-offload model for CIP baselines (paper §6.2).
//!
//! Convolution-intended processors cannot parse non-traditional layers;
//! the baselines ship those layers' inputs to an ARM A53 over PCIe 4.0,
//! compute there, and reload the results. The offload can overlap
//! on-chip computation across mini-batches (double buffering), so the
//! chain-level latency is `max(on-chip, offload)` — which is exactly why
//! EagerPruning, the fastest on-chip baseline, "suffers the most from
//! offloading" (Fig. 12): its offload lane dominates.

/// The offload host + link.
#[derive(Clone, Copy, Debug)]
pub struct OffloadHost {
    /// Host sustained rate in ops/s (ARM A53 quad-core NEON ≈ 24 GFLOP/s).
    pub host_ops_per_s: f64,
    /// Effective PCIe bandwidth in words/s (PCIe 4.0 ×16 ≈ 16 GB/s
    /// effective = 8 G words/s at 16-bit).
    pub link_words_per_s: f64,
    /// Per-transfer fixed latency in seconds (driver + DMA setup).
    pub per_transfer_s: f64,
}

impl Default for OffloadHost {
    fn default() -> Self {
        OffloadHost {
            host_ops_per_s: 24.0e9,
            link_words_per_s: 8.0e9,
            per_transfer_s: 5.0e-6,
        }
    }
}

/// Latency + traffic of offloading one GCONV/layer to the host.
#[derive(Clone, Copy, Debug, Default)]
pub struct OffloadCost {
    /// Seconds on the host + link.
    pub seconds: f64,
    /// Words crossing the link (both directions) — charged at the 146×
    /// offload energy rate.
    pub words: f64,
}

impl OffloadHost {
    /// Cost of offloading an operation with the given footprint.
    pub fn cost(&self, work: usize, input_words: usize, output_words: usize) -> OffloadCost {
        let words = (input_words + output_words) as f64;
        let transfer = words / self.link_words_per_s + 2.0 * self.per_transfer_s;
        let compute = work as f64 / self.host_ops_per_s;
        OffloadCost { seconds: transfer + compute, words }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_offload() {
        // Heavy work, little data: host compute dominates.
        let h = OffloadHost::default();
        let c = h.cost(24_000_000_000, 1000, 1000);
        assert!((c.seconds - 1.0).abs() < 0.01, "{}", c.seconds);
    }

    #[test]
    fn transfer_bound_offload() {
        // Light work, much data: the link dominates.
        let h = OffloadHost::default();
        let c = h.cost(1000, 4_000_000_000, 4_000_000_000);
        assert!((c.seconds - 1.0).abs() < 0.01, "{}", c.seconds);
        assert_eq!(c.words, 8.0e9);
    }

    #[test]
    fn fixed_latency_floors_small_transfers() {
        let h = OffloadHost::default();
        let c = h.cost(0, 1, 1);
        assert!(c.seconds >= 2.0 * h.per_transfer_s);
    }
}
