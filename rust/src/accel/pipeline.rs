//! LIP two-stage pipeline model (paper §2.3 / Fig. 12).
//!
//! Layer-instruction processors dedicate one engine to traditional
//! layers and one to the non-traditional rest, pipelined across inputs.
//! Resources are partitioned once — "based on the ratio of the
//! traditional and non-traditional computation in all the networks"
//! (Table 1(b) column 3) — so per-network imbalance creates pipeline
//! bubbles, and barrier layers (batch normalization reduces over the
//! whole mini-batch) drain the pipeline entirely.

/// Fixed resource split of the LIP (fraction given to the traditional
/// stage). Derived from the average traditional-computation share across
/// the seven benchmarks, which the 3-D/capsule networks pull down.
pub const TRADITIONAL_SHARE: f64 = 0.7;

/// Outcome of running a workload through the two-stage pipeline.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineResult {
    /// Total seconds.
    pub seconds: f64,
    /// Seconds only the traditional stage is busy.
    pub trad_only: f64,
    /// Seconds only the non-traditional stage is busy.
    pub nontrad_only: f64,
    /// Seconds both stages overlap ("all-busy" in Fig. 12).
    pub all_busy: f64,
    /// Average PE utilization (Table 1(b) column 3).
    pub utilization: f64,
}

/// Simulate the pipeline given per-class busy times *at full-chip speed*
/// and the number of pipeline barriers (layers that forbid overlap).
///
/// `trad_s`/`nontrad_s`: time each class would take using the whole
/// chip. The stages own `TRADITIONAL_SHARE` / `1−TRADITIONAL_SHARE` of
/// the resources, so their stage times inflate accordingly. Barriers
/// split the run into `barriers + 1` segments that cannot overlap.
pub fn pipeline(trad_s: f64, nontrad_s: f64, barriers: usize) -> PipelineResult {
    let t = trad_s / TRADITIONAL_SHARE;
    let n = nontrad_s / (1.0 - TRADITIONAL_SHARE);
    let segments = (barriers + 1) as f64;
    // Within a segment the stages overlap; across barriers they drain.
    // Per segment: max(t,n)/segments overlapped + pipeline fill/drain of
    // the shorter stage once per segment.
    let long = t.max(n);
    let short = t.min(n);
    let fill = short / segments; // fill+drain cost per barrier segment
    let seconds = long + fill * (segments - 1.0).max(0.0) / segments;
    let all_busy = short * (1.0 / segments).max(1.0 - barriers as f64 * 0.1).clamp(0.0, 1.0);
    let trad_only = (t - all_busy).max(0.0);
    let nontrad_only = (n - all_busy).max(0.0);
    // Utilization: busy resource-seconds over total resource-seconds.
    let utilization =
        (trad_s + nontrad_s) / seconds.max(f64::EPSILON);
    PipelineResult {
        seconds,
        trad_only,
        nontrad_only,
        all_busy,
        utilization: utilization.min(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_load_high_utilization() {
        // Work split matching the resource split → near-full utilization.
        let r = pipeline(0.7, 0.3, 0);
        assert!(r.utilization > 0.9, "utilization {}", r.utilization);
    }

    #[test]
    fn imbalanced_load_starves_a_stage() {
        // All-traditional workload leaves the non-traditional stage idle:
        // utilization ≈ the traditional share.
        let r = pipeline(1.0, 0.0, 0);
        assert!(
            (r.utilization - TRADITIONAL_SHARE).abs() < 0.05,
            "utilization {}",
            r.utilization
        );
    }

    #[test]
    fn barriers_slow_the_pipeline() {
        let free = pipeline(0.5, 0.5, 0);
        let barred = pipeline(0.5, 0.5, 50);
        assert!(barred.seconds > free.seconds);
        assert!(barred.utilization < free.utilization);
    }

    #[test]
    fn nontraditional_heavy_network_collapses() {
        // C3D-like: 99% non-traditional work on a 30% stage → utilization
        // craters (Table 1(b) reports 1%-ish).
        let r = pipeline(0.01, 0.99, 0);
        assert!(r.utilization < 0.4, "utilization {}", r.utilization);
    }
}
