//! The abstract accelerator structure the GCONV mapper consumes (§4.4).
//!
//! "All the accelerators manifest both the spatial and temporal unrolling
//! dimensions. The difference lies in the number and functions of the
//! spatial dimensions as well as the capacity and hierarchy of the
//! memory." Each spatial dimension carries capability flags; local
//! scratchpads that do not exist are modelled with size 1.

use crate::gconv::op::Param;

/// Accelerator class per the paper's taxonomy (§2.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    /// Tensor instruction processor (RISC-like, im2col).
    Tip,
    /// Layer instruction processor (dedicated unit per layer type).
    Lip,
    /// Convolution intended processor.
    Cip,
}

impl Category {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Category::Tip => "TIP",
            Category::Lip => "LIP",
            Category::Cip => "CIP",
        }
    }
}

/// One spatial unrolling dimension of the PE array.
#[derive(Clone, Debug)]
pub struct SpatialDim {
    /// Display name (`"py"`, `"px"`, `"sub"`, …).
    pub name: &'static str,
    /// Number of PEs along this dimension.
    pub size: usize,
    /// Partial results can be reduced along this dimension (forwarding
    /// links / adder chains) — required to spatially unroll `ks`.
    pub reduce: bool,
    /// This dimension participates in the overlap-reuse primitive
    /// (row-stationary-style diagonal sharing, Fig. 8(b)).
    pub overlap: bool,
}

/// Per-PE local scratchpad capacities in words (1 = a pipeline register,
/// i.e. no temporal reuse at this level).
#[derive(Clone, Copy, Debug)]
pub struct LocalStores {
    /// Input scratchpad (ILS).
    pub ils: usize,
    /// Output scratchpad (OLS).
    pub ols: usize,
    /// Kernel-parameter scratchpad (KLS).
    pub kls: usize,
}

/// Global buffer capacities in words (16-bit words as in Eyeriss).
#[derive(Clone, Copy, Debug)]
pub struct GlobalBuffer {
    /// Input partition.
    pub i: usize,
    /// Output partition.
    pub o: usize,
    /// Kernel-parameter partition.
    pub k: usize,
}

/// Words per cycle between global buffer and PE array.
#[derive(Clone, Copy, Debug)]
pub struct Bandwidth {
    /// Input bus.
    pub i: usize,
    /// Output bus.
    pub o: usize,
    /// Kernel-parameter bus.
    pub k: usize,
}

/// A complete accelerator description (Table 4 row).
#[derive(Clone, Debug)]
pub struct AccelStructure {
    /// Display name (`"ER"`, `"TPU"`, …).
    pub name: &'static str,
    /// Full name for reports.
    pub full_name: &'static str,
    /// Accelerator class.
    pub category: Category,
    /// Spatial unrolling dimensions (PE-array axes), outermost first.
    pub spatial: Vec<SpatialDim>,
    /// Per-PE local scratchpads.
    pub ls: LocalStores,
    /// Global buffer partitions.
    pub gb: GlobalBuffer,
    /// GB↔array bandwidths.
    pub bw: Bandwidth,
    /// Clock (all Table-4 accelerators run at 700 MHz, §6.2).
    pub freq_ghz: f64,
    /// Spatial fill priority per axis for the *GCONV* mapping
    /// (Algorithm 1 lines 14–19; §4.4: per-accelerator priority tweaks).
    pub spatial_priority: Vec<[Param; 4]>,
    /// Temporal fill priority (Algorithm 1 lines 20–22).
    pub temporal_priority: [Param; 4],
    /// Dimensions the *baseline* dataflow restricts each spatial axis to
    /// (None = the baseline can use any dim, as in flexible baselines).
    pub baseline_dims: Vec<Option<Vec<crate::ir::Dim>>>,
    /// Fraction of host-offload time the baseline can hide behind
    /// on-chip computation (§6.3: "ER and NLR can overlap the offloading
    /// by computation to some extent"; EP, with the highest on-chip
    /// performance and a fully-synchronous subsystem design, hides the
    /// least and "suffers the most from offloading").
    pub offload_overlap: f64,
}

impl AccelStructure {
    /// Total number of PEs.
    pub fn pes(&self) -> usize {
        self.spatial.iter().map(|s| s.size).product()
    }

    /// Peak MACs/s.
    pub fn peak_macs_per_s(&self) -> f64 {
        self.pes() as f64 * self.freq_ghz * 1e9
    }

    /// Index of the first reduce-capable spatial axis, if any.
    pub fn reduce_axis(&self) -> Option<usize> {
        self.spatial.iter().position(|s| s.reduce)
    }

    /// Index of the overlap-primitive spatial axis, if any.
    pub fn overlap_axis(&self) -> Option<usize> {
        self.spatial.iter().position(|s| s.overlap)
    }

    /// LS capacity for a store kind (`'i'`, `'o'`, `'k'`).
    pub fn ls_cap(&self, store: char) -> usize {
        match store {
            'i' => self.ls.ils,
            'o' => self.ls.ols,
            'k' => self.ls.kls,
            _ => panic!("unknown store {store}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::*;

    #[test]
    fn pe_counts_match_table4() {
        assert_eq!(tpu().pes(), 4096);
        assert_eq!(eyeriss().pes(), 168);
        assert_eq!(eager_pruning().pes(), 2048);
        assert_eq!(nlr().pes(), 448);
        assert_eq!(dnnweaver().pes(), 14 * 74);
    }

    #[test]
    fn eyeriss_has_reduce_and_overlap_axes() {
        let er = eyeriss();
        assert_eq!(er.reduce_axis(), Some(0)); // py forwarding links
        assert!(er.overlap_axis().is_some());
    }

    #[test]
    fn tpu_has_no_overlap_primitive() {
        assert!(tpu().overlap_axis().is_none());
    }

    #[test]
    fn categories_match_table4() {
        assert_eq!(tpu().category, Category::Tip);
        assert_eq!(dnnweaver().category, Category::Lip);
        for a in [eyeriss(), eager_pruning(), nlr()] {
            assert_eq!(a.category, Category::Cip);
        }
    }
}
