//! TIP baseline: im2col lowering with input replication (paper §2.3,
//! Fig. 1(c)).
//!
//! Tensor instruction processors flatten every convolution window into a
//! matrix column and run a matrix multiply. The transformation destroys
//! overlap-reuse: every input element is replicated into each window
//! that covers it (the red cells of Fig. 1(c)), inflating input traffic
//! by `Π_d Nks_d / s_d` for the sliding dims (Table 1(b) column 1
//! measures 2×–35× across the benchmarks).

use crate::gconv::op::{DimParams, GconvOp};
use crate::ir::Dim;

/// Rewrite a GCONV as the matrix operation a TIP executes.
///
/// All sliding-window (`Nks`,`Nopc`) dims collapse into the matmul
/// reduction: the kernel loops move into the C dimension's `Nks`
/// (columns of the weight matrix) and every output position becomes a
/// row of the im2col input matrix (folded into B's `Nopc`).
pub fn im2col_op(op: &GconvOp) -> GconvOp {
    let mut out = op.clone();
    let mut ks_total = 1usize; // reduction length from sliding dims
    let mut positions = 1usize; // output positions from sliding dims
    let mut dims: Vec<(Dim, DimParams)> = Vec::new();
    for &(d, p) in &op.dims {
        match d {
            Dim::C | Dim::B => dims.push((d, p)),
            _ => {
                // Sliding dim: kernel extent joins the reduction, output
                // extent joins the positions; group loops stay.
                ks_total *= p.nks;
                positions *= p.nopc;
                if p.ng > 1 {
                    dims.push((d, DimParams::g(p.ng)));
                }
            }
        }
    }
    for (d, p) in dims.iter_mut() {
        match d {
            Dim::C => p.nks *= ks_total,
            Dim::B => p.nopc *= positions,
            _ => {}
        }
    }
    if !dims.iter().any(|&(d, _)| d == Dim::C) && ks_total > 1 {
        dims.push((Dim::C, DimParams::ks(ks_total)));
    }
    if !dims.iter().any(|&(d, _)| d == Dim::B) && positions > 1 {
        dims.push((Dim::B, DimParams::opc(positions)));
    }
    out.dims = dims;
    out.name = format!("{}.im2col", op.name);
    out
}

/// Input replication factor of the im2col transform: replicated input
/// elements / original input elements (Table 1(b) column 1).
pub fn replication_factor(op: &GconvOp) -> f64 {
    let original = op.input_elements() as f64;
    let replicated = im2col_op(op).input_elements() as f64;
    (replicated / original).max(1.0)
}

/// Does this op even have sliding windows to replicate?
pub fn has_overlap(op: &GconvOp) -> bool {
    op.dims.iter().any(|&(_, p)| p.overlap_reuse())
}

/// TIP control/load instruction overhead per matrix operation (§6.4:
/// TIPs "require load instructions ... and control operations when the
/// computation cannot be mapped to only one matrix/vector operation").
/// Returns the instruction count the TIP needs for this op.
pub fn tip_instruction_count(op: &GconvOp, matrix_tile: usize) -> usize {
    let m = im2col_op(op);
    // Matrix ops executed tile by tile: one compute + two load + one
    // store instruction per tile.
    let work = m.work();
    let tiles = work.div_ceil(matrix_tile.max(1));
    4 * tiles.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::op::{DataRef, Param};

    fn conv(ks: usize, s: usize) -> GconvOp {
        GconvOp::conv(
            "c",
            vec![
                (Dim::B, DimParams::opc(4)),
                (Dim::C, DimParams { nop: 8, nks: 3, ..Default::default() }),
                (Dim::H, DimParams::window(16, ks, s, ks / 2)),
                (Dim::W, DimParams::window(16, ks, s, ks / 2)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        )
    }

    #[test]
    fn im2col_preserves_work_and_outputs() {
        let op = conv(3, 1);
        let m = im2col_op(&op);
        assert_eq!(op.work(), m.work());
        assert_eq!(op.output_elements(), m.output_elements());
    }

    #[test]
    fn replication_grows_with_kernel_and_shrinks_with_stride() {
        // 3x3 stride 1 replicates ~9x; stride 2 about a quarter of that.
        let r1 = replication_factor(&conv(3, 1));
        let r2 = replication_factor(&conv(3, 2));
        assert!(r1 > 6.0 && r1 <= 9.5, "r1 = {r1}");
        assert!(r2 < r1 / 2.0, "r2 = {r2}");
    }

    #[test]
    fn elementwise_has_no_replication() {
        let ew = GconvOp {
            name: "relu".into(),
            dims: vec![(Dim::B, DimParams::opc(4)), (Dim::C, DimParams::opc(64))],
            pre: crate::gconv::op::PreOp::None,
            main: crate::gconv::op::MainOp::Pass,
            reduce: crate::gconv::op::ReduceOp::None,
            post: crate::gconv::op::PostOp::Lut("relu"),
            input: DataRef::External("x".into()),
            kernel: None,
        };
        assert_eq!(replication_factor(&ew), 1.0);
        assert!(!has_overlap(&ew));
    }

    #[test]
    fn im2col_collapses_sliding_dims() {
        let m = im2col_op(&conv(3, 1));
        // No H/W loops remain; C carries the 3*3*3 reduction.
        assert_eq!(m.params(Dim::C).nks, 27);
        assert_eq!(m.params(Dim::H).get(Param::Ks), 1);
        assert_eq!(m.params(Dim::B).nopc, 4 * 16 * 16);
    }
}
