//! Energy model.
//!
//! Per-event energies are in normalized units (1 = one MAC at 16 bit),
//! the standard relative costs of the CNN-accelerator literature
//! (Eyeriss/EIE): a local-scratchpad access costs about the same as a
//! MAC, a global-buffer access ~6×, and host offloading — PCIe transfer
//! + DRAM at both ends — is charged at the paper's measured ratio:
//! "the offloading energy consumption can be as high as 146× of the
//! on-chip data movement" (§2.3).

pub mod overhead;

/// Per-event energy table (normalized units per 16-bit word / op).
#[derive(Clone, Copy, Debug)]
pub struct EnergyTable {
    /// One main-operator evaluation (MAC).
    pub mac: f64,
    /// One local-scratchpad access.
    pub ls: f64,
    /// One global-buffer access.
    pub gb: f64,
    /// One word moved to/from the offload host (PCIe + host memory).
    pub offload: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        // offload = 146x the on-chip (GB) movement cost, §2.3.
        EnergyTable { mac: 1.0, ls: 1.0, gb: 6.0, offload: 6.0 * 146.0 }
    }
}

/// Energy totals of a simulated run (normalized units).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Energy {
    /// Main/reduce/pre/post operator evaluations.
    pub compute: f64,
    /// Local-scratchpad traffic.
    pub ls: f64,
    /// Global-buffer traffic.
    pub gb: f64,
    /// Offload traffic (CIP baselines only).
    pub offload: f64,
}

impl Energy {
    /// Total energy.
    pub fn total(&self) -> f64 {
        self.compute + self.ls + self.gb + self.offload
    }

    /// Movement-only energy (the Fig. 18 metric: on-chip GB movements
    /// plus offloading/reloading; LS and compute excluded).
    pub fn movement(&self) -> f64 {
        self.gb + self.offload
    }

    /// Element-wise accumulate.
    pub fn add(&mut self, other: &Energy) {
        self.compute += other.compute;
        self.ls += other.ls;
        self.gb += other.gb;
        self.offload += other.offload;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offload_ratio_matches_paper() {
        let t = EnergyTable::default();
        assert!((t.offload / t.gb - 146.0).abs() < 1e-9);
    }

    #[test]
    fn totals_accumulate() {
        let mut e = Energy { compute: 1.0, ls: 2.0, gb: 3.0, offload: 4.0 };
        e.add(&Energy { compute: 1.0, ls: 1.0, gb: 1.0, offload: 1.0 });
        assert_eq!(e.total(), 14.0);
        assert_eq!(e.movement(), 9.0);
    }
}
