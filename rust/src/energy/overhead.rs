//! GCONV-support area and power overhead on a CIP (paper §6.4,
//! Figs. 16/17).
//!
//! The overhead has three components (Fig. 11): *storage* for the three
//! instruction buffers, *compute* for widening the fixed multiply/add
//! PEs into `main`/`reduce` operators, and *control* for the
//! decoder + unrolling-list state machine. The paper synthesizes Eyeriss
//! and reports 20% area and 19% power overhead in total; we derive the
//! same breakdown structurally from the Eyeriss area/power budget
//! reported in the original work.

/// Relative area/power budget of a baseline CIP (fractions of total).
#[derive(Clone, Copy, Debug)]
pub struct ChipBudget {
    /// PE-array arithmetic.
    pub pe_arith: f64,
    /// Local scratchpads.
    pub ls: f64,
    /// Global buffer.
    pub gb: f64,
    /// NoC + control.
    pub control: f64,
}

impl ChipBudget {
    /// Eyeriss-like budget (derived from the ISSCC'16 breakdown).
    pub fn eyeriss() -> Self {
        ChipBudget { pe_arith: 0.27, ls: 0.40, gb: 0.23, control: 0.10 }
    }
}

/// GCONV-support overhead, each component as a fraction of the baseline
/// chip total.
#[derive(Clone, Copy, Debug)]
pub struct Overhead {
    /// Instruction buffers (basic info + unrolling lists + output
    /// addresses, Fig. 11(a)).
    pub storage: f64,
    /// `main`/`reduce` operator generalization in every PE (Fig. 11(b)).
    pub compute: f64,
    /// Decoder + loop state machine + MUXes (Fig. 11(c)).
    pub control: f64,
}

impl Overhead {
    /// Total overhead fraction.
    pub fn total(&self) -> f64 {
        self.storage + self.compute + self.control
    }
}

/// Area overhead of GCONV support on an Eyeriss-class CIP.
///
/// * storage: the three instruction buffers are small SRAM — ~4% of the
///   global-buffer area budget scaled by buffer depth.
/// * compute: adding comparator/AND/square paths + operand MUXes to each
///   PE costs ~30% of each PE's arithmetic area.
/// * control: the Fig. 11(c) state machine (counters + 16:1 MUX + address
///   generator) roughly doubles the (small) control budget.
pub fn area_overhead(budget: &ChipBudget) -> Overhead {
    Overhead {
        storage: 0.15 * budget.gb,
        compute: 0.30 * budget.pe_arith,
        control: 0.80 * budget.control,
    }
}

/// Power overhead — same structure; instruction buffers toggle less than
/// data buffers, the widened PEs burn a bit more per op, and the decoder
/// runs continuously.
pub fn power_overhead(budget: &ChipBudget) -> Overhead {
    Overhead {
        storage: 0.12 * budget.gb,
        compute: 0.32 * budget.pe_arith,
        control: 0.75 * budget.control,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_paper_figures_16_17() {
        // Paper §6.4: "GCONV Chain brings 20% area and 19% power
        // consumption overhead."
        let b = ChipBudget::eyeriss();
        let area = area_overhead(&b).total();
        let power = power_overhead(&b).total();
        assert!((area - 0.20).abs() < 0.02, "area overhead {area:.3}");
        assert!((power - 0.19).abs() < 0.02, "power overhead {power:.3}");
    }

    #[test]
    fn components_are_positive() {
        let o = area_overhead(&ChipBudget::eyeriss());
        assert!(o.storage > 0.0 && o.compute > 0.0 && o.control > 0.0);
    }
}
