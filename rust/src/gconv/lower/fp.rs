//! Forward-propagation lowering of each layer kind.

use super::{ew_dims, ew_op, reduce_op, Lowerer};
use crate::gconv::chain::SpecialOp;
use crate::gconv::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp, ReduceOp};
use crate::ir::{Dim, Layer, NodeId, PoolKind, Shape};

impl Lowerer<'_> {
    /// Lower the forward pass of node `id`, recording its activation ref.
    pub fn lower_fp(&mut self, id: NodeId) {
        let node = self.net.node(id).clone();
        let name = node.name.clone();
        let out = node.output.clone();
        let ins: Vec<DataRef> = node.inputs.iter().map(|&i| self.act_of(i)).collect();
        let in_shapes: Vec<Shape> =
            node.inputs.iter().map(|&i| self.net.node(i).output.clone()).collect();

        let act = match &node.layer {
            Layer::Input { .. } => DataRef::External(format!("{name}.data")),
            Layer::Conv { out_channels, kernel, stride, pad, groups } => {
                let s = &in_shapes[0];
                let op = conv_gconv(
                    &format!("{name}.fp"),
                    s,
                    &out,
                    *out_channels,
                    (1, kernel.0, kernel.1),
                    *stride,
                    *pad,
                    *groups,
                    ins[0].clone(),
                    DataRef::Weights(name.clone()),
                );
                self.emit_fp(id, op)
            }
            Layer::Conv3d { out_channels, kernel, stride, pad } => {
                let s = &in_shapes[0];
                let op = conv_gconv(
                    &format!("{name}.fp"),
                    s,
                    &out,
                    *out_channels,
                    *kernel,
                    *stride,
                    *pad,
                    1,
                    ins[0].clone(),
                    DataRef::Weights(name.clone()),
                );
                self.emit_fp(id, op)
            }
            Layer::FullyConnected { out_features } => {
                let s = &in_shapes[0];
                // Kernel covers the whole input in every non-batch dim.
                let mut dims = vec![(Dim::B, DimParams::opc(s.extent(Dim::B)))];
                for (d, n) in s.iter() {
                    if d == Dim::B || n == 1 {
                        continue;
                    }
                    let p = if d == Dim::C {
                        DimParams { nop: *out_features, nks: n, ..Default::default() }
                    } else {
                        DimParams::ks(n)
                    };
                    dims.push((d, p));
                }
                let op = GconvOp::conv(
                    &format!("{name}.fp"),
                    dims,
                    ins[0].clone(),
                    DataRef::Weights(name.clone()),
                );
                self.emit_fp(id, op)
            }
            Layer::Pool { kind, kernel, stride, pad } => {
                let op = pool_gconv(
                    &format!("{name}.fp"),
                    &in_shapes[0],
                    &out,
                    *kind,
                    (1, *kernel, *kernel),
                    (1, *stride, *stride),
                    *pad,
                    ins[0].clone(),
                );
                self.emit_fp(id, op)
            }
            Layer::Pool3d { kind, kernel, stride } => {
                let op = pool_gconv(
                    &format!("{name}.fp"),
                    &in_shapes[0],
                    &out,
                    *kind,
                    *kernel,
                    *stride,
                    0,
                    ins[0].clone(),
                );
                self.emit_fp(id, op)
            }
            Layer::GlobalAvgPool => {
                let s = &in_shapes[0];
                let hw = (s.extent(Dim::H) * s.extent(Dim::W)) as f32;
                let op = reduce_op(
                    &format!("{name}.fp"),
                    s,
                    &[Dim::H, Dim::W],
                    PreOp::None,
                    ReduceOp::Add,
                    PostOp::Mul(1.0 / hw),
                    ins[0].clone(),
                );
                self.emit_fp(id, op)
            }
            Layer::Relu => {
                let op = ew_op(
                    &format!("{name}.fp"),
                    &out,
                    &[],
                    PreOp::None,
                    MainOp::Pass,
                    PostOp::Lut("relu"),
                    ins[0].clone(),
                    None,
                );
                self.emit_fp(id, op)
            }
            Layer::Sigmoid => {
                let op = ew_op(
                    &format!("{name}.fp"),
                    &out,
                    &[],
                    PreOp::None,
                    MainOp::Pass,
                    PostOp::Lut("sigmoid"),
                    ins[0].clone(),
                    None,
                );
                self.emit_fp(id, op)
            }
            Layer::Softmax => self.lower_softmax_fp(id, &name, &out, ins[0].clone()),
            Layer::Lrn { local_size } => {
                let s = &in_shapes[0];
                // G1: channel-window sum of squares, LUT to the scale
                // (§3.1: LRN is a general convolution in C).
                let mut dims = ew_dims(s, &[]);
                for (d, p) in dims.iter_mut() {
                    if *d == Dim::C {
                        *p = DimParams::window(s.extent(Dim::C), *local_size, 1, (local_size - 1) / 2);
                    }
                }
                let g1 = GconvOp {
                    name: format!("{name}.FP1"),
                    dims,
                    pre: PreOp::Square,
                    main: MainOp::Pass,
                    reduce: ReduceOp::Add,
                    post: PostOp::Lut("lrn_scale"),
                    input: ins[0].clone(),
                    kernel: None,
                };
                let g1 = self.emit_fp_tmp(id, g1);
                // G2: element-wise multiply by the scale (varies everywhere).
                let g2 = ew_op(
                    &format!("{name}.FP2"),
                    &out,
                    &out.dims(),
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    ins[0].clone(),
                    Some(g1),
                );
                self.emit_fp(id, g2)
            }
            Layer::BatchNorm => self.lower_bn_fp(id, &name, &in_shapes[0], ins[0].clone()),
            Layer::Scale => {
                // Per-channel y = γ·x + β: kernel varies over C only.
                let g1 = ew_op(
                    &format!("{name}.FP1"),
                    &out,
                    &[Dim::C],
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    ins[0].clone(),
                    Some(DataRef::Weights(format!("{name}.gamma"))),
                );
                let g1 = self.emit_fp_tmp(id, g1);
                let g2 = ew_op(
                    &format!("{name}.FP2"),
                    &out,
                    &[Dim::C],
                    PreOp::None,
                    MainOp::Add,
                    PostOp::None,
                    g1,
                    Some(DataRef::Weights(format!("{name}.beta"))),
                );
                self.emit_fp(id, g2)
            }
            Layer::Dropout => {
                // Training-mode dropout: multiply by the Bernoulli mask
                // (mask varies in every dimension — no kernel reuse).
                let op = ew_op(
                    &format!("{name}.fp"),
                    &out,
                    &out.dims(),
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    ins[0].clone(),
                    Some(DataRef::Weights(format!("{name}.mask"))),
                );
                self.emit_fp(id, op)
            }
            Layer::Concat => {
                // Pairwise channel-concatenation steps (pure data
                // movement, executed by a dedicated native routine):
                // each step copies the accumulated prefix and the next
                // branch side by side along C. A single-input concat
                // degenerates to one copy GCONV.
                assert!(!ins.is_empty(), "concat with no inputs");
                if ins.len() == 1 {
                    let op = ew_op(
                        &format!("{name}.FP1"),
                        &in_shapes[0],
                        &[],
                        PreOp::None,
                        MainOp::Pass,
                        PostOp::None,
                        ins[0].clone(),
                        None,
                    );
                    self.emit_fp(id, op)
                } else {
                    let mut acc = ins[0].clone();
                    let mut acc_c = in_shapes[0].extent(Dim::C);
                    let mut acc_shape = in_shapes[0].clone();
                    for (bi, (r, s)) in ins.iter().zip(&in_shapes).enumerate().skip(1) {
                        let branch_c = s.extent(Dim::C);
                        acc_shape = acc_shape.with(Dim::C, acc_c + branch_c);
                        let dims = ew_dims(&acc_shape, &[]);
                        let axis = dims
                            .iter()
                            .position(|&(d, _)| d == Dim::C)
                            .expect("concat output has no C dimension");
                        let op = GconvOp {
                            name: format!("{name}.FP{bi}"),
                            dims,
                            pre: PreOp::None,
                            main: MainOp::Pass,
                            reduce: ReduceOp::None,
                            post: PostOp::None,
                            input: acc.clone(),
                            kernel: Some(r.clone()),
                        };
                        let sp = SpecialOp::Concat {
                            axis,
                            pre_extent: acc_c,
                            branch_extent: branch_c,
                        };
                        acc = self.emit_fp_special(id, op, sp);
                        acc_c += branch_c;
                    }
                    acc
                }
            }
            Layer::Eltwise => {
                // Pairwise adds (kernel = other operand, varies everywhere).
                let mut acc = ins[0].clone();
                for (bi, r) in ins.iter().enumerate().skip(1) {
                    let op = ew_op(
                        &format!("{name}.FP{bi}"),
                        &out,
                        &out.dims(),
                        PreOp::None,
                        MainOp::Add,
                        PostOp::None,
                        acc,
                        Some(r.clone()),
                    );
                    acc = self.emit_fp(id, op);
                }
                acc
            }
            Layer::RoiPool { num_rois, output } => {
                let s = &in_shapes[0];
                // Each RoI max-pools an adaptive window; modelled as a
                // pooled GCONV whose B dim carries batch × #rois.
                // Adaptive-pool arithmetic: stride = ⌊in/out⌋ and kernel
                // = in − (out−1)·stride, so the windows exactly tile the
                // input (any residual overhang becomes end padding and
                // is skipped by the Max reduction).
                let adaptive = |inp: usize, out: usize| {
                    let st = (inp / out).max(1);
                    let k = inp.saturating_sub((out - 1) * st).max(1);
                    let pe = ((out - 1) * st + k).saturating_sub(inp);
                    DimParams { nopc: out, nks: k, s: st, pe, ..Default::default() }
                };
                let dims = vec![
                    (Dim::B, DimParams::opc(s.extent(Dim::B) * num_rois)),
                    (Dim::C, DimParams::opc(s.extent(Dim::C))),
                    (Dim::H, adaptive(s.extent(Dim::H), output.0)),
                    (Dim::W, adaptive(s.extent(Dim::W), output.1)),
                ];
                let op = GconvOp {
                    name: format!("{name}.fp"),
                    dims,
                    pre: PreOp::None,
                    main: MainOp::Pass,
                    reduce: ReduceOp::Max,
                    post: PostOp::None,
                    input: ins[0].clone(),
                    kernel: None,
                };
                self.emit_fp(id, op)
            }
            Layer::Proposal { .. } => {
                // Box regression (per-anchor affine) + objectness LUT +
                // NMS-style max over neighbourhoods; three GCONVs. The
                // regression widens C (4 coordinates per anchor vs 2
                // scores): Ng groups of Nop parallel one-weight kernels
                // when the widths divide, a full Nop×Nks mix otherwise.
                let s = &in_shapes[0];
                let icc = s.extent(Dim::C);
                let occ = out.extent(Dim::C);
                let (cp, red) = if occ % icc == 0 {
                    (DimParams { ng: icc, nop: occ / icc, ..Default::default() }, ReduceOp::None)
                } else {
                    (DimParams { nop: occ, nks: icc, ..Default::default() }, ReduceOp::Add)
                };
                let mut dims = Vec::new();
                for (d, n) in out.iter() {
                    if d == Dim::C {
                        dims.push((d, cp));
                    } else if n > 1 {
                        dims.push((d, DimParams::opc(n)));
                    }
                }
                let g1 = GconvOp {
                    name: format!("{name}.FP1"),
                    dims,
                    pre: PreOp::None,
                    main: MainOp::Mul,
                    reduce: red,
                    post: PostOp::None,
                    input: ins[0].clone(),
                    kernel: Some(DataRef::Weights(format!("{name}.anchors"))),
                };
                let g1 = self.emit_fp_tmp(id, g1);
                let g2 = ew_op(
                    &format!("{name}.FP2"),
                    &out,
                    &[],
                    PreOp::None,
                    MainOp::Pass,
                    PostOp::Lut("sigmoid"),
                    g1,
                    None,
                );
                let g2 = self.emit_fp_tmp(id, g2);
                // NMS approximation: max over 3x3 spatial neighbourhoods.
                let mut dims = ew_dims(&out, &[]);
                for (d, p) in dims.iter_mut() {
                    if *d == Dim::H || *d == Dim::W {
                        let n = out.extent(*d);
                        *p = DimParams::window(n, 3.min(n), 1, if n >= 3 { 1 } else { 0 });
                    }
                }
                let g3 = GconvOp {
                    name: format!("{name}.FP3"),
                    dims,
                    pre: PreOp::None,
                    main: MainOp::Pass,
                    reduce: ReduceOp::Max,
                    post: PostOp::None,
                    input: g2,
                    kernel: None,
                };
                self.emit_fp(id, g3)
            }
            Layer::PrimaryCaps { caps_channels, vec, kernel, stride } => {
                let s = &in_shapes[0];
                // Capsule convolution: a conv whose V dim applies `vec`
                // kernels in parallel (pose components).
                let mut op = conv_gconv(
                    &format!("{name}.FP1"),
                    s,
                    &out,
                    *caps_channels,
                    (1, *kernel, *kernel),
                    *stride,
                    0,
                    1,
                    ins[0].clone(),
                    DataRef::Weights(name.clone()),
                );
                op.dims.push((Dim::V, DimParams::op(*vec)));
                let u = self.emit_fp_tmp(id, op);
                self.lower_squash(id, &name, &out, u, 1)
            }
            Layer::DigitCaps { out_caps, out_vec, routing } => {
                let s = &in_shapes[0];
                let in_caps = s.extent(Dim::C)
                    * s.extent(Dim::H)
                    * s.extent(Dim::W)
                    * s.extent(Dim::T);
                let in_vec = s.extent(Dim::V);
                let nbs = s.extent(Dim::B);
                // û_{j|i} = W_{ij} u_i : the dominant computation.
                let pred = GconvOp::conv(
                    &format!("{name}.FP1"),
                    vec![
                        (Dim::B, DimParams::opc(nbs)),
                        (Dim::C, DimParams { ng: in_caps, nop: *out_caps, ..Default::default() }),
                        (Dim::V, DimParams { nop: *out_vec, nks: in_vec, ..Default::default() }),
                    ],
                    ins[0].clone(),
                    DataRef::Weights(name.clone()),
                );
                let pred = self.emit_fp_tmp(id, pred);
                // Dynamic routing iterations.
                let pred_shape = Shape::new(&[
                    (Dim::B, nbs),
                    (Dim::C, in_caps * out_caps),
                    (Dim::V, *out_vec),
                ]);
                let mut v = pred.clone();
                for it in 0..*routing {
                    // c = softmax(b) over output capsules (2 GCONVs: exp
                    // reduction + normalize).
                    let logits_shape =
                        Shape::new(&[(Dim::B, nbs), (Dim::C, in_caps * out_caps)]);
                    let denom = reduce_op(
                        &format!("{name}.R{it}.softmax_sum"),
                        &logits_shape,
                        &[Dim::C],
                        PreOp::Lut("exp"),
                        ReduceOp::Add,
                        PostOp::Lut("recip"),
                        DataRef::External(format!("{name}.b{it}")),
                    );
                    let denom = self.emit_fp_tmp(id, denom);
                    let c = ew_op(
                        &format!("{name}.R{it}.softmax_mul"),
                        &logits_shape,
                        &[Dim::B],
                        PreOp::Lut("exp"),
                        MainOp::Mul,
                        PostOp::None,
                        DataRef::External(format!("{name}.b{it}")),
                        Some(denom),
                    );
                    let c = self.emit_fp_tmp(id, c);
                    // s_j = Σ_i c_{ij} û_{j|i} — reduce over input
                    // capsules. The input is the *fixed* prediction
                    // tensor û (reading the squashed v here under-covered
                    // the nest from iteration 1). KNOWN APPROXIMATION:
                    // û is laid out i-major (FP1's Ng = in_caps) while
                    // this nest reads it j-major — the four-loop algebra
                    // cannot transpose (groups are always outermost), so
                    // the routing pairs c_{ij} with a permuted û element.
                    // Loop counts, operand footprints and executability
                    // are exact; the permutation is the same one the
                    // seed's analytical form carried.
                    let sum = GconvOp {
                        name: format!("{name}.R{it}.agree_sum"),
                        dims: vec![
                            // B is a group dim: the routing coefficients
                            // c (the kernel operand) vary per sample.
                            (Dim::B, DimParams::g(nbs)),
                            (Dim::C, DimParams { ng: *out_caps, nks: in_caps, ..Default::default() }),
                            (Dim::V, DimParams::opc(*out_vec)),
                        ],
                        pre: PreOp::None,
                        main: MainOp::Mul,
                        reduce: ReduceOp::Add,
                        post: PostOp::None,
                        input: pred.clone(),
                        kernel: Some(c),
                    };
                    let sj = self.emit_fp_tmp(id, sum);
                    v = self.lower_squash(id, &format!("{name}.R{it}"), &out, sj, 2);
                    if it + 1 < *routing {
                        // b += û·v agreement (dot over V). The kernel v
                        // varies per (sample, output capsule) only, so B
                        // is a group dim and C splits into Ng = out_caps
                        // groups of Nopc = in_caps kernel-sharing slots —
                        // the kernel operand binds v's extents exactly.
                        // Reads û j-major like agree_sum (same known
                        // layout approximation, same work as before).
                        let agree = GconvOp {
                            name: format!("{name}.R{it}.logit_upd"),
                            dims: vec![
                                (Dim::B, DimParams::g(nbs)),
                                (Dim::C, DimParams { ng: *out_caps, nopc: in_caps, ..Default::default() }),
                                (Dim::V, DimParams::ks(*out_vec)),
                            ],
                            pre: PreOp::None,
                            main: MainOp::Mul,
                            reduce: ReduceOp::Add,
                            post: PostOp::None,
                            input: pred.clone(),
                            kernel: Some(v.clone()),
                        };
                        self.emit_fp_tmp(id, agree);
                    }
                }
                let _ = pred_shape;
                v
            }
        };
        self.act[id] = Some(act);
    }

    /// Softmax over channels: max, subtract+exp, sum+recip, normalize.
    fn lower_softmax_fp(&mut self, id: NodeId, name: &str, out: &Shape, x: DataRef) -> DataRef {
        let mx = reduce_op(
            &format!("{name}.FP1"),
            out,
            &[Dim::C],
            PreOp::None,
            ReduceOp::Max,
            PostOp::None,
            x.clone(),
        );
        let mx = self.emit_fp_tmp(id, mx);
        let shifted = ew_op(
            &format!("{name}.FP2"),
            out,
            &non_c_dims(out),
            PreOp::None,
            MainOp::Sub,
            PostOp::Lut("exp"),
            x,
            Some(mx),
        );
        let shifted = self.emit_fp_tmp(id, shifted);
        let denom = reduce_op(
            &format!("{name}.FP3"),
            out,
            &[Dim::C],
            PreOp::None,
            ReduceOp::Add,
            PostOp::Lut("recip"),
            shifted.clone(),
        );
        let denom = self.emit_fp_tmp(id, denom);
        let norm = ew_op(
            &format!("{name}.FP4"),
            out,
            &non_c_dims(out),
            PreOp::None,
            MainOp::Mul,
            PostOp::None,
            shifted,
            Some(denom),
        );
        self.emit_fp(id, norm)
    }

    /// Batch normalization forward, exactly Table 2 FP1–FP4.
    fn lower_bn_fp(&mut self, id: NodeId, name: &str, s: &Shape, x: DataRef) -> DataRef {
        let nbs = s.extent(Dim::B) as f32;
        // FP1: μ = Σ_b I / Nbs.
        let fp1 = reduce_op(
            &format!("{name}.FP1"),
            s,
            &[Dim::B],
            PreOp::None,
            ReduceOp::Add,
            PostOp::Mul(1.0 / nbs),
            x.clone(),
        );
        let fp1 = self.emit_fp_tmp(id, fp1);
        // FP2: t1 = I − μ (kernel μ varies in C/H/W, reused over B).
        let fp2 = ew_op(
            &format!("{name}.FP2"),
            s,
            &non_b_dims(s),
            PreOp::None,
            MainOp::Sub,
            PostOp::None,
            x,
            Some(fp1),
        );
        let fp2 = self.emit_fp_tmp(id, fp2);
        // FP3: t2 = 1/sqrt(Σ t1²/Nbs + ε) — square pre, add reduce, LUT.
        let fp3 = reduce_op(
            &format!("{name}.FP3"),
            s,
            &[Dim::B],
            PreOp::Square,
            ReduceOp::Add,
            PostOp::Lut("rsqrt_eps"),
            fp2.clone(),
        );
        let fp3 = self.emit_fp_tmp(id, fp3);
        // FP4: O = t1 × t2.
        let fp4 = ew_op(
            &format!("{name}.FP4"),
            s,
            &non_b_dims(s),
            PreOp::None,
            MainOp::Mul,
            PostOp::None,
            fp2,
            Some(fp3),
        );
        self.emit_fp(id, fp4)
    }

    /// Capsule squash: ‖s‖² LUT scale + multiply. `start` numbers the
    /// emitted FP ops for display.
    fn lower_squash(
        &mut self,
        id: NodeId,
        name: &str,
        out: &Shape,
        s: DataRef,
        start: usize,
    ) -> DataRef {
        let norm = reduce_op(
            &format!("{name}.FP{}", start + 1),
            out,
            &[Dim::V],
            PreOp::Square,
            ReduceOp::Add,
            PostOp::Lut("squash_scale"),
            s.clone(),
        );
        let norm = self.emit_fp_tmp(id, norm);
        let scaled = ew_op(
            &format!("{name}.FP{}", start + 2),
            out,
            &non_v_dims(out),
            PreOp::None,
            MainOp::Mul,
            PostOp::None,
            s,
            Some(norm),
        );
        self.emit_fp(id, scaled)
    }
}

/// Dims of `s` except C (where a reduction-derived kernel is constant).
fn non_c_dims(s: &Shape) -> Vec<Dim> {
    s.dims().into_iter().filter(|&d| d != Dim::C).collect()
}

/// Dims of `s` except B.
fn non_b_dims(s: &Shape) -> Vec<Dim> {
    s.dims().into_iter().filter(|&d| d != Dim::B).collect()
}

/// Dims of `s` except V.
fn non_v_dims(s: &Shape) -> Vec<Dim> {
    s.dims().into_iter().filter(|&d| d != Dim::V).collect()
}

/// Build the GCONV of a (grouped/3-D) convolution layer per Fig. 5.
/// `kernel` is `(kt, kh, kw)`; `kt = 1` for 2-D convolutions.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_gconv(
    name: &str,
    input: &Shape,
    output: &Shape,
    out_channels: usize,
    kernel: (usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
    x: DataRef,
    w: DataRef,
) -> GconvOp {
    let ic = input.extent(Dim::C);
    let mut dims = vec![
        (Dim::B, DimParams::opc(input.extent(Dim::B))),
        (
            Dim::C,
            DimParams {
                ng: groups,
                nop: out_channels / groups,
                nks: ic / groups,
                ..Default::default()
            },
        ),
    ];
    if input.extent(Dim::T) > 1 || kernel.0 > 1 {
        dims.push((Dim::T, DimParams::window(output.extent(Dim::T), kernel.0, stride, pad)));
    }
    dims.push((Dim::H, DimParams::window(output.extent(Dim::H), kernel.1, stride, pad)));
    dims.push((Dim::W, DimParams::window(output.extent(Dim::W), kernel.2, stride, pad)));
    GconvOp::conv(name, dims, x, w)
}

/// Loop dims of a pooling layer, shared by the forward lowering and the
/// max-pool BP routing metadata. Ceil-mode output extents (Caffe rounds
/// up, [`crate::ir::layer::pool_out`]) make the last window overhang the
/// input; the overhang becomes end padding (`pe`) so the covered extent
/// matches the real input and the op binds natively.
pub(crate) fn pool_dims(
    input: &Shape,
    output: &Shape,
    kernel: (usize, usize, usize),
    stride: (usize, usize, usize),
    pad: usize,
) -> Vec<(Dim, DimParams)> {
    let mut dims = vec![
        (Dim::B, DimParams::opc(input.extent(Dim::B))),
        (Dim::C, DimParams::opc(input.extent(Dim::C))),
    ];
    let window = |d: Dim, k: usize, s: usize, ps: usize| {
        DimParams::window_ceil(output.extent(d), k, s, ps, input.extent(d))
    };
    if input.extent(Dim::T) > 1 {
        dims.push((Dim::T, window(Dim::T, kernel.0, stride.0, 0)));
    }
    dims.push((Dim::H, window(Dim::H, kernel.1, stride.1, pad)));
    dims.push((Dim::W, window(Dim::W, kernel.2, stride.2, pad)));
    dims
}

/// Build the GCONV of a pooling layer.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pool_gconv(
    name: &str,
    input: &Shape,
    output: &Shape,
    kind: PoolKind,
    kernel: (usize, usize, usize),
    stride: (usize, usize, usize),
    pad: usize,
    x: DataRef,
) -> GconvOp {
    let dims = pool_dims(input, output, kernel, stride, pad);
    let (reduce, post) = match kind {
        PoolKind::Max => (ReduceOp::Max, PostOp::None),
        PoolKind::Avg => {
            let k = (kernel.0 * kernel.1 * kernel.2) as f32;
            (ReduceOp::Add, PostOp::Mul(1.0 / k))
        }
    };
    GconvOp {
        name: name.to_string(),
        dims,
        pre: PreOp::None,
        main: MainOp::Pass,
        reduce,
        post,
        input: x,
        kernel: None,
    }
}
