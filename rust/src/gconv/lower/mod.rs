//! Layer → GCONV lowering (paper §3.2, Table 2).
//!
//! Every layer decomposes into a short sequence of GCONVs by matching the
//! *variance pattern* of each tensor against the four loop parameters.
//! For each data dimension `d`:
//!
//! | input varies | kernel varies | output | parameter |
//! |---|---|---|---|
//! | yes | yes | varies     | `Ng`  (independent groups)            |
//! | yes | yes | reduced    | `Nks` (kernel covers the input)       |
//! | yes | no  | varies     | `Nopc` (one-weight kernel sliding)    |
//! | no  | yes | varies     | `Nop` (kernels applied in parallel)   |
//! | window |  |            | `Nopc`+`Nks` with stride/padding      |
//!
//! This reproduces the paper's examples exactly: Fig. 5 (convolution),
//! Table 2 (batch normalization FP1–FP4 / BP1–BP6), §3.1's LRN-as-
//! channel-convolution observation, etc.

mod bp;
mod fp;

use super::chain::{ChainEntry, GconvChain, Phase, SpecialOp};
use super::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp, ReduceOp};
use crate::ir::{Dim, Network, NodeId, Shape};

/// What to lower.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Forward pass only.
    Inference,
    /// Forward + backward + weight gradients (the paper evaluates
    /// training, §6.1).
    Training,
}

/// Lower a network into its GCONV chain.
pub fn lower_network(net: &Network, mode: Mode) -> GconvChain {
    let mut lw = Lowerer::new(net);
    for node in net.nodes() {
        lw.lower_fp(node.id);
    }
    if mode == Mode::Training {
        lw.seed_output_gradients();
        for node in net.nodes().iter().rev() {
            lw.lower_bp(node.id);
        }
    }
    lw.chain
}

/// Lowering context: tracks, per IR node, which chain entry (or external
/// tensor) holds its activation and its gradient.
pub(crate) struct Lowerer<'n> {
    pub net: &'n Network,
    pub chain: GconvChain,
    /// Activation of each node.
    pub act: Vec<Option<DataRef>>,
    /// Gradient w.r.t. each node's output (populated during BP).
    pub grad: Vec<Option<DataRef>>,
}

impl<'n> Lowerer<'n> {
    fn new(net: &'n Network) -> Self {
        Lowerer {
            net,
            chain: GconvChain::new(&net.name),
            act: vec![None; net.len()],
            grad: vec![None; net.len()],
        }
    }

    /// Activation ref of node `id` (panics if not yet lowered).
    pub fn act_of(&self, id: NodeId) -> DataRef {
        self.act[id].clone().unwrap_or_else(|| panic!("node {id} has no activation"))
    }

    /// Push an op for `node`, record it as the node's activation.
    pub fn emit_fp(&mut self, node: NodeId, op: GconvOp) -> DataRef {
        let traditional = self.net.node(node).layer.is_traditional();
        let idx = self.chain.push(ChainEntry::new(op, node, traditional, Phase::Fp));
        DataRef::Gconv(idx)
    }

    /// Push an intermediate FP op (not the node's final activation).
    pub fn emit_fp_tmp(&mut self, node: NodeId, op: GconvOp) -> DataRef {
        self.emit_fp(node, op)
    }

    /// Push an FP op carrying a special-execution routine.
    pub fn emit_fp_special(&mut self, node: NodeId, op: GconvOp, sp: SpecialOp) -> DataRef {
        let traditional = self.net.node(node).layer.is_traditional();
        let entry = ChainEntry::new(op, node, traditional, Phase::Fp).with_special(sp);
        DataRef::Gconv(self.chain.push(entry))
    }

    /// Push a BP op.
    pub fn emit_bp(&mut self, node: NodeId, op: GconvOp) -> DataRef {
        let traditional = self.net.node(node).layer.is_traditional();
        let idx = self.chain.push(ChainEntry::new(op, node, traditional, Phase::Bp));
        DataRef::Gconv(idx)
    }

    /// Push a BP op carrying a special-execution routine.
    pub fn emit_bp_special(&mut self, node: NodeId, op: GconvOp, sp: SpecialOp) -> DataRef {
        let traditional = self.net.node(node).layer.is_traditional();
        let entry = ChainEntry::new(op, node, traditional, Phase::Bp).with_special(sp);
        DataRef::Gconv(self.chain.push(entry))
    }

    /// Push a weight-gradient op.
    pub fn emit_wg(&mut self, node: NodeId, op: GconvOp) -> DataRef {
        let traditional = self.net.node(node).layer.is_traditional();
        let idx = self.chain.push(ChainEntry::new(op, node, traditional, Phase::Wg));
        DataRef::Gconv(idx)
    }

    /// Seed `grad` at the network outputs with the loss gradient.
    fn seed_output_gradients(&mut self) {
        for out in self.net.outputs() {
            self.grad[out] = Some(DataRef::External(format!("loss_grad.{out}")));
        }
    }

    /// Gradient flowing into node `id`'s output; if several consumers
    /// contributed, they have already been summed by `accumulate_grad`.
    pub fn grad_of(&self, id: NodeId) -> Option<DataRef> {
        self.grad[id].clone()
    }

    /// Record `g` as (part of) the gradient of node `id`, emitting an
    /// element-wise accumulation GCONV when a gradient is already present
    /// (fan-out nodes receive one contribution per consumer).
    pub fn accumulate_grad(&mut self, id: NodeId, g: DataRef) {
        let merged = match self.grad[id].take() {
            None => g,
            Some(prev) => {
                let shape = self.net.node(id).output.clone();
                let name = format!("{}.grad_acc", self.net.node(id).name);
                let op = GconvOp {
                    name,
                    dims: ew_dims(&shape, &shape.dims()),
                    pre: PreOp::None,
                    main: MainOp::Add,
                    reduce: ReduceOp::None,
                    post: PostOp::None,
                    input: prev,
                    kernel: Some(g),
                };
                self.emit_bp(id, op)
            }
        };
        self.grad[id] = Some(merged);
    }
}

/// Dim params for an element-wise GCONV over `shape`: dimensions in
/// `kernel_varies` become `Ng` (a distinct kernel parameter per
/// position), the rest become `Nopc` (one-weight kernel sliding — the
/// paper's B-dimension idiom, Fig. 5).
pub(crate) fn ew_dims(shape: &Shape, kernel_varies: &[Dim]) -> Vec<(Dim, DimParams)> {
    shape
        .iter()
        .filter(|&(_, n)| n > 1)
        .map(|(d, n)| {
            if kernel_varies.contains(&d) {
                (d, DimParams::g(n))
            } else {
                (d, DimParams::opc(n))
            }
        })
        .collect()
}

/// An element-wise GCONV (no reduction).
#[allow(clippy::too_many_arguments)]
pub(crate) fn ew_op(
    name: &str,
    shape: &Shape,
    kernel_varies: &[Dim],
    pre: PreOp,
    main: MainOp,
    post: PostOp,
    input: DataRef,
    kernel: Option<DataRef>,
) -> GconvOp {
    GconvOp {
        name: name.to_string(),
        dims: ew_dims(shape, kernel_varies),
        pre,
        main,
        reduce: ReduceOp::None,
        post,
        input,
        kernel,
    }
}

/// A kernel-less reduction over dimension `rd` of `shape` (mean/var/sum/
/// max patterns: BN FP1/FP3, softmax denominators, global pooling).
pub(crate) fn reduce_op(
    name: &str,
    shape: &Shape,
    rd: &[Dim],
    pre: PreOp,
    reduce: ReduceOp,
    post: PostOp,
    input: DataRef,
) -> GconvOp {
    let dims = shape
        .iter()
        .filter(|&(_, n)| n > 1)
        .map(|(d, n)| if rd.contains(&d) { (d, DimParams::ks(n)) } else { (d, DimParams::opc(n)) })
        .collect();
    GconvOp {
        name: name.to_string(),
        dims,
        pre,
        main: MainOp::Pass,
        reduce,
        post,
        input,
        kernel: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Layer, PoolKind};

    fn bn_net() -> Network {
        let mut net = Network::new("bn");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(32, 16, 8, 8) }, &[]);
        net.add("bn1", Layer::BatchNorm, &[i]);
        net
    }

    #[test]
    fn bn_fp_produces_four_gconvs() {
        // Table 2: FP decomposes into FP1..FP4 (input layer adds 0).
        let chain = lower_network(&bn_net(), Mode::Inference);
        assert_eq!(chain.len(), 4);
        let names: Vec<&str> =
            chain.entries().iter().map(|e| e.op.name.rsplit('.').next().unwrap()).collect();
        assert_eq!(names, vec!["FP1", "FP2", "FP3", "FP4"]);
    }

    #[test]
    fn bn_training_adds_six_bp_gconvs() {
        // Table 2: BP decomposes into BP1..BP6.
        let chain = lower_network(&bn_net(), Mode::Training);
        assert_eq!(chain.len(), 10);
        assert_eq!(chain.entries().iter().filter(|e| e.phase == Phase::Bp).count(), 6);
    }

    #[test]
    fn bn_fp1_matches_table2() {
        // FP1: B:[Nks: Nbs], C/H/W:[Nopc], reduce add, post x 1/Nbs.
        let chain = lower_network(&bn_net(), Mode::Inference);
        let fp1 = &chain.entries()[0].op;
        assert_eq!(fp1.params(Dim::B), DimParams::ks(32));
        assert_eq!(fp1.params(Dim::C), DimParams::opc(16));
        assert_eq!(fp1.reduce, ReduceOp::Add);
        assert!(matches!(fp1.post, PostOp::Mul(_)));
        assert!(fp1.kernel.is_none());
    }

    #[test]
    fn bn_fp2_matches_table2() {
        // FP2: B:[Nopc: Nbs], C/H/W:[Ng], main sub, kernel = FP1 output.
        let chain = lower_network(&bn_net(), Mode::Inference);
        let fp2 = &chain.entries()[1].op;
        assert_eq!(fp2.params(Dim::B), DimParams::opc(32));
        assert_eq!(fp2.params(Dim::C), DimParams::g(16));
        assert_eq!(fp2.main, MainOp::Sub);
        assert_eq!(fp2.kernel, Some(DataRef::Gconv(0)));
    }

    #[test]
    fn fanout_gradients_are_accumulated() {
        // A node consumed twice must get an accumulation GCONV in BP.
        let mut net = Network::new("fanout");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(4, 8, 4, 4) }, &[]);
        let r = net.add("relu", Layer::Relu, &[i]);
        let a = net.add("b1", Layer::Relu, &[r]);
        let b = net.add("b2", Layer::Relu, &[r]);
        net.add("join", Layer::Eltwise, &[a, b]);
        let chain = lower_network(&net, Mode::Training);
        assert!(chain.entries().iter().any(|e| e.op.name.contains("grad_acc")));
    }

    #[test]
    fn conv_layer_matches_figure5() {
        let mut net = Network::new("conv");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(32, 3, 32, 32) }, &[]);
        net.add(
            "conv1",
            Layer::Conv { out_channels: 64, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
            &[i],
        );
        let chain = lower_network(&net, Mode::Inference);
        assert_eq!(chain.len(), 1);
        let g = &chain.entries()[0].op;
        // Fig. 5: B:[Nopc:Nbs]; C:[Ng:Ngp, Nop:Noc, Nks:Nic]; H/W windows.
        assert_eq!(g.params(Dim::B), DimParams::opc(32));
        assert_eq!(g.params(Dim::C), DimParams { nop: 64, nks: 3, ..Default::default() });
        assert_eq!(g.params(Dim::H), DimParams::window(32, 3, 1, 1));
        assert_eq!(g.main, MainOp::Mul);
        assert_eq!(g.reduce, ReduceOp::Add);
    }

    #[test]
    fn pooling_uses_max_reduce_without_kernel() {
        let mut net = Network::new("pool");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(4, 8, 8, 8) }, &[]);
        net.add("p", Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 }, &[i]);
        let chain = lower_network(&net, Mode::Inference);
        let g = &chain.entries()[0].op;
        assert_eq!(g.reduce, ReduceOp::Max);
        assert!(g.kernel.is_none());
        assert_eq!(g.params(Dim::H), DimParams::window(4, 2, 2, 0));
    }

    #[test]
    fn training_work_exceeds_inference_work() {
        let mut net = Network::new("t");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(8, 3, 16, 16) }, &[]);
        let c = net.add(
            "conv",
            Layer::Conv { out_channels: 8, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
            &[i],
        );
        net.add("relu", Layer::Relu, &[c]);
        let inf = lower_network(&net, Mode::Inference).total_work();
        let trn = lower_network(&net, Mode::Training).total_work();
        assert!(trn >= 2 * inf, "training {trn} should be >= 2x inference {inf}");
    }
}
