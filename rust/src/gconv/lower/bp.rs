//! Backward-propagation lowering of each layer kind.
//!
//! BP GCONVs follow the same variance-pattern rules as FP (see the module
//! docs of [`super`]): the batch-norm chain is Table 2 BP1–BP6 verbatim;
//! convolution yields the classic pair (input gradient = correlation with
//! the kernels flipped, weight gradient = correlation of activations with
//! output gradients, reduced over the batch).

use super::fp::pool_dims;
use super::{ew_dims, ew_op, reduce_op, Lowerer};
use crate::gconv::chain::SpecialOp;
use crate::gconv::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp, ReduceOp};
use crate::ir::{Dim, Layer, NodeId, PoolKind, Shape};

impl Lowerer<'_> {
    /// Lower the backward pass of node `id` (assumes consumers have
    /// already deposited this node's output gradient via
    /// [`Lowerer::accumulate_grad`] or the loss seed).
    pub fn lower_bp(&mut self, id: NodeId) {
        let node = self.net.node(id).clone();
        let Some(g_out) = self.grad_of(id) else {
            return; // dead branch (e.g. auxiliary head not trained)
        };
        let name = node.name.clone();
        let out = node.output.clone();
        let in_shapes: Vec<Shape> =
            node.inputs.iter().map(|&i| self.net.node(i).output.clone()).collect();

        match &node.layer {
            Layer::Input { .. } => {}
            Layer::Conv { out_channels, kernel, stride, pad, groups } => {
                self.conv_bp(
                    id,
                    &name,
                    &in_shapes[0],
                    &out,
                    *out_channels,
                    (1, kernel.0, kernel.1),
                    *stride,
                    *pad,
                    *groups,
                    g_out,
                    node.inputs[0],
                );
            }
            Layer::Conv3d { out_channels, kernel, stride, pad } => {
                self.conv_bp(
                    id,
                    &name,
                    &in_shapes[0],
                    &out,
                    *out_channels,
                    *kernel,
                    *stride,
                    *pad,
                    1,
                    g_out,
                    node.inputs[0],
                );
            }
            Layer::FullyConnected { out_features } => {
                let s = &in_shapes[0];
                let nbs = s.extent(Dim::B);
                let feat: usize = s.elements() / nbs;
                // dI = W^T · dO : roles of op/ks swap vs. FP.
                let di = GconvOp::conv(
                    &format!("{name}.BPi"),
                    vec![
                        (Dim::B, DimParams::opc(nbs)),
                        (Dim::C, DimParams { nop: feat, nks: *out_features, ..Default::default() }),
                    ],
                    g_out.clone(),
                    DataRef::Weights(name.clone()),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
                // dW = Σ_b I ⊗ dO : outer product reduced over batch.
                let dw = GconvOp {
                    name: format!("{name}.WG"),
                    dims: vec![
                        (Dim::B, DimParams::ks(nbs)),
                        (Dim::C, DimParams::op(*out_features)),
                        (Dim::H, DimParams::opc(feat)),
                    ],
                    pre: PreOp::None,
                    main: MainOp::Mul,
                    reduce: ReduceOp::Add,
                    post: PostOp::None,
                    input: self.act_of(node.inputs[0]),
                    kernel: Some(g_out),
                };
                self.emit_wg(id, dw);
            }
            Layer::Pool { kind, kernel, stride, pad } => {
                self.pool_bp(
                    id,
                    &name,
                    &in_shapes[0],
                    &out,
                    *kind,
                    (1, *kernel, *kernel),
                    (1, *stride, *stride),
                    *pad,
                    g_out,
                    node.inputs[0],
                );
            }
            Layer::Pool3d { kind, kernel, stride } => {
                self.pool_bp(
                    id,
                    &name,
                    &in_shapes[0],
                    &out,
                    *kind,
                    *kernel,
                    *stride,
                    0,
                    g_out,
                    node.inputs[0],
                );
            }
            Layer::GlobalAvgPool => {
                let s = &in_shapes[0];
                let hw = (s.extent(Dim::H) * s.extent(Dim::W)) as f32;
                // Broadcast dO/HW back over the spatial dims.
                let mut dims = ew_dims(s, &[]);
                for (d, p) in dims.iter_mut() {
                    if *d == Dim::H || *d == Dim::W {
                        *p = DimParams::opc(s.extent(*d));
                    }
                }
                let di = GconvOp {
                    name: format!("{name}.BP"),
                    dims,
                    pre: PreOp::Mul(1.0 / hw),
                    main: MainOp::Pass,
                    reduce: ReduceOp::None,
                    post: PostOp::None,
                    input: g_out,
                    kernel: None,
                };
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::Relu => {
                // dI = dO ⊙ 1[x > 0]; the mask is the stored activation
                // pattern (varies everywhere).
                let di = ew_op(
                    &format!("{name}.BP"),
                    &out,
                    &out.dims(),
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    g_out,
                    Some(DataRef::External(format!("{name}.mask"))),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::Sigmoid => {
                let di = ew_op(
                    &format!("{name}.BP"),
                    &out,
                    &out.dims(),
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    g_out,
                    Some(DataRef::External(format!("{name}.dsigmoid"))),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::Softmax => {
                // Fused with cross-entropy: dI = O − target.
                let di = ew_op(
                    &format!("{name}.BP"),
                    &out,
                    &out.dims(),
                    PreOp::None,
                    MainOp::Sub,
                    PostOp::None,
                    self.act_of(id),
                    Some(DataRef::External("target".into())),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::Lrn { local_size } => {
                let s = &in_shapes[0];
                // Direct term: dO × scale^{-β} (element-wise) plus the
                // cross-channel term: a channel-window correlation of
                // dO·O/scale with the inputs.
                let g1 = ew_op(
                    &format!("{name}.BP1"),
                    s,
                    &s.dims(),
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    g_out.clone(),
                    Some(DataRef::External(format!("{name}.scale"))),
                );
                let g1 = self.emit_bp(id, g1);
                let mut dims = ew_dims(s, &[]);
                for (d, p) in dims.iter_mut() {
                    if *d == Dim::C {
                        *p = DimParams::window(s.extent(Dim::C), *local_size, 1, (local_size - 1) / 2);
                    }
                }
                let g2 = GconvOp {
                    name: format!("{name}.BP2"),
                    dims,
                    pre: PreOp::None,
                    main: MainOp::Mul,
                    reduce: ReduceOp::Add,
                    post: PostOp::None,
                    input: g_out,
                    kernel: Some(DataRef::External(format!("{name}.cross"))),
                };
                let g2 = self.emit_bp(id, g2);
                let di = ew_op(
                    &format!("{name}.BP3"),
                    s,
                    &s.dims(),
                    PreOp::None,
                    MainOp::Sub,
                    PostOp::None,
                    g1,
                    Some(g2),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::BatchNorm => {
                let di = self.lower_bn_bp(id, &name, &in_shapes[0], g_out);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::Scale => {
                // dI = dO·γ; dγ = Σ dO·I; dβ = Σ dO.
                let s = &in_shapes[0];
                let di = ew_op(
                    &format!("{name}.BP"),
                    s,
                    &[Dim::C],
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    g_out.clone(),
                    Some(DataRef::Weights(format!("{name}.gamma"))),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
                let reduce_dims: Vec<Dim> =
                    s.dims().into_iter().filter(|&d| d != Dim::C).collect();
                let dgamma = GconvOp {
                    name: format!("{name}.WG1"),
                    dims: s
                        .iter()
                        .filter(|&(_, n)| n > 1)
                        .map(|(d, n)| {
                            if reduce_dims.contains(&d) {
                                (d, DimParams::ks(n))
                            } else {
                                (d, DimParams::opc(n))
                            }
                        })
                        .collect(),
                    pre: PreOp::None,
                    main: MainOp::Mul,
                    reduce: ReduceOp::Add,
                    post: PostOp::None,
                    input: g_out.clone(),
                    kernel: Some(self.act_of(node.inputs[0])),
                };
                self.emit_wg(id, dgamma);
                let dbeta = reduce_op(
                    &format!("{name}.WG2"),
                    s,
                    &reduce_dims,
                    PreOp::None,
                    ReduceOp::Add,
                    PostOp::None,
                    g_out,
                );
                self.emit_wg(id, dbeta);
            }
            Layer::Dropout => {
                let di = ew_op(
                    &format!("{name}.BP"),
                    &out,
                    &out.dims(),
                    PreOp::None,
                    MainOp::Mul,
                    PostOp::None,
                    g_out,
                    Some(DataRef::Weights(format!("{name}.mask"))),
                );
                let di = self.emit_bp(id, di);
                self.accumulate_grad(node.inputs[0], di);
            }
            Layer::Concat => {
                // Slice the gradient back to each branch (pure movement).
                for (bi, (&src, s)) in node.inputs.iter().zip(&in_shapes).enumerate() {
                    let op = ew_op(
                        &format!("{name}.BP{}", bi + 1),
                        s,
                        &[],
                        PreOp::None,
                        MainOp::Pass,
                        PostOp::None,
                        g_out.clone(),
                        None,
                    );
                    let g = self.emit_bp(id, op);
                    self.accumulate_grad(src, g);
                }
            }
            Layer::Eltwise => {
                // Gradient passes through unchanged to every operand.
                for &src in &node.inputs {
                    self.accumulate_grad(src, g_out.clone());
                }
            }
            Layer::RoiPool { .. } | Layer::Proposal { .. } => {
                // Max-pool style routing back through the argmax mask;
                // proposals themselves are not differentiated (Faster
                // R-CNN treats them as data).
                if let Layer::RoiPool { .. } = node.layer {
                    let s = &in_shapes[0];
                    let di = ew_op(
                        &format!("{name}.BP"),
                        s,
                        &s.dims(),
                        PreOp::None,
                        MainOp::Mul,
                        PostOp::None,
                        g_out,
                        Some(DataRef::External(format!("{name}.argmax"))),
                    );
                    let di = self.emit_bp(id, di);
                    self.accumulate_grad(node.inputs[0], di);
                }
            }
            Layer::PrimaryCaps { caps_channels, vec, kernel, stride } => {
                // Squash backward (2 element-wise GCONVs) then the
                // convolution pair.
                let g = self.squash_bp(id, &name, &out, g_out);
                self.conv_bp(
                    id,
                    &name,
                    &in_shapes[0],
                    &out,
                    caps_channels * vec,
                    (1, *kernel, *kernel),
                    *stride,
                    0,
                    1,
                    g,
                    node.inputs[0],
                );
            }
            Layer::DigitCaps { out_caps, out_vec, routing } => {
                let s = &in_shapes[0];
                let in_caps =
                    s.extent(Dim::C) * s.extent(Dim::H) * s.extent(Dim::W) * s.extent(Dim::T);
                let in_vec = s.extent(Dim::V);
                let nbs = s.extent(Dim::B);
                // Routing backward: mirror of the forward iterations
                // (squash-bp + weighted scatter per iteration).
                let mut g = g_out;
                for it in 0..*routing {
                    g = self.squash_bp(id, &format!("{name}.R{it}"), &out, g);
                    let scatter = GconvOp {
                        name: format!("{name}.R{it}.BPs"),
                        dims: vec![
                            (Dim::B, DimParams::opc(nbs)),
                            (Dim::C, DimParams { ng: in_caps, nop: *out_caps, ..Default::default() }),
                            (Dim::V, DimParams::opc(*out_vec)),
                        ],
                        pre: PreOp::None,
                        main: MainOp::Mul,
                        reduce: ReduceOp::None,
                        post: PostOp::None,
                        input: g.clone(),
                        kernel: Some(DataRef::External(format!("{name}.c{it}"))),
                    };
                    g = self.emit_bp(id, scatter);
                }
                // dU = W^T dÛ (swap op/ks on V), dW = u ⊗ dÛ.
                let du = GconvOp::conv(
                    &format!("{name}.BPi"),
                    vec![
                        (Dim::B, DimParams::opc(nbs)),
                        (Dim::C, DimParams { ng: in_caps, nks: *out_caps, ..Default::default() }),
                        (Dim::V, DimParams { nop: in_vec, nks: *out_vec, ..Default::default() }),
                    ],
                    g.clone(),
                    DataRef::Weights(name.clone()),
                );
                let du = self.emit_bp(id, du);
                self.accumulate_grad(node.inputs[0], du);
                let dw = GconvOp {
                    name: format!("{name}.WG"),
                    dims: vec![
                        (Dim::B, DimParams::ks(nbs)),
                        (Dim::C, DimParams { ng: in_caps, nop: *out_caps, ..Default::default() }),
                        (Dim::V, DimParams { nop: *out_vec, nopc: in_vec, ..Default::default() }),
                    ],
                    pre: PreOp::None,
                    main: MainOp::Mul,
                    reduce: ReduceOp::Add,
                    post: PostOp::None,
                    input: self.act_of(node.inputs[0]),
                    kernel: Some(g),
                };
                self.emit_wg(id, dw);
            }
        }
    }

    /// Convolution backward: input-gradient + weight-gradient GCONVs.
    #[allow(clippy::too_many_arguments)]
    fn conv_bp(
        &mut self,
        id: NodeId,
        name: &str,
        input: &Shape,
        output: &Shape,
        out_channels: usize,
        kernel: (usize, usize, usize),
        stride: usize,
        pad: usize,
        groups: usize,
        g_out: DataRef,
        src: NodeId,
    ) {
        let ic = input.extent(Dim::C);
        let first_layer = matches!(self.net.node(src).layer, Layer::Input { .. });
        // dI: "full" correlation of dO with flipped kernels; op and ks
        // swap roles in C, the spatial windows invert (output size = Ni).
        if !first_layer {
            let mut dims = vec![
                (Dim::B, DimParams::opc(input.extent(Dim::B))),
                (
                    Dim::C,
                    DimParams {
                        ng: groups,
                        nop: ic / groups,
                        nks: out_channels / groups,
                        ..Default::default()
                    },
                ),
            ];
            if input.extent(Dim::T) > 1 || kernel.0 > 1 {
                dims.push((
                    Dim::T,
                    DimParams::window(input.extent(Dim::T), kernel.0, 1, kernel.0.saturating_sub(1)),
                ));
            }
            dims.push((
                Dim::H,
                DimParams::window(input.extent(Dim::H), kernel.1, 1, kernel.1.saturating_sub(1)),
            ));
            dims.push((
                Dim::W,
                DimParams::window(input.extent(Dim::W), kernel.2, 1, kernel.2.saturating_sub(1)),
            ));
            let di = GconvOp::conv(
                &format!("{name}.BPi"),
                dims,
                g_out.clone(),
                DataRef::Weights(name.to_string()),
            );
            let di = self.emit_bp(id, di);
            self.accumulate_grad(src, di);
        }
        // dW: correlate stored activations with dO, reduce over batch and
        // output positions; output extent = kernel size.
        let mut dims = vec![
            (Dim::B, DimParams::ks(input.extent(Dim::B))),
            (
                Dim::C,
                DimParams {
                    ng: groups,
                    nop: out_channels / groups,
                    nopc: ic / groups,
                    ..Default::default()
                },
            ),
        ];
        if input.extent(Dim::T) > 1 || kernel.0 > 1 {
            dims.push((
                Dim::T,
                DimParams { nopc: kernel.0, nks: output.extent(Dim::T), s: stride, ps: pad, ..Default::default() },
            ));
        }
        dims.push((
            Dim::H,
            DimParams { nopc: kernel.1, nks: output.extent(Dim::H), s: stride, ps: pad, ..Default::default() },
        ));
        dims.push((
            Dim::W,
            DimParams { nopc: kernel.2, nks: output.extent(Dim::W), s: stride, ps: pad, ..Default::default() },
        ));
        let dw = GconvOp {
            name: format!("{name}.WG"),
            dims,
            pre: PreOp::None,
            main: MainOp::Mul,
            reduce: ReduceOp::Add,
            post: PostOp::None,
            input: self.act_of(src),
            kernel: Some(g_out),
        };
        self.emit_wg(id, dw);
    }

    /// Pooling backward.
    #[allow(clippy::too_many_arguments)]
    fn pool_bp(
        &mut self,
        id: NodeId,
        name: &str,
        input: &Shape,
        output: &Shape,
        kind: PoolKind,
        kernel: (usize, usize, usize),
        stride: (usize, usize, usize),
        pad: usize,
        g_out: DataRef,
        src: NodeId,
    ) {
        let di = match kind {
            PoolKind::Max => {
                // Argmax routing is pure data movement whose gradient
                // operand genuinely under-covers the loop nest, so it
                // cannot run as a GCONV. Lower it as a *special* entry:
                // the native engine recomputes the argmax mask from the
                // saved forward input (the kernel operand) and routes
                // each window's gradient to the winning element. The op
                // keeps the analytical footprint of the old
                // mask-multiply form (same dims, main and element
                // counts), so the cycle/movement models are unchanged.
                let op = GconvOp {
                    name: format!("{name}.BP"),
                    dims: ew_dims(input, &input.dims()),
                    pre: PreOp::None,
                    main: MainOp::Mul,
                    reduce: ReduceOp::None,
                    post: PostOp::None,
                    input: g_out,
                    kernel: Some(self.act_of(src)),
                };
                let fwd = pool_dims(input, output, kernel, stride, pad);
                let in_extents = fwd.iter().map(|&(d, _)| input.extent(d)).collect();
                let di = self.emit_bp_special(id, op, SpecialOp::MaxPoolBp { fwd, in_extents });
                self.accumulate_grad(src, di);
                return;
            }
            PoolKind::Avg => {
                // Spread dO/k over each window: a correlation of dO with a
                // uniform kernel (kernel-less, pre-scaled).
                let k = (kernel.0 * kernel.1 * kernel.2) as f32;
                let mut dims = vec![
                    (Dim::B, DimParams::opc(input.extent(Dim::B))),
                    (Dim::C, DimParams::opc(input.extent(Dim::C))),
                ];
                if input.extent(Dim::T) > 1 {
                    dims.push((
                        Dim::T,
                        DimParams::window(input.extent(Dim::T), kernel.0, 1, kernel.0 / stride.0),
                    ));
                }
                dims.push((
                    Dim::H,
                    DimParams::window(input.extent(Dim::H), kernel.1, 1, kernel.1 / stride.1),
                ));
                dims.push((
                    Dim::W,
                    DimParams::window(input.extent(Dim::W), kernel.2, 1, kernel.2 / stride.2),
                ));
                GconvOp {
                    name: format!("{name}.BP"),
                    dims,
                    pre: PreOp::Mul(1.0 / k),
                    main: MainOp::Pass,
                    reduce: ReduceOp::Add,
                    post: PostOp::None,
                    input: g_out,
                    kernel: None,
                }
            }
        };
        let di = self.emit_bp(id, di);
        self.accumulate_grad(src, di);
    }

    /// Batch normalization backward, exactly Table 2 BP1–BP6. Returns dI.
    fn lower_bn_bp(&mut self, id: NodeId, name: &str, s: &Shape, g_out: DataRef) -> DataRef {
        let nbs = s.extent(Dim::B) as f32;
        let o = self.act_of(id); // FP4 output
        let fp2;
        let fp3;
        // Recover the intra-layer FP refs by name (FP lowering pushed
        // them in order: FP1, FP2, FP3, FP4 ending at act_of(id)).
        if let DataRef::Gconv(fp4) = o.clone() {
            fp2 = DataRef::Gconv(fp4 - 2);
            fp3 = DataRef::Gconv(fp4 - 1);
        } else {
            fp2 = DataRef::External(format!("{name}.t1"));
            fp3 = DataRef::External(format!("{name}.t2"));
        }
        let _ = fp2;
        let non_b: Vec<Dim> = s.dims().into_iter().filter(|&d| d != Dim::B).collect();
        // BP1: t3 = Σ_b O·gO / Nbs.
        let bp1 = GconvOp {
            name: format!("{name}.BP1"),
            dims: s
                .iter()
                .filter(|&(_, n)| n > 1)
                .map(|(d, n)| {
                    if d == Dim::B {
                        (d, DimParams::ks(n))
                    } else {
                        (d, DimParams::g(n))
                    }
                })
                .collect(),
            pre: PreOp::None,
            main: MainOp::Mul,
            reduce: ReduceOp::Add,
            post: PostOp::Mul(1.0 / nbs),
            input: g_out.clone(),
            kernel: Some(o.clone()),
        };
        let bp1 = self.emit_bp(id, bp1);
        // BP2: t4 = O × t3.
        let bp2 = ew_op(
            &format!("{name}.BP2"),
            s,
            &non_b,
            PreOp::None,
            MainOp::Mul,
            PostOp::None,
            o,
            Some(bp1),
        );
        let bp2 = self.emit_bp(id, bp2);
        // BP3: t5 = Σ_b gO / Nbs.
        let bp3 = reduce_op(
            &format!("{name}.BP3"),
            s,
            &[Dim::B],
            PreOp::None,
            ReduceOp::Add,
            PostOp::Mul(1.0 / nbs),
            g_out.clone(),
        );
        let bp3 = self.emit_bp(id, bp3);
        // BP4: t6 = gO − t5.
        let bp4 = ew_op(
            &format!("{name}.BP4"),
            s,
            &non_b,
            PreOp::None,
            MainOp::Sub,
            PostOp::None,
            g_out,
            Some(bp3),
        );
        let bp4 = self.emit_bp(id, bp4);
        // BP5: t7 = t6 − t4.
        let bp5 = ew_op(
            &format!("{name}.BP5"),
            s,
            &s.dims(),
            PreOp::None,
            MainOp::Sub,
            PostOp::None,
            bp4,
            Some(bp2),
        );
        let bp5 = self.emit_bp(id, bp5);
        // BP6: gI = t7 × t2.
        let bp6 = ew_op(
            &format!("{name}.BP6"),
            s,
            &non_b,
            PreOp::None,
            MainOp::Mul,
            PostOp::None,
            bp5,
            Some(fp3),
        );
        self.emit_bp(id, bp6)
    }

    /// Squash backward: two element-wise GCONVs (scale gradient + vector
    /// correction).
    fn squash_bp(&mut self, id: NodeId, name: &str, out: &Shape, g: DataRef) -> DataRef {
        let g1 = ew_op(
            &format!("{name}.BPsq1"),
            out,
            &out.dims(),
            PreOp::None,
            MainOp::Mul,
            PostOp::None,
            g,
            Some(DataRef::External(format!("{name}.squash_scale"))),
        );
        let g1 = self.emit_bp(id, g1);
        let g2 = ew_op(
            &format!("{name}.BPsq2"),
            out,
            &out.dims(),
            PreOp::None,
            MainOp::Sub,
            PostOp::None,
            g1,
            Some(DataRef::External(format!("{name}.squash_corr"))),
        );
        self.emit_bp(id, g2)
    }
}
