//! The GCONV operation (paper §3.1).
//!
//! A 1-D GCONV is characterized by four loop parameters — groups `Ng`,
//! parallel kernels `Nop`, outputs per kernel `Nopc`, kernel size `Nks` —
//! plus stride `s` and padding `ps`. A multi-dimension GCONV duplicates
//! the same four loops per data dimension (Fig. 4). Four operators
//! (`pre`/`main`/`reduce`/`post`) replace the fixed multiply-accumulate
//! of traditional convolution (§3.1 "Representability").

use crate::ir::Dim;
use std::fmt;

/// The four loop parameters of one dimension of a GCONV, plus stride and
/// padding. Defaults (paper §3.1): `ps: 0, s: 1, Ng: 1, Nop: 1, Nks: 1,
/// Nopc: 1` — a dimension left at defaults contributes no loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DimParams {
    /// Number of isolated groups (no inter-group reuse).
    pub ng: usize,
    /// Number of kernels applied in parallel (input parallel-reuse).
    pub nop: usize,
    /// Number of outputs per kernel (kernel parallel-reuse).
    pub nopc: usize,
    /// Kernel size (output parallel-reuse / reduction depth).
    pub nks: usize,
    /// Stride.
    pub s: usize,
    /// Padding (symmetric, both window ends).
    pub ps: usize,
    /// Extra *end* padding beyond the symmetric `ps`. Ceil-mode pooling
    /// (Caffe rounds output extents up) makes the last window overhang
    /// the input; modelling the overhang as end padding keeps the
    /// covered input extent equal to the real input so the op binds —
    /// the native interpreter already treats out-of-range positions as
    /// padding (zero under `Add`, skipped under `Max`).
    pub pe: usize,
}

impl Default for DimParams {
    fn default() -> Self {
        DimParams { ng: 1, nop: 1, nopc: 1, nks: 1, s: 1, ps: 0, pe: 0 }
    }
}

impl DimParams {
    /// `[Ng: n]`
    pub fn g(n: usize) -> Self {
        DimParams { ng: n, ..Default::default() }
    }
    /// `[Nop: n]`
    pub fn op(n: usize) -> Self {
        DimParams { nop: n, ..Default::default() }
    }
    /// `[Nopc: n]`
    pub fn opc(n: usize) -> Self {
        DimParams { nopc: n, ..Default::default() }
    }
    /// `[Nks: n]`
    pub fn ks(n: usize) -> Self {
        DimParams { nks: n, ..Default::default() }
    }
    /// Sliding-window dimension `[Nopc: o, Nks: k, s, ps]`.
    pub fn window(nopc: usize, nks: usize, s: usize, ps: usize) -> Self {
        DimParams { nopc, nks, s, ps, ..Default::default() }
    }
    /// Ceil-mode sliding window: like [`DimParams::window`] but clipping
    /// the covered extent to `input` via end padding (`pe`) when the
    /// last window overhangs (Caffe pooling rounds output extents up).
    pub fn window_ceil(nopc: usize, nks: usize, s: usize, ps: usize, input: usize) -> Self {
        let covered = (nopc - 1) * s + nks;
        let pe = covered.saturating_sub(2 * ps).saturating_sub(input);
        DimParams { nopc, nks, s, ps, pe, ..Default::default() }
    }
    /// Fully-connected / reduction dimension `[Nop: o, Nks: k]`.
    pub fn op_ks(nop: usize, nks: usize) -> Self {
        DimParams { nop, nks, ..Default::default() }
    }
    /// Grouped reduction dimension `[Ng: g, Nks: k]`.
    pub fn g_ks(ng: usize, nks: usize) -> Self {
        DimParams { ng, nks, ..Default::default() }
    }

    /// Input extent covered by this dimension, from Eq. (1) (with the
    /// standard convolution arithmetic `Nips = (Nopc−1)·s + Nks − 2·ps`;
    /// the paper's printing has a `+1` typo). Ceil-mode end padding
    /// (`pe`) shrinks the covered extent further.
    pub fn input_extent(&self) -> usize {
        let covered = (self.nopc - 1) * self.s + self.nks;
        // Degenerate windows (kernel larger than the padded input, which
        // backward-pass "full" correlations can produce at tensor edges)
        // clamp to a single input element.
        self.ng * covered.saturating_sub(2 * self.ps + self.pe).max(1)
    }

    /// Kernel parameters stored for this dimension.
    pub fn kernel_extent(&self) -> usize {
        self.ng * self.nop * self.nks
    }

    /// Outputs produced along this dimension.
    pub fn output_extent(&self) -> usize {
        self.ng * self.nop * self.nopc
    }

    /// Loop iterations (work) along this dimension.
    pub fn work(&self) -> usize {
        self.ng * self.nop * self.nopc * self.nks
    }

    /// Does this dimension overlap-reuse inputs? §3.1: consecutive output
    /// windows overlap when `Nks > s` — which requires an actual sliding
    /// window (`Nopc > 1`; a kernel covering the whole input in parallel,
    /// like the C dimension of Fig. 5, produces a single window).
    pub fn overlap_reuse(&self) -> bool {
        self.nopc > 1 && self.nks > self.s && self.nks > 1
    }

    /// Is every parameter at its default (contributes no loops)?
    pub fn is_default(&self) -> bool {
        *self == DimParams::default()
    }

    /// Loop count of parameter `p`.
    pub fn get(&self, p: Param) -> usize {
        match p {
            Param::G => self.ng,
            Param::Op => self.nop,
            Param::Opc => self.nopc,
            Param::Ks => self.nks,
        }
    }
}

/// The four GCONV loop parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Param {
    /// Kernel size loop.
    Ks,
    /// Outputs-per-kernel loop.
    Opc,
    /// Parallel-kernel loop.
    Op,
    /// Group loop.
    G,
}

impl Param {
    /// All parameters.
    pub const ALL: [Param; 4] = [Param::Ks, Param::Opc, Param::Op, Param::G];

    /// Short name as used in the paper's unrolling entries.
    pub fn name(self) -> &'static str {
        match self {
            Param::Ks => "ks",
            Param::Opc => "opc",
            Param::Op => "op",
            Param::G => "g",
        }
    }
}

impl fmt::Display for Param {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One scalar stage of a composed `pre`/`post` pipeline written by
/// executable operation fusion (§4.3): the element-wise maps of the
/// absorbed ops, applied in order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalarStage {
    /// `x²`.
    Square,
    /// `c·x`.
    Mul(f32),
    /// Look-up-table function by lowering name.
    Lut(&'static str),
}

/// Most scalar stages a composed pipeline can hold; the fusion pass
/// refuses to compose further rather than overflow.
pub const MAX_FUSED_STAGES: usize = 6;

/// A fixed-capacity, `Copy` pipeline of scalar stages (the slots past
/// `len` stay at a fixed filler so derived equality is well-defined).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageStack {
    len: u8,
    stages: [ScalarStage; MAX_FUSED_STAGES],
}

impl StageStack {
    /// Empty pipeline (identity).
    pub const fn empty() -> Self {
        StageStack { len: 0, stages: [ScalarStage::Square; MAX_FUSED_STAGES] }
    }

    /// Append a stage; returns false (leaving the stack unchanged) when
    /// the stack is full.
    pub fn push(&mut self, s: ScalarStage) -> bool {
        if (self.len as usize) < MAX_FUSED_STAGES {
            self.stages[self.len as usize] = s;
            self.len += 1;
            return true;
        }
        false
    }

    /// Append every stage of `other`; returns false (leaving the stack
    /// unchanged) when the combined pipeline would not fit.
    pub fn extend(&mut self, other: &StageStack) -> bool {
        if self.len as usize + other.len as usize > MAX_FUSED_STAGES {
            return false;
        }
        for &s in other.as_slice() {
            self.push(s);
        }
        true
    }

    /// The stages, in application order.
    pub fn as_slice(&self) -> &[ScalarStage] {
        &self.stages[..self.len as usize]
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// True when the pipeline is the identity.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for StageStack {
    fn default() -> Self {
        StageStack::empty()
    }
}

/// Pre-processing operator applied to each input as it is loaded into the
/// convolution engine (§3.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PreOp {
    /// No pre-processing.
    None,
    /// Square each input (BN FP3).
    Square,
    /// Multiply by a scalar constant.
    Mul(f32),
    /// Look-up-table function (exp, sigmoid, …) named for reports.
    Lut(&'static str),
    /// Composed pipeline written by executable operation fusion (§4.3).
    Stack(StageStack),
}

impl PreOp {
    /// This operator as a scalar-stage pipeline (empty for `None`).
    pub fn stages(self) -> StageStack {
        let mut s = StageStack::empty();
        match self {
            PreOp::None => {}
            PreOp::Square => {
                s.push(ScalarStage::Square);
            }
            PreOp::Mul(c) => {
                s.push(ScalarStage::Mul(c));
            }
            PreOp::Lut(n) => {
                s.push(ScalarStage::Lut(n));
            }
            PreOp::Stack(st) => return st,
        }
        s
    }

    /// Canonical operator for a pipeline: single stages collapse back to
    /// their dedicated variants, the empty pipeline to `None`.
    pub fn from_stages(s: StageStack) -> PreOp {
        match s.as_slice() {
            [] => PreOp::None,
            [ScalarStage::Square] => PreOp::Square,
            [ScalarStage::Mul(c)] => PreOp::Mul(*c),
            [ScalarStage::Lut(n)] => PreOp::Lut(n),
            _ => PreOp::Stack(s),
        }
    }
}

/// Main operator between inputs and kernel parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MainOp {
    /// Multiply (traditional convolution).
    Mul,
    /// Add.
    Add,
    /// Subtract (input − parameter).
    Sub,
    /// Square of the difference.
    SquareDiff,
    /// Logical/bitwise AND (binary networks, masks).
    And,
    /// Pass the input through unchanged (pooling, copies — no kernel).
    Pass,
    /// Compare against the parameter, keep max (maxout-style).
    Max,
}

/// Reduction operator over the partial results within a kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    /// No reduction (element-wise GCONV, `Nks = 1` everywhere).
    None,
    /// Sum (traditional convolution).
    Add,
    /// Maximum (max pooling).
    Max,
}

/// Post-processing operator applied to each output on write-back.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PostOp {
    /// No post-processing.
    None,
    /// Multiply by a scalar constant (e.g. `1/Nbs` for means).
    Mul(f32),
    /// Look-up-table function (rsqrt, exp, relu, sigmoid, …).
    Lut(&'static str),
    /// Composed pipeline written by executable operation fusion (§4.3).
    Stack(StageStack),
}

impl PostOp {
    /// This operator as a scalar-stage pipeline (empty for `None`).
    pub fn stages(self) -> StageStack {
        let mut s = StageStack::empty();
        match self {
            PostOp::None => {}
            PostOp::Mul(c) => {
                s.push(ScalarStage::Mul(c));
            }
            PostOp::Lut(n) => {
                s.push(ScalarStage::Lut(n));
            }
            PostOp::Stack(st) => return st,
        }
        s
    }

    /// Canonical operator for a pipeline: single stages collapse back to
    /// their dedicated variants, the empty pipeline to `None`.
    pub fn from_stages(s: StageStack) -> PostOp {
        match s.as_slice() {
            [] => PostOp::None,
            [ScalarStage::Mul(c)] => PostOp::Mul(*c),
            [ScalarStage::Lut(n)] => PostOp::Lut(n),
            _ => PostOp::Stack(s),
        }
    }
}

/// Where a GCONV operand comes from.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum DataRef {
    /// Output of a previous GCONV on the chain (by chain index).
    Gconv(usize),
    /// An external tensor: the network input, a layer's stored
    /// activations (`"L12.out"`), gradients from the next layer, …
    External(String),
    /// Trained parameters of a layer (weights, BN γ/β, masks).
    Weights(String),
}

impl fmt::Display for DataRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataRef::Gconv(i) => write!(f, "#{i}"),
            DataRef::External(s) => write!(f, "{s}"),
            DataRef::Weights(s) => write!(f, "W[{s}]"),
        }
    }
}

/// A multi-dimension GCONV operation.
#[derive(Clone, Debug)]
pub struct GconvOp {
    /// Label for reports (e.g. `"conv1.fp"`, `"bn3.FP2"`).
    pub name: String,
    /// Per-dimension loop parameters in a canonical order. Dimensions not
    /// listed are at defaults (pruned, §3.1 "Scalability").
    pub dims: Vec<(Dim, DimParams)>,
    /// Operators.
    pub pre: PreOp,
    /// Main operator.
    pub main: MainOp,
    /// Reduction operator.
    pub reduce: ReduceOp,
    /// Post operator.
    pub post: PostOp,
    /// Input operand.
    pub input: DataRef,
    /// Kernel-parameter operand (None for kernel-less ops like pooling).
    pub kernel: Option<DataRef>,
}

impl GconvOp {
    /// Construct with default operators (multiply/add convolution).
    pub fn conv(name: &str, dims: Vec<(Dim, DimParams)>, input: DataRef, kernel: DataRef) -> Self {
        GconvOp {
            name: name.to_string(),
            dims,
            pre: PreOp::None,
            main: MainOp::Mul,
            reduce: ReduceOp::Add,
            post: PostOp::None,
            input,
            kernel: Some(kernel),
        }
    }

    /// Parameters for dimension `d` (defaults if unlisted).
    pub fn params(&self, d: Dim) -> DimParams {
        self.dims.iter().find(|&&(x, _)| x == d).map_or_else(DimParams::default, |&(_, p)| p)
    }

    /// Dimensions with non-default parameters.
    pub fn active_dims(&self) -> Vec<Dim> {
        self.dims.iter().filter(|(_, p)| !p.is_default()).map(|&(d, _)| d).collect()
    }

    /// Total loop iterations = `Π_d Π_p loops[d][p]` — the number of
    /// `main` operations executed.
    pub fn work(&self) -> usize {
        self.dims.iter().map(|(_, p)| p.work()).product()
    }

    /// Total input elements touched (with overlap-reuse discounted),
    /// `Π_d Ng·((Nopc−1)s+Nks−2ps)` per Table 3.
    pub fn input_elements(&self) -> usize {
        self.dims.iter().map(|(_, p)| p.input_extent()).product()
    }

    /// Total kernel parameters, `Π_d Ng·Nop·Nks`.
    pub fn kernel_elements(&self) -> usize {
        if self.kernel.is_none() {
            return 0;
        }
        self.dims.iter().map(|(_, p)| p.kernel_extent()).product()
    }

    /// Total outputs, `Π_d Ng·Nop·Nopc`.
    pub fn output_elements(&self) -> usize {
        self.dims.iter().map(|(_, p)| p.output_extent()).product()
    }

    /// Per-dimension input extents in dimension order (the tensor shape
    /// the native interpreter expects; see [`DimParams::input_extent`]).
    pub fn input_extents(&self) -> Vec<usize> {
        self.dims.iter().map(|(_, p)| p.input_extent()).collect()
    }

    /// Per-dimension kernel extents in dimension order.
    pub fn kernel_extents(&self) -> Vec<usize> {
        self.dims.iter().map(|(_, p)| p.kernel_extent()).collect()
    }

    /// Per-dimension output extents in dimension order.
    pub fn output_extents(&self) -> Vec<usize> {
        self.dims.iter().map(|(_, p)| p.output_extent()).collect()
    }

    /// True when the op has no reduction — a candidate for operation
    /// fusion into a neighbour's `pre`/`post`/`main` (paper §4.3).
    pub fn is_fusible(&self) -> bool {
        self.reduce == ReduceOp::None
    }

    /// True when evaluating this op maps input element `i` straight to
    /// output element `i` (modulo the scalar `pre`/`main`/`post` maps):
    /// no kernel reuse (`Nop`), no reduction windows (`Nks`), no padding
    /// and no stride subsampling. This is the indexing-legality core of
    /// *executable* operation fusion: only such ops can be folded into a
    /// neighbour's scalar pipeline without changing which elements the
    /// host touches.
    pub fn is_identity_indexed(&self) -> bool {
        self.dims.iter().all(|&(_, p)| {
            p.nks == 1 && p.nop == 1 && p.ps == 0 && p.pe == 0 && (p.nopc <= 1 || p.s == 1)
        }) && self.input_elements() == self.output_elements()
    }

    /// Dimensions that overlap-reuse inputs, in mapping order.
    pub fn overlap_dims(&self) -> Vec<Dim> {
        Dim::MAPPING_ORDER
            .iter()
            .copied()
            .filter(|&d| self.params(d).overlap_reuse())
            .collect()
    }
}

impl fmt::Display for GconvOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ", self.name)?;
        for (d, p) in &self.dims {
            if p.is_default() {
                continue;
            }
            write!(f, "{d}[")?;
            let mut first = true;
            let mut field = |f: &mut fmt::Formatter<'_>, name: &str, v: usize, dft: usize| {
                if v != dft {
                    if !first {
                        let _ = write!(f, " ");
                    }
                    first = false;
                    let _ = write!(f, "{name}:{v}");
                }
                Ok::<(), fmt::Error>(())
            };
            field(f, "Ng", p.ng, 1)?;
            field(f, "Nop", p.nop, 1)?;
            field(f, "Nopc", p.nopc, 1)?;
            field(f, "Nks", p.nks, 1)?;
            field(f, "s", p.s, 1)?;
            field(f, "ps", p.ps, 0)?;
            field(f, "pe", p.pe, 0)?;
            write!(f, "] ")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_3x3() -> GconvOp {
        // 16 kernels of 3x3x8 on a 8x10x10 input (pad 1), batch 4.
        GconvOp::conv(
            "conv",
            vec![
                (Dim::B, DimParams::opc(4)),
                (Dim::C, DimParams { nop: 16, nks: 8, ..Default::default() }),
                (Dim::H, DimParams::window(10, 3, 1, 1)),
                (Dim::W, DimParams::window(10, 3, 1, 1)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        )
    }

    #[test]
    fn work_counts_macs() {
        // 4 * (16*8) * (10*3) * (10*3) MACs.
        assert_eq!(conv_3x3().work(), 4 * 16 * 8 * 30 * 30);
    }

    #[test]
    fn input_extent_inverts_conv_arithmetic() {
        // H: (10-1)*1 + 3 - 2*1 = 10 inputs.
        assert_eq!(DimParams::window(10, 3, 1, 1).input_extent(), 10);
        // stride-2: (5-1)*2 + 3 = 11 inputs, no pad.
        assert_eq!(DimParams::window(5, 3, 2, 0).input_extent(), 11);
    }

    #[test]
    fn element_counts() {
        let g = conv_3x3();
        assert_eq!(g.input_elements(), 4 * 8 * 10 * 10);
        assert_eq!(g.kernel_elements(), 16 * 8 * 3 * 3);
        assert_eq!(g.output_elements(), 4 * 16 * 10 * 10);
    }

    #[test]
    fn overlap_dims_detect_sliding_windows() {
        assert_eq!(conv_3x3().overlap_dims(), vec![Dim::W, Dim::H]);
    }

    #[test]
    fn default_dims_prune() {
        let g = conv_3x3();
        assert_eq!(g.params(Dim::T), DimParams::default());
        assert!(!g.active_dims().contains(&Dim::T));
    }

    #[test]
    fn batch_dim_as_kernel_sliding() {
        // Fig. 5: B dimension of a conv layer is [Nopc: Nbs] — one-weight
        // kernel sliding along the batch.
        let p = DimParams::opc(32);
        assert_eq!(p.input_extent(), 32);
        assert_eq!(p.output_extent(), 32);
        assert_eq!(p.kernel_extent(), 1);
        assert!(!p.overlap_reuse());
    }

    #[test]
    fn reduction_dim_covers_input() {
        // Fig. 5: C dimension has Nks = Nic (kernel covers the input).
        let p = DimParams { nop: 16, nks: 8, ..Default::default() };
        assert_eq!(p.input_extent(), 8);
        assert_eq!(p.kernel_extent(), 16 * 8);
        assert_eq!(p.output_extent(), 16);
    }

    #[test]
    fn ceil_mode_window_clips_to_the_input() {
        // Caffe ceil-mode pool: 3x3 stride 2 over 28 yields 14 outputs,
        // whose last window overhangs by one — modelled as pe = 1.
        let p = DimParams::window_ceil(14, 3, 2, 0, 28);
        assert_eq!(p.pe, 1);
        assert_eq!(p.input_extent(), 28);
        // Exact covers keep pe = 0 and the plain-window arithmetic.
        let q = DimParams::window_ceil(27, 3, 2, 0, 55);
        assert_eq!(q.pe, 0);
        assert_eq!(q, DimParams::window(27, 3, 2, 0));
    }

    #[test]
    fn stage_stacks_compose_and_collapse() {
        let mut a = PreOp::Lut("relu").stages();
        assert!(a.extend(&PostOp::Mul(2.0).stages()));
        assert_eq!(a.as_slice(), &[ScalarStage::Lut("relu"), ScalarStage::Mul(2.0)]);
        assert!(matches!(PreOp::from_stages(a), PreOp::Stack(_)));
        // Single stages collapse back to the dedicated variants.
        assert_eq!(PreOp::from_stages(PreOp::Square.stages()), PreOp::Square);
        assert_eq!(PostOp::from_stages(PostOp::Lut("exp").stages()), PostOp::Lut("exp"));
        assert_eq!(PostOp::from_stages(StageStack::empty()), PostOp::None);
        // Overflow is refused, not truncated.
        let mut full = StageStack::empty();
        for _ in 0..MAX_FUSED_STAGES {
            assert!(full.push(ScalarStage::Square));
        }
        assert!(!full.push(ScalarStage::Square));
        let mut one = PreOp::Square.stages();
        assert!(!one.extend(&full));
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn identity_indexing_detects_element_wise_ops() {
        let copy = GconvOp {
            name: "copy".into(),
            dims: vec![(Dim::C, DimParams::g(4)), (Dim::W, DimParams::opc(5))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: DataRef::External("x".into()),
            kernel: None,
        };
        assert!(copy.is_identity_indexed());
        let mut windowed = copy.clone();
        windowed.dims[1].1 = DimParams::window(5, 3, 1, 1);
        assert!(!windowed.is_identity_indexed());
        let mut strided = copy.clone();
        strided.dims[1].1 = DimParams { nopc: 5, s: 2, ..Default::default() };
        assert!(!strided.is_identity_indexed());
        let mut replicated = copy;
        replicated.dims[0].1 = DimParams::op(4);
        assert!(!replicated.is_identity_indexed());
    }
}
