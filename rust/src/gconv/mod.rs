//! The GCONV operation model and layer→GCONV lowering (paper §3).
//!
//! A GCONV is a concisely parameterized 1-D convolution scaled up to the
//! dimensions present in the data ([`op::GconvOp`]). The [`lower`]
//! module decomposes every CNN layer — forward and backward — into a
//! short sequence of GCONVs, and [`chain`] threads the per-layer
//! sequences into the end-to-end [`chain::GconvChain`].

pub mod chain;
pub mod lower;
pub mod op;

pub use chain::{ChainEntry, GconvChain, SpecialOp};
pub use op::{
    DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp, ReduceOp, ScalarStage, StageStack,
};
