//! The GCONV chain: end-to-end CNN computation as a sequence of GCONVs
//! linked by producer/consumer relations (paper §3.2).

use super::op::{DataRef, DimParams, GconvOp};
use crate::ir::{Dim, NodeId};
use std::fmt;

/// Propagation phase a chain entry belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Forward propagation.
    Fp,
    /// Backward propagation (gradients).
    Bp,
    /// Weight-gradient computation.
    Wg,
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Phase::Fp => "FP",
            Phase::Bp => "BP",
            Phase::Wg => "WG",
        })
    }
}

/// A GCONV absorbed into a neighbour's `pre`/`post`/`main` operator by
/// operation fusion (§4.3).
#[derive(Clone, Debug, PartialEq)]
pub struct FusedOp {
    /// Name of the absorbed GCONV.
    pub name: String,
    /// Which operator slot it landed in (`"pre"`, `"post"`, `"main"`).
    pub slot: &'static str,
    /// Kernel-parameter elements the host op must now additionally load
    /// ("due to the pre/post parameter loading, the kernel parameter
    /// movement of the global buffer has increased", §4.3).
    pub param_elements: usize,
}

/// A chain entry whose numerics the GCONV loop-nest interpreter cannot
/// express, executed by a dedicated native routine instead (see
/// `exec::special`). The entry's [`GconvOp`] still carries the loop
/// footprint the analytical models read (work, operand extents), so the
/// cycle/movement/energy models are unaffected by this metadata.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecialOp {
    /// Max-pool backward (argmax routing): the entry's `input` operand
    /// is the pooled-output gradient and its `kernel` operand the saved
    /// forward input. `fwd` is the forward pooling geometry and
    /// `in_extents` the forward-input extents, dimension for dimension.
    /// The native engine recomputes the argmax mask from the forward
    /// input and routes each window's gradient to the winning element
    /// (first maximum in reduction order; fully-padded windows route
    /// nothing).
    MaxPoolBp {
        /// Forward pooling loop dims (`pool_dims` of the lowering).
        fwd: Vec<(Dim, DimParams)>,
        /// Forward-input extent per `fwd` dimension.
        in_extents: Vec<usize>,
    },
    /// One concatenation step: copy the `input` operand then the
    /// `kernel` operand side by side along the axis at position `axis`
    /// of the entry's dims (`pre_extent + branch_extent` equals that
    /// axis' output extent). Multi-branch concats lower to a chain of
    /// these pairwise steps.
    Concat {
        /// Index of the concatenation axis within the op's dims.
        axis: usize,
        /// Extent the `input` operand contributes along the axis.
        pre_extent: usize,
        /// Extent the `kernel` operand contributes along the axis.
        branch_extent: usize,
    },
}

/// One GCONV on the chain plus provenance metadata.
#[derive(Clone, Debug)]
pub struct ChainEntry {
    /// The operation.
    pub op: GconvOp,
    /// IR node this GCONV was lowered from.
    pub source: NodeId,
    /// Whether the source layer is traditional (paper §2.1) — drives the
    /// CIP-offload and LIP-pipeline baseline models.
    pub traditional: bool,
    /// FP / BP / WG.
    pub phase: Phase,
    /// GCONVs fused into this one (empty before `fuse_chain`).
    pub fused: Vec<FusedOp>,
    /// Set when the entry executes through a dedicated native routine
    /// instead of the loop-nest interpreter. Special entries never
    /// participate in operation fusion.
    pub special: Option<SpecialOp>,
}

impl ChainEntry {
    /// Entry with no fusions.
    pub fn new(op: GconvOp, source: NodeId, traditional: bool, phase: Phase) -> Self {
        ChainEntry { op, source, traditional, phase, fused: Vec::new(), special: None }
    }

    /// Attach a special-execution routine to the entry.
    pub fn with_special(mut self, sp: SpecialOp) -> Self {
        self.special = Some(sp);
        self
    }
}

/// A chain of GCONV operations in execution order.
#[derive(Clone, Debug, Default)]
pub struct GconvChain {
    /// Network name this chain was generated from.
    pub network: String,
    entries: Vec<ChainEntry>,
}

impl GconvChain {
    /// Empty chain for `network`.
    pub fn new(network: &str) -> Self {
        GconvChain { network: network.to_string(), entries: Vec::new() }
    }

    /// Append an entry; returns its chain index (usable as
    /// [`DataRef::Gconv`] by later entries).
    pub fn push(&mut self, entry: ChainEntry) -> usize {
        // Validate producer references point backwards.
        let idx = self.entries.len();
        let check = |r: &DataRef| {
            if let DataRef::Gconv(i) = r {
                assert!(*i < idx, "entry {idx} references future GCONV {i}");
            }
        };
        check(&entry.op.input);
        if let Some(k) = &entry.op.kernel {
            check(k);
        }
        self.entries.push(entry);
        idx
    }

    /// All entries in order.
    pub fn entries(&self) -> &[ChainEntry] {
        &self.entries
    }

    /// Mutable entries (used by fusion).
    pub fn entries_mut(&mut self) -> &mut Vec<ChainEntry> {
        &mut self.entries
    }

    /// Chain length (the code-density metric of Fig. 15 counts these).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the chain is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total `main`-operator work across the chain.
    pub fn total_work(&self) -> usize {
        self.entries.iter().map(|e| e.op.work()).sum()
    }

    /// Work split `(traditional, non_traditional)` — Table 1(a) column
    /// "non-traditional computation".
    pub fn work_split(&self) -> (usize, usize) {
        let mut trad = 0;
        let mut non = 0;
        for e in &self.entries {
            if e.traditional {
                trad += e.op.work();
            } else {
                non += e.op.work();
            }
        }
        (trad, non)
    }

    /// Indices of chain entries that consume entry `i`'s output.
    pub fn consumers(&self, i: usize) -> Vec<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| {
                e.op.input == DataRef::Gconv(i) || e.op.kernel == Some(DataRef::Gconv(i))
            })
            .map(|(j, _)| j)
            .collect()
    }
}

impl fmt::Display for GconvChain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "GCONV Chain for {} ({} ops)", self.network, self.len())?;
        for (i, e) in self.entries.iter().enumerate() {
            writeln!(f, "  #{i:<4} [{}] {}", e.phase, e.op)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::op::{DimParams, MainOp, PostOp, PreOp, ReduceOp};
    use crate::ir::Dim;

    fn entry(name: &str, input: DataRef) -> ChainEntry {
        ChainEntry::new(
            GconvOp {
                name: name.into(),
                dims: vec![(Dim::C, DimParams::opc(4))],
                pre: PreOp::None,
                main: MainOp::Pass,
                reduce: ReduceOp::None,
                post: PostOp::None,
                input,
                kernel: None,
            },
            0,
            true,
            Phase::Fp,
        )
    }

    #[test]
    fn push_links_producers() {
        let mut c = GconvChain::new("t");
        let a = c.push(entry("a", DataRef::External("x".into())));
        let b = c.push(entry("b", DataRef::Gconv(a)));
        assert_eq!(c.consumers(a), vec![b]);
    }

    #[test]
    #[should_panic(expected = "future GCONV")]
    fn forward_reference_rejected() {
        let mut c = GconvChain::new("t");
        c.push(entry("a", DataRef::Gconv(3)));
    }

    #[test]
    fn work_split_partitions_total() {
        let mut c = GconvChain::new("t");
        c.push(entry("a", DataRef::External("x".into())));
        let mut e = entry("b", DataRef::Gconv(0));
        e.traditional = false;
        c.push(e);
        let (t, n) = c.work_split();
        assert_eq!(t + n, c.total_work());
        assert_eq!(t, n); // identical ops, one of each class
    }
}
