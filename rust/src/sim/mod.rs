//! Top-level simulator (paper §6.2): run a network's GCONV chain — or
//! the accelerator's baseline execution model — and report latency,
//! latency breakdown, data movement and energy.
//!
//! Baseline semantics per accelerator class:
//! * **TIP** — every op is im2col-transformed and executed on the matrix
//!   unit (traditional ops) or vector unit (the rest) in a fine-grained
//!   pipeline.
//! * **LIP** — two-stage layer pipeline with the fixed resource split of
//!   [`crate::accel::pipeline`]; batch-norm-style mini-batch reductions
//!   are pipeline barriers.
//! * **CIP** — traditional layers on-chip with the original dataflow
//!   (baseline mapping mode); everything else offloads to the A53 host,
//!   overlapped with on-chip compute across mini-batches.
//!
//! GCONV-chain mode runs *everything* on the (GCONV-augmented)
//! convolution engine with Algorithm-1 mappings, consistent-mapping loop
//! exchange and operation fusion.

use crate::accel::baseline::im2col_op;
use crate::accel::offload::OffloadHost;
use crate::accel::pipeline::pipeline;
use crate::accel::structure::{AccelStructure, Category};
use crate::energy::{Energy, EnergyTable};
use crate::gconv::chain::GconvChain;
use crate::gconv::lower::{lower_network, Mode};
use crate::gconv::op::{DataRef, DimParams, Param};
use crate::ir::{Dim, Network};
use crate::mapping::{fuse_chain, is_consistent, load_parallelism, make_consistent, map_gconv, MapMode};
use crate::model::cycles::gconv_cycles;

/// Execution mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// The accelerator's original execution model.
    Baseline,
    /// GCONV Chain with both chain optimizations.
    GconvChain,
    /// GCONV Chain without fusion (ablation).
    GconvNoFusion,
    /// GCONV Chain without consistent mapping (ablation).
    GconvNoConsistent,
}

/// Latency breakdown in seconds (the Fig. 12 stack).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyBreakdown {
    /// Only traditional-layer engines busy.
    pub trad_only: f64,
    /// Only non-traditional engines busy.
    pub nontrad_only: f64,
    /// All components busy.
    pub all_busy: f64,
    /// Offload-dominated time (CIP baselines).
    pub offload: f64,
}

impl LatencyBreakdown {
    /// Total seconds.
    pub fn total(&self) -> f64 {
        self.trad_only + self.nontrad_only + self.all_busy + self.offload
    }
}

/// Aggregate data movement in words.
#[derive(Clone, Copy, Debug, Default)]
pub struct MovementTotals {
    /// GB↔array input words.
    pub input: f64,
    /// GB↔array kernel words.
    pub kernel: f64,
    /// GB↔array output words.
    pub output: f64,
    /// Words offloaded to/reloaded from the host.
    pub offload: f64,
}

impl MovementTotals {
    /// On-chip GB words.
    pub fn gb_total(&self) -> f64 {
        self.input + self.kernel + self.output
    }
}

/// Result of one simulation.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Network name.
    pub network: String,
    /// Accelerator code.
    pub accel: &'static str,
    /// End-to-end seconds per training (or inference) step.
    pub seconds: f64,
    /// Seconds spent in convolution/FC layers only (Fig. 13).
    pub conv_seconds: f64,
    /// Fig. 12 stack.
    pub breakdown: LatencyBreakdown,
    /// Movement totals.
    pub movement: MovementTotals,
    /// Energy totals (normalized units).
    pub energy: Energy,
    /// Chain length after optimizations (Fig. 15).
    pub chain_len: usize,
    /// PE utilization (0..1).
    pub utilization: f64,
}

/// Simulation options.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Execution mode.
    pub mode: ExecMode,
    /// Train (FP+BP+WG) or inference-only.
    pub training: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { mode: ExecMode::GconvChain, training: true }
    }
}

/// Simulate `net` on `accel`.
pub fn simulate(net: &Network, accel: &AccelStructure, opts: SimOptions) -> SimResult {
    let mode = if opts.training { Mode::Training } else { Mode::Inference };
    let chain = lower_network(net, mode);
    simulate_chain(net, &chain, accel, opts)
}

/// Simulate a pre-lowered chain (lets callers reuse the lowering).
pub fn simulate_chain(
    net: &Network,
    chain: &GconvChain,
    accel: &AccelStructure,
    opts: SimOptions,
) -> SimResult {
    match opts.mode {
        ExecMode::Baseline => match accel.category {
            Category::Cip => simulate_cip_baseline(net, chain, accel),
            Category::Tip => simulate_tip_baseline(net, chain, accel),
            Category::Lip => simulate_lip_baseline(net, chain, accel),
        },
        m => simulate_gconv(net, chain, accel, m),
    }
}

/// Mapping-relevant signature of an op: loop structure + operators.
fn op_signature(op: &crate::gconv::op::GconvOp) -> String {
    use std::fmt::Write;
    let mut s = String::with_capacity(64);
    for (d, p) in &op.dims {
        let _ = write!(s, "{d}:{},{},{},{},{},{};", p.ng, p.nop, p.nopc, p.nks, p.s, p.ps);
    }
    let _ = write!(s, "|{:?}|{:?}|{}", op.main, op.reduce, op.kernel.is_some());
    s
}

/// Systolic structures move operands register-to-register across the
/// array every cycle (input shift + partial-sum shift): ~2 extra local
/// transfers per MAC on top of the canonical 3 — the energy tax that
/// makes scratchpad-rich CIPs the efficiency winners of Fig. 19.
fn systolic_shift_energy(
    accel: &AccelStructure,
    op: &crate::gconv::op::GconvOp,
    et: &EnergyTable,
) -> f64 {
    if accel.category == Category::Tip {
        2.0 * op.work() as f64 * et.ls
    } else {
        0.0
    }
}

/// Is this chain entry a mini-batch reduction (LIP pipeline barrier)?
fn is_batch_barrier(entry: &crate::gconv::chain::ChainEntry) -> bool {
    entry.op.params(Dim::B).nks > 1
}

/// Words of the operands an offloaded op must ship to the host and back.
fn offload_words(op: &crate::gconv::op::GconvOp) -> usize {
    op.input_elements() + op.kernel_elements() + op.output_elements()
}

/// GCONV-chain execution on any accelerator.
fn simulate_gconv(
    net: &Network,
    chain: &GconvChain,
    accel: &AccelStructure,
    mode: ExecMode,
) -> SimResult {
    let et = EnergyTable::default();
    let mut chain = chain.clone();
    if mode != ExecMode::GconvNoFusion {
        fuse_chain(&mut chain);
    }
    // Map every entry. The auto-mapper also considers the matrix-style
    // view of the op (kernel size = input size — §3.1: "GCONV can always
    // model a tensor operation by setting the kernel size equal to the
    // input size") and keeps whichever unrolling is faster; this is the
    // "flexible unrolling strategies" credit the paper gives TPU/ER.
    // Chains repeat op shapes heavily (DenseNet: 2.7k entries over ~60
    // distinct shapes), so the representation choice + Algorithm-1
    // mapping are memoized per op *signature* (loop structure +
    // operators; names and data refs do not affect the mapping).
    let mut chain2 = chain.clone();
    let mut swapped = vec![false; chain2.len()];
    let mut memo: std::collections::HashMap<String, (crate::mapping::Mapping, bool, Vec<(Dim, DimParams)>)> =
        std::collections::HashMap::new();
    let mappings: Vec<_> = chain2
        .entries_mut()
        .iter_mut()
        .zip(swapped.iter_mut())
        .map(|(e, sw)| {
            let key = op_signature(&e.op);
            if let Some((m, s, dims)) = memo.get(&key) {
                *sw = *s;
                if *s {
                    e.op.dims = dims.clone();
                }
                return m.clone();
            }
            let direct = map_gconv(&e.op, accel, MapMode::Gconv);
            let alt_op = im2col_op(&e.op);
            let alt = map_gconv(&alt_op, accel, MapMode::Gconv);
            // Compare under pessimistic (inconsistent-format) loading —
            // consistency with the neighbours is unknown at this point,
            // so both candidates are judged at the degraded bus width.
            let pess = load_parallelism(false, accel.bw.i);
            let (cd, _) = gconv_cycles(&e.op, accel, &direct, pess);
            let (ca, _) = gconv_cycles(&alt_op, accel, &alt, pess);
            let m = if ca.total < cd.total {
                e.op = alt_op;
                *sw = true;
                alt
            } else {
                direct
            };
            memo.insert(key, (m.clone(), *sw, e.op.dims.clone()));
            m
        })
        .collect();
    let chain = chain2;
    // Consistent-mapping pass: a legal loop exchange (movement-neutral,
    // §4.3) restores full-width loading for each producer/consumer pair.
    let consistent: Vec<bool> = chain
        .entries()
        .iter()
        .enumerate()
        .map(|(i, e)| match &e.op.input {
            DataRef::Gconv(p) => {
                // Two matrix-form ops share the single im2col layout
                // convention — consistent by construction.
                if swapped[i] && swapped[*p] {
                    true
                } else if mode == ExecMode::GconvNoConsistent {
                    is_consistent(&mappings[*p], &mappings[i])
                } else {
                    make_consistent(&mappings[*p], &mappings[i])
                }
            }
            _ => true,
        })
        .collect();

    let mut r = SimResult {
        network: chain.network.clone(),
        accel: accel.name,
        chain_len: chain.len(),
        ..Default::default()
    };
    let mut busy_pe_cycles = 0.0;
    let mut total_cycles = 0.0;
    // (signature, loading parallelism) fully determines the cycle/
    // movement result — memoize it alongside the mapping memo (§Perf).
    let mut cyc_memo: std::collections::HashMap<(String, u64), (crate::model::cycles::CycleBreakdown, crate::model::movement::Movement)> =
        std::collections::HashMap::new();
    for (i, e) in chain.entries().iter().enumerate() {
        let lp = load_parallelism(consistent[i], accel.bw.i);
        let key = (op_signature(&e.op), lp.to_bits());
        let (cb, mut mv) = *cyc_memo
            .entry(key)
            .or_insert_with(|| gconv_cycles(&e.op, accel, &mappings[i], lp));
        // Fused pre/post parameters ride the kernel bus (§4.3).
        let extra_params: usize = e.fused.iter().map(|f| f.param_elements).sum();
        mv.kernel += extra_params as f64;
        total_cycles += cb.total;
        busy_pe_cycles += cb.compute * mappings[i].occupied_pes() as f64;
        if conv_like_source(net, e) {
            r.conv_seconds += cb.total / (accel.freq_ghz * 1e9);
        }
        r.movement.input += mv.input;
        r.movement.kernel += mv.kernel;
        r.movement.output += mv.output;
        r.energy.compute += e.op.work() as f64 * et.mac;
        r.energy.ls += mv.ls_accesses * et.ls + systolic_shift_energy(accel, &e.op, &et);
        r.energy.gb += (mv.input + mv.kernel + mv.output) * et.gb;
    }
    r.seconds = total_cycles / (accel.freq_ghz * 1e9);
    r.breakdown.all_busy = r.seconds;
    r.utilization =
        (busy_pe_cycles / (total_cycles * accel.pes() as f64)).clamp(0.0, 1.0);
    r
}

/// Does entry `e` come from a convolution-like (conv/fc) layer's forward
/// or backward compute (the Fig. 13 population)?
fn conv_like_source(net: &Network, e: &crate::gconv::chain::ChainEntry) -> bool {
    use crate::ir::Layer;
    matches!(
        net.node(e.source).layer,
        Layer::Conv { .. } | Layer::Conv3d { .. } | Layer::FullyConnected { .. }
    )
}

/// CIP baseline: traditional layers on-chip (original dataflow),
/// non-traditional layers offloaded; on-chip and offload lanes overlap
/// across mini-batches.
fn simulate_cip_baseline(net: &Network, chain: &GconvChain, accel: &AccelStructure) -> SimResult {
    let et = EnergyTable::default();
    let host = OffloadHost::default();
    let mut r = SimResult {
        network: chain.network.clone(),
        accel: accel.name,
        chain_len: chain.len(),
        ..Default::default()
    };
    let mut onchip_s = 0.0;
    let mut offload_s = 0.0;
    let mut busy_pe_cycles = 0.0;
    let mut onchip_cycles = 0.0;
    for e in chain.entries() {
        if e.traditional {
            let m = map_gconv(&e.op, accel, MapMode::Baseline);
            let (cb, mv) = gconv_cycles(&e.op, accel, &m, accel.bw.i as f64);
            let secs = cb.total / (accel.freq_ghz * 1e9);
            onchip_s += secs;
            onchip_cycles += cb.total;
            busy_pe_cycles += cb.compute * m.occupied_pes() as f64;
            if conv_like_source(net, e) {
                r.conv_seconds += secs;
            }
            r.movement.input += mv.input;
            r.movement.kernel += mv.kernel;
            r.movement.output += mv.output;
            r.energy.compute += e.op.work() as f64 * et.mac;
            r.energy.ls += mv.ls_accesses * et.ls;
            r.energy.gb += (mv.input + mv.kernel + mv.output) * et.gb;
        } else {
            let words = offload_words(&e.op);
            let cost = host.cost(e.op.work(), words - e.op.output_elements(), e.op.output_elements());
            offload_s += cost.seconds;
            r.movement.offload += cost.words;
            r.energy.offload += cost.words * et.offload;
        }
    }
    // Mini-batch double buffering hides part of the shorter lane behind
    // the longer; how much depends on the accelerator (§6.3).
    let overlapped = accel.offload_overlap * onchip_s.min(offload_s);
    r.seconds = onchip_s + offload_s - overlapped;
    r.breakdown.all_busy = overlapped;
    r.breakdown.trad_only = (onchip_s - overlapped).max(0.0);
    r.breakdown.offload = (offload_s - overlapped).max(0.0);
    r.utilization = if onchip_cycles > 0.0 {
        (busy_pe_cycles / (onchip_cycles * accel.pes() as f64) * (onchip_s / r.seconds))
            .clamp(0.0, 1.0)
    } else {
        0.0
    };
    r
}

/// TIP baseline: im2col everything; matrix ops and vector ops share the
/// chip in a fine-grained pipeline.
fn simulate_tip_baseline(net: &Network, chain: &GconvChain, accel: &AccelStructure) -> SimResult {
    let et = EnergyTable::default();
    let mut r = SimResult {
        network: chain.network.clone(),
        accel: accel.name,
        chain_len: chain.len(),
        ..Default::default()
    };
    let mut mat_s = 0.0; // matrix-unit seconds (reduction ops)
    let mut vec_s = 0.0; // vector-unit seconds (element-wise ops)
    let mut busy_pe_cycles = 0.0;
    let mut cycles_total = 0.0;
    for e in chain.entries() {
        let t = im2col_op(&e.op);
        let m = map_gconv(&t, accel, MapMode::Baseline);
        let (cb, mut mv) = gconv_cycles(&t, accel, &m, accel.bw.i as f64);
        // im2col materialization: the replicated input matrix is written
        // to the global buffer before the matmul reads it (Fig. 1(c) —
        // the red duplicated cells are real traffic).
        mv.input += t.input_elements() as f64;
        let secs = cb.total / (accel.freq_ghz * 1e9);
        if t.reduce != crate::gconv::op::ReduceOp::None {
            mat_s += secs;
        } else {
            vec_s += secs;
        }
        cycles_total += cb.total;
        busy_pe_cycles += cb.compute * m.occupied_pes() as f64;
        if conv_like_source(net, e) {
            r.conv_seconds += secs;
        }
        r.movement.input += mv.input;
        r.movement.kernel += mv.kernel;
        r.movement.output += mv.output;
        r.energy.compute += e.op.work() as f64 * et.mac;
        r.energy.ls += mv.ls_accesses * et.ls + systolic_shift_energy(accel, &e.op, &et);
        r.energy.gb += (mv.input + mv.kernel + mv.output) * et.gb;
    }
    // Matrix and vector units overlap partially (TPU all-busy ≈ 31%,
    // Fig. 12): the shorter stream hides behind the longer.
    let overlap = mat_s.min(vec_s);
    r.seconds = mat_s.max(vec_s) + 0.5 * overlap;
    r.breakdown.all_busy = 0.5 * overlap;
    r.breakdown.trad_only = (mat_s - 0.5 * overlap).max(0.0);
    r.breakdown.nontrad_only = (vec_s - 0.5 * overlap).max(0.0);
    r.utilization = (busy_pe_cycles / (cycles_total.max(1.0) * accel.pes() as f64)).clamp(0.0, 1.0);
    r
}

/// LIP baseline: two-stage traditional/non-traditional pipeline.
fn simulate_lip_baseline(net: &Network, chain: &GconvChain, accel: &AccelStructure) -> SimResult {
    let et = EnergyTable::default();
    let mut r = SimResult {
        network: chain.network.clone(),
        accel: accel.name,
        chain_len: chain.len(),
        ..Default::default()
    };
    let mut trad_s = 0.0;
    let mut nontrad_s = 0.0;
    let mut barriers = 0usize;
    for e in chain.entries() {
        let m = map_gconv(&e.op, accel, MapMode::Baseline);
        let (cb, mv) = gconv_cycles(&e.op, accel, &m, accel.bw.i as f64);
        let secs = cb.total / (accel.freq_ghz * 1e9);
        if e.traditional {
            trad_s += secs;
        } else {
            nontrad_s += secs;
        }
        if is_batch_barrier(e) {
            barriers += 1;
        }
        if conv_like_source(net, e) {
            r.conv_seconds += secs;
        }
        r.movement.input += mv.input;
        r.movement.kernel += mv.kernel;
        r.movement.output += mv.output;
        r.energy.compute += e.op.work() as f64 * et.mac;
        r.energy.ls += mv.ls_accesses * et.ls;
        r.energy.gb += (mv.input + mv.kernel + mv.output) * et.gb;
    }
    let p = pipeline(trad_s, nontrad_s, barriers);
    r.seconds = p.seconds;
    r.breakdown.trad_only = p.trad_only;
    r.breakdown.nontrad_only = p.nontrad_only;
    r.breakdown.all_busy = p.all_busy;
    // Mini-batch reductions flush the ~16 items the two-stage pipeline
    // keeps in flight; the *resource* utilization craters accordingly
    // (Table 1(b): BN wrecks DenseNet/MobileNet LIP utilization) even
    // where latency hiding keeps the wall-clock acceptable.
    r.utilization = p.utilization / (1.0 + barriers as f64 / 16.0);
    // The conv-only time also inflates by the stage split.
    r.conv_seconds /= crate::accel::pipeline::TRADITIONAL_SHARE;
    r
}

/// Convenience: a GCONV op is "degenerate" if it has no loops at all
/// (used by property tests).
pub fn degenerate(op: &crate::gconv::op::GconvOp) -> bool {
    op.dims.iter().all(|(_, p)| {
        Param::ALL.iter().all(|&q| p.get(q) == 1) && *p == DimParams { s: p.s, ps: p.ps, ..Default::default() }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::{all_accelerators, by_code};
    use crate::networks::{benchmark, mobilenet_block};

    fn block_sim(accel_code: &str, mode: ExecMode) -> SimResult {
        let net = mobilenet_block(8, 32, 28);
        simulate(&net, &by_code(accel_code), SimOptions { mode, training: true })
    }

    #[test]
    fn gconv_beats_cip_baseline_on_bn_heavy_block() {
        // The MobileNet block is depthwise + BN heavy: the CIP baseline
        // offloads most of it, GCONV chain runs it all on-chip.
        let base = block_sim("ER", ExecMode::Baseline);
        let gc = block_sim("ER", ExecMode::GconvChain);
        assert!(
            gc.seconds < base.seconds,
            "GCONV {} should beat baseline {}",
            gc.seconds,
            base.seconds
        );
    }

    #[test]
    fn baseline_cip_reports_offload_time() {
        let base = block_sim("EP", ExecMode::Baseline);
        assert!(base.breakdown.offload > 0.0 || base.breakdown.all_busy > 0.0);
        assert!(base.movement.offload > 0.0);
        assert!(base.energy.offload > 0.0);
    }

    #[test]
    fn gconv_mode_never_offloads() {
        for a in all_accelerators() {
            let r = simulate(
                &mobilenet_block(4, 16, 14),
                &a,
                SimOptions { mode: ExecMode::GconvChain, training: true },
            );
            assert_eq!(r.movement.offload, 0.0, "{}", a.name);
            assert_eq!(r.energy.offload, 0.0, "{}", a.name);
        }
    }

    #[test]
    fn ablations_bracket_the_full_chain() {
        // Disabling an optimization can only slow things down (or tie).
        let full = block_sim("ER", ExecMode::GconvChain);
        let nofuse = block_sim("ER", ExecMode::GconvNoFusion);
        let noconsist = block_sim("ER", ExecMode::GconvNoConsistent);
        assert!(full.seconds <= nofuse.seconds * 1.001);
        assert!(full.seconds <= noconsist.seconds * 1.001);
        assert!(full.chain_len <= nofuse.chain_len);
    }

    #[test]
    fn utilization_is_a_fraction() {
        for mode in [ExecMode::Baseline, ExecMode::GconvChain] {
            let r = block_sim("ER", mode);
            assert!((0.0..=1.0).contains(&r.utilization), "{mode:?}: {}", r.utilization);
        }
    }

    #[test]
    fn alexnet_end_to_end_speedup_is_positive() {
        // Smoke the full AlexNet on Eyeriss (Fig. 14 cell AN/ER).
        let net = benchmark("AN");
        let accel = by_code("ER");
        let base = simulate(&net, &accel, SimOptions { mode: ExecMode::Baseline, training: true });
        let gc = simulate(&net, &accel, SimOptions { mode: ExecMode::GconvChain, training: true });
        let speedup = base.seconds / gc.seconds;
        assert!(speedup >= 1.0, "speedup {speedup}");
        assert!(speedup < 100.0, "speedup {speedup} implausible");
    }
}
