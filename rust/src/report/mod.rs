//! Table/figure printers shared by the CLI and the bench harnesses.

use std::fmt::Display;

/// Render an aligned text table with a title.
pub fn print_table<H: Display, C: Display>(title: &str, headers: &[H], rows: &[Vec<C>]) {
    let headers: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    let rows: Vec<Vec<String>> =
        rows.iter().map(|r| r.iter().map(|c| c.to_string()).collect()).collect();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in &rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    println!("\n=== {title} ===");
    let line: usize = widths.iter().sum::<usize>() + 3 * widths.len();
    let fmt_row = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = widths.get(i).copied().unwrap_or(8);
            if i == 0 {
                s.push_str(&format!("{c:<w$}"));
            } else {
                s.push_str(&format!(" | {c:>w$}"));
            }
        }
        s
    };
    println!("{}", fmt_row(&headers));
    println!("{}", "-".repeat(line));
    for r in &rows {
        println!("{}", fmt_row(r));
    }
}

/// Format a float with 2 decimals (for ratio tables).
pub fn r2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a fraction as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.0}%", 100.0 * x)
}

/// Format with SI suffix (k/M/G/T).
pub fn si(x: f64) -> String {
    let (v, suffix) = if x.abs() >= 1e12 {
        (x / 1e12, "T")
    } else if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2}{suffix}")
}

/// Geometric mean of positive values (the paper's "average of 3.4x" is a
/// ratio average).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_constant_is_constant() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_balances_ratios() {
        assert!((geomean(&[2.0, 0.5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn si_formats() {
        assert_eq!(si(1_500_000.0), "1.50M");
        assert_eq!(si(42.0), "42.00");
        assert_eq!(si(2.5e12), "2.50T");
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.34), "34%");
    }
}
