//! Layer-level network IR.
//!
//! Networks are expressed as DAGs of [`Layer`]s over named-dimension
//! tensors (paper §2). This is the input to the GCONV Chain compiler
//! (`crate::gconv::lower`), playing the role the Caffe prototxt +
//! Pycaffe interface plays in the paper's implementation (§5).

mod graph;
mod layer;
mod tensor;

pub use graph::{LayerNode, Network, NodeId};
pub use layer::{Layer, PoolKind};
pub use tensor::{Dim, Shape};
