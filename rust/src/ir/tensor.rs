//! Named-dimension tensor shapes.
//!
//! The GCONV model (paper §3.1) treats every data dimension uniformly, so
//! shapes carry dimension *names* — batch, channel, spatial, time (3-D
//! CNNs), vector (capsule networks) — rather than positional axes.

use std::fmt;

/// A named tensor/GCONV dimension.
///
/// `B`/`C`/`H`/`W` are the classic four of paper Fig. 5; `T` is the time
/// dimension of 3-D CNNs (C3D) and `V` the vector dimension of capsule
/// networks, both of which §3.1 calls out as scale-ups of the same 1-D
/// GCONV.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dim {
    /// Mini-batch.
    B,
    /// Channel.
    C,
    /// Height.
    H,
    /// Width.
    W,
    /// Time (3-D convolutions).
    T,
    /// Vector (capsule pose components).
    V,
}

impl Dim {
    /// All dimensions in the canonical mapping order used by Algorithm 1
    /// (`for d in ["W","H","C","B"]`, extended with T and V after W since
    /// they behave like extra spatial/inner dimensions).
    pub const MAPPING_ORDER: [Dim; 6] = [Dim::W, Dim::H, Dim::T, Dim::V, Dim::C, Dim::B];

    /// Short display name.
    pub fn name(self) -> &'static str {
        match self {
            Dim::B => "B",
            Dim::C => "C",
            Dim::H => "H",
            Dim::W => "W",
            Dim::T => "T",
            Dim::V => "V",
        }
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A tensor shape: an ordered list of `(dimension, extent)` pairs.
///
/// Absent dimensions are implicitly extent-1 (the same pruning rule GCONV
/// applies to default-parameter loops).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<(Dim, usize)>,
}

impl Shape {
    /// Build a shape from `(dim, extent)` pairs. Panics on duplicates or
    /// zero extents.
    pub fn new(dims: &[(Dim, usize)]) -> Self {
        let mut seen = Vec::new();
        for &(d, n) in dims {
            assert!(n > 0, "zero extent for {d}");
            assert!(!seen.contains(&d), "duplicate dim {d}");
            seen.push(d);
        }
        Shape { dims: dims.to_vec() }
    }

    /// Classic image batch `[B, C, H, W]`.
    pub fn bchw(b: usize, c: usize, h: usize, w: usize) -> Self {
        Shape::new(&[(Dim::B, b), (Dim::C, c), (Dim::H, h), (Dim::W, w)])
    }

    /// Video batch `[B, C, T, H, W]`.
    pub fn bcthw(b: usize, c: usize, t: usize, h: usize, w: usize) -> Self {
        Shape::new(&[(Dim::B, b), (Dim::C, c), (Dim::T, t), (Dim::H, h), (Dim::W, w)])
    }

    /// Extent of `d` (1 if absent).
    pub fn extent(&self, d: Dim) -> usize {
        self.dims.iter().find(|&&(x, _)| x == d).map_or(1, |&(_, n)| n)
    }

    /// Iterate `(dim, extent)` pairs in declaration order.
    pub fn iter(&self) -> impl Iterator<Item = (Dim, usize)> + '_ {
        self.dims.iter().copied()
    }

    /// Dimensions present in this shape.
    pub fn dims(&self) -> Vec<Dim> {
        self.dims.iter().map(|&(d, _)| d).collect()
    }

    /// Total number of elements.
    pub fn elements(&self) -> usize {
        self.dims.iter().map(|&(_, n)| n).product()
    }

    /// Copy with dimension `d` set to `n` (appended if absent, removed if
    /// `n == 1` and you call [`Shape::pruned`] afterwards).
    pub fn with(&self, d: Dim, n: usize) -> Self {
        assert!(n > 0);
        let mut dims = self.dims.clone();
        match dims.iter_mut().find(|(x, _)| *x == d) {
            Some(slot) => slot.1 = n,
            None => dims.push((d, n)),
        }
        Shape { dims }
    }

    /// Copy without extent-1 dimensions.
    pub fn pruned(&self) -> Self {
        Shape { dims: self.dims.iter().copied().filter(|&(_, n)| n > 1).collect() }
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, (d, n)) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}:{n}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extent_defaults_to_one() {
        let s = Shape::bchw(4, 3, 8, 8);
        assert_eq!(s.extent(Dim::C), 3);
        assert_eq!(s.extent(Dim::T), 1);
    }

    #[test]
    fn elements_is_product() {
        assert_eq!(Shape::bchw(2, 3, 4, 5).elements(), 120);
        assert_eq!(Shape::bcthw(1, 3, 16, 112, 112).elements(), 3 * 16 * 112 * 112);
    }

    #[test]
    fn with_updates_or_appends() {
        let s = Shape::bchw(1, 3, 8, 8).with(Dim::C, 16).with(Dim::T, 4);
        assert_eq!(s.extent(Dim::C), 16);
        assert_eq!(s.extent(Dim::T), 4);
    }

    #[test]
    fn pruned_drops_unit_dims() {
        let s = Shape::bchw(1, 3, 8, 1).pruned();
        assert_eq!(s.dims(), vec![Dim::C, Dim::H]);
    }

    #[test]
    #[should_panic(expected = "duplicate dim")]
    fn duplicate_dims_rejected() {
        Shape::new(&[(Dim::C, 2), (Dim::C, 3)]);
    }

    #[test]
    fn display_formats() {
        assert_eq!(Shape::bchw(1, 2, 3, 4).to_string(), "[B:1, C:2, H:3, W:4]");
    }
}
