//! Layer definitions and shape inference.
//!
//! Covers the traditional layers of LeNet-era CNNs plus every
//! non-traditional layer introduced by the paper's seven benchmarks
//! (Table 1(a)): LRN + dropout (AlexNet), average pooling + concat
//! (GoogLeNet), batch norm + scale (DenseNet), depthwise convolution
//! (MobileNet), RoI pooling + proposal (Faster R-CNN), 3-D conv/pool
//! (C3D) and primary/digit capsules (CapsNet).

use super::tensor::{Dim, Shape};

/// Pooling flavour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// A CNN layer. Spatial hyper-parameters follow Caffe conventions
/// (square kernels unless noted; `pad` applied symmetrically).
#[derive(Clone, Debug, PartialEq)]
pub enum Layer {
    /// Network input placeholder.
    Input { shape: Shape },
    /// 2-D convolution. `groups == in_channels` models depthwise
    /// convolution (MobileNet); `groups > 1` models grouped convolution
    /// (AlexNet).
    Conv { out_channels: usize, kernel: (usize, usize), stride: usize, pad: usize, groups: usize },
    /// 3-D convolution over `(T, H, W)` (C3D).
    Conv3d { out_channels: usize, kernel: (usize, usize, usize), stride: usize, pad: usize },
    /// Fully-connected layer.
    FullyConnected { out_features: usize },
    /// 2-D pooling.
    Pool { kind: PoolKind, kernel: usize, stride: usize, pad: usize },
    /// Global average pooling over all spatial dims (GoogLeNet head).
    GlobalAvgPool,
    /// 3-D pooling over `(T, H, W)` (C3D).
    Pool3d { kind: PoolKind, kernel: (usize, usize, usize), stride: (usize, usize, usize) },
    /// Rectified linear unit.
    Relu,
    /// Sigmoid activation.
    Sigmoid,
    /// Softmax over channels.
    Softmax,
    /// Local response normalization (AlexNet): `local_size` window over C.
    Lrn { local_size: usize },
    /// Batch normalization (statistics over B×H×W per channel).
    BatchNorm,
    /// Per-channel affine scale + shift (Caffe `Scale`, follows BN).
    Scale,
    /// Dropout (training: multiply by Bernoulli mask and rescale).
    Dropout,
    /// Channel-wise concatenation of all inputs.
    Concat,
    /// Element-wise addition of all inputs (residual joins).
    Eltwise,
    /// RoI max-pooling (Faster R-CNN): pools `num_rois` regions to a
    /// fixed `output` spatial size; RoI coordinates come from `Proposal`.
    RoiPool { num_rois: usize, output: (usize, usize) },
    /// Region proposal (Faster R-CNN): per-anchor box regression +
    /// objectness scoring + NMS, modelled as element-wise chains.
    Proposal { anchors: usize },
    /// Primary capsules (CapsNet): conv into `caps × vec` channels then
    /// squash; `vec` is the capsule pose length.
    PrimaryCaps { caps_channels: usize, vec: usize, kernel: usize, stride: usize },
    /// Digit capsules (CapsNet): fully-connected capsule transform with
    /// `routing` iterations of dynamic routing.
    DigitCaps { out_caps: usize, out_vec: usize, routing: usize },
}

impl Layer {
    /// Human-readable kind name (used in reports and chain labels).
    pub fn kind(&self) -> &'static str {
        match self {
            Layer::Input { .. } => "input",
            Layer::Conv { groups, out_channels, .. } if groups == out_channels && *groups > 1 => "conv(grouped)",
            Layer::Conv { .. } => "conv",
            Layer::Conv3d { .. } => "conv3d",
            Layer::FullyConnected { .. } => "fc",
            Layer::Pool { .. } => "pool",
            Layer::GlobalAvgPool => "global_avg_pool",
            Layer::Pool3d { .. } => "pool3d",
            Layer::Relu => "relu",
            Layer::Sigmoid => "sigmoid",
            Layer::Softmax => "softmax",
            Layer::Lrn { .. } => "lrn",
            Layer::BatchNorm => "batch_norm",
            Layer::Scale => "scale",
            Layer::Dropout => "dropout",
            Layer::Concat => "concat",
            Layer::Eltwise => "eltwise",
            Layer::RoiPool { .. } => "roi_pool",
            Layer::Proposal { .. } => "proposal",
            Layer::PrimaryCaps { .. } => "primary_caps",
            Layer::DigitCaps { .. } => "digit_caps",
        }
    }

    /// Is this one of the *traditional* layers a convolution-intended
    /// processor (CIP) handles on-chip (paper §2.1/§6.2: convolution,
    /// fully-connected, max pooling, ReLU, softmax)?
    ///
    /// Everything else is "non-traditional" and must be offloaded by CIP
    /// baselines. Depthwise/grouped convolution counts as non-traditional:
    /// Table 1(a) lists `depthwise conv` as MobileNet's new layer type
    /// (CIP dataflows cannot exploit their feature-map unrolling, Fig. 13).
    pub fn is_traditional(&self) -> bool {
        match self {
            Layer::Input { .. } => true,
            // Grouped convolution is part of the traditional definition
            // (Fig. 2 includes Ngp); *depthwise* convolution — one group
            // per channel — is the non-traditional MobileNet layer.
            Layer::Conv { groups, out_channels, .. } => groups < out_channels || *groups == 1,
            Layer::FullyConnected { .. } => true,
            Layer::Pool { kind: PoolKind::Max, .. } => true,
            Layer::Relu => true,
            Layer::Softmax => true,
            _ => false,
        }
    }

    /// Infer the output shape from input shapes (most layers are
    /// single-input; `Concat`/`Eltwise` take several).
    pub fn infer_shape(&self, inputs: &[&Shape]) -> Shape {
        let single = || -> &Shape {
            assert_eq!(inputs.len(), 1, "{} expects one input", self.kind());
            inputs[0]
        };
        match self {
            Layer::Input { shape } => {
                assert!(inputs.is_empty(), "input layer takes no inputs");
                shape.clone()
            }
            Layer::Conv { out_channels, kernel, stride, pad, groups } => {
                let s = single();
                let ic = s.extent(Dim::C);
                assert_eq!(ic % groups, 0, "channels {ic} not divisible by groups {groups}");
                assert_eq!(out_channels % groups, 0);
                s.with(Dim::C, *out_channels)
                    .with(Dim::H, conv_out(s.extent(Dim::H), kernel.0, *stride, *pad))
                    .with(Dim::W, conv_out(s.extent(Dim::W), kernel.1, *stride, *pad))
            }
            Layer::Conv3d { out_channels, kernel, stride, pad } => {
                let s = single();
                s.with(Dim::C, *out_channels)
                    .with(Dim::T, conv_out(s.extent(Dim::T), kernel.0, *stride, *pad))
                    .with(Dim::H, conv_out(s.extent(Dim::H), kernel.1, *stride, *pad))
                    .with(Dim::W, conv_out(s.extent(Dim::W), kernel.2, *stride, *pad))
            }
            Layer::FullyConnected { out_features } => {
                let s = single();
                Shape::new(&[(Dim::B, s.extent(Dim::B)), (Dim::C, *out_features)])
            }
            Layer::Pool { kernel, stride, pad, .. } => {
                let s = single();
                s.with(Dim::H, pool_out(s.extent(Dim::H), *kernel, *stride, *pad))
                    .with(Dim::W, pool_out(s.extent(Dim::W), *kernel, *stride, *pad))
            }
            Layer::GlobalAvgPool => {
                let s = single();
                s.with(Dim::H, 1).with(Dim::W, 1)
            }
            Layer::Pool3d { kernel, stride, .. } => {
                let s = single();
                s.with(Dim::T, pool_out(s.extent(Dim::T), kernel.0, stride.0, 0))
                    .with(Dim::H, pool_out(s.extent(Dim::H), kernel.1, stride.1, 0))
                    .with(Dim::W, pool_out(s.extent(Dim::W), kernel.2, stride.2, 0))
            }
            Layer::Relu
            | Layer::Sigmoid
            | Layer::Softmax
            | Layer::Lrn { .. }
            | Layer::BatchNorm
            | Layer::Scale
            | Layer::Dropout => single().clone(),
            Layer::Concat => {
                assert!(!inputs.is_empty());
                let base = inputs[0];
                let mut c = 0;
                for s in inputs {
                    assert_eq!(s.extent(Dim::H), base.extent(Dim::H), "concat H mismatch");
                    assert_eq!(s.extent(Dim::W), base.extent(Dim::W), "concat W mismatch");
                    c += s.extent(Dim::C);
                }
                base.with(Dim::C, c)
            }
            Layer::Eltwise => {
                assert!(!inputs.is_empty());
                for s in inputs {
                    assert_eq!(*s, inputs[0], "eltwise shape mismatch");
                }
                inputs[0].clone()
            }
            Layer::RoiPool { num_rois, output } => {
                let s = single();
                // RoIs become the batch dimension of the pooled output
                // (Caffe semantics: N = #rois).
                Shape::new(&[
                    (Dim::B, s.extent(Dim::B) * num_rois),
                    (Dim::C, s.extent(Dim::C)),
                    (Dim::H, output.0),
                    (Dim::W, output.1),
                ])
            }
            Layer::Proposal { anchors } => {
                let s = single();
                // 4 regressed coordinates per anchor per position.
                Shape::new(&[
                    (Dim::B, s.extent(Dim::B)),
                    (Dim::C, anchors * 4),
                    (Dim::H, s.extent(Dim::H)),
                    (Dim::W, s.extent(Dim::W)),
                ])
            }
            Layer::PrimaryCaps { caps_channels, vec, kernel, stride } => {
                let s = single();
                Shape::new(&[
                    (Dim::B, s.extent(Dim::B)),
                    (Dim::C, *caps_channels),
                    (Dim::H, conv_out(s.extent(Dim::H), *kernel, *stride, 0)),
                    (Dim::W, conv_out(s.extent(Dim::W), *kernel, *stride, 0)),
                    (Dim::V, *vec),
                ])
            }
            Layer::DigitCaps { out_caps, out_vec, .. } => {
                let s = single();
                Shape::new(&[(Dim::B, s.extent(Dim::B)), (Dim::C, *out_caps), (Dim::V, *out_vec)])
            }
        }
    }

    /// Number of trainable parameters given the input shapes.
    pub fn param_count(&self, inputs: &[&Shape]) -> usize {
        match self {
            Layer::Conv { out_channels, kernel, groups, .. } => {
                let ic = inputs[0].extent(Dim::C);
                kernel.0 * kernel.1 * (ic / groups) * out_channels + out_channels
            }
            Layer::Conv3d { out_channels, kernel, .. } => {
                let ic = inputs[0].extent(Dim::C);
                kernel.0 * kernel.1 * kernel.2 * ic * out_channels + out_channels
            }
            Layer::FullyConnected { out_features } => {
                let in_features = inputs[0].elements() / inputs[0].extent(Dim::B);
                in_features * out_features + out_features
            }
            Layer::BatchNorm => 2 * inputs[0].extent(Dim::C),
            Layer::Scale => 2 * inputs[0].extent(Dim::C),
            Layer::PrimaryCaps { caps_channels, vec, kernel, .. } => {
                let ic = inputs[0].extent(Dim::C);
                kernel * kernel * ic * caps_channels * vec
            }
            Layer::DigitCaps { out_caps, out_vec, .. } => {
                let s = inputs[0];
                let in_caps =
                    s.extent(Dim::C) * s.extent(Dim::H) * s.extent(Dim::W) * s.extent(Dim::T);
                let in_vec = s.extent(Dim::V);
                in_caps * in_vec * out_caps * out_vec
            }
            _ => 0,
        }
    }
}

/// Output extent of a convolution along one axis.
pub fn conv_out(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    assert!(input + 2 * pad >= kernel, "kernel {kernel} larger than padded input {input}+2*{pad}");
    (input + 2 * pad - kernel) / stride + 1
}

/// Output extent of pooling along one axis (Caffe rounds *up*).
pub fn pool_out(input: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    assert!(input + 2 * pad >= kernel);
    (input + 2 * pad - kernel).div_ceil(stride) + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img(c: usize, hw: usize) -> Shape {
        Shape::bchw(32, c, hw, hw)
    }

    #[test]
    fn conv_shape_alexnet_conv1() {
        // AlexNet conv1: 96 kernels 11x11 stride 4 on 3x227x227.
        let out = Layer::Conv { out_channels: 96, kernel: (11, 11), stride: 4, pad: 0, groups: 1 }
            .infer_shape(&[&img(3, 227)]);
        assert_eq!(out, Shape::bchw(32, 96, 55, 55));
    }

    #[test]
    fn depthwise_conv_shape() {
        let out = Layer::Conv { out_channels: 32, kernel: (3, 3), stride: 1, pad: 1, groups: 32 }
            .infer_shape(&[&img(32, 112)]);
        assert_eq!(out, Shape::bchw(32, 32, 112, 112));
    }

    #[test]
    fn pool_rounds_up() {
        // AlexNet pool: 3x3 stride 2 on 55x55 -> 27x27.
        let out = Layer::Pool { kind: PoolKind::Max, kernel: 3, stride: 2, pad: 0 }
            .infer_shape(&[&img(96, 55)]);
        assert_eq!(out.extent(Dim::H), 27);
    }

    #[test]
    fn fc_flattens() {
        let out =
            Layer::FullyConnected { out_features: 4096 }.infer_shape(&[&Shape::bchw(32, 256, 6, 6)]);
        assert_eq!(out, Shape::new(&[(Dim::B, 32), (Dim::C, 4096)]));
    }

    #[test]
    fn concat_sums_channels() {
        let a = img(64, 28);
        let b = img(32, 28);
        let out = Layer::Concat.infer_shape(&[&a, &b]);
        assert_eq!(out.extent(Dim::C), 96);
    }

    #[test]
    fn conv3d_shape() {
        let inp = Shape::bcthw(8, 3, 16, 112, 112);
        let out = Layer::Conv3d { out_channels: 64, kernel: (3, 3, 3), stride: 1, pad: 1 }
            .infer_shape(&[&inp]);
        assert_eq!(out, Shape::bcthw(8, 64, 16, 112, 112));
    }

    #[test]
    fn primary_caps_adds_vector_dim() {
        let inp = Shape::bchw(16, 256, 20, 20);
        let out = Layer::PrimaryCaps { caps_channels: 32, vec: 8, kernel: 9, stride: 2 }
            .infer_shape(&[&inp]);
        assert_eq!(out.extent(Dim::V), 8);
        assert_eq!(out.extent(Dim::H), 6);
    }

    #[test]
    fn roi_pool_expands_batch() {
        let inp = Shape::bchw(1, 256, 14, 14);
        let out =
            Layer::RoiPool { num_rois: 300, output: (6, 6) }.infer_shape(&[&inp]);
        assert_eq!(out.extent(Dim::B), 300);
        assert_eq!(out.extent(Dim::H), 6);
    }

    #[test]
    fn traditional_classification() {
        assert!(Layer::Relu.is_traditional());
        assert!(Layer::Conv { out_channels: 8, kernel: (3, 3), stride: 1, pad: 1, groups: 1 }
            .is_traditional());
        assert!(!Layer::Conv { out_channels: 8, kernel: (3, 3), stride: 1, pad: 1, groups: 8 }
            .is_traditional());
        assert!(!Layer::BatchNorm.is_traditional());
        assert!(!Layer::Pool { kind: PoolKind::Avg, kernel: 2, stride: 2, pad: 0 }.is_traditional());
    }

    #[test]
    fn param_counts() {
        let inp = img(3, 227);
        let conv = Layer::Conv { out_channels: 96, kernel: (11, 11), stride: 4, pad: 0, groups: 1 };
        assert_eq!(conv.param_count(&[&inp]), 11 * 11 * 3 * 96 + 96);
        assert_eq!(Layer::BatchNorm.param_count(&[&img(64, 8)]), 128);
    }
}
