//! Network DAG with automatic shape inference.

use super::layer::Layer;
use super::tensor::Shape;

/// Index of a node in a [`Network`].
pub type NodeId = usize;

/// A layer instance in the network DAG.
#[derive(Clone, Debug)]
pub struct LayerNode {
    /// Stable identifier (index into [`Network::nodes`]).
    pub id: NodeId,
    /// Display name, e.g. `"conv1"`.
    pub name: String,
    /// The layer operation.
    pub layer: Layer,
    /// Producer nodes.
    pub inputs: Vec<NodeId>,
    /// Inferred output shape.
    pub output: Shape,
}

/// A CNN expressed as a DAG of layers in topological order (nodes are
/// appended after their producers, which the builder enforces).
#[derive(Clone, Debug, Default)]
pub struct Network {
    /// Network name, e.g. `"AlexNet"`.
    pub name: String,
    nodes: Vec<LayerNode>,
}

impl Network {
    /// Create an empty network.
    pub fn new(name: &str) -> Self {
        Network { name: name.to_string(), nodes: Vec::new() }
    }

    /// Append a layer fed by `inputs`; returns its id. Shapes are
    /// inferred eagerly so construction fails fast on bad wiring.
    pub fn add(&mut self, name: &str, layer: Layer, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "node {name}: input {i} not yet defined");
        }
        let input_shapes: Vec<&Shape> = inputs.iter().map(|&i| &self.nodes[i].output).collect();
        let output = layer.infer_shape(&input_shapes);
        self.nodes.push(LayerNode { id, name: name.to_string(), layer, inputs: inputs.to_vec(), output });
        id
    }

    /// All nodes in topological order.
    pub fn nodes(&self) -> &[LayerNode] {
        &self.nodes
    }

    /// Node by id.
    pub fn node(&self, id: NodeId) -> &LayerNode {
        &self.nodes[id]
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the network has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Input shapes of a node.
    pub fn input_shapes(&self, id: NodeId) -> Vec<&Shape> {
        self.nodes[id].inputs.iter().map(|&i| &self.nodes[i].output).collect()
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.layer.param_count(&self.input_shapes(n.id)))
            .sum()
    }

    /// Ids of nodes nothing consumes (network outputs).
    pub fn outputs(&self) -> Vec<NodeId> {
        let mut consumed = vec![false; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                consumed[i] = true;
            }
        }
        (0..self.nodes.len()).filter(|&i| !consumed[i]).collect()
    }

    /// Consumers of each node (inverse edges).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Dim, PoolKind};

    fn tiny() -> Network {
        let mut net = Network::new("tiny");
        let inp = net.add("data", Layer::Input { shape: Shape::bchw(4, 3, 8, 8) }, &[]);
        let c = net.add(
            "conv1",
            Layer::Conv { out_channels: 16, kernel: (3, 3), stride: 1, pad: 1, groups: 1 },
            &[inp],
        );
        let r = net.add("relu1", Layer::Relu, &[c]);
        net.add("pool1", Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 }, &[r]);
        net
    }

    #[test]
    fn shapes_propagate() {
        let net = tiny();
        assert_eq!(net.node(3).output.extent(Dim::H), 4);
        assert_eq!(net.node(1).output.extent(Dim::C), 16);
    }

    #[test]
    fn outputs_are_unconsumed_nodes() {
        let net = tiny();
        assert_eq!(net.outputs(), vec![3]);
    }

    #[test]
    fn consumers_inverse_edges() {
        let net = tiny();
        assert_eq!(net.consumers()[1], vec![2]);
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_reference_rejected() {
        let mut net = Network::new("bad");
        net.add("r", Layer::Relu, &[5]);
    }

    #[test]
    fn param_count_sums() {
        let net = tiny();
        assert_eq!(net.param_count(), 3 * 3 * 3 * 16 + 16);
    }
}
