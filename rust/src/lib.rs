//! # GCONV Chain
//!
//! Reproduction of *"Optimizing the Whole-life Cost in End-to-end CNN
//! Acceleration"* (Zhang, Chen, Ray, Li — 2021).
//!
//! The library converts end-to-end CNN computation (forward and backward)
//! into a chain of **general convolutions** (GCONV), auto-maps the chain
//! onto a parameterized accelerator model with a single loop-unrolling
//! algorithm (the paper's Algorithm 1), and evaluates performance, data
//! movement, energy and whole-life cost with the analytical model of
//! paper §4.2. The chain is also directly *executable*: the [`exec`]
//! engine interprets GCONV numerics in pure Rust.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`ir`] — layer-level network IR with shape inference.
//! * [`networks`] — the seven benchmark CNNs of the paper, plus
//!   spec-backed resolution (`networks::resolve`) so imported models
//!   and builder networks share every downstream path.
//! * [`frontend`] — model frontend: versioned JSON spec files with
//!   analyser-style shape/parameter inference (`frontend::spec` /
//!   `infer` / `build`), a network exporter (`frontend::export`) whose
//!   bundled `rust/specs/` files are the round-trip conformance
//!   oracle, and a self-contained JSON layer (`frontend::json`).
//! * [`gconv`] — the GCONV operation model and layer→GCONV lowering,
//!   including the special-execution entries (max-pool BP argmax
//!   routing, concatenation) and composed scalar pipelines written by
//!   executable fusion.
//! * [`exec`] — native execution engine: tensor type, tiered GCONV
//!   loop-nest interpreter (§3.1's four operators; GEMM/odometer/naive
//!   kernels, bind-time weight prepacking, and the opt-in
//!   `Precision::Fast` lane microkernel vs the default bit-exact
//!   path), special-op routines, parallel chain scheduler with
//!   up-front operand validation and buffer-pool trim policies,
//!   bind-once/run-many serving (`exec::serve`: pre-bound `Session`s,
//!   the chain-caching and request-coalescing `Engine`), seeded
//!   fault injection with named sites through the serving hot path
//!   (`exec::faults`), and the naive-vs-fast-vs-fused + serve bench
//!   harnesses.
//! * [`analysis`] — static chain auditor: proves operand coverage,
//!   parallel write disjointness, fusion legality, dataflow soundness
//!   and resource bounds over a lowered chain *without executing it*,
//!   or emits structured rule-id diagnostics. Wired into
//!   `SessionBuilder::build` (debug), `Engine::register_spec`, and the
//!   `audit` / `specs` CLI subcommands.
//! * [`obs`] — observability spine: lock-light metrics registry
//!   (counters, gauges, log-bucket latency histograms with
//!   nearest-rank p50/p99), disarmed-by-default kernel profiling
//!   hooks, monotonic span stamps, and export surfaces (Prometheus
//!   text exposition for the wire metrics frame, chrome://tracing
//!   JSON for `profile --trace-out`).
//! * [`accel`] — accelerator structures (Table 4) and baseline modes.
//! * [`mapping`] — Algorithm 1, consistent mapping, operation fusion
//!   (analytical *and* executable policies over shared legality).
//! * [`model`] — cycles (Eq. 6) and data movement (Eq. 7–10) models.
//! * [`energy`] — per-event energy and area/power overhead models.
//! * [`isa`] — the GCONV instruction encoding of Fig. 11.
//! * [`cost`] — development cost and total cost of ownership models.
//! * [`sim`] — the top-level simulator tying everything together.
//! * [`runtime`] — PJRT loader for AOT-compiled HLO-text artifacts
//!   (cargo feature `pjrt`).
//! * [`server`] — TCP serving front over `exec::serve::Engine`:
//!   length-prefixed binary protocol with hard frame caps, bounded
//!   submission queue with `BUSY` backpressure, per-connection read
//!   deadlines, graceful drain on shutdown, and a blocking client with
//!   jittered `BUSY` backoff. The driver doubles as a supervisor:
//!   panics are caught per wave, repeat offenders are quarantined, and
//!   a `health` frame exposes the counters + quarantine list.
//! * [`coordinator`] — batches request streams onto a pluggable
//!   execution backend (native by default, PJRT with `pjrt`).
//! * [`report`] — table/figure printers used by benches and the CLI.
//! * [`args`] — shared CLI flag helpers (`--threads` etc.).

pub mod accel;
pub mod analysis;
pub mod args;
pub mod coordinator;
pub mod cost;
pub mod energy;
pub mod exec;
pub mod frontend;
pub mod gconv;
pub mod ir;
pub mod isa;
pub mod mapping;
pub mod model;
pub mod networks;
pub mod obs;
pub mod prop;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod sim;
