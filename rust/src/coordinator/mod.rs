//! L3 coordinator: drives GCONV-chain *numerics* through the PJRT
//! runtime.
//!
//! The paper's contribution is the compiler + mapper + accelerator
//! model, so the execution driver is deliberately thin: it owns the
//! artifact lifecycle, batches incoming samples to the mini-batch size
//! the artifacts were lowered for, executes the compiled chain step, and
//! reports latency/throughput. Python is never on this path — the
//! artifacts are AOT-compiled HLO (see [`crate::runtime`]).

use crate::runtime::{literal_f32, to_vec_f32, Runtime};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// A single inference/training request: one flattened sample.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-assigned id.
    pub id: u64,
    /// Flattened sample data.
    pub data: Vec<f32>,
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Flattened output.
    pub data: Vec<f32>,
    /// Seconds spent queued + executing.
    pub latency_s: f64,
}

/// Run statistics of the executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Batches executed.
    pub batches: usize,
    /// Samples served.
    pub samples: usize,
    /// Total execution seconds.
    pub exec_s: f64,
    /// Mean per-sample latency.
    pub mean_latency_s: f64,
}

impl ExecStats {
    /// Samples per second across the run.
    pub fn throughput(&self) -> f64 {
        if self.exec_s == 0.0 {
            0.0
        } else {
            self.samples as f64 / self.exec_s
        }
    }
}

/// Batching executor for one compiled chain artifact.
///
/// The artifact takes `(x, w...)` where `x` is `[batch, sample_len]`-
/// reshaped input and returns a tuple whose first element is the output
/// batch; extra weight tensors are bound once at construction.
pub struct ChainExecutor {
    runtime: Runtime,
    artifact: String,
    batch: usize,
    sample_len: usize,
    out_len: usize,
    weights: Vec<xla::Literal>,
    input_dims: Vec<i64>,
    queue: VecDeque<(Request, Instant)>,
    stats: ExecStats,
    latency_acc: f64,
}

impl ChainExecutor {
    /// Create an executor for `artifact` in `artifact_dir`.
    ///
    /// `input_dims` is the full batched input shape (first dim = batch);
    /// `out_len` the per-sample output length; `weights` any additional
    /// parameter tensors the artifact expects after the input.
    pub fn new(
        artifact_dir: &str,
        artifact: &str,
        input_dims: &[i64],
        out_len: usize,
        weights: Vec<xla::Literal>,
    ) -> Result<Self> {
        let mut runtime = Runtime::cpu(artifact_dir)?;
        runtime.load(artifact).with_context(|| format!("loading {artifact}"))?;
        let batch = input_dims[0] as usize;
        let sample_len: usize =
            input_dims[1..].iter().map(|&d| d as usize).product();
        Ok(ChainExecutor {
            runtime,
            artifact: artifact.to_string(),
            batch,
            sample_len,
            out_len,
            weights,
            input_dims: input_dims.to_vec(),
            queue: VecDeque::new(),
            stats: ExecStats::default(),
            latency_acc: 0.0,
        })
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        anyhow::ensure!(
            req.data.len() == self.sample_len,
            "sample length {} != expected {}",
            req.data.len(),
            self.sample_len
        );
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    /// Pending queue depth.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Execute one full (or padded, if `flush`) batch; returns responses
    /// in submission order. Returns an empty vec when not enough work is
    /// queued and `flush` is false (the dynamic-batching policy: wait
    /// for a full batch unless flushing).
    pub fn step(&mut self, flush: bool) -> Result<Vec<Response>> {
        if self.queue.is_empty() || (!flush && self.queue.len() < self.batch) {
            return Ok(Vec::new());
        }
        let take = self.queue.len().min(self.batch);
        let mut batch_data = Vec::with_capacity(self.batch * self.sample_len);
        let mut meta = Vec::with_capacity(take);
        for _ in 0..take {
            let (req, t0) = self.queue.pop_front().expect("non-empty");
            batch_data.extend_from_slice(&req.data);
            meta.push((req.id, t0));
        }
        // Pad the final partial batch with zeros.
        batch_data.resize(self.batch * self.sample_len, 0.0);

        let x = literal_f32(&batch_data, &self.input_dims)?;
        let mut inputs = vec![x];
        for w in &self.weights {
            // Literals are cheap client-side handles; re-reshape clones.
            inputs.push(w.reshape(&shape_of(w)?)?);
        }
        let t_exec = Instant::now();
        let outputs = self.runtime.execute(&self.artifact, &inputs)?;
        let exec_s = t_exec.elapsed().as_secs_f64();
        let out = to_vec_f32(&outputs[0])?;

        let mut responses = Vec::with_capacity(take);
        for (i, (id, t0)) in meta.into_iter().enumerate() {
            let start = i * self.out_len;
            let latency = t0.elapsed().as_secs_f64();
            self.latency_acc += latency;
            responses.push(Response {
                id,
                data: out[start..start + self.out_len].to_vec(),
                latency_s: latency,
            });
        }
        self.stats.batches += 1;
        self.stats.samples += take;
        self.stats.exec_s += exec_s;
        self.stats.mean_latency_s = self.latency_acc / self.stats.samples as f64;
        Ok(responses)
    }

    /// Drain the queue completely.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.step(true)?);
        }
        Ok(all)
    }

    /// Run statistics.
    pub fn stats(&self) -> ExecStats {
        self.stats
    }
}

/// Dims of a literal's array shape.
fn shape_of(l: &xla::Literal) -> Result<Vec<i64>> {
    let shape = l.shape()?;
    match shape {
        xla::Shape::Array(a) => Ok(a.dims().to_vec()),
        _ => anyhow::bail!("expected array literal"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_throughput() {
        let s = ExecStats { batches: 2, samples: 8, exec_s: 2.0, mean_latency_s: 0.1 };
        assert_eq!(s.throughput(), 4.0);
    }

    #[test]
    fn zero_time_throughput_is_zero() {
        assert_eq!(ExecStats::default().throughput(), 0.0);
    }
}
