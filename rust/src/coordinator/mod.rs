//! L3 coordinator: batches incoming requests and drives GCONV-chain
//! *numerics* through a pluggable execution backend.
//!
//! The paper's contribution is the compiler + mapper + accelerator
//! model, so the execution driver is deliberately thin: it batches
//! incoming samples to the mini-batch size the chain was lowered for,
//! executes one chain step per batch, and reports latency/throughput.
//! Where the numbers come from is a [`Backend`] decision:
//!
//! * [`NativeBackend`] (default, pure Rust) — interprets the lowered
//!   [`GconvChain`] directly with [`crate::exec`]; no Python, no XLA,
//!   no artifacts.
//! * `PjrtBackend` (cargo feature `pjrt`) — executes AOT-compiled
//!   HLO-text artifacts on the PJRT CPU client via [`crate::runtime`].
//!
//! Both sit behind the same submit/step/drain API, so callers never
//! know which engine served them.

use crate::exec::{RunReport, Session, Tensor};
use crate::gconv::chain::GconvChain;
use crate::gconv::lower::{lower_network, Mode};
use crate::ir::{Layer, Network};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// A single inference/training request: one flattened sample.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-assigned id.
    pub id: u64,
    /// Flattened sample data.
    pub data: Vec<f32>,
}

/// A completed response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Flattened output.
    pub data: Vec<f32>,
    /// Seconds spent queued + executing.
    pub latency_s: f64,
}

/// Run statistics of the executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Batches executed.
    pub batches: usize,
    /// Samples served.
    pub samples: usize,
    /// Total execution seconds.
    pub exec_s: f64,
    /// Mean per-sample latency.
    pub mean_latency_s: f64,
}

impl ExecStats {
    /// Samples per second across the run.
    pub fn throughput(&self) -> f64 {
        if self.exec_s == 0.0 {
            0.0
        } else {
            self.samples as f64 / self.exec_s
        }
    }
}

/// An execution engine the coordinator can batch requests onto.
///
/// A backend owns one compiled/lowered chain, fixed at a mini-batch
/// size; [`Backend::execute`] consumes one full batch of flattened
/// samples (`batch() * sample_len()` values, zero-padded by the caller
/// when flushing a partial batch) and returns `batch() * out_len()`
/// output values in the same sample order.
pub trait Backend {
    /// Human-readable engine name (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;
    /// Mini-batch size the chain was lowered/compiled for.
    fn batch(&self) -> usize;
    /// Flattened per-sample input length.
    fn sample_len(&self) -> usize;
    /// Flattened per-sample output length.
    fn out_len(&self) -> usize;
    /// Execute one full batch (takes ownership — the native backend
    /// wraps the buffer into a tensor without copying).
    fn execute(&mut self, batch_data: Vec<f32>) -> Result<Vec<f32>>;
}

/// Pure-Rust backend: serves the lowered GCONV chain through a
/// bind-once/run-many [`crate::exec::Session`] — operand validation,
/// reachability and every entry's plan binding happen once at
/// construction, and each batch execution only runs the pre-bound
/// chain. Missing weights are synthesized deterministically (provide
/// real ones with [`NativeBackend::set_weights`]).
pub struct NativeBackend {
    session: Session,
    input_name: String,
    input_dims: Vec<usize>,
    batch: usize,
    sample_len: usize,
    out_len: usize,
    last_report: Option<RunReport>,
}

impl NativeBackend {
    /// Build a backend for `chain`, reading its network input from the
    /// external operand `input_name` with shape `input_dims`
    /// (`input_dims[0]` is the mini-batch size). The chain's last entry
    /// is taken as the network output; see [`NativeBackend::with_output`].
    pub fn new(chain: GconvChain, input_name: &str, input_dims: &[usize]) -> Result<Self> {
        anyhow::ensure!(!chain.is_empty(), "cannot execute an empty chain");
        anyhow::ensure!(
            !input_dims.is_empty() && input_dims.iter().all(|&d| d > 0),
            "bad input shape {input_dims:?}"
        );
        // The chain must actually read this operand — otherwise
        // submitted samples would be silently ignored in favour of
        // synthesized data.
        let input_ref = crate::gconv::op::DataRef::External(input_name.to_string());
        anyhow::ensure!(
            chain.entries().iter().any(|e| {
                e.op.input == input_ref || e.op.kernel.as_ref() == Some(&input_ref)
            }),
            "no chain entry consumes external operand {input_name:?}"
        );
        let batch = input_dims[0];
        let sample_len: usize = input_dims[1..].iter().product();
        let output_entry = chain.len() - 1;
        let out_total = chain.entries()[output_entry].op.output_elements();
        anyhow::ensure!(
            out_total % batch == 0,
            "output of entry #{output_entry} ({out_total} elements) does not split into \
             batch {batch}"
        );
        // Freeze the serving session: the zero placeholder fixes the
        // input extents every request must match, and every entry's
        // plan binds now, not per batch.
        let session = Session::builder(chain)
            .wanted(&[output_entry])
            .input(input_name, Tensor::zeros(input_dims))
            .build()?;
        Ok(NativeBackend {
            session,
            input_name: input_name.to_string(),
            input_dims: input_dims.to_vec(),
            batch,
            sample_len,
            out_len: out_total / batch,
            last_report: None,
        })
    }

    /// Lower `net` for inference and build a backend for it. The input
    /// operand name and shape are taken from the network's `Input`
    /// layer (`"<name>.data"`, as emitted by the lowering).
    pub fn for_network(net: &Network) -> Result<Self> {
        let input = net
            .nodes()
            .iter()
            .find(|n| matches!(n.layer, Layer::Input { .. }))
            .context("network has no Input layer")?;
        let dims: Vec<usize> = input.output.iter().map(|(_, n)| n).collect();
        let name = format!("{}.data", input.name);
        NativeBackend::new(lower_network(net, Mode::Inference), &name, &dims)
    }

    /// Use entry `i`'s output as the network output instead of the
    /// last chain entry. The session is rebuilt around the new wanted
    /// set (the pre-computed schedule depends on it), keeping every
    /// operand tensor — including weights provided via
    /// [`NativeBackend::set_weights`] — intact.
    pub fn with_output(mut self, i: usize) -> Result<Self> {
        anyhow::ensure!(i < self.session.chain().len(), "entry #{i} out of range");
        let out_total = self.session.chain().entries()[i].op.output_elements();
        anyhow::ensure!(
            out_total % self.batch == 0,
            "output of entry #{i} ({out_total} elements) does not split into batch {}",
            self.batch
        );
        self.session = self.session.with_wanted(&[i])?;
        self.out_len = out_total / self.batch;
        Ok(self)
    }

    /// Provide real trained parameters for a layer (by lowering name).
    /// The element count must match the bound layout.
    pub fn set_weights(&mut self, name: &str, t: Tensor) -> Result<()> {
        self.session.set_weights(name, t)
    }

    /// Per-entry timing of the most recent batch execution.
    pub fn last_report(&self) -> Option<&RunReport> {
        self.last_report.as_ref()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn sample_len(&self) -> usize {
        self.sample_len
    }

    fn out_len(&self) -> usize {
        self.out_len
    }

    fn execute(&mut self, batch_data: Vec<f32>) -> Result<Vec<f32>> {
        let t = Tensor::new(&self.input_dims, batch_data)?;
        self.session.set_input(&self.input_name, t)?;
        let mut report = self.session.run()?;
        // Outputs are Arc-shared with the session; the requested entry
        // is uniquely owned after the run, so this unwrap moves the
        // buffer out without copying (the fallback clone only triggers
        // if a caller-visible Arc is still alive, which `run` precludes
        // for a single wanted entry).
        let out = match std::sync::Arc::try_unwrap(report.outputs.remove(0)) {
            Ok(t) => t.into_data(),
            Err(shared) => shared.data().to_vec(),
        };
        self.last_report = Some(report);
        anyhow::ensure!(
            out.len() == self.batch * self.out_len,
            "backend produced {} values, expected {}",
            out.len(),
            self.batch * self.out_len
        );
        Ok(out)
    }
}

/// PJRT backend for one compiled chain artifact (cargo feature `pjrt`).
///
/// The artifact takes `(x, w...)` where `x` is `[batch, sample_len]`-
/// reshaped input and returns a tuple whose first element is the output
/// batch; extra weight tensors are bound once at construction.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    runtime: crate::runtime::Runtime,
    artifact: String,
    batch: usize,
    sample_len: usize,
    out_len: usize,
    weights: Vec<xla::Literal>,
    input_dims: Vec<i64>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    /// Create a backend for `artifact` in `artifact_dir`.
    ///
    /// `input_dims` is the full batched input shape (first dim = batch);
    /// `out_len` the per-sample output length; `weights` any additional
    /// parameter tensors the artifact expects after the input.
    pub fn new(
        artifact_dir: &str,
        artifact: &str,
        input_dims: &[i64],
        out_len: usize,
        weights: Vec<xla::Literal>,
    ) -> Result<Self> {
        let mut runtime = crate::runtime::Runtime::cpu(artifact_dir)?;
        runtime.load(artifact).with_context(|| format!("loading {artifact}"))?;
        let batch = input_dims[0] as usize;
        let sample_len: usize = input_dims[1..].iter().map(|&d| d as usize).product();
        Ok(PjrtBackend {
            runtime,
            artifact: artifact.to_string(),
            batch,
            sample_len,
            out_len,
            weights,
            input_dims: input_dims.to_vec(),
        })
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn sample_len(&self) -> usize {
        self.sample_len
    }

    fn out_len(&self) -> usize {
        self.out_len
    }

    fn execute(&mut self, batch_data: Vec<f32>) -> Result<Vec<f32>> {
        let x = crate::runtime::literal_f32(&batch_data, &self.input_dims)?;
        let mut inputs = vec![x];
        for w in &self.weights {
            // Literals are cheap client-side handles; re-reshape clones.
            inputs.push(w.reshape(&shape_of(w)?)?);
        }
        let outputs = self.runtime.execute(&self.artifact, &inputs)?;
        crate::runtime::to_vec_f32(&outputs[0])
    }
}

/// Dims of a literal's array shape.
#[cfg(feature = "pjrt")]
fn shape_of(l: &xla::Literal) -> Result<Vec<i64>> {
    let shape = l.shape()?;
    match shape {
        xla::Shape::Array(a) => Ok(a.dims().to_vec()),
        _ => anyhow::bail!("expected array literal"),
    }
}

/// Batching executor over one [`Backend`].
///
/// Incoming [`Request`]s queue until a full mini-batch is available
/// (or the caller flushes), then execute as one chain step.
pub struct ChainExecutor {
    backend: Box<dyn Backend>,
    queue: VecDeque<(Request, Instant)>,
    stats: ExecStats,
    latency_acc: f64,
}

impl ChainExecutor {
    /// Wrap an arbitrary backend.
    pub fn with_backend(backend: Box<dyn Backend>) -> Self {
        ChainExecutor {
            backend,
            queue: VecDeque::new(),
            stats: ExecStats::default(),
            latency_acc: 0.0,
        }
    }

    /// Native executor for a lowered chain (see [`NativeBackend::new`]).
    pub fn native(chain: GconvChain, input_name: &str, input_dims: &[usize]) -> Result<Self> {
        Ok(Self::with_backend(Box::new(NativeBackend::new(chain, input_name, input_dims)?)))
    }

    /// Native executor for a network (lowered for inference).
    pub fn for_network(net: &Network) -> Result<Self> {
        Ok(Self::with_backend(Box::new(NativeBackend::for_network(net)?)))
    }

    /// PJRT executor for a compiled artifact (kept signature-compatible
    /// with the pre-`Backend` API; see [`PjrtBackend::new`]).
    #[cfg(feature = "pjrt")]
    pub fn new(
        artifact_dir: &str,
        artifact: &str,
        input_dims: &[i64],
        out_len: usize,
        weights: Vec<xla::Literal>,
    ) -> Result<Self> {
        Ok(Self::with_backend(Box::new(PjrtBackend::new(
            artifact_dir,
            artifact,
            input_dims,
            out_len,
            weights,
        )?)))
    }

    /// Name of the engine serving this executor.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Flattened per-sample input length the backend expects.
    pub fn sample_len(&self) -> usize {
        self.backend.sample_len()
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        anyhow::ensure!(
            req.data.len() == self.backend.sample_len(),
            "sample length {} != expected {}",
            req.data.len(),
            self.backend.sample_len()
        );
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    /// Pending queue depth.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Execute one full (or padded, if `flush`) batch; returns responses
    /// in submission order. Returns an empty vec when not enough work is
    /// queued and `flush` is false (the dynamic-batching policy: wait
    /// for a full batch unless flushing).
    ///
    /// A flushed partial batch is zero-padded to the chain's mini-batch
    /// size. For chains with cross-sample ops — BatchNorm reduces over
    /// the batch dimension even in FP (the lowering computes batch
    /// statistics, Table 2) — the padding participates in those
    /// reductions, so a sample's result can depend on how full its
    /// batch was; chains of purely per-sample ops are unaffected.
    pub fn step(&mut self, flush: bool) -> Result<Vec<Response>> {
        let (batch, sample_len, out_len) =
            (self.backend.batch(), self.backend.sample_len(), self.backend.out_len());
        if self.queue.is_empty() || (!flush && self.queue.len() < batch) {
            return Ok(Vec::new());
        }
        let take = self.queue.len().min(batch);
        let mut batch_data = Vec::with_capacity(batch * sample_len);
        let mut meta = Vec::with_capacity(take);
        for _ in 0..take {
            let (req, t0) = self.queue.pop_front().expect("non-empty");
            batch_data.extend_from_slice(&req.data);
            meta.push((req.id, t0));
        }
        // Pad the final partial batch with zeros.
        batch_data.resize(batch * sample_len, 0.0);

        let t_exec = Instant::now();
        let out = self.backend.execute(batch_data)?;
        let exec_s = t_exec.elapsed().as_secs_f64();
        anyhow::ensure!(
            out.len() >= take * out_len,
            "backend returned {} values for {} samples of {}",
            out.len(),
            take,
            out_len
        );

        let mut responses = Vec::with_capacity(take);
        for (i, (id, t0)) in meta.into_iter().enumerate() {
            let start = i * out_len;
            let latency = t0.elapsed().as_secs_f64();
            self.latency_acc += latency;
            responses.push(Response {
                id,
                data: out[start..start + out_len].to_vec(),
                latency_s: latency,
            });
        }
        self.stats.batches += 1;
        self.stats.samples += take;
        self.stats.exec_s += exec_s;
        self.stats.mean_latency_s = self.latency_acc / self.stats.samples as f64;
        Ok(responses)
    }

    /// Drain the queue completely.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.step(true)?);
        }
        Ok(all)
    }

    /// Run statistics.
    pub fn stats(&self) -> ExecStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::chain::{ChainEntry, Phase};
    use crate::gconv::op::{DataRef, DimParams, GconvOp, MainOp, PostOp, PreOp, ReduceOp};
    use crate::ir::Dim;

    #[test]
    fn stats_throughput() {
        let s = ExecStats { batches: 2, samples: 8, exec_s: 2.0, mean_latency_s: 0.1 };
        assert_eq!(s.throughput(), 4.0);
    }

    #[test]
    fn zero_time_throughput_is_zero() {
        assert_eq!(ExecStats::default().throughput(), 0.0);
    }

    /// One batched ReLU entry: batch 2, 4 features.
    fn relu_chain() -> GconvChain {
        let mut c = GconvChain::new("relu");
        c.push(ChainEntry::new(
            GconvOp {
                name: "relu.fp".into(),
                dims: vec![(Dim::B, DimParams::opc(2)), (Dim::C, DimParams::opc(4))],
                pre: PreOp::None,
                main: MainOp::Pass,
                reduce: ReduceOp::None,
                post: PostOp::Lut("relu"),
                input: DataRef::External("x".into()),
                kernel: None,
            },
            0,
            true,
            Phase::Fp,
        ));
        c
    }

    #[test]
    fn native_executor_serves_batches_in_order() {
        let mut exec = ChainExecutor::native(relu_chain(), "x", &[2, 4]).unwrap();
        assert_eq!(exec.backend_name(), "native");
        for id in 0..2 {
            let sign = if id == 0 { 1.0 } else { -1.0 };
            exec.submit(Request { id, data: vec![sign; 4] }).unwrap();
        }
        let out = exec.step(false).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].data, vec![1.0; 4]);
        assert_eq!(out[1].data, vec![0.0; 4]);
        assert_eq!(exec.stats().samples, 2);
    }

    #[test]
    fn native_executor_rejects_bad_sample_length() {
        let mut exec = ChainExecutor::native(relu_chain(), "x", &[2, 4]).unwrap();
        assert!(exec.submit(Request { id: 0, data: vec![0.0; 3] }).is_err());
        assert_eq!(exec.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_unless_flushed() {
        let mut exec = ChainExecutor::native(relu_chain(), "x", &[2, 4]).unwrap();
        exec.submit(Request { id: 7, data: vec![2.0; 4] }).unwrap();
        assert!(exec.step(false).unwrap().is_empty());
        assert_eq!(exec.pending(), 1);
        let out = exec.drain().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[0].data, vec![2.0; 4]);
        assert_eq!(exec.pending(), 0);
    }
}
