//! Consistent mapping via unrolling-loop exchange (paper §4.3, Fig. 10).
//!
//! The producer's output-bandwidth spatial axis determines how the
//! intermediate tensor is laid out in the global buffer; the consumer's
//! innermost temporal loops determine the order it wants to read it. If
//! the two disagree, only one word can be loaded per bus cycle; after a
//! loop exchange (which leaves Eq. (6) cycles and Eq. (10) movement
//! untouched — products commute) the consumer streams at full width.

use super::unroll::Mapping;
use crate::ir::Dim;

/// Dimension order of the producer's output format: the dims of its
/// output-writing spatial axis (the last axis: px in Eyeriss), innermost
/// first, followed by its temporal output loops.
pub fn output_format(m: &Mapping) -> Vec<Dim> {
    let mut dims = Vec::new();
    if let Some(last_axis) = m.spatial.last() {
        for e in last_axis {
            if !dims.contains(&e.dim) {
                dims.push(e.dim);
            }
        }
    }
    for e in &m.temporal {
        if !dims.contains(&e.dim) {
            dims.push(e.dim);
        }
    }
    dims
}

/// The dimension the consumer's innermost input-touching temporal loop
/// walks — the order it wants the intermediate data in.
pub fn input_format(m: &Mapping) -> Option<Dim> {
    m.temporal
        .iter()
        .find(|e| {
            use crate::gconv::op::Param;
            matches!(e.param, Param::Ks | Param::Opc | Param::G)
        })
        .map(|e| e.dim)
}

/// Is consumer `cons` consistent with producer `prod`?
pub fn is_consistent(prod: &Mapping, cons: &Mapping) -> bool {
    match (output_format(prod).first(), input_format(cons)) {
        (Some(p), Some(c)) => *p == c,
        // Nothing to disagree about.
        _ => true,
    }
}

/// Can the producer/consumer pair be made consistent by a loop exchange
/// (§4.3)? The exchange itself happens at instruction generation and is
/// movement-neutral — "the unrolling loop exchange does not affect the
/// performance or data movement based on Equations (6) and (10) but
/// significantly reduces the loading time" — so the analytical model
/// only needs to know whether a legal exchange *exists*:
///
/// 1. the consumer has *some* input-touching temporal loop in the
///    producer's leading output dimension (exchange it innermost), or
/// 2. the producer's output axis carries the consumer's wanted dimension
///    (exchange on the producer side).
pub fn make_consistent(prod: &Mapping, cons: &Mapping) -> bool {
    if is_consistent(prod, cons) {
        return true;
    }
    let Some(&want) = output_format(prod).first() else {
        return true;
    };
    use crate::gconv::op::Param;
    // Consumer-side exchange opportunity.
    if cons
        .temporal
        .iter()
        .any(|e| e.dim == want && matches!(e.param, Param::Ks | Param::Opc | Param::G))
    {
        return true;
    }
    // Producer-side exchange opportunity.
    if let Some(have) = input_format(cons) {
        if let Some(last_axis) = prod.spatial.last() {
            if last_axis.iter().any(|e| e.dim == have) {
                return true;
            }
        }
        if prod.temporal.iter().any(|e| e.dim == have) {
            return true;
        }
    }
    false
}

/// Loading parallelism of a consumer given consistency: the full input
/// bus when consistent, degraded otherwise. On Eyeriss's narrow bus the
/// degradation reaches a single word per cycle (Fig. 10(d): "only one
/// input is loaded into ILS per cycle"); the paper measures the
/// consistent-mapping benefit at "up to 3.9×" (§4.3), so the penalty is
/// capped at 4× — wider structures reorder part of the stream in the
/// global buffer.
pub fn load_parallelism(consistent: bool, bus_width: usize) -> f64 {
    if consistent {
        bus_width as f64
    } else {
        (bus_width as f64 / 4.0).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::eyeriss;
    use crate::gconv::op::{DataRef, DimParams, GconvOp};
    use crate::mapping::unroll::{map_gconv, MapMode};

    fn relu_like(n: usize) -> GconvOp {
        GconvOp {
            name: "relu".into(),
            dims: vec![
                (Dim::B, DimParams::opc(8)),
                (Dim::C, DimParams::opc(n)),
                (Dim::H, DimParams::opc(28)),
                (Dim::W, DimParams::opc(28)),
            ],
            pre: crate::gconv::op::PreOp::None,
            main: crate::gconv::op::MainOp::Pass,
            reduce: crate::gconv::op::ReduceOp::None,
            post: crate::gconv::op::PostOp::Lut("relu"),
            input: DataRef::External("x".into()),
            kernel: None,
        }
    }

    fn conv_like() -> GconvOp {
        GconvOp::conv(
            "conv",
            vec![
                (Dim::B, DimParams::opc(8)),
                (Dim::C, DimParams { nop: 32, nks: 16, ..Default::default() }),
                (Dim::H, DimParams::window(28, 3, 1, 1)),
                (Dim::W, DimParams::window(28, 3, 1, 1)),
            ],
            DataRef::Gconv(0),
            DataRef::Weights("w".into()),
        )
    }

    #[test]
    fn exchange_establishes_consistency() {
        // A conv consumer always has sliding-window temporal loops in the
        // classic dims, so an exchange opportunity must exist whatever
        // dimension the element-wise producer leads with.
        let accel = eyeriss();
        let prod = map_gconv(&relu_like(16), &accel, MapMode::Gconv);
        let cons = map_gconv(&conv_like(), &accel, MapMode::Gconv);
        assert!(make_consistent(&prod, &cons));
    }

    #[test]
    fn feasibility_check_mutates_nothing() {
        // The exchange is movement-neutral and performed at instruction
        // generation; the analytical mappings stay untouched.
        let accel = eyeriss();
        let op = conv_like();
        let prod = map_gconv(&relu_like(16), &accel, MapMode::Gconv);
        let cons = map_gconv(&op, &accel, MapMode::Gconv);
        let cyc_before = crate::model::cycles::compute_cycles(&op, &cons);
        let iters_before = cons.temporal_iterations();
        make_consistent(&prod, &cons);
        assert_eq!(crate::model::cycles::compute_cycles(&op, &cons), cyc_before);
        assert_eq!(cons.temporal_iterations(), iters_before);
    }

    #[test]
    fn load_parallelism_degrades_when_inconsistent() {
        assert_eq!(load_parallelism(true, 4), 4.0);
        assert_eq!(load_parallelism(false, 4), 1.0);
    }
}
