//! GCONV mapping: Algorithm 1 (loop unrolling onto an accelerator),
//! consistent mapping (loop exchange, §4.3) and operation fusion (§4.3).

pub mod consistent;
pub mod fusion;
pub mod unroll;

pub use consistent::{is_consistent, load_parallelism, make_consistent};
pub use fusion::{fuse_chain, fuse_chain_with, fuse_executable, FusePolicy, FusionStats};
pub use unroll::{map_gconv, MapMode, Mapping, UnrollEntry};
