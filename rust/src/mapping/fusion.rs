//! Operation fusion (paper §4.3).
//!
//! GCONVs with no `reduce` operator are absorbed into the `pre`, `post`
//! or `main` operator of their consumer or producer, shortening the
//! chain (up to 30% in the paper) and eliminating the intermediate
//! tensor's round trip through the global buffer (up to 63% input
//! movement). Fusing into the producer's `post` is preferred: outputs
//! are processed exactly once on write-back, while a `pre` runs once per
//! (replicated) load. The absorbed op's kernel parameters become
//! `pre`/`post` parameters of the host, increasing its kernel traffic.
//!
//! The pass runs under one of two policies sharing the same structural
//! walk and base legality rules (so the analytical and executable
//! views of fusibility cannot drift):
//!
//! * [`FusePolicy::Analytical`] ([`fuse_chain`]) — the paper's
//!   accounting view: any reduce-free op may be absorbed, parametric
//!   absorbs included; the host slot is marked with the `"fused"`
//!   placeholder LUT (identity at execution time). Used by the
//!   simulator and the movement/cycle models.
//! * [`FusePolicy::Executable`] ([`fuse_executable`]) — the native
//!   engine's view: only *scalar* element-wise followers (kernel-less
//!   `Pass` ops with identity indexing — ReLU, sigmoid, scalar scales,
//!   copies) are absorbed, and their `pre`/`post` maps are composed
//!   into real [`StageStack`] pipelines that
//!   [`crate::exec::eval_gconv`] resolves to LUT handles at bind and
//!   executes bit-identically to the unfused chain. Pure copies are
//!   elided outright. Ops carrying a special-execution routine
//!   ([`crate::gconv::chain::SpecialOp`]) never fuse in either policy.
//!
//! [`StageStack`]: crate::gconv::op::StageStack

use crate::exec::LutFn;
use crate::gconv::chain::{ChainEntry, FusedOp, GconvChain};
use crate::gconv::op::{DataRef, GconvOp, MainOp, PostOp, PreOp, ReduceOp, ScalarStage, StageStack};

/// Which fusion policy [`fuse_chain_with`] applies (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusePolicy {
    /// Paper-accounting fusion: placeholder LUTs, parametric absorbs.
    Analytical,
    /// Semantics-preserving fusion for the native execution engine.
    Executable,
}

/// Statistics of one fusion pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct FusionStats {
    /// Entries before fusion.
    pub before: usize,
    /// Entries after fusion.
    pub after: usize,
    /// Intermediate words no longer moved through the GB (input + output
    /// of the erased ops).
    pub words_saved: f64,
}

impl FusionStats {
    /// Fractional chain-length reduction.
    pub fn length_reduction(&self) -> f64 {
        1.0 - self.after as f64 / self.before.max(1) as f64
    }
}

/// Base legality shared by both policies: the entry must have no
/// reduction and no special-execution routine.
fn absorbable(e: &ChainEntry) -> bool {
    e.op.is_fusible() && e.special.is_none()
}

/// The scalar pipeline a kernel-less `Pass` op applies to each element
/// (`post ∘ pre`), or `None` when the op is not a pure scalar map or a
/// LUT in it is unknown to the native engine.
fn scalar_pipeline(op: &GconvOp) -> Option<StageStack> {
    if op.main != MainOp::Pass || op.kernel.is_some() || op.reduce != ReduceOp::None {
        return None;
    }
    let mut s = op.pre.stages();
    if !s.extend(&op.post.stages()) {
        return None;
    }
    for &st in s.as_slice() {
        if let ScalarStage::Lut(n) = st {
            LutFn::resolve(n)?;
        }
    }
    Some(s)
}

/// After erasing `e` (chain index `i`), its consumers bind `repl`'s
/// output instead: same element count, but possibly different extents.
/// Rebinding is shape-independent only when the extents match exactly or
/// every consumer reading `e` as *input* binds by exact element count
/// (reshape semantics; kernel operands always bind by exact count).
fn rebind_safe(
    chain: &GconvChain,
    i: usize,
    e: &GconvOp,
    repl: &GconvOp,
    consumers: &[usize],
) -> bool {
    if repl.output_extents() == e.output_extents() {
        return true;
    }
    consumers.iter().all(|&c| {
        let co = &chain.entries()[c].op;
        co.input != DataRef::Gconv(i) || co.input_elements() == e.output_elements()
    })
}

/// Evaluate a pipeline at `x` (`None` when a LUT is unknown).
fn stack_value(stack: &StageStack, x: f32) -> Option<f32> {
    let mut v = x;
    for &s in stack.as_slice() {
        v = match s {
            ScalarStage::Square => v * v,
            ScalarStage::Mul(c) => v * c,
            ScalarStage::Lut(n) => LutFn::resolve(n)?.apply(v),
        };
    }
    Some(v)
}

/// Fuse the chain in place under the analytical policy.
pub fn fuse_chain(chain: &mut GconvChain) -> FusionStats {
    fuse_chain_with(chain, FusePolicy::Analytical)
}

/// Fuse the chain in place under the executable policy: the rewritten
/// chain executes on the native engine bit-identically to the original.
pub fn fuse_executable(chain: &mut GconvChain) -> FusionStats {
    fuse_chain_with(chain, FusePolicy::Executable)
}

/// Fuse the chain in place; returns the statistics.
///
/// Strategy per absorbable op `e` (single pass, greedy):
/// 1. (executable only) *elision* — a pure copy with identity indexing
///    vanishes, all consumers rewired to its producer;
/// 2. producer fusion into `post` — if `e.input` is a chain op whose
///    output is consumed only by `e` and whose `post` slot accepts the
///    absorb (free under the analytical policy, composable under the
///    executable one);
/// 3. otherwise consumer fusion into `pre` — if `e` has exactly one
///    consumer that reads it as `input` and whose `pre` slot accepts it.
pub fn fuse_chain_with(chain: &mut GconvChain, policy: FusePolicy) -> FusionStats {
    let before = chain.len();
    let mut words_saved = 0.0;
    let n = chain.len();
    let mut erased = vec![false; n];

    // Consumer lists computed once and maintained incrementally — the
    // per-query `chain.consumers()` scan is O(n) and made the pass
    // quadratic on DenseNet-sized chains (§Perf).
    let mut cons: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (j, e) in chain.entries().iter().enumerate() {
        if let DataRef::Gconv(p) = e.op.input {
            cons[p].push(j);
        }
        if let Some(DataRef::Gconv(p)) = e.op.kernel {
            cons[p].push(j);
        }
    }

    for i in 0..n {
        if erased[i] || !absorbable(&chain.entries()[i]) {
            continue;
        }
        let (op_i, consumers) = {
            let e = &chain.entries()[i];
            (e.op.clone(), cons[i].clone())
        };

        // --- Executable elision of pure identity copies. ---
        if policy == FusePolicy::Executable
            && op_i.pre == PreOp::None
            && op_i.post == PostOp::None
            && op_i.main == MainOp::Pass
            && op_i.kernel.is_none()
            && op_i.is_identity_indexed()
            && !consumers.is_empty()
        {
            if let DataRef::Gconv(p2) = op_i.input {
                let exact = !erased[p2]
                    && chain.entries()[p2].op.output_elements() == op_i.input_elements()
                    && rebind_safe(chain, i, &op_i, &chain.entries()[p2].op, &consumers);
                if exact {
                    for &c in &consumers {
                        let ce = &mut chain.entries_mut()[c];
                        if ce.op.input == DataRef::Gconv(i) {
                            ce.op.input = DataRef::Gconv(p2);
                        }
                        if ce.op.kernel == Some(DataRef::Gconv(i)) {
                            ce.op.kernel = Some(DataRef::Gconv(p2));
                        }
                    }
                    cons[p2].retain(|&x| x != i);
                    cons[p2].extend(consumers.iter().copied());
                    chain.entries_mut()[p2].fused.push(FusedOp {
                        name: op_i.name.clone(),
                        slot: "elided",
                        param_elements: 0,
                    });
                    words_saved += (op_i.input_elements() + op_i.output_elements()) as f64;
                    erased[i] = true;
                    continue;
                }
            }
        }

        // --- Try producer fusion (preferred: post runs once/output). ---
        if let DataRef::Gconv(p) = op_i.input {
            let host_ok = !erased[p]
                && cons[p] == vec![i]
                && chain.entries()[p].special.is_none()
                // The producer must emit exactly the elements `e`
                // consumes (same tensor footprint).
                && chain.entries()[p].op.output_elements() == op_i.input_elements();
            let new_post = if !host_ok {
                None
            } else {
                match policy {
                    FusePolicy::Analytical => (chain.entries()[p].op.post == PostOp::None)
                        .then_some(PostOp::Lut("fused")),
                    FusePolicy::Executable => {
                        let tail_ok = i + 1 == n && ((p + 1)..i).all(|j| erased[j]);
                        if rebind_safe(chain, i, &op_i, &chain.entries()[p].op, &consumers) {
                            executable_post(
                                &chain.entries()[p].op,
                                &op_i,
                                consumers.is_empty(),
                                tail_ok,
                            )
                        } else {
                            None
                        }
                    }
                }
            };
            if let Some(post) = new_post {
                let host = &mut chain.entries_mut()[p];
                host.op.post = post;
                host.fused.push(FusedOp {
                    name: op_i.name.clone(),
                    slot: "post",
                    param_elements: op_i.kernel_elements(),
                });
                words_saved += (op_i.input_elements() + op_i.output_elements()) as f64;
                // Rewire consumers of i to read p directly.
                for &c in &consumers {
                    let ce = &mut chain.entries_mut()[c];
                    if ce.op.input == DataRef::Gconv(i) {
                        ce.op.input = DataRef::Gconv(p);
                    }
                    if ce.op.kernel == Some(DataRef::Gconv(i)) {
                        ce.op.kernel = Some(DataRef::Gconv(p));
                    }
                }
                cons[p] = consumers;
                erased[i] = true;
                continue;
            }
        }

        // --- Try consumer fusion into pre. ---
        if consumers.len() == 1 {
            let c = consumers[0];
            let host_ok = !erased[c]
                && chain.entries()[c].op.input == DataRef::Gconv(i)
                && chain.entries()[c].special.is_none();
            let new_pre = if !host_ok {
                None
            } else {
                match policy {
                    FusePolicy::Analytical => {
                        // pre must be element-wise on the consumer's
                        // input stream: the fused op may not change
                        // element count.
                        let ok = chain.entries()[c].op.pre == PreOp::None
                            && op_i.input_elements() == op_i.output_elements()
                            && matches!(
                                op_i.main,
                                MainOp::Pass | MainOp::Mul | MainOp::Add | MainOp::Sub
                            );
                        ok.then_some(PreOp::Lut("fused"))
                    }
                    FusePolicy::Executable => {
                        executable_pre(chain, &chain.entries()[c].op, &op_i, &erased)
                    }
                }
            };
            if let Some(pre) = new_pre {
                let input_of_i = op_i.input.clone();
                // The host now reads i's input directly.
                if let DataRef::Gconv(src) = input_of_i {
                    cons[src].retain(|&x| x != i);
                    cons[src].push(c);
                }
                let host = &mut chain.entries_mut()[c];
                host.op.pre = pre;
                host.op.input = input_of_i;
                host.fused.push(FusedOp {
                    name: op_i.name.clone(),
                    slot: "pre",
                    param_elements: op_i.kernel_elements(),
                });
                words_saved += (op_i.input_elements() + op_i.output_elements()) as f64;
                erased[i] = true;
            }
        }
    }

    // Compact the chain, remapping references.
    let mut remap = vec![usize::MAX; n];
    let mut kept = Vec::with_capacity(n);
    for (i, e) in chain.entries().iter().enumerate() {
        if !erased[i] {
            remap[i] = kept.len();
            kept.push(e.clone());
        }
    }
    for e in &mut kept {
        if let DataRef::Gconv(ref mut idx) = e.op.input {
            assert_ne!(remap[*idx], usize::MAX, "dangling input after fusion");
            *idx = remap[*idx];
        }
        if let Some(DataRef::Gconv(ref mut idx)) = e.op.kernel {
            assert_ne!(remap[*idx], usize::MAX, "dangling kernel after fusion");
            *idx = remap[*idx];
        }
    }
    *chain.entries_mut() = kept;
    FusionStats { before, after: chain.len(), words_saved }
}

/// Executable producer fusion: the follower `e` folds into `host.post`
/// when it is a pure scalar map with identity indexing and the composed
/// pipeline fits. A consumer-less follower may only fold when erasing it
/// leaves the host as the chain's final entry (`tail_ok`) *and* the host
/// emits the same extents — `run_last` then returns the network output
/// with the shape the unfused chain produced (bit-identity compares
/// extents, not just values).
fn executable_post(
    host: &GconvOp,
    e: &GconvOp,
    no_consumers: bool,
    tail_ok: bool,
) -> Option<PostOp> {
    if no_consumers && (!tail_ok || host.output_extents() != e.output_extents()) {
        return None;
    }
    let pipeline = scalar_pipeline(e)?;
    if !e.is_identity_indexed() {
        return None;
    }
    let mut stack = host.post.stages();
    if !stack.extend(&pipeline) {
        return None;
    }
    Some(PostOp::from_stages(stack))
}

/// Executable consumer fusion: the producer `e` folds into `host.pre`
/// when it is a pure scalar map with identity indexing, its own input is
/// a chain op of exactly matching footprint (so the host re-binds it the
/// way `e` did), the composed pipeline fits, and padding stays safe —
/// the host either has no padded windows or the pipeline maps the
/// padding value 0 to 0 bit-exactly.
fn executable_pre(
    chain: &GconvChain,
    host: &GconvOp,
    e: &GconvOp,
    erased: &[bool],
) -> Option<PreOp> {
    let pipeline = scalar_pipeline(e)?;
    if !e.is_identity_indexed() {
        return None;
    }
    let DataRef::Gconv(p2) = e.input else {
        return None;
    };
    if erased[p2] || chain.entries()[p2].op.output_elements() != e.input_elements() {
        return None;
    }
    // The host re-binds p2's output in place of e's: safe only when the
    // extents match or the host binds by exact element count.
    let same_shape = chain.entries()[p2].op.output_extents() == e.output_extents();
    if !same_shape && host.input_elements() != e.output_elements() {
        return None;
    }
    // Bit-exact +0.0: even a −0.0 would change the padding bits the
    // host's operators see (the differential tests compare bit patterns).
    let pad_free = host.dims.iter().all(|&(_, p)| p.ps == 0 && p.pe == 0);
    if !pad_free && stack_value(&pipeline, 0.0).map(f32::to_bits) != Some(0.0f32.to_bits()) {
        return None;
    }
    let mut stack = pipeline;
    if !stack.extend(&host.pre.stages()) {
        return None;
    }
    Some(PreOp::from_stages(stack))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::chain::Phase;
    use crate::gconv::lower::{lower_network, Mode};
    use crate::gconv::op::DimParams;
    use crate::ir::{Dim, Layer, Network, PoolKind, Shape};
    use crate::networks::{benchmark, mobilenet_block};

    #[test]
    fn fusion_shortens_bn_chains() {
        // BN FP2 (no reduce) fuses into a neighbour — the paper's own
        // example ("GCONV FP2 in Table 2 can be processed as the post of
        // FP1 or pre of FP3 and FP4").
        let mut chain = lower_network(&mobilenet_block(8, 16, 14), Mode::Inference);
        let before = chain.len();
        let stats = fuse_chain(&mut chain);
        assert!(chain.len() < before, "no fusion happened");
        assert!(stats.length_reduction() > 0.1);
        assert!(stats.words_saved > 0.0);
    }

    #[test]
    fn fusion_reduction_within_paper_band() {
        // Paper: "reduces the length of GCONV Chain by up to 30%".
        for code in ["AN", "DN", "MN"] {
            let mut chain = lower_network(&benchmark(code), Mode::Training);
            let stats = fuse_chain(&mut chain);
            let r = stats.length_reduction();
            assert!(r > 0.0 && r <= 0.45, "{code}: reduction {r:.2}");
        }
    }

    #[test]
    fn references_stay_valid_after_fusion() {
        let mut chain = lower_network(&benchmark("MN"), Mode::Training);
        fuse_chain(&mut chain);
        for (i, e) in chain.entries().iter().enumerate() {
            if let DataRef::Gconv(p) = e.op.input {
                assert!(p < i, "entry {i} input points forward");
            }
            if let Some(DataRef::Gconv(p)) = e.op.kernel {
                assert!(p < i, "entry {i} kernel points forward");
            }
        }
    }

    #[test]
    fn fused_ops_record_parameter_loads() {
        let mut chain = lower_network(&mobilenet_block(8, 16, 14), Mode::Inference);
        fuse_chain(&mut chain);
        let fused: usize = chain.entries().iter().map(|e| e.fused.len()).sum();
        assert!(fused > 0);
    }

    #[test]
    fn fusion_preserves_reduce_ops() {
        // Ops with a reduction must all survive.
        let mut chain = lower_network(&mobilenet_block(8, 16, 14), Mode::Inference);
        let reduces_before = chain
            .entries()
            .iter()
            .filter(|e| e.op.reduce != crate::gconv::op::ReduceOp::None)
            .count();
        fuse_chain(&mut chain);
        let reduces_after = chain
            .entries()
            .iter()
            .filter(|e| e.op.reduce != crate::gconv::op::ReduceOp::None)
            .count();
        assert_eq!(reduces_before, reduces_after);
    }

    #[test]
    fn executable_fusion_composes_real_pipelines() {
        // MobileNet block: relu.fp folds into bn FP4's post as a real
        // relu LUT (not the analytical "fused" placeholder).
        let mut chain = lower_network(&mobilenet_block(2, 4, 6), Mode::Inference);
        let before = chain.len();
        let stats = fuse_executable(&mut chain);
        assert!(chain.len() < before, "no executable fusion happened");
        assert_eq!(stats.after, chain.len());
        let mut relu_posts = 0;
        for e in chain.entries() {
            match e.op.post {
                PostOp::Lut("fused") => panic!("executable pass wrote a placeholder LUT"),
                PostOp::Lut("relu") => relu_posts += 1,
                PostOp::Stack(s) => {
                    assert!(s.as_slice().contains(&ScalarStage::Lut("relu")));
                    relu_posts += 1;
                }
                _ => {}
            }
            if let PreOp::Lut(n) = e.op.pre {
                assert_ne!(n, "fused");
            }
        }
        assert!(relu_posts >= 2, "both block ReLUs should fold into a post");
    }

    #[test]
    fn special_entries_never_fuse() {
        // A max-pool training chain: the argmax-routing special entry
        // must survive both policies untouched.
        let mut net = Network::new("p");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(2, 4, 8, 8) }, &[]);
        let p = net.add(
            "pool",
            Layer::Pool { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
            &[i],
        );
        net.add("relu", Layer::Relu, &[p]);
        for policy in [FusePolicy::Analytical, FusePolicy::Executable] {
            let mut chain = lower_network(&net, Mode::Training);
            let specials = chain.entries().iter().filter(|e| e.special.is_some()).count();
            assert!(specials > 0, "training chain should carry the BP special");
            fuse_chain_with(&mut chain, policy);
            let after = chain.entries().iter().filter(|e| e.special.is_some()).count();
            assert_eq!(specials, after, "{policy:?} dropped a special entry");
        }
    }

    #[test]
    fn reshaping_tail_followers_do_not_fold() {
        // A consumer-less tail copy that *reshapes* (same count, new
        // extents) must survive: folding it would change the shape
        // `run_last` hands back, and bit-identity compares extents.
        use crate::gconv::chain::ChainEntry;
        use crate::gconv::op::GconvOp;

        let mut chain = GconvChain::new("t");
        let src = GconvOp {
            name: "src".into(),
            dims: vec![(Dim::W, DimParams::opc(4))],
            pre: PreOp::None,
            main: MainOp::Mul,
            reduce: ReduceOp::None,
            post: PostOp::None,
            input: DataRef::External("x".into()),
            kernel: Some(DataRef::Weights("w".into())),
        };
        let reshape_tail = GconvOp {
            name: "tail".into(),
            dims: vec![(Dim::C, DimParams::opc(2)), (Dim::W, DimParams::opc(2))],
            pre: PreOp::None,
            main: MainOp::Pass,
            reduce: ReduceOp::None,
            post: PostOp::Lut("relu"),
            input: DataRef::Gconv(0),
            kernel: None,
        };
        chain.push(ChainEntry::new(src, 0, true, Phase::Fp));
        chain.push(ChainEntry::new(reshape_tail, 0, true, Phase::Fp));
        fuse_executable(&mut chain);
        assert_eq!(chain.len(), 2, "a reshaping tail must not fold");
        // The same tail with matching extents does fold.
        let mut chain2 = GconvChain::new("t2");
        let src2 = chain.entries()[0].op.clone();
        let mut flat_tail = chain.entries()[1].op.clone();
        flat_tail.dims = vec![(Dim::W, DimParams::opc(4))];
        chain2.push(ChainEntry::new(src2, 0, true, Phase::Fp));
        chain2.push(ChainEntry::new(flat_tail, 0, true, Phase::Fp));
        fuse_executable(&mut chain2);
        assert_eq!(chain2.len(), 1, "a shape-preserving tail folds");
        assert_eq!(chain2.entries()[0].op.post, PostOp::Lut("relu"));
    }

    #[test]
    fn padded_consumers_only_absorb_zero_preserving_pipelines() {
        // producer(post sigmoid) → padded conv: sigmoid(0) ≠ 0 would
        // corrupt the padding, so the executable pass must refuse; a
        // relu producer (relu(0) = 0) must fold.
        use crate::gconv::chain::ChainEntry;
        use crate::gconv::op::GconvOp;

        let build = |lut: &'static str| {
            let mut chain = GconvChain::new("t");
            let ew = GconvOp {
                name: "act".into(),
                dims: vec![(Dim::W, DimParams::opc(4))],
                pre: PreOp::None,
                main: MainOp::Pass,
                reduce: ReduceOp::None,
                post: PostOp::Lut(lut),
                input: DataRef::Gconv(0),
                kernel: None,
            };
            let src = GconvOp {
                name: "src".into(),
                dims: vec![(Dim::W, DimParams::opc(4))],
                pre: PreOp::None,
                main: MainOp::Mul,
                reduce: ReduceOp::None,
                post: PostOp::None,
                input: DataRef::External("x".into()),
                kernel: Some(DataRef::Weights("w".into())),
            };
            let conv = GconvOp::conv(
                "conv",
                vec![(Dim::W, DimParams::window(4, 3, 1, 1))],
                DataRef::Gconv(1),
                DataRef::Weights("k".into()),
            );
            // src has two consumers (act + a side reader) so `act`
            // cannot producer-fuse and must try the consumer path.
            let side = GconvOp {
                name: "side".into(),
                dims: vec![(Dim::W, DimParams::opc(4))],
                pre: PreOp::None,
                main: MainOp::Pass,
                reduce: ReduceOp::None,
                post: PostOp::Lut("exp"),
                input: DataRef::Gconv(0),
                kernel: None,
            };
            chain.push(ChainEntry::new(src, 0, true, Phase::Fp));
            chain.push(ChainEntry::new(ew, 0, true, Phase::Fp));
            chain.push(ChainEntry::new(conv, 0, true, Phase::Fp));
            chain.push(ChainEntry::new(side, 0, true, Phase::Fp));
            chain
        };

        let mut relu = build("relu");
        fuse_executable(&mut relu);
        assert_eq!(relu.len(), 3, "relu must fold into the padded conv's pre");
        let conv = relu.entries().iter().find(|e| e.op.name == "conv").unwrap();
        assert_eq!(conv.op.pre, PreOp::Lut("relu"));

        let mut sig = build("sigmoid");
        fuse_executable(&mut sig);
        assert_eq!(sig.len(), 4, "sigmoid(0) != 0 must block the fold");
    }
}
