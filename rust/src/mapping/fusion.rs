//! Operation fusion (paper §4.3).
//!
//! GCONVs with no `reduce` operator are absorbed into the `pre`, `post`
//! or `main` operator of their consumer or producer, shortening the
//! chain (up to 30% in the paper) and eliminating the intermediate
//! tensor's round trip through the global buffer (up to 63% input
//! movement). Fusing into the producer's `post` is preferred: outputs
//! are processed exactly once on write-back, while a `pre` runs once per
//! (replicated) load. The absorbed op's kernel parameters become
//! `pre`/`post` parameters of the host, increasing its kernel traffic.

use crate::gconv::chain::{FusedOp, GconvChain};
use crate::gconv::op::{DataRef, MainOp, PostOp, PreOp};

/// Statistics of one fusion pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct FusionStats {
    /// Entries before fusion.
    pub before: usize,
    /// Entries after fusion.
    pub after: usize,
    /// Intermediate words no longer moved through the GB (input + output
    /// of the erased ops).
    pub words_saved: f64,
}

impl FusionStats {
    /// Fractional chain-length reduction.
    pub fn length_reduction(&self) -> f64 {
        1.0 - self.after as f64 / self.before.max(1) as f64
    }
}

/// Can `e` be absorbed at all? It must have no reduction and at most a
/// trivially-wide operator footprint (pre and post both free on the
/// host side is checked at the host).
fn fusible(chain: &GconvChain, idx: usize) -> bool {
    let e = &chain.entries()[idx].op;
    e.is_fusible()
}

/// Fuse the chain in place; returns the statistics.
///
/// Strategy per fusible op `e` (single pass, greedy):
/// 1. producer fusion into `post` — if `e.input` is a chain op whose
///    `post` slot is free and whose output is consumed only by `e`;
/// 2. otherwise consumer fusion into `pre` — if `e` has exactly one
///    consumer that reads it as `input` and whose `pre` slot is free.
pub fn fuse_chain(chain: &mut GconvChain) -> FusionStats {
    let before = chain.len();
    let mut words_saved = 0.0;
    let n = chain.len();
    let mut erased = vec![false; n];

    // Consumer lists computed once and maintained incrementally — the
    // per-query `chain.consumers()` scan is O(n) and made the pass
    // quadratic on DenseNet-sized chains (§Perf).
    let mut cons: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (j, e) in chain.entries().iter().enumerate() {
        if let DataRef::Gconv(p) = e.op.input {
            cons[p].push(j);
        }
        if let Some(DataRef::Gconv(p)) = e.op.kernel {
            cons[p].push(j);
        }
    }

    for i in 0..n {
        if erased[i] || !fusible(chain, i) {
            continue;
        }
        let (op_i, consumers) = {
            let e = &chain.entries()[i];
            (e.op.clone(), cons[i].clone())
        };
        // --- Try producer fusion (preferred: post runs once/output). ---
        if let DataRef::Gconv(p) = op_i.input {
            let producer_ok = !erased[p]
                && cons[p] == vec![i]
                && chain.entries()[p].op.post == PostOp::None
                // The producer must emit exactly the elements `e`
                // consumes (same tensor footprint).
                && chain.entries()[p].op.output_elements() == op_i.input_elements();
            if producer_ok {
                let host = &mut chain.entries_mut()[p];
                host.op.post = PostOp::Lut("fused");
                host.fused.push(FusedOp {
                    name: op_i.name.clone(),
                    slot: "post",
                    param_elements: op_i.kernel_elements(),
                });
                words_saved +=
                    (op_i.input_elements() + op_i.output_elements()) as f64;
                // Rewire consumers of i to read p directly.
                for &c in &consumers {
                    let ce = &mut chain.entries_mut()[c];
                    if ce.op.input == DataRef::Gconv(i) {
                        ce.op.input = DataRef::Gconv(p);
                    }
                    if ce.op.kernel == Some(DataRef::Gconv(i)) {
                        ce.op.kernel = Some(DataRef::Gconv(p));
                    }
                }
                cons[p] = consumers;
                erased[i] = true;
                continue;
            }
        }
        // --- Try consumer fusion into pre. ---
        if consumers.len() == 1 {
            let c = consumers[0];
            let consumer_ok = !erased[c]
                && chain.entries()[c].op.input == DataRef::Gconv(i)
                && chain.entries()[c].op.pre == PreOp::None
                // pre must be element-wise on the consumer's input
                // stream: the fused op may not change element count.
                && op_i.input_elements() == op_i.output_elements()
                && matches!(op_i.main, MainOp::Pass | MainOp::Mul | MainOp::Add | MainOp::Sub);
            if consumer_ok {
                let input_of_i = op_i.input.clone();
                // The host now reads i's input directly.
                if let DataRef::Gconv(src) = input_of_i {
                    cons[src].retain(|&x| x != i);
                    cons[src].push(c);
                }
                let host = &mut chain.entries_mut()[c];
                host.op.pre = PreOp::Lut("fused");
                host.op.input = input_of_i;
                host.fused.push(FusedOp {
                    name: op_i.name.clone(),
                    slot: "pre",
                    param_elements: op_i.kernel_elements(),
                });
                words_saved +=
                    (op_i.input_elements() + op_i.output_elements()) as f64;
                erased[i] = true;
            }
        }
    }

    // Compact the chain, remapping references.
    let mut remap = vec![usize::MAX; n];
    let mut kept = Vec::with_capacity(n);
    for (i, e) in chain.entries().iter().enumerate() {
        if !erased[i] {
            remap[i] = kept.len();
            kept.push(e.clone());
        }
    }
    for e in &mut kept {
        if let DataRef::Gconv(ref mut idx) = e.op.input {
            assert_ne!(remap[*idx], usize::MAX, "dangling input after fusion");
            *idx = remap[*idx];
        }
        if let Some(DataRef::Gconv(ref mut idx)) = e.op.kernel {
            assert_ne!(remap[*idx], usize::MAX, "dangling kernel after fusion");
            *idx = remap[*idx];
        }
    }
    *chain.entries_mut() = kept;
    FusionStats { before, after: chain.len(), words_saved }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gconv::lower::{lower_network, Mode};
    use crate::networks::{benchmark, mobilenet_block};

    #[test]
    fn fusion_shortens_bn_chains() {
        // BN FP2 (no reduce) fuses into a neighbour — the paper's own
        // example ("GCONV FP2 in Table 2 can be processed as the post of
        // FP1 or pre of FP3 and FP4").
        let mut chain = lower_network(&mobilenet_block(8, 16, 14), Mode::Inference);
        let before = chain.len();
        let stats = fuse_chain(&mut chain);
        assert!(chain.len() < before, "no fusion happened");
        assert!(stats.length_reduction() > 0.1);
        assert!(stats.words_saved > 0.0);
    }

    #[test]
    fn fusion_reduction_within_paper_band() {
        // Paper: "reduces the length of GCONV Chain by up to 30%".
        for code in ["AN", "DN", "MN"] {
            let mut chain = lower_network(&benchmark(code), Mode::Training);
            let stats = fuse_chain(&mut chain);
            let r = stats.length_reduction();
            assert!(r > 0.0 && r <= 0.45, "{code}: reduction {r:.2}");
        }
    }

    #[test]
    fn references_stay_valid_after_fusion() {
        let mut chain = lower_network(&benchmark("MN"), Mode::Training);
        fuse_chain(&mut chain);
        for (i, e) in chain.entries().iter().enumerate() {
            if let DataRef::Gconv(p) = e.op.input {
                assert!(p < i, "entry {i} input points forward");
            }
            if let Some(DataRef::Gconv(p)) = e.op.kernel {
                assert!(p < i, "entry {i} kernel points forward");
            }
        }
    }

    #[test]
    fn fused_ops_record_parameter_loads() {
        let mut chain = lower_network(&mobilenet_block(8, 16, 14), Mode::Inference);
        fuse_chain(&mut chain);
        let fused: usize = chain.entries().iter().map(|e| e.fused.len()).sum();
        assert!(fused > 0);
    }

    #[test]
    fn fusion_preserves_reduce_ops() {
        // Ops with a reduction must all survive.
        let mut chain = lower_network(&mobilenet_block(8, 16, 14), Mode::Inference);
        let reduces_before = chain
            .entries()
            .iter()
            .filter(|e| e.op.reduce != crate::gconv::op::ReduceOp::None)
            .count();
        fuse_chain(&mut chain);
        let reduces_after = chain
            .entries()
            .iter()
            .filter(|e| e.op.reduce != crate::gconv::op::ReduceOp::None)
            .count();
        assert_eq!(reduces_before, reduces_after);
    }
}
