//! Algorithm 1: mapping a single GCONV onto an accelerator.
//!
//! The mapper unrolls the GCONV loop nest spatially (across the PE-array
//! axes) and temporally (into the local scratchpads), producing the two
//! unrolling lists of Fig. 9. The same engine serves both the GCONV
//! mapping (paper priorities) and the *baseline* mapping of each
//! accelerator's original dataflow (§4.4: "the mapping strategies
//! provided in the original works ... just slightly changes the priority
//! of the parameters"), which additionally pins each spatial axis to the
//! dimensions the original dataflow understands.

use crate::accel::structure::AccelStructure;
use crate::gconv::op::{GconvOp, Param};
use crate::ir::Dim;
use std::collections::BTreeMap;

/// `[p, d, uf]` — unrolling factor `uf` of parameter `p` in dimension
/// `d` (one entry of Fig. 9's lists).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UnrollEntry {
    /// Loop parameter.
    pub param: Param,
    /// Data dimension.
    pub dim: Dim,
    /// Unrolling factor (spatial) or iteration count (temporal).
    pub factor: usize,
}

/// Result of mapping one GCONV.
#[derive(Clone, Debug, Default)]
pub struct Mapping {
    /// Spatial unrolling list per accelerator axis (Fig. 9 columns).
    pub spatial: Vec<Vec<UnrollEntry>>,
    /// Temporal unrolling list (innermost first).
    pub temporal: Vec<UnrollEntry>,
    /// Stride per dimension (needed for input-tile arithmetic).
    pub strides: BTreeMap<Dim, usize>,
}

/// Whether to use the paper's GCONV priorities or the accelerator's
/// original (baseline) dataflow restrictions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapMode {
    /// Full Algorithm 1 with the accelerator's GCONV priorities.
    Gconv,
    /// Original-dataflow baseline: spatial axes pinned to the dims the
    /// original work unrolls; overlap primitives dedicated to H/W.
    Baseline,
}

impl Mapping {
    /// Product of spatial factors for parameter `p` in dimension `d`
    /// (`SP_Pp_d` in Eq. (6)).
    pub fn spatial_factor(&self, d: Dim, p: Param) -> usize {
        self.spatial
            .iter()
            .flatten()
            .filter(|e| e.dim == d && e.param == p)
            .map(|e| e.factor)
            .product()
    }

    /// Number of PEs actually occupied.
    pub fn occupied_pes(&self) -> usize {
        self.spatial.iter().map(|axis| axis.iter().map(|e| e.factor).product::<usize>()).product()
    }

    /// Iteration count of the temporal list (≈ Eq. (6) cycles).
    pub fn temporal_iterations(&self) -> usize {
        self.temporal.iter().map(|e| e.factor).product()
    }
}

/// Remaining loop counts per (dim, param).
#[derive(Clone, Debug)]
struct Loops {
    counts: BTreeMap<(Dim, Param), usize>,
}

impl Loops {
    fn from_op(op: &GconvOp) -> Self {
        let mut counts = BTreeMap::new();
        for &(d, p) in &op.dims {
            for param in Param::ALL {
                let n = p.get(param);
                if n > 1 {
                    counts.insert((d, param), n);
                }
            }
        }
        Loops { counts }
    }

    fn get(&self, d: Dim, p: Param) -> usize {
        self.counts.get(&(d, p)).copied().unwrap_or(1)
    }

    /// The paper's `unrolling` function (Algorithm 1 lines 1–5) with the
    /// resource handled by the caller: consume up to `limit` iterations,
    /// return the factor.
    fn consume(&mut self, d: Dim, p: Param, limit: usize) -> usize {
        let n = self.get(d, p);
        let uf = n.min(limit.max(1));
        if uf > 1 {
            self.counts.insert((d, p), n.div_ceil(uf));
        }
        uf
    }
}

/// Map one GCONV op onto `accel` (Algorithm 1).
pub fn map_gconv(op: &GconvOp, accel: &AccelStructure, mode: MapMode) -> Mapping {
    let mut loops = Loops::from_op(op);
    let mut m = Mapping {
        spatial: vec![Vec::new(); accel.spatial.len()],
        temporal: Vec::new(),
        strides: op.dims.iter().map(|&(d, p)| (d, p.s)).collect(),
    };
    let mut spatial_left: Vec<usize> = accel.spatial.iter().map(|s| s.size).collect();
    let mut tiles = TileTracker::new(op);
    // Temporal sub-lists: `inner` collects the LS-fill phase (Algorithm 1
    // uses `temporal.insert`, i.e. these loops run innermost to maximize
    // scratchpad reuse), `prim` the overlap-reuse streaming primitive,
    // and the remaining loops are appended outermost.
    let mut inner: Vec<UnrollEntry> = Vec::new();
    let mut prim: Vec<UnrollEntry> = Vec::new();

    // --- Lines 7–13: allocate the overlap-reuse primitives. ---
    let overlap_dims: Vec<Dim> = match mode {
        MapMode::Gconv => op.overlap_dims(),
        // The baseline dedicates its primitives to the classic spatial
        // dims (row-stationary "W or H", §4.1), whether or not the layer
        // has overlap there.
        MapMode::Baseline => op
            .overlap_dims()
            .into_iter()
            .filter(|d| matches!(d, Dim::H | Dim::W))
            .collect(),
    };
    let mut overlap_iter = overlap_dims.into_iter();
    if let (Some(d), Some(oa)) = (overlap_iter.next(), accel.overlap_axis()) {
        // First overlap dim: ks into the overlap axis, opc into the
        // partner axis (Fig. 8(b)); on single-partner structures the opc
        // half lands temporally.
        let uf = loops.consume(d, Param::Ks, spatial_left[oa]);
        if uf > 1 {
            m.spatial[oa].push(UnrollEntry { param: Param::Ks, dim: d, factor: uf });
            spatial_left[oa] /= uf;
        }
        let partner = (0..accel.spatial.len()).find(|&i| i != oa);
        if let Some(pa) = partner {
            let uf = loops.consume(d, Param::Opc, spatial_left[pa]);
            if uf > 1 {
                m.spatial[pa].push(UnrollEntry { param: Param::Opc, dim: d, factor: uf });
                spatial_left[pa] /= uf;
            }
        }
        // Second overlap dim: the temporal primitive (Fig. 8(a)) — ks
        // then the *full* opc loop (Algorithm 1 line 13). The opc loop
        // streams through the scratchpad (load `s` new inputs per step),
        // so only the ks window counts against ILS capacity.
        if let Some(d2) = overlap_iter.next() {
            let limit = tiles.max_temporal_factor(accel, d2, Param::Ks, &loops);
            let uf = loops.consume(d2, Param::Ks, limit);
            if uf > 1 {
                tiles.apply(d2, Param::Ks, uf);
                prim.push(UnrollEntry { param: Param::Ks, dim: d2, factor: uf });
            }
            let full = loops.get(d2, Param::Opc);
            if full > 1 {
                let uf = loops.consume(d2, Param::Opc, full);
                prim.push(UnrollEntry { param: Param::Opc, dim: d2, factor: uf });
            }
        }
    }

    // --- Lines 14–19: fill the spatial axes by priority. ---
    for (axis, left) in spatial_left.iter_mut().enumerate() {
        let prio = &accel.spatial_priority[axis];
        let allowed: Option<&[Dim]> = match mode {
            MapMode::Baseline => accel.baseline_dims[axis].as_deref(),
            MapMode::Gconv => None,
        };
        for &p in prio {
            // ks reduction needs forwarding links on this axis.
            if p == Param::Ks && !accel.spatial[axis].reduce {
                continue;
            }
            for d in Dim::MAPPING_ORDER {
                if let Some(a) = allowed {
                    if !a.contains(&d) {
                        continue;
                    }
                }
                if *left <= 1 {
                    break;
                }
                let uf = loops.consume(d, p, *left);
                if uf > 1 {
                    m.spatial[axis].push(UnrollEntry { param: p, dim: d, factor: uf });
                    *left /= uf;
                }
            }
        }
    }

    // --- Lines 20–22: fill the local scratchpads temporally. These are
    // *inserted* innermost (before the streaming primitive) so the data
    // they pin in the scratchpads is reused across the outer sweeps. ---
    for &p in &accel.temporal_priority {
        for d in Dim::MAPPING_ORDER {
            let limit = tiles.max_temporal_factor(accel, d, p, &loops);
            if limit <= 1 {
                continue;
            }
            let uf = loops.consume(d, p, limit);
            if uf > 1 {
                tiles.apply(d, p, uf);
                inner.push(UnrollEntry { param: p, dim: d, factor: uf });
            }
        }
    }

    m.temporal.extend(inner);
    m.temporal.extend(prim);

    // --- Lines 23–25: append every remaining loop (g last). ---
    for p in [Param::Opc, Param::Op, Param::Ks, Param::G] {
        for d in Dim::MAPPING_ORDER {
            let n = loops.get(d, p);
            if n > 1 {
                loops.consume(d, p, n);
                m.temporal.push(UnrollEntry { param: p, dim: d, factor: n });
            }
        }
    }
    m
}

/// Tracks per-PE temporal tile sizes for the three local scratchpads.
pub(crate) struct TileTracker {
    /// Temporal unroll products per (dim, param).
    tp: BTreeMap<(Dim, Param), usize>,
    strides: BTreeMap<Dim, usize>,
    dims: Vec<Dim>,
}

impl TileTracker {
    pub(crate) fn new(op: &GconvOp) -> Self {
        TileTracker {
            tp: BTreeMap::new(),
            strides: op.dims.iter().map(|&(d, p)| (d, p.s)).collect(),
            dims: op.dims.iter().map(|&(d, _)| d).collect(),
        }
    }

    fn get(&self, d: Dim, p: Param) -> usize {
        self.tp.get(&(d, p)).copied().unwrap_or(1)
    }

    pub(crate) fn apply(&mut self, d: Dim, p: Param, uf: usize) {
        let e = self.tp.entry((d, p)).or_insert(1);
        *e *= uf;
    }

    /// Tile size in store `x` ∈ {'i','o','k'} if `(d, p)` were unrolled
    /// by an extra factor `f` (Table 3 per-dimension data amounts).
    pub(crate) fn tile_with(&self, x: char, extra: Option<(Dim, Param, usize)>) -> usize {
        let mut total = 1usize;
        for &d in &self.dims {
            let g = self.boosted(d, Param::G, extra);
            let op = self.boosted(d, Param::Op, extra);
            let opc = self.boosted(d, Param::Opc, extra);
            let ks = self.boosted(d, Param::Ks, extra);
            let s = self.strides.get(&d).copied().unwrap_or(1);
            let per_dim = match x {
                'i' => g * (ks + s * (opc - 1)),
                'k' => g * op * ks,
                'o' => g * op * opc,
                _ => panic!("unknown store {x}"),
            };
            total = total.saturating_mul(per_dim);
        }
        total
    }

    fn boosted(&self, d: Dim, p: Param, extra: Option<(Dim, Param, usize)>) -> usize {
        let base = self.get(d, p);
        match extra {
            Some((ed, ep, f)) if ed == d && ep == p => base * f,
            _ => base,
        }
    }

    /// Largest factor for loop `(d, p)` that keeps every scratchpad the
    /// parameter grows within capacity (Algorithm 1's temporal resource
    /// check). Stores already over capacity no longer constrain.
    fn max_temporal_factor(
        &self,
        accel: &AccelStructure,
        d: Dim,
        p: Param,
        loops: &Loops,
    ) -> usize {
        let n = loops.get(d, p);
        if n <= 1 {
            return 1;
        }
        let grows: &[char] = match p {
            Param::G => &['i', 'o', 'k'],
            Param::Op => &['o', 'k'],
            Param::Opc => &['i', 'o'],
            Param::Ks => &['i', 'k'],
        };
        // Only stores that actually exist (cap > 1; §4.4 models missing
        // scratchpads as size 1) and are still within capacity constrain
        // the factor — data in a degenerate or already-overflowed store
        // re-streams regardless, so growing it costs nothing extra.
        let constraining: Vec<char> = grows
            .iter()
            .copied()
            .filter(|&x| accel.ls_cap(x) > 1 && self.tile_with(x, None) <= accel.ls_cap(x))
            .collect();
        if constraining.is_empty() {
            return 1;
        }
        // Tile growth is monotone in the factor — binary search the
        // largest factor that still fits.
        let fits = |f: usize| {
            constraining.iter().all(|&x| self.tile_with(x, Some((d, p, f))) <= accel.ls_cap(x))
        };
        let (mut lo, mut hi) = (1usize, n);
        while lo < hi {
            let mid = (lo + hi).div_ceil(2);
            if fits(mid) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    /// Scan a finished temporal list and return the reuse pointers
    /// `(ilst, olst, klst)`: for each store, the last entry index such
    /// that all entries *before* it fit in the scratchpad. The entry at
    /// the pointer itself may exceed capacity — it is the *streaming*
    /// loop: its data makes a single pass through the scratchpad (the
    /// overlap primitive loads only `s` new inputs per step, Fig. 8(a)),
    /// so it still counts as reused. Loops outside the pointer re-stream
    /// the tile and multiply movement (Eq. (8)).
    pub(crate) fn pointers(
        op: &GconvOp,
        accel: &AccelStructure,
        temporal: &[UnrollEntry],
    ) -> [Option<usize>; 3] {
        let mut t = TileTracker::new(op);
        let mut ptrs = [None, None, None];
        for (idx, e) in temporal.iter().enumerate() {
            // Prefix (everything before `idx`) must be resident; entry
            // `idx` itself streams.
            for (slot, x) in ['i', 'o', 'k'].into_iter().enumerate() {
                if t.tile_with(x, None) <= accel.ls_cap(x) {
                    ptrs[slot] = Some(idx);
                }
            }
            t.apply(e.dim, e.param, e.factor);
        }
        ptrs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::{eyeriss, nlr, tpu};
    use crate::gconv::op::{DataRef, DimParams};

    fn conv_op() -> GconvOp {
        // A DenseNet-ish 3x3 conv: 32 kernels of 3x3x16 on 16x56x56, batch 32.
        GconvOp::conv(
            "conv",
            vec![
                (Dim::B, DimParams::opc(32)),
                (Dim::C, DimParams { nop: 32, nks: 16, ..Default::default() }),
                (Dim::H, DimParams::window(56, 3, 1, 1)),
                (Dim::W, DimParams::window(56, 3, 1, 1)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        )
    }

    /// Invariant: spatial factors × temporal iterations cover the nest.
    fn covers_all_loops(op: &GconvOp, m: &Mapping) {
        for &(d, dp) in &op.dims {
            for p in Param::ALL {
                let n = dp.get(p);
                let sp = m.spatial_factor(d, p);
                let tp: usize = m
                    .temporal
                    .iter()
                    .filter(|e| e.dim == d && e.param == p)
                    .map(|e| e.factor)
                    .product();
                assert!(
                    sp * tp >= n,
                    "loop [{d}][{p}] = {n} not covered: spatial {sp} x temporal {tp}"
                );
            }
        }
    }

    #[test]
    fn eyeriss_gconv_mapping_covers_loops() {
        let op = conv_op();
        let m = map_gconv(&op, &eyeriss(), MapMode::Gconv);
        covers_all_loops(&op, &m);
    }

    #[test]
    fn eyeriss_overlap_primitive_takes_ks_in_py() {
        // Fig. 9(a): the first overlap dim's ks lands on py.
        let op = conv_op();
        let m = map_gconv(&op, &eyeriss(), MapMode::Gconv);
        let py = &m.spatial[0];
        assert_eq!(py[0].param, Param::Ks);
        assert!(matches!(py[0].dim, Dim::W | Dim::H));
        assert_eq!(py[0].factor, 3);
    }

    #[test]
    fn occupied_pes_never_exceed_array() {
        for accel in [eyeriss(), tpu(), nlr()] {
            let m = map_gconv(&conv_op(), &accel, MapMode::Gconv);
            assert!(m.occupied_pes() <= accel.pes(), "{}", accel.name);
        }
    }

    #[test]
    fn baseline_nlr_only_unrolls_channels() {
        let m = map_gconv(&conv_op(), &nlr(), MapMode::Baseline);
        for axis in &m.spatial {
            for e in axis {
                assert_eq!(e.dim, Dim::C, "NLR baseline must stay in C, got {:?}", e);
            }
        }
    }

    #[test]
    fn gconv_mapping_beats_baseline_on_depthwise() {
        // Depthwise conv: no channel reduction — NLR's baseline dataflow
        // (C only) starves, the GCONV mapping spreads over H/W.
        let dw = GconvOp::conv(
            "dw",
            vec![
                (Dim::B, DimParams::opc(32)),
                (Dim::C, DimParams::g(64)),
                (Dim::H, DimParams::window(56, 3, 1, 1)),
                (Dim::W, DimParams::window(56, 3, 1, 1)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        );
        let a = nlr();
        let base = map_gconv(&dw, &a, MapMode::Baseline);
        let gc = map_gconv(&dw, &a, MapMode::Gconv);
        assert!(gc.occupied_pes() > base.occupied_pes());
    }

    #[test]
    fn temporal_tiles_respect_scratchpads() {
        let op = conv_op();
        let accel = eyeriss();
        let m = map_gconv(&op, &accel, MapMode::Gconv);
        let ptrs = TileTracker::pointers(&op, &accel, &m.temporal);
        // Eyeriss has a 224-word KLS: at least one temporal loop must be
        // kernel-resident.
        assert!(ptrs[2].is_some(), "klst should cover some temporal loops");
    }

    #[test]
    fn elementwise_op_maps_without_panic() {
        let ew = GconvOp {
            name: "relu".into(),
            dims: vec![(Dim::B, DimParams::opc(32)), (Dim::C, DimParams::opc(64))],
            pre: crate::gconv::op::PreOp::None,
            main: crate::gconv::op::MainOp::Pass,
            reduce: crate::gconv::op::ReduceOp::None,
            post: crate::gconv::op::PostOp::Lut("relu"),
            input: DataRef::External("x".into()),
            kernel: None,
        };
        for accel in crate::accel::configs::all_accelerators() {
            let m = map_gconv(&ew, &accel, MapMode::Gconv);
            covers_all_loops(&ew, &m);
        }
    }
}
