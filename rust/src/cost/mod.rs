//! Whole-life cost models (paper §6.6): development cost (Fig. 20) and
//! total cost of ownership (Fig. 21).

pub mod dev;
pub mod tco;

pub use dev::{dev_cost, DevCostParams, Platform};
pub use tco::{tco, TcoParams};
