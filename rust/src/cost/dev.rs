//! Development-cost model (paper Fig. 20): hardware + software
//! non-recurring engineering (NRE) plus per-update costs, as a function
//! of the number of network-generation updates.
//!
//! Constants from §6.6: hardware NRE quoted at 152 k$ (TIP), 165 k$
//! (GC-CIP) and 220 k$ (LIP) [43]; each update costs a LIP another
//! 200 k$ of hardware design; software costs derive from engineer
//! salary [44] at the canonical 10 lines of (shippable) code per day
//! [45].

/// Accelerator platform for the whole-life cost comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Platform {
    /// Tensor instruction processor.
    Tip,
    /// GCONV-Chain-armed CIP.
    GcCip,
    /// Layer instruction processor.
    Lip,
}

impl Platform {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Platform::Tip => "TIP",
            Platform::GcCip => "GC-CIP",
            Platform::Lip => "LIP",
        }
    }
}

/// Cost-model parameters.
#[derive(Clone, Copy, Debug)]
pub struct DevCostParams {
    /// Engineer cost per line of code: (salary $75/h × 8 h) / 10 LoC.
    pub usd_per_loc: f64,
    /// Hardware NRE per platform in USD (TIP, GC-CIP, LIP).
    pub hw_nre: [f64; 3],
    /// LIP hardware redesign per update.
    pub lip_hw_update: f64,
    /// Initial compiler size in LoC (TIP, GC-CIP, LIP). The TIP software
    /// stack is the largest: explicit data loading and matrix/vector
    /// code generation per layer (§6.4: worst code density).
    pub sw_nre_loc: [f64; 3],
    /// LoC to support one new layer generation (TIP, GC-CIP, LIP).
    /// GC-CIP only adds a lowering recipe; the TIP also needs new
    /// kernels + codegen; the LIP needs a driver for its new unit.
    pub sw_update_loc: [f64; 3],
}

impl Default for DevCostParams {
    fn default() -> Self {
        DevCostParams {
            usd_per_loc: 60.0,
            hw_nre: [152_000.0, 165_000.0, 220_000.0],
            lip_hw_update: 200_000.0,
            sw_nre_loc: [2_000.0, 1_400.0, 1_000.0],
            sw_update_loc: [100.0, 45.0, 80.0],
        }
    }
}

/// Cumulative development cost after `updates` network-generation
/// updates, split `(hardware, software)`.
pub fn dev_cost(p: &DevCostParams, platform: Platform, updates: usize) -> (f64, f64) {
    let i = match platform {
        Platform::Tip => 0,
        Platform::GcCip => 1,
        Platform::Lip => 2,
    };
    let mut hw = p.hw_nre[i];
    if platform == Platform::Lip {
        hw += p.lip_hw_update * updates as f64;
    }
    let sw = (p.sw_nre_loc[i] + p.sw_update_loc[i] * updates as f64) * p.usd_per_loc;
    (hw, sw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gc_cip_hw_nre_slightly_above_tip() {
        // §6.6: "GC-CIPs consume more in the hardware than TIPs".
        let p = DevCostParams::default();
        let (tip_hw, _) = dev_cost(&p, Platform::Tip, 0);
        let (gc_hw, _) = dev_cost(&p, Platform::GcCip, 0);
        assert!(gc_hw > tip_hw);
        assert!(gc_hw - tip_hw < 20_000.0);
    }

    #[test]
    fn tip_software_gap_widens_with_updates() {
        // §6.6: "60K additional USDs ... for TIPs than GC-CIPs after ten
        // updates" (total development cost gap).
        let p = DevCostParams::default();
        let total = |pl, u| {
            let (h, s) = dev_cost(&p, pl, u);
            h + s
        };
        let gap10 = total(Platform::Tip, 10) - total(Platform::GcCip, 10);
        assert!(
            (40_000.0..100_000.0).contains(&gap10),
            "gap after 10 updates = {gap10}"
        );
        let gap0 = total(Platform::Tip, 0) - total(Platform::GcCip, 0);
        assert!(gap10 > gap0);
    }

    #[test]
    fn lip_updates_dominate_everything() {
        // 200 k$ hardware redesign per update makes LIP the most
        // expensive to keep current.
        let p = DevCostParams::default();
        let (lip_hw, lip_sw) = dev_cost(&p, Platform::Lip, 10);
        let (tip_hw, tip_sw) = dev_cost(&p, Platform::Tip, 10);
        assert!(lip_hw + lip_sw > 2.0 * (tip_hw + tip_sw));
    }
}
