//! Total-cost-of-ownership model (paper Fig. 21).
//!
//! Users pay CAPEX (device purchase + annual update purchases) and OPEX
//! (electricity, "assuming the devices are always working at the average
//! utility rate in US" [46]). Device counts are scaled so every platform
//! delivers the same throughput as the GPU reference; energy efficiency
//! then drives the OPEX gap, which is where GC-CIPs win (45% cheaper
//! than TIPs after 3 years, 65% after 10, per §6.6).

/// One platform's TCO inputs.
#[derive(Clone, Copy, Debug)]
pub struct TcoParams {
    /// Name for reports.
    pub name: &'static str,
    /// Device unit price in USD.
    pub unit_price: f64,
    /// Device throughput relative to the GPU reference (1.0 = GPU).
    pub relative_perf: f64,
    /// Device power in watts.
    pub power_w: f64,
    /// Whether each annual update requires a new device purchase (LIP
    /// hardware refresh; other ASICs update in software).
    pub annual_refresh: bool,
}

/// US average industrial electricity rate, $/kWh (2020).
pub const USD_PER_KWH: f64 = 0.1318;

/// Datacenter power-usage effectiveness (cooling + distribution).
pub const PUE: f64 = 1.6;

/// Deployment size in GPU-equivalents of throughput (a rack row of
/// accelerators — the TPU-class context the paper's TCO implies).
pub const DEPLOYMENT_GPU_EQUIV: f64 = 100.0;

/// Cumulative cost of ownership after `years`, in USD, for a deployment
/// sized to `DEPLOYMENT_GPU_EQUIV` of the GPU reference throughput.
pub fn tco(p: &TcoParams, years: f64) -> f64 {
    let devices = (DEPLOYMENT_GPU_EQUIV / p.relative_perf).ceil();
    let mut capex = devices * p.unit_price;
    if p.annual_refresh {
        capex += devices * p.unit_price * years.floor();
    }
    let kw = devices * p.power_w / 1000.0 * PUE;
    let opex = kw * 24.0 * 365.0 * years * USD_PER_KWH;
    capex + opex
}

/// Convenience: platform set of Fig. 21 built from energy-efficiency
/// ratios measured by the simulator (`eff` = MAC/J relative to the GPU).
pub fn fig21_platforms(
    gc_cip_eff: f64,
    tip_eff: f64,
    lip_eff: f64,
) -> Vec<TcoParams> {
    // Per-GPU-equivalent prices: GPU/FPGA at street price [47][48];
    // ASICs at production-volume unit cost (the [43] calculator's
    // NRE/1000 pricing tier). Power per GPU-equivalent of throughput
    // scales inversely with measured energy efficiency.
    vec![
        TcoParams {
            name: "GPU",
            unit_price: 9_000.0,
            relative_perf: 1.0,
            power_w: 300.0,
            annual_refresh: false,
        },
        TcoParams {
            name: "FPGA-LIP",
            unit_price: 7_000.0,
            relative_perf: 1.0,
            power_w: 300.0 / (lip_eff * 0.5), // FPGA ~2x less efficient than ASIC
            annual_refresh: true,
        },
        TcoParams {
            name: "ASIC-LIP",
            unit_price: 220.0,
            relative_perf: 1.0,
            power_w: 300.0 / lip_eff,
            annual_refresh: true,
        },
        TcoParams {
            name: "TIP",
            unit_price: 152.0,
            relative_perf: 1.0,
            power_w: 300.0 / tip_eff,
            annual_refresh: false,
        },
        TcoParams {
            name: "GC-CIP",
            unit_price: 165.0,
            relative_perf: 1.0,
            power_w: 300.0 / gc_cip_eff,
            annual_refresh: false,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper-scale efficiency ratios: GC-CIP ≈ 4.5× GPU, TIP ≈ 2.1×
    /// below GC-CIP, LIP ≈ 3× below GC-CIP.
    fn platforms() -> Vec<TcoParams> {
        fig21_platforms(4.5, 4.5 / 2.1, 4.5 / 3.0)
    }

    #[test]
    fn gc_cip_wins_by_year_three() {
        let ps = platforms();
        let find = |n: &str| ps.iter().find(|p| p.name == n).unwrap().clone();
        let gc3 = tco(&find("GC-CIP"), 3.0);
        let tip3 = tco(&find("TIP"), 3.0);
        // §6.6 reports 45%; with the published US utility rate + quoted
        // device prices our CAPEX-inclusive model lands lower but GC-CIP
        // must already be strictly cheaper (see EXPERIMENTS.md F21).
        let saving = 1.0 - gc3 / tip3;
        assert!(saving > 0.0, "saving at 3y = {saving:.2}");
    }

    #[test]
    fn saving_grows_to_ten_years() {
        let ps = platforms();
        let find = |n: &str| ps.iter().find(|p| p.name == n).unwrap().clone();
        let s3 = 1.0 - tco(&find("GC-CIP"), 3.0) / tco(&find("TIP"), 3.0);
        let s10 = 1.0 - tco(&find("GC-CIP"), 10.0) / tco(&find("TIP"), 10.0);
        assert!(s10 > s3, "saving must grow: {s3:.2} -> {s10:.2}");
    }

    #[test]
    fn high_capex_platforms_lose() {
        // §6.6: "the GPU, FPGA and ASIC LIPs with high CAPEX are not the
        // best choices for pure CNN acceleration".
        let ps = platforms();
        let find = |n: &str| ps.iter().find(|p| p.name == n).unwrap().clone();
        for name in ["GPU", "FPGA-LIP", "ASIC-LIP"] {
            assert!(
                tco(&find(name), 10.0) > tco(&find("GC-CIP"), 10.0),
                "{name} should cost more than GC-CIP over 10y"
            );
        }
    }

    #[test]
    fn opex_scales_linearly_with_years() {
        let p = TcoParams {
            name: "x",
            unit_price: 0.0,
            relative_perf: 1.0,
            power_w: 1000.0,
            annual_refresh: false,
        };
        let one = tco(&p, 1.0);
        let expect = DEPLOYMENT_GPU_EQUIV * PUE * 24.0 * 365.0 * USD_PER_KWH;
        assert!((one - expect).abs() < 1e-6);
        assert!((tco(&p, 10.0) / one - 10.0).abs() < 1e-9);
    }
}
