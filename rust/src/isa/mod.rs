//! The GCONV instruction encoding (paper Fig. 11(a)).
//!
//! Three instruction buffers drive the GCONV-augmented accelerator:
//!
//! * **basic information** — stride, the four operator selectors, input
//!   and kernel producer ids; an all-zero entry delimits ops;
//! * **unrolling lists** — one `[dim, param, factor, argument]` entry
//!   per unrolling-list entry (Fig. 9), per unrolling dimension,
//!   delimited by all-zero entries;
//! * **output address** — one entry per GCONV, allocated at run time.
//!
//! Instruction *counts* from this encoding are the Fig. 15 code-length
//! metric; LIPs need a single instruction per layer and TIPs one
//! compute + loads per matrix tile ([`crate::accel::baseline`]).

use crate::gconv::chain::GconvChain;
use crate::gconv::op::{GconvOp, MainOp, Param, PostOp, PreOp, ReduceOp};
use crate::ir::Dim;
use crate::mapping::unroll::{Mapping, UnrollEntry};

/// One encoded instruction word (fields packed into u64).
pub type Word = u64;

/// Encoded program for one GCONV op.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GconvProgram {
    /// Basic-information buffer entries.
    pub basic: Vec<Word>,
    /// Unrolling-list buffer entries.
    pub unrolling: Vec<Word>,
    /// Output-address buffer entries.
    pub address: Vec<Word>,
}

impl GconvProgram {
    /// Total instruction entries (Fig. 15 metric).
    pub fn len(&self) -> usize {
        self.basic.len() + self.unrolling.len() + self.address.len()
    }

    /// True if no instructions.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn dim_code(d: Dim) -> u64 {
    match d {
        Dim::B => 1,
        Dim::C => 2,
        Dim::H => 3,
        Dim::W => 4,
        Dim::T => 5,
        Dim::V => 6,
    }
}

fn dim_from(code: u64) -> Dim {
    match code {
        1 => Dim::B,
        2 => Dim::C,
        3 => Dim::H,
        4 => Dim::W,
        5 => Dim::T,
        6 => Dim::V,
        c => panic!("bad dim code {c}"),
    }
}

fn param_code(p: Param) -> u64 {
    match p {
        Param::Ks => 1,
        Param::Opc => 2,
        Param::Op => 3,
        Param::G => 4,
    }
}

fn param_from(code: u64) -> Param {
    match code {
        1 => Param::Ks,
        2 => Param::Opc,
        3 => Param::Op,
        4 => Param::G,
        c => panic!("bad param code {c}"),
    }
}

fn operator_words(op: &GconvOp) -> Vec<Word> {
    // First field = operator type (1 pre, 2 main, 3 reduce, 4 post),
    // second = function selector. Absent operators are skipped (the
    // paper: "some GCONVs do not have pre, main, reduce or post").
    let mut v = Vec::new();
    let sel_pre = match op.pre {
        PreOp::None => 0,
        PreOp::Square => 1,
        PreOp::Mul(_) => 2,
        // Composed fusion pipelines encode as the LUT selector: the
        // hardware realizes them as one chained lookup table (§4.3).
        PreOp::Lut(_) | PreOp::Stack(_) => 3,
    };
    if sel_pre != 0 {
        v.push(1 << 8 | sel_pre);
    }
    let sel_main = match op.main {
        MainOp::Mul => 1,
        MainOp::Add => 2,
        MainOp::Sub => 3,
        MainOp::SquareDiff => 4,
        MainOp::And => 5,
        MainOp::Pass => 6,
        MainOp::Max => 7,
    };
    v.push(2 << 8 | sel_main);
    let sel_red = match op.reduce {
        ReduceOp::None => 0,
        ReduceOp::Add => 1,
        ReduceOp::Max => 2,
    };
    if sel_red != 0 {
        v.push(3 << 8 | sel_red);
    }
    let sel_post = match op.post {
        PostOp::None => 0,
        PostOp::Mul(_) => 1,
        PostOp::Lut(_) | PostOp::Stack(_) => 2,
    };
    if sel_post != 0 {
        v.push(4 << 8 | sel_post);
    }
    v
}

/// Encode one mapped GCONV into its instruction program.
pub fn encode(op: &GconvOp, mapping: &Mapping) -> GconvProgram {
    let mut p = GconvProgram::default();
    // Basic info: one stride entry per active dim + operator entries +
    // producer-id entries + all-zero delimiter.
    for &(d, dp) in &op.dims {
        p.basic.push(0xA << 60 | dim_code(d) << 32 | (dp.s as u64) << 16 | dp.ps as u64);
    }
    p.basic.extend(operator_words(op));
    p.basic.push(0xB << 60 | 1); // input producer id entry
    if op.kernel.is_some() {
        p.basic.push(0xB << 60 | 2); // kernel producer id entry
    }
    p.basic.push(0); // delimiter

    // Unrolling lists: spatial axes then temporal, each delimited.
    let encode_entry = |e: &UnrollEntry, arg: u64| -> Word {
        dim_code(e.dim) << 48 | param_code(e.param) << 40 | (e.factor as u64) << 16 | arg
    };
    for axis in &mapping.spatial {
        for e in axis {
            let arg = op.params(e.dim).get(e.param) as u64;
            p.unrolling.push(encode_entry(e, arg));
        }
        p.unrolling.push(0);
    }
    for e in &mapping.temporal {
        let arg = op.params(e.dim).get(e.param) as u64;
        p.unrolling.push(encode_entry(e, arg));
    }
    p.unrolling.push(0);

    // Output address (allocated at run time; encode a placeholder slot).
    p.address.push(0xC << 60);
    p
}

/// Decoded unrolling entry (for verification / the state machine).
pub fn decode_unrolling(words: &[Word]) -> Vec<Vec<UnrollEntry>> {
    let mut lists = Vec::new();
    let mut cur = Vec::new();
    for &w in words {
        if w == 0 {
            lists.push(std::mem::take(&mut cur));
            continue;
        }
        cur.push(UnrollEntry {
            dim: dim_from(w >> 48 & 0xFF),
            param: param_from(w >> 40 & 0xFF),
            factor: (w >> 16 & 0xFF_FFFF) as usize,
        });
    }
    if !cur.is_empty() {
        lists.push(cur);
    }
    lists
}

/// Code length of a whole chain on a GC-CIP (Fig. 15).
pub fn chain_code_length(chain: &GconvChain, mappings: &[Mapping]) -> usize {
    chain
        .entries()
        .iter()
        .zip(mappings)
        .map(|(e, m)| encode(&e.op, m).len())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::eyeriss;
    use crate::gconv::op::{DataRef, DimParams};
    use crate::mapping::unroll::{map_gconv, MapMode};

    fn conv_op() -> GconvOp {
        GconvOp::conv(
            "c",
            vec![
                (Dim::B, DimParams::opc(8)),
                (Dim::C, DimParams { nop: 16, nks: 8, ..Default::default() }),
                (Dim::H, DimParams::window(14, 3, 1, 1)),
                (Dim::W, DimParams::window(14, 3, 1, 1)),
            ],
            DataRef::External("x".into()),
            DataRef::Weights("w".into()),
        )
    }

    #[test]
    fn unrolling_round_trips() {
        let op = conv_op();
        let m = map_gconv(&op, &eyeriss(), MapMode::Gconv);
        let prog = encode(&op, &m);
        let lists = decode_unrolling(&prog.unrolling);
        // spatial axes + temporal list.
        assert_eq!(lists.len(), m.spatial.len() + 1);
        for (axis, decoded) in m.spatial.iter().zip(&lists) {
            assert_eq!(axis, decoded);
        }
        assert_eq!(&m.temporal, lists.last().unwrap());
    }

    #[test]
    fn kernel_less_ops_omit_kernel_producer() {
        let pool = GconvOp {
            kernel: None,
            reduce: ReduceOp::Max,
            main: MainOp::Pass,
            ..conv_op()
        };
        let m = map_gconv(&pool, &eyeriss(), MapMode::Gconv);
        let with_kernel = encode(&conv_op(), &map_gconv(&conv_op(), &eyeriss(), MapMode::Gconv));
        let without = encode(&pool, &m);
        assert!(without.basic.len() < with_kernel.basic.len());
    }

    #[test]
    fn program_length_counts_all_buffers() {
        let op = conv_op();
        let m = map_gconv(&op, &eyeriss(), MapMode::Gconv);
        let p = encode(&op, &m);
        assert_eq!(p.len(), p.basic.len() + p.unrolling.len() + p.address.len());
        assert!(p.len() > 5);
    }

    #[test]
    fn delimiters_are_all_zero_entries() {
        let op = conv_op();
        let m = map_gconv(&op, &eyeriss(), MapMode::Gconv);
        let p = encode(&op, &m);
        assert_eq!(*p.basic.last().unwrap(), 0);
        assert_eq!(*p.unrolling.last().unwrap(), 0);
    }
}
