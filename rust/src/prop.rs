//! Minimal in-repo property-testing support (no external crates are
//! available offline, so this stands in for `proptest`).
//!
//! [`Rng`] is a splitmix64/xorshift-style deterministic generator; the
//! [`prop_check`] helper runs a closure over many generated cases and
//! reports the seed of the first failing case so it can be replayed.

/// Deterministic 64-bit PRNG (splitmix64). Good enough statistical
/// quality for test-case generation; NOT cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi, "bad range [{lo}, {hi}]");
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.int(0, xs.len() - 1)]
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Run `f` over `cases` generated cases. On failure (panic or `Err`),
/// panics with the offending case index + seed so it can be replayed with
/// `Rng::new(seed)`.
pub fn prop_check<F>(cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base_seed = 0xC0FF_EE00_D15E_A5E5u64;
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn int_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let v = rng.int(3, 17);
            assert!((3..=17).contains(&v));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn prop_check_reports_failure() {
        prop_check(10, |rng| {
            if rng.int(0, 3) == 0 { Err("boom".into()) } else { Ok(()) }
        });
    }
}
