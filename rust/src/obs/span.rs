//! Monotonic span stamps and chrome://tracing trace events.
//!
//! All span math is `Instant`-based (monotonic) — `SystemTime` is
//! banned from this module by `ci/lint-denylist.sh` because wall-clock
//! steps (NTP, suspend) would corrupt latency deltas.

use std::time::{Duration, Instant};

/// A started span: one monotonic stamp, measured on demand. The
/// typical shape is `let s = Span::start(); ...; hist.record(s.elapsed_ns())`.
pub struct Span(Instant);

impl Span {
    #[inline]
    pub fn start() -> Span {
        Span(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed nanoseconds, saturated into `u64` (584 years — the cast
    /// can only truncate on a clock that has left the building).
    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.0.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// One complete event (`"ph": "X"`) in the chrome://tracing JSON
/// format — `export::trace_json` renders a slice of these into a file
/// chrome://tracing / Perfetto can open directly.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event label (layer name, stage name).
    pub name: String,
    /// Category — groups related events in the trace UI (e.g. a
    /// kernel tier or a pipeline stage).
    pub cat: String,
    /// Start offset in microseconds from the beginning of the trace.
    pub ts_us: f64,
    /// Duration in microseconds.
    pub dur_us: f64,
    /// Track (thread lane) the event renders on.
    pub tid: u64,
    /// Free-form key/value annotations (tier, gops, phase, ...).
    pub args: Vec<(String, String)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_measure_forward_time() {
        let s = Span::start();
        std::thread::sleep(Duration::from_millis(2));
        let ns = s.elapsed_ns();
        assert!(ns >= 2_000_000, "span measured {ns} ns for a 2 ms sleep");
        assert!(s.elapsed_ns() >= ns, "spans are monotonic");
    }
}
