//! Observability spine: metrics registry, latency histograms, span
//! stamps, and export surfaces (Prometheus text, chrome://tracing
//! JSON).
//!
//! Design rules, in the style of [`crate::exec::faults`]:
//!
//! * **Lock-light** — registration takes a mutex once; every handle
//!   after that is a relaxed atomic with zero allocation.
//! * **Disarmed by default** — the per-entry kernel timing hooks in
//!   `exec::interp::eval_bound` cost exactly one relaxed load
//!   ([`profiling`]) until a [`profile`] guard arms them; serving
//!   output is bit-identical armed or disarmed.
//! * **Monotonic spans** — all span math uses `Instant`
//!   ([`span::Span`]); `SystemTime` is denied by `ci/lint-denylist.sh`.
//!
//! Layering: `obs` is a leaf — it depends only on `std`. The exec
//! engine mirrors kernel/session/pool/engine metrics into the
//! process-[`global`] registry; the TCP server owns one registry per
//! listener (`server::Counters`) so concurrent servers never
//! co-mingle, and answers wire kind-6 requests with a capped kind-7
//! Prometheus exposition.

pub mod export;
pub mod hist;
pub mod registry;
pub mod span;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

pub use hist::Hist;
pub use registry::{global, Counter, Gauge, MetricSnapshot, Registry};
pub use span::{Span, TraceEvent};

/// Get-or-register a counter in the process-global registry.
pub fn counter(name: &str) -> Arc<Counter> {
    global().counter(name)
}

/// Get-or-register a gauge in the process-global registry.
pub fn gauge(name: &str) -> Arc<Gauge> {
    global().gauge(name)
}

/// Get-or-register a histogram in the process-global registry.
pub fn hist(name: &str) -> Arc<Hist> {
    global().hist(name)
}

static PROFILING: AtomicBool = AtomicBool::new(false);

fn arm_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Whether per-entry kernel profiling is armed. This single relaxed
/// load is the *entire* disarmed-path cost of the `eval_bound` hooks.
#[inline]
pub fn profiling() -> bool {
    PROFILING.load(Ordering::Relaxed)
}

/// RAII guard returned by [`profile`]; dropping it disarms the
/// per-entry kernel timing hooks.
pub struct ProfileGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for ProfileGuard {
    fn drop(&mut self) {
        PROFILING.store(false, Ordering::SeqCst);
    }
}

/// Arm per-entry kernel profiling for the lifetime of the returned
/// guard. The guard holds an exclusive process-wide arm lock (the
/// discipline of `faults::FaultPlan::arm`), so concurrent tests that
/// arm profiling serialize instead of trampling each other.
pub fn profile() -> ProfileGuard {
    let lock = arm_lock().lock().unwrap_or_else(|e| e.into_inner());
    PROFILING.store(true, Ordering::SeqCst);
    ProfileGuard { _lock: lock }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiling_is_disarmed_by_default_and_guard_scoped() {
        let guard = profile();
        assert!(profiling());
        drop(guard);
        // Whenever the arm lock is free, profiling is disarmed (the
        // guard stores `false` before releasing the lock) — so holding
        // the lock makes this assertion race-free against other tests.
        let _lock = arm_lock().lock().unwrap_or_else(|e| e.into_inner());
        assert!(!profiling());
    }
}
