//! Export surfaces: Prometheus-style text exposition and
//! chrome://tracing JSON.
//!
//! Both renderers are pure functions over snapshots — no registry
//! locks are held while formatting, and the wire layer can cap the
//! exposition with [`truncate_text`] without re-rendering.

use super::registry::{snapshot_name, MetricSnapshot};
use super::span::TraceEvent;

/// Render metric snapshots in the Prometheus text format. Counters and
/// gauges emit `# TYPE` + one sample line; histograms emit the summary
/// form (`{quantile="0.5"}`, `{quantile="0.99"}`, `_sum`, `_count`)
/// with nanosecond-quantized quantiles.
pub fn render_text(snapshots: &[MetricSnapshot]) -> String {
    let mut out = String::new();
    for s in snapshots {
        let name = snapshot_name(s);
        match s {
            MetricSnapshot::Counter { value, .. } => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {value}\n"));
            }
            MetricSnapshot::Gauge { value, .. } => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {value}\n"));
            }
            MetricSnapshot::Hist { count, sum, p50, p99, .. } => {
                out.push_str(&format!(
                    "# TYPE {name} summary\n\
                     {name}{{quantile=\"0.5\"}} {p50}\n\
                     {name}{{quantile=\"0.99\"}} {p99}\n\
                     {name}_sum {sum}\n\
                     {name}_count {count}\n"
                ));
            }
        }
    }
    out
}

/// Cap an exposition at `max_bytes`, cutting at a line boundary so the
/// result stays parseable (the wire layer applies the metrics-frame
/// cap with this before framing).
pub fn truncate_text(text: &str, max_bytes: usize) -> &str {
    if text.len() <= max_bytes {
        return text;
    }
    match text[..max_bytes].rfind('\n') {
        Some(cut) => &text[..=cut],
        None => "",
    }
}

/// Pull one sample value out of an exposition: the `u64` on the line
/// whose first token is exactly `name`. Tests and smoke scripts use
/// this instead of a real Prometheus parser.
pub fn scrape(text: &str, name: &str) -> Option<u64> {
    text.lines().find_map(|line| {
        let mut toks = line.split_whitespace();
        if toks.next() != Some(name) {
            return None;
        }
        toks.next().and_then(|v| v.parse().ok())
    })
}

/// Render trace events as chrome://tracing JSON (the
/// `{"traceEvents": [...]}` object form, complete `"ph": "X"` events)
/// — openable directly in chrome://tracing or Perfetto.
pub fn trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{:.3},\"dur\":{:.3},\"args\":{{",
            esc(&e.name),
            esc(&e.cat),
            e.tid,
            e.ts_us,
            e.dur_us
        ));
        for (j, (k, v)) in e.args.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":\"{}\"", esc(k), esc(v)));
        }
        out.push_str("}}");
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;

    #[test]
    fn exposition_renders_all_three_kinds_and_scrapes_back() {
        let reg = Registry::new();
        reg.counter("gconv_reqs").add(6);
        reg.gauge("gconv_depth").set(2);
        let h = reg.hist("gconv_lat_ns");
        h.record(1000);
        h.record(3000);
        let text = reg.render_text();
        assert!(text.contains("# TYPE gconv_reqs counter\n"), "{text}");
        assert!(text.contains("# TYPE gconv_depth gauge\n"), "{text}");
        assert!(text.contains("# TYPE gconv_lat_ns summary\n"), "{text}");
        assert_eq!(scrape(&text, "gconv_reqs"), Some(6));
        assert_eq!(scrape(&text, "gconv_depth"), Some(2));
        assert_eq!(scrape(&text, "gconv_lat_ns_count"), Some(2));
        assert_eq!(scrape(&text, "gconv_lat_ns_sum"), Some(4000));
        assert_eq!(
            scrape(&text, "gconv_lat_ns{quantile=\"0.5\"}"),
            Some(crate::obs::hist::quantize(1000))
        );
        assert_eq!(scrape(&text, "gconv_missing"), None);
    }

    #[test]
    fn truncation_cuts_at_line_boundaries() {
        let text = "aaa 1\nbbb 2\nccc 3\n";
        assert_eq!(truncate_text(text, text.len()), text);
        let cut = truncate_text(text, 13);
        assert_eq!(cut, "aaa 1\nbbb 2\n");
        assert_eq!(truncate_text(text, 3), "");
    }

    #[test]
    fn trace_json_is_well_formed_and_escaped() {
        let events = vec![TraceEvent {
            name: "conv\"1".into(),
            cat: "gemm".into(),
            ts_us: 0.0,
            dur_us: 12.5,
            tid: 0,
            args: vec![("tier".into(), "Gemm".into()), ("gops".into(), "3.2".into())],
        }];
        let json = trace_json(&events);
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.contains("\\\"1"), "quote must be escaped: {json}");
        assert!(json.contains("\"ph\":\"X\""), "{json}");
        assert!(json.contains("\"dur\":12.500"), "{json}");
        assert!(json.trim_end().ends_with("\"displayTimeUnit\":\"ms\"}"), "{json}");
    }
}
