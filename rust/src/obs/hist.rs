//! Fixed-bucket log-scale latency histogram with nearest-rank
//! percentile extraction.
//!
//! Values (nanoseconds by convention) are quantized to power-of-two
//! buckets: bucket `i` holds the values whose bit length is `i` — the
//! range `[2^(i-1), 2^i)` — with bucket 0 reserved for zero and the top
//! bucket absorbing everything from `2^63` up (recording *saturates*
//! into it; nothing is ever dropped). Recording is one relaxed
//! `fetch_add` per atomic — no locks, no allocation — so it is safe on
//! the kernel hot path once profiling is armed.
//!
//! Percentiles use the same nearest-rank convention as
//! `exec::bench::percentile` (rank = `count * p / 100`, clamped to the
//! last sample; 0 when empty): the reported value is the *upper bound*
//! of the bucket holding the nearest-rank sample. Rank selection is
//! exact — never interpolated — and the value is exact up to the
//! log-bucket quantization, which [`quantize`] exposes so tests can pin
//! the histogram against a sorted-vector oracle.

use std::sync::atomic::{AtomicU64, Ordering};

/// One bucket per possible bit length of a `u64` (1..=64), plus bucket
/// 0 for the value zero.
pub const NUM_BUCKETS: usize = 65;

/// The quantization applied by [`Hist::record`]: the upper bound of
/// the bucket that `v` lands in. Monotonic, so the nearest-rank sample
/// of the quantized multiset is the quantization of the nearest-rank
/// raw sample — the property the oracle tests lean on.
pub fn quantize(v: u64) -> u64 {
    bound(index(v))
}

/// Bucket index of `v`: its bit length (0 for zero, 64 for the top).
fn index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i`.
fn bound(i: usize) -> u64 {
    if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Log-scale latency histogram. All state is `AtomicU64`; construction
/// is the only allocation-ish moment (it is `const`-free but heap-free),
/// and every operation after that is wait-free.
pub struct Hist {
    counts: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Hist {
    pub fn new() -> Self {
        Hist {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation: three relaxed `fetch_add`s, zero
    /// allocation, no lock. Values past the top bucket bound saturate
    /// into the top bucket.
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all raw (unquantized) observations.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Nearest-rank percentile (`p` in 0..=100): the bucket upper bound
    /// of sample number `count * p / 100` (clamped to the last sample),
    /// or 0 with no observations — the convention of
    /// `exec::bench::percentile`.
    pub fn percentile(&self, p: u64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((total as u128 * p as u128 / 100) as u64).min(total - 1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen = seen.saturating_add(c.load(Ordering::Relaxed));
            if seen > rank {
                return bound(i);
            }
        }
        u64::MAX
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_quantize_to_power_of_two_upper_bounds() {
        assert_eq!(quantize(0), 0);
        assert_eq!(quantize(1), 1);
        assert_eq!(quantize(2), 3);
        assert_eq!(quantize(3), 3);
        assert_eq!(quantize(4), 7);
        assert_eq!(quantize(7), 7);
        assert_eq!(quantize(8), 15);
        assert_eq!(quantize(1023), 1023);
        assert_eq!(quantize(1024), 2047);
        assert_eq!(quantize((1 << 62) + 1), (1 << 63) - 1);
    }

    #[test]
    fn top_bucket_saturates_instead_of_dropping() {
        let h = Hist::new();
        h.record(1 << 63);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(50), u64::MAX);
        assert_eq!(h.percentile(99), u64::MAX);
        assert_eq!(quantize(1 << 63), u64::MAX);
        assert_eq!(quantize(u64::MAX), u64::MAX);
    }

    #[test]
    fn empty_histogram_reports_zero_everywhere() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
        assert_eq!(h.percentile(50), 0);
        assert_eq!(h.percentile(99), 0);
    }

    #[test]
    fn single_sample_is_its_own_p50_and_p99() {
        let h = Hist::new();
        h.record(300);
        assert_eq!(h.percentile(50), quantize(300));
        assert_eq!(h.percentile(99), quantize(300));
        assert_eq!(h.sum(), 300);
    }

    /// The acceptance oracle: on random samples, nearest-rank p50/p99
    /// out of the histogram must equal the quantization of the
    /// nearest-rank element of the sorted raw samples — the exact
    /// convention `exec::bench::percentile` uses, bucket-quantized.
    #[test]
    fn percentiles_match_a_sorted_vec_oracle_on_random_samples() {
        let mut rng = crate::prop::Rng::new(0x0B5_CAFE);
        for round in 0..8u64 {
            let n = 10 + (rng.f64() * 500.0) as usize;
            let h = Hist::new();
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                // Span many decades so every bucket scale gets hit.
                let v = (rng.f64() * rng.f64() * 1.0e12) as u64;
                samples.push(v);
                h.record(v);
            }
            samples.sort_unstable();
            for p in [0u64, 50, 90, 99, 100] {
                let rank = ((n as u64 * p / 100) as usize).min(n - 1);
                let expect = quantize(samples[rank]);
                assert_eq!(
                    h.percentile(p),
                    expect,
                    "round {round}: p{p} over {n} samples diverged from the oracle"
                );
            }
            assert_eq!(h.count(), n as u64);
            assert_eq!(h.sum(), samples.iter().sum::<u64>());
        }
    }
}
