//! Lock-light metric registry: named monotonic counters, gauges, and
//! latency histograms.
//!
//! Registration (a name lookup under one mutex) may allocate; the
//! handles it returns are `Arc`s whose operations are single relaxed
//! atomic ops with **zero allocation and zero locking** — the registry
//! is only locked again to take a snapshot. Two deployment shapes:
//!
//! * [`global()`] — one process-wide registry carrying engine-side
//!   metrics (kernel/session/pool/engine). The `profile` CLI and the
//!   bench harness read it directly.
//! * Instance registries ([`Registry::new`]) — the TCP server gives
//!   each listener its own registry (inside `server::Counters`) so
//!   concurrent servers (tests, multi-tenant processes) never
//!   co-mingle counts, and a health snapshot equals its registry by
//!   construction.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::hist::Hist;

/// Monotonic counter. `inc`/`add` are single relaxed `fetch_add`s.
pub struct Counter(AtomicU64);

impl Counter {
    fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge: a value that moves both ways (queue depths, high-water
/// marks). `dec` saturates at zero rather than wrapping.
pub struct Gauge(AtomicU64);

impl Gauge {
    fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment and return the post-increment value (race-exact, for
    /// high-water tracking: `max.maximize(depth.inc_and_get())`).
    #[inline]
    pub fn inc_and_get(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed) + 1
    }

    #[inline]
    pub fn dec(&self) {
        self.sub(1);
    }

    /// Subtract `n`, saturating at zero rather than wrapping.
    #[inline]
    pub fn sub(&self, n: u64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(n)));
    }

    /// Ratchet the gauge up to `v` if `v` is larger (high-water marks).
    #[inline]
    pub fn maximize(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

/// A point-in-time reading of one metric, for rendering and tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MetricSnapshot {
    Counter { name: String, value: u64 },
    Gauge { name: String, value: u64 },
    Hist { name: String, count: u64, sum: u64, p50: u64, p99: u64 },
}

/// Named metric store. Hot-path cost lives entirely in the handles;
/// the registry itself is only touched at registration and snapshot
/// time.
pub struct Registry {
    entries: Mutex<Vec<(String, Metric)>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry { entries: Mutex::new(Vec::new()) }
    }

    /// Get-or-register the counter `name`. If `name` is already
    /// registered as a *different* kind (a programming error), a
    /// detached handle is returned so the caller still never panics
    /// and the rendered output stays unambiguous.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut entries = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((_, metric)) = entries.iter().find(|(n, _)| n == name) {
            if let Metric::Counter(c) = metric {
                return Arc::clone(c);
            }
            return Arc::new(Counter::new());
        }
        let c = Arc::new(Counter::new());
        entries.push((name.to_string(), Metric::Counter(Arc::clone(&c))));
        c
    }

    /// Get-or-register the gauge `name` (same kind-mismatch rule as
    /// [`Registry::counter`]).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut entries = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((_, metric)) = entries.iter().find(|(n, _)| n == name) {
            if let Metric::Gauge(g) = metric {
                return Arc::clone(g);
            }
            return Arc::new(Gauge::new());
        }
        let g = Arc::new(Gauge::new());
        entries.push((name.to_string(), Metric::Gauge(Arc::clone(&g))));
        g
    }

    /// Get-or-register the histogram `name` (same kind-mismatch rule
    /// as [`Registry::counter`]).
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        let mut entries = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((_, metric)) = entries.iter().find(|(n, _)| n == name) {
            if let Metric::Hist(h) = metric {
                return Arc::clone(h);
            }
            return Arc::new(Hist::new());
        }
        let h = Arc::new(Hist::new());
        entries.push((name.to_string(), Metric::Hist(Arc::clone(&h))));
        h
    }

    /// Current value of the counter or gauge `name`, if registered.
    pub fn value(&self, name: &str) -> Option<u64> {
        let entries = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        entries.iter().find(|(n, _)| n == name).and_then(|(_, m)| match m {
            Metric::Counter(c) => Some(c.get()),
            Metric::Gauge(g) => Some(g.get()),
            Metric::Hist(_) => None,
        })
    }

    /// Point-in-time readings of every registered metric, sorted by
    /// name for deterministic rendering.
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        let entries = self.entries.lock().unwrap_or_else(|e| e.into_inner());
        let mut out: Vec<MetricSnapshot> = entries
            .iter()
            .map(|(name, metric)| match metric {
                Metric::Counter(c) => {
                    MetricSnapshot::Counter { name: name.clone(), value: c.get() }
                }
                Metric::Gauge(g) => MetricSnapshot::Gauge { name: name.clone(), value: g.get() },
                Metric::Hist(h) => MetricSnapshot::Hist {
                    name: name.clone(),
                    count: h.count(),
                    sum: h.sum(),
                    p50: h.percentile(50),
                    p99: h.percentile(99),
                },
            })
            .collect();
        out.sort_by(|a, b| snapshot_name(a).cmp(snapshot_name(b)));
        out
    }

    /// Prometheus-style text exposition of [`Registry::snapshot`].
    pub fn render_text(&self) -> String {
        super::export::render_text(&self.snapshot())
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

pub(crate) fn snapshot_name(s: &MetricSnapshot) -> &str {
    match s {
        MetricSnapshot::Counter { name, .. }
        | MetricSnapshot::Gauge { name, .. }
        | MetricSnapshot::Hist { name, .. } => name,
    }
}

/// The process-global registry carrying engine-side metrics.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent_and_handles_share_storage() {
        let reg = Registry::new();
        let a = reg.counter("reqs");
        let b = reg.counter("reqs");
        a.inc();
        b.add(2);
        assert_eq!(reg.value("reqs"), Some(3));
        assert_eq!(a.get(), 3);
    }

    #[test]
    fn kind_mismatch_returns_a_detached_handle_without_panicking() {
        let reg = Registry::new();
        let c = reg.counter("x");
        c.inc();
        let g = reg.gauge("x");
        g.set(99);
        // The registered metric keeps its original kind and value.
        assert_eq!(reg.value("x"), Some(1));
        assert_eq!(reg.snapshot().len(), 1);
    }

    #[test]
    fn gauges_move_both_ways_and_saturate_at_zero() {
        let reg = Registry::new();
        let g = reg.gauge("depth");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec();
        assert_eq!(g.get(), 0, "dec must saturate, not wrap");
        g.maximize(7);
        g.maximize(3);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn snapshot_is_sorted_and_covers_all_kinds() {
        let reg = Registry::new();
        reg.hist("z_lat").record(100);
        reg.counter("a_reqs").inc();
        reg.gauge("m_depth").set(4);
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.iter().map(snapshot_name).collect();
        assert_eq!(names, ["a_reqs", "m_depth", "z_lat"]);
        match &snap[2] {
            MetricSnapshot::Hist { count, sum, p50, .. } => {
                assert_eq!((*count, *sum), (1, 100));
                assert_eq!(*p50, crate::obs::hist::quantize(100));
            }
            other => panic!("expected a hist snapshot, got {other:?}"),
        }
    }
}
