//! Network serving front: a TCP request/response server over the
//! bind-once/run-many [`crate::exec::serve::Engine`].
//!
//! The paper's whole-life-cost argument (§2, §6) wants one GCONV
//! engine serving *every* workload end-to-end; this module gives the
//! engine a wire. std::net + threads only — the crate's dependency
//! discipline (anyhow + rayon, no async runtime) holds here too.
//!
//! * [`protocol`] — versioned length-prefixed binary frames with hard
//!   caps on frame size, name length, and rank, so a malformed header
//!   can never trigger a huge allocation.
//! * `conn` — per-connection reader/writer threads with poll-tick
//!   shutdown checks, mid-frame read deadlines (slow-client defense),
//!   and structured error replies.
//! * `scheduler` — a bounded submission queue bridging connection
//!   threads to the single engine driver thread; per-model admission
//!   control and queue-depth backpressure reject with `BUSY` rather
//!   than buffering unboundedly. The driver doubles as a *supervisor*:
//!   panics are caught per per-model wave group (structured `INTERNAL`
//!   replies, engine state purged and rebuilt), repeat offenders are
//!   quarantined (`QUARANTINED` at admission, other models unaffected),
//!   and queued jobs past their driver-side deadline answer `TIMEOUT`
//!   without being evaluated.
//! * `listener` — accept loop with a connection cap and graceful
//!   shutdown that drains in-flight micro-batches before closing.
//! * [`client`] — blocking client with seeded jittered-exponential
//!   `BUSY`-retry discipline and `health`/`metrics` probes, used by
//!   the CLI `client`/`stats` subcommands, the load benchmark, and
//!   tests.
//!
//! Observability: `server::Counters` is backed by a per-listener
//! [`crate::obs::Registry`] (concurrent servers never co-mingle
//! counts) plus per-request stage histograms (read / queue-wait /
//! eval / write). A kind-6 metrics request is answered inline with a
//! kind-7 Prometheus-style text frame — the listener's registry
//! concatenated with the process-global engine-side registry — without
//! consuming the request budget, exactly like health frames.
//!
//! Responses are bit-identical to in-process `Engine::submit`/`drain`
//! for the same inputs: the server adds routing, never arithmetic.
//! Failure paths are testable deterministically via the seeded
//! injection sites in [`crate::exec::faults`] (armed by the `--faults`
//! CLI flag or a test's `FaultPlan`); disarmed, every site is a single
//! relaxed atomic load.

pub mod client;
mod conn;
mod listener;
pub mod protocol;
mod scheduler;

pub use client::{Backoff, Client};
pub use listener::{serve, ServerConfig, ServerHandle, ServerReport};
pub use protocol::{
    ErrorCode, HealthField, HealthSnapshot, QuarantinedModel, Request, Response, HEALTH_FIELDS,
};
pub use scheduler::{Counters, Quarantine, SchedulerConfig};
