//! Accept loop, connection cap, and graceful shutdown.
//!
//! [`serve`] binds a `TcpListener`, starts the scheduler's engine
//! driver thread, and spawns one accept thread. Each accepted
//! connection gets its own thread (capped at
//! [`ServerConfig::max_conns`]; overflow connections are answered with
//! a `BUSY` error frame and closed). Shutdown is graceful by
//! construction: the accept thread stops accepting, joins every
//! connection thread, and drops its scheduler handle — at which point
//! the driver drains whatever the bounded queue still holds (the last
//! micro-batches) and exits, returning the engine for a final stats
//! report.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::exec::serve::{Engine, EngineStats};

use super::conn::{handle_conn, ConnConfig};
use super::protocol::{write_response, ErrorCode, QuarantinedModel, Response};
use super::scheduler::{self, Counters, SchedulerConfig};

/// Tunables of the serving front. Every limit is a hard bound — the
/// server never buffers past `queue_depth` or threads past
/// `max_conns`.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Concurrent connection cap; overflow is answered `BUSY`.
    pub max_conns: usize,
    /// Bounded submission queue depth (the backpressure point).
    pub queue_depth: usize,
    /// Per-model in-flight admission cap.
    pub per_model_inflight: usize,
    /// Mid-frame read deadline (slow-client bound).
    pub read_timeout: Duration,
    /// Socket write timeout for responses.
    pub write_timeout: Duration,
    /// How long a request may wait for the engine before `TIMEOUT`.
    pub request_timeout: Duration,
    /// Serve this many requests, then shut down gracefully (used by
    /// smoke tests and `--max-requests`); `None` serves forever.
    pub max_requests: Option<u64>,
    /// Driver panics a model may accumulate before it is quarantined
    /// (`0` disables quarantine; panics are still caught and answered
    /// `INTERNAL`).
    pub quarantine_after: u32,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 64,
            queue_depth: 64,
            per_model_inflight: 64,
            read_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            request_timeout: Duration::from_secs(30),
            max_requests: None,
            quarantine_after: 1,
        }
    }
}

/// Final tally of one server run.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Requests answered with an output frame.
    pub served: u64,
    /// Submissions rejected with `BUSY` backpressure.
    pub rejected_busy: u64,
    /// Requests answered with a non-`BUSY` error frame.
    pub errored: u64,
    /// Requests that timed out waiting for the engine.
    pub timeouts: u64,
    /// Requests whose driver-side deadline expired before evaluation.
    pub expired: u64,
    /// Submissions refused because their model was quarantined.
    pub quarantine_rejected: u64,
    /// Driver panics caught by the supervisor.
    pub panics: u64,
    /// Models quarantined at shutdown.
    pub quarantined: Vec<QuarantinedModel>,
    /// Frames refused as malformed/oversized.
    pub malformed: u64,
    /// Connections dropped for blowing the mid-frame read deadline.
    pub slow_clients: u64,
    /// Connections accepted.
    pub conns_accepted: u64,
    /// Connections refused at the connection cap.
    pub conns_rejected: u64,
    /// High-water mark of the bounded queue.
    pub max_queue_depth: usize,
    /// The engine's own counters (batches, coalescing, exec time).
    pub engine: EngineStats,
}

/// A running server. Dropping the handle does *not* stop the server —
/// call [`ServerHandle::shutdown`] or [`ServerHandle::wait`].
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: JoinHandle<()>,
    driver: JoinHandle<Engine>,
    counters: Arc<Counters>,
    quarantine: Arc<scheduler::Quarantine>,
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal shutdown, then wait for the drain to finish.
    pub fn shutdown(self) -> Result<ServerReport> {
        self.shutdown.store(true, Ordering::SeqCst);
        self.join()
    }

    /// Wait for the server to stop on its own (`max_requests`, or an
    /// external `shutdown` flag flip).
    pub fn wait(self) -> Result<ServerReport> {
        self.join()
    }

    /// The live counter set (registry-backed), for reading stage
    /// latency histograms mid-run — used by the serve bench's profile
    /// block before shutdown.
    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    fn join(self) -> Result<ServerReport> {
        self.accept.join().map_err(|_| anyhow!("server accept thread panicked"))?;
        let engine = self.driver.join().map_err(|_| anyhow!("engine driver thread panicked"))?;
        let c = &self.counters;
        Ok(ServerReport {
            served: c.completed.get(),
            rejected_busy: c.rejected_busy.get(),
            errored: c.errored.get(),
            timeouts: c.timeouts.get(),
            expired: c.expired.get(),
            quarantine_rejected: c.quarantine_rejected.get(),
            panics: c.panics.get(),
            quarantined: self.quarantine.snapshot(),
            malformed: c.malformed.get(),
            slow_clients: c.slow_clients.get(),
            conns_accepted: c.conns_accepted.get(),
            conns_rejected: c.conns_rejected.get(),
            max_queue_depth: c.max_queue_depth.get() as usize,
            engine: engine.stats(),
        })
    }
}

/// Bind `addr` and serve `engine` until shutdown. Returns immediately
/// with a handle; the accept loop, connection threads, and engine
/// driver all run in the background.
pub fn serve(addr: &str, engine: Engine, config: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr().context("resolving bound address")?;
    listener.set_nonblocking(true).context("setting the listener non-blocking")?;

    let counters = Arc::new(Counters::default());
    let shutdown = Arc::new(AtomicBool::new(false));
    let (sched, driver) = scheduler::start(
        engine,
        SchedulerConfig {
            queue_depth: config.queue_depth,
            per_model_cap: config.per_model_inflight,
            // The driver enforces the same deadline the connection
            // waits out, so a job the client has given up on is never
            // evaluated.
            deadline: Some(config.request_timeout),
            quarantine_after: config.quarantine_after,
        },
        counters.clone(),
    )
    .context("spawning the engine driver thread")?;
    let quarantine = sched.quarantine_arc();

    let accept_shutdown = shutdown.clone();
    let accept_counters = counters.clone();
    let accept = std::thread::Builder::new()
        .name("gconv-serve-accept".into())
        .spawn(move || {
            accept_loop(listener, sched, config, accept_shutdown, accept_counters);
        })
        .context("spawning the accept thread")?;

    Ok(ServerHandle { addr: local, shutdown, accept, driver, counters, quarantine })
}

fn accept_loop(
    listener: TcpListener,
    sched: scheduler::SchedulerHandle,
    config: ServerConfig,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    let conn_cfg = ConnConfig {
        frame_deadline: config.read_timeout,
        write_timeout: config.write_timeout,
        request_timeout: config.request_timeout,
    };
    let mut conns: HashMap<u64, JoinHandle<()>> = HashMap::new();
    let mut next_conn: u64 = 0;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        if let Some(cap) = config.max_requests {
            if counters.completed.get() >= cap {
                shutdown.store(true, Ordering::SeqCst);
                break;
            }
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                conns.retain(|_, h| !h.is_finished());
                if conns.len() >= config.max_conns {
                    counters.conns_rejected.inc();
                    refuse(stream, config);
                    continue;
                }
                counters.conns_accepted.inc();
                let id = next_conn;
                next_conn += 1;
                let sched = sched.clone();
                let shutdown = shutdown.clone();
                let counters = counters.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("gconv-serve-conn-{id}"))
                    .spawn(move || {
                        handle_conn(stream, peer, sched, conn_cfg, shutdown, counters);
                    });
                match spawned {
                    Ok(handle) => {
                        conns.insert(id, handle);
                    }
                    Err(_) => counters.conns_rejected.inc(),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    // Graceful drain: stop accepting, join every connection thread
    // (each notices the shutdown flag within one poll tick), then drop
    // the last scheduler handle so the driver finishes the queue.
    shutdown.store(true, Ordering::SeqCst);
    drop(sched);
    for (_, handle) in conns {
        let _ = handle.join();
    }
}

/// Answer an over-cap connection with `BUSY` and close it.
fn refuse(mut stream: TcpStream, config: ServerConfig) {
    let _ = stream.set_write_timeout(Some(config.write_timeout));
    let resp = Response::Error {
        code: ErrorCode::Busy,
        message: "connection cap reached — retry later".into(),
    };
    let _ = write_response(&mut stream, &resp);
}
