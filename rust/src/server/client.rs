//! Blocking client for the serving protocol.
//!
//! One [`Client`] wraps one TCP connection and issues requests
//! sequentially: write a request frame, read one response frame.
//! [`Client::infer_retry_busy`] layers the retry discipline the
//! backpressure design expects — a `BUSY` rejection means "the bounded
//! queue is full right now", so the client backs off and resends, and
//! reports how many rejections it absorbed.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{read_response, write_request, ErrorCode, Response};

/// A connected protocol client.
pub struct Client {
    stream: TcpStream,
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolved to no address"))
}

impl Client {
    /// Connect with a timeout.
    pub fn connect(addr: &str, timeout: Duration) -> Result<Client> {
        let sock = resolve(addr)?;
        let stream = TcpStream::connect_timeout(&sock, timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream })
    }

    /// Connect, retrying while the server comes up (for smoke tests
    /// that race the listener's bind).
    pub fn connect_retry(addr: &str, total: Duration) -> Result<Client> {
        let deadline = Instant::now() + total;
        loop {
            match Client::connect(addr, Duration::from_secs(1)) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!("server at {addr} never came up")));
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }

    /// Bound how long a single request may block on the socket.
    pub fn set_timeouts(&mut self, read: Duration, write: Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(read)).context("setting the read timeout")?;
        self.stream.set_write_timeout(Some(write)).context("setting the write timeout")?;
        Ok(())
    }

    /// Send one request and read one response frame (which may be a
    /// structured error).
    pub fn request(&mut self, model: &str, dims: &[usize], data: &[f32]) -> Result<Response> {
        write_request(&mut self.stream, model, dims, data)
            .map_err(|e| anyhow!("sending request: {e}"))?;
        read_response(&mut self.stream).map_err(|e| anyhow!("reading response: {e}"))
    }

    /// Send one request and return the output payload, treating any
    /// error frame as failure.
    pub fn infer(&mut self, model: &str, dims: &[usize], data: &[f32]) -> Result<Vec<f32>> {
        match self.request(model, dims, data)? {
            Response::Output { data, .. } => Ok(data),
            Response::Error { code, message } => {
                bail!("server error {}: {message}", code.name())
            }
        }
    }

    /// Send one request, retrying `BUSY` rejections with a fixed
    /// backoff. Returns the output and how many `BUSY` responses were
    /// absorbed along the way.
    pub fn infer_retry_busy(
        &mut self,
        model: &str,
        dims: &[usize],
        data: &[f32],
        retries: u32,
        backoff: Duration,
    ) -> Result<(Vec<f32>, u32)> {
        let mut busy = 0;
        loop {
            match self.request(model, dims, data)? {
                Response::Output { data, .. } => return Ok((data, busy)),
                Response::Error { code: ErrorCode::Busy, .. } if busy < retries => {
                    busy += 1;
                    std::thread::sleep(backoff);
                }
                Response::Error { code, message } => {
                    bail!("server error {}: {message}", code.name())
                }
            }
        }
    }
}
