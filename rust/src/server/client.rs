//! Blocking client for the serving protocol.
//!
//! One [`Client`] wraps one TCP connection and issues requests
//! sequentially: write a request frame, read one response frame.
//! [`Client::infer_retry_busy`] layers the retry discipline the
//! backpressure design expects — a `BUSY` rejection means "the bounded
//! queue is full right now", so the client backs off with seeded,
//! jittered exponential delays ([`Backoff`]) and resends, and reports
//! how many rejections it absorbed. [`Client::health`] fetches the
//! server's live counter/quarantine snapshot; [`Client::metrics`]
//! fetches the Prometheus-style text exposition (wire kinds 6/7).

use std::io::Write;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::prop::Rng;

use super::protocol::{
    encode_health_request, encode_metrics_request, read_response, write_request, ErrorCode,
    HealthSnapshot, Response,
};

/// Seeded equal-jitter exponential backoff schedule.
///
/// Delay `i` (0-based) is drawn uniformly from `[base·2^i / 2,
/// base·2^i)`, capped at `cap` — the standard "equal jitter" variant:
/// enough spread to decorrelate a thundering herd of retriers, while
/// keeping at least half the exponential spacing. The schedule is a
/// pure function of `(seed, attempt sequence)`: [`Backoff::next_delay`]
/// never sleeps, so tests assert the exact schedule without waiting on
/// wall-clock time.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// Schedule starting at `base`, never exceeding `cap` per delay.
    pub fn new(seed: u64, base: Duration, cap: Duration) -> Backoff {
        Backoff { base, cap, attempt: 0, rng: Rng::new(seed) }
    }

    /// The next delay in the schedule (advances the attempt counter).
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.base.saturating_mul(1u32 << self.attempt.min(16));
        let ceil = exp.min(self.cap);
        if self.attempt < u32::MAX {
            self.attempt += 1;
        }
        let half = ceil / 2;
        half + Duration::from_secs_f64((ceil - half).as_secs_f64() * self.rng.f64())
    }

    /// Delays handed out so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Sleep for the next delay in the schedule.
    pub fn sleep(&mut self) {
        std::thread::sleep(self.next_delay());
    }
}

/// A connected protocol client.
pub struct Client {
    stream: TcpStream,
}

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr} resolved to no address"))
}

impl Client {
    /// Connect with a timeout.
    pub fn connect(addr: &str, timeout: Duration) -> Result<Client> {
        let sock = resolve(addr)?;
        let stream = TcpStream::connect_timeout(&sock, timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream })
    }

    /// Connect, retrying while the server comes up (for smoke tests
    /// that race the listener's bind).
    pub fn connect_retry(addr: &str, total: Duration) -> Result<Client> {
        let deadline = Instant::now() + total;
        loop {
            match Client::connect(addr, Duration::from_secs(1)) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!("server at {addr} never came up")));
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }

    /// Bound how long a single request may block on the socket.
    pub fn set_timeouts(&mut self, read: Duration, write: Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(read)).context("setting the read timeout")?;
        self.stream.set_write_timeout(Some(write)).context("setting the write timeout")?;
        Ok(())
    }

    /// Send one request and read one response frame (which may be a
    /// structured error).
    pub fn request(&mut self, model: &str, dims: &[usize], data: &[f32]) -> Result<Response> {
        write_request(&mut self.stream, model, dims, data)
            .map_err(|e| anyhow!("sending request: {e}"))?;
        read_response(&mut self.stream).map_err(|e| anyhow!("reading response: {e}"))
    }

    /// Send one request and return the output payload, treating any
    /// error frame as failure.
    pub fn infer(&mut self, model: &str, dims: &[usize], data: &[f32]) -> Result<Vec<f32>> {
        match self.request(model, dims, data)? {
            Response::Output { data, .. } => Ok(data),
            Response::Error { code, message } => {
                bail!("server error {}: {message}", code.name())
            }
            Response::Health(_) | Response::Metrics(_) => {
                bail!("unexpected status frame answering an inference")
            }
        }
    }

    /// Fetch the server's live health snapshot (counters + quarantine).
    pub fn health(&mut self) -> Result<HealthSnapshot> {
        self.stream
            .write_all(&encode_health_request())
            .and_then(|()| self.stream.flush())
            .map_err(|e| anyhow!("sending health request: {e}"))?;
        match read_response(&mut self.stream).map_err(|e| anyhow!("reading response: {e}"))? {
            Response::Health(h) => Ok(h),
            Response::Error { code, message } => {
                bail!("server error {}: {message}", code.name())
            }
            other => bail!("unexpected {} frame answering a health probe", frame_name(&other)),
        }
    }

    /// Fetch the server's metrics as Prometheus-style text (counters,
    /// gauges, and stage-latency histogram quantiles).
    pub fn metrics(&mut self) -> Result<String> {
        self.stream
            .write_all(&encode_metrics_request())
            .and_then(|()| self.stream.flush())
            .map_err(|e| anyhow!("sending metrics request: {e}"))?;
        match read_response(&mut self.stream).map_err(|e| anyhow!("reading response: {e}"))? {
            Response::Metrics(text) => Ok(text),
            Response::Error { code, message } => {
                bail!("server error {}: {message}", code.name())
            }
            other => bail!("unexpected {} frame answering a metrics probe", frame_name(&other)),
        }
    }

    /// Send one request, retrying `BUSY` rejections with seeded,
    /// jittered exponential backoff starting at `backoff` (capped at
    /// 16× and bounded to at most two minutes of cumulative sleeping).
    /// Returns the output and how many `BUSY` responses were absorbed.
    pub fn infer_retry_busy(
        &mut self,
        model: &str,
        dims: &[usize],
        data: &[f32],
        retries: u32,
        backoff: Duration,
    ) -> Result<(Vec<f32>, u32)> {
        let mut busy = 0;
        let mut schedule = Backoff::new(0x9e3779b97f4a7c15, backoff, backoff.saturating_mul(16));
        let mut slept = Duration::ZERO;
        const MAX_ELAPSED: Duration = Duration::from_secs(120);
        loop {
            match self.request(model, dims, data)? {
                Response::Output { data, .. } => return Ok((data, busy)),
                Response::Error { code: ErrorCode::Busy, .. }
                    if busy < retries && slept < MAX_ELAPSED =>
                {
                    busy += 1;
                    let delay = schedule.next_delay();
                    slept += delay;
                    std::thread::sleep(delay);
                }
                Response::Error { code, message } => {
                    bail!("server error {}: {message}", code.name())
                }
                Response::Health(_) | Response::Metrics(_) => {
                    bail!("unexpected status frame answering an inference")
                }
            }
        }
    }
}

fn frame_name(resp: &Response) -> &'static str {
    match resp {
        Response::Output { .. } => "output",
        Response::Error { .. } => "error",
        Response::Health(_) => "health",
        Response::Metrics(_) => "metrics",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedules_deterministically_from_the_seed() {
        // Pure schedule — no sleeping: two instances with one seed
        // agree delay-for-delay, a different seed diverges somewhere.
        let base = Duration::from_millis(2);
        let cap = Duration::from_millis(32);
        let mut a = Backoff::new(42, base, cap);
        let mut b = Backoff::new(42, base, cap);
        let mut c = Backoff::new(43, base, cap);
        let sa: Vec<Duration> = (0..12).map(|_| a.next_delay()).collect();
        let sb: Vec<Duration> = (0..12).map(|_| b.next_delay()).collect();
        let sc: Vec<Duration> = (0..12).map(|_| c.next_delay()).collect();
        assert_eq!(sa, sb);
        assert_ne!(sa, sc);
        assert_eq!(a.attempts(), 12);
    }

    #[test]
    fn backoff_grows_exponentially_within_jitter_bounds_and_caps() {
        let base = Duration::from_millis(2);
        let cap = Duration::from_millis(32);
        let mut b = Backoff::new(7, base, cap);
        for i in 0..12u32 {
            let ceil = (base * 2u32.pow(i.min(16))).min(cap);
            let d = b.next_delay();
            assert!(d >= ceil / 2, "delay {i}: {d:?} below the equal-jitter floor {:?}", ceil / 2);
            assert!(d < ceil + Duration::from_micros(1), "delay {i}: {d:?} above ceiling {ceil:?}");
        }
    }

    #[test]
    fn backoff_never_overflows_on_deep_attempts() {
        let mut b = Backoff::new(1, Duration::from_secs(1), Duration::from_secs(30));
        for _ in 0..100 {
            assert!(b.next_delay() <= Duration::from_secs(30));
        }
    }
}
