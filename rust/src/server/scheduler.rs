//! Bounded submission queue between connection threads and the one
//! engine driver thread, plus the driver's *supervisor loop*.
//!
//! [`crate::exec::serve::Engine`] is deliberately single-owner —
//! `submit` and `step` take `&mut self` so the micro-batch coalescing
//! queue needs no locks. The scheduler keeps that shape under
//! concurrent connections: every connection thread holds a cloned
//! [`SchedulerHandle`] whose [`SchedulerHandle::submit`] performs
//! *admission control* (a per-model in-flight cap, tracked by an RAII
//! [`InflightSlot`] owned by the job so abandoned replies can never
//! leak a slot) and then a non-blocking push onto a bounded
//! `sync_channel`. Both limits reject with a structured `BUSY` instead
//! of buffering unboundedly — the queue depth is the whole memory
//! bound of the serving front.
//!
//! The driver thread owns the [`Engine`] and is also its supervisor:
//! each wave is grouped per model and every group's engine work runs
//! under `catch_unwind`. A panic does not kill the thread — the group
//! is answered with structured `INTERNAL` errors, the model's engine
//! state is purged (rebuilt from its registered builder on next use),
//! and the model collects a *strike*; at
//! [`SchedulerConfig::quarantine_after`] strikes the model is
//! quarantined and later submits are refused with `QUARANTINED` while
//! every other model keeps serving bit-identically. Jobs whose
//! [`SchedulerConfig::deadline`] expired while queued are answered
//! `TIMEOUT` *before* evaluation. When every handle clone is dropped
//! the driver finishes the remaining queue and returns the engine, so
//! shutdown *drains* in-flight work rather than dropping it.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::exec::faults;
use crate::exec::serve::{Engine, EngineResponse, SubmitError};

use super::protocol::{ErrorCode, HealthSnapshot, QuarantinedModel};

/// Reply to one scheduled job: the flat output, or the structured
/// error the connection reports to its client.
pub type JobReply = Result<Vec<f32>, (ErrorCode, String)>;

/// Scheduler tunables (split out of `ServerConfig` so the scheduler is
/// testable without a listener).
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Bounded submission queue depth.
    pub queue_depth: usize,
    /// Per-model in-flight admission cap.
    pub per_model_cap: usize,
    /// Driver-side request deadline, measured from submit: jobs still
    /// queued past it are answered `TIMEOUT` and skipped before eval.
    /// `None` disables the driver-side check.
    pub deadline: Option<Duration>,
    /// Driver panics a model may accumulate before it is quarantined.
    /// `0` disables quarantine entirely (panics are still caught and
    /// answered `INTERNAL` — the reply-channel recovery contract holds
    /// with no supervision policy on top).
    pub quarantine_after: u32,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            queue_depth: 64,
            per_model_cap: 64,
            deadline: None,
            quarantine_after: 1,
        }
    }
}

/// An acquired per-model admission slot. Dropping it releases the
/// slot, so every exit path — completion, error reply, an abandoned
/// reply receiver, a panic unwinding the wave — decrements exactly
/// once.
struct InflightSlot {
    inflight: Arc<Mutex<HashMap<String, usize>>>,
    model: String,
}

impl InflightSlot {
    /// Acquire a slot under the cap, or return the current in-flight
    /// count.
    fn acquire(
        inflight: &Arc<Mutex<HashMap<String, usize>>>,
        model: &str,
        cap: usize,
    ) -> Result<InflightSlot, usize> {
        let mut map = inflight.lock().unwrap_or_else(|e| e.into_inner());
        let n = map.entry(model.to_string()).or_insert(0);
        if *n >= cap {
            return Err(*n);
        }
        *n += 1;
        Ok(InflightSlot {
            inflight: inflight.clone(),
            model: model.to_string(),
        })
    }
}

impl Drop for InflightSlot {
    fn drop(&mut self) {
        let mut map = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(n) = map.get_mut(&self.model) {
            *n = n.saturating_sub(1);
        }
    }
}

/// One queued request. The job owns its admission slot: wherever the
/// job is dropped, the slot releases.
struct Job {
    model: String,
    data: Vec<f32>,
    reply: SyncSender<JobReply>,
    deadline: Option<Instant>,
    /// Monotonic submit stamp: the driver records the queue-wait span
    /// (`gconv_queue_wait_ns`) from it when the job is picked up.
    submitted_at: Instant,
    _slot: InflightSlot,
}

/// Shared monotonic counters of the serving front. Since the obs
/// migration every field is a handle into a per-server
/// [`crate::obs::Registry`] (each listener gets its own, so concurrent
/// servers in one process never co-mingle counts): the health snapshot
/// and the kind-7 metrics exposition read the *same* storage, which is
/// what the registry-pinning test leans on. The registry also carries
/// the per-stage latency histograms (`read`/`queue_wait`/`eval`/
/// `write`) the span stamps in `conn`/`scheduler` record into.
pub struct Counters {
    /// Jobs accepted into the queue (`gconv_submitted`).
    pub submitted: Arc<crate::obs::Counter>,
    /// Jobs answered with an output frame (`gconv_completed`).
    pub completed: Arc<crate::obs::Counter>,
    /// Submissions rejected with `BUSY` — queue full or per-model cap
    /// (`gconv_rejected_busy`).
    pub rejected_busy: Arc<crate::obs::Counter>,
    /// Jobs answered with a non-`BUSY` error frame. Accepted jobs
    /// always resolve: `submitted == completed + errored + expired`
    /// (`gconv_errored`).
    pub errored: Arc<crate::obs::Counter>,
    /// Requests whose reply wait exceeded the request timeout
    /// (`gconv_timeouts`).
    pub timeouts: Arc<crate::obs::Counter>,
    /// Jobs whose driver-side deadline expired before evaluation —
    /// answered `TIMEOUT`, never evaluated (`gconv_expired`).
    pub expired: Arc<crate::obs::Counter>,
    /// Submissions refused because the model is quarantined
    /// (`gconv_quarantine_rejected`).
    pub quarantine_rejected: Arc<crate::obs::Counter>,
    /// Driver panics caught by the supervisor (`gconv_panics`).
    pub panics: Arc<crate::obs::Counter>,
    /// Frames refused as malformed/oversized (`gconv_malformed`).
    pub malformed: Arc<crate::obs::Counter>,
    /// Connections dropped for blowing a mid-frame read deadline
    /// (`gconv_slow_clients`).
    pub slow_clients: Arc<crate::obs::Counter>,
    /// Connections accepted (`gconv_conns_accepted`).
    pub conns_accepted: Arc<crate::obs::Counter>,
    /// Connections refused at the connection cap
    /// (`gconv_conns_rejected`).
    pub conns_rejected: Arc<crate::obs::Counter>,
    /// Current queue depth (`gconv_queue_depth`).
    pub queue_depth: Arc<crate::obs::Gauge>,
    /// High-water mark of the queue depth — must stay ≤ the configured
    /// bound, the no-unbounded-buffering invariant
    /// (`gconv_max_queue_depth`).
    pub max_queue_depth: Arc<crate::obs::Gauge>,
    /// Frame-read time, first byte to full frame (`gconv_read_ns`).
    pub read_ns: Arc<crate::obs::Hist>,
    /// Submit-to-driver-pickup queue wait (`gconv_queue_wait_ns`).
    pub queue_wait_ns: Arc<crate::obs::Hist>,
    /// Engine-side per-request evaluation latency (`gconv_eval_ns`).
    pub eval_ns: Arc<crate::obs::Hist>,
    /// Reply-write time (`gconv_write_ns`).
    pub write_ns: Arc<crate::obs::Hist>,
    registry: Arc<crate::obs::Registry>,
}

impl Counters {
    /// Build the counter set over a fresh per-server registry. Metric
    /// names are `gconv_` + the [`super::protocol::HEALTH_FIELDS`]
    /// field name, so the snapshot and the exposition line up by
    /// construction.
    pub fn new() -> Counters {
        let registry = Arc::new(crate::obs::Registry::new());
        Counters {
            submitted: registry.counter("gconv_submitted"),
            completed: registry.counter("gconv_completed"),
            rejected_busy: registry.counter("gconv_rejected_busy"),
            errored: registry.counter("gconv_errored"),
            timeouts: registry.counter("gconv_timeouts"),
            expired: registry.counter("gconv_expired"),
            quarantine_rejected: registry.counter("gconv_quarantine_rejected"),
            panics: registry.counter("gconv_panics"),
            malformed: registry.counter("gconv_malformed"),
            slow_clients: registry.counter("gconv_slow_clients"),
            conns_accepted: registry.counter("gconv_conns_accepted"),
            conns_rejected: registry.counter("gconv_conns_rejected"),
            queue_depth: registry.gauge("gconv_queue_depth"),
            max_queue_depth: registry.gauge("gconv_max_queue_depth"),
            read_ns: registry.hist("gconv_read_ns"),
            queue_wait_ns: registry.hist("gconv_queue_wait_ns"),
            eval_ns: registry.hist("gconv_eval_ns"),
            write_ns: registry.hist("gconv_write_ns"),
            registry,
        }
    }

    /// The per-server registry backing these counters.
    pub fn registry(&self) -> &crate::obs::Registry {
        &self.registry
    }

    /// The kind-7 metrics-frame body: this server's registry followed
    /// by the process-global engine-side registry (kernel, session,
    /// pool, engine metrics). Name sets are disjoint by convention.
    pub fn metrics_text(&self) -> String {
        format!("{}{}", self.registry.render_text(), crate::obs::global().render_text())
    }
}

impl Default for Counters {
    fn default() -> Counters {
        Counters::new()
    }
}

/// Per-model panic strikes and the quarantine policy. Shared between
/// admission (handles) and the driver (which assigns strikes).
pub struct Quarantine {
    strikes: Mutex<HashMap<String, u32>>,
    threshold: u32,
}

impl Quarantine {
    /// Quarantine after `threshold` strikes; `0` disables quarantine.
    pub fn new(threshold: u32) -> Quarantine {
        Quarantine { strikes: Mutex::new(HashMap::new()), threshold }
    }

    fn lock(&self) -> MutexGuard<'_, HashMap<String, u32>> {
        self.strikes.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record one driver panic against `model`; returns its strikes.
    pub fn strike(&self, model: &str) -> u32 {
        let mut map = self.lock();
        let n = map.entry(model.to_string()).or_insert(0);
        *n += 1;
        *n
    }

    /// Whether submits for `model` are refused.
    pub fn is_quarantined(&self, model: &str) -> bool {
        self.threshold > 0
            && self.lock().get(model).is_some_and(|&n| n >= self.threshold)
    }

    /// The quarantined models (sorted by name, for deterministic
    /// health frames).
    pub fn snapshot(&self) -> Vec<QuarantinedModel> {
        if self.threshold == 0 {
            return Vec::new();
        }
        let map = self.lock();
        let mut out: Vec<QuarantinedModel> = map
            .iter()
            .filter(|(_, &n)| n >= self.threshold)
            .map(|(m, &n)| QuarantinedModel { model: m.clone(), strikes: n })
            .collect();
        out.sort_by(|a, b| a.model.cmp(&b.model));
        out
    }
}

/// Cloneable submission side of the scheduler, one clone per
/// connection thread plus the listener's own.
pub struct SchedulerHandle {
    tx: SyncSender<Job>,
    inflight: Arc<Mutex<HashMap<String, usize>>>,
    per_model_cap: usize,
    deadline: Option<Duration>,
    counters: Arc<Counters>,
    quarantine: Arc<Quarantine>,
}

impl Clone for SchedulerHandle {
    fn clone(&self) -> SchedulerHandle {
        SchedulerHandle {
            tx: self.tx.clone(),
            inflight: self.inflight.clone(),
            per_model_cap: self.per_model_cap,
            deadline: self.deadline,
            counters: self.counters.clone(),
            quarantine: self.quarantine.clone(),
        }
    }
}

impl SchedulerHandle {
    /// Try to enqueue one single-sample request. On success the job is
    /// owned by the driver and the returned receiver yields exactly one
    /// [`JobReply`]. On failure nothing was enqueued and the error maps
    /// directly to a wire error frame.
    pub fn submit(
        &self,
        model: &str,
        data: Vec<f32>,
    ) -> Result<Receiver<JobReply>, (ErrorCode, String)> {
        if self.quarantine.is_quarantined(model) {
            self.counters.quarantine_rejected.inc();
            return Err((
                ErrorCode::Quarantined,
                format!(
                    "model {model:?} is quarantined after panicking in the driver — \
                     other models keep serving"
                ),
            ));
        }
        // Admission: cap the number of in-flight requests per model.
        let slot = match InflightSlot::acquire(&self.inflight, model, self.per_model_cap) {
            Ok(slot) => slot,
            Err(n) => {
                self.counters.rejected_busy.inc();
                let cap = self.per_model_cap;
                return Err((
                    ErrorCode::Busy,
                    format!("model {model:?} has {n} requests in flight (cap {cap})"),
                ));
            }
        };
        let (reply, rx) = sync_channel(1);
        let job = Job {
            model: model.to_string(),
            data,
            reply,
            deadline: self.deadline.map(|d| Instant::now() + d),
            submitted_at: Instant::now(),
            _slot: slot,
        };
        match self.tx.try_send(job) {
            Ok(()) => {
                let depth = self.counters.queue_depth.inc_and_get();
                self.counters.max_queue_depth.maximize(depth);
                self.counters.submitted.inc();
                Ok(rx)
            }
            // The unsent job (and its slot) drops here — no leak.
            Err(TrySendError::Full(_)) => {
                self.counters.rejected_busy.inc();
                Err((ErrorCode::Busy, "submission queue is full — retry later".into()))
            }
            Err(TrySendError::Disconnected(_)) => Err((
                ErrorCode::ShuttingDown,
                "server is shutting down and accepts no new work".into(),
            )),
        }
    }

    /// Point-in-time health snapshot: every counter plus the
    /// quarantine list (the body of a `health` wire frame).
    pub fn health(&self) -> HealthSnapshot {
        let c = &self.counters;
        HealthSnapshot {
            submitted: c.submitted.get(),
            completed: c.completed.get(),
            rejected_busy: c.rejected_busy.get(),
            errored: c.errored.get(),
            timeouts: c.timeouts.get(),
            expired: c.expired.get(),
            quarantine_rejected: c.quarantine_rejected.get(),
            malformed: c.malformed.get(),
            slow_clients: c.slow_clients.get(),
            conns_accepted: c.conns_accepted.get(),
            conns_rejected: c.conns_rejected.get(),
            panics: c.panics.get(),
            queue_depth: c.queue_depth.get(),
            max_queue_depth: c.max_queue_depth.get(),
            quarantined: self.quarantine.snapshot(),
        }
    }

    /// The shared quarantine state (for the final server report).
    pub fn quarantine_arc(&self) -> Arc<Quarantine> {
        self.quarantine.clone()
    }
}

/// Map an [`Engine::submit`] failure to its wire error code.
fn map_engine_error(e: &anyhow::Error) -> (ErrorCode, String) {
    let code = match e.downcast_ref::<SubmitError>() {
        Some(SubmitError::UnknownModel { .. }) => ErrorCode::UnknownModel,
        Some(SubmitError::ShapeMismatch { .. }) => ErrorCode::BadShape,
        None => ErrorCode::Internal,
    };
    (code, format!("{e:#}"))
}

/// Record a failed wave against the model's error histogram
/// (`gconv_model_error_ns_<model>`, registered lazily on the server's
/// registry — the error path is cold, so the name lookup is fine
/// here). The histogram's `_count` is the per-model error count the
/// chaos suite asserts on; the recorded value is the wave duration at
/// failure.
fn record_model_error(counters: &Counters, model: &str, wave_span: &crate::obs::Span) {
    counters
        .registry()
        .hist(&format!("gconv_model_error_ns_{model}"))
        .record(wave_span.elapsed_ns());
}

/// Answer one accepted job with a structured error (its slot releases
/// as the job drops).
fn fail(job: Job, code: ErrorCode, message: String, counters: &Counters) {
    counters.errored.inc();
    let _ = job.reply.send(Err((code, message)));
}

/// Spawn the driver thread over `engine` and return the submission
/// handle plus the driver's join handle (it yields the engine back for
/// the final stats report).
pub fn start(
    engine: Engine,
    cfg: SchedulerConfig,
    counters: Arc<Counters>,
) -> std::io::Result<(SchedulerHandle, JoinHandle<Engine>)> {
    let (tx, rx) = sync_channel::<Job>(cfg.queue_depth.max(1));
    let handle = SchedulerHandle {
        tx,
        inflight: Arc::new(Mutex::new(HashMap::new())),
        per_model_cap: cfg.per_model_cap.max(1),
        deadline: cfg.deadline,
        counters: counters.clone(),
        quarantine: Arc::new(Quarantine::new(cfg.quarantine_after)),
    };
    // The driver must NOT hold a `SchedulerHandle` (its `tx` clone
    // would keep the channel connected forever and `recv` would never
    // disconnect at shutdown) — it shares only the counters and the
    // quarantine state.
    let quarantine = handle.quarantine.clone();
    let driver = std::thread::Builder::new()
        .name("gconv-serve-driver".into())
        .spawn(move || drive(engine, rx, counters, quarantine))?;
    Ok((handle, driver))
}

/// The supervisor/driver loop: wave in, per-model groups through the
/// engine under `catch_unwind`, replies out. Survives injected and
/// organic panics alike; exits (returning the engine) only when every
/// submission handle is gone and the queue is empty.
fn drive(
    mut engine: Engine,
    rx: Receiver<Job>,
    counters: Arc<Counters>,
    quarantine: Arc<Quarantine>,
) -> Engine {
    let mut next_id: u64 = 0;
    while let Ok(first) = rx.recv() {
        // Greedy wave: everything already queued rides this drain, so
        // concurrent same-model requests coalesce into micro-batches.
        let mut wave = vec![first];
        while let Ok(job) = rx.try_recv() {
            wave.push(job);
        }
        counters.queue_depth.sub(wave.len() as u64);
        for (model, jobs) in group_by_model(wave) {
            serve_group(&mut engine, &model, jobs, &mut next_id, &counters, &quarantine);
        }
    }
    engine
}

/// Split a wave into per-model groups, preserving arrival order within
/// each group and across first appearances. Per-model grouping is what
/// lets a panic be *attributed*: when a group's engine work unwinds,
/// the offending model is known by construction.
fn group_by_model(wave: Vec<Job>) -> Vec<(String, VecDeque<Job>)> {
    let mut order: Vec<String> = Vec::new();
    let mut groups: HashMap<String, VecDeque<Job>> = HashMap::new();
    for job in wave {
        if !groups.contains_key(&job.model) {
            order.push(job.model.clone());
        }
        groups.entry(job.model.clone()).or_default().push_back(job);
    }
    order
        .into_iter()
        .map(|m| {
            let jobs = groups.remove(&m).expect("grouped by model");
            (m, jobs)
        })
        .collect()
}

/// Serve one per-model group: quarantine check, deadline sweep, then
/// the engine work under `catch_unwind`. Every job in the group is
/// answered exactly once on every path.
fn serve_group(
    engine: &mut Engine,
    model: &str,
    jobs: VecDeque<Job>,
    next_id: &mut u64,
    counters: &Counters,
    quarantine: &Quarantine,
) {
    // Jobs accepted before the model was quarantined still get the
    // structured refusal, without touching the engine.
    if quarantine.is_quarantined(model) {
        let msg = format!("model {model:?} is quarantined after panicking in the driver");
        for job in jobs {
            fail(job, ErrorCode::Quarantined, msg.clone(), counters);
        }
        return;
    }
    // Driver-side deadline: a job that waited out its budget in the
    // queue is answered `TIMEOUT` and never evaluated — expired work
    // must not displace live work.
    let now = Instant::now();
    let mut live: VecDeque<Job> = VecDeque::with_capacity(jobs.len());
    for job in jobs {
        match job.deadline {
            Some(d) if now >= d => {
                counters.expired.inc();
                let _ = job.reply.send(Err((
                    ErrorCode::Timeout,
                    "request deadline expired before evaluation".into(),
                )));
            }
            _ => live.push_back(job),
        }
    }
    // Queue-wait span: submit stamp to driver pickup, per live job.
    for job in &live {
        let waited = now.saturating_duration_since(job.submitted_at);
        counters.queue_wait_ns.record(u64::try_from(waited.as_nanos()).unwrap_or(u64::MAX));
    }
    let mut todo = live;
    let mut pending: HashMap<u64, Job> = HashMap::new();
    let wave_span = crate::obs::Span::start();
    let drained = catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<Vec<EngineResponse>> {
        faults::trip_scoped(faults::SITE_SCHEDULER_WAVE, model)?;
        while let Some(mut job) = todo.pop_front() {
            let id = *next_id;
            *next_id += 1;
            let data = std::mem::take(&mut job.data);
            match engine.submit(model, id, data) {
                Ok(()) => {
                    pending.insert(id, job);
                }
                Err(e) => {
                    let (code, msg) = map_engine_error(&e);
                    fail(job, code, msg, counters);
                }
            }
        }
        engine.drain()
    }));
    match drained {
        Ok(Ok(responses)) => {
            for r in responses {
                if let Some(job) = pending.remove(&r.id) {
                    counters.completed.inc();
                    counters.eval_ns.record((r.latency_s * 1e9) as u64);
                    let _ = job.reply.send(Ok(r.data));
                }
            }
        }
        Ok(Err(e)) => {
            // The engine failed gracefully mid-group. Purge the model's
            // queued/cached engine state so a persistent failure cannot
            // wedge later waves, and answer the whole group.
            record_model_error(counters, model, &wave_span);
            engine.purge(model);
            let msg = format!("engine drain failed: {e:#}");
            for job in todo {
                fail(job, ErrorCode::Internal, msg.clone(), counters);
            }
            for (_, job) in pending.drain() {
                fail(job, ErrorCode::Internal, msg.clone(), counters);
            }
        }
        Err(_) => {
            // Panic isolation: the supervisor survives, the group is
            // answered `INTERNAL`, the model's engine state is rebuilt
            // from its registered builder on next use, and repeated
            // panics quarantine the model.
            counters.panics.inc();
            record_model_error(counters, model, &wave_span);
            let strikes = quarantine.strike(model);
            engine.purge(model);
            let msg = if quarantine.is_quarantined(model) {
                format!("engine panicked serving {model:?} (strike {strikes}) — quarantined")
            } else {
                format!("engine panicked serving {model:?} (strike {strikes})")
            };
            for job in todo {
                fail(job, ErrorCode::Internal, msg.clone(), counters);
            }
            for (_, job) in pending.drain() {
                fail(job, ErrorCode::Internal, msg.clone(), counters);
            }
        }
    }
    // A request the engine accepted but never answered would be a
    // coalescing bug — fail it loudly rather than hanging clients.
    for (_, job) in pending.drain() {
        fail(
            job,
            ErrorCode::Internal,
            "engine dropped an accepted request".into(),
            counters,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::exec::faults::{FaultKind, FaultPlan, FaultRule, Trigger};
    use crate::ir::{Layer, Network, Shape};

    fn tiny_net(batch: usize) -> Network {
        let mut net = Network::new("tiny");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, 2, 4, 4) }, &[]);
        let r = net.add("relu", Layer::Relu, &[i]);
        net.add("fc", Layer::FullyConnected { out_features: 3 }, &[r]);
        net
    }

    fn engine_with(codes: &[&str]) -> Engine {
        let mut e = Engine::new(4);
        for code in codes {
            e.register(code, tiny_net);
        }
        e
    }

    fn engine() -> Engine {
        engine_with(&["tiny"])
    }

    fn cfg(queue_depth: usize, per_model_cap: usize) -> SchedulerConfig {
        SchedulerConfig {
            queue_depth,
            per_model_cap,
            ..SchedulerConfig::default()
        }
    }

    fn step_panic_rule(model: &str) -> FaultRule {
        FaultRule {
            site: faults::SITE_SERVE_STEP.to_string(),
            scope: Some(model.to_string()),
            kind: FaultKind::Panic,
            trigger: Trigger::Nth(1),
        }
    }

    fn inflight_of(handle: &SchedulerHandle, model: &str) -> usize {
        *handle.inflight.lock().unwrap_or_else(|e| e.into_inner()).get(model).unwrap()
    }

    fn wait_for_drained_inflight(handle: &SchedulerHandle, model: &str) {
        let t0 = Instant::now();
        loop {
            if inflight_of(handle, model) == 0 {
                return;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "in-flight slots for {model} never released"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn jobs_round_trip_through_the_driver() {
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), cfg(8, 8), counters.clone()).unwrap();
        let rx = handle.submit("tiny", vec![0.5; 32]).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let out = reply.expect("job must succeed");
        assert_eq!(out.len(), 3);
        assert_eq!(counters.completed.get(), 1);
        drop(handle);
        let _ = driver.join().unwrap();
    }

    #[test]
    fn queue_overflow_rejects_busy_without_blocking() {
        // No driver consumes: the queue deterministically fills at its
        // configured depth and the next submit must reject, not block.
        let counters = Arc::new(Counters::default());
        let (tx, _rx) = sync_channel::<Job>(2);
        let handle = SchedulerHandle {
            tx,
            inflight: Arc::new(Mutex::new(HashMap::new())),
            per_model_cap: 100,
            deadline: None,
            counters: counters.clone(),
            quarantine: Arc::new(Quarantine::new(1)),
        };
        let _a = handle.submit("tiny", vec![0.0; 32]).unwrap();
        let _b = handle.submit("tiny", vec![0.0; 32]).unwrap();
        let err = handle.submit("tiny", vec![0.0; 32]).unwrap_err();
        assert_eq!(err.0, ErrorCode::Busy);
        assert_eq!(counters.rejected_busy.get(), 1);
        assert_eq!(counters.max_queue_depth.get(), 2);
        // The rejected submission must not leak an in-flight slot.
        assert_eq!(inflight_of(&handle, "tiny"), 2);
    }

    #[test]
    fn per_model_cap_rejects_busy_and_releases_on_job_drop() {
        let counters = Arc::new(Counters::default());
        let (tx, rx) = sync_channel::<Job>(64);
        let handle = SchedulerHandle {
            tx,
            inflight: Arc::new(Mutex::new(HashMap::new())),
            per_model_cap: 1,
            deadline: None,
            counters: counters.clone(),
            quarantine: Arc::new(Quarantine::new(1)),
        };
        let _a = handle.submit("tiny", vec![0.0; 32]).unwrap();
        let err = handle.submit("tiny", vec![0.0; 32]).unwrap_err();
        assert_eq!(err.0, ErrorCode::Busy);
        // Another model is admitted independently.
        assert!(handle.submit("other", vec![0.0; 32]).is_ok());
        // Dropping the queued job releases its RAII slot.
        drop(rx.try_recv().unwrap());
        assert!(handle.submit("tiny", vec![0.0; 32]).is_ok());
    }

    #[test]
    fn abandoned_replies_still_release_inflight_slots() {
        // Regression for the in-flight leak: flood up to the cap, drop
        // every reply receiver immediately (a disconnecting client),
        // and the cap must recover once the driver finishes the jobs.
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), cfg(8, 2), counters.clone()).unwrap();
        for _ in 0..2 {
            drop(handle.submit("tiny", vec![0.5; 32]).unwrap());
        }
        wait_for_drained_inflight(&handle, "tiny");
        // The cap is fully available again.
        let _a = handle.submit("tiny", vec![0.5; 32]).unwrap();
        let _b = handle.submit("tiny", vec![0.5; 32]).unwrap();
        drop(handle);
        let _ = driver.join().unwrap();
        assert_eq!(counters.completed.get(), 4);
    }

    #[test]
    fn unknown_models_map_to_the_unknown_model_code() {
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), cfg(8, 8), counters.clone()).unwrap();
        let rx = handle.submit("no-such-model", vec![0.0; 32]).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let (code, msg) = reply.expect_err("unknown model must fail");
        assert_eq!(code, ErrorCode::UnknownModel);
        assert!(msg.contains("no-such-model"), "{msg}");
        // Bad shape maps to BAD_SHAPE.
        let rx = handle.submit("tiny", vec![0.0; 3]).unwrap();
        let (code, _) = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect_err("bad shape must fail");
        assert_eq!(code, ErrorCode::BadShape);
        assert_eq!(counters.errored.get(), 2);
        // Failed jobs release their admission slots.
        wait_for_drained_inflight(&handle, "tiny");
        drop(handle);
        let _ = driver.join().unwrap();
    }

    #[test]
    fn shutdown_drains_queued_jobs_before_the_driver_exits() {
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), cfg(8, 8), counters.clone()).unwrap();
        let receivers: Vec<_> =
            (0..4).map(|_| handle.submit("tiny", vec![0.25; 32]).unwrap()).collect();
        // Drop the last submission handle immediately: the driver must
        // still answer everything already queued.
        drop(handle);
        for rx in receivers {
            let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(reply.expect("queued job must drain").len(), 3);
        }
        let engine = driver.join().unwrap();
        assert_eq!(engine.stats().requests, 4);
        assert_eq!(counters.completed.get(), 4);
        assert_eq!(counters.queue_depth.get(), 0);
    }

    #[test]
    fn expired_deadlines_answer_timeout_before_eval() {
        let counters = Arc::new(Counters::default());
        let cfg = SchedulerConfig {
            deadline: Some(Duration::ZERO),
            ..cfg(8, 8)
        };
        let (handle, driver) = start(engine(), cfg, counters.clone()).unwrap();
        let rx = handle.submit("tiny", vec![0.5; 32]).unwrap();
        let (code, msg) = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect_err("a zero deadline must expire in the queue");
        assert_eq!(code, ErrorCode::Timeout);
        assert!(msg.contains("deadline"), "{msg}");
        drop(handle);
        let engine = driver.join().unwrap();
        assert_eq!(engine.stats().requests, 0, "expired jobs are skipped before eval");
        assert_eq!(counters.expired.get(), 1);
        assert_eq!(counters.completed.get(), 0);
    }

    #[test]
    fn injected_panic_yields_internal_replies_without_supervision() {
        // The recovery contract at the reply-channel level: even with
        // quarantine (the supervision policy) disabled, a panic inside
        // the wave must surface as structured INTERNAL replies — never
        // a dead driver and hanging clients.
        faults::silence_injected_panics();
        let counters = Arc::new(Counters::default());
        let cfg = SchedulerConfig {
            quarantine_after: 0,
            ..cfg(8, 8)
        };
        let (handle, driver) = start(engine_with(&["panicky"]), cfg, counters.clone()).unwrap();
        let guard = FaultPlan::new(11).with(step_panic_rule("panicky")).arm();
        let rx = handle.submit("panicky", vec![0.5; 32]).unwrap();
        let (code, msg) = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect_err("the panicked wave must fail structurally");
        assert_eq!(code, ErrorCode::Internal);
        assert!(msg.contains("panicked"), "{msg}");
        assert_eq!(counters.panics.get(), 1);
        // No supervision: the model is NOT quarantined, and the purged
        // engine state rebuilds on the next request (the one-shot
        // trigger has already fired).
        let rx = handle.submit("panicky", vec![0.5; 32]).unwrap();
        let out = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect("the driver must have survived the panic");
        assert_eq!(out.len(), 3);
        assert!(handle.health().quarantined.is_empty());
        drop(guard);
        drop(handle);
        let _ = driver.join().unwrap();
    }

    #[test]
    fn panics_quarantine_the_model_and_isolate_others() {
        faults::silence_injected_panics();
        let counters = Arc::new(Counters::default());
        let (handle, driver) =
            start(engine_with(&["flaky", "stable"]), cfg(8, 8), counters.clone()).unwrap();
        let guard = FaultPlan::new(5).with(step_panic_rule("flaky")).arm();
        // First flaky request: the wave panics, strike 1 quarantines
        // (threshold 1 by default).
        let rx = handle.submit("flaky", vec![0.5; 32]).unwrap();
        let (code, _) = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect_err("injected panic must fail the job");
        assert_eq!(code, ErrorCode::Internal);
        // Later submits are refused at admission with QUARANTINED.
        let t0 = Instant::now();
        loop {
            match handle.submit("flaky", vec![0.5; 32]) {
                Err((ErrorCode::Quarantined, msg)) => {
                    assert!(msg.contains("flaky"), "{msg}");
                    break;
                }
                // The strike lands when the driver unwinds the wave; a
                // submit racing it is answered INTERNAL by the driver.
                Ok(rx) => {
                    let _ = rx.recv_timeout(Duration::from_secs(30));
                }
                Err(other) => panic!("expected QUARANTINED, got {other:?}"),
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "model never quarantined");
        }
        // Other models keep serving.
        let rx = handle.submit("stable", vec![0.5; 32]).unwrap();
        let out = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect("healthy models must keep serving");
        assert_eq!(out.len(), 3);
        // The health snapshot names the quarantined model.
        let health = handle.health();
        assert_eq!(health.panics, 1);
        assert!(health.quarantine_rejected >= 1);
        assert_eq!(health.quarantined.len(), 1);
        assert_eq!(health.quarantined[0].model, "flaky");
        drop(guard);
        drop(handle);
        let _ = driver.join().unwrap();
    }
}
