//! Bounded submission queue between connection threads and the one
//! engine driver thread.
//!
//! [`crate::exec::serve::Engine`] is deliberately single-owner —
//! `submit` and `step` take `&mut self` so the micro-batch coalescing
//! queue needs no locks. The scheduler keeps that shape under
//! concurrent connections: every connection thread holds a cloned
//! [`SchedulerHandle`] whose [`SchedulerHandle::submit`] performs
//! *admission control* (a per-model in-flight cap) and then a
//! non-blocking push onto a bounded `sync_channel`. Both limits reject
//! with a structured `BUSY` instead of buffering unboundedly — the
//! queue depth is the whole memory bound of the serving front.
//!
//! The driver thread owns the [`Engine`]: it blocks on the queue,
//! greedily drains whatever else is already waiting (one *wave*),
//! submits the wave to the engine — which coalesces same-model
//! single-sample requests into micro-batches, bit-identically — and
//! routes each [`EngineResponse`] back through its job's reply
//! channel. When every handle clone is dropped (listener and
//! connection threads have exited) the driver finishes the remaining
//! queue and returns the engine, so shutdown *drains* in-flight work
//! rather than dropping it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::exec::serve::{Engine, SubmitError};

use super::protocol::ErrorCode;

/// Reply to one scheduled job: the flat output, or the structured
/// error the connection reports to its client.
pub type JobReply = Result<Vec<f32>, (ErrorCode, String)>;

/// One queued request.
struct Job {
    model: String,
    data: Vec<f32>,
    reply: SyncSender<JobReply>,
}

/// Shared monotonic counters of the serving front (atomics — read at
/// any time, snapshot in the final report).
#[derive(Debug, Default)]
pub struct Counters {
    /// Jobs accepted into the queue.
    pub submitted: AtomicU64,
    /// Jobs answered with an output frame.
    pub completed: AtomicU64,
    /// Submissions rejected with `BUSY` (queue full or per-model cap).
    pub rejected_busy: AtomicU64,
    /// Jobs answered with a non-`BUSY` error frame.
    pub errored: AtomicU64,
    /// Requests whose reply wait exceeded the request timeout.
    pub timeouts: AtomicU64,
    /// Frames refused as malformed/oversized.
    pub malformed: AtomicU64,
    /// Connections dropped for blowing a mid-frame read deadline.
    pub slow_clients: AtomicU64,
    /// Connections accepted.
    pub conns_accepted: AtomicU64,
    /// Connections refused at the connection cap.
    pub conns_rejected: AtomicU64,
    /// Current queue depth.
    pub queue_depth: AtomicUsize,
    /// High-water mark of the queue depth (must stay ≤ the configured
    /// bound — the no-unbounded-buffering invariant).
    pub max_queue_depth: AtomicUsize,
}

/// Cloneable submission side of the scheduler, one clone per
/// connection thread plus the listener's own.
pub struct SchedulerHandle {
    tx: SyncSender<Job>,
    inflight: Arc<Mutex<HashMap<String, usize>>>,
    per_model_cap: usize,
    counters: Arc<Counters>,
}

impl Clone for SchedulerHandle {
    fn clone(&self) -> SchedulerHandle {
        SchedulerHandle {
            tx: self.tx.clone(),
            inflight: self.inflight.clone(),
            per_model_cap: self.per_model_cap,
            counters: self.counters.clone(),
        }
    }
}

impl SchedulerHandle {
    /// Try to enqueue one single-sample request. On success the job is
    /// owned by the driver and the returned receiver yields exactly one
    /// [`JobReply`]. On failure nothing was enqueued and the error maps
    /// directly to a wire error frame.
    pub fn submit(
        &self,
        model: &str,
        data: Vec<f32>,
    ) -> Result<Receiver<JobReply>, (ErrorCode, String)> {
        // Admission: cap the number of in-flight requests per model.
        {
            let mut inflight = self.inflight.lock().expect("inflight lock");
            let n = inflight.entry(model.to_string()).or_insert(0);
            if *n >= self.per_model_cap {
                self.counters.rejected_busy.fetch_add(1, Ordering::Relaxed);
                let cap = self.per_model_cap;
                return Err((
                    ErrorCode::Busy,
                    format!("model {model:?} has {n} requests in flight (cap {cap})"),
                ));
            }
            *n += 1;
        }
        let (reply, rx) = sync_channel(1);
        let job = Job { model: model.to_string(), data, reply };
        match self.tx.try_send(job) {
            Ok(()) => {
                let depth = self.counters.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
                self.counters.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
                self.counters.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(e) => {
                self.release(model);
                match e {
                    TrySendError::Full(_) => {
                        self.counters.rejected_busy.fetch_add(1, Ordering::Relaxed);
                        Err((ErrorCode::Busy, "submission queue is full — retry later".into()))
                    }
                    TrySendError::Disconnected(_) => Err((
                        ErrorCode::ShuttingDown,
                        "server is shutting down and accepts no new work".into(),
                    )),
                }
            }
        }
    }

    fn release(&self, model: &str) {
        release(&self.inflight, model);
    }
}

fn release(inflight: &Mutex<HashMap<String, usize>>, model: &str) {
    let mut inflight = inflight.lock().expect("inflight lock");
    if let Some(n) = inflight.get_mut(model) {
        *n = n.saturating_sub(1);
    }
}

/// Map an [`Engine::submit`] failure to its wire error code.
fn map_engine_error(e: &anyhow::Error) -> (ErrorCode, String) {
    let code = match e.downcast_ref::<SubmitError>() {
        Some(SubmitError::UnknownModel { .. }) => ErrorCode::UnknownModel,
        Some(SubmitError::ShapeMismatch { .. }) => ErrorCode::BadShape,
        None => ErrorCode::Internal,
    };
    (code, format!("{e:#}"))
}

/// Spawn the driver thread over `engine` and return the submission
/// handle plus the driver's join handle (it yields the engine back for
/// the final stats report).
pub fn start(
    engine: Engine,
    queue_depth: usize,
    per_model_cap: usize,
    counters: Arc<Counters>,
) -> std::io::Result<(SchedulerHandle, JoinHandle<Engine>)> {
    let (tx, rx) = sync_channel::<Job>(queue_depth.max(1));
    let handle = SchedulerHandle {
        tx,
        inflight: Arc::new(Mutex::new(HashMap::new())),
        per_model_cap: per_model_cap.max(1),
        counters: counters.clone(),
    };
    // The driver must NOT hold a `SchedulerHandle` (its `tx` clone
    // would keep the channel connected forever and `recv` would never
    // disconnect at shutdown) — it shares only the map and counters.
    let inflight = handle.inflight.clone();
    let driver = std::thread::Builder::new()
        .name("gconv-serve-driver".into())
        .spawn(move || drive(engine, rx, inflight, counters))?;
    Ok((handle, driver))
}

/// The driver loop: wave in, micro-batches through the engine, replies
/// out. Exits (returning the engine) when every submission handle is
/// gone and the queue is empty.
fn drive(
    mut engine: Engine,
    rx: Receiver<Job>,
    inflight: Arc<Mutex<HashMap<String, usize>>>,
    counters: Arc<Counters>,
) -> Engine {
    let mut next_id: u64 = 0;
    while let Ok(first) = rx.recv() {
        // Greedy wave: everything already queued rides this drain, so
        // concurrent same-model requests coalesce into micro-batches.
        let mut wave = vec![first];
        while let Ok(job) = rx.try_recv() {
            wave.push(job);
        }
        counters.queue_depth.fetch_sub(wave.len(), Ordering::Relaxed);

        let mut pending: HashMap<u64, (String, SyncSender<JobReply>)> = HashMap::new();
        for job in wave {
            let id = next_id;
            next_id += 1;
            match engine.submit(&job.model, id, job.data) {
                Ok(()) => {
                    pending.insert(id, (job.model, job.reply));
                }
                Err(e) => {
                    counters.errored.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Err(map_engine_error(&e)));
                    release(&inflight, &job.model);
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        match engine.drain() {
            Ok(responses) => {
                for r in responses {
                    if let Some((model, reply)) = pending.remove(&r.id) {
                        counters.completed.fetch_add(1, Ordering::Relaxed);
                        let _ = reply.send(Ok(r.data));
                        release(&inflight, &model);
                    }
                }
            }
            Err(e) => {
                let msg = format!("engine drain failed: {e:#}");
                for (_, (model, reply)) in pending.drain() {
                    counters.errored.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(Err((ErrorCode::Internal, msg.clone())));
                    release(&inflight, &model);
                }
            }
        }
        // A request the engine accepted but never answered would be a
        // coalescing bug — fail it loudly rather than hanging clients.
        for (_, (model, reply)) in pending.drain() {
            counters.errored.fetch_add(1, Ordering::Relaxed);
            let _ = reply
                .send(Err((ErrorCode::Internal, "engine dropped an accepted request".into())));
            release(&inflight, &model);
        }
    }
    engine
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::time::Duration;

    use crate::ir::{Layer, Network, Shape};

    fn tiny_net(batch: usize) -> Network {
        let mut net = Network::new("tiny");
        let i = net.add("data", Layer::Input { shape: Shape::bchw(batch, 2, 4, 4) }, &[]);
        let r = net.add("relu", Layer::Relu, &[i]);
        net.add("fc", Layer::FullyConnected { out_features: 3 }, &[r]);
        net
    }

    fn engine() -> Engine {
        let mut e = Engine::new(4);
        e.register("tiny", tiny_net);
        e
    }

    #[test]
    fn jobs_round_trip_through_the_driver() {
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), 8, 8, counters.clone()).unwrap();
        let rx = handle.submit("tiny", vec![0.5; 32]).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let out = reply.expect("job must succeed");
        assert_eq!(out.len(), 3);
        assert_eq!(counters.completed.load(Ordering::Relaxed), 1);
        drop(handle);
        let _ = driver.join().unwrap();
    }

    #[test]
    fn queue_overflow_rejects_busy_without_blocking() {
        // No driver consumes: the queue deterministically fills at its
        // configured depth and the next submit must reject, not block.
        let counters = Arc::new(Counters::default());
        let (tx, _rx) = sync_channel::<Job>(2);
        let handle = SchedulerHandle {
            tx,
            inflight: Arc::new(Mutex::new(HashMap::new())),
            per_model_cap: 100,
            counters: counters.clone(),
        };
        let _a = handle.submit("tiny", vec![0.0; 32]).unwrap();
        let _b = handle.submit("tiny", vec![0.0; 32]).unwrap();
        let err = handle.submit("tiny", vec![0.0; 32]).unwrap_err();
        assert_eq!(err.0, ErrorCode::Busy);
        assert_eq!(counters.rejected_busy.load(Ordering::Relaxed), 1);
        assert_eq!(counters.max_queue_depth.load(Ordering::Relaxed), 2);
        // The rejected submission must not leak an in-flight slot.
        assert_eq!(*handle.inflight.lock().unwrap().get("tiny").unwrap(), 2);
    }

    #[test]
    fn per_model_cap_rejects_busy_and_releases_on_completion() {
        let counters = Arc::new(Counters::default());
        let (tx, _rx) = sync_channel::<Job>(64);
        let handle = SchedulerHandle {
            tx,
            inflight: Arc::new(Mutex::new(HashMap::new())),
            per_model_cap: 1,
            counters: counters.clone(),
        };
        let _a = handle.submit("tiny", vec![0.0; 32]).unwrap();
        let err = handle.submit("tiny", vec![0.0; 32]).unwrap_err();
        assert_eq!(err.0, ErrorCode::Busy);
        // Another model is admitted independently.
        assert!(handle.submit("other", vec![0.0; 32]).is_ok());
        handle.release("tiny");
        assert!(handle.submit("tiny", vec![0.0; 32]).is_ok());
    }

    #[test]
    fn unknown_models_map_to_the_unknown_model_code() {
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), 8, 8, counters.clone()).unwrap();
        let rx = handle.submit("no-such-model", vec![0.0; 32]).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let (code, msg) = reply.expect_err("unknown model must fail");
        assert_eq!(code, ErrorCode::UnknownModel);
        assert!(msg.contains("no-such-model"), "{msg}");
        // Bad shape maps to BAD_SHAPE.
        let rx = handle.submit("tiny", vec![0.0; 3]).unwrap();
        let (code, _) = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect_err("bad shape must fail");
        assert_eq!(code, ErrorCode::BadShape);
        assert_eq!(counters.errored.load(Ordering::Relaxed), 2);
        // Failed jobs release their admission slots.
        assert_eq!(*handle.inflight.lock().unwrap().get("tiny").unwrap(), 0);
        drop(handle);
        let _ = driver.join().unwrap();
    }

    #[test]
    fn shutdown_drains_queued_jobs_before_the_driver_exits() {
        let counters = Arc::new(Counters::default());
        let (handle, driver) = start(engine(), 8, 8, counters.clone()).unwrap();
        let receivers: Vec<_> =
            (0..4).map(|_| handle.submit("tiny", vec![0.25; 32]).unwrap()).collect();
        // Drop the last submission handle immediately: the driver must
        // still answer everything already queued.
        drop(handle);
        for rx in receivers {
            let reply = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(reply.expect("queued job must drain").len(), 3);
        }
        let engine = driver.join().unwrap();
        assert_eq!(engine.stats().requests, 4);
        assert_eq!(counters.completed.load(Ordering::Relaxed), 4);
        assert_eq!(counters.queue_depth.load(Ordering::Relaxed), 0);
    }
}
